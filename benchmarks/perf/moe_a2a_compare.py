"""§Perf iteration c4 (beyond-paper): gather-EP vs all-to-all EP for the
qwen3 MoE train cell.  Run in its own process (512 host devices)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses, json, sys
from repro.launch.dryrun import lower_cell
from repro.launch import mesh as mesh_lib
from repro.configs.base import get_config, SHAPES
from repro.core.meshsig.hlo_counters import analyze_hlo

def measure(cfg, shape):
    mesh = mesh_lib.make_production_mesh()
    with mesh_lib.cell_context(mesh, cfg, shape):
        jitted, args, _ = lower_cell(cfg, shape, mesh)
        compiled = jitted.lower(*args).compile()
    a = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops": a.flops, "bytes": a.hbm_bytes,
        "link": a.collective_summary()["link_bytes_total"],
        "per_kind": {k: v["link_bytes"] for k, v in a.collective_summary()["per_kind"].items()},
        "temp_gb": mem.temp_size_in_bytes / 2**30,
    }

shape = SHAPES["train_4k"]
base = get_config("qwen3-moe-30b-a3b")
out = {}
for impl in ("gather", "a2a"):
    cfg = dataclasses.replace(base, moe_impl=impl)
    out[impl] = measure(cfg, shape)
    r = out[impl]
    print(f"{impl:7s} flops={r['flops']:.3e} bytes={r['bytes']:.3e} link={r['link']:.3e} temp={r['temp_gb']:.1f}GB", flush=True)
    print(f"        kinds: {({k: f'{v:.2e}' for k, v in r['per_kind'].items()})}", flush=True)
json_path = "benchmarks/dryrun_results/moe_a2a_compare.json"
json.dump(out, open(json_path, "w"), indent=1)
print("saved", json_path)
