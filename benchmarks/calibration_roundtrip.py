"""Closed-loop validation of the learned topology calibration (CI-gated).

For each preset under test this benchmark

1. simulates a probe sweep on the *known* machine (synthetic ground
   truth),
2. fits a machine blind from the samples alone
   (``repro.core.numa.calibrate.fit_from_simulated`` — the template keeps
   only structure: link list, routes, core rates, remote path bases),
3. reports the per-link bandwidth recovery error and the per-node local
   bandwidth recovery error, and
4. re-runs a placement sweep (``evaluate_batch``, same workloads /
   placements / noise keys) on both the ground-truth and the fitted
   machine and compares their median model errors.

CI runs this as a gated step: non-zero exit when any per-link relative
error exceeds ``--max-link-error`` or the refit sweep's median error
drifts more than ``--max-sweep-delta`` percentage points from the
ground-truth model's.  The ``--json`` artifact is uploaded alongside the
placement-sweep artifact for trending.

    PYTHONPATH=src python benchmarks/calibration_roundtrip.py \
        [--json OUT.json] [--steps 200] [--noise-std 0.0] \
        [--max-link-error 0.05] [--max-sweep-delta 0.25]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def roundtrip(
    machine,
    *,
    steps: int = 200,
    noise_std: float = 0.0,
    sweep_benchmarks: tuple[str, ...] = ("Swim", "CG", "EP", "NPO"),
    sweep_noise_std: float = 0.02,
    max_placements: int = 64,
) -> dict:
    """Fit one machine blind and score the recovery.  Returns a JSON-able
    record (also consumed by the test suite and the example)."""
    import jax
    import numpy as np

    from repro.core.numa.benchmarks import benchmark_workload
    from repro.core.numa.calibrate import (
        fit_from_simulated,
        link_relative_errors,
        local_bw_relative_errors,
    )
    from repro.core.numa.evaluate import evaluate_batch, sweep_placements

    t0 = time.time()
    result = fit_from_simulated(machine, steps=steps, noise_std=noise_std)
    fit_s = time.time() - t0

    link_err = link_relative_errors(result.machine, machine)
    local_err = local_bw_relative_errors(result.machine, machine)

    # Same workloads, placements and measurement-noise keys on both
    # machines: any median-error difference is purely the fitted
    # parameters' doing.
    # two nodes' worth of threads, rounded down so the 2-run profiling
    # fit can split them evenly over the machine's NUMA nodes
    n_threads = 2 * machine.cores_per_node
    n_threads -= n_threads % machine.n_nodes
    placements = sweep_placements(machine, n_threads, max_placements=max_placements)
    workloads = [benchmark_workload(b, n_threads) for b in sweep_benchmarks]
    keys = jax.numpy.stack(
        [jax.random.fold_in(jax.random.PRNGKey(0), i) for i in range(len(workloads))]
    )
    medians = {}
    for label, m in (("truth", machine), ("fit", result.machine)):
        batch = evaluate_batch(
            m, workloads, placements, noise_std=sweep_noise_std, keys=keys
        )
        errs = np.asarray(batch.errors_combined).reshape(-1) * 100.0
        medians[label] = float(np.median(errs))

    return {
        "machine": machine.name,
        "topology": machine.topology.name,
        "n_links": machine.n_links,
        "n_samples": None,  # filled below for reporting symmetry
        "steps": steps,
        "noise_std": noise_std,
        "fit_s": round(fit_s, 2),
        "seed_loss": float(result.seed_loss),
        "final_loss": float(result.final_loss),
        "max_link_error": float(link_err.max()),
        "median_link_error": float(np.median(link_err)),
        "max_local_read_error": float(local_err["read"].max()),
        "max_local_write_error": float(local_err["write"].max()),
        "hop_attenuation_fit": float(result.machine.hop_attenuation),
        "hop_attenuation_true": float(machine.hop_attenuation),
        "sweep_median_error_truth_pct": medians["truth"],
        "sweep_median_error_fit_pct": medians["fit"],
        "sweep_median_delta_pp": abs(medians["fit"] - medians["truth"]),
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", type=Path, default=None)
    parser.add_argument("--steps", type=int, default=200)
    parser.add_argument("--noise-std", type=float, default=0.0)
    parser.add_argument(
        "--max-link-error",
        type=float,
        default=0.05,
        help="gate: max allowed per-link relative recovery error",
    )
    parser.add_argument(
        "--max-sweep-delta",
        type=float,
        default=0.25,
        help="gate: max allowed |median sweep error(fit) - (truth)| in pp",
    )
    args = parser.parse_args()

    from repro.core.numa import E5_2699_V3_SNC2, E7_8860_V3
    from repro.core.numa.calibrate import probe_suite

    failures: list[str] = []
    records = []
    for machine in (E7_8860_V3, E5_2699_V3_SNC2):
        rec = roundtrip(machine, steps=args.steps, noise_std=args.noise_std)
        rec["n_samples"] = len(probe_suite(machine))
        records.append(rec)
        print(f"{rec['machine']}: fit {rec['fit_s']}s over {rec['n_samples']} samples")
        for k in (
            "max_link_error",
            "max_local_read_error",
            "max_local_write_error",
            "sweep_median_error_truth_pct",
            "sweep_median_error_fit_pct",
            "sweep_median_delta_pp",
        ):
            print(f"  {k}: {rec[k]:.6f}")
        if rec["max_link_error"] > args.max_link_error:
            failures.append(
                f"{rec['machine']}: per-link recovery error "
                f"{rec['max_link_error']:.4f} > {args.max_link_error}"
            )
        if rec["sweep_median_delta_pp"] > args.max_sweep_delta:
            failures.append(
                f"{rec['machine']}: refit sweep median drifted "
                f"{rec['sweep_median_delta_pp']:.4f}pp > {args.max_sweep_delta}"
            )

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(records, indent=2) + "\n")
        print(f"wrote {args.json}")

    if failures:
        for msg in failures:
            print(f"CALIBRATION REGRESSION: {msg}", file=sys.stderr)
        raise SystemExit(1)
    print("calibration round-trip gate passed")


if __name__ == "__main__":
    main()
