"""Throughput benchmark for the batched multi-socket placement-sweep engine.

Sweeps one-thread-per-core placements through the single jitted
``evaluate_batch`` trace and reports

* placements/sec (fit + simulate + predict + error, per placement,
  steady-state after compilation), and
* the median model error as % of run bandwidth (paper's headline metric:
  2.34% at s = 2).

Three machines are swept: the fully-connected quad-socket preset (1469
compositions of 24 threads — the paper's §6.2.2 protocol at beyond-paper
socket count), the glued 8-socket preset, whose node-controller topology
routes cross-quad traffic over 2 links (a deterministic budget samples
its combinatorial placement space), and the SNC-2 variant of the 18-core
2-socket machine, whose 4 half-socket NUMA nodes share one QPI port per
socket.

Run directly:

    PYTHONPATH=src python benchmarks/placement_sweep.py [--json OUT.json]

``--json`` artifacts are uploaded by CI and gated against the committed
baseline (``benchmarks/sweep_baseline.json``) by
``benchmarks/check_sweep_regression.py``.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np


def numa_placement_sweep(
    machine=None,
    n_threads: int | None = None,
    *,
    benchmarks: tuple[str, ...] = ("Swim", "CG", "EP", "NPO"),
    noise_std: float = 0.02,
    min_placements: int = 500,
    max_placements: int | None = None,
) -> tuple[float, dict]:
    """Returns ``(placements_per_sec, details)`` for the harness."""
    from repro.core.numa import E7_4830_V3
    from repro.core.numa.benchmarks import benchmark_workload
    from repro.core.numa.evaluate import evaluate_batch, sweep_placements

    if machine is None:
        machine = E7_4830_V3
    if n_threads is None:
        n_threads = 2 * machine.cores_per_node  # the largest sweep space

    placements = sweep_placements(
        machine, n_threads, max_placements=max_placements
    )
    n_p = placements.shape[0]
    assert n_p >= min_placements, (n_p, min_placements)
    workloads = [benchmark_workload(b, n_threads) for b in benchmarks]
    keys = jax.numpy.stack(
        [jax.random.fold_in(jax.random.PRNGKey(0), i) for i in range(len(workloads))]
    )

    def run():
        batch = evaluate_batch(
            machine, workloads, placements, noise_std=noise_std, keys=keys
        )
        jax.block_until_ready(batch.errors_combined)
        return batch

    t0 = time.time()
    batch = run()  # includes compilation
    compile_s = time.time() - t0
    t0 = time.time()
    batch = run()  # steady state (one cached trace)
    steady_s = time.time() - t0

    evaluated = n_p * len(workloads)
    errors_pct = np.asarray(batch.errors_combined).reshape(-1) * 100.0
    details = {
        "machine": machine.name,
        "topology": machine.topology.name,
        "n_links": machine.n_links,
        "max_hops": machine.topology.max_hops,
        "sockets": machine.sockets,
        "n_nodes": machine.n_nodes,
        "n_threads": n_threads,
        "placements": n_p,
        "benchmarks": len(workloads),
        "median_error_pct": round(float(np.median(errors_pct)), 4),
        "p95_error_pct": round(float(np.percentile(errors_pct, 95)), 4),
        "compile_s": round(compile_s, 3),
        "steady_s": round(steady_s, 3),
    }
    return evaluated / steady_s, details


def glued8s_placement_sweep(
    *, max_placements: int = 512, **kwargs
) -> tuple[float, dict]:
    """The routed 8-socket sweep: cross-quad flows charge both links of
    their node-controller route and pay the per-hop remote attenuation."""
    from repro.core.numa import E7_8860_V3

    kwargs.setdefault("min_placements", min(500, max_placements))
    return numa_placement_sweep(
        E7_8860_V3, max_placements=max_placements, **kwargs
    )


def snc2_placement_sweep(**kwargs) -> tuple[float, dict]:
    """The sub-NUMA-clustered sweep: the 18-core 2-socket machine in SNC-2
    mode places 16 threads over 4 half-socket NUMA nodes (633 compositions
    under the 9-core per-node cap); cross-socket traffic from a
    non-endpoint node routes through its socket's shared QPI port."""
    from repro.core.numa import E5_2699_V3_SNC2

    kwargs.setdefault("min_placements", 500)
    return numa_placement_sweep(E5_2699_V3_SNC2, n_threads=16, **kwargs)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--json",
        type=Path,
        default=None,
        help="write results as a JSON artifact (for CI upload/trending)",
    )
    parser.add_argument(
        "--glued-max-placements",
        type=int,
        default=512,
        help="deterministic placement budget for the 8-socket sweep",
    )
    args = parser.parse_args()

    records = []
    for label, fn in (
        ("4-socket fully-connected", numa_placement_sweep),
        (
            "8-socket glued (routed)",
            lambda: glued8s_placement_sweep(
                max_placements=args.glued_max_placements
            ),
        ),
        ("2-socket SNC-2 (4 nodes)", snc2_placement_sweep),
    ):
        pps, details = fn()
        records.append({"sweep": label, "placements_per_sec": round(pps, 1), **details})
        print(f"{label}: placements/sec: {pps:,.0f}")
        for k, v in details.items():
            print(f"  {k}: {v}")

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(records, indent=2) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
