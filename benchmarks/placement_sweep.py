"""Throughput benchmark for the batched multi-socket placement-sweep engine.

Sweeps every one-thread-per-core placement on the quad-socket preset
(1469 compositions of 24 threads over 4 x 12 cores — the paper's §6.2.2
protocol at beyond-paper socket count) through the single jitted
``evaluate_batch`` trace and reports

* placements/sec (fit + simulate + predict + error, per placement,
  steady-state after compilation), and
* the median model error as % of run bandwidth (paper's headline metric:
  2.34% at s = 2).

Run directly:

    PYTHONPATH=src python benchmarks/placement_sweep.py
"""

from __future__ import annotations

import time

import jax
import numpy as np


def numa_placement_sweep(
    machine=None,
    n_threads: int | None = None,
    *,
    benchmarks: tuple[str, ...] = ("Swim", "CG", "EP", "NPO"),
    noise_std: float = 0.02,
    min_placements: int = 500,
) -> tuple[float, dict]:
    """Returns ``(placements_per_sec, details)`` for the harness."""
    from repro.core.numa import E7_4830_V3
    from repro.core.numa.benchmarks import benchmark_workload
    from repro.core.numa.evaluate import evaluate_batch, sweep_placements

    if machine is None:
        machine = E7_4830_V3
    if n_threads is None:
        n_threads = 2 * machine.cores_per_socket  # the largest sweep space

    placements = sweep_placements(machine, n_threads)
    n_p = placements.shape[0]
    assert n_p >= min_placements, (n_p, min_placements)
    workloads = [benchmark_workload(b, n_threads) for b in benchmarks]
    keys = jax.numpy.stack(
        [jax.random.fold_in(jax.random.PRNGKey(0), i) for i in range(len(workloads))]
    )

    def run():
        batch = evaluate_batch(
            machine, workloads, placements, noise_std=noise_std, keys=keys
        )
        jax.block_until_ready(batch.errors_combined)
        return batch

    t0 = time.time()
    batch = run()  # includes compilation
    compile_s = time.time() - t0
    t0 = time.time()
    batch = run()  # steady state (one cached trace)
    steady_s = time.time() - t0

    evaluated = n_p * len(workloads)
    errors_pct = np.asarray(batch.errors_combined).reshape(-1) * 100.0
    details = {
        "machine": machine.name,
        "sockets": machine.sockets,
        "n_threads": n_threads,
        "placements": n_p,
        "benchmarks": len(workloads),
        "median_error_pct": round(float(np.median(errors_pct)), 4),
        "p95_error_pct": round(float(np.percentile(errors_pct, 95)), 4),
        "compile_s": round(compile_s, 3),
        "steady_s": round(steady_s, 3),
    }
    return evaluated / steady_s, details


def main() -> None:
    pps, details = numa_placement_sweep()
    print(f"placements/sec: {pps:,.0f}")
    for k, v in details.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
