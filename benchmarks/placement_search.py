"""Time-to-solution benchmark for the placement *search* engines.

The sweep benchmark (``placement_sweep.py``) measures how fast the batched
engine scores every composition; this one measures how fast the search
modes find the *best* composition without scoring them all:

* ``optimize_placement`` — multi-start gradient ascent through the
  differentiable grouped solver, rounded and hill-polished;
* ``branch_and_bound`` — best-first over compositions under the
  admissible per-group roofline bound (certificate of optimality).

Three records are emitted:

* two exhaustively-checkable machines (the 4-socket preset and the SNC-2
  preset) where *regret* is measured against the true ``evaluate_batch``
  argmax over the full enumeration, and
* the 16-node SNC machine (8 sockets x 2 nodes, ~1.07e10 compositions)
  where no exhaustive reference exists: regret is measured against the
  branch-and-bound incumbent, itself certified within 1% by its bound,
  and the headline number is the gradient searcher's warm
  time-to-solution (< 1 s floor, gated in CI).

Run directly:

    PYTHONPATH=src python benchmarks/placement_search.py [--json OUT.json]

``--json`` artifacts are uploaded by CI next to the sweep artifact and
gated against ``benchmarks/sweep_baseline.json`` by
``benchmarks/check_sweep_regression.py`` (regret <= max_regret_pct,
time-to-solution <= max_time_to_solution_s).
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def search_record(
    label: str,
    machine,
    n_threads: int,
    *,
    benchmark: str = "CG",
    exhaustive_cap: int | None = 20_000,
    bnb_kwargs: dict | None = None,
) -> dict:
    """One benchmark record: warm gradient-search time-to-solution plus
    regret against the best available reference (exhaustive argmax when
    the space fits under ``exhaustive_cap``, else the certified
    branch-and-bound incumbent)."""
    from repro.core.numa import (
        branch_and_bound,
        exact_objectives,
        optimize_placement,
    )
    from repro.core.numa.benchmarks import benchmark_workload
    from repro.core.numa.evaluate import count_placements, enumerate_placements

    wl = benchmark_workload(benchmark, n_threads)
    space = count_placements(machine, n_threads)

    grad = optimize_placement(machine, wl)  # compile + first solve
    grad, time_grad = _timed(lambda: optimize_placement(machine, wl))
    bnb, time_bnb = _timed(
        lambda: branch_and_bound(
            machine, wl,
            seed_placements=[grad.placement],
            **(bnb_kwargs or {}),
        )
    )

    if space <= (exhaustive_cap or 0):
        placements = np.asarray(enumerate_placements(machine, n_threads))
        optimum = float(np.asarray(exact_objectives(machine, wl, placements)).max())
        regret_vs = "exhaustive"
    else:
        optimum = bnb.objective
        regret_vs = (
            f"bnb-incumbent(gap<={bnb_kwargs.get('gap', 0.0):.0%})"
            if bnb_kwargs else "bnb-incumbent"
        )
    regret_pct = max(0.0, (optimum - grad.objective) / optimum * 100.0)

    return {
        "sweep": label,
        "machine": machine.name,
        "n_nodes": machine.n_nodes,
        "n_threads": n_threads,
        "benchmark": benchmark,
        "search_space": space,
        "time_to_solution_s": round(time_grad, 4),
        "regret_pct": round(regret_pct, 4),
        "regret_vs": regret_vs,
        "evaluations": grad.evaluations,
        "objective": round(grad.objective, 1),
        "bnb_time_s": round(time_bnb, 4),
        "bnb_nodes": bnb.nodes_expanded,
        "bnb_optimal": bnb.optimal,
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--json",
        type=Path,
        default=None,
        help="write results as a JSON artifact (for CI upload/trending)",
    )
    args = parser.parse_args()

    from repro.core.numa import E5_2699_V3_SNC2, E7_4830_V3, make_machine

    m16 = make_machine(
        "snc2-8s", sockets=8, cores_per_socket=8, nodes_per_socket=2,
        qpi_bw=25.6e9,
    )
    records = [
        search_record(
            "placement-search 4-socket (vs exhaustive)", E7_4830_V3, 24
        ),
        search_record(
            "placement-search SNC-2 (vs exhaustive)", E5_2699_V3_SNC2, 16
        ),
        search_record(
            "placement-search 16-node SNC 8s",
            m16,
            32,
            exhaustive_cap=None,
            bnb_kwargs={"gap": 0.01, "max_nodes": 20_000},
        ),
    ]
    for rec in records:
        print(f"{rec['sweep']}:")
        for k, v in rec.items():
            if k != "sweep":
                print(f"  {k}: {v}")

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(records, indent=2) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
