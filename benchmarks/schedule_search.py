"""Schedule-search benchmark: what the time axis buys, and how fast.

The one-shot advisor answers "where should these threads run?"; the
scheduler (``repro.core.numa.temporal.optimize_schedule``) answers it
*per phase*, trading steady-state throughput against migration cost at
every phase boundary.  This benchmark pins the two numbers that make the
time axis worth shipping:

* **gain** — on a phased workload whose per-phase optima differ, the
  scheduler's total work must beat the best *static* placement (the
  one-shot answer held for the whole horizon) by at least the committed
  ``min_static_gain_pct`` whenever migration is cheap.  With migration
  priced out the gain must collapse to exactly the static answer
  (``gain_pct == 0`` — the DP's feasible set contains the static
  trajectory, so it can never do worse); and
* **time-to-solution** — the candidate-pool + DP/beam search must answer
  inside the committed ``max_time_to_solution_s`` (warm, after one
  compile pass), so ``advise_schedule`` stays interactive.

Records are gated against ``benchmarks/sweep_baseline.json`` by
``benchmarks/check_sweep_regression.py`` (a baseline record carrying
``min_static_gain_pct`` selects the schedule branch of the gate).

Run directly:

    PYTHONPATH=src python benchmarks/schedule_search.py [--json OUT.json]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def _flip_phases(n_threads: int, sockets: tuple[int, int], bpi: float = 5.0):
    """Two static-heavy phases whose hot buffer flips between sockets —
    the canonical workload the time axis exists for."""
    from repro.core.numa import mixed_workload

    return [
        (
            mixed_workload(
                f"phase-s{s}", n_threads,
                read_mix=(0.7, 0.1, 0.0), read_bpi=bpi, static_socket=s,
            ),
            5.0,
        )
        for s in sockets
    ]


def schedule_record(
    label: str,
    machine,
    phases,
    *,
    model=None,
    expect_static: bool = False,
) -> dict:
    """One benchmark record: warm schedule-search time plus the gain over
    the best static placement (and, on ``expect_static`` records, the
    degrade-to-static sanity number — the gain must be exactly zero)."""
    from repro.core.numa.temporal import optimize_schedule, phased_workload

    pw = phased_workload(label, phases)
    optimize_schedule(machine, pw, model=model)  # compile + first solve
    t0 = time.perf_counter()
    res = optimize_schedule(machine, pw, model=model)
    elapsed = time.perf_counter() - t0

    return {
        "sweep": label,
        "machine": machine.name,
        "n_nodes": machine.n_nodes,
        "n_threads": pw.n_threads,
        "phases": len(pw.phases),
        "gain_pct": round(res.gain_pct, 4),
        "time_to_solution_s": round(elapsed, 4),
        "candidates": res.candidates,
        "states_expanded": res.states_expanded,
        "moved_threads": sum(res.schedule.moved_threads),
        "moved_pages": sum(res.schedule.moved_pages),
        "static_matches": res.gain_pct == 0.0 if expect_static else None,
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--json",
        type=Path,
        default=None,
        help="write results as a JSON artifact (for CI upload/gating)",
    )
    args = parser.parse_args()

    from repro.core.numa import E5_2630_V3, E7_4830_V3, mixed_workload
    from repro.core.numa.temporal import MigrationModel

    cheap = MigrationModel(thread_move_bytes=1e6, page_move_bytes=1e6)
    prohibitive = MigrationModel(thread_move_bytes=1e13, page_move_bytes=1e13)

    tri_phases = [
        (
            mixed_workload(
                "tri-s0", 24, read_mix=(0.7, 0.1, 0.0), read_bpi=4.0,
                static_socket=0,
            ),
            4.0,
        ),
        (
            mixed_workload(
                "tri-s2", 24, read_mix=(0.7, 0.1, 0.0), read_bpi=4.0,
                static_socket=2,
            ),
            4.0,
        ),
        (
            mixed_workload("tri-local", 24, read_mix=(0.1, 0.6, 0.1),
                           read_bpi=4.0),
            2.0,
        ),
    ]

    records = [
        schedule_record(
            "schedule-search 2-socket flip (cheap migration)",
            E5_2630_V3,
            _flip_phases(8, (0, 1)),
            model=cheap,
        ),
        schedule_record(
            "schedule-search 2-socket flip (prohibitive migration)",
            E5_2630_V3,
            _flip_phases(8, (0, 1)),
            model=prohibitive,
            expect_static=True,
        ),
        schedule_record(
            "schedule-search 4-socket 3-phase (cheap migration)",
            E7_4830_V3,
            tri_phases,
            model=cheap,
        ),
    ]
    for rec in records:
        print(f"{rec['sweep']}:")
        for k, v in rec.items():
            if k != "sweep" and v is not None:
                print(f"  {k}: {v}")

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(records, indent=2) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
