"""Throughput + tail-latency benchmark for the placement-advisor service.

Drives open-loop load against an :class:`repro.serve.AdvisorService` in
four phases and emits one record per phase, gated in CI against
``benchmarks/sweep_baseline.json`` by ``check_sweep_regression.py``:

* **cache-hit** — a hot signature set served from the tier-1 LRU; commits
  a ``min_qps`` floor (>= 10x the miss-path floor: the cache must earn
  its place) and a ``max_p99_ms`` ceiling.
* **miss-batched** — distinct signatures submitted open-loop so
  concurrent misses coalesce; commits the batched-sweep qps floor, a p99
  ceiling and a mean-batch-size floor (coalescing actually happening).
* **search-fallback** — fresh queries against a 16-node machine whose
  composition space (~1.07e10) exceeds any sweep; answered by
  advisor-warm-started branch and bound.
* **mixed** — a 1000-query hit/miss/search stream over warmed machines;
  commits qps + p99 AND ``max_retraces = 0``: steady-state serving must
  not retrace, whatever the stream's batching pattern.

Run directly:

    PYTHONPATH=src python benchmarks/advisor_serve.py [--json OUT.json]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def serve_records(
    *,
    n_hot: int = 32,
    n_hits: int = 2000,
    n_miss: int = 256,
    n_search: int = 4,
    n_mixed: int = 1000,
    max_batch: int = 8,
    max_wait_ms: float = 2.0,
    workers: int = 4,
) -> list[dict]:
    from repro.core.numa import E7_4830_V3, make_machine
    from repro.launch.advisor_serve import (
        drive_async,
        drive_threads,
        mixed_stream,
        signature_pool,
    )
    from repro.serve import AdvisorService

    service = AdvisorService(
        max_batch=max_batch, max_wait_s=max_wait_ms / 1e3
    )
    sweep_fp = service.register(E7_4830_V3)
    m16 = make_machine(
        "snc2-8s", sockets=8, cores_per_socket=8, nodes_per_socket=2,
        qpi_bw=25.6e9,
    )
    search_fp = service.register(m16)

    hot = signature_pool(n_hot, seed=0)
    miss_sigs = signature_pool(n_miss, seed=7)
    mixed_fresh = signature_pool(n_mixed, seed=11)
    search_sigs = signature_pool(max(2, n_search), seed=13)

    # -- warmup: trace each group's single steady-state shape, pre-answer
    # the hot set, and warm the search path (fit + B&B jit caches)
    service.warmup(sweep_fp, 24)
    for sig in hot:
        service.query(sweep_fp, sig, 24)
    service.query(search_fp, search_sigs[0], 32)
    service.metrics.reset(keep_traces=True)

    records: list[dict] = []

    def phase_record(sweep: str, n_queries: int, wall: float,
                     tier: str) -> dict:
        snap = service.metrics.snapshot()
        rec = {
            "sweep": sweep,
            "queries": n_queries,
            "qps": round(n_queries / wall, 1),
            "wall_s": round(wall, 4),
            "p50_ms": round(snap.get(f"{tier}_p50_ms", float("nan")), 4),
            "p99_ms": round(snap.get(f"{tier}_p99_ms", float("nan")), 4),
            "retraces": snap["retraces"],
        }
        if tier == "batch":
            rec["mean_batch_size"] = round(snap["mean_batch_size"], 2)
        service.metrics.reset(keep_traces=True)
        return rec

    # -- phase 1: cache hits (closed-loop threads over the hot set)
    stream = [
        (sweep_fp, hot[i % n_hot], 24) for i in range(n_hits)
    ]
    _, wall = drive_threads(service, stream, n_workers=workers)
    records.append(
        phase_record("advisor-serve cache-hit", n_hits, wall, "cache")
    )

    # -- phase 2: batched misses (open-loop submit, coalesced)
    stream = [(sweep_fp, sig, 24) for sig in miss_sigs]
    _, wall = drive_async(service, stream)
    records.append(
        phase_record("advisor-serve miss-batched", n_miss, wall, "batch")
    )

    # -- phase 3: search fallback (fresh signatures, warm search path)
    stream = [(search_fp, sig, 32) for sig in search_sigs[:n_search]]
    _, wall = drive_threads(service, stream, n_workers=2)
    records.append(
        phase_record("advisor-serve search-fallback", n_search, wall, "search")
    )

    # -- phase 4: mixed 1k-query stream; the retrace counter must stay 0
    stream = mixed_stream(
        hot, mixed_fresh, search_sigs[:n_search], n_mixed,
        sweep_target=(sweep_fp, 24), search_target=(search_fp, 32),
        hit_fraction=0.8, search_fraction=0.02,
    )
    snap_before = service.metrics.snapshot()
    _, wall = drive_threads(service, stream, n_workers=workers)
    snap = service.metrics.snapshot()
    rec = {
        "sweep": "advisor-serve mixed",
        "queries": n_mixed,
        "qps": round(n_mixed / wall, 1),
        "wall_s": round(wall, 4),
        "p50_ms": round(snap["p50_ms"], 4),
        "p99_ms": round(snap["p99_ms"], 4),
        "retraces": snap["retraces"] - snap_before["retraces"],
        "hit_rate": round(snap["tier_counts"]["cache"] / n_mixed, 3),
        "tier_counts": snap["tier_counts"],
        "mean_batch_size": round(snap["mean_batch_size"], 2),
    }
    records.append(rec)

    service.close()
    return records


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--json",
        type=Path,
        default=None,
        help="write results as a JSON artifact (for CI upload/trending)",
    )
    args = parser.parse_args()

    records = serve_records()
    for rec in records:
        print(f"{rec['sweep']}:")
        for k, v in rec.items():
            if k != "sweep":
                print(f"  {k}: {v}")

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(records, indent=2) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
