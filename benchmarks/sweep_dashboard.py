"""Trend dashboard over the CI placement-sweep artifact history.

CI uploads one ``placement-sweep-<sha>-<run_id>`` JSON artifact per
push/nightly run (and gates each against the committed baseline).  This
script turns the *history* of those artifacts into the dashboard the
ROADMAP asked for: per-sweep median-error-over-time aggregation rendered
as a markdown table with unicode sparklines, written to
``$GITHUB_STEP_SUMMARY`` (so every run's summary page shows the trend)
and to an uploaded artifact of its own.

The workflow downloads the artifact history with ``gh api`` into a
directory of ``<created_at>__<artifact-name>/placement_sweep.json``
entries (see ``.github/workflows/ci.yml``); locally any directory whose
(sorted) entries contain ``*.json`` sweep records works:

    PYTHONPATH=src python benchmarks/sweep_dashboard.py sweep-history \
        [--current sweep-results/placement_sweep.json] \
        [--output sweep_dashboard.md] [--summary "$GITHUB_STEP_SUMMARY"]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

_SPARK_LEVELS = "▁▂▃▄▅▆▇█"


def sparkline(values: list[float]) -> str:
    """Render a series as unicode block characters (min..max normalized;
    a flat series renders mid-level)."""
    if not values:
        return ""
    lo, hi = min(values), max(values)
    if hi <= lo:
        return _SPARK_LEVELS[3] * len(values)
    span = hi - lo
    out = []
    for v in values:
        idx = int((v - lo) / span * (len(_SPARK_LEVELS) - 1) + 0.5)
        out.append(_SPARK_LEVELS[idx])
    return "".join(out)


def load_history(
    history_dir: Path, current: Path | list[Path] | None = None
) -> list[dict]:
    """Collect sweep-record lists in run order.

    Each entry of ``history_dir`` (sorted by name — the workflow prefixes
    directory names with the artifact's ``created_at`` timestamp, so
    lexicographic == chronological) contributes its JSON files; the
    ``current`` artifact(s) — this run may write several (placement sweep
    + mesh advisor), merged into one "current" point — are appended last.
    Returns ``[{"run": label, "records": [sweep records]}]``; unreadable
    or non-sweep JSON files are skipped (artifact history can contain
    partial uploads from failed runs)."""
    runs: list[dict] = []
    if history_dir.is_dir():
        for entry in sorted(history_dir.iterdir()):
            paths = sorted(entry.glob("**/*.json")) if entry.is_dir() else [entry]
            records: list[dict] = []
            for path in paths:
                if path.suffix != ".json":
                    continue
                try:
                    data = json.loads(path.read_text())
                except (OSError, json.JSONDecodeError):
                    continue
                if isinstance(data, list):
                    records.extend(
                        r for r in data if isinstance(r, dict) and "sweep" in r
                    )
            if records:
                runs.append({"run": entry.name, "records": records})
    currents = (
        [] if current is None
        else current if isinstance(current, list)
        else [current]
    )
    records = []
    for path in currents:
        if not path.exists():
            continue
        data = json.loads(path.read_text())
        records.extend(r for r in data if isinstance(r, dict) and "sweep" in r)
    if records:
        runs.append({"run": "current", "records": records})
    return runs


def aggregate(runs: list[dict]) -> dict[str, dict]:
    """Per-sweep time series over the run history.

    Returns ``{sweep label: {"errors": [...], "pps": [...], "runs":
    [...]}}`` with one point per run that reported the sweep (machines
    added later simply have shorter series).  ``placement-search``
    records (``regret_pct`` / ``time_to_solution_s`` instead of error /
    throughput — see ``benchmarks/placement_search.py``) aggregate into
    ``regret`` / ``tts`` series instead, ``advisor-serve`` records
    (``benchmarks/advisor_serve.py``) into ``qps`` / ``p99`` series,
    ``schedule-search`` records into ``gain`` / ``stts`` series, and
    ``serve-resilience`` records into one headline-metric series each
    (degraded rate, recovery seconds, torn reads)."""
    series: dict[str, dict] = {}
    for run in runs:
        by_sweep = {rec["sweep"]: rec for rec in run["records"]}
        for sweep, rec in by_sweep.items():
            if (
                "degraded_rate" in rec
                or "recovery_s" in rec
                or "torn_reads" in rec
            ):
                # resilience record (benchmarks/serve_resilience.py);
                # checked before qps — the chaos record carries qps too.
                # Each record trends one headline metric.
                if "degraded_rate" in rec:
                    metric, val = "degraded_rate", rec["degraded_rate"]
                elif "recovery_s" in rec:
                    metric, val = "recovery_s", rec["recovery_s"]
                else:
                    metric, val = "torn_reads", rec["torn_reads"]
                s = series.setdefault(
                    sweep, {"resilience": [], "metric": metric, "runs": []}
                )
                s["resilience"].append(float(val))
            elif "qps" in rec:
                s = series.setdefault(
                    sweep, {"qps": [], "p99": [], "runs": []}
                )
                s["qps"].append(float(rec["qps"]))
                s["p99"].append(float(rec.get("p99_ms", 0.0)))
            elif "gain_pct" in rec:
                # schedule-search record (benchmarks/schedule_search.py)
                s = series.setdefault(
                    sweep, {"gain": [], "stts": [], "runs": []}
                )
                s["gain"].append(float(rec["gain_pct"]))
                s["stts"].append(float(rec.get("time_to_solution_s", 0.0)))
            elif "regret_pct" in rec:
                s = series.setdefault(
                    sweep, {"regret": [], "tts": [], "runs": []}
                )
                s["regret"].append(float(rec["regret_pct"]))
                s["tts"].append(float(rec.get("time_to_solution_s", 0.0)))
            else:
                s = series.setdefault(
                    sweep, {"errors": [], "pps": [], "runs": []}
                )
                s["errors"].append(float(rec["median_error_pct"]))
                s["pps"].append(float(rec.get("placements_per_sec", 0.0)))
            s["runs"].append(run["run"])
    return series


def render_markdown(series: dict[str, dict]) -> str:
    """The dashboard: one row per sweep with the latest median error, the
    delta against the previous run, series extremes and a sparkline;
    placement-search rows trend regret and warm time-to-solution;
    advisor-serve rows trend phase qps and p99 latency; schedule-search
    rows trend static gain; serve-resilience rows trend their headline
    metric (degraded rate / recovery time / torn reads)."""
    sweeps = sorted(k for k, s in series.items() if "errors" in s)
    searches = sorted(k for k, s in series.items() if "regret" in s)
    lines = [
        "## Placement-sweep trend",
        "",
        "| sweep | runs | median err % (latest) | Δ vs prev | best | worst | trend |",
        "| --- | ---: | ---: | ---: | ---: | ---: | --- |",
    ]
    if not series:
        lines.append("| _no sweep artifacts found_ | | | | | | |")
        return "\n".join(lines) + "\n"
    for sweep in sweeps:
        errs = series[sweep]["errors"]
        latest = errs[-1]
        delta = latest - errs[-2] if len(errs) > 1 else 0.0
        lines.append(
            f"| {sweep} | {len(errs)} | {latest:.4f} | {delta:+.4f} "
            f"| {min(errs):.4f} | {max(errs):.4f} | `{sparkline(errs)}` |"
        )
    lines += [
        "",
        "Throughput (placements/sec; floors are gated, the trend is "
        "informational — runner speed varies):",
        "",
        "| sweep | latest | x vs first run | trend |",
        "| --- | ---: | ---: | --- |",
    ]
    for sweep in sweeps:
        pps = series[sweep]["pps"]
        ratio = f"x{pps[-1] / pps[0]:.1f}" if pps[0] else "–"
        lines.append(
            f"| {sweep} | {pps[-1]:,.0f} | {ratio} | `{sparkline(pps)}` |"
        )
    if searches:
        lines += [
            "",
            "Placement search (optimizer regret vs best-known reference, "
            "and warm time-to-solution; both gated):",
            "",
            "| search | runs | regret % (latest) | worst | time-to-solution s (latest) | trend (tts) |",
            "| --- | ---: | ---: | ---: | ---: | --- |",
        ]
        for sweep in searches:
            regret, tts = series[sweep]["regret"], series[sweep]["tts"]
            lines.append(
                f"| {sweep} | {len(regret)} | {regret[-1]:.4f} "
                f"| {max(regret):.4f} | {tts[-1]:.3f} | `{sparkline(tts)}` |"
            )
    serves = sorted(k for k, s in series.items() if "qps" in s)
    if serves:
        lines += [
            "",
            "Advisor service (throughput + tail latency per phase; qps "
            "floors, p99 ceilings and the zero-retrace bar are gated):",
            "",
            "| phase | runs | qps (latest) | x vs first run | p99 ms (latest) | worst p99 | trend (qps) |",
            "| --- | ---: | ---: | ---: | ---: | ---: | --- |",
        ]
        for sweep in serves:
            qps, p99 = series[sweep]["qps"], series[sweep]["p99"]
            ratio = f"x{qps[-1] / qps[0]:.1f}" if qps[0] else "–"
            lines.append(
                f"| {sweep} | {len(qps)} | {qps[-1]:,.0f} | {ratio} "
                f"| {p99[-1]:.3f} | {max(p99):.3f} | `{sparkline(qps)}` |"
            )
    schedules = sorted(k for k, s in series.items() if "gain" in s)
    if schedules:
        lines += [
            "",
            "Schedule search (gain over the best static placement and "
            "warm time-to-solution; floors/caps are gated):",
            "",
            "| schedule | runs | gain % (latest) | best | time-to-solution s (latest) | trend (gain) |",
            "| --- | ---: | ---: | ---: | ---: | --- |",
        ]
        for sweep in schedules:
            gain, stts = series[sweep]["gain"], series[sweep]["stts"]
            lines.append(
                f"| {sweep} | {len(gain)} | {gain[-1]:.4f} "
                f"| {max(gain):.4f} | {stts[-1]:.3f} | `{sparkline(gain)}` |"
            )
    resil = sorted(k for k, s in series.items() if "resilience" in s)
    if resil:
        lines += [
            "",
            "Serve resilience (chaos degraded-answer rate, post-fault "
            "recovery time, hot-swap torn reads; all gated):",
            "",
            "| record | runs | metric | latest | worst | trend |",
            "| --- | ---: | --- | ---: | ---: | --- |",
        ]
        for sweep in resil:
            vals = series[sweep]["resilience"]
            metric = series[sweep]["metric"]
            lines.append(
                f"| {sweep} | {len(vals)} | {metric} | {vals[-1]:.4g} "
                f"| {max(vals):.4g} | `{sparkline(vals)}` |"
            )
    return "\n".join(lines) + "\n"


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "history", type=Path, help="directory of downloaded sweep artifacts"
    )
    parser.add_argument(
        "--current",
        type=Path,
        action="append",
        default=None,
        help="this run's sweep artifact(s); repeatable — all records merge "
        "into the newest point",
    )
    parser.add_argument("--output", type=Path, default=None)
    parser.add_argument(
        "--summary",
        type=Path,
        default=None,
        help="append the dashboard to this file ($GITHUB_STEP_SUMMARY)",
    )
    args = parser.parse_args()

    runs = load_history(args.history, args.current)
    md = render_markdown(aggregate(runs))
    print(md)
    if args.output is not None:
        args.output.parent.mkdir(parents=True, exist_ok=True)
        args.output.write_text(md)
        print(f"wrote {args.output}")
    if args.summary is not None:
        with args.summary.open("a") as fh:
            fh.write(md)


if __name__ == "__main__":
    main()
