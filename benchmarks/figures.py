"""One benchmark per paper figure/table (deliverable d).

Each function returns ``(derived_metric, details)`` where the derived
metric is the figure's headline number; ``benchmarks.run`` times them and
emits the ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bwsig import (
    DirectionSignature,
    fit_signature,
    misfit_score,
    placement_matrix,
    predict_counters,
)
from repro.core.numa import (
    E5_2630_V3,
    E5_2699_V3,
    mixed_workload,
    profile_pair,
    pure_workload,
    simulate,
)
from repro.core.numa.benchmarks import benchmark_workload, suite_names
from repro.core.numa.evaluate import (
    evaluate_accuracy,
    evaluate_stability,
    evaluate_suite,
)


def fig01_placement_speedups():
    """Figure 1: speedup of thread/memory placements on the two machines.

    Placements: memory on socket 1 / interleaved / local x threads on one
    socket / both.  Derived: the 8-core machine's worst/best slowdown
    (paper: ~3x) vs the 18-core machine's (paper: 'far more forgiving')."""
    rows = {}
    for machine, n in ((E5_2630_V3, 8), (E5_2699_V3, 18)):
        runs = {}
        for mem, pattern, socket in (
            ("first", "static", 0),
            ("interleave", "interleaved", 0),
            ("local", "local", 0),
        ):
            # memory-intensive: per-thread demand ~7 GB/s saturates the
            # links exactly like the paper's index-chasing benchmark
            wl = pure_workload(mem, n, pattern, read_bpi=3.0, static_socket=socket)
            for threads, placement in (
                ("1socket", [n, 0]),
                ("2sockets", [n // 2, n - n // 2]),
            ):
                res = simulate(machine, wl, jnp.asarray(placement, jnp.int32))
                runs[f"{mem}/{threads}"] = float(res.throughput)
        slowest = min(runs.values())
        rows[machine.name] = {k: v / slowest for k, v in runs.items()}
    spread_8 = max(rows[E5_2630_V3.name].values())
    spread_18 = max(rows[E5_2699_V3.name].values())
    return spread_8 / spread_18, rows


def fig02_machine_bandwidths():
    """Figure 2: remote/local bandwidth ratios of the simulated machines
    match the paper's measured ratios by construction; derived = max
    deviation from the paper's numbers (0 = exact)."""
    paper = {
        E5_2630_V3.name: (0.16, 0.23),
        E5_2699_V3.name: (0.59, 0.83),
    }
    dev = 0.0
    details = {}
    for m in (E5_2630_V3, E5_2699_V3):
        # node_local_bw: robust to per-node local-bandwidth tuples (the
        # paper machines are scalar, where the mean is the scalar itself)
        rr = m.remote_read_bw / float(np.asarray(m.node_local_bw("read")).mean())
        rw = m.remote_write_bw / float(np.asarray(m.node_local_bw("write")).mean())
        pr, pw = paper[m.name]
        dev = max(dev, abs(rr - pr), abs(rw - pw))
        details[m.name] = {"remote_read_ratio": rr, "remote_write_ratio": rw}
    return dev, details


def fig05_worked_example():
    """Figure 5: the worked example's combined placement matrix.
    Derived: max |entry - paper value|."""
    sig = DirectionSignature.make(1, 0.2, 0.35, 0.3)
    m = np.asarray(placement_matrix(sig, jnp.asarray([3, 1])))
    paper = np.array([[0.65, 0.35], [0.30, 0.70]])
    return float(np.abs(m - paper).max()), {"matrix": m.tolist()}


def fig12_synthetic_signatures():
    """§6.1 / Figure 12: pure synthetic benchmarks on both machines.
    Derived: worst miscategorized bandwidth fraction (paper: <0.9%)."""
    worst = 0.0
    details = {}
    for machine, n in ((E5_2630_V3, 8), (E5_2699_V3, 16)):
        for pattern in ("static", "local", "interleaved", "per_thread"):
            wl = pure_workload(pattern, n, pattern)
            sym, asym = profile_pair(machine, wl)
            sig = fit_signature(sym, asym)
            got = np.array(
                [
                    float(sig.read.static_fraction),
                    float(sig.read.local_fraction),
                    float(sig.read.per_thread_fraction),
                ]
            )
            want = {
                "static": [1, 0, 0],
                "local": [0, 1, 0],
                "per_thread": [0, 0, 1],
                "interleaved": [0, 0, 0],
            }[pattern]
            mis = 0.5 * (
                np.abs(got - np.array(want, float)).sum()
                + abs((1 - got.sum()) - (1 - sum(want)))
            )
            worst = max(worst, float(mis))
            details[f"{machine.name}/{pattern}"] = float(mis)
    return worst, details


def fig13_15_stability():
    """Figures 13-15: signature stability across the two machines.
    Derived: mean combined-signature change % (paper: mean 6.8%, median
    4.2% on real hardware; the simulator's only cross-machine variation is
    saturation-induced rate asymmetry, so ours must come in below)."""
    r = evaluate_stability(E5_2630_V3, E5_2699_V3, noise_std=0.01)
    changes = sorted(r.combined_change.values())
    cdf = {
        "p50": float(np.percentile(changes, 50)),
        "p75": float(np.percentile(changes, 75)),
        "p90": float(np.percentile(changes, 90)),
    }
    return r.mean_combined_pct, {"median": r.median_combined_pct, "cdf": cdf}


def fig16_misfit_detection():
    """Figure 16 / §6.2.1: Page-rank-like violator — prediction error and
    the redundancy detector.  Derived: detector score ratio
    (violator / well-behaved); large = clean separation."""
    good = benchmark_workload("Swim", 16)
    bad = benchmark_workload("Page rank", 16)
    res_good = evaluate_accuracy(E5_2699_V3, good)
    res_bad = evaluate_accuracy(E5_2699_V3, bad)
    ratio = float(res_bad.misfit) / max(float(res_good.misfit), 1e-9)
    return ratio, {
        "violator_mean_err_pct": float(np.mean(np.asarray(res_bad.errors_combined))) * 100,
        "good_mean_err_pct": float(np.mean(np.asarray(res_good.errors_combined))) * 100,
        "violator_misfit": float(res_bad.misfit),
        "good_misfit": float(res_good.misfit),
    }


def fig17_accuracy_cdf():
    """Figure 17 / §6.2.2: error CDF over every benchmark x placement x
    counter, with realistic counter noise.  Derived: median error % of
    bandwidth (paper: 2.34%; ours must be <= since our ground truth is
    in-model except the violator)."""
    r = evaluate_suite(E5_2699_V3, noise_std=0.02)
    e = r.all_errors
    return r.median_error_pct, {
        "n_measurements": int(e.size),
        "p50": float(np.percentile(e, 50)),
        "p75": float(np.percentile(e, 75)),
        "p90": float(np.percentile(e, 90)),
        "paper_median": 2.34,
    }


def fig18_error_vs_bandwidth():
    """Figure 18: per-benchmark mean error vs mean bandwidth.  Derived:
    Spearman-style sign — do large errors concentrate in low-bandwidth
    benchmarks (negative correlation, as the paper observes)?"""
    r = evaluate_suite(E5_2699_V3, noise_std=0.02)
    names, errs, bws = [], [], []
    for name, res in r.per_benchmark.items():
        names.append(name)
        errs.append(float(np.mean(np.asarray(res.errors_combined))) * 100)
        bws.append(float(np.mean(np.asarray(res.total_bw))))
    errs_a, bws_a = np.asarray(errs), np.asarray(bws)
    rank_e = errs_a.argsort().argsort().astype(float)
    rank_b = bws_a.argsort().argsort().astype(float)
    corr = float(np.corrcoef(rank_e, rank_b)[0, 1])
    top = sorted(zip(errs, names), reverse=True)[:3]
    return corr, {"highest_error_benchmarks": top}
