"""Chaos/resilience benchmark for the placement-advisor service.

Where ``benchmarks/advisor_serve.py`` commits what the service does when
*healthy* (qps floors, p99 ceilings, zero retraces), this benchmark
commits what it does when *unhealthy* — driven by the fault-injection
harness (:mod:`repro.serve.faults`) — and emits three records gated in
CI by ``check_sweep_regression.py``:

* **chaos-mixed** — a 1k mixed query stream with a per-query deadline
  while faults fire: slow and failing batch dispatches, batcher-thread
  deaths (self-healed), and search-attempt failures (absorbed by the
  retry ladder).  Commits: zero hangs (no query's wall time exceeds the
  deadline plus a grace bound), every answer fidelity-tagged, a ceiling
  on the degraded-answer rate and a qps floor under fire.
* **recovery** — the faults are cleared and fresh queries are issued
  until the exact tier answers again; commits a recovery-time ceiling
  (the committed "recovery-time floor" of the serving contract: the
  service must be back to exact-fidelity answers within it).
* **hot-swap** — a live recalibration cycle under a sustained query
  stream: a clean counter sweep from a drifted machine is ingested and
  hot-swapped in (epoch bump), then a guard-rejected refit is rolled
  back; commits exactly one swap, exactly one rollback, NaN-corrupted
  rows rejected at ingest, and ZERO torn reads — every (signature,
  epoch) pair observed by the stream maps to exactly one answer.

Run directly:

    PYTHONPATH=src python benchmarks/serve_resilience.py [--json OUT.json]
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path


def chaos_records(
    *,
    n_chaos: int = 1000,
    n_hot: int = 32,
    workers: int = 4,
    deadline_s: float = 0.25,
    hang_grace_s: float = 1.0,
    max_batch: int = 8,
) -> list[dict]:
    """Run the three resilience phases and return their records."""
    from repro.core.numa import E7_4830_V3, E5_2699_V3_SNC2, make_machine
    from repro.core.numa import calibrate as C
    from repro.launch.advisor_serve import signature_pool
    from repro.serve import (
        AdvisorService,
        FaultInjector,
        Recalibrator,
    )

    fi = FaultInjector()
    service = AdvisorService(
        max_batch=max_batch, max_wait_s=0.002, faults=fi,
        default_deadline_s=deadline_s,
    )
    sweep_fp = service.register(E7_4830_V3)
    m16 = make_machine(
        "snc2-8s", sockets=8, cores_per_socket=8, nodes_per_socket=2,
        qpi_bw=25.6e9,
    )
    search_fp = service.register(m16)

    hot = signature_pool(n_hot, seed=0)
    fresh = signature_pool(n_chaos, seed=7)
    search_sigs = signature_pool(4, seed=13)

    # warm every path the chaos phase will exercise, including the
    # degradation ladder's ranked rung (warmup primes it)
    service.warmup(sweep_fp, 24)
    service.warmup(search_fp, 32, search_sigs[0])
    for sig in hot:
        service.query(sweep_fp, sig, 24)
    service.metrics.reset(keep_traces=True)

    records: list[dict] = []

    # -- phase 1: chaos-mixed ------------------------------------------------
    fi.inject_slow("batch", 0.3, times=12)
    fi.inject_error("batch", times=8)
    fi.inject_error("batcher", times=2)
    fi.inject_error("search", times=2)

    import numpy as np

    rng = np.random.default_rng(3)
    fresh_iter = iter(fresh)
    stream = []
    for _ in range(n_chaos):
        if rng.random() < 0.6:
            stream.append(hot[int(rng.integers(n_hot))])
        else:
            stream.append(next(fresh_iter))

    walls = [0.0] * n_chaos
    answers = [None] * n_chaos
    import itertools

    counter = itertools.count()

    def worker() -> None:
        while True:
            i = next(counter)
            if i >= n_chaos:
                return
            t0 = time.perf_counter()
            answers[i] = service.query(sweep_fp, stream[i], 24)
            walls[i] = time.perf_counter() - t0

    threads = [threading.Thread(target=worker) for _ in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    # one fresh search-tier query rides along: the injected search-attempt
    # failures must be absorbed by retry-with-backoff, not surface
    search_adv = service.query(search_fp, search_sigs[1], 32, deadline_s=30.0)
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    t_faults_cleared = time.perf_counter()
    fi.clear()

    from repro.serve.metrics import FIDELITIES

    degraded = sum(1 for a in answers if a.fidelity != "exact")
    hangs = sum(1 for w in walls if w > deadline_s + hang_grace_s)
    snap = service.metrics.snapshot()
    records.append({
        "sweep": "serve-resilience chaos-mixed",
        "queries": n_chaos,
        "qps": round(n_chaos / wall, 1),
        "wall_s": round(wall, 3),
        "deadline_ms": deadline_s * 1e3,
        "degraded_queries": degraded,
        "degraded_rate": round(degraded / n_chaos, 4),
        "hangs": hangs,
        "all_tagged": all(
            a is not None and a.fidelity in FIDELITIES for a in answers
        ),
        "worker_restarts": snap["worker_restarts"],
        "search_retry_ok": bool(
            search_adv.tier == "search" and search_adv.fidelity == "exact"
        ),
        "batch_faults_fired": fi.fired("batch"),
        "batcher_faults_fired": fi.fired("batcher"),
        "min_qps": 25,
        "max_degraded_rate": 0.5,
        "max_hangs": 0,
    })

    # -- phase 2: recovery ---------------------------------------------------
    # faults are cleared; issue fresh queries until the exact tier answers
    recovery_s = float("nan")
    probe = signature_pool(64, seed=23)
    for sig in probe:
        adv = service.query(sweep_fp, sig, 24, deadline_s=deadline_s)
        if adv.fidelity == "exact":
            recovery_s = time.perf_counter() - t_faults_cleared
            break
    records.append({
        "sweep": "serve-resilience recovery",
        "recovery_s": round(recovery_s, 3),
        "max_recovery_s": 10.0,
    })

    # -- phase 3: hot-swap under a sustained stream --------------------------
    truth = E5_2699_V3_SNC2
    # the serving spec starts drifted: remote links 25% under-reported
    drifted = truth._replace(
        remote_read_bw=truth.remote_read_bw * 0.75,
        remote_write_bw=truth.remote_write_bw * 0.75,
    )
    prod_fp = service.register(drifted, machine_id="prod-snc2")
    service.warmup(prod_fp, 8)
    swap_sigs = signature_pool(12, seed=31)

    observed: list[tuple] = []
    stop = threading.Event()

    def stream_worker() -> None:
        i = 0
        # cap bounds the audit log's memory; epoch coverage, not volume,
        # is what the torn-read check needs
        while not stop.is_set() and i < 100_000:
            sig = swap_sigs[i % len(swap_sigs)]
            adv = service.query(prod_fp, sig, 8)  # no deadline: exact only
            observed.append((
                i % len(swap_sigs), adv.epoch, adv.placement,
                adv.objective, adv.predicted_bandwidth,
            ))
            i += 1

    streamers = [threading.Thread(target=stream_worker) for _ in range(2)]
    for t in streamers:
        t.start()

    recal = Recalibrator(service, min_samples=16, fit_steps=150)
    clean = C.collect_sweep(
        truth, C.probe_suite(truth, n_threads=8), noise_std=0.01
    )
    recal.ingest(prod_fp, clean)
    accept_event = recal.recalibrate(prod_fp)

    # second cycle: corrupted rows at ingest + a guard pinned unmeetable
    # (demands a >=100pp improvement), so the refit is deterministically
    # rejected — the rollback path under test
    fi.inject_counter_corruption(fraction=0.25, times=1, seed=5)
    guard = Recalibrator(
        service, min_samples=16, fit_steps=20,
        max_error_regression_pp=-100.0,
    )
    diag = guard.ingest(prod_fp, C.collect_sweep(
        truth, C.probe_suite(truth, n_threads=8), noise_std=0.01
    ))
    reject_event = guard.recalibrate(prod_fp)
    fi.clear()

    time.sleep(0.2)  # let the stream straddle the post-rollback epoch too
    stop.set()
    for t in streamers:
        t.join()

    # torn-read audit: one answer per (signature, epoch) pair, ever
    by_key: dict[tuple, tuple] = {}
    torn = 0
    for sig_id, epoch, placement, obj, bw in observed:
        key = (sig_id, epoch)
        val = (placement, obj, bw)
        if key in by_key and by_key[key] != val:
            torn += 1
        by_key[key] = val

    snap = service.metrics.snapshot()
    records.append({
        "sweep": "serve-resilience hot-swap",
        "stream_queries": len(observed),
        "epochs_observed": sorted({e for _, e, _, _, _ in observed}),
        "swaps": snap["swaps"],
        "rollbacks": snap["rollbacks"],
        "swap_accepted": bool(accept_event.accepted),
        "swap_error_pct": round(accept_event.new_error_pct, 3),
        "reject_reason_guard": "regressed" in reject_event.reason
        or "improvement" in reject_event.reason
        or not reject_event.accepted,
        "nan_rejected": int(diag.n_rejected),
        "torn_reads": torn,
        "expected_swaps": 1,
        "expected_rollbacks": 1,
        "max_torn_reads": 0,
        "min_nan_rejected": 1,
    })

    service.close()
    return records


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--json",
        type=Path,
        default=None,
        help="write results as a JSON artifact (for CI upload/trending)",
    )
    args = parser.parse_args()

    records = chaos_records()
    for rec in records:
        print(f"{rec['sweep']}:")
        for k, v in rec.items():
            if k != "sweep":
                print(f"  {k}: {v}")

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(records, indent=2) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
