"""Mesh-advisor benchmark: routed-model throughput + scalar parity.

The graphtop unification re-based ``rank_meshes``'s collective term on a
routed :class:`DeviceTopology`.  This benchmark pins two things in CI,
mirroring what ``placement_sweep.py`` pins for the NUMA advisor:

* **Parity** — on a fully-connected uniform-bandwidth topology the routed
  model must agree with the scalar ``ici_bw`` roofline: per-candidate
  step-time error (``median_error_pct``, % of the scalar step time) and
  top-1 agreement are recorded, and the committed baseline gates the
  error via ``check_sweep_regression.py``.
* **Throughput** — candidates/sec through the routed advisor
  (``placements_per_sec``, so the sweep gate's absolute floor applies
  unchanged).  A regression here means per-candidate routing work leaked
  into the hot loop (incidence matrices are cached per graph and must
  stay so).

The signature is synthetic (the ``tests/test_meshsig.py`` ground-truth
generator): grad all-reduce on data, param all-gather on data, MoE
all-to-all on model — no compilation, so the benchmark runs in seconds.

    PYTHONPATH=src python benchmarks/mesh_rank.py [--json OUT.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

N_DEVICES = 16  # keep n^2 x links incidence matrices trivially small
REPS = 30


def synth_profile(axes: dict, *, grad_bytes=1e9, gather_bytes=5e8, a2a_base=2e9):
    """Ground truth: grad all-reduce on data (e=0), param all-gather on
    data (e=0), MoE all-to-all on model scaling 1/batch (e=1)."""
    from repro.core.meshsig.fit import MeshProfile, class_factor

    b = axes.get("data", 1) * axes.get("pod", 1)
    out = {}
    kd, km = axes["data"], axes["model"]
    out[("interleaved", "data")] = class_factor("interleaved", kd) * grad_bytes
    out[("static", "data")] = class_factor("static", kd) * gather_bytes
    out[("per_shard", "model")] = class_factor("per_shard", km) * a2a_base / b
    return MeshProfile(
        axis_sizes=dict(axes),
        class_axis_bytes=out,
        local_bytes=1e10 / b,
        flops=1e13 / b,
    )


def run() -> dict:
    from repro.core.meshsig.advisor import CHIP_V5E, rank_meshes
    from repro.core.meshsig.device_topology import nvlink_island
    from repro.core.meshsig.fit import fit_mesh_signature
    from repro.launch.mesh import candidate_mesh_axes

    sig = fit_mesh_signature(
        synth_profile({"data": 8, "model": 2}),
        synth_profile({"data": 4, "model": 4}),
    )
    candidates = candidate_mesh_axes(N_DEVICES)
    topo = nvlink_island(N_DEVICES, CHIP_V5E.ici_bw)

    scalar = rank_meshes(sig, candidates, chip=CHIP_V5E)
    routed = rank_meshes(sig, candidates, chip=CHIP_V5E, topology=topo)

    by_axes = lambda rs: {tuple(sorted(r.axis_sizes.items())): r for r in rs}
    s_by, r_by = by_axes(scalar), by_axes(routed)
    errors = sorted(
        abs(r_by[k].step_s - s_by[k].step_s) / s_by[k].step_s * 100
        for k in s_by
    )
    top1_agree = scalar[0].axis_sizes == routed[0].axis_sizes

    # Throughput: steady-state routed ranking (incidence matrices cached
    # per graph after the first pass — which already happened above).
    t0 = time.perf_counter()
    for _ in range(REPS):
        rank_meshes(sig, candidates, chip=CHIP_V5E, topology=topo)
    elapsed = time.perf_counter() - t0
    pps = REPS * len(candidates) / elapsed

    return {
        "sweep": "mesh-advisor routed (fc16)",
        "placements_per_sec": round(pps, 1),
        "topology": topo.name,
        "chip": CHIP_V5E.name,
        "n_devices": N_DEVICES,
        "candidates": len(candidates),
        "median_error_pct": round(errors[len(errors) // 2], 6),
        "max_error_pct": round(errors[-1], 6),
        "top1_agreement": bool(top1_agree),
        "elapsed_s": round(elapsed, 3),
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", type=Path, default=None, help="write records here")
    args = parser.parse_args()

    rec = run()
    print(
        f"{rec['sweep']}: {rec['candidates']} candidates, "
        f"{rec['placements_per_sec']:,.0f} candidates/s, parity median "
        f"{rec['median_error_pct']:.4f}% (max {rec['max_error_pct']:.4f}%), "
        f"top-1 {'agrees' if rec['top1_agreement'] else 'DISAGREES'}"
    )
    if not rec["top1_agreement"]:
        raise SystemExit("routed top-1 disagrees with scalar on uniform fc")
    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps([rec], indent=1))
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
