"""Gate a placement-sweep JSON artifact against the committed baseline.

CI runs ``placement_sweep.py --json`` on every push and nightly; this
script compares that artifact with ``benchmarks/sweep_baseline.json`` and
exits non-zero when the model's *median error* regresses beyond tolerance
on any sweep — the accuracy trend check ROADMAP asked for on top of the
uploaded artifact history.  Throughput (placements/sec) is reported for
trending but only enforced via the loose ``--min-pps-ratio`` floor (CI
runner speed varies run to run; the default 0 disables the gate, and the
in-repo perf floor lives in the test suite instead).

    PYTHONPATH=src python benchmarks/check_sweep_regression.py NEW.json \
        [--baseline benchmarks/sweep_baseline.json] \
        [--error-tolerance 0.25] [--min-pps-ratio 0.0]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = Path(__file__).resolve().parent / "sweep_baseline.json"


def check(
    new: list[dict],
    baseline: list[dict],
    *,
    error_tolerance: float,
    min_pps_ratio: float,
) -> list[str]:
    """Returns a list of failure messages (empty = pass)."""
    failures: list[str] = []
    base_by_sweep = {rec["sweep"]: rec for rec in baseline}
    new_by_sweep = {rec["sweep"]: rec for rec in new}
    for sweep, base in base_by_sweep.items():
        rec = new_by_sweep.get(sweep)
        if rec is None:
            failures.append(f"{sweep!r}: missing from the new artifact")
            continue
        err, base_err = rec["median_error_pct"], base["median_error_pct"]
        delta = err - base_err
        status = "OK" if delta <= error_tolerance else "FAIL"
        print(
            f"{sweep}: median_error_pct {base_err:.4f} -> {err:.4f} "
            f"({delta:+.4f}, tolerance {error_tolerance}) [{status}]"
        )
        if delta > error_tolerance:
            failures.append(
                f"{sweep!r}: median error regressed {base_err:.4f} -> {err:.4f} %"
            )
        pps, base_pps = rec["placements_per_sec"], base["placements_per_sec"]
        ratio = pps / base_pps if base_pps else float("inf")
        print(f"{sweep}: placements/sec {base_pps:.0f} -> {pps:.0f} (x{ratio:.2f})")
        if ratio < min_pps_ratio:
            failures.append(
                f"{sweep!r}: throughput fell to {ratio:.2f}x of baseline "
                f"(floor {min_pps_ratio}x)"
            )
    return failures


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("artifact", type=Path, help="placement_sweep --json output")
    parser.add_argument("--baseline", type=Path, default=BASELINE)
    parser.add_argument(
        "--error-tolerance",
        type=float,
        default=0.25,
        help="max allowed median-error increase, in absolute %% of bandwidth",
    )
    parser.add_argument(
        "--min-pps-ratio",
        type=float,
        default=0.0,
        help="fail when placements/sec falls below this fraction of baseline "
        "(0 disables — CI runner speed is not comparable across runs)",
    )
    args = parser.parse_args()

    new = json.loads(args.artifact.read_text())
    baseline = json.loads(args.baseline.read_text())
    failures = check(
        new,
        baseline,
        error_tolerance=args.error_tolerance,
        min_pps_ratio=args.min_pps_ratio,
    )
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        raise SystemExit(1)
    print("sweep trend check passed")


if __name__ == "__main__":
    main()
