"""Gate a placement-sweep JSON artifact against the committed baseline.

CI runs ``placement_sweep.py --json`` on every push and nightly; this
script compares that artifact with ``benchmarks/sweep_baseline.json`` and
exits non-zero when, on any sweep,

* the model's *median error* regresses beyond tolerance (the accuracy
  trend check ROADMAP asked for on top of the uploaded artifact
  history), or
* *throughput* (placements/sec) falls below the sweep's absolute
  ``min_placements_per_sec`` floor committed in the baseline.  The floor
  locks in the batched-engine speedups (the grouped solver and the
  shared-slab batching each contributed one 3x+ step): it is set
  conservatively so CI-runner speed variance cannot trip it, but a silent
  fallback to a slower path always will; or
* on a ``placement-search`` record (``benchmarks/placement_search.py``),
  the optimizer's *regret* against the best-known reference exceeds the
  committed ``max_regret_pct``, or its warm *time-to-solution* exceeds
  the committed ``max_time_to_solution_s`` (the 16-node record's < 1 s
  floor is the searchable-without-enumeration acceptance bar); or
* on an ``advisor-serve`` record (``benchmarks/advisor_serve.py``),
  service qps falls below ``min_qps``, p99 latency exceeds
  ``max_p99_ms``, the mixed stream's jit retrace counter exceeds
  ``max_retraces`` (committed as 0), or micro-batch coalescing degrades
  below ``min_mean_batch_size``; or
* on a ``serve-resilience`` record (``benchmarks/serve_resilience.py``),
  the chaos stream's degraded-answer rate exceeds its committed ceiling,
  any query hangs past the deadline-plus-grace bound, post-fault recovery
  exceeds ``max_recovery_s``, the hot-swap cycle's swap/rollback counts
  differ from the committed exact values, any torn read is observed
  (``max_torn_reads = 0``: one answer per (signature, epoch) pair), or
  corrupted counter rows stopped being rejected at ingest; or
* on a ``schedule-search`` record (``benchmarks/schedule_search.py``),
  the scheduler's *gain* over the best static placement falls below the
  committed ``min_static_gain_pct`` (the time axis must keep paying for
  itself on phased workloads), exceeds ``max_gain_pct`` where committed
  (the prohibitive-migration record must degrade to *exactly* the static
  answer — gain 0), or warm time-to-solution exceeds the committed
  ``max_time_to_solution_s``.

The looser relative ``--min-pps-ratio`` floor (default 0 = disabled)
remains for local use.  ``--summary`` appends a one-line
baseline-vs-current speedup summary (for ``$GITHUB_STEP_SUMMARY``, next
to the dashboard's error trend).

Several artifacts may be passed (the placement sweep and the mesh-advisor
benchmark each write their own JSON); their records are concatenated
before checking, so every baseline sweep must appear in *some* artifact.

    PYTHONPATH=src python benchmarks/check_sweep_regression.py NEW.json \
        [MORE.json ...] \
        [--baseline benchmarks/sweep_baseline.json] \
        [--error-tolerance 0.25] [--min-pps-ratio 0.0] \
        [--summary "$GITHUB_STEP_SUMMARY"]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = Path(__file__).resolve().parent / "sweep_baseline.json"


def check(
    new: list[dict],
    baseline: list[dict],
    *,
    error_tolerance: float,
    min_pps_ratio: float,
) -> list[str]:
    """Returns a list of failure messages (empty = pass)."""
    failures: list[str] = []
    base_by_sweep = {rec["sweep"]: rec for rec in baseline}
    new_by_sweep = {rec["sweep"]: rec for rec in new}
    for sweep, base in base_by_sweep.items():
        rec = new_by_sweep.get(sweep)
        if rec is None:
            failures.append(f"{sweep!r}: missing from the new artifact")
            continue
        if (
            "max_degraded_rate" in base
            or "max_recovery_s" in base
            or "max_torn_reads" in base
        ):
            # resilience record (benchmarks/serve_resilience.py): gate the
            # chaos stream's degraded-answer rate and hang count, the
            # post-fault recovery time, and the hot-swap cycle's exact
            # swap/rollback counts + zero torn reads.  Checked before the
            # min_qps branch: the chaos record carries a qps floor too.
            checks = [
                ("qps", "min_qps", "floor", lambda v, b: v >= b),
                ("degraded_rate", "max_degraded_rate", "max",
                 lambda v, b: v <= b),
                ("hangs", "max_hangs", "max", lambda v, b: v <= b),
                ("recovery_s", "max_recovery_s", "max",
                 lambda v, b: v == v and v <= b),  # NaN = never recovered
                ("torn_reads", "max_torn_reads", "max",
                 lambda v, b: v <= b),
                ("swaps", "expected_swaps", "exactly",
                 lambda v, b: v == b),
                ("rollbacks", "expected_rollbacks", "exactly",
                 lambda v, b: v == b),
                ("nan_rejected", "min_nan_rejected", "floor",
                 lambda v, b: v >= b),
            ]
            for field, gate, kind, ok in checks:
                bound = base.get(gate)
                if bound is None:
                    continue
                val = rec.get(field)
                good = val is not None and ok(val, bound)
                status = "OK" if good else "FAIL"
                print(f"{sweep}: {field} {val} ({kind} {bound}) [{status}]")
                if not good:
                    failures.append(
                        f"{sweep!r}: {field} {val} violates the committed "
                        f"{gate} {bound} (resilience contract broken)"
                    )
            for flag in ("all_tagged", "search_retry_ok"):
                if flag in base and not rec.get(flag, False):
                    print(f"{sweep}: {flag} False [FAIL]")
                    failures.append(
                        f"{sweep!r}: {flag} is False (resilience "
                        f"contract broken)"
                    )
            continue
        if "min_qps" in base:
            # advisor-serve record (benchmarks/advisor_serve.py): gate
            # service throughput against the committed absolute qps floor,
            # tail latency against the p99 ceiling, and — on the mixed
            # stream — the jit retrace counter against max_retraces (0:
            # steady-state serving must never retrace).  Floors are set
            # with CI-runner headroom like min_placements_per_sec; the
            # cache-hit floor sits >= 10x the miss-path floor by
            # construction (the acceptance bar for the answer cache).
            qps, floor = rec["qps"], base["min_qps"]
            status = "OK" if qps >= floor else "FAIL"
            print(f"{sweep}: {qps:.0f} qps (floor {floor:.0f}) [{status}]")
            if qps < floor:
                failures.append(
                    f"{sweep!r}: {qps:.0f} qps below the committed floor "
                    f"{floor:.0f} (serve fast path lost?)"
                )
            cap = base.get("max_p99_ms")
            if cap is not None:
                p99 = rec["p99_ms"]
                status = "OK" if p99 <= cap else "FAIL"
                print(f"{sweep}: p99 {p99:.3f}ms (max {cap}ms) [{status}]")
                if p99 > cap:
                    failures.append(
                        f"{sweep!r}: p99 {p99:.3f}ms above the committed "
                        f"ceiling {cap}ms"
                    )
            cap = base.get("max_retraces")
            if cap is not None:
                retraces = rec["retraces"]
                status = "OK" if retraces <= cap else "FAIL"
                print(
                    f"{sweep}: {retraces} retraces (max {cap}) [{status}]"
                )
                if retraces > cap:
                    failures.append(
                        f"{sweep!r}: {retraces} jit retraces at steady "
                        f"state (max {cap}) — a serve shape is varying"
                    )
            floor = base.get("min_mean_batch_size")
            if floor is not None:
                mean = rec["mean_batch_size"]
                status = "OK" if mean >= floor else "FAIL"
                print(
                    f"{sweep}: mean batch {mean:.2f} (floor {floor}) "
                    f"[{status}]"
                )
                if mean < floor:
                    failures.append(
                        f"{sweep!r}: mean batch size {mean:.2f} below "
                        f"{floor} (micro-batch coalescing lost?)"
                    )
            continue
        if "min_static_gain_pct" in base:
            # schedule-search record (benchmarks/schedule_search.py): gate
            # the scheduler's gain over the best static placement against
            # the committed floor (gains come from the model, not runner
            # speed, so the floor is tight), the prohibitive-migration
            # record's gain against its exact-zero ceiling, and warm
            # time-to-solution against the absolute cap
            gain, floor = rec["gain_pct"], base["min_static_gain_pct"]
            status = "OK" if gain >= floor else "FAIL"
            print(
                f"{sweep}: gain {gain:.4f}% over static "
                f"(floor {floor}%) [{status}]"
            )
            if gain < floor:
                failures.append(
                    f"{sweep!r}: schedule gain {gain:.4f}% below the "
                    f"committed floor {floor}% (time axis lost?)"
                )
            cap = base.get("max_gain_pct")
            if cap is not None:
                status = "OK" if gain <= cap else "FAIL"
                print(f"{sweep}: gain {gain:.4f}% (max {cap}%) [{status}]")
                if gain > cap:
                    failures.append(
                        f"{sweep!r}: gain {gain:.4f}% above {cap}% — the "
                        f"scheduler moved despite prohibitive migration cost"
                    )
            tts = rec["time_to_solution_s"]
            cap = base.get("max_time_to_solution_s")
            status = "OK" if cap is None or tts <= cap else "FAIL"
            print(
                f"{sweep}: time-to-solution {tts:.3f}s (max {cap}s) "
                f"[{status}]"
            )
            if cap is not None and tts > cap:
                failures.append(
                    f"{sweep!r}: time-to-solution {tts:.3f}s above the "
                    f"committed floor {cap}s"
                )
            continue
        if "regret_pct" in base:
            # placement-search record: gate optimizer regret against the
            # best-known reference and warm time-to-solution against the
            # committed absolute floor (like min_placements_per_sec, set
            # with CI-runner headroom; the 16-node machine's < 1 s floor
            # is the PR's searchable-without-enumeration acceptance bar)
            regret = rec["regret_pct"]
            max_regret = base.get("max_regret_pct", 1.0)
            status = "OK" if regret <= max_regret else "FAIL"
            print(
                f"{sweep}: regret {regret:.4f}% vs {rec.get('regret_vs', '?')} "
                f"(max {max_regret}%) [{status}]"
            )
            if regret > max_regret:
                failures.append(
                    f"{sweep!r}: search regret {regret:.4f}% exceeds "
                    f"{max_regret}%"
                )
            tts = rec["time_to_solution_s"]
            cap = base.get("max_time_to_solution_s")
            status = "OK" if cap is None or tts <= cap else "FAIL"
            print(
                f"{sweep}: time-to-solution {tts:.3f}s "
                f"(max {cap}s) [{status}]"
            )
            if cap is not None and tts > cap:
                failures.append(
                    f"{sweep!r}: time-to-solution {tts:.3f}s above the "
                    f"committed floor {cap}s"
                )
            continue
        err, base_err = rec["median_error_pct"], base["median_error_pct"]
        delta = err - base_err
        status = "OK" if delta <= error_tolerance else "FAIL"
        print(
            f"{sweep}: median_error_pct {base_err:.4f} -> {err:.4f} "
            f"({delta:+.4f}, tolerance {error_tolerance}) [{status}]"
        )
        if delta > error_tolerance:
            failures.append(
                f"{sweep!r}: median error regressed {base_err:.4f} -> {err:.4f} %"
            )
        pps, base_pps = rec["placements_per_sec"], base["placements_per_sec"]
        ratio = pps / base_pps if base_pps else float("inf")
        print(f"{sweep}: placements/sec {base_pps:.0f} -> {pps:.0f} (x{ratio:.2f})")
        if ratio < min_pps_ratio:
            failures.append(
                f"{sweep!r}: throughput fell to {ratio:.2f}x of baseline "
                f"(floor {min_pps_ratio}x)"
            )
        floor = base.get("min_placements_per_sec")
        if floor is not None and pps < floor:
            failures.append(
                f"{sweep!r}: throughput {pps:.0f} placements/s below the "
                f"committed floor {floor:.0f} (grouped-solver speedup lost?)"
            )
    return failures


def speedup_summary(new: list[dict], baseline: list[dict]) -> str:
    """One line: current placements/s as a multiple of the committed
    (pre-grouping) baseline, per sweep."""
    base_by_sweep = {rec["sweep"]: rec for rec in baseline}
    parts = []
    for rec in new:
        base = base_by_sweep.get(rec["sweep"])
        if base is None or not base.get("placements_per_sec"):
            continue
        ratio = rec["placements_per_sec"] / base["placements_per_sec"]
        parts.append(
            f"{rec['sweep']}: {rec['placements_per_sec']:,.0f} pps "
            f"(x{ratio:.1f} vs baseline {base['placements_per_sec']:,.0f})"
        )
    return "**Sweep throughput** — " + " · ".join(parts) if parts else ""


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "artifact",
        type=Path,
        nargs="+",
        help="one or more benchmark --json outputs (records concatenated)",
    )
    parser.add_argument("--baseline", type=Path, default=BASELINE)
    parser.add_argument(
        "--error-tolerance",
        type=float,
        default=0.25,
        help="max allowed median-error increase, in absolute %% of bandwidth",
    )
    parser.add_argument(
        "--min-pps-ratio",
        type=float,
        default=0.0,
        help="fail when placements/sec falls below this fraction of baseline "
        "(0 disables — CI runner speed is not comparable across runs; the "
        "enforced floor is the absolute min_placements_per_sec in the "
        "baseline records)",
    )
    parser.add_argument(
        "--summary",
        type=Path,
        default=None,
        help="append a one-line baseline-vs-current speedup summary to this "
        "file ($GITHUB_STEP_SUMMARY)",
    )
    args = parser.parse_args()

    new = [rec for path in args.artifact for rec in json.loads(path.read_text())]
    baseline = json.loads(args.baseline.read_text())
    failures = check(
        new,
        baseline,
        error_tolerance=args.error_tolerance,
        min_pps_ratio=args.min_pps_ratio,
    )
    line = speedup_summary(new, baseline)
    if line:
        print(line)
    if args.summary is not None and line:
        with args.summary.open("a") as fh:
            fh.write(line + "\n\n")
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        raise SystemExit(1)
    print("sweep trend check passed")


if __name__ == "__main__":
    main()
