"""D1-style docstring gate for the public API (stdlib-only, no pydocstyle).

Walks python packages with ``ast`` and fails when a *public* module,
class, function, or method has no docstring — the pydocstyle D100-D103
family, reimplemented on the stdlib because the CI container pins its
environment (no ruff/pydocstyle to install).

Public means: the module itself, and any ``def``/``class`` whose name
does not start with ``_``, at module scope or inside a public class.
Dunder methods and nested (function-local) definitions are exempt, as is
anything under a private module path (a ``_``-prefixed package segment).

Defaults to the packages whose docstrings the docs tree leans on —
``src/repro/core/numa`` and ``src/repro/serve`` — and is wired into CI
next to the test suite, so an undocumented public symbol fails the build.

    PYTHONPATH=src python benchmarks/check_docstrings.py [PATHS ...]
"""

from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path

DEFAULT_PATHS = ("src/repro/core/numa", "src/repro/serve")


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _missing_in(node, path: Path, scope: str = "") -> list[str]:
    """Recurse over public defs of one class/module body, reporting every
    public definition whose first statement is not a docstring."""
    findings = []
    for child in ast.iter_child_nodes(node):
        if not isinstance(
            child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
        ):
            continue
        if not _is_public(child.name):
            continue
        kind = "class" if isinstance(child, ast.ClassDef) else (
            "method" if scope else "function"
        )
        qualname = f"{scope}{child.name}"
        if ast.get_docstring(child) is None:
            findings.append(
                f"{path}:{child.lineno}: public {kind} "
                f"{qualname!r} has no docstring"
            )
        if isinstance(child, ast.ClassDef):
            findings.extend(_missing_in(child, path, scope=f"{qualname}."))
    return findings


def check_file(path: Path) -> list[str]:
    """All D1 findings for one file (module docstring + public defs)."""
    tree = ast.parse(path.read_text(), filename=str(path))
    findings = []
    if ast.get_docstring(tree) is None:
        findings.append(f"{path}:1: public module has no docstring")
    findings.extend(_missing_in(tree, path))
    return findings


def check_paths(paths) -> list[str]:
    """All findings across files/packages, skipping private path segments."""
    findings = []
    for root in paths:
        root = Path(root)
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            if any(part.startswith("_") and part != "__init__.py"
                   for part in f.parts):
                continue
            findings.extend(check_file(f))
    return findings


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "paths", nargs="*", default=list(DEFAULT_PATHS),
        help="files or package directories to check "
        f"(default: {' '.join(DEFAULT_PATHS)})",
    )
    args = parser.parse_args()
    findings = check_paths(args.paths)
    for line in findings:
        print(line, file=sys.stderr)
    if findings:
        print(f"{len(findings)} public symbols missing docstrings",
              file=sys.stderr)
        raise SystemExit(1)
    print(f"docstring check passed ({', '.join(map(str, args.paths))})")


if __name__ == "__main__":
    main()
