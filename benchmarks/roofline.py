"""Roofline analysis (deliverable g) over the dry-run artifacts.

For every (arch x shape x mesh) cell, derive the three roofline terms from
the compiled dry-run (TPU v5e-class constants):

    compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16 / chip)
    memory     = HLO_bytes / HBM_bw              (819 GB/s / chip)
    collective = collective link bytes / ICI_bw  (~50 GB/s / link)

FLOPs/bytes come from the repo's own HLO analyzer (loop trip counts
multiplied through — XLA's cost_analysis counts while bodies once);
collective bytes use ring-algorithm link formulas per op.  NOTE on the
memory term: the byte counter treats every top-level HLO op boundary as
HBM traffic.  Fusion granularity on this CPU-compiled module is coarser
than a real TPU pass, so the memory term is an UPPER BOUND (flagged in
EXPERIMENTS.md).

MODEL_FLOPS uses 6*N*D for training (N = active params, D = tokens),
2*N*D for prefill and 2*N*B for decode steps.  ``useful fraction`` =
(MODEL_FLOPS / peak) / dominant-term — how close the step is to ideal
compute-bound time; this is the score §Perf hillclimbs.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

RESULTS = Path(__file__).resolve().parent / "dryrun_results"

# Chip constants come from the advisor's ChipSpec presets (one source of
# truth; these used to be duplicated literals).
from repro.core.meshsig.advisor import CHIP_V5E  # noqa: E402

PEAK_FLOPS = CHIP_V5E.peak_flops
HBM_BW = CHIP_V5E.hbm_bw
ICI_BW = CHIP_V5E.ici_bw

SHAPE_TOKENS = {
    "train_4k": (4096, 256),
    "prefill_32k": (32768, 32),
    "decode_32k": (32768, 128),
    "long_500k": (524288, 1),
}


def model_flops_per_device(rec: dict, devices: int) -> float:
    seq, batch = SHAPE_TOKENS[rec["shape"]]
    # active params come from the live config (metric definition), not the
    # compile-time artifact snapshot
    from repro.configs.base import get_config

    n = get_config(rec["arch"]).active_param_count()
    if rec["shape"] == "train_4k":
        return 6.0 * n * seq * batch / devices
    if rec["shape"] == "prefill_32k":
        return 2.0 * n * seq * batch / devices
    return 2.0 * n * batch / devices  # decode: one token per sequence


def load_cells() -> list[dict]:
    cells = []
    for p in sorted(RESULTS.glob("*__*.json")):
        if p.name.startswith("meshsig"):
            continue
        cells.append(json.loads(p.read_text()))
    return cells


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    devices = 512 if rec["mesh"] == "multi" else 256
    flops = rec.get("hlo_flops", 0.0)
    hbm = rec.get("hlo_bytes", 0.0)
    link = rec.get("collectives", {}).get("link_bytes_total", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_l = link / ICI_BW
    dominant = max(
        ("compute", t_c), ("memory", t_m), ("collective", t_l), key=lambda kv: kv[1]
    )
    mf = model_flops_per_device(rec, devices)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_l,
        "dominant": dominant[0],
        "dominant_s": dominant[1],
        "model_flops": mf,
        "flops_ratio": mf / flops if flops else 0.0,
        "useful_fraction": (mf / PEAK_FLOPS) / dominant[1] if dominant[1] else 0.0,
        "hbm_gb_per_dev": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30,
    }


def analyze(mesh: str = "single") -> list[dict]:
    rows = []
    for rec in load_cells():
        if rec.get("mesh") != mesh:
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def pick_hillclimb_cells(rows: list[dict]) -> dict[str, dict]:
    """The three §Perf cells: worst useful-fraction, most collective-bound,
    most paper-representative (the MoE EP cell — all-to-all traffic is the
    paper's Per-thread class)."""
    train = [r for r in rows if r["shape"] == "train_4k"]
    worst = min(rows, key=lambda r: r["useful_fraction"])
    coll = max(rows, key=lambda r: r["collective_s"])
    moe = max(
        (r for r in train if r["arch"].startswith(("qwen3", "jamba", "mixtral"))),
        key=lambda r: r["collective_s"],
        default=None,
    )
    return {"worst_fraction": worst, "most_collective": coll, "paper_representative": moe}


def main() -> None:
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    rows = analyze(mesh)
    hdr = (
        f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
        f"{'collect_s':>10s} {'dominant':>10s} {'6ND/HLO':>8s} {'useful%':>8s}"
    )
    print(hdr)
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(
            f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} {r['dominant']:>10s} "
            f"{r['flops_ratio']:8.3f} {100*r['useful_fraction']:8.2f}"
        )
    picks = pick_hillclimb_cells(rows)
    print("\nhillclimb picks:")
    for why, r in picks.items():
        if r:
            print(f"  {why:22s} -> {r['arch']} / {r['shape']} ({r['dominant']}-bound, useful {100*r['useful_fraction']:.2f}%)")


if __name__ == "__main__":
    main()
