"""End-to-end training driver: a small LM trained for a few hundred steps
with the full production substrate (AdamW, cosine schedule, grad accum,
async checkpointing, restart, straggler monitor).

Defaults are CPU-sized (~9M params, 200 steps, a couple of minutes).  On a
pod, pass --arch llama3-8b (full config) and --mesh single.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or [])

from repro.launch.train import main as train_main  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    args, extra = ap.parse_known_args()
    sys.argv = [
        "train",
        "--arch", args.arch,
        "--reduced",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--lr", "3e-3",
        "--ckpt-dir", "/tmp/repro_train_lm",
        *extra,
    ]
    train_main()


if __name__ == "__main__":
    main()
