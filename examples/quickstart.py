"""Quickstart: the paper's pipeline end to end in ~40 lines.

1. Define a workload whose true traffic mix we know.
2. Profile it with the paper's two runs (symmetric + asymmetric placement)
   on the simulated 18-core Haswell machine.
3. Fit the 8-property bandwidth signature (paper §5).
4. Predict the per-bank counters of an unseen placement (paper §4) and
   compare against the simulator's measurement.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.bwsig import fit_signature, predict_counters
from repro.core.numa import E5_2699_V3, mixed_workload, profile_pair, simulate

# A workload: 20% static (socket 1), 35% thread-local, 30% per-thread,
# remainder interleaved — the paper's worked example (§4).
wl = mixed_workload(
    "worked-example", n_threads=16, read_mix=(0.2, 0.35, 0.3), static_socket=1
)

# Two profiling runs (paper Figure 7): (8,8) symmetric, (12,4) asymmetric.
sym, asym = profile_pair(E5_2699_V3, wl)
sig = fit_signature(sym, asym)

print("fitted read signature:")
print(f"  static   : {float(sig.read.static_fraction):.3f} @ socket {int(sig.read.static_socket)}")
print(f"  local    : {float(sig.read.local_fraction):.3f}")
print(f"  per-thread: {float(sig.read.per_thread_fraction):.3f}")

# Apply to an unseen placement: 11 threads on socket 0, 5 on socket 1.
target = jnp.asarray([11, 5], jnp.int32)
measured = simulate(E5_2699_V3, wl, target)
demand = measured.read_flows.sum(axis=1)  # per-socket demand (measured)
pred_local, pred_remote = predict_counters(sig.read, demand, target)

total = float((measured.sample.local_read + measured.sample.remote_read).sum())
err = (
    np.abs(np.asarray(pred_local - measured.sample.local_read)).sum()
    + np.abs(np.asarray(pred_remote - measured.sample.remote_read)).sum()
) / total

print(f"\nplacement {target.tolist()}:")
print(f"  predicted local reads/bank : {np.asarray(pred_local) / 1e9}")
print(f"  measured  local reads/bank : {np.asarray(measured.sample.local_read) / 1e9}")
print(f"  prediction error           : {100 * err:.2f}% of bandwidth")
assert err < 0.05, "prediction should be within a few % for in-model workloads"
print("OK")
