"""Placement advisor: the Pandia use-case on a TPU mesh.

Loads the fitted mesh signature from the validation artifact (produced by
``python -m repro.core.meshsig.validate``) and ranks candidate mesh aspect
ratios for llama3-8b training WITHOUT compiling them.  Falls back to a
NUMA-domain advisor demo when the artifact is missing.

    PYTHONPATH=src python examples/placement_advisor.py
"""

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "benchmarks" / "dryrun_results"


def mesh_demo(rec: dict) -> None:
    from repro.core.meshsig.advisor import rank_meshes
    from repro.core.meshsig.fit import MeshSignature

    terms = {}
    for key, t in rec["terms"].items():
        cls, axis = key.split("/")
        terms[(cls, axis)] = (float(t["beta"]), float(t["e"]))
    sig = MeshSignature(
        terms=terms,
        local_bytes0=1.0,  # HBM term not needed for collective ranking
        flops0=float(rec.get("flops0", 1e14)),
        batch_shards0=32,
    )
    candidates = [
        {"data": 256, "model": 1},
        {"data": 64, "model": 4},
        {"data": 32, "model": 8},
        {"data": 16, "model": 16},
        {"data": 8, "model": 32},
        {"data": 4, "model": 64},
    ]
    print(f"advisor ranking for {rec['arch']}/{rec['shape']} (no compilation):")
    for r in rank_meshes(sig, candidates):
        axes = "x".join(str(v) for v in r.axis_sizes.values())
        print(
            f"  mesh {axes:8s} collective={r.collective_s*1e3:8.2f} ms/step "
            f"(per-axis: { {a: f'{v*1e3:.1f}ms' for a, v in r.per_axis_s.items()} })"
        )


def numa_demo() -> None:
    import jax.numpy as jnp

    from repro.core.bwsig import fit_signature, placement_matrix
    from repro.core.numa import E5_2630_V3, mixed_workload, profile_pair, simulate

    wl = mixed_workload("app", 8, read_mix=(0.5, 0.1, 0.2), read_bpi=1.2)
    sym, asym = profile_pair(E5_2630_V3, wl)
    sig = fit_signature(sym, asym)
    print("NUMA advisor: throughput of every placement (8 threads, 8-core box):")
    best = None
    for i in range(0, 9):
        placement = jnp.asarray([i, 8 - i], jnp.int32)
        thr = float(simulate(E5_2630_V3, wl, placement).throughput)
        m = placement_matrix(sig.read, placement)
        w = placement / placement.sum()  # thread-weighted local traffic
        remote = 1.0 - float((w * jnp.diagonal(m)).sum())
        print(f"  ({i},{8-i}): throughput={thr:.2f}  predicted-remote={100*remote:.0f}%")
        if best is None or thr > best[1]:
            best = (placement.tolist(), thr)
    print(f"best placement: {best[0]}")


def numa_multisocket_demo() -> None:
    """The generalized engine: rank every 16-thread placement on the
    quad-socket preset from 2 profiling runs, then verify the extremes by
    simulating only those two candidates."""
    from repro.core.meshsig.advisor import rank_numa_placements
    from repro.core.numa import E7_4830_V3, mixed_workload, simulate
    from repro.core.numa.evaluate import count_placements

    wl = mixed_workload("app4", 16, read_mix=(0.35, 0.25, 0.2), read_bpi=3.0)
    total = count_placements(E7_4830_V3, 16)
    ranked = rank_numa_placements(E7_4830_V3, wl)
    print(
        f"\nNUMA advisor on {E7_4830_V3.name}: ranked {total} placements "
        "of 16 threads from 2 profiling runs (no per-candidate measurement)"
    )
    import jax.numpy as jnp

    for label, r in (("best", ranked[0]), ("worst", ranked[-1])):
        thr = float(simulate(E7_4830_V3, wl, jnp.asarray(r.placement, jnp.int32)).throughput)
        print(
            f"  {label}: {r.placement}  predicted-throughput="
            f"{r.predicted_throughput:.2f}  predicted-remote="
            f"{100 * r.remote_fraction:.0f}%  measured-throughput={thr:.2f}"
        )


def numa_glued8s_demo() -> None:
    """Hop-aware ranking on the glued 8-socket preset: cross-quad traffic
    routes over node-controller links (2 hops), so the advisor separates
    placements the old single-``qpi_bw`` model scored identically."""
    import jax.numpy as jnp

    from repro.core.meshsig.advisor import rank_numa_placements
    from repro.core.numa import E7_8860_V3, mixed_workload, simulate

    machine = E7_8860_V3
    hops = machine.topology.hop_matrix()
    print(
        f"\nNUMA advisor on {machine.name}: topology={machine.topology.name} "
        f"({machine.n_links} links, max {machine.topology.max_hops} hops)"
    )
    wl = mixed_workload("app8", 32, read_mix=(0.3, 0.2, 0.2), read_bpi=2.5)
    ranked = rank_numa_placements(machine, wl, max_placements=400, top_k=None)
    for label, r in (("best", ranked[0]), ("worst", ranked[-1])):
        p = jnp.asarray(r.placement, jnp.int32)
        thr = float(simulate(machine, wl, p).throughput)
        used = [i for i, v in enumerate(r.placement) if v]
        max_hop = max(
            (int(hops[i, j]) for i in used for j in used if i != j), default=0
        )
        print(
            f"  {label}: {r.placement}  predicted-throughput="
            f"{r.predicted_throughput:.2f}  max-hops-used={max_hop}  "
            f"measured-throughput={thr:.2f}"
        )


def numa_snc2_demo() -> None:
    """Node-graph ranking on the SNC-2 preset: the 18-core machine split
    into 4 half-socket NUMA nodes whose cross-socket traffic shares one
    QPI port per socket — placements the per-socket model could not even
    describe (it had no intra-socket locality to trade)."""
    import jax.numpy as jnp

    from repro.core.meshsig.advisor import rank_numa_placements
    from repro.core.numa import E5_2699_V3_SNC2, mixed_workload, simulate

    machine = E5_2699_V3_SNC2
    print(
        f"\nNUMA advisor on {machine.name}: {machine.sockets} sockets x "
        f"{machine.nodes_per_socket} nodes ({machine.cores_per_node} cores/node), "
        f"topology={machine.topology.name}"
    )
    wl = mixed_workload("snc-app", 16, read_mix=(0.3, 0.3, 0.2), read_bpi=2.0)
    ranked = rank_numa_placements(machine, wl)
    for label, r in (("best", ranked[0]), ("worst", ranked[-1])):
        thr = float(simulate(machine, wl, jnp.asarray(r.placement, jnp.int32)).throughput)
        print(
            f"  {label}: {r.placement}  predicted-throughput="
            f"{r.predicted_throughput:.2f}  predicted-remote="
            f"{100 * r.remote_fraction:.0f}%  measured-throughput={thr:.2f}"
        )


def numa_heterogeneous_demo() -> None:
    """Heterogeneous core rates: on the throttled preset the advisor's
    roofline weighs socket 1's slower cores against memory locality, so a
    compute-bound workload concentrates on the fast socket."""
    import jax.numpy as jnp

    from repro.core.meshsig.advisor import rank_numa_placements
    from repro.core.numa import E5_2630_V3_THROTTLED, mixed_workload, simulate

    machine = E5_2630_V3_THROTTLED
    rates = tuple(float(r) / 1e9 for r in machine.core_rate)
    print(f"\nNUMA advisor on {machine.name}: per-node core rates {rates} GHz")
    wl = mixed_workload("cpu-app", 6, read_mix=(0.1, 0.7, 0.1), read_bpi=0.3)
    ranked = rank_numa_placements(machine, wl)
    for label, r in (("best", ranked[0]), ("worst", ranked[-1])):
        res = simulate(machine, wl, jnp.asarray(r.placement, jnp.int32))
        instr = float(res.sample.instructions.sum()) / 1e9
        print(
            f"  {label}: {r.placement}  predicted-throughput="
            f"{r.predicted_throughput:.2f}  measured-Ginstr/s={instr:.1f}"
        )


def numa_search_demo() -> None:
    """Search instead of sweep: a 16-node machine (8 sockets in SNC-2
    mode) has ~1.07e10 thread compositions — no sweep, ranked or
    simulated, can touch that space.  The gradient searcher answers from
    a handful of solver evaluations in well under a second (warm), and
    branch-and-bound certifies the answer against its admissible roofline
    bound without enumerating."""
    import time

    from repro.core.numa import (
        branch_and_bound,
        make_machine,
        optimize_placement,
    )
    from repro.core.numa.benchmarks import benchmark_workload
    from repro.core.numa.evaluate import count_placements

    machine = make_machine(
        "snc2-8s", sockets=8, cores_per_socket=8, nodes_per_socket=2,
        qpi_bw=25.6e9,
    )
    wl = benchmark_workload("CG", 32)
    total = count_placements(machine, 32)
    print(
        f"\nPlacement search on {machine.name}: {machine.sockets} sockets x "
        f"{machine.nodes_per_socket} nodes = {machine.n_nodes} NUMA nodes, "
        f"{total:,} compositions of 32 threads"
    )
    result = optimize_placement(machine, wl)  # first call compiles
    t0 = time.perf_counter()
    result = optimize_placement(machine, wl)
    warm_ms = (time.perf_counter() - t0) * 1e3
    print(
        f"  gradient search: {result.placement} "
        f"({result.objective / 1e9:.1f} Ginstr/s, "
        f"{result.evaluations} exact evaluations, {warm_ms:.0f} ms warm)"
    )
    t0 = time.perf_counter()
    cert = branch_and_bound(
        machine, wl, gap=0.01, max_nodes=20_000,
        seed_placements=[result.placement],
    )
    bnb_s = time.perf_counter() - t0
    verdict = (
        "certified within 1% of optimal" if cert.optimal
        else f"search budget hit after {cert.nodes_expanded} nodes"
    )
    print(
        f"  branch-and-bound: {cert.placement} "
        f"({cert.objective / 1e9:.1f} Ginstr/s, {verdict}, {bnb_s:.1f} s)"
    )


def main() -> None:
    recs = sorted(RESULTS.glob("meshsig_validation__*.json"))
    if recs:
        mesh_demo(json.loads(recs[0].read_text()))
    else:
        print("(no mesh validation artifact; showing the NUMA advisor)")
    numa_demo()
    numa_multisocket_demo()
    numa_glued8s_demo()
    numa_snc2_demo()
    numa_heterogeneous_demo()
    numa_search_demo()


if __name__ == "__main__":
    main()
