"""Batched serving example: prefill a batch of prompts through the decode
path, then greedy-decode continuations with the KV cache.

    PYTHONPATH=src python examples/serve_batch.py
"""

import sys

from repro.launch.serve import main as serve_main


def main() -> None:
    sys.argv = [
        "serve",
        "--arch", "llama3-8b",
        "--reduced",
        "--batch", "4",
        "--prompt-len", "12",
        "--gen", "12",
    ]
    serve_main()


if __name__ == "__main__":
    main()
