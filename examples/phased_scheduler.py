"""Phased scheduling: when is a thread migration worth its cost?

A workload whose hot buffer flips between sockets at a phase boundary
(think: build phase writes into socket 0, probe phase hammers a table on
socket 1) has no single good placement — the one-shot advisor must
compromise.  The time-axis scheduler
(:func:`repro.core.numa.temporal.optimize_schedule`) searches per-phase
placements jointly against a migration cost model, and this demo walks
the crossover: as the per-thread migration cost rises, the scheduler
moves from "migrate at the boundary" to "hold the best static placement"
— and its gain over static collapses to exactly zero, never below.

Also shows the page-placement axis: the scheduler may *leave pages
behind* when threads move (``bank_assignment``), trading a one-off copy
for steady remote traffic.

    PYTHONPATH=src python examples/phased_scheduler.py
"""

from repro.core.numa import E5_2630_V3, mixed_workload
from repro.core.numa.temporal import (
    MigrationModel,
    optimize_schedule,
    phased_workload,
)


def main() -> None:
    machine = E5_2630_V3
    # two static-heavy phases whose hot buffer flips between sockets
    build = mixed_workload(
        "build", 8, read_mix=(0.7, 0.1, 0.0), read_bpi=5.0, static_socket=0
    )
    probe = mixed_workload(
        "probe", 8, read_mix=(0.7, 0.1, 0.0), read_bpi=5.0, static_socket=1
    )
    pw = phased_workload("build-probe", [(build, 5.0), (probe, 5.0)])

    print(f"machine: {machine.name}  workload: {pw.name} "
          f"({len(pw.phases)} phases x 5 s, {pw.n_threads} threads)\n")
    print(f"{'thread move':>14} {'gain over static':>17} "
          f"{'placements':>22} {'stall':>9}")
    for move_bytes in (1e6, 1e8, 1e9, 1e10, 1e11, 1e13):
        model = MigrationModel(
            thread_move_bytes=move_bytes, page_move_bytes=move_bytes
        )
        res = optimize_schedule(machine, pw, model=model)
        placements = " -> ".join(str(p) for p in res.schedule.placements)
        stall = sum(res.schedule.transition_times)
        print(
            f"{move_bytes:>12.0e} B {res.gain_pct:>16.3f}% "
            f"{placements:>22} {stall*1e3:>7.2f} ms"
        )

    cheap = optimize_schedule(
        machine, pw, model=MigrationModel(
            thread_move_bytes=1e6, page_move_bytes=1e6
        )
    )
    print(
        f"\ncheap migration: the scheduler moves "
        f"{cheap.schedule.moved_threads[0]} threads "
        f"(re-banking {cheap.schedule.moved_pages[0]} threads' pages) at "
        f"the boundary,\nretiring {cheap.gain_pct:.2f}% more instructions "
        f"than the best static placement "
        f"({cheap.schedule.total_work:.3e} vs "
        f"{cheap.static.total_work:.3e}).\n"
        f"expensive migration keeps the static placement "
        f"{cheap.static.placements[0]} — gain exactly 0, never negative."
    )


if __name__ == "__main__":
    main()
