"""Learned topology calibration: recover a machine from its counters.

Demonstrates the inverse problem end to end on the SNC-2 preset (4
half-socket NUMA nodes, shared QPI port, hop attenuation 0.9):

1. design a probe sweep from structure alone,
2. simulate it on the "real" machine (the synthetic stand-in for a PCM
   counter trace),
3. seed from the counters (the closed-form stage) and refine by projected
   gradient over the differentiable simulator,
4. compare the recovered per-link bandwidths / per-node banks /
   attenuation against the hidden truth, and
5. show the fitted machine ranking placements like the real one.

    PYTHONPATH=src python examples/topology_calibration.py
"""

import numpy as np


def main() -> None:
    import jax.numpy as jnp

    from repro.core.meshsig.advisor import rank_numa_placements
    from repro.core.numa import (
        E5_2630_V3_MIXED_DIMM,
        E5_2699_V3_SNC2,
        blind_template,
        collect_sweep,
        fit_machine,
        link_relative_errors,
        mixed_workload,
        probe_suite,
        simulate,
    )

    truth = E5_2699_V3_SNC2
    probes = probe_suite(truth)
    print(
        f"calibrating {truth.name}: {truth.n_nodes} nodes, "
        f"{truth.n_links} links, {len(probes)} probe runs"
    )

    samples = collect_sweep(truth, probes)
    template = blind_template(truth)  # structure only, no bandwidths
    result = fit_machine(template, samples, steps=200, name=f"{truth.name}-fit")

    print(f"  seed loss {result.seed_loss:.2e} -> final {result.final_loss:.2e}")
    print("  link bandwidths (GB/s), fitted vs true:")
    for (i, j), fit, true in zip(
        truth.topology.link_ends,
        result.machine.topology.link_bw,
        truth.topology.link_bw,
    ):
        print(f"    {i}-{j}: {fit / 1e9:6.2f} vs {true / 1e9:6.2f}")
    print(
        "  per-node local read BW (GB/s), fitted vs true:",
        [round(v / 1e9, 2) for v in result.machine.local_read_bw],
        "vs",
        [round(float(v) / 1e9, 2) for v in np.asarray(truth.node_local_bw("read"))],
    )
    print(
        f"  hop attenuation: {result.machine.hop_attenuation:.3f} "
        f"vs {truth.hop_attenuation}"
    )
    print(
        f"  worst per-link error: "
        f"{100 * link_relative_errors(result.machine, truth).max():.2f}%"
    )

    # The payoff: the fitted machine advises placements like the real one.
    wl = mixed_workload("snc-app", 16, read_mix=(0.3, 0.3, 0.2), read_bpi=2.0)
    best_fit = rank_numa_placements(result.machine, wl)[0]
    measured = float(
        simulate(truth, wl, jnp.asarray(best_fit.placement, jnp.int32)).throughput
    )
    print(
        f"  advisor on the FITTED machine picks {best_fit.placement}; "
        f"measured throughput on the real machine: {measured:.2f}"
    )

    # Mixed DIMM populations: per-node banks the scalar model had no words
    # for are recovered as tuples.
    truth2 = E5_2630_V3_MIXED_DIMM
    result2 = fit_machine(
        blind_template(truth2), collect_sweep(truth2), steps=150
    )
    print(
        f"\n{truth2.name}: fitted per-node read banks "
        f"{[round(v / 1e9, 1) for v in result2.machine.local_read_bw]} GB/s "
        f"(true: {[round(v / 1e9, 1) for v in truth2.local_read_bw]})"
    )


if __name__ == "__main__":
    main()
