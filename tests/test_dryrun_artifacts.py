"""Regression checks over the dry-run artifact matrix (deliverable e).

These validate the committed artifacts, not live compiles (the matrix
itself is produced by ``repro.launch.dryrun`` in its own 512-device
process; see benchmarks/dryrun_results/).
"""

import json
from pathlib import Path

import pytest

RESULTS = Path(__file__).resolve().parents[1] / "benchmarks" / "dryrun_results"

pytestmark = pytest.mark.skipif(
    not RESULTS.exists(), reason="dry-run artifacts not generated yet"
)


def _cells():
    return [
        json.loads(p.read_text())
        for p in RESULTS.glob("*__*.json")
        if not p.name.startswith("meshsig") and not p.name.startswith("moe_")
    ]


def test_full_matrix_present():
    cells = _cells()
    assert len(cells) == 80  # 10 archs x 4 shapes x 2 meshes
    archs = {c["arch"] for c in cells}
    assert len(archs) == 10


def test_no_failed_cells():
    bad = [(c["arch"], c["shape"], c["mesh"]) for c in _cells() if c["status"] == "failed"]
    assert not bad, bad


def test_skips_match_design():
    """long_500k skips exactly the six pure-full-attention archs."""
    skipped = {
        c["arch"] for c in _cells() if c["status"] == "skipped"
    }
    assert skipped == {
        "qwen3-moe-30b-a3b",
        "whisper-medium",
        "llama3-8b",
        "deepseek-7b",
        "gemma2-9b",
        "internvl2-2b",
    }
    for c in _cells():
        if c["status"] == "skipped":
            assert c["shape"] == "long_500k"


def test_every_ok_cell_has_roofline_inputs():
    for c in _cells():
        if c["status"] != "ok":
            continue
        key = (c["arch"], c["shape"], c["mesh"])
        assert c.get("hlo_flops", 0) > 0, key
        assert c.get("hlo_bytes", 0) > 0, key
        assert "link_bytes_total" in c.get("collectives", {}), key
        assert c.get("memory", {}).get("temp_size_in_bytes", 0) > 0, key
        assert c.get("unknown_trip_loops", 0) == 0, key  # trip counts resolved


def test_decode_cells_fit_hbm():
    """Post-§Perf: every decode cell's working set fits 16 GB v5e HBM.

    The CPU pipeline materializes one extra copy of the donated KV cache
    as a while-loop carry (TPU's in-place dynamic-update-slice does not),
    so the honest bound is temp minus the aliased cache copy."""
    for c in _cells():
        if c["status"] != "ok" or c["shape"] not in ("decode_32k", "long_500k"):
            continue
        temp = c["memory"]["temp_size_in_bytes"]
        aliased = c["memory"].get("alias_size_in_bytes", 0)
        honest_gb = (temp - aliased) / 2**30
        assert honest_gb < 16.0, (c["arch"], c["shape"], c["mesh"], honest_gb)


def test_multi_pod_flops_scale():
    """512-chip cells do ~half the per-chip work of 256-chip cells for
    batch-scaled shapes (the pod axis carries data parallelism)."""
    by_key = {}
    for c in _cells():
        if c["status"] == "ok":
            by_key[(c["arch"], c["shape"], c["mesh"])] = c
    checked = 0
    for (arch, shape, mesh), c in by_key.items():
        if mesh != "single" or shape != "train_4k":
            continue
        m = by_key.get((arch, shape, "multi"))
        if not m:
            continue
        ratio = c["hlo_flops"] / m["hlo_flops"]
        assert 1.5 < ratio < 2.6, (arch, shape, ratio)
        checked += 1
    assert checked >= 8
