"""Checkpoint/restart, elastic re-shard, straggler detection, compression."""

import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.runtime.fault_tolerance import (
    FailureInjector,
    StragglerMonitor,
    TrainLoop,
)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (64, 32)),
        "nested": {"b": jnp.arange(8, dtype=jnp.int32)},
        "m": jnp.zeros((64, 32), jnp.bfloat16),
    }


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    store.save(tmp_path, 7, state)
    like = jax.eval_shape(lambda x: x, state)
    back = store.restore(tmp_path, 7, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunked_leaves_roundtrip(tmp_path):
    big = {"x": jnp.arange(4 * 1024 * 300, dtype=jnp.float32).reshape(4, -1)}
    store.save(tmp_path, 1, big, chunk_mb=1)  # force multi-chunk
    back = store.restore(tmp_path, 1, jax.eval_shape(lambda x: x, big))
    np.testing.assert_array_equal(np.asarray(big["x"]), np.asarray(back["x"]))


def test_latest_step_ignores_tmp_and_missing_manifest(tmp_path):
    store.save(tmp_path, 3, _state())
    store.save(tmp_path, 9, _state())
    (tmp_path / "step_00000011.tmp").mkdir()  # crashed writer
    assert store.latest_step(tmp_path) == 9


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(tmp_path)
    ck.save(5, _state())
    ck.wait()
    assert store.latest_step(tmp_path) == 5


def test_train_loop_restart_bit_identical(tmp_path):
    """Kill training at step 7, resume, verify the final state matches an
    uninterrupted run exactly (deterministic replay)."""

    def step_fn(state, step):
        new = jax.tree.map(
            lambda x: x + 1 if jnp.issubdtype(x.dtype, jnp.floating) else x, state
        )
        return new, {"loss": float(step)}

    def run(with_failure):
        loop = TrainLoop(
            step_fn=step_fn,
            ckpt_dir=tmp_path / ("f" if with_failure else "g"),
            save_every=5,
            injector=FailureInjector({7}) if with_failure else None,
        )
        state = _state()
        if with_failure:
            with pytest.raises(FailureInjector.NodeFailure):
                loop.run(state, 12)
            # restart: resumes from step 5's checkpoint automatically
            final, step, _ = loop.run(state, 12)
        else:
            final, step, _ = loop.run(state, 12)
        return final, step

    a, _ = run(with_failure=False)
    b, _ = run(with_failure=True)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    for s in range(10):
        assert not mon.observe(s, 1.0)
    assert mon.observe(10, 5.0)  # 5x the EWMA
    assert mon.flagged and mon.flagged[0][0] == 10
    assert not mon.observe(11, 1.0)  # EWMA not poisoned by the outlier


_MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.parallel import context as ctx
from repro.parallel.compression import compressed_psum_mean
from repro.runtime.fault_tolerance import remesh
from repro.checkpoint import store
from repro.launch import mesh as mesh_lib

# --- compressed mean numerics across a 4-way axis ---
mesh = jax.make_mesh((4, 2), ("data", "model"))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 32))

def body(xb):
    return compressed_psum_mean(xb[0], ("data",))[None]

out = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=P("data", None, None),
                               out_specs=P("data", None, None), check_vma=False))(x)
expect = jnp.broadcast_to(x.mean(axis=0, keepdims=True), x.shape)
got = np.asarray(out)
err = np.abs(got - np.asarray(expect)).max() / np.abs(np.asarray(expect)).max()
assert err < 0.02, f"compressed mean error too large: {err}"

# --- elastic remesh 8 -> 4 devices via topology-independent specs ---
state = {"w": jax.random.normal(jax.random.PRNGKey(1), (16, 8))}
specs = {"w": ("fsdp", "tp")}
with ctx.use_mesh(mesh):
    sh = mesh_lib.tree_shardings(mesh, specs)
    placed = jax.tree.map(lambda a, s: jax.device_put(a, s), state, sh)
small = jax.sharding.Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
moved = remesh(placed, specs, small)
np.testing.assert_array_equal(np.asarray(moved["w"]), np.asarray(state["w"]))
assert moved["w"].sharding.mesh.shape["data"] == 2
print("MULTIDEV OK")
"""


@pytest.mark.slow
def test_compression_and_remesh_multidevice():
    """Collectives need >1 device; run in a subprocess with 8 host devices
    so the main test session keeps its single-device invariant."""
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SNIPPET],
        capture_output=True,
        text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
        timeout=300,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTIDEV OK" in r.stdout
