"""Placement search vs the exhaustive sweep, and its supporting options.

The PR-7 tentpole replaces "sweep every composition" with *search*:
``optimize_placement`` (multi-start gradient ascent through the
differentiable grouped solver, round + polish) and ``branch_and_bound``
(best-first over compositions with an admissible roofline bound).  These
tests gate the acceptance criteria:

* both search modes land within 1% of the exhaustive ``evaluate_batch``
  argmax on every preset (they actually hit 0% regret);
* ``placement_upper_bound`` is admissible — at or above the simulated
  work rate for every placement (relative tolerance: the bound and the
  solver accumulate fp error on ~1e11-scale objectives);
* branch-and-bound certifies global optimality on fully-searchable
  machines;
* a 16-node SNC machine (10.6e9 compositions) is solved without
  enumeration.

Also pinned here: the ``multipath`` ECMP option stays bit-for-bit
inert by default, and ``enumerate_placements`` subsampling is a pure
function of its seed (exact pinned sets).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.numa import (
    E5_2630_V3,
    E5_2630_V3_MIXED_DIMM,
    E5_2630_V3_THROTTLED,
    E5_2699_V3,
    E5_2699_V3_SNC2,
    E7_4830_V3,
    E7_8860_V3,
    branch_and_bound,
    exact_objectives,
    make_machine,
    mesh2d,
    optimize_placement,
    placement_upper_bound,
    simulate,
    simulate_reference,
)
from repro.core.numa.benchmarks import benchmark_workload
from repro.core.numa.evaluate import enumerate_placements

# (preset, thread count): one thread per core on every node
ALL_PRESETS = [
    (E5_2630_V3, 8),
    (E5_2699_V3, 18),
    (E7_4830_V3, 12),
    (E7_8860_V3, 16),
    (E5_2699_V3_SNC2, 16),
    (E5_2630_V3_THROTTLED, 8),
    (E5_2630_V3_MIXED_DIMM, 8),
]

# fp slack on ~1e11-scale objectives; absolute comparisons are meaningless
REL = 1e-5


def _exhaustive_best(machine, workload, n_threads, max_placements=2000):
    placements = np.asarray(
        enumerate_placements(machine, n_threads, max_placements=max_placements)
    )
    vals = np.asarray(exact_objectives(machine, workload, placements))
    return placements, vals


def _assert_feasible(machine, n_threads, placement):
    p = np.asarray(placement)
    assert p.shape == (machine.n_nodes,)
    assert p.sum() == n_threads
    assert (p >= 0).all() and (p <= machine.cores_per_node).all()


@pytest.mark.parametrize(
    "machine,n_threads", ALL_PRESETS, ids=[m.name for m, _ in ALL_PRESETS]
)
def test_search_within_one_percent_of_exhaustive(machine, n_threads):
    wl = benchmark_workload("CG", n_threads)
    _, vals = _exhaustive_best(machine, wl, n_threads)
    opt = vals.max()
    g = optimize_placement(machine, wl)
    b = branch_and_bound(machine, wl)
    _assert_feasible(machine, n_threads, g.placement)
    _assert_feasible(machine, n_threads, b.placement)
    # the sweep may be a subsample on the big 8-socket space, so the
    # search can legitimately exceed `opt`; the gate is one-sided
    assert g.objective >= 0.99 * opt
    assert b.objective >= 0.99 * opt


@pytest.mark.parametrize(
    "machine,n_threads",
    [(E7_4830_V3, 12), (E5_2699_V3_SNC2, 16), (E5_2630_V3_THROTTLED, 8)],
    ids=["E7-4830", "SNC2", "throttled"],
)
def test_search_multiclass_workload(machine, n_threads):
    # "Page rank" mixes thread classes -> exercises the class-partitioned
    # bound tables and the grouped objective with C > 1
    wl = benchmark_workload("Page rank", n_threads)
    _, vals = _exhaustive_best(machine, wl, n_threads)
    opt = vals.max()
    g = optimize_placement(machine, wl)
    b = branch_and_bound(machine, wl)
    assert g.objective >= 0.99 * opt
    assert b.objective >= 0.99 * opt


@pytest.mark.parametrize(
    "machine,n_threads",
    [(E5_2630_V3, 8), (E7_4830_V3, 12), (E5_2699_V3_SNC2, 16)],
    ids=["E5-2630", "E7-4830", "SNC2"],
)
def test_bound_admissible_over_full_enumeration(machine, n_threads):
    for bench in ("CG", "Page rank"):
        wl = benchmark_workload(bench, n_threads)
        placements, vals = _exhaustive_best(machine, wl, n_threads)
        bounds = np.asarray(
            placement_upper_bound(machine, wl, placements)
        )
        assert (vals <= bounds * (1 + REL)).all(), (
            f"{machine.name}/{bench}: bound below simulated rate by "
            f"{(vals / bounds).max() - 1:.2e} relative"
        )


def test_bnb_certifies_global_optimality():
    for machine, n_threads in [(E5_2630_V3, 8), (E7_4830_V3, 12)]:
        wl = benchmark_workload("CG", n_threads)
        _, vals = _exhaustive_best(machine, wl, n_threads, max_placements=None)
        b = branch_and_bound(machine, wl)
        assert b.optimal
        assert b.objective >= vals.max() * (1 - REL)


def test_advisor_bounds_are_the_admissible_ones():
    # the meshsig advisor exposes the admissible bound (its own worst-util
    # roofline is a ranking heuristic, NOT admissible) by delegation
    from repro.core.meshsig import numa_placement_bounds

    wl = benchmark_workload("CG", 12)
    placements = np.asarray(enumerate_placements(E7_4830_V3, 12))[:64]
    np.testing.assert_array_equal(
        np.asarray(numa_placement_bounds(E7_4830_V3, wl, placements)),
        np.asarray(placement_upper_bound(E7_4830_V3, wl, placements)),
    )


def test_sixteen_node_machine_searched_without_enumeration():
    # 8 sockets x SNC-2 = 16 nodes, ~1.07e10 compositions: far beyond any
    # sweep.  The optimizer must return a feasible placement; warm-path
    # latency is gated in CI by benchmarks/placement_search.py (< 1 s),
    # here we only guard against catastrophic regressions.
    m16 = make_machine(
        "snc2-8s", sockets=8, cores_per_socket=8, nodes_per_socket=2,
        qpi_bw=25.6e9,
    )
    wl = benchmark_workload("CG", 32)
    g = optimize_placement(m16, wl)  # includes compile
    t0 = time.perf_counter()
    g = optimize_placement(m16, wl)
    warm = time.perf_counter() - t0
    _assert_feasible(m16, 32, g.placement)
    assert g.objective > 0
    assert warm < 10.0, f"warm 16-node search took {warm:.1f}s"
    # a gap-bounded B&B seeded with the gradient answer must at least
    # match it (the incumbent only improves)
    b = branch_and_bound(
        m16, wl, gap=0.01, max_nodes=20_000, seed_placements=[g.placement]
    )
    assert b.objective >= g.objective


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 16), st.integers(0, 2**31 - 1))
def test_bound_admissible_on_random_placements(n_threads, seed):
    machine = E5_2699_V3_SNC2
    rng = np.random.default_rng(seed)
    counts = np.zeros(machine.n_nodes, np.int64)
    for _ in range(n_threads):
        open_nodes = np.flatnonzero(counts < machine.cores_per_node)
        counts[open_nodes[rng.integers(len(open_nodes))]] += 1
    wl = benchmark_workload("CG", n_threads)
    val = float(
        np.asarray(exact_objectives(machine, wl, counts[None, :]))[0]
    )
    bound = float(
        np.asarray(placement_upper_bound(machine, wl, counts[None, :]))[0]
    )
    assert val <= bound * (1 + REL)


@settings(max_examples=5, deadline=None)
@given(st.integers(2, 12), st.integers(0, 2**31 - 1))
def test_optimizer_always_returns_feasible_placement(n_threads, seed):
    wl = benchmark_workload("NPO", n_threads)
    g = optimize_placement(
        E7_4830_V3, wl, n_starts=4, steps=40, seed=seed
    )
    _assert_feasible(E7_4830_V3, n_threads, g.placement)


# ---------------------------------------------------------------------------
# advisor warm start: seeds only raise the incumbent, never the certificate
# ---------------------------------------------------------------------------


def test_advisor_warm_seeds_are_feasible_rankings():
    from repro.core.numa import advisor_warm_seeds

    wl = benchmark_workload("CG", 12)
    seeds = advisor_warm_seeds(E7_4830_V3, wl, top_k=5)
    assert len(seeds) == 5
    for p in seeds:
        _assert_feasible(E7_4830_V3, 12, p)
    # seeds come ranked: the top seed's exact value is the best of the five
    vals = np.asarray(exact_objectives(E7_4830_V3, wl, np.stack(seeds)))
    assert vals[0] >= vals.max() * (1 - 1e-6)


def test_advisor_warm_seeds_unavailable_without_symmetric_profiling():
    from repro.core.numa import advisor_warm_seeds

    # 10 threads over 4 nodes: the 2-run fit needs the symmetric run, so
    # the ranking degrades to no seeds (and B&B still works off its
    # heuristics)
    wl = benchmark_workload("CG", 10)
    assert advisor_warm_seeds(E7_4830_V3, wl) == []
    b = branch_and_bound(E7_4830_V3, wl, advisor_seeds=4)
    _assert_feasible(E7_4830_V3, 10, b.placement)


def test_warm_start_never_worsens_certificate_on_easy_preset():
    wl = benchmark_workload("CG", 24)
    cold = branch_and_bound(E7_4830_V3, wl)
    warm = branch_and_bound(E7_4830_V3, wl, advisor_seeds=8)
    assert warm.optimal == cold.optimal
    assert warm.objective >= cold.objective * (1 - REL)
    assert warm.nodes_expanded <= cold.nodes_expanded


def test_warm_start_shrinks_sixteen_node_tree():
    # A bandwidth-starved heterogeneous 16-node SNC machine (fast/slow
    # node pairs, thin links) sits past the root-certificate regime: the
    # admissible bound is loose enough that cold B&B burns its whole node
    # budget without certifying.  The advisor's signature-only ranking
    # seeds the TRUE optimum, which meets the root bound — the warm run
    # certifies global optimality with ZERO nodes expanded.  Warm start
    # must never worsen either receipt (incumbent or tree size).
    scale = 0.27
    m16 = make_machine(
        "snc2-8s-tight", sockets=8, cores_per_socket=8, nodes_per_socket=2,
        qpi_bw=25.6e9 * scale, core_rate=(2.4e9, 1.6e9) * 8,
        local_read_bw=(52e9 * scale, 26e9 * scale) * 8,
        local_write_bw=(28e9 * scale, 14e9 * scale) * 8,
    )
    wl = benchmark_workload("CG", 48)
    cold = branch_and_bound(m16, wl, gap=0.0, max_nodes=4000)
    warm = branch_and_bound(m16, wl, gap=0.0, max_nodes=4000, advisor_seeds=8)
    _assert_feasible(m16, 48, warm.placement)
    assert warm.objective >= cold.objective * (1 - REL)  # never worse
    assert warm.nodes_expanded <= cold.nodes_expanded
    # and on this preset the effect is total: budget exhausted vs certified
    assert not cold.optimal and cold.nodes_expanded == 4000
    assert warm.optimal and warm.nodes_expanded == 0
    assert warm.objective > cold.objective * 1.01  # strictly better incumbent


# ---------------------------------------------------------------------------
# multipath (ECMP) option: default off bit-for-bit, effective under ECMP
# ---------------------------------------------------------------------------


def _mesh_machine(link_bw):
    # 2x2 mesh: the two diagonals each have TWO equal-cost 2-hop routes,
    # the only preset-independent ECMP fixture in the topology zoo
    return make_machine(
        "mesh4", sockets=4, cores_per_socket=4,
        topology=mesh2d(2, 2, link_bw), hop_attenuation=1.0,
    )


def test_multipath_default_off_is_bitforbit():
    m = _mesh_machine(1.5e9)
    wl = benchmark_workload("CG", 8)
    p = jnp.asarray([4, 0, 0, 4])
    r_default = simulate(m, wl, p)
    r_off = simulate(m, wl, p, multipath=False)
    for a, b in zip(jax.tree.leaves(r_default), jax.tree.leaves(r_off)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # on a fully-connected preset every route is single-link, so ECMP has
    # nothing to split: multipath=True is also exact there
    p2 = jnp.asarray([4, 4])
    r2 = simulate(E5_2630_V3, wl, p2)
    r2m = simulate(E5_2630_V3, wl, p2, multipath=True)
    for a, b in zip(jax.tree.leaves(r2), jax.tree.leaves(r2m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_multipath_splits_ecmp_flow():
    # slow links make the interconnect the binding resource, so halving
    # each diagonal's per-link charge must change the saturation point
    m = _mesh_machine(1.5e9)
    wl = benchmark_workload("CG", 8)
    p = jnp.asarray([4, 0, 0, 4])  # opposite corners -> diagonal traffic
    r_off = simulate(m, wl, p)
    r_on = simulate(m, wl, p, multipath=True)
    assert any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(r_off), jax.tree.leaves(r_on))
    )
    # splitting over two paths relieves the bottleneck: rates go up
    assert float(r_on.rates.sum()) > float(r_off.rates.sum())
    # adjacent-corner traffic is single-hop (one shortest route): inert
    p_adj = jnp.asarray([4, 4, 0, 0])
    r_off = simulate(m, wl, p_adj)
    r_on = simulate(m, wl, p_adj, multipath=True)
    for a, b in zip(jax.tree.leaves(r_off), jax.tree.leaves(r_on)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_multipath_grouped_matches_reference():
    m = _mesh_machine(1.5e9)
    wl = benchmark_workload("CG", 8)
    p = jnp.asarray([3, 1, 1, 3])
    grouped = simulate(m, wl, p, multipath=True)
    ref = simulate_reference(m, wl, p, multipath=True)
    np.testing.assert_allclose(
        np.asarray(grouped.rates), np.asarray(ref.rates), atol=1e-6
    )


# ---------------------------------------------------------------------------
# enumerate_placements subsampling: a pure function of (machine, n, seed)
# ---------------------------------------------------------------------------


def test_subsample_is_seed_deterministic_pinned():
    # exact pinned sets — any change to the sampling stream (RNG, rank
    # unranking, ordering) is a silent benchmark-comparability break and
    # must show up here
    got0 = np.asarray(
        enumerate_placements(E7_8860_V3, 16, max_placements=6, seed=0)
    )
    np.testing.assert_array_equal(
        got0,
        [
            [0, 0, 2, 3, 2, 8, 0, 1],
            [1, 1, 7, 0, 1, 0, 0, 6],
            [1, 2, 9, 2, 1, 1, 0, 0],
            [4, 0, 2, 3, 2, 0, 4, 1],
            [5, 2, 0, 5, 0, 2, 2, 0],
            [6, 5, 0, 3, 1, 0, 0, 1],
        ],
    )
    got1 = np.asarray(
        enumerate_placements(E7_8860_V3, 16, max_placements=6, seed=1)
    )
    np.testing.assert_array_equal(
        got1,
        [
            [0, 0, 5, 1, 3, 5, 2, 0],
            [0, 1, 8, 1, 1, 2, 2, 1],
            [2, 2, 0, 0, 1, 4, 7, 0],
            [4, 0, 5, 0, 1, 2, 4, 0],
            [4, 3, 6, 3, 0, 0, 0, 0],
            [5, 2, 2, 2, 1, 2, 1, 1],
        ],
    )
    np.testing.assert_array_equal(
        np.asarray(
            enumerate_placements(E5_2699_V3_SNC2, 16, max_placements=5, seed=3)
        ),
        [
            [1, 8, 6, 1],
            [3, 3, 6, 4],
            [5, 1, 6, 4],
            [8, 0, 5, 3],
            [9, 1, 1, 5],
        ],
    )
    # repeat call -> identical array (memoized table, stateless sampling)
    np.testing.assert_array_equal(
        got0,
        np.asarray(
            enumerate_placements(E7_8860_V3, 16, max_placements=6, seed=0)
        ),
    )
    # rows are sorted ranks of the lexicographic enumeration: strictly
    # increasing lexicographically, and every row is feasible
    assert (got0.sum(axis=1) == 16).all()
    assert (got0 <= E7_8860_V3.cores_per_node).all()
    for a, b in zip(got0[:-1], got0[1:]):
        assert tuple(a) < tuple(b)
