"""End-to-end training integration: loss must actually descend."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import TokenStream, synthetic_batch
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import adamw


@pytest.mark.slow
def test_loss_descends_on_fixed_batch():
    """Overfit one batch with the production train step (accum=2): loss
    must drop substantially — exercises grads, AdamW, schedule, remat,
    scan, microbatching in one go."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params, cfg.moment_dtype)
    batch = synthetic_batch(cfg, 64, 4, jax.random.PRNGKey(1))
    schedule = adamw.cosine_schedule(5e-3, 5, 100)
    step = jax.jit(
        steps_lib.make_train_step(cfg, accum=2, lr_schedule=schedule),
        donate_argnums=(0, 1),
    )
    losses = []
    for i in range(30):
        params, opt, metrics = step(params, opt, batch, jnp.asarray(i, jnp.int32))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.7, losses[::6]


@pytest.mark.slow
def test_moe_train_step_descends():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params, cfg.moment_dtype)
    batch = synthetic_batch(cfg, 32, 2, jax.random.PRNGKey(1))
    step = jax.jit(
        steps_lib.make_train_step(
            cfg, lr_schedule=adamw.cosine_schedule(5e-3, 5, 100)
        ),
        donate_argnums=(0, 1),
    )
    losses = []
    for i in range(20):
        params, opt, metrics = step(params, opt, batch, jnp.asarray(i, jnp.int32))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.8, losses[::4]


def test_data_stream_deterministic_and_host_sharded():
    cfg = get_config("llama3-8b").reduced()
    full = TokenStream(cfg, 32, 8, n_hosts=1, host_id=0, seed=3)
    a = full.batch_at(5)
    b = full.batch_at(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # different hosts see different slices; same host replays identically
    h0 = TokenStream(cfg, 32, 8, n_hosts=2, host_id=0, seed=3)
    h1 = TokenStream(cfg, 32, 8, n_hosts=2, host_id=1, seed=3)
    assert h0.batch_at(0)["tokens"].shape == (4, 32)
    assert not np.array_equal(
        np.asarray(h0.batch_at(0)["tokens"]), np.asarray(h1.batch_at(0)["tokens"])
    )


def test_auto_accum_divisibility():
    from repro.launch.steps import auto_accum

    cfg = get_config("llama3-8b")
    accum = auto_accum(cfg, 256)
    assert 256 % accum == 0
