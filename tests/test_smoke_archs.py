"""Per-architecture smoke tests (deliverable f).

Each assigned arch is instantiated at a REDUCED same-family config and runs
a forward pass, one gradient step, and (where the family supports it) a
decode step on CPU, asserting output shapes and absence of NaNs.  The FULL
configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.data.pipeline import synthetic_batch
from repro.models import model as M

ARCHS = list_configs()
SMOKE_SEQ = 64
SMOKE_BATCH = 2


def _reduced(name):
    return get_config(name).reduced()


@pytest.fixture(scope="module")
def setups():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = _reduced(name)
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, setups):
    cfg, params = setups(arch)
    batch = synthetic_batch(cfg, SMOKE_SEQ, SMOKE_BATCH, jax.random.PRNGKey(1))
    logits, aux = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)
    t = batch["tokens"].shape[1]
    s_total = t + (cfg.frontend_tokens if cfg.frontend == "vit_patches" else 0)
    assert logits.shape == (SMOKE_BATCH, s_total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss_structure(arch, setups):
    """One SGD step: loss is finite, grads exist for every param leaf."""
    cfg, params = setups(arch)
    batch = synthetic_batch(cfg, SMOKE_SEQ, SMOKE_BATCH, jax.random.PRNGKey(2))

    def loss(p):
        l, _ = M.loss_fn(cfg, p, batch)
        return l

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val)), arch
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all(), arch
    # and at least one grad is non-zero (the model is actually wired in)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, setups):
    cfg, params = setups(arch)
    dtype = jnp.bfloat16
    cache = M.init_cache(cfg, SMOKE_BATCH, SMOKE_SEQ, dtype)
    if cfg.is_encoder_decoder:
        # fill the cross cache from a fake encoder output
        from repro.models.attention import cross_kv

        enc = jax.random.normal(
            jax.random.PRNGKey(3), (SMOKE_BATCH, SMOKE_SEQ, cfg.d_model)
        ).astype(dtype)
        ks, vs = [], []
        for g in range(cfg.n_groups):
            p = jax.tree.map(lambda x: x[g], params["groups"]["slot0"]["cross"])
            k, v = cross_kv(cfg, p, enc)
            ks.append(k)
            vs.append(v)
        cache["cross"] = type(cache["cross"])(k=jnp.stack(ks), v=jnp.stack(vs))
    tokens = jnp.zeros((SMOKE_BATCH, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    logits, cache = step(params, cache, tokens, jnp.asarray(0, jnp.int32))
    logits2, cache = step(params, cache, tokens + 1, jnp.asarray(1, jnp.int32))
    assert logits.shape == (SMOKE_BATCH, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch
    # decoding two different tokens must change the distribution
    assert not np.allclose(
        np.asarray(logits, np.float32), np.asarray(logits2, np.float32)
    )


def test_decode_matches_forward_prefix():
    """Teacher-forced decode over a short prefix agrees with the parallel
    forward pass (cache correctness)."""
    cfg, _ = (None, None)
    cfg = _reduced("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    T = 8
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, T), 0, cfg.vocab_size)
    logits_par, _ = M.forward(cfg, params, {"tokens": tokens})
    cache = M.init_cache(cfg, 1, T, jnp.float32)
    outs = []
    for t in range(T):
        lg, cache = M.decode_step(
            cfg, params, cache, tokens[:, t : t + 1], jnp.asarray(t, jnp.int32)
        )
        outs.append(lg)
    logits_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_par, np.float32),
        np.asarray(logits_seq, np.float32),
        atol=2e-2,
        rtol=2e-2,
    )


def test_swa_equals_full_for_short_seq():
    """A sliding window larger than the sequence must not change outputs."""
    import dataclasses

    cfg = _reduced("h2o-danube-1.8b")
    cfg_full = dataclasses.replace(cfg, attn_pattern="full")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, 16, 2, jax.random.PRNGKey(1), train=False)
    a, _ = M.forward(cfg, params, batch)  # window=32 > seq=16
    b, _ = M.forward(cfg_full, params, batch)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5
    )
