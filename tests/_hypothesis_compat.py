"""Graceful degradation when ``hypothesis`` is missing.

The property-based tests are dev-only depth; the container image the
tier-1 suite runs in does not ship hypothesis (it is listed in
``requirements-dev.txt``).  Importing ``given``/``settings``/``st`` from
here instead of ``hypothesis`` keeps those modules collectable everywhere:
with hypothesis installed the real API is re-exported; without it the
``@given`` tests are individually skipped (with a reason) while every
example-based test in the same module still runs.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    import pytest

    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (see requirements-dev.txt)"
        )

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategy:
        """Absorbs any strategy construction: ``st.lists(...).filter(...)``
        etc. all return another inert _Strategy."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, _name):
            return self

    st = _Strategy()

__all__ = ["HAS_HYPOTHESIS", "given", "settings", "st"]
