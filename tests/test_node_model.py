"""Node-graph machine model: sockets decoupled from NUMA nodes.

* **Behavior preservation**: homogeneous ``nodes_per_socket=1`` machines
  must reproduce the pre-refactor per-socket model — proven three ways:
  ``simulate_reference`` (the per-thread path) stays *bit for bit* equal
  to a verbatim replica of the pre-refactor ``simulate``
  (platform-independent) and to byte digests recorded from the
  pre-refactor code on both 2-socket paper presets (golden; re-record if
  the pinned jax/XLA version ever changes), while the group-collapsed
  ``simulate`` hot path matches the replica to <= 1e-6 (its max-min
  arithmetic reorders float sums across a group's identical rows).
* **Sub-NUMA clustering**: the SNC-2 preset (4 half-socket nodes, shared
  QPI ports) runs end to end through ``evaluate_batch`` and the advisor.
* **Heterogeneous core rates**: the throttled preset issues, demands and
  ranks according to per-node rates.
* **Placement enumeration invariants** on both machine families, plus the
  ``MachineSpec.fingerprint`` regression guard for the new fields.
"""

import hashlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bwsig.counters import counters_from_flows
from repro.core.numa import (
    E5_2630_V3,
    E5_2630_V3_MIXED_DIMM,
    E5_2630_V3_THROTTLED,
    E5_2699_V3,
    E5_2699_V3_SNC2,
    E7_4830_V3,
    make_machine,
    mixed_workload,
    simulate,
)
from repro.core.numa.benchmarks import benchmark_workload
from repro.core.numa.evaluate import (
    count_placements,
    enumerate_placements,
    evaluate_batch,
    evaluate_suite,
    sweep_placements,
)
from repro.core.numa.simulator import (
    SimulationResult,
    _mix_rows,
    _progressive_fill,
    _resource_tensor,
    _thread_nodes,
    asymmetric_placement,
    simulate_reference,
    symmetric_placement,
)

# ---------------------------------------------------------------------------
# bit-for-bit behavior preservation for nodes_per_socket = 1
# ---------------------------------------------------------------------------


def _legacy_simulate(machine, workload, n_per_socket, **kwargs):
    """The pre-refactor per-socket ``simulate``, verbatim: scalar
    ``core_rate`` multiplications and socket-indexed everything.  Only
    valid for homogeneous machines (all node rates equal)."""
    core_rate = float(np.asarray(machine.node_rates())[0])
    elapsed = kwargs.get("elapsed", 1.0)
    noise_std = kwargs.get("noise_std", 0.0)
    background_bw = kwargs.get("background_bw", 0.0)
    key = kwargs.get("key")
    s = machine.sockets
    n = workload.n_threads
    n_per_socket = jnp.asarray(n_per_socket)
    socket_of = _thread_nodes(n_per_socket, n)

    read_mix = _mix_rows(
        workload.read_static,
        workload.read_local,
        workload.read_per_thread,
        workload.static_socket,
        socket_of,
        n_per_socket,
    )
    write_mix = _mix_rows(
        workload.write_static,
        workload.write_local,
        workload.write_per_thread,
        workload.static_socket,
        socket_of,
        n_per_socket,
    )
    read_unit = core_rate * workload.read_bpi[:, None] * read_mix
    write_unit = core_rate * workload.write_bpi[:, None] * write_mix

    usage, caps = _resource_tensor(machine, read_unit, write_unit, socket_of)
    iterations = min(usage.shape[0], usage.shape[1]) + 1
    rates = _progressive_fill(usage, caps, iterations)

    onehot = jax.nn.one_hot(socket_of, s)
    read_flows = onehot.T @ (rates[:, None] * read_unit) * elapsed
    write_flows = onehot.T @ (rates[:, None] * write_unit) * elapsed
    instructions = onehot.T @ (rates * core_rate) * elapsed

    if noise_std > 0.0 or background_bw > 0.0:
        if key is None:
            key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        read_flows = read_flows * jnp.exp(
            noise_std * jax.random.normal(k1, read_flows.shape)
        ) + background_bw * elapsed / (s * s)
        write_flows = write_flows * jnp.exp(
            noise_std * jax.random.normal(k2, write_flows.shape)
        ) + background_bw * elapsed / (s * s)
        instructions = instructions * jnp.exp(
            0.2 * noise_std * jax.random.normal(k3, instructions.shape)
        )

    sample = counters_from_flows(
        read_flows, write_flows, instructions, jnp.asarray(elapsed), n_per_socket
    )
    return SimulationResult(
        rates=rates,
        read_flows=read_flows,
        write_flows=write_flows,
        sample=sample,
        throughput=rates.sum(),
    )


@pytest.mark.parametrize(
    "machine,n_per",
    [
        (E5_2630_V3, [5, 3]),
        (E5_2630_V3, [8, 0]),
        (E5_2699_V3, [12, 6]),
        (E7_4830_V3, [6, 4, 4, 2]),
    ],
)
def test_simulate_is_bitwise_legacy_for_single_node_sockets(machine, n_per):
    wl = benchmark_workload("CG", int(sum(n_per)))
    for kwargs in (
        {},
        {"noise_std": 0.02, "background_bw": 1e8, "key": jax.random.PRNGKey(9)},
    ):
        ref = simulate_reference(machine, wl, jnp.asarray(n_per, jnp.int32), **kwargs)
        old = _legacy_simulate(machine, wl, jnp.asarray(n_per, jnp.int32), **kwargs)
        for got, want in zip(jax.tree.leaves(ref), jax.tree.leaves(old)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # the grouped hot path reorders float sums across identical rows:
        # equal to the per-thread model within solver tolerance, not bits
        new = simulate(machine, wl, jnp.asarray(n_per, jnp.int32), **kwargs)
        for got, want in zip(jax.tree.leaves(new), jax.tree.leaves(old)):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
            )


def _digest(*arrays) -> str:
    d = hashlib.blake2b(digest_size=16)
    for a in arrays:
        d.update(np.asarray(a).tobytes())
    return d.hexdigest()


# Byte digests of simulate outputs recorded from the pre-refactor
# per-socket implementation (commit 43408e4) under the pinned jax version
# — CG @ 8 threads on both 2-socket paper presets.  ``simulate_reference``
# (the per-thread path) must still reproduce them byte for byte.  The
# ``batch`` digests pin the group-collapsed ``evaluate_batch`` pipeline
# instead (re-recorded at the grouped-solver PR and again at the
# shared-slab batch PR, which batched the measurement-noise draws — new
# PRNG stream, same model; equivalence with the per-thread reference is
# covered to 1e-6 by tests/test_grouped_solver.py and noise-free by
# tests/test_placement_sweep.py).
_PRE_REFACTOR_DIGESTS = {
    ("E5-2630v3-8c", "batch"): "cbc81790eff3f6f609638af31319e114",
    ("E5-2630v3-8c", "sim"): "26bc2013541a68d19b0f83cb220ab9d4",
    ("E5-2630v3-8c", "simnoise"): "929f752f4b02f8aed18b9e281494e44b",
    ("E5-2699v3-18c", "batch"): "715d4b8762d838c68f3cab36de16827f",
    ("E5-2699v3-18c", "sim"): "d129b2fbbb31f4fe72f22f3a7e6ce368",
    ("E5-2699v3-18c", "simnoise"): "d0f57816e463d1bb8fbf00396debe775",
}


@pytest.mark.parametrize("machine", [E5_2630_V3, E5_2699_V3])
def test_golden_digests_match_pre_refactor_model(machine):
    """simulate_reference reproduces the pre-refactor outputs byte for
    byte on both 2-socket presets; the jitted grouped evaluate_batch
    pipeline reproduces its own recorded digests (change detector)."""
    wl = benchmark_workload("CG", 8)
    batch = evaluate_batch(
        machine,
        [wl],
        sweep_placements(machine, 8),
        noise_std=0.02,
        keys=jnp.stack([jax.random.PRNGKey(3)]),
    )
    assert (
        _digest(
            batch.errors_read, batch.errors_write, batch.errors_combined, batch.total_bw
        )
        == _PRE_REFACTOR_DIGESTS[(machine.name, "batch")]
    )
    res = simulate_reference(machine, wl, jnp.asarray([5, 3], jnp.int32))
    assert (
        _digest(
            res.rates,
            res.read_flows,
            res.write_flows,
            res.sample.local_read,
            res.sample.remote_read,
            res.sample.local_write,
            res.sample.remote_write,
            res.sample.instructions,
        )
        == _PRE_REFACTOR_DIGESTS[(machine.name, "sim")]
    )
    resn = simulate_reference(
        machine,
        wl,
        jnp.asarray([2, 6], jnp.int32),
        noise_std=0.02,
        background_bw=1e8,
        key=jax.random.PRNGKey(9),
    )
    assert (
        _digest(resn.rates, resn.read_flows, resn.write_flows, resn.sample.instructions)
        == _PRE_REFACTOR_DIGESTS[(machine.name, "simnoise")]
    )


# ---------------------------------------------------------------------------
# sub-NUMA clustering end to end
# ---------------------------------------------------------------------------


def test_snc2_preset_shape():
    m = E5_2699_V3_SNC2
    assert m.sockets == 2 and m.nodes_per_socket == 2
    assert m.n_nodes == 4 and m.cores_per_node == 9
    assert m.topology.n_nodes == 4
    m.validate()
    np.testing.assert_array_equal(
        np.asarray(symmetric_placement(m, 16)), [4, 4, 4, 4]
    )
    asym = np.asarray(asymmetric_placement(m, 16))
    assert asym.sum() == 16 and asym.max() <= 9 and len(set(asym.tolist())) > 1


def test_snc2_evaluate_batch_noise_free_exact():
    """In-model workloads stay exactly representable over 4 half-socket
    nodes: fit on 2 runs, predict every placement, zero error."""
    m = E5_2699_V3_SNC2
    wl = benchmark_workload("CG", 16)
    placements = enumerate_placements(m, 16, max_placements=24, seed=2)
    batch = evaluate_batch(m, wl, placements, keys=jax.random.PRNGKey(5))
    errs = np.asarray(batch.errors_combined)
    assert errs.shape == (1, 24, 2 * m.n_nodes)
    assert np.isfinite(errs).all()
    assert errs.max() < 2e-3


def test_snc2_advisor_end_to_end():
    from repro.core.meshsig.advisor import rank_numa_placements

    m = E5_2699_V3_SNC2
    wl = benchmark_workload("CG", 16)
    ranked = rank_numa_placements(m, wl, max_placements=64, top_k=8)
    assert len(ranked) == 8
    thrs = [r.predicted_throughput for r in ranked]
    assert thrs == sorted(thrs, reverse=True)
    assert all(sum(r.placement) == 16 for r in ranked)
    assert all(max(r.placement) <= m.cores_per_node for r in ranked)


def test_snc2_shared_qpi_port_caps_both_nodes():
    """Both of socket 0's nodes streaming to socket 1 share ONE QPI link:
    total cross-socket traffic stays within that link's capacity, which a
    2-endpoint-per-socket (fully connected) machine would exceed."""
    from repro.core.numa import fully_connected

    m = E5_2699_V3_SNC2._replace(
        local_read_bw=400e9,  # decap banks: isolate the interconnect
        remote_read_bw=400e9,
        hop_attenuation=1.0,
    )
    wl = mixed_workload(
        "cross", 8, read_mix=(1.0, 0.0, 0.0), read_bpi=16.0, write_bpi=0.0,
        static_socket=2,  # socket 1's endpoint node
    )
    p = jnp.asarray([4, 4, 0, 0], jnp.int32)  # all threads on socket 0
    res = simulate(m, wl, p)
    qpi_bw = dict(zip(m.topology.link_ends, m.topology.link_bw))[(0, 2)]
    cross = float(np.asarray(res.read_flows)[:2, 2:].sum())
    assert cross <= qpi_bw * (1 + 1e-4)
    # same machine with per-node direct links moves strictly more
    direct = m._replace(topology=fully_connected(4, qpi_bw))
    res_direct = simulate(direct, wl, p)
    assert float(res_direct.throughput) > float(res.throughput)


def test_snc2_evaluate_suite_default_threads():
    """evaluate_suite's default thread count rounds down to a node-even
    split (18 -> 16 on the SNC-2 preset) and the suite runs end to end."""
    r = evaluate_suite(
        E5_2699_V3_SNC2, include_violators=False, max_placements=8, noise_std=0.02
    )
    assert r.all_errors.size > 0
    assert 0.0 < r.median_error_pct < 2.34


# ---------------------------------------------------------------------------
# heterogeneous core rates end to end
# ---------------------------------------------------------------------------


def test_throttled_node_issues_fewer_instructions():
    m = E5_2630_V3_THROTTLED
    wl = mixed_workload("cpu", 4, read_mix=(0.0, 1.0, 0.0), read_bpi=1e-3)
    res = simulate(m, wl, jnp.asarray([2, 2], jnp.int32))
    instr = np.asarray(res.sample.instructions)
    # unconstrained threads run at rate 1.0: instruction ratio == rate ratio
    np.testing.assert_allclose(instr[1] / instr[0], 1.6e9 / 2.4e9, rtol=1e-5)
    # and bandwidth demand scales with the node rate too
    flows = np.asarray(res.read_flows)
    np.testing.assert_allclose(
        flows[1, 1] / flows[0, 0], 1.6e9 / 2.4e9, rtol=1e-5
    )


def test_throttled_advisor_prefers_fast_node():
    """A compute-bound workload concentrates on the fast socket: the
    roofline's per-node rate weighting beats plain thread counting."""
    from repro.core.meshsig.advisor import rank_numa_placements

    m = E5_2630_V3_THROTTLED
    wl = mixed_workload("cpu", 6, read_mix=(0.1, 0.7, 0.1), read_bpi=0.3)
    ranked = rank_numa_placements(m, wl)
    assert ranked[0].placement[0] > ranked[0].placement[1]
    # the homogeneous twin has no such preference at equal remote fractions
    best, worst = ranked[0], ranked[-1]
    assert best.predicted_throughput > worst.predicted_throughput


def test_throttled_remote_fraction_is_demand_weighted():
    """remote_fraction must follow traffic (thread count x node rate), not
    raw thread count: with a pure-Static-on-node-0 signature and an equal
    [4, 4] split on the throttled machine, node 0 carries 2.4/(2.4+1.6) =
    0.6 of the demand, so 0.4 of the traffic is remote — not 0.5."""
    from repro.core.bwsig import DirectionSignature
    from repro.core.meshsig.advisor import _placement_scores

    sig = DirectionSignature.make(static_socket=0, static_fraction=1.0)
    fracs, _ = _placement_scores(
        E5_2630_V3_THROTTLED,
        sig,
        sig,
        jnp.asarray([[4, 4]], jnp.int32),
        1.0,
        0.25,
    )
    np.testing.assert_allclose(float(fracs[0]), 1.0 - 0.6, rtol=1e-6)
    # the homogeneous twin keeps the plain thread weighting
    fracs_h, _ = _placement_scores(
        E5_2630_V3, sig, sig, jnp.asarray([[4, 4]], jnp.int32), 1.0, 0.25
    )
    np.testing.assert_allclose(float(fracs_h[0]), 0.5, rtol=1e-6)
    # sub-unit demand mass must still normalize: one thread on the slow
    # node with a fully-local signature has zero remote traffic
    local = DirectionSignature.make(local_fraction=1.0)
    fracs_1, _ = _placement_scores(
        E5_2630_V3_THROTTLED,
        local,
        local,
        jnp.asarray([[0, 1]], jnp.int32),
        1.0,
        0.25,
    )
    np.testing.assert_allclose(float(fracs_1[0]), 0.0, atol=1e-6)


def test_throttled_machine_through_evaluate_batch():
    m = E5_2630_V3_THROTTLED
    wl = benchmark_workload("Swim", 8)
    batch = evaluate_batch(m, wl, sweep_placements(m, 8), keys=jax.random.PRNGKey(1))
    errs = np.asarray(batch.errors_combined)
    assert np.isfinite(errs).all()
    assert errs.max() < 2e-3  # noise-free + in-model stays exact


# ---------------------------------------------------------------------------
# MachineSpec.fingerprint guards the signature cache
# ---------------------------------------------------------------------------


def test_fingerprint_changes_with_node_fields():
    base = E5_2630_V3_THROTTLED
    fp = base.fingerprint()
    # any per-node core-rate entry
    assert base._replace(core_rate=(2.4e9, 1.7e9)).fingerprint() != fp
    assert base._replace(core_rate=(2.3e9, 1.6e9)).fingerprint() != fp
    # tuple vs scalar spelling must not collide either
    assert (
        base._replace(core_rate=(2.4e9, 2.4e9)).fingerprint()
        != base._replace(core_rate=2.4e9).fingerprint()
    )
    # nodes_per_socket participates even with everything else fixed
    snc = E5_2699_V3_SNC2
    flat = snc._replace(nodes_per_socket=1, sockets=4, cores_per_socket=9)
    assert flat.n_nodes == snc.n_nodes  # same node count, different meaning
    assert flat.fingerprint() != snc.fingerprint()
    # and the permutation of a heterogeneous rate vector matters
    assert (
        base._replace(core_rate=(1.6e9, 2.4e9)).fingerprint() != fp
    )


def test_make_machine_validates_node_fields():
    with pytest.raises(ValueError):
        make_machine("bad", sockets=2, cores_per_socket=9, nodes_per_socket=2)
    with pytest.raises(ValueError):
        make_machine("bad", sockets=2, core_rate=(2.4e9, 2.4e9, 2.4e9))
    with pytest.raises(ValueError):
        make_machine("bad", sockets=2, nodes_per_socket=0)
    m = make_machine(
        "ok", sockets=2, cores_per_socket=8, nodes_per_socket=2,
        core_rate=(2.4e9, 2.4e9, 1.8e9, 1.8e9),
    )
    assert m.n_nodes == 4 and m.topology.name == "snc2x2"
    assert isinstance(m.core_rate, tuple)


# ---------------------------------------------------------------------------
# per-node local bandwidth vectors (mixed DIMM populations)
# ---------------------------------------------------------------------------


def test_node_local_bw_broadcasts_scalar_and_tuple():
    """Every per-node consumer of local_*_bw goes through node_local_bw:
    scalars broadcast (the pre-refactor path, same values/dtype), tuples
    map each bank to its own capacity."""
    np.testing.assert_array_equal(
        np.asarray(E5_2630_V3.node_local_bw("read")),
        np.full((2,), E5_2630_V3.local_read_bw, np.float32),
    )
    np.testing.assert_array_equal(
        np.asarray(E5_2630_V3_MIXED_DIMM.node_local_bw("read")),
        np.asarray([52e9, 26e9], np.float32),
    )
    np.testing.assert_array_equal(
        np.asarray(E5_2630_V3_MIXED_DIMM.bank_write_caps()),
        np.asarray([28e9, 14e9], np.float32),
    )
    with pytest.raises(ValueError):
        E5_2630_V3.node_local_bw("sideways")


def test_local_bw_tuple_validation_and_fingerprint():
    with pytest.raises(ValueError):
        E5_2630_V3._replace(local_read_bw=(52e9,)).validate()
    with pytest.raises(ValueError):
        E5_2630_V3._replace(local_write_bw=(28e9, -1.0)).validate()
    # tuple vs scalar spelling must not collide in signature-cache keys
    fp = E5_2630_V3.fingerprint()
    assert E5_2630_V3._replace(local_read_bw=(52e9, 52e9)).fingerprint() != fp
    assert E5_2630_V3_MIXED_DIMM.fingerprint() != fp
    # and the per-node values themselves participate
    assert (
        E5_2630_V3_MIXED_DIMM._replace(local_read_bw=(26e9, 52e9)).fingerprint()
        != E5_2630_V3_MIXED_DIMM.fingerprint()
    )


def test_mixed_dimm_banks_cap_per_node():
    """Simulation respects each bank's own capacity: the half-populated
    bank saturates at half the bandwidth of the full one."""
    m = E5_2630_V3_MIXED_DIMM
    wl = mixed_workload("local", 8, read_mix=(0.0, 1.0, 0.0), read_bpi=8.0)
    res = simulate(m, wl, jnp.asarray([4, 4], jnp.int32))
    reads = np.asarray(res.read_flows).sum(0)
    assert np.isclose(reads[0], 52e9, rtol=1e-3)
    assert np.isclose(reads[1], 26e9, rtol=1e-3)


def test_mixed_dimm_through_evaluate_batch_and_advisor():
    """The scalar/tuple coercion audit end to end: the batched fit+predict
    engine stays exact on an in-model workload, and the advisor's roofline
    charges each bank its own capacity (so a bandwidth-bound workload
    concentrates on the fat-DIMM node)."""
    from repro.core.meshsig.advisor import rank_numa_placements

    m = E5_2630_V3_MIXED_DIMM
    wl = benchmark_workload("Swim", 8)
    batch = evaluate_batch(m, wl, sweep_placements(m, 8), keys=jax.random.PRNGKey(2))
    errs = np.asarray(batch.errors_combined)
    assert np.isfinite(errs).all()
    assert errs.max() < 2e-3
    heavy = mixed_workload("bw", 6, read_mix=(0.0, 1.0, 0.0), read_bpi=8.0)
    ranked = rank_numa_placements(m, heavy)
    assert ranked[0].placement[0] > ranked[0].placement[1]


def test_make_machine_canonicalizes_local_bw_sequences():
    m = make_machine(
        "mixed", sockets=2, cores_per_socket=8,
        local_read_bw=[50e9, 25e9], local_write_bw=[28e9, 14e9],
        remote_read_ratio=0.2, remote_write_ratio=0.3,
    )
    assert m.local_read_bw == (50e9, 25e9)
    assert isinstance(m.local_read_bw, tuple)
    # remote path bases anchor on the mean bank bandwidth
    assert m.remote_read_bw == pytest.approx(0.2 * 37.5e9)
    assert m.remote_write_bw == pytest.approx(0.3 * 21e9)
    with pytest.raises(ValueError):
        make_machine("bad", sockets=2, local_read_bw=[50e9, 25e9, 10e9])


# ---------------------------------------------------------------------------
# placement-enumeration invariants (homogeneous and SNC-2)
# ---------------------------------------------------------------------------

_ENUM_MACHINES = [E5_2630_V3, E5_2699_V3_SNC2, E5_2630_V3_THROTTLED]


@pytest.mark.parametrize("machine", _ENUM_MACHINES)
@pytest.mark.parametrize("n_threads", [1, 7, 16])
def test_enumeration_invariants(machine, n_threads):
    if n_threads > machine.n_nodes * machine.cores_per_node:
        with pytest.raises(ValueError):
            enumerate_placements(machine, n_threads)
        return
    full = np.asarray(enumerate_placements(machine, n_threads))
    assert full.shape == (count_placements(machine, n_threads), machine.n_nodes)
    assert (full.sum(axis=1) == n_threads).all()
    assert full.min() >= 0 and full.max() <= machine.cores_per_node
    assert len({tuple(r) for r in full.tolist()}) == full.shape[0]

    budget = max(1, full.shape[0] // 2)
    a = np.asarray(enumerate_placements(machine, n_threads, max_placements=budget, seed=5))
    b = np.asarray(enumerate_placements(machine, n_threads, max_placements=budget, seed=5))
    np.testing.assert_array_equal(a, b)  # deterministic under the budget
    assert a.shape[0] == min(budget, full.shape[0])
    full_set = {tuple(r) for r in full.tolist()}
    assert all(tuple(r) in full_set for r in a.tolist())


@settings(max_examples=25, deadline=None)
@given(
    n_threads=st.integers(1, 24),
    sockets=st.integers(2, 4),
    cores=st.integers(2, 8),
    nodes_per_socket=st.integers(1, 2),
    seed=st.integers(0, 3),
)
def test_property_enumeration_invariants(
    n_threads, sockets, cores, nodes_per_socket, seed
):
    """enumerate_placements rows sum to n_threads, respect per-node core
    caps, match count_placements, and subsample deterministically — on
    homogeneous and sub-NUMA-clustered machines alike."""
    cores_per_socket = cores * nodes_per_socket  # always divisible
    machine = make_machine(
        "prop",
        sockets=sockets,
        cores_per_socket=cores_per_socket,
        nodes_per_socket=nodes_per_socket,
    )
    total_cores = machine.n_nodes * machine.cores_per_node
    if n_threads > total_cores:
        with pytest.raises(ValueError):
            enumerate_placements(machine, n_threads)
        return
    full = np.asarray(enumerate_placements(machine, n_threads))
    assert full.shape == (count_placements(machine, n_threads), machine.n_nodes)
    assert (full.sum(axis=1) == n_threads).all()
    assert full.min() >= 0 and full.max() <= machine.cores_per_node
    a = np.asarray(enumerate_placements(machine, n_threads, max_placements=16, seed=seed))
    b = np.asarray(enumerate_placements(machine, n_threads, max_placements=16, seed=seed))
    np.testing.assert_array_equal(a, b)
    assert a.shape[0] == min(16, full.shape[0])
