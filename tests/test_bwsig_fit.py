"""Tests for the 2-run fitting procedure (paper §5) against the simulator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bwsig import (
    fit_signature,
    misfit_score,
    predict_counters,
    signature_distance,
)
from repro.core.numa import (
    E5_2630_V3,
    E5_2699_V3,
    mixed_workload,
    profile_pair,
    pure_workload,
    simulate,
)
from repro.core.numa.workload import violator_workload

MACHINE = E5_2699_V3
N_THREADS = 16


def _fit(workload, machine=MACHINE, **kwargs):
    sym, asym = profile_pair(machine, workload, **kwargs)
    return fit_signature(sym, asym)


@pytest.mark.parametrize(
    "pattern,expect",
    [
        ("static", (1.0, 0.0, 0.0, 0.0)),
        ("local", (0.0, 1.0, 0.0, 0.0)),
        ("per_thread", (0.0, 0.0, 1.0, 0.0)),
        ("interleaved", (0.0, 0.0, 0.0, 1.0)),
    ],
)
def test_synthetic_pure_patterns_recovered(pattern, expect):
    """Paper §6.1: each pure synthetic benchmark's signature is recovered
    with <0.9% miscategorized bandwidth."""
    wl = pure_workload(pattern, N_THREADS, pattern)
    sig = _fit(wl)
    got = (
        float(sig.read.static_fraction),
        float(sig.read.local_fraction),
        float(sig.read.per_thread_fraction),
        float(
            1.0
            - sig.read.static_fraction
            - sig.read.local_fraction
            - sig.read.per_thread_fraction
        ),
    )
    miscategorized = 0.5 * sum(abs(g - e) for g, e in zip(got, expect))
    assert miscategorized < 0.009, (pattern, got)


def test_static_socket_identified():
    wl = pure_workload("static1", N_THREADS, "static", static_socket=1)
    sig = _fit(wl)
    assert int(sig.read.static_socket) == 1
    assert float(sig.read.static_fraction) > 0.99


@pytest.mark.parametrize("machine", [E5_2630_V3, E5_2699_V3])
def test_mixed_workload_recovered(machine):
    """The paper's worked-example mix fits back to its true fractions."""
    n = 8 if machine.cores_per_socket == 8 else 16
    wl = mixed_workload(
        "worked", n, read_mix=(0.2, 0.35, 0.3), static_socket=1, read_bpi=0.3
    )
    sig = _fit(wl, machine=machine)
    assert int(sig.read.static_socket) == 1
    np.testing.assert_allclose(float(sig.read.static_fraction), 0.2, atol=0.02)
    np.testing.assert_allclose(float(sig.read.local_fraction), 0.35, atol=0.02)
    np.testing.assert_allclose(float(sig.read.per_thread_fraction), 0.3, atol=0.02)


def test_read_write_fitted_separately():
    wl = mixed_workload(
        "rw",
        N_THREADS,
        read_mix=(0.5, 0.2, 0.1),
        write_mix=(0.0, 0.8, 0.1),
        static_socket=0,
    )
    sig = _fit(wl)
    np.testing.assert_allclose(float(sig.read.static_fraction), 0.5, atol=0.03)
    np.testing.assert_allclose(float(sig.write.local_fraction), 0.8, atol=0.03)


@settings(max_examples=25, deadline=None)
@given(
    fracs=st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3).filter(
        lambda f: sum(f) <= 1.0
    ),
    socket=st.integers(0, 1),
)
def test_fit_roundtrip_property(fracs, socket):
    """Property: any representable homogeneous workload is recovered by the
    2-run fit to within 2% per class (noise-free counters)."""
    wl = mixed_workload(
        "prop", 8, read_mix=tuple(fracs), static_socket=socket, read_bpi=0.2
    )
    sig = _fit(wl)
    got = np.array(
        [
            float(sig.read.static_fraction),
            float(sig.read.local_fraction),
            float(sig.read.per_thread_fraction),
        ]
    )
    want = np.array(fracs)
    # Degenerate case: with a tiny static fraction the argmax socket is
    # noise-driven; distance metric still applies.
    assert np.abs(got - want).max() < 0.02, (got, want)


@settings(max_examples=25, deadline=None)
@given(
    fracs=st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3).filter(
        lambda f: sum(f) <= 1.0
    )
)
def test_fit_fractions_valid(fracs):
    """Property: fitted fractions are always a valid sub-distribution."""
    wl = mixed_workload("prop2", 8, read_mix=tuple(fracs))
    sig = _fit(wl)
    for d in (sig.read, sig.write):
        s = float(d.static_fraction)
        l = float(d.local_fraction)
        p = float(d.per_thread_fraction)
        assert -1e-6 <= s <= 1 + 1e-6
        assert -1e-6 <= l <= 1 + 1e-6
        assert -1e-6 <= p <= 1 + 1e-6
        assert s + l + p <= 1 + 1e-5


def test_fit_robust_to_noise():
    wl = mixed_workload("noisy", N_THREADS, read_mix=(0.2, 0.35, 0.3), static_socket=1)
    sig = _fit(wl, noise_std=0.01, key=jax.random.PRNGKey(7))
    np.testing.assert_allclose(float(sig.read.static_fraction), 0.2, atol=0.05)
    np.testing.assert_allclose(float(sig.read.local_fraction), 0.35, atol=0.05)
    np.testing.assert_allclose(float(sig.read.per_thread_fraction), 0.3, atol=0.05)


def test_prediction_matches_measurement_on_new_placement():
    """End-to-end §6.2.2-style check: fit on the 2 profiling runs, predict
    the counters of an unseen placement, compare against simulation."""
    wl = mixed_workload("acc", N_THREADS, read_mix=(0.2, 0.35, 0.3), static_socket=1)
    sig = _fit(wl)
    target = jnp.asarray([11, 5], jnp.int32)
    res = simulate(MACHINE, wl, target)
    measured_local = res.sample.local_read
    measured_remote = res.sample.remote_read
    # Per-socket demand taken from the measurement (the model predicts the
    # *distribution*, the totals come from elsewhere — paper §4).
    demand = jnp.asarray(res.read_flows.sum(axis=1))
    pred_local, pred_remote = predict_counters(sig.read, demand, target)
    total = float((measured_local + measured_remote).sum())
    err = (
        np.abs(np.asarray(pred_local - measured_local)).sum()
        + np.abs(np.asarray(pred_remote - measured_remote)).sum()
    ) / total
    assert err < 0.02, err


def test_misfit_detector_flags_violator():
    """Paper §6.2.1: the symmetry redundancy check separates representable
    workloads from Page-rank-like violators."""
    good = mixed_workload("good", N_THREADS, read_mix=(0.2, 0.35, 0.3))
    bad = violator_workload("pagerank", N_THREADS)
    sym_good, _ = profile_pair(MACHINE, good)
    sym_bad, _ = profile_pair(MACHINE, bad)
    score_good = float(misfit_score(sym_good, "read"))
    score_bad = float(misfit_score(sym_bad, "read"))
    assert score_bad > 5 * max(score_good, 1e-6), (score_good, score_bad)


def test_signature_distance_metric():
    wl_a = mixed_workload("a", 8, read_mix=(1.0, 0.0, 0.0), static_socket=0)
    wl_b = mixed_workload("b", 8, read_mix=(0.0, 1.0, 0.0))
    sig_a = _fit(wl_a)
    sig_b = _fit(wl_b)
    d_ab = float(signature_distance(sig_a, sig_b))
    d_aa = float(signature_distance(sig_a, sig_a))
    assert d_aa < 1e-5
    assert 0.95 < d_ab <= 1.0 + 1e-6
