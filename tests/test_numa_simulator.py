"""Properties of the max-min fair NUMA bandwidth simulator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.numa import (
    E5_2630_V3,
    E5_2699_V3,
    mixed_workload,
    pure_workload,
    simulate,
)
from repro.core.numa.simulator import _resource_tensor, _thread_nodes, _mix_rows


def test_thread_node_assignment_contiguous():
    got = _thread_nodes(jnp.asarray([3, 1]), 4)
    np.testing.assert_array_equal(np.asarray(got), [0, 0, 0, 1])


def test_rates_in_unit_interval():
    wl = mixed_workload("m", 16, read_mix=(0.2, 0.3, 0.3))
    res = simulate(E5_2699_V3, wl, jnp.asarray([8, 8]))
    r = np.asarray(res.rates)
    assert (r > 0).all() and (r <= 1.0 + 1e-6).all()


def test_unconstrained_threads_run_full_speed():
    """A workload with negligible bandwidth demand is CPU-bound: x == 1."""
    wl = mixed_workload("tiny", 8, read_mix=(0.0, 1.0, 0.0), read_bpi=1e-4, write_bpi=0.0)
    res = simulate(E5_2630_V3, wl, jnp.asarray([4, 4]))
    np.testing.assert_allclose(np.asarray(res.rates), 1.0, atol=1e-5)


def test_capacity_constraints_respected():
    """No resource exceeds its capacity at the solved rates."""
    wl = mixed_workload("heavy", 16, read_mix=(0.5, 0.0, 0.0), read_bpi=4.0, write_bpi=2.0)
    machine = E5_2630_V3
    n_per = jnp.asarray([8, 8])
    res = simulate(machine, wl, n_per)
    # banks
    assert float(res.read_flows.sum(0).max()) <= machine.local_read_bw * (1 + 1e-4)
    assert float(res.write_flows.sum(0).max()) <= machine.local_write_bw * (1 + 1e-4)
    # remote paths
    off = ~np.eye(2, dtype=bool)
    assert np.asarray(res.read_flows)[off].max() <= machine.remote_read_bw * (1 + 1e-4)
    assert np.asarray(res.write_flows)[off].max() <= machine.remote_write_bw * (1 + 1e-4)
    # interconnect (2 sockets: one link carries all cross traffic)
    qpi = float(np.asarray(res.read_flows)[off].sum() + np.asarray(res.write_flows)[off].sum())
    assert qpi <= float(machine.link_caps()[0]) * (1 + 1e-4)


def test_maxmin_some_resource_saturated_or_full_speed():
    wl = mixed_workload("sat", 16, read_mix=(1.0, 0.0, 0.0), read_bpi=2.0)
    machine = E5_2630_V3
    res = simulate(machine, wl, jnp.asarray([8, 8]))
    r = np.asarray(res.rates)
    if not np.allclose(r, 1.0):
        # static reads all hit bank 0: either the bank's read capacity or
        # the remote read path into it must be tight (max-min: someone's
        # bottleneck is saturated)
        bank0 = float(res.read_flows.sum(0)[0])
        remote0 = float(res.read_flows[1, 0])
        assert np.isclose(bank0, machine.local_read_bw, rtol=1e-3) or np.isclose(
            remote0, machine.remote_read_bw, rtol=1e-3
        ), (bank0, remote0)


def test_remote_saturation_slows_threads():
    """Static memory on socket 0, threads split: remote threads are limited
    by the weak remote path on the 8-core machine (paper Figure 1)."""
    wl = pure_workload("static", 8, "static", read_bpi=1.0, static_socket=0)
    machine = E5_2630_V3
    res = simulate(machine, wl, jnp.asarray([4, 4]))
    r = np.asarray(res.rates)
    # threads 0-3 are local to the static bank, 4-7 remote
    assert r[4:].max() < r[:4].min()


def test_18core_more_forgiving_than_8core():
    """Paper Figure 1: the 18-core machine tolerates remote placement far
    better than the 8-core machine."""
    def remote_penalty(machine, n):
        wl = pure_workload("static", n, "static", read_bpi=0.9, static_socket=0)
        local = simulate(machine, wl, jnp.asarray([n, 0])).throughput
        split = simulate(machine, wl, jnp.asarray([n // 2, n // 2])).throughput
        return float(local) / float(split)

    p8 = remote_penalty(E5_2630_V3, 8)
    p18 = remote_penalty(E5_2699_V3, 18)
    # On the cheap machine remote access hurts much more.
    assert p8 > p18


def test_vmap_over_placements():
    """The §6.2.2 evaluation shape: thousands of placements in one call."""
    wl = mixed_workload("v", 16, read_mix=(0.2, 0.3, 0.3))
    placements = jnp.stack(
        [jnp.asarray([i, 16 - i], jnp.int32) for i in range(1, 16)]
    )
    f = jax.vmap(lambda p: simulate(E5_2699_V3, wl, p).throughput)
    out = np.asarray(f(placements))
    assert out.shape == (15,)
    assert (out > 0).all()


def test_conservation_flows_match_demand():
    """Total flows equal sum over threads of rate*intensity*core_rate,
    each thread issuing at its node's rate."""
    wl = mixed_workload("c", 8, read_mix=(0.1, 0.5, 0.2), read_bpi=0.4, write_bpi=0.1)
    machine = E5_2699_V3
    n_per = jnp.asarray([5, 3])
    res = simulate(machine, wl, n_per)
    rate_of = np.asarray(machine.node_rates())[np.asarray(_thread_nodes(n_per, 8))]
    expect_read = float((np.asarray(res.rates) * rate_of * np.asarray(wl.read_bpi)).sum())
    np.testing.assert_allclose(float(res.read_flows.sum()), expect_read, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    n0=st.integers(1, 8),
    bpi=st.floats(0.01, 4.0),
    mix=st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3).filter(
        lambda f: sum(f) <= 1.0
    ),
)
def test_property_caps_never_exceeded(n0, bpi, mix):
    wl = mixed_workload("p", 8, read_mix=tuple(mix), read_bpi=bpi, write_bpi=bpi / 3)
    machine = E5_2630_V3
    res = simulate(machine, wl, jnp.asarray([n0, 8 - n0]))
    read = np.asarray(res.read_flows)
    write = np.asarray(res.write_flows)
    assert read.sum(0).max() <= machine.local_read_bw * (1 + 1e-3)
    assert write.sum(0).max() <= machine.local_write_bw * (1 + 1e-3)
    off = ~np.eye(2, dtype=bool)
    assert read[off].max() <= machine.remote_read_bw * (1 + 1e-3)
    assert write[off].max() <= machine.remote_write_bw * (1 + 1e-3)
    assert (np.asarray(res.rates) <= 1 + 1e-6).all()
