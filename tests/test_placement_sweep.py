"""The batched multi-socket placement-sweep engine (beyond-paper s >= 2).

Covers the composition enumerator (exactness, budget subsampling, s = 2
reduction to the paper's ``[i, n - i]`` sweep), the ``evaluate_batch``
equivalence with per-placement simulation on a 4-socket machine, the
single-trace guarantee behind ``evaluate_suite``, and the fitted-signature
cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.bwsig import fit_signature, misfit_score, predict_counters
from repro.core.numa import (
    E5_2630_V3,
    E5_2699_V3,
    E7_4830_V3,
    E7_8860_V3,
    make_machine,
    mixed_workload,
    profile_pair,
    simulate,
)
from repro.core.numa.benchmarks import benchmark_workload
from repro.core.numa.evaluate import (
    _evaluate_batch_jit,
    count_placements,
    enumerate_placements,
    evaluate_accuracy,
    evaluate_batch,
    evaluate_suite,
    fitted_signatures,
    sweep_placements,
)

# ---------------------------------------------------------------------------
# enumerator properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", [E5_2630_V3, E7_4830_V3, E7_8860_V3])
@pytest.mark.parametrize("n_threads", [1, 8, 16])
def test_enumeration_is_exact_and_valid(machine, n_threads):
    p = np.asarray(enumerate_placements(machine, n_threads, max_placements=400))
    assert p.shape[0] >= 1
    assert (p.sum(axis=1) == n_threads).all()
    assert p.min() >= 0 and p.max() <= machine.cores_per_socket
    # no duplicates (subsampling draws ranks without replacement)
    assert len({tuple(row) for row in p.tolist()}) == p.shape[0]


@pytest.mark.parametrize("n_threads", [1, 5, 8, 12, 16])
def test_s2_reduces_to_legacy_pair_sweep(n_threads):
    """At s = 2 the generalized enumerator must emit exactly the paper's
    ``[i, n - i]`` sweep, in the same order."""
    machine = E5_2630_V3
    cores = machine.cores_per_socket
    lo, hi = max(0, n_threads - cores), min(cores, n_threads)
    legacy = [[i, n_threads - i] for i in range(lo, hi + 1)]
    got = np.asarray(sweep_placements(machine, n_threads)).tolist()
    assert got == legacy


def test_count_matches_enumeration_and_budget_is_deterministic():
    machine = E7_4830_V3
    total = count_placements(machine, 10)
    full = np.asarray(enumerate_placements(machine, 10))
    assert full.shape == (total, 4)
    a = np.asarray(enumerate_placements(machine, 10, max_placements=50, seed=3))
    b = np.asarray(enumerate_placements(machine, 10, max_placements=50, seed=3))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (50, 4)
    # the sample is a subset of the full enumeration
    full_set = {tuple(r) for r in full.tolist()}
    assert all(tuple(r) in full_set for r in a.tolist())


def test_enumerate_rejects_impossible_thread_counts():
    with pytest.raises(ValueError):
        enumerate_placements(E5_2630_V3, 17)


def test_vectorized_unranking_matches_bigint_loop_and_brute_force():
    """The numpy-vectorized unranking emits the exact lexicographic
    enumeration (checked against a brute-force product filter) and the
    per-rank bigint fallback (same table, forced path)."""
    from itertools import product

    from repro.core.numa.evaluate import _composition_table, _unrank_compositions

    s, cap, n = 4, 5, 9
    table = _composition_table(s, cap, n)
    total = table[s][n]
    got = _unrank_compositions(table, range(total), s, cap, n)
    brute = np.asarray(
        [c for c in product(range(cap + 1), repeat=s) if sum(c) == n], np.int32
    )
    np.testing.assert_array_equal(got, brute)  # product() is lexicographic
    # the bigint fallback (huge sentinel in an unused cell flips the int64
    # guard): unrank compositions of n-1 through both paths
    big = tuple(tuple(row) for row in table[:-1]) + (
        tuple(table[-1][:-1]) + (2**70,),
    )
    total2 = table[s][n - 1]
    ranks = [0, 1, total2 // 2, total2 - 1]
    np.testing.assert_array_equal(
        _unrank_compositions(big, ranks, s, cap, n - 1),
        _unrank_compositions(table, ranks, s, cap, n - 1),
    )


@settings(max_examples=20, deadline=None)
@given(
    n_threads=st.integers(1, 32),
    sockets=st.integers(2, 5),
    cores=st.integers(2, 8),
)
def test_property_compositions_sum_and_bound(n_threads, sockets, cores):
    machine = make_machine("prop", sockets=sockets, cores_per_socket=cores)
    if n_threads > sockets * cores:
        with pytest.raises(ValueError):
            enumerate_placements(machine, n_threads)
        return
    p = np.asarray(enumerate_placements(machine, n_threads, max_placements=64))
    assert (p.sum(axis=1) == n_threads).all()
    assert p.min() >= 0 and p.max() <= cores


# ---------------------------------------------------------------------------
# evaluate_batch equivalence with per-placement simulate on 4 sockets
# ---------------------------------------------------------------------------


def _manual_accuracy(machine, workload, placements, key):
    """The seed implementation's per-placement math, written out longhand."""
    k_prof, k_meas = jax.random.split(key)
    sym, asym = profile_pair(machine, workload, key=k_prof)
    sig = fit_signature(sym, asym)
    sig_c = fit_signature(sym, asym, combined=True)
    keys = jax.random.split(k_meas, placements.shape[0])

    rows = []
    for placement, k in zip(placements, keys):
        res = simulate(machine, workload, placement, key=k)
        total = float(res.read_flows.sum() + res.write_flows.sum())
        total = max(total, 1e-9)
        comb_flows = res.read_flows + res.write_flows
        demand = comb_flows.sum(axis=1)
        pred_l, pred_r = predict_counters(sig_c.read, demand, placement)
        err = jnp.concatenate(
            [
                jnp.abs(pred_l - (res.sample.local_read + res.sample.local_write)),
                jnp.abs(pred_r - (res.sample.remote_read + res.sample.remote_write)),
            ]
        )
        rows.append(np.asarray(err) / total)
    return np.stack(rows), sig


def test_evaluate_batch_equals_per_placement_simulate_4socket():
    machine = E7_4830_V3
    wl = benchmark_workload("CG", 16)
    placements = enumerate_placements(machine, 16, max_placements=16, seed=1)
    key = jax.random.PRNGKey(7)

    with jax.disable_jit():
        # eager vs eager: the shared-slab engine computes the same math
        # with batched contractions (structured remote einsums, closed-form
        # counter predictions), so equality holds to float32 round-off
        # rather than bit-for-bit
        batch = evaluate_batch(machine, wl, placements, keys=key)
        manual, manual_sig = _manual_accuracy(machine, wl, placements, key)
        np.testing.assert_allclose(
            np.asarray(batch.errors_combined[0]), manual, atol=1e-6
        )

    # the jitted trace agrees to float tolerance (XLA fusion reorders ops)
    batch_jit = evaluate_batch(machine, wl, placements, keys=key)
    np.testing.assert_allclose(
        np.asarray(batch_jit.errors_combined[0]), manual, atol=1e-5
    )
    # fitted signature round-trips through the batch path too
    sig = jax.tree.map(lambda x: x[0], batch_jit.signatures)
    for got, want in zip(jax.tree.leaves(sig), jax.tree.leaves(manual_sig)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_accuracy_is_noise_free_exact_on_4_and_8_sockets():
    """The §6.2.2 anchor generalized: with perfect counters and an in-model
    workload, predictions must match measurements on any socket count."""
    for machine in (E7_4830_V3, E7_8860_V3):
        wl = benchmark_workload("Swim", machine.cores_per_socket)
        res = evaluate_accuracy(machine, wl, max_placements=40)
        assert float(np.asarray(res.errors_combined).max()) < 2e-3, machine.name


def test_evaluate_suite_uses_single_trace():
    """All benchmarks of a suite evaluation must flow through ONE
    compilation of the batched engine (no per-benchmark retracing)."""
    machine = E5_2699_V3
    before = _evaluate_batch_jit._cache_size()
    r = evaluate_suite(machine, 8, noise_std=0.02, seed=11)
    after = _evaluate_batch_jit._cache_size()
    assert after - before <= 1
    assert len(r.names) == 23
    assert r.all_errors.size == 23 * 9 * 4  # benchmarks x placements x 2s


def test_suite_median_error_on_4socket_machine():
    """The paper's headline protocol on a 4-socket box: ≥500 placements,
    median model error reported and inside the paper's 2.34% band."""
    r = evaluate_suite(
        E7_4830_V3,
        2 * E7_4830_V3.cores_per_socket,
        noise_std=0.02,
        include_violators=False,
        max_placements=30,
    )
    n_placements = count_placements(E7_4830_V3, 2 * E7_4830_V3.cores_per_socket)
    assert n_placements >= 500  # the full sweep space is paper-scale
    assert r.all_errors.size > 1000
    assert 0.0 < r.median_error_pct < 2.34


def test_fitted_signature_cache_hits():
    machine = E5_2630_V3
    wl = mixed_workload("cache-me", 8, read_mix=(0.3, 0.3, 0.2))
    a = fitted_signatures(machine, wl)[0]
    b = fitted_signatures(machine, wl)[0]
    assert a[0] is b[0]  # identical object => served from the cache
    # different noise is a different key
    c = fitted_signatures(machine, wl, noise_std=0.01)[0]
    assert c[0] is not a[0]


def test_sig_cache_evicts_oldest_and_keeps_hot_keys(monkeypatch):
    """Ordered LRU eviction: filling the cache past its high-water mark
    drops the *oldest* entries, and a key touched mid-fill (LRU hit)
    survives a full eviction cycle instead of being nuked with the rest."""
    from repro.core.numa import evaluate as ev

    monkeypatch.setattr(ev, "_SIG_CACHE", {})
    monkeypatch.setattr(ev, "_SIG_CACHE_MAX", 8)

    def put(i):
        ev._SIG_CACHE[("key", i)] = i
        ev._evict_cache_if_full()

    for i in range(8):
        put(i)
    hot = ("key", 0)
    for i in range(8, 15):  # 7 younger entries; touch the hot key each time
        assert ev._cache_lookup(hot) == 0
        put(i)
    assert hot in ev._SIG_CACHE  # survived a full eviction cycle
    assert len(ev._SIG_CACHE) == 8
    # the oldest untouched keys are the ones that left
    assert ("key", 1) not in ev._SIG_CACHE
    assert ("key", 14) in ev._SIG_CACHE
    assert ev._cache_lookup(("key", 1)) is None


def test_vectorized_link_resources_match_reference_loop():
    """The vectorized per-link charging (endpoint gather + routed-incidence
    matmul) must reproduce a python loop walking every ordered pair's route
    on the glued 8-socket topology."""
    from repro.core.numa.simulator import _resource_tensor, _thread_nodes

    machine = E7_8860_V3
    topo = machine.topology
    n_threads = 16
    rng = np.random.default_rng(0)
    read_unit = jnp.asarray(rng.uniform(0, 1e9, (n_threads, machine.sockets)), jnp.float32)
    write_unit = jnp.asarray(rng.uniform(0, 1e9, (n_threads, machine.sockets)), jnp.float32)
    n_per = jnp.asarray([4, 4, 2, 2, 2, 1, 1, 0], jnp.int32)
    socket_of = _thread_nodes(n_per, n_threads)
    usage, caps = _resource_tensor(machine, read_unit, write_unit, socket_of)

    s = machine.sockets
    onehot = jax.nn.one_hot(socket_of, s)
    rr = onehot[:, :, None] * read_unit[:, None, :]
    ww = onehot[:, :, None] * write_unit[:, None, :]
    off = (1.0 - jnp.eye(s))[None, :, :]
    cross = np.asarray(rr * off + ww * off)  # (n, s, s)
    legacy = np.zeros((n_threads, topo.n_links), np.float64)
    for i in range(s):
        for j in range(s):
            for l in topo.route(i, j):
                legacy[:, l] += cross[:, i, j]
    n_links = topo.n_links
    np.testing.assert_allclose(
        np.asarray(usage[:, -n_links:]), legacy, rtol=1e-5
    )
    np.testing.assert_array_equal(
        np.asarray(caps[-n_links:]), np.asarray(topo.link_bw, np.float32)
    )
    # a 2-hop pair's flow shows up on BOTH links of its route
    t = 0  # thread 0 lives on socket 0; pair (0, 5) routes over 2 links
    route = topo.route(0, 5)
    assert len(route) == 2
    for l in route:
        a, b = topo.link_ends[l]
        contributions = sum(
            cross[t, i, j]
            for i in range(s)
            for j in range(s)
            if l in topo.route(i, j)
        )
        np.testing.assert_allclose(float(usage[t, -n_links + l]), contributions, rtol=1e-5)


def test_misfit_detector_still_flags_violators_on_4socket():
    good = benchmark_workload("Swim", 16)
    bad = benchmark_workload("Page rank", 16)
    m_good = float(misfit_score(profile_pair(E7_4830_V3, good)[0], "read"))
    m_bad = float(misfit_score(profile_pair(E7_4830_V3, bad)[0], "read"))
    assert m_bad > 10 * (m_good + 1e-6)
