"""Learned topology calibration: the simulator's inverse problem.

* **Round-trip acceptance**: sweep a known machine (glued 8-socket and
  SNC-2 — multi-hop routing, shared links, attenuation), fit blind from
  the samples alone, recover every per-link bandwidth within 5% and keep
  the refit model's median sweep error within 0.25pp of the ground-truth
  model's.  The test drives ``benchmarks/calibration_roundtrip.py``'s
  ``roundtrip`` so the CI gate and the suite share one code path.
* **Packing layer**: ``link_groups`` / ``from_fit`` (routes held static).
* **Seeding**: closed-form counter bounds land on the true capacities.
* **Counter-trace path**: externally supplied ``CounterSample``s fit the
  same as simulator-collected sweeps.
* **Per-node bandwidth vectors**: the mixed-DIMM preset's unequal banks
  are recovered as tuples — the regression the scalar model could not
  express.
"""

import importlib.util
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.numa import (
    E5_2630_V3,
    E5_2630_V3_MIXED_DIMM,
    E5_2699_V3_SNC2,
    E7_8860_V3,
    blind_template,
    collect_sweep,
    fit_from_simulated,
    fit_machine,
    link_relative_errors,
    local_bw_relative_errors,
    probe_suite,
    samples_from_counters,
    seed_parameters,
)
from repro.core.numa.calibrate import _caps_from, CalibrationParams
from repro.core.numa.simulator import machine_caps, simulate
from repro.core.numa.topology import from_fit, link_groups, ring


def _load_benchmark(name):
    path = Path(__file__).resolve().parents[1] / "benchmarks" / f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# Round-trip acceptance: fit blind, recover the machine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", [E7_8860_V3, E5_2699_V3_SNC2])
def test_roundtrip_recovers_links_and_sweep_error(machine):
    """The acceptance loop: known machine -> synthetic sweep -> blind fit.
    Every per-link bandwidth within 5% relative error; the refit model's
    median placement-sweep error within 0.25pp of the ground truth's."""
    roundtrip = _load_benchmark("calibration_roundtrip").roundtrip
    rec = roundtrip(
        machine,
        steps=200,
        sweep_benchmarks=("Swim", "CG"),
        max_placements=24,
    )
    assert rec["max_link_error"] < 0.05, rec
    assert rec["sweep_median_delta_pp"] < 0.25, rec
    # local banks come along for free (they are fitted jointly)
    assert rec["max_local_read_error"] < 0.05
    assert rec["max_local_write_error"] < 0.05


def test_roundtrip_recovers_attenuation_when_observable():
    """On the SNC-2 preset the hop-attenuated remote caps are tighter than
    every link on their routes, so the attenuation itself is identifiable
    — the fit must recover 0.9, not just a behavioral equivalent."""
    res = fit_from_simulated(E5_2699_V3_SNC2, steps=200)
    assert abs(res.machine.hop_attenuation - 0.9) < 0.02
    assert float(link_relative_errors(res.machine, E5_2699_V3_SNC2).max()) < 0.05


def test_blind_template_carries_no_answer():
    """The template handed to the fit must not leak the quantities under
    recovery (the 'fit blind' contract)."""
    t = blind_template(E7_8860_V3)
    assert t.local_read_bw != E7_8860_V3.local_read_bw
    assert t.local_write_bw != E7_8860_V3.local_write_bw
    assert t.hop_attenuation != E7_8860_V3.hop_attenuation
    assert len(set(t.topology.link_bw)) == 1  # all links one placeholder
    # structure is preserved: link list, routes, remote bases, rates
    assert t.topology.link_ends == E7_8860_V3.topology.link_ends
    assert t.topology.routes == E7_8860_V3.topology.routes
    assert t.remote_read_bw == E7_8860_V3.remote_read_bw
    assert t.core_rate == E7_8860_V3.core_rate


# ---------------------------------------------------------------------------
# Packing layer and from_fit
# ---------------------------------------------------------------------------


def test_link_groups_untied_and_tied():
    topo = E7_8860_V3.topology  # 12 QPI links + 4 node-controller links
    untied = link_groups(topo)
    assert untied.n_params == topo.n_links
    assert untied.groups == tuple((l,) for l in range(topo.n_links))
    tied = link_groups(topo, tie_equal_bw=True)
    assert tied.n_params == 2
    assert sorted(len(g) for g in tied.groups) == [4, 12]
    # pack/unpack round-trips per-link values through the group structure
    bw = np.asarray(topo.link_bw)
    packed = tied.pack(bw)
    np.testing.assert_allclose(np.asarray(tied.unpack(packed)), bw)
    with pytest.raises(ValueError):
        type(untied)(groups=((0, 1), (1, 2))).validate()  # not a partition


def test_from_fit_holds_routes_static():
    """Fitted bandwidths must NOT reroute: the routing table is structural
    knowledge the inverse problem conditions on."""
    template = ring(4, 10.0)
    new_bw = [1.0, 100.0, 100.0, 100.0]  # widest-path would now avoid link 0
    fitted = from_fit(template, new_bw)
    assert fitted.routes == template.routes
    assert fitted.link_ends == template.link_ends
    assert fitted.link_bw == (1.0, 100.0, 100.0, 100.0)
    assert hash(fitted)  # still a valid jit static arg / cache key


def test_caps_from_matches_machine_caps_at_truth():
    """With parameters set to a machine's true values, the calibration's
    traced capacity vector equals the simulator's own (modulo the finite
    stand-in for the unconstrained diagonal)."""
    m = E5_2699_V3_SNC2
    groups = link_groups(m.topology)
    params = CalibrationParams(
        log_link_bw=np.log(np.asarray(groups.pack(m.topology.link_bw), np.float32)),
        log_local_read=np.log(np.asarray(m.node_local_bw("read"))),
        log_local_write=np.log(np.asarray(m.node_local_bw("write"))),
        att_raw=np.float32(np.log(m.hop_attenuation / (1 - m.hop_attenuation))),
    )
    got = np.asarray(_caps_from(m, groups, params))
    want = np.asarray(machine_caps(m))
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-5)
    assert (got[~finite] > 0).all() and np.isfinite(got[~finite]).all()


# ---------------------------------------------------------------------------
# Seeding
# ---------------------------------------------------------------------------


def test_seed_parameters_are_tight_on_probe_sweep():
    """The closed-form counter bounds land on the true capacities when the
    probe suite saturates them (noise-free): the gradient stage refines,
    it does not rescue."""
    m = E5_2630_V3
    samples = collect_sweep(m)
    seed = seed_parameters(blind_template(m), samples)
    np.testing.assert_allclose(
        np.exp(np.asarray(seed.log_local_read)),
        np.asarray(m.node_local_bw("read")),
        rtol=0.02,
    )
    np.testing.assert_allclose(
        np.exp(np.asarray(seed.log_link_bw)),
        np.asarray(m.topology.link_bw),
        rtol=0.02,
    )


def test_probe_suite_shares_thread_count_and_respects_caps():
    for m in (E5_2630_V3, E5_2699_V3_SNC2, E7_8860_V3):
        probes = probe_suite(m)
        nts = {wl.n_threads for wl, _ in probes}
        assert len(nts) == 1
        for _, placement in probes:
            p = np.asarray(placement)
            assert p.sum() == next(iter(nts))
            assert p.min() >= 0 and p.max() <= m.cores_per_node
    with pytest.raises(ValueError):
        probe_suite(E5_2630_V3, n_threads=E5_2630_V3.cores_per_node + 1)


# ---------------------------------------------------------------------------
# The external counter-trace path
# ---------------------------------------------------------------------------


def test_samples_from_counters_matches_collect_sweep():
    """A bwsig/counters.py-shaped trace (one CounterSample per run) fits
    identically to the simulator-collected sweep — the real-machine
    entry point."""
    m = E5_2630_V3
    probes = probe_suite(m)
    via_sim = collect_sweep(m)
    counters = [
        simulate(m, wl, np.asarray(p)).sample for wl, p in probes
    ]
    via_trace = samples_from_counters(
        [wl for wl, _ in probes], np.stack([p for _, p in probes]), counters
    )
    for a, b in zip(via_sim[1:], via_trace[1:]):  # skip wl_arrays tuple
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    res = fit_machine(blind_template(m), via_trace, steps=80)
    assert float(link_relative_errors(res.machine, m).max()) < 0.05
    with pytest.raises(ValueError):
        samples_from_counters([p[0] for p in probes], np.stack([p for _, p in probes]), counters[:-1])
    # a counters/placements order mismatch must fail loudly, not corrupt
    # the apportionment: each CounterSample records its own run's placement
    shuffled = np.stack([p for _, p in probes])[::-1]
    with pytest.raises(ValueError, match="recorded placement"):
        samples_from_counters([p[0] for p in probes], shuffled, counters)


def test_fit_is_noise_robust():
    """Measurement noise on the sweep degrades recovery gracefully — the
    fit averages over the whole sample set instead of trusting any single
    saturated run."""
    m = E5_2630_V3
    res = fit_from_simulated(
        m, steps=150, noise_std=0.02, key=jax.random.PRNGKey(7)
    )
    assert float(link_relative_errors(res.machine, m).max()) < 0.15
    errs = local_bw_relative_errors(res.machine, m)
    assert float(errs["read"].max()) < 0.15
    assert float(errs["write"].max()) < 0.15


# ---------------------------------------------------------------------------
# Per-node bandwidth vectors: the mixed-DIMM regression
# ---------------------------------------------------------------------------


def test_mixed_dimm_banks_are_recovered_per_node():
    """The calibration must recover UNEQUAL bank capacities — node 1's
    half-populated DIMMs — which the scalar local_*_bw model could not
    even represent."""
    m = E5_2630_V3_MIXED_DIMM
    res = fit_from_simulated(m, steps=150)
    fitted_read = np.asarray(res.machine.node_local_bw("read"))
    assert fitted_read[0] > 1.8 * fitted_read[1]  # asymmetry survives
    errs = local_bw_relative_errors(res.machine, m)
    assert float(errs["read"].max()) < 0.05
    assert float(errs["write"].max()) < 0.05
    assert float(link_relative_errors(res.machine, m).max()) < 0.05


def test_fit_rejects_mismatched_samples():
    samples = collect_sweep(E5_2630_V3)
    with pytest.raises(ValueError):
        fit_machine(blind_template(E5_2699_V3_SNC2), samples, steps=1)
