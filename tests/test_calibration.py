"""Learned topology calibration: the simulator's inverse problem.

* **Round-trip acceptance**: sweep a known machine (glued 8-socket and
  SNC-2 — multi-hop routing, shared links, attenuation), fit blind from
  the samples alone, recover every per-link bandwidth within 5% and keep
  the refit model's median sweep error within 0.25pp of the ground-truth
  model's.  The test drives ``benchmarks/calibration_roundtrip.py``'s
  ``roundtrip`` so the CI gate and the suite share one code path.
* **Packing layer**: ``link_groups`` / ``from_fit`` (routes held static).
* **Seeding**: closed-form counter bounds land on the true capacities.
* **Counter-trace path**: externally supplied ``CounterSample``s fit the
  same as simulator-collected sweeps.
* **Per-node bandwidth vectors**: the mixed-DIMM preset's unequal banks
  are recovered as tuples — the regression the scalar model could not
  express.
* **Ingestion guards and the swap-guard metric**: ``clean_samples``
  rejects corrupted rows with counted receipts, partial sweeps
  concatenate/subset and still fit, the Huber loss survives outlier
  rows, and ``sweep_median_error_pct`` orders truth below drift — the
  exact comparison the live-recalibration guard makes.
"""

import importlib.util
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.numa import (
    E5_2630_V3,
    E5_2630_V3_MIXED_DIMM,
    E5_2699_V3_SNC2,
    E7_8860_V3,
    blind_template,
    clean_samples,
    collect_sweep,
    concat_samples,
    counter_errors_pct,
    fit_from_simulated,
    fit_machine,
    link_relative_errors,
    local_bw_relative_errors,
    probe_suite,
    samples_from_counters,
    seed_parameters,
    sweep_median_error_pct,
    take_samples,
)
from repro.core.numa.calibrate import _caps_from, CalibrationParams
from repro.core.numa.simulator import machine_caps, simulate
from repro.core.numa.topology import from_fit, link_groups, ring


def _load_benchmark(name):
    path = Path(__file__).resolve().parents[1] / "benchmarks" / f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# Round-trip acceptance: fit blind, recover the machine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", [E7_8860_V3, E5_2699_V3_SNC2])
def test_roundtrip_recovers_links_and_sweep_error(machine):
    """The acceptance loop: known machine -> synthetic sweep -> blind fit.
    Every per-link bandwidth within 5% relative error; the refit model's
    median placement-sweep error within 0.25pp of the ground truth's."""
    roundtrip = _load_benchmark("calibration_roundtrip").roundtrip
    rec = roundtrip(
        machine,
        steps=200,
        sweep_benchmarks=("Swim", "CG"),
        max_placements=24,
    )
    assert rec["max_link_error"] < 0.05, rec
    assert rec["sweep_median_delta_pp"] < 0.25, rec
    # local banks come along for free (they are fitted jointly)
    assert rec["max_local_read_error"] < 0.05
    assert rec["max_local_write_error"] < 0.05


def test_roundtrip_recovers_attenuation_when_observable():
    """On the SNC-2 preset the hop-attenuated remote caps are tighter than
    every link on their routes, so the attenuation itself is identifiable
    — the fit must recover 0.9, not just a behavioral equivalent."""
    res = fit_from_simulated(E5_2699_V3_SNC2, steps=200)
    assert abs(res.machine.hop_attenuation - 0.9) < 0.02
    assert float(link_relative_errors(res.machine, E5_2699_V3_SNC2).max()) < 0.05


def test_blind_template_carries_no_answer():
    """The template handed to the fit must not leak the quantities under
    recovery (the 'fit blind' contract)."""
    t = blind_template(E7_8860_V3)
    assert t.local_read_bw != E7_8860_V3.local_read_bw
    assert t.local_write_bw != E7_8860_V3.local_write_bw
    assert t.hop_attenuation != E7_8860_V3.hop_attenuation
    assert len(set(t.topology.link_bw)) == 1  # all links one placeholder
    # structure is preserved: link list, routes, remote bases, rates
    assert t.topology.link_ends == E7_8860_V3.topology.link_ends
    assert t.topology.routes == E7_8860_V3.topology.routes
    assert t.remote_read_bw == E7_8860_V3.remote_read_bw
    assert t.core_rate == E7_8860_V3.core_rate


# ---------------------------------------------------------------------------
# Packing layer and from_fit
# ---------------------------------------------------------------------------


def test_link_groups_untied_and_tied():
    topo = E7_8860_V3.topology  # 12 QPI links + 4 node-controller links
    untied = link_groups(topo)
    assert untied.n_params == topo.n_links
    assert untied.groups == tuple((l,) for l in range(topo.n_links))
    tied = link_groups(topo, tie_equal_bw=True)
    assert tied.n_params == 2
    assert sorted(len(g) for g in tied.groups) == [4, 12]
    # pack/unpack round-trips per-link values through the group structure
    bw = np.asarray(topo.link_bw)
    packed = tied.pack(bw)
    np.testing.assert_allclose(np.asarray(tied.unpack(packed)), bw)
    with pytest.raises(ValueError):
        type(untied)(groups=((0, 1), (1, 2))).validate()  # not a partition


def test_from_fit_holds_routes_static():
    """Fitted bandwidths must NOT reroute: the routing table is structural
    knowledge the inverse problem conditions on."""
    template = ring(4, 10.0)
    new_bw = [1.0, 100.0, 100.0, 100.0]  # widest-path would now avoid link 0
    fitted = from_fit(template, new_bw)
    assert fitted.routes == template.routes
    assert fitted.link_ends == template.link_ends
    assert fitted.link_bw == (1.0, 100.0, 100.0, 100.0)
    assert hash(fitted)  # still a valid jit static arg / cache key


def test_caps_from_matches_machine_caps_at_truth():
    """With parameters set to a machine's true values, the calibration's
    traced capacity vector equals the simulator's own (modulo the finite
    stand-in for the unconstrained diagonal)."""
    m = E5_2699_V3_SNC2
    groups = link_groups(m.topology)
    params = CalibrationParams(
        log_link_bw=np.log(np.asarray(groups.pack(m.topology.link_bw), np.float32)),
        log_local_read=np.log(np.asarray(m.node_local_bw("read"))),
        log_local_write=np.log(np.asarray(m.node_local_bw("write"))),
        att_raw=np.float32(np.log(m.hop_attenuation / (1 - m.hop_attenuation))),
    )
    got = np.asarray(_caps_from(m, groups, params))
    want = np.asarray(machine_caps(m))
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-5)
    assert (got[~finite] > 0).all() and np.isfinite(got[~finite]).all()


# ---------------------------------------------------------------------------
# Seeding
# ---------------------------------------------------------------------------


def test_seed_parameters_are_tight_on_probe_sweep():
    """The closed-form counter bounds land on the true capacities when the
    probe suite saturates them (noise-free): the gradient stage refines,
    it does not rescue."""
    m = E5_2630_V3
    samples = collect_sweep(m)
    seed = seed_parameters(blind_template(m), samples)
    np.testing.assert_allclose(
        np.exp(np.asarray(seed.log_local_read)),
        np.asarray(m.node_local_bw("read")),
        rtol=0.02,
    )
    np.testing.assert_allclose(
        np.exp(np.asarray(seed.log_link_bw)),
        np.asarray(m.topology.link_bw),
        rtol=0.02,
    )


def test_probe_suite_shares_thread_count_and_respects_caps():
    for m in (E5_2630_V3, E5_2699_V3_SNC2, E7_8860_V3):
        probes = probe_suite(m)
        nts = {wl.n_threads for wl, _ in probes}
        assert len(nts) == 1
        for _, placement in probes:
            p = np.asarray(placement)
            assert p.sum() == next(iter(nts))
            assert p.min() >= 0 and p.max() <= m.cores_per_node
    with pytest.raises(ValueError):
        probe_suite(E5_2630_V3, n_threads=E5_2630_V3.cores_per_node + 1)


# ---------------------------------------------------------------------------
# The external counter-trace path
# ---------------------------------------------------------------------------


def test_samples_from_counters_matches_collect_sweep():
    """A bwsig/counters.py-shaped trace (one CounterSample per run) fits
    identically to the simulator-collected sweep — the real-machine
    entry point."""
    m = E5_2630_V3
    probes = probe_suite(m)
    via_sim = collect_sweep(m)
    counters = [
        simulate(m, wl, np.asarray(p)).sample for wl, p in probes
    ]
    via_trace = samples_from_counters(
        [wl for wl, _ in probes], np.stack([p for _, p in probes]), counters
    )
    for a, b in zip(via_sim[1:], via_trace[1:]):  # skip wl_arrays tuple
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    res = fit_machine(blind_template(m), via_trace, steps=80)
    assert float(link_relative_errors(res.machine, m).max()) < 0.05
    with pytest.raises(ValueError):
        samples_from_counters([p[0] for p in probes], np.stack([p for _, p in probes]), counters[:-1])
    # a counters/placements order mismatch must fail loudly, not corrupt
    # the apportionment: each CounterSample records its own run's placement
    shuffled = np.stack([p for _, p in probes])[::-1]
    with pytest.raises(ValueError, match="recorded placement"):
        samples_from_counters([p[0] for p in probes], shuffled, counters)


def test_fit_is_noise_robust():
    """Measurement noise on the sweep degrades recovery gracefully — the
    fit averages over the whole sample set instead of trusting any single
    saturated run."""
    m = E5_2630_V3
    res = fit_from_simulated(
        m, steps=150, noise_std=0.02, key=jax.random.PRNGKey(7)
    )
    assert float(link_relative_errors(res.machine, m).max()) < 0.15
    errs = local_bw_relative_errors(res.machine, m)
    assert float(errs["read"].max()) < 0.15
    assert float(errs["write"].max()) < 0.15


# ---------------------------------------------------------------------------
# Per-node bandwidth vectors: the mixed-DIMM regression
# ---------------------------------------------------------------------------


def test_mixed_dimm_banks_are_recovered_per_node():
    """The calibration must recover UNEQUAL bank capacities — node 1's
    half-populated DIMMs — which the scalar local_*_bw model could not
    even represent."""
    m = E5_2630_V3_MIXED_DIMM
    res = fit_from_simulated(m, steps=150)
    fitted_read = np.asarray(res.machine.node_local_bw("read"))
    assert fitted_read[0] > 1.8 * fitted_read[1]  # asymmetry survives
    errs = local_bw_relative_errors(res.machine, m)
    assert float(errs["read"].max()) < 0.05
    assert float(errs["write"].max()) < 0.05
    assert float(link_relative_errors(res.machine, m).max()) < 0.05


def test_fit_rejects_mismatched_samples():
    samples = collect_sweep(E5_2630_V3)
    with pytest.raises(ValueError):
        fit_machine(blind_template(E5_2699_V3_SNC2), samples, steps=1)


# ---------------------------------------------------------------------------
# Ingestion guards, partial sweeps, and the swap-guard metric
# ---------------------------------------------------------------------------


def _poisoned_sweep(machine):
    """A clean sweep with three distinct corruption modes planted: row 0
    goes non-finite, row 1 gets a negative counter (wrap-around), row 2 a
    dead sampling interval (elapsed 0)."""
    samples = collect_sweep(machine)
    lr = np.array(samples.local_read, np.float64)
    lr[0] = np.nan
    rr = np.array(samples.remote_read, np.float64)
    rr[1, 0] = -1.0
    el = np.array(samples.elapsed, np.float64)
    el[2] = 0.0
    return samples, samples._replace(local_read=lr, remote_read=rr, elapsed=el)


def test_clean_samples_rejects_corruption_with_receipts():
    """Each of the three production corruption modes is rejected and
    *counted* under its own reason; surviving rows pass through
    bit-identically."""
    clean, bad = _poisoned_sweep(E5_2630_V3)
    P = clean.n_samples
    kept, diag = clean_samples(bad)
    assert (diag.n_total, diag.n_kept, diag.n_rejected) == (P, P - 3, 3)
    assert diag.reject_rate == pytest.approx(3 / P)
    text = " ".join(diag.reasons)
    assert "non-finite" in text
    assert "negative counters" in text
    assert "non-positive elapsed" in text
    keep = np.arange(3, P)
    np.testing.assert_array_equal(
        np.asarray(kept.placements), np.asarray(clean.placements)[keep]
    )
    np.testing.assert_allclose(
        np.asarray(kept.local_read), np.asarray(clean.local_read)[keep]
    )


def test_clean_samples_passthrough_and_empty_batch():
    """A healthy batch passes through untouched (zero-copy); an all-bad
    batch raises by default and returns empty under on_empty='ignore' —
    the accumulate-across-batches mode the recalibration stream uses."""
    samples = collect_sweep(E5_2630_V3)
    kept, diag = clean_samples(samples)
    assert kept is samples
    assert diag.n_rejected == 0 and diag.reject_rate == 0.0 and diag.reasons == ()
    all_bad = samples._replace(
        elapsed=np.zeros((samples.n_samples,), np.float64)
    )
    with pytest.raises(ValueError, match="rejected"):
        clean_samples(all_bad)
    empty, ediag = clean_samples(all_bad, on_empty="ignore")
    assert empty.n_samples == 0
    assert ediag.n_kept == 0 and ediag.n_rejected == samples.n_samples


def test_concat_and_take_samples_round_trip():
    """Splitting a sweep into partial batches and concatenating them back
    reproduces the original — the accumulation step of the production
    recalibration stream — and mismatched batches fail loudly."""
    samples = collect_sweep(E5_2630_V3)
    P = samples.n_samples
    head = take_samples(samples, np.arange(P // 2))
    tail = take_samples(samples, np.arange(P // 2, P))
    assert head.n_samples + tail.n_samples == P
    merged = concat_samples([head, tail])
    assert merged.n_samples == P
    np.testing.assert_array_equal(
        np.asarray(merged.placements), np.asarray(samples.placements)
    )
    for a, b in zip(merged.wl_arrays, samples.wl_arrays):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(
        np.asarray(merged.remote_write), np.asarray(samples.remote_write)
    )
    assert concat_samples([samples]) is samples  # single batch: passthrough
    with pytest.raises(ValueError, match="at least one"):
        concat_samples([])
    with pytest.raises(ValueError, match="node count"):
        concat_samples([samples, collect_sweep(E5_2699_V3_SNC2)])
    with pytest.raises(ValueError, match="workload shape"):
        concat_samples(
            [samples, collect_sweep(E5_2630_V3, probe_suite(E5_2630_V3, 3))]
        )


def test_partial_sweep_still_fits():
    """A fit from whatever 60% of the probe suite a production trace
    happened to cover still recovers the links — partial sweeps are a
    first-class input, not a degraded mode."""
    m = E5_2630_V3
    samples = collect_sweep(m)
    idx = np.random.default_rng(3).choice(
        samples.n_samples, size=int(samples.n_samples * 0.6), replace=False
    )
    res = fit_machine(blind_template(m), take_samples(samples, idx), steps=120)
    assert float(link_relative_errors(res.machine, m).max()) < 0.1
    errs = local_bw_relative_errors(res.machine, m)
    assert float(errs["read"].max()) < 0.1


def test_fit_clean_true_survives_poisoned_rows():
    """fit_machine's default clean=True drops corrupted rows (receipts in
    result.diagnostics) and fits from the survivors as if the poison never
    arrived; clean=False on a healthy sweep records no diagnostics."""
    m = E5_2630_V3
    clean, bad = _poisoned_sweep(m)
    res = fit_machine(blind_template(m), bad, steps=120)
    assert res.diagnostics is not None
    assert res.diagnostics.n_rejected == 3
    assert np.isfinite(res.final_loss)
    assert float(link_relative_errors(res.machine, m).max()) < 0.05
    res_raw = fit_machine(blind_template(m), clean, steps=1, clean=False)
    assert res_raw.diagnostics is None


def test_huber_fit_tolerates_outlier_rows():
    """Finite-but-garbage rows (8x counter blowup — past what clean_samples
    can detect) pull a Huber fit linearly instead of quadratically: the
    robust loss stays within tolerance where the squared loss degrades."""
    m = E5_2630_V3
    samples = collect_sweep(m)
    lr = np.array(samples.local_read, np.float64)
    rr = np.array(samples.remote_read, np.float64)
    lr[4] *= 8.0
    rr[5] *= 8.0
    bad = samples._replace(local_read=lr, remote_read=rr)
    robust = fit_machine(blind_template(m), bad, steps=150, huber_delta=0.05)
    squared = fit_machine(blind_template(m), bad, steps=150)
    err_robust = float(link_relative_errors(robust.machine, m).max())
    err_squared = float(link_relative_errors(squared.machine, m).max())
    assert err_robust < 0.1, (err_robust, err_squared)
    assert err_robust <= err_squared + 1e-6


def test_sweep_median_error_is_the_swap_guard_ordering():
    """The metric the live-recalibration guard gates on: the truth spec
    replays its own noise-free sweep near-exactly, a drifted spec scores
    strictly worse — so guard comparisons order specs correctly."""
    m = E5_2630_V3
    samples = collect_sweep(m)
    per_row = counter_errors_pct(m, samples)
    assert per_row.shape == (samples.n_samples,)
    true_err = sweep_median_error_pct(m, samples)
    assert true_err < 0.5
    drifted = m._replace(
        remote_read_bw=m.remote_read_bw * 0.6,
        remote_write_bw=m.remote_write_bw * 0.6,
    )
    assert sweep_median_error_pct(drifted, samples) > true_err + 1.0
    with pytest.raises(ValueError, match="zero samples"):
        counter_errors_pct(m, take_samples(samples, np.arange(0)))
    with pytest.raises(ValueError, match="nodes"):
        counter_errors_pct(E5_2699_V3_SNC2, samples)
