"""Time-axis tests: the page/bank placement axis, phased workloads, the
migration cost model, and the schedule search.

The two pinned guarantees this file carries:

* **Default path bit-for-bit** — ``bank_assignment=None`` and the
  identity assignment reproduce today's ``simulate`` outputs exactly,
  and a single-phase schedule reproduces the steady-state argmax.
* **Migration crossover** — on a two-phase workload whose per-phase
  optima differ, ``optimize_schedule`` strictly beats the best static
  placement whenever migration cost sits below the phase-gain
  crossover, and degrades exactly to the static answer (gain == 0)
  when it sits above.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.numa.machine import (
    E5_2630_V3,
    E5_2630_V3_MIXED_DIMM,
    E7_4830_V3,
    canonical_bank_assignment,
)
from repro.core.numa.evaluate import enumerate_placements, evaluate_batch
from repro.core.numa.search import exact_objectives
from repro.core.numa.simulator import simulate, simulate_reference
from repro.core.numa.temporal import (
    MigrationModel,
    PhasedWorkload,
    evaluate_schedule,
    follow_banks,
    optimize_schedule,
    phased_workload,
    thread_banks,
    thread_nodes,
    transition_cost,
)
from repro.core.numa.workload import mixed_workload


def _flip_phases(n=8, bpi=5.0):
    """Two phases whose optima sit on opposite nodes: static-heavy
    traffic with the static buffer flipping from node 0 to node 1."""
    wa = mixed_workload(
        "a", n, read_mix=(0.7, 0.1, 0.0), read_bpi=bpi, static_socket=0
    )
    wb = mixed_workload(
        "b", n, read_mix=(0.7, 0.1, 0.0), read_bpi=bpi, static_socket=1
    )
    return wa, wb


# ---------------------------------------------------------------------------
# bank_assignment axis
# ---------------------------------------------------------------------------


def test_canonical_bank_assignment():
    m = E5_2630_V3
    assert canonical_bank_assignment(m, None) is None
    assert canonical_bank_assignment(m, (0, 1)) is None  # identity
    assert canonical_bank_assignment(m, [1, 0]) == (1, 0)
    with pytest.raises(ValueError):
        canonical_bank_assignment(m, (0,))
    with pytest.raises(ValueError):
        canonical_bank_assignment(m, (0, 2))


def test_identity_bank_assignment_bit_for_bit():
    m = E5_2630_V3
    wl = mixed_workload("t", 8, read_mix=(0.1, 0.6, 0.1), read_bpi=4.0)
    p = jnp.asarray([5, 3])
    r0 = simulate(m, wl, p)
    r1 = simulate(m, wl, p, bank_assignment=(0, 1))
    assert np.array_equal(np.asarray(r0.rates), np.asarray(r1.rates))
    assert np.array_equal(np.asarray(r0.read_flows), np.asarray(r1.read_flows))
    assert np.array_equal(
        np.asarray(r0.write_flows), np.asarray(r1.write_flows)
    )


@pytest.mark.parametrize("ba", [(1, 0), (0, 0), (1, 1)])
def test_bank_assignment_grouped_matches_reference(ba):
    m = E5_2630_V3
    wl = mixed_workload("t", 8, read_mix=(0.1, 0.6, 0.1), read_bpi=4.0)
    p = jnp.asarray([5, 3])
    g = simulate(m, wl, p, bank_assignment=ba)
    ref = simulate_reference(m, wl, p, bank_assignment=ba)
    scale = float(np.max(np.abs(np.asarray(ref.read_flows)))) or 1.0
    assert np.max(
        np.abs(np.asarray(g.read_flows) - np.asarray(ref.read_flows))
    ) / scale < 1e-6
    assert np.max(np.abs(np.asarray(g.rates) - np.asarray(ref.rates))) < 1e-6


def test_remote_banks_cost_throughput():
    """A local-heavy workload with swapped banks pays remote-path prices."""
    m = E5_2630_V3
    wl = mixed_workload("t", 8, read_mix=(0.1, 0.6, 0.1), read_bpi=4.0)
    p = jnp.asarray([5, 3])
    t_local = float(simulate(m, wl, p).throughput)
    t_swapped = float(simulate(m, wl, p, bank_assignment=(1, 0)).throughput)
    assert t_swapped < t_local


def test_exact_objectives_bank_assignment():
    m = E7_4830_V3
    wl = mixed_workload("t4", 24, read_mix=(0.1, 0.5, 0.1), read_bpi=3.0)
    pl = np.asarray([[6, 6, 6, 6], [12, 12, 0, 0]], np.int32)
    base = exact_objectives(m, wl, pl)
    ident = exact_objectives(m, wl, pl, bank_assignment=(0, 1, 2, 3))
    moved = exact_objectives(m, wl, pl, bank_assignment=(1, 0, 3, 2))
    assert np.array_equal(base, ident)
    assert (moved <= base + 1e-6).all() and (moved < base - 1e-6).any()


def test_evaluate_batch_bank_assignment_default_unchanged():
    m = E5_2630_V3
    wl = mixed_workload("t", 8, read_mix=(0.2, 0.3, 0.2), read_bpi=2.0)
    pl = np.asarray(enumerate_placements(m, 8))
    a = evaluate_batch(m, wl, pl)
    b = evaluate_batch(m, wl, pl, bank_assignment=(0, 1))
    assert np.array_equal(np.asarray(a.total_bw), np.asarray(b.total_bw))
    c = evaluate_batch(m, wl, pl, bank_assignment=(1, 0))
    assert not np.array_equal(np.asarray(a.total_bw), np.asarray(c.total_bw))


# ---------------------------------------------------------------------------
# PhasedWorkload + migration accounting
# ---------------------------------------------------------------------------


def test_phased_workload_validation():
    wa, wb = _flip_phases()
    pw = phased_workload("ok", [(wa, 1.0), (wb, 2.0)])
    assert pw.n_threads == 8 and len(pw.phases) == 2
    with pytest.raises(ValueError):
        phased_workload("neg", [(wa, 0.0)])
    with pytest.raises(ValueError):
        phased_workload(
            "mismatch", [(wa, 1.0), (mixed_workload("c", 4), 1.0)]
        )
    with pytest.raises(ValueError):
        PhasedWorkload("empty", ()).validate()


def test_thread_and_bank_maps():
    assert thread_nodes((5, 3), 8).tolist() == [0] * 5 + [1] * 3
    assert thread_banks((5, 3), None, 8).tolist() == [0] * 5 + [1] * 3
    assert thread_banks((5, 3), (1, 0), 8).tolist() == [1] * 5 + [0] * 3
    with pytest.raises(ValueError):
        thread_nodes((5, 3), 9)


def test_transition_cost_counts_and_time():
    m = E5_2630_V3
    model = MigrationModel(
        thread_move_bytes=1e6, page_move_bytes=1e8, bandwidth=1e9
    )
    # (5,3) -> (3,5): threads 3,4 move node AND (identity banks) re-bank
    t, mt, mp = transition_cost(m, model, 8, (5, 3), None, (3, 5), None)
    assert mt == 2 and mp == 2
    assert t == pytest.approx((2 * 1e6 + 2 * 1e8) / 1e9)
    # same move, pages stay behind via follow_banks: no page traffic
    fb = follow_banks(m, 8, (5, 3), None, (3, 5))
    t2, mt2, mp2 = transition_cost(m, model, 8, (5, 3), None, (3, 5), fb)
    assert mt2 == 2
    assert mp2 <= mp
    # no move, no cost
    t3, mt3, mp3 = transition_cost(m, model, 8, (5, 3), None, (5, 3), None)
    assert (t3, mt3, mp3) == (0.0, 0, 0)


def test_follow_banks_plurality():
    m = E7_4830_V3
    # (12,6,6,0) -> (6,6,6,6): node 1's arrivals (threads 6-11) held bank
    # 0, node 2's held bank 1, node 3's held bank 2 -- pages stay put.
    fb = follow_banks(m, 24, (12, 6, 6, 0), None, (6, 6, 6, 6))
    assert fb == (0, 0, 1, 2)
    # nothing moved -> identity -> canonicalized to None
    assert follow_banks(m, 24, (6, 6, 6, 6), None, (6, 6, 6, 6)) is None


def test_evaluate_schedule_accounting():
    m = E5_2630_V3
    wa, wb = _flip_phases()
    pw = phased_workload("flip", [(wa, 5.0), (wb, 5.0)])
    model = MigrationModel(
        thread_move_bytes=1e6, page_move_bytes=1e6, bandwidth=52e9
    )
    sched = evaluate_schedule(
        m, pw, [(5, 3), (3, 5)], model=model
    )
    r0 = float(exact_objectives(m, wa, np.asarray([[5, 3]], np.int32))[0])
    r1 = float(exact_objectives(m, wb, np.asarray([[3, 5]], np.int32))[0])
    stall = sched.transition_times[0]
    assert sched.phase_rates == (r0, r1)
    assert sched.total_work == pytest.approx(
        r0 * 5.0 + r1 * (5.0 - stall), rel=1e-12
    )
    # a stall longer than the phase forfeits the phase, never negative
    slow = MigrationModel(
        thread_move_bytes=1e15, page_move_bytes=0.0, bandwidth=1e9
    )
    sched2 = evaluate_schedule(m, pw, [(5, 3), (3, 5)], model=slow)
    assert sched2.total_work == pytest.approx(r0 * 5.0)


# ---------------------------------------------------------------------------
# optimize_schedule: the pinned crossover + structure guarantees
# ---------------------------------------------------------------------------


def test_single_phase_matches_steady_state_argmax():
    """A 1-phase schedule is exactly today's one-shot answer: the best
    placement by the grouped solver, total work = duration * its rate."""
    m = E5_2630_V3
    wa, _ = _flip_phases()
    scores = exact_objectives(m, wa, np.asarray(enumerate_placements(m, 8)))
    res = optimize_schedule(m, phased_workload("one", [(wa, 3.0)]))
    assert len(res.schedule.placements) == 1
    assert res.schedule.bank_assignments == (None,)
    chosen = exact_objectives(
        m, wa, np.asarray([res.schedule.placements[0]], np.int32)
    )[0]
    # batch shapes differ between the full sweep and the single row, so
    # compare at solver precision rather than bitwise
    assert float(chosen) == pytest.approx(float(scores.max()), rel=1e-6)
    assert res.schedule.total_work == pytest.approx(
        3.0 * float(scores.max()), rel=1e-6
    )
    assert res.gain_pct == 0.0


def test_migration_crossover_pinned():
    """Below the phase-gain crossover the scheduler strictly beats the
    best static placement; above it, it degrades exactly to static."""
    m = E5_2630_V3
    wa, wb = _flip_phases()
    pw = phased_workload("flip", [(wa, 5.0), (wb, 5.0)])

    cheap = MigrationModel(thread_move_bytes=1e6, page_move_bytes=1e6)
    res = optimize_schedule(m, pw, model=cheap)
    assert res.gain_pct > 0.0
    assert res.schedule.placements[0] != res.schedule.placements[1]
    assert res.schedule.moved_threads[0] > 0
    assert res.schedule.total_work > res.static.total_work

    prohibitive = MigrationModel(
        thread_move_bytes=1e13, page_move_bytes=1e13
    )
    res2 = optimize_schedule(m, pw, model=prohibitive)
    assert res2.gain_pct == 0.0
    assert res2.schedule.placements[0] == res2.schedule.placements[1]
    assert res2.schedule.total_work == res2.static.total_work


def test_schedule_never_below_static():
    """The static trajectory is in the DP's feasible set, so gain_pct is
    never negative — across a migration-cost ladder."""
    m = E5_2630_V3
    wa, wb = _flip_phases()
    pw = phased_workload("flip", [(wa, 2.0), (wb, 8.0)])
    for scale in (1e4, 1e7, 1e9, 1e11, 1e13):
        res = optimize_schedule(
            m, pw,
            model=MigrationModel(
                thread_move_bytes=scale, page_move_bytes=10 * scale
            ),
        )
        assert res.gain_pct >= 0.0, scale


def test_page_placement_option_never_hurts():
    """With page moves priced out, leaving pages behind (the bank axis)
    can only help: the page-placement DP dominates the thread-only DP."""
    m = E5_2630_V3_MIXED_DIMM
    wl_local = mixed_workload(
        "loc", 8, read_mix=(0.05, 0.8, 0.05), read_bpi=4.0
    )
    wl_static = mixed_workload(
        "stat", 8, read_mix=(0.8, 0.1, 0.0), read_bpi=4.0, static_socket=1
    )
    pw = phased_workload("mix", [(wl_local, 4.0), (wl_static, 4.0)])
    model = MigrationModel(thread_move_bytes=1e5, page_move_bytes=1e12)
    # unpruned beam: with the page option the DP's feasible set is a
    # strict superset, so its optimum dominates
    with_pages = optimize_schedule(m, pw, model=model, beam_width=256)
    without = optimize_schedule(
        m, pw, model=model, allow_page_placement=False, beam_width=256
    )
    assert with_pages.schedule.total_work >= without.schedule.total_work - 1e-6


def test_evaluate_schedule_agrees_with_search():
    m = E5_2630_V3
    wa, wb = _flip_phases()
    pw = phased_workload("flip", [(wa, 5.0), (wb, 5.0)])
    model = MigrationModel(thread_move_bytes=1e6, page_move_bytes=1e6)
    res = optimize_schedule(m, pw, model=model)
    ev = evaluate_schedule(
        m, pw, res.schedule.placements,
        bank_assignments=res.schedule.bank_assignments, model=model,
    )
    assert ev.total_work == pytest.approx(
        res.schedule.total_work, rel=1e-9
    )


def test_three_phase_four_socket_schedule():
    """A bigger instance: 3 phases on the 4-socket preset; the scheduler
    returns a consistent trajectory and beats static with cheap moves."""
    m = E7_4830_V3
    phases = [
        (mixed_workload("p0", 24, read_mix=(0.7, 0.1, 0.0), read_bpi=4.0,
                        static_socket=0), 4.0),
        (mixed_workload("p1", 24, read_mix=(0.7, 0.1, 0.0), read_bpi=4.0,
                        static_socket=2), 4.0),
        (mixed_workload("p2", 24, read_mix=(0.1, 0.6, 0.1), read_bpi=4.0),
         2.0),
    ]
    pw = phased_workload("tri", phases)
    res = optimize_schedule(
        m, pw, model=MigrationModel(thread_move_bytes=1e6,
                                    page_move_bytes=1e6)
    )
    assert len(res.schedule.placements) == 3
    assert all(sum(p) == 24 for p in res.schedule.placements)
    assert res.gain_pct > 0.0
    assert len(res.schedule.transition_times) == 2
