"""Beyond-paper: the fit generalizes past s=2 (the paper's equations are
written for 2 sockets; ours reduce to them there and extend to s>2 with a
documented remote-attribution assumption)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bwsig import fit_signature, predict_counters
from repro.core.numa.machine import make_machine
from repro.core.numa.simulator import simulate, simulate_counters
from repro.core.numa.workload import mixed_workload

MACHINE4 = make_machine(
    "quad", sockets=4, cores_per_socket=8, remote_read_ratio=0.4,
    remote_write_ratio=0.5, qpi_bw=40e9,
)


def _profile4(wl):
    sym = simulate_counters(MACHINE4, wl, jnp.asarray([4, 4, 4, 4], jnp.int32))
    asym = simulate_counters(MACHINE4, wl, jnp.asarray([7, 5, 3, 1], jnp.int32))
    return sym, asym


@pytest.mark.parametrize(
    "mix,socket",
    [
        ((1.0, 0.0, 0.0), 2),
        ((0.0, 1.0, 0.0), 0),
        ((0.0, 0.0, 1.0), 0),
        ((0.2, 0.35, 0.3), 1),
    ],
)
def test_four_socket_fit_recovers_mix(mix, socket):
    wl = mixed_workload("m4", 16, read_mix=mix, static_socket=socket, read_bpi=0.3)
    sym, asym = _profile4(wl)
    sig = fit_signature(sym, asym)
    got = np.array(
        [
            float(sig.read.static_fraction),
            float(sig.read.local_fraction),
            float(sig.read.per_thread_fraction),
        ]
    )
    np.testing.assert_allclose(got, np.array(mix), atol=0.05)
    if mix[0] > 0.1:
        assert int(sig.read.static_socket) == socket


def test_four_socket_prediction_unseen_placement():
    wl = mixed_workload("m4p", 16, read_mix=(0.2, 0.35, 0.3), static_socket=1)
    sym, asym = _profile4(wl)
    sig = fit_signature(sym, asym)
    target = jnp.asarray([8, 4, 2, 2], jnp.int32)
    res = simulate(MACHINE4, wl, target)
    demand = res.read_flows.sum(axis=1)
    pred_local, pred_remote = predict_counters(sig.read, demand, target)
    total = float((res.sample.local_read + res.sample.remote_read).sum())
    err = (
        np.abs(np.asarray(pred_local - res.sample.local_read)).sum()
        + np.abs(np.asarray(pred_remote - res.sample.remote_read)).sum()
    ) / total
    # s>2 remote attribution is approximate (hardware merges remote
    # sources); stay within a few % of bandwidth
    assert err < 0.05, err
