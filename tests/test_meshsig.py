"""Unit tests for the mesh-domain signature (fit/predict/advisor) using
synthetic profiles — no devices needed."""

import math

import pytest

from repro.core.meshsig.advisor import rank_meshes
from repro.core.meshsig.fit import (
    MeshProfile,
    class_factor,
    fit_mesh_signature,
    profile_from_analysis,
)
from repro.core.meshsig.hlo_counters import CollectiveOp, HloAnalysis, analyze_hlo


def synth_profile(axes: dict, *, grad_bytes=1e9, gather_bytes=5e8, a2a_base=2e9):
    """Ground-truth generator: grad all-reduce on data (e=0), param
    all-gather on data (e=0), MoE all-to-all on model scaling 1/batch
    (e=1)."""
    b = axes.get("data", 1) * axes.get("pod", 1)
    out = {}
    kd, km = axes["data"], axes["model"]
    out[("interleaved", "data")] = class_factor("interleaved", kd) * grad_bytes
    out[("static", "data")] = class_factor("static", kd) * gather_bytes
    out[("per_shard", "model")] = class_factor("per_shard", km) * a2a_base / b
    return MeshProfile(
        axis_sizes=dict(axes),
        class_axis_bytes=out,
        local_bytes=1e10 / b,
        flops=1e13 / b,
    )


def test_fit_recovers_synthetic_signature():
    sym = synth_profile({"data": 32, "model": 8})
    asym = synth_profile({"data": 64, "model": 4})
    sig = fit_mesh_signature(sym, asym)
    beta_ar, e_ar = sig.terms[("interleaved", "data")]
    beta_a2a, e_a2a = sig.terms[("per_shard", "model")]
    assert e_ar == 0.0 and abs(beta_ar - 1e9) / 1e9 < 1e-6
    assert e_a2a == 1.0


def test_prediction_on_unseen_mesh():
    sym = synth_profile({"data": 32, "model": 8})
    asym = synth_profile({"data": 64, "model": 4})
    sig = fit_mesh_signature(sym, asym)
    target = {"data": 8, "model": 32}
    truth = synth_profile(target)
    pred = sig.predict_axis_bytes(target)
    for axis in target:
        want = sum(
            v for (c, a), v in truth.class_axis_bytes.items() if a == axis
        )
        assert abs(pred[axis] - want) <= 0.02 * max(want, 1.0), (axis, pred[axis], want)


def test_advisor_ranks_by_dominant_term():
    sym = synth_profile({"data": 32, "model": 8})
    asym = synth_profile({"data": 64, "model": 4})
    sig = fit_mesh_signature(sym, asym)
    candidates = [{"data": 8, "model": 32}, {"data": 64, "model": 4}]
    ranked = rank_meshes(sig, candidates)
    # grad all-reduce grows with the data axis -> 8x32 should beat 64x4
    # on the collective term
    per = {tuple(r.axis_sizes.values()): r.collective_s for r in ranked}
    assert per[(8, 32)] < per[(64, 4)]


def test_profile_attribution_distinct_sizes_exact():
    a = HloAnalysis(
        flops=1.0,
        hbm_bytes=10.0,
        collectives=[
            CollectiveOp(kind="all-reduce", bytes=8.0, group=32, count=1, link_bytes=8.0),
            CollectiveOp(kind="all-to-all", bytes=4.0, group=8, count=1, link_bytes=4.0),
        ],
    )
    prof = profile_from_analysis(a, {"data": 32, "model": 8})
    assert prof.class_axis_bytes[("interleaved", "data")] == 8.0
    assert prof.class_axis_bytes[("per_shard", "model")] == 4.0


def test_profile_attribution_tie_splits():
    a = HloAnalysis(
        collectives=[
            CollectiveOp(kind="all-gather", bytes=6.0, group=16, count=1, link_bytes=6.0)
        ],
    )
    prof = profile_from_analysis(a, {"data": 16, "model": 16})
    assert prof.class_axis_bytes[("static", "data")] == pytest.approx(3.0)
    assert prof.class_axis_bytes[("static", "model")] == pytest.approx(3.0)


def test_hlo_analyzer_trip_count_and_flops():
    """End-to-end analyzer check on a real jit'd scan."""
    import jax
    import jax.numpy as jnp

    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    a = analyze_hlo(c.as_text())
    assert a.flops == pytest.approx(2 * 256**3 * 7, rel=1e-6)
    assert a.unknown_trip_loops == 0
    assert a.hbm_bytes > 0 and a.hbm_bytes <= a.hbm_bytes_raw
