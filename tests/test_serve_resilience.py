"""Resilience layer of the advisor service (``repro.serve``): fault
injection, the deadline degradation ladder, spec-epoch hot-swap, live
recalibration, and close/drain semantics.

Contracts under test (the deterministic twins of what
``benchmarks/serve_resilience.py`` gates open-loop):

* **FaultInjector** — armed faults fire exactly their budget, the log
  records the scenario, the skewed clock and counter corruption behave
  deterministically, ``NO_FAULTS`` stays inert.
* **Degradation ladder** — a deadline-armed query whose exact tier fails
  answers ``ranked``; with the ranked rung also failing it answers
  ``stale`` off the last known good, else ``fallback`` (even spread).
  Degraded answers are tagged, never cached, and the next healthy query
  is ``exact`` again.
* **Search retries** — injected search-attempt failures within the retry
  budget are absorbed (the answer stays exact); beyond it they surface.
* **Hot-swap** — epochs only move forward; invalidation is per-machine;
  in-flight batches finish on the spec they were admitted under; a
  concurrent query stream straddling a swap never observes two answers
  for one ``(signature, epoch)``; rollback restores the previous spec.
* **Recalibration** — NaN rows rejected at ingest, insufficient samples
  refused, an unmeetable guard rejects the refit (previous spec keeps
  serving, rollback counted), and a clean refit of a drifted spec is
  accepted and swapped in.
* **Lifecycle** — close is idempotent and concurrent-safe; queries racing
  a close either answer or raise ``ServiceClosedError``, never hang.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.numa import E5_2630_V3, E7_4830_V3
from repro.core.numa import calibrate as C
from repro.serve import (
    Advice,
    AdvisorService,
    FIDELITIES,
    FaultError,
    FaultInjector,
    NO_FAULTS,
    QuerySignature,
    Recalibrator,
    ServiceClosedError,
)


def _sigs(n, seed=0):
    from repro.launch.advisor_serve import signature_pool

    return signature_pool(n, seed=seed)


# ---------------------------------------------------------------------------
# FaultInjector
# ---------------------------------------------------------------------------


def test_fault_injector_error_budget_and_log():
    fi = FaultInjector()
    fi.fire("batch")  # nothing armed: no-op
    fi.inject_error("batch", times=2)
    with pytest.raises(FaultError):
        fi.fire("batch")
    with pytest.raises(FaultError):
        fi.fire("batch")
    fi.fire("batch")  # budget exhausted: healed
    assert fi.fired("batch") == 2
    assert fi.log == [("batch", "error"), ("batch", "error")]


def test_fault_injector_slow_and_custom_exception():
    fi = FaultInjector()
    fi.inject_slow("batch", 0.05, times=1)
    t0 = time.perf_counter()
    fi.fire("batch")
    assert time.perf_counter() - t0 >= 0.05
    fi.inject_error("search", exc_factory=lambda: KeyError("boom"))
    with pytest.raises(KeyError):
        fi.fire("search")


def test_fault_injector_clear_and_clock_skew():
    fi = FaultInjector()
    fi.inject_error("batch", times=None)  # unlimited
    with pytest.raises(FaultError):
        fi.fire("batch")
    fi.clear("batch")
    fi.fire("batch")  # disarmed
    fi.inject_clock_skew(3.5)
    assert fi.now() - time.monotonic() == pytest.approx(3.5, abs=0.05)
    fi.clear()
    assert fi.now() - time.monotonic() == pytest.approx(0.0, abs=0.05)


def test_fault_injector_counter_corruption_deterministic():
    fi = FaultInjector()
    arrays = tuple(np.arange(8, dtype=np.float64) + i for i in range(3))
    same = fi.corrupt_counters(arrays)
    assert same is arrays  # disarmed: identity, no copy
    fi.inject_counter_corruption(fraction=0.25, times=1, seed=3)
    poisoned = fi.corrupt_counters(arrays)
    bad_rows = np.isnan(np.stack(poisoned)).any(axis=0)
    assert bad_rows.sum() == 2  # round(0.25 * 8)
    # every leaf is poisoned on the SAME rows (a corrupt sample is
    # corrupt across all its counters)
    for arr in poisoned:
        assert (np.isnan(arr) == bad_rows).all()
    # budget consumed: the next batch passes clean
    clean = fi.corrupt_counters(arrays)
    assert not np.isnan(np.stack(clean)).any()


def test_no_faults_singleton_is_inert():
    NO_FAULTS.fire("batch")
    NO_FAULTS.fire("anything")
    arrays = (np.ones(4),)
    assert NO_FAULTS.corrupt_counters(arrays) is arrays
    assert NO_FAULTS.log == []


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------


@pytest.fixture()
def faulty_service():
    fi = FaultInjector()
    svc = AdvisorService(max_wait_s=0.002, faults=fi)
    yield svc, fi
    fi.clear()
    svc.close()


def test_deadline_miss_degrades_to_ranked(faulty_service):
    svc, fi = faulty_service
    fp = svc.register(E5_2630_V3)
    svc.warmup(fp, 8)
    fi.inject_error("batch", times=1)
    adv = svc.query(fp, _sigs(1, seed=1)[0], 8, deadline_s=5.0)
    assert adv.tier == "degraded" and adv.fidelity == "ranked"
    p = np.asarray(adv.placement)
    assert p.sum() == 8 and (p >= 0).all()
    assert adv.objective > 0  # the roofline rung still scores its pick
    assert np.isnan(adv.predicted_bandwidth)  # ...but never simulates
    snap = svc.metrics.snapshot()
    assert snap["tier_counts"]["degraded"] == 1
    assert snap["fidelity_counts"]["ranked"] == 1
    assert snap["degraded_rate"] > 0


def test_ladder_falls_to_stale_then_fallback(faulty_service):
    svc, fi = faulty_service
    fp = svc.register(E5_2630_V3)
    exact = svc.warmup(fp, 8)  # populates the last-known-good cache
    # exact tier AND the ranked rung both fail -> last known good
    fi.inject_error("batch", times=1)
    fi.inject_error("rank", times=1)
    adv = svc.query(fp, _sigs(1, seed=2)[0], 8, deadline_s=5.0)
    assert adv.fidelity == "stale" and adv.tier == "degraded"
    assert adv.placement == exact.placement  # it IS the old exact answer
    assert adv.objective == exact.objective


def test_ladder_fallback_is_even_spread():
    fi = FaultInjector()
    # fresh service, no warmup: the last-known-good cache is empty
    svc = AdvisorService(max_wait_s=0.002, faults=fi)
    fp = svc.register(E5_2630_V3)
    fi.inject_error("batch", times=1)
    fi.inject_error("rank", times=1)
    adv = svc.query(fp, _sigs(1, seed=3)[0], 9, deadline_s=5.0)
    svc.close()
    assert adv.fidelity == "fallback" and adv.tier == "degraded"
    assert adv.placement == (5, 4)  # divmod even spread, remainder first
    assert np.isnan(adv.objective) and np.isnan(adv.predicted_bandwidth)


def test_degraded_answers_are_never_cached(faulty_service):
    svc, fi = faulty_service
    fp = svc.register(E5_2630_V3)
    svc.warmup(fp, 8)
    sig = _sigs(1, seed=4)[0]
    fi.inject_error("batch", times=1)
    degraded = svc.query(fp, sig, 8, deadline_s=5.0)
    assert degraded.fidelity == "ranked"
    # the world healed: the SAME signature now answers exact, proving the
    # degraded answer never entered the cache
    healed = svc.query(fp, sig, 8, deadline_s=5.0)
    assert healed.fidelity == "exact" and healed.tier == "batch"
    assert svc.query(fp, sig, 8) is healed  # and THIS one is cached


def test_all_answers_fidelity_tagged_in_mixed_chaos(faulty_service):
    svc, fi = faulty_service
    fp = svc.register(E5_2630_V3)
    svc.warmup(fp, 8)
    sigs = _sigs(40, seed=5)
    fi.inject_slow("batch", 0.05, times=2)
    fi.inject_error("batch", times=3)
    fi.inject_error("batcher", times=1)
    answers = {}
    lock = threading.Lock()
    idx = iter(range(len(sigs)))

    def worker():
        while True:
            with lock:
                i = next(idx, None)
            if i is None:
                return
            answers[i] = svc.query(fp, sigs[i], 8, deadline_s=2.0)

    threads = [threading.Thread(target=worker) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(answers) == len(sigs)
    assert all(a.fidelity in FIDELITIES for a in answers.values())
    snap = svc.metrics.snapshot()
    assert snap["worker_restarts"] >= 1  # the batcher kill self-healed
    # recovery: once the faults are spent, fresh queries are exact again
    fi.clear()
    post = svc.query(fp, _sigs(1, seed=6)[0], 8, deadline_s=2.0)
    assert post.fidelity == "exact"


# ---------------------------------------------------------------------------
# Search-tier retries
# ---------------------------------------------------------------------------


def test_search_faults_absorbed_within_retry_budget():
    fi = FaultInjector()
    # sweep_limit=1 forces even the 2-socket machine onto the search tier
    svc = AdvisorService(
        sweep_limit=1, search_retries=2, search_backoff_s=0.001, faults=fi
    )
    fi.inject_error("search", times=2)
    adv = svc.query(E5_2630_V3, _sigs(1, seed=7)[0], 8, timeout=300)
    svc.close()
    assert adv.tier == "search" and adv.fidelity == "exact"
    assert np.asarray(adv.placement).sum() == 8
    # both armed failures were consumed (the healthy attempt fires no
    # armed fault, so it does not log)
    assert fi.fired("search") == 2


def test_search_faults_beyond_budget_surface_without_deadline():
    fi = FaultInjector()
    svc = AdvisorService(
        sweep_limit=1, search_retries=1, search_backoff_s=0.001, faults=fi
    )
    fi.inject_error("search", times=3)  # budget is 1+1 attempts
    with pytest.raises(FaultError):
        svc.query(E5_2630_V3, _sigs(1, seed=8)[0], 8, timeout=300)
    svc.close()


def test_search_faults_beyond_budget_degrade_with_deadline():
    fi = FaultInjector()
    svc = AdvisorService(
        sweep_limit=1, search_retries=1, search_backoff_s=0.001, faults=fi
    )
    fi.inject_error("search", times=3)
    adv = svc.query(E5_2630_V3, _sigs(1, seed=9)[0], 8, deadline_s=30.0)
    svc.close()
    assert adv.tier == "degraded" and adv.fidelity == "ranked"


# ---------------------------------------------------------------------------
# Spec epochs & hot-swap
# ---------------------------------------------------------------------------


def _drift(spec, factor=0.8):
    return spec._replace(
        remote_read_bw=spec.remote_read_bw * factor,
        remote_write_bw=spec.remote_write_bw * factor,
    )


def test_swap_bumps_epoch_and_answers_move():
    svc = AdvisorService(max_wait_s=0.0)
    fp = svc.register(E5_2630_V3, machine_id="prod")
    assert fp == "prod" and svc.epoch_of(fp) == 0
    sig = _sigs(1, seed=10)[0]
    before = svc.query(fp, sig, 8)
    assert before.epoch == 0
    new_epoch = svc.swap_machine(fp, _drift(E5_2630_V3))
    assert new_epoch == 1 and svc.epoch_of(fp) == 1
    assert svc.machine_spec(fp) == _drift(E5_2630_V3)
    after = svc.query(fp, sig, 8)
    assert after.epoch == 1
    assert after is not before  # epoch-0 answer was invalidated
    assert svc.metrics.snapshot()["swaps"] == 1
    svc.close()


def test_swap_invalidation_is_per_machine():
    svc = AdvisorService(max_wait_s=0.0)
    a = svc.register(E5_2630_V3, machine_id="a")
    b = svc.register(E7_4830_V3, machine_id="b")
    sig = _sigs(1, seed=11)[0]
    adv_a = svc.query(a, sig, 8)
    adv_b = svc.query(b, sig, 24)
    svc.swap_machine(a, _drift(E5_2630_V3))
    # machine b's cached answer survived machine a's swap
    assert svc.query(b, sig, 24) is adv_b
    assert svc.query(a, sig, 8) is not adv_a
    svc.close()


def test_swap_rejects_structural_change_and_unknown_handle():
    svc = AdvisorService()
    fp = svc.register(E5_2630_V3)
    with pytest.raises(ValueError):
        svc.swap_machine(fp, E7_4830_V3)  # 2 nodes -> 4 nodes
    with pytest.raises(KeyError):
        svc.swap_machine("nope", E5_2630_V3)
    svc.close()


def test_register_is_idempotent_across_swaps():
    svc = AdvisorService(max_wait_s=0.0)
    fp = svc.register(E5_2630_V3, machine_id="prod")
    svc.swap_machine(fp, _drift(E5_2630_V3))
    # re-presenting the original spec must NOT clobber the swapped one
    assert svc.register(E5_2630_V3, machine_id="prod") == fp
    assert svc.machine_spec(fp) == _drift(E5_2630_V3)
    svc.close()


def test_rollback_restores_previous_spec_as_new_epoch():
    svc = AdvisorService(max_wait_s=0.0)
    fp = svc.register(E5_2630_V3, machine_id="prod")
    with pytest.raises(RuntimeError):
        svc.rollback_machine(fp)  # nothing to roll back to yet
    svc.swap_machine(fp, _drift(E5_2630_V3))
    epoch = svc.rollback_machine(fp)
    assert epoch == 2  # epochs only move forward
    assert svc.machine_spec(fp) == E5_2630_V3
    snap = svc.metrics.snapshot()
    assert snap["swaps"] == 1 and snap["rollbacks"] == 1
    svc.close()


def test_inflight_batch_pins_its_epoch():
    """Queries admitted before a swap answer on the OLD spec/epoch even
    when the swap lands while they wait in the pending queue."""
    svc = AdvisorService(max_batch=8, max_wait_s=0.3)
    fp = svc.register(E5_2630_V3, machine_id="prod")
    svc.warmup(fp, 8)
    # reference: what the old spec answers
    sigs = _sigs(3, seed=12)
    ref = [svc.query(fp, s, 8) for s in sigs]
    fresh = _sigs(3, seed=13)
    # submit misses; the batcher holds the queue open for max_wait_s
    futures = [svc.submit(fp, s, 8) for s in fresh]
    svc.swap_machine(fp, _drift(E5_2630_V3, 0.5))  # lands mid-wait
    answers = [f.result(timeout=60) for f in futures]
    assert all(a.epoch == 0 for a in answers)
    # bit-identical to the old spec's serial answers
    old = AdvisorService(max_wait_s=0.0)
    want = [old.query(E5_2630_V3, s, 8) for s in fresh]
    old.close()
    for got, ref_adv in zip(answers, want):
        assert got.placement == ref_adv.placement
        assert got.objective == ref_adv.objective
    # post-swap queries are epoch 1
    assert svc.query(fp, sigs[0], 8).epoch == 1
    assert ref[0].epoch == 0
    svc.close()


def test_sustained_stream_straddling_swap_has_no_torn_reads():
    svc = AdvisorService(max_wait_s=0.002)
    fp = svc.register(E5_2630_V3, machine_id="prod")
    svc.warmup(fp, 8)
    sigs = _sigs(6, seed=14)
    for s in sigs:
        svc.query(fp, s, 8)
    observed = []
    stop = threading.Event()

    def streamer():
        i = 0
        while not stop.is_set() and i < 20_000:
            sig = sigs[i % len(sigs)]
            adv = svc.query(fp, sig, 8)
            observed.append(
                (i % len(sigs), adv.epoch, adv.placement, adv.objective)
            )
            i += 1

    threads = [threading.Thread(target=streamer) for _ in range(2)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    svc.swap_machine(fp, _drift(E5_2630_V3))
    time.sleep(0.05)
    svc.rollback_machine(fp)
    time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join()
    svc.close()
    assert {e for _, e, _, _ in observed} >= {0, 1}  # stream saw a swap
    by_key = {}
    for sig_id, epoch, placement, obj in observed:
        key, val = (sig_id, epoch), (placement, obj)
        assert by_key.setdefault(key, val) == val, f"torn read at {key}"


# ---------------------------------------------------------------------------
# Recalibration
# ---------------------------------------------------------------------------


def _sweep(machine, n_threads=4, noise_std=0.0):
    return C.collect_sweep(
        machine, C.probe_suite(machine, n_threads=n_threads),
        noise_std=noise_std,
    )


def test_recalibrator_rejects_nan_rows_at_ingest():
    fi = FaultInjector()
    svc = AdvisorService(faults=fi)
    fp = svc.register(E5_2630_V3, machine_id="prod")
    recal = Recalibrator(svc)
    samples = _sweep(E5_2630_V3)
    fi.inject_counter_corruption(fraction=0.5, times=1, seed=1)
    diag = recal.ingest(fp, samples)
    assert diag.n_rejected == round(0.5 * samples.n_samples)
    assert diag.n_kept == samples.n_samples - diag.n_rejected
    assert recal.buffered(fp) == diag.n_kept
    svc.close()


def test_recalibrator_refuses_insufficient_samples():
    svc = AdvisorService()
    fp = svc.register(E5_2630_V3, machine_id="prod")
    recal = Recalibrator(svc, min_samples=10_000)
    recal.ingest(fp, _sweep(E5_2630_V3))
    event = recal.recalibrate(fp)
    svc.close()
    assert not event.accepted and "insufficient" in event.reason
    assert svc.epoch_of(fp) == 0  # no swap happened
    assert recal.events == [event]
    assert recal.buffered(fp) == 0  # the buffer was consumed regardless


def test_recalibrator_guard_rejects_and_rolls_back():
    svc = AdvisorService()
    fp = svc.register(E5_2630_V3, machine_id="prod")
    # a guard demanding a >=100pp improvement is unmeetable: the refit is
    # deterministically rejected whatever the fit quality
    recal = Recalibrator(
        svc, min_samples=4, fit_steps=5, max_error_regression_pp=-100.0
    )
    recal.ingest(fp, _sweep(E5_2630_V3))
    event = recal.recalibrate(fp)
    svc.close()
    assert not event.accepted and "previous spec retained" in event.reason
    assert svc.epoch_of(fp) == 0  # never swapped
    assert svc.machine_spec(fp) == E5_2630_V3
    assert svc.metrics.snapshot()["rollbacks"] == 1
    assert event.new_error_pct == event.new_error_pct  # scored, not NaN


def test_recalibrator_fit_failure_is_an_event_not_a_crash():
    fi = FaultInjector()
    svc = AdvisorService(faults=fi)
    fp = svc.register(E5_2630_V3, machine_id="prod")
    recal = Recalibrator(svc, min_samples=4)
    recal.ingest(fp, _sweep(E5_2630_V3))
    fi.inject_error("recalibrate", times=1)
    event = recal.recalibrate(fp)
    svc.close()
    assert not event.accepted and "refit failed" in event.reason
    assert svc.epoch_of(fp) == 0


@pytest.mark.slow
def test_recalibrator_accepts_refit_of_drifted_spec():
    """The full loop: a service starts on a drifted spec, ingests a clean
    sweep measured on the TRUE machine, and the guarded refit is accepted
    and hot-swapped in with a better counter error than the drifted
    spec's."""
    truth = E5_2630_V3
    drifted = _drift(truth, 0.7)
    svc = AdvisorService(max_wait_s=0.0)
    fp = svc.register(drifted, machine_id="prod")
    svc.warmup(fp, 8)
    recal = Recalibrator(svc, min_samples=8, fit_steps=150)
    recal.ingest(fp, _sweep(truth, n_threads=8, noise_std=0.01))
    event = recal.recalibrate(fp)
    assert event.accepted, event.reason
    assert event.new_error_pct < event.old_error_pct
    assert event.epoch == 1 and svc.epoch_of(fp) == 1
    assert svc.machine_spec(fp) != drifted
    # the swapped spec serves immediately
    adv = svc.query(fp, _sigs(1, seed=15)[0], 8)
    assert adv.epoch == 1 and adv.fidelity == "exact"
    svc.close()


# ---------------------------------------------------------------------------
# Lifecycle: close/drain
# ---------------------------------------------------------------------------


def test_closed_service_raises_everywhere():
    svc = AdvisorService()
    fp = svc.register(E5_2630_V3)
    svc.close()
    svc.close()  # idempotent
    sig = _sigs(1)[0]
    with pytest.raises(ServiceClosedError):
        svc.query(fp, sig, 8)
    with pytest.raises(ServiceClosedError):
        svc.submit(fp, sig, 8)
    with pytest.raises(ServiceClosedError):
        svc.query_schedule(fp, [(sig, 1.0)], 8)
    with pytest.raises(ServiceClosedError):
        svc.swap_machine(fp, _drift(E5_2630_V3))


def test_concurrent_close_calls_are_safe():
    svc = AdvisorService()
    svc.register(E5_2630_V3)
    threads = [threading.Thread(target=svc.close) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not any(t.is_alive() for t in threads)


def test_close_during_query_hammer_never_hangs():
    """Queries racing a close either answer or raise ServiceClosedError —
    no third outcome, no hang."""
    svc = AdvisorService(max_batch=4, max_wait_s=0.01)
    fp = svc.register(E5_2630_V3, machine_id="prod")
    svc.warmup(fp, 8)
    sigs = _sigs(64, seed=16)
    outcomes = []
    lock = threading.Lock()
    idx = iter(range(len(sigs)))

    def worker():
        while True:
            with lock:
                i = next(idx, None)
            if i is None:
                return
            try:
                adv = svc.query(fp, sigs[i], 8, timeout=30)
                with lock:
                    outcomes.append(adv)
            except ServiceClosedError:
                with lock:
                    outcomes.append("closed")

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.05)  # let some queries land mid-flight
    svc.close()
    for t in threads:
        t.join(timeout=30)
    assert not any(t.is_alive() for t in threads), "a query hung on close"
    assert len(outcomes) == len(sigs)
    answered = [o for o in outcomes if isinstance(o, Advice)]
    for adv in answered:
        assert np.asarray(adv.placement).sum() == 8


def test_close_drains_pending_batches():
    """Futures already queued when close begins resolve — exactly (the
    drain) or with ServiceClosedError (the cutoff) — never silently."""
    svc = AdvisorService(max_batch=8, max_wait_s=0.5)
    fp = svc.register(E5_2630_V3, machine_id="prod")
    svc.warmup(fp, 8)
    futures = [svc.submit(fp, s, 8) for s in _sigs(3, seed=17)]
    svc.close()  # batcher is mid-wait holding the group open
    for f in futures:
        try:
            adv = f.result(timeout=30)
            assert isinstance(adv, Advice)
        except ServiceClosedError:
            pass
