"""The shared routed-graph engine (``repro.core.graphtop``) and the
bit-for-bit contract of its NUMA wrapper (``repro.core.numa.topology``).

The wrapper pins are the load-bearing ones: machine fingerprints digest
``repr(topology)``, so ``Topology`` must remain a class literally named
``Topology`` producing byte-identical reprs, link orders and routes off
the re-hosted engine.
"""

import numpy as np
import pytest

from repro.core import graphtop as G
from repro.core.numa import topology as numa_topo


# ---------------------------------------------------------------------------
# New builders
# ---------------------------------------------------------------------------


def test_torus2d_wraps_both_axes():
    t = G.torus2d(3, 4, 10e9)
    t.validate()
    assert t.n_nodes == 12
    # every node has degree 4 (two per axis)
    deg = np.zeros(12)
    for i, j in t.link_ends:
        deg[i] += 1
        deg[j] += 1
    assert (deg == 4).all()
    # wrap makes the worst pair ceil(3/2) + ceil(4/2) = 1 + 2 = 3 hops
    assert t.max_hops == 3


def test_torus2d_length2_axis_dedupes_wrap_link():
    t = G.torus2d(2, 2, 10e9)
    # 2x2 torus: each pair of adjacent nodes shares ONE link, not two
    assert t.n_links == 4
    t.validate()


def test_torus3d_shape():
    t = G.torus3d(2, 2, 4, 10e9)
    t.validate()
    assert t.n_nodes == 16
    # degree: z axis contributes 2, each length-2 axis 1 (deduped wrap)
    deg = np.zeros(16)
    for i, j in t.link_ends:
        deg[i] += 1
        deg[j] += 1
    assert (deg == 4).all()


def test_tree_routes_through_root():
    t = G.tree(7, 10e9)  # balanced binary: 0 -> (1, 2) -> (3..6)
    t.validate()
    assert t.n_links == 6
    # leaves in different subtrees route through the root
    route = t.route(3, 5)
    ends = {t.link_ends[l] for l in route}
    assert (0, 1) in ends and (0, 2) in ends and len(route) == 4


def test_glued_generalizes_glued_8s():
    gen = G.glued(2, 4, 12.8e9, 9.6e9)
    old = numa_topo.glued_8s(12.8e9, 9.6e9)
    assert gen.link_ends == old.link_ends
    assert gen.link_bw == old.link_bw
    assert gen.routes == old.routes
    assert gen.name == "glued2x4" and old.name == "glued8s"


def test_glued_ring_islands_wraps():
    g = G.glued(3, 2, 100e9, 10e9, ring_islands=True)
    g.validate()
    # 3 islands x 1 intra link + 3 glue stages x 2 twins = 9 links
    assert g.n_links == 9
    # ring wrap: island 2 reaches island 0 directly (1 hop via twin)
    assert len(g.route(4, 0)) == 1


def test_glued_two_islands_no_duplicate_wrap():
    a = G.glued(2, 3, 100e9, 10e9, ring_islands=True)
    b = G.glued(2, 3, 100e9, 10e9)
    assert a.link_ends == b.link_ends  # wrap == forward link for 2 islands


# ---------------------------------------------------------------------------
# Multipath routing (the carried-over ROADMAP thread)
# ---------------------------------------------------------------------------


def test_ring_multipath_splits_both_directions():
    r = G.ring(4, 10e9)
    n = r.n_nodes
    # single-path: the 0 -> 2 pair pins one side of the ring
    single = r.route_incidence()
    assert single[0 * n + 2].sum() == 2.0
    assert set(np.unique(single)) <= {0.0, 1.0}
    # multipath: both 2-hop sides carry half the flow each — all 4 links
    multi = r.route_incidence(multipath=True)
    row = multi[0 * n + 2]
    assert row.tolist() == [0.5, 0.5, 0.5, 0.5]
    # adjacent pairs still have a unique shortest route
    assert multi[0 * n + 1].tolist() == [1.0, 0.0, 0.0, 0.0]


def test_multipath_off_is_bitwise_default():
    for g in (G.ring(6, 5e9), G.torus2d(3, 3, 5e9), G.glued(2, 4, 10e9, 5e9)):
        a = g.route_incidence()
        b = g.route_incidence(multipath=False)
        assert a is b  # same cached array — the old table, untouched


def test_all_widest_routes_respects_bottleneck():
    # diamond: 0-1-3 wide, 0-2-3 narrow; only the wide route is optimal
    bw = np.zeros((4, 4))
    bw[0, 1] = bw[1, 0] = 10e9
    bw[1, 3] = bw[3, 1] = 10e9
    bw[0, 2] = bw[2, 0] = 1e9
    bw[2, 3] = bw[3, 2] = 10e9
    g = G.from_bandwidth_matrix("diamond", bw)
    routes = g.all_routes(0, 3)
    assert len(routes) == 1
    assert routes[0] == g.route(0, 3)
    # equal-bandwidth diamond: both routes are optimal
    bw[0, 2] = bw[2, 0] = 10e9
    g2 = G.from_bandwidth_matrix("diamond-eq", bw)
    assert len(g2.all_routes(0, 3)) == 2
    assert g.route(0, 3) in g2.all_routes(0, 3)


def test_directed_incidence_walks_directions():
    r = G.ring(4, 10e9)
    n = r.n_nodes
    R = r.directed_route_incidence()
    # 0 -> 1 crosses link (0,1) low->high: slot 0; 1 -> 0 the reverse slot
    l01 = r.link_ends.index((0, 1))
    assert R[0 * n + 1, 2 * l01] == 1.0 and R[0 * n + 1, 2 * l01 + 1] == 0.0
    assert R[1 * n + 0, 2 * l01] == 0.0 and R[1 * n + 0, 2 * l01 + 1] == 1.0
    # undirected fold of the directed matrix == the undirected matrix
    undirected = R[:, 0::2] + R[:, 1::2]
    assert np.array_equal(undirected, r.route_incidence())


def test_directed_incidence_multipath_fractional():
    r = G.ring(4, 10e9)
    R = r.directed_route_incidence(multipath=True)
    row = R[0 * 4 + 2]
    assert row.sum() == pytest.approx(2.0)  # 2 hops of total flow
    assert set(np.round(row[row > 0], 6)) == {0.5}


def test_bottleneck_weighting_on_thin_link_ring():
    # ring(4) link order is sorted endpoint pairs: (0,1),(0,3),(1,2),(2,3)
    # — make (0,3) ten times thinner than the rest.  The 0 -> 2 pair has
    # two 2-hop routes: {0,2} via node 1 (bottleneck 10) and {1,3} via
    # node 3 (bottleneck 1).
    r = G.ring(4, [10e9, 1e9, 10e9, 10e9])
    assert r.link_ends == ((0, 1), (0, 3), (1, 2), (2, 3))
    n = r.n_nodes
    both = r.all_shortest_routes_of(0, 2)
    assert sorted(frozenset(rt) for rt in both) == [{0, 2}, {1, 3}]
    # widest-tie equal split drops the thin route entirely...
    eq = r.route_incidence(multipath=True)[0 * n + 2]
    assert eq.tolist() == [1.0, 0.0, 1.0, 0.0]
    # ...bottleneck weighting keeps it at a 1/11 share
    bn = r.route_incidence(multipath=True, weighting="bottleneck")
    row = bn[0 * n + 2]
    assert row == pytest.approx(
        np.float32([10 / 11, 1 / 11, 10 / 11, 1 / 11])
    )
    # adjacent pairs have a single route either way
    assert bn[0 * n + 1].tolist() == [1.0, 0.0, 0.0, 0.0]


def test_bottleneck_weighting_equal_bandwidths_match_equal_split():
    # all-equal bottlenecks: the shortest-route set == the widest-tie set
    # and every share is 1/k — bit-for-bit the equal-split table
    for g in (G.ring(6, 5e9), G.torus2d(3, 3, 5e9)):
        eq = g.route_incidence(multipath=True)
        bn = g.route_incidence(multipath=True, weighting="bottleneck")
        assert np.array_equal(eq, bn)


def test_bottleneck_weighting_default_table_unchanged():
    r = G.ring(4, [10e9, 1e9, 10e9, 10e9])
    # the single-route default is untouched by the new option: 0/1 rows
    # following the widest-shortest primary routes
    single = r.route_incidence()
    assert set(np.unique(single)) <= {0.0, 1.0}
    assert single[0 * 4 + 2].tolist() == [1.0, 0.0, 1.0, 0.0]
    assert r.route_incidence(weighting="equal") is single  # same cache hit


def test_bottleneck_weighting_argument_validation():
    r = G.ring(4, 10e9)
    with pytest.raises(ValueError, match="requires multipath"):
        r.route_incidence(weighting="bottleneck")
    with pytest.raises(ValueError, match="requires multipath"):
        r.directed_route_incidence(weighting="bottleneck")
    with pytest.raises(ValueError, match="unknown multipath weighting"):
        r.route_incidence(multipath=True, weighting="widest")
    with pytest.raises(ValueError, match="unknown multipath weighting"):
        r.directed_route_incidence(multipath=True, weighting="widest")


def test_directed_bottleneck_weighting_folds_to_undirected():
    r = G.ring(4, [10e9, 1e9, 10e9, 10e9])
    R = r.directed_route_incidence(multipath=True, weighting="bottleneck")
    undirected = R[:, 0::2] + R[:, 1::2]
    assert np.allclose(
        undirected,
        r.route_incidence(multipath=True, weighting="bottleneck"),
    )


# ---------------------------------------------------------------------------
# NUMA wrapper: bit-for-bit compatibility pins
# ---------------------------------------------------------------------------


def test_topology_class_and_repr_preserved():
    t = numa_topo.fully_connected(4, 10e9)
    assert type(t).__name__ == "Topology"
    assert isinstance(t, G.LinkGraph)
    assert repr(t).startswith("Topology(name='fc4', n_nodes=4,")
    # _replace and from_fit preserve the subclass (fingerprints depend on it)
    assert type(t._replace(name="x")) is numa_topo.Topology
    assert type(numa_topo.from_fit(t, np.asarray(t.link_bw) * 2)) is numa_topo.Topology
    assert type(numa_topo.from_bandwidth_matrix("m", np.array([[0, 1e9], [1e9, 0]]))) \
        is numa_topo.Topology


def test_wrapper_builders_match_engine():
    pairs = [
        (numa_topo.fully_connected(4, 10e9), G.fully_connected(4, 10e9)),
        (numa_topo.ring(5, 5e9), G.ring(5, 5e9)),
        (numa_topo.mesh2d(2, 3, 5e9), G.mesh2d(2, 3, 5e9)),
        (
            numa_topo.snc(2, 2, qpi_bw=9e9, intra_bw=30e9),
            G.snc(2, 2, qpi_bw=9e9, intra_bw=30e9),
        ),
    ]
    for wrapped, engine in pairs:
        assert tuple(wrapped) == tuple(engine)  # same fields, NUMA class
        assert type(wrapped) is numa_topo.Topology


def test_machine_fingerprints_unchanged():
    """Golden pins: the digests these presets had before the graphtop
    extraction.  fingerprint() hashes repr(topology) among other fields, so
    any drift in class name, link order or routing breaks these."""
    from repro.core.numa.machine import E5_2630_V3, E7_8860_V3, E5_2699_V3_SNC2

    assert E5_2630_V3.fingerprint() == "134f795377b0ac9a817e78565d19b8f8"
    assert E7_8860_V3.fingerprint() == "b48bf7290b885333f6bc953b102373fa"
    assert E5_2699_V3_SNC2.fingerprint() == "7490ad694bceecbcb02dee20719e29e3"
