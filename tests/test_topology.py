"""Topology-aware interconnect model.

* builder/routing properties (ring hop counts, mesh distances, glued-8s
  node-controller routes),
* exact degeneration: for fully-connected topologies the per-link resource
  tensor and the whole ``evaluate_accuracy`` pipeline reproduce the seed's
  scalar-pair model bit for bit (golden medians recorded from the seed),
* routed-topology behaviour: multi-hop link charging, hop-attenuated
  remote capacities, end-to-end ``evaluate_batch`` + advisor on the glued
  8-socket preset,
* the ``_progressive_fill`` iteration-count reduction and the
  ``asymmetric_placement`` graceful fallback.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.numa import (
    E5_2630_V3,
    E5_2699_V3,
    E7_4830_V3,
    E7_8860_V3,
    MachineSpec,
    Topology,
    from_bandwidth_matrix,
    fully_connected,
    glued_8s,
    make_machine,
    mesh2d,
    mixed_workload,
    ring,
    simulate,
    snc,
)
from repro.core.numa.benchmarks import benchmark_workload
from repro.core.numa.simulator import (
    _progressive_fill,
    _resource_tensor,
    _thread_nodes,
    asymmetric_placement,
    symmetric_placement,
)

# ---------------------------------------------------------------------------
# builders + routing
# ---------------------------------------------------------------------------


def test_fully_connected_structure():
    topo = fully_connected(4, 10e9)
    assert topo.n_links == 6
    assert topo.is_fully_direct and topo.max_hops == 1
    assert (topo.hop_matrix() == np.ones((4, 4)) - np.eye(4)).all()
    # links enumerate the upper triangle in order
    assert topo.link_ends == ((0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3))


def test_ring_hop_counts():
    topo = ring(6, 12e9)
    assert topo.n_links == 6
    hops = topo.hop_matrix()
    expect = np.array([[min(abs(i - j), 6 - abs(i - j)) for j in range(6)] for i in range(6)])
    np.testing.assert_array_equal(hops, expect)
    # the 3-hop antipodal route is a contiguous walk of 3 distinct links
    route = topo.route(0, 3)
    assert len(route) == 3 and len(set(route)) == 3
    # 2-node ring collapses to a single link, not two parallel ones
    assert ring(2, 1e9).n_links == 1


def test_mesh2d_hop_counts_are_manhattan():
    topo = mesh2d(2, 3, 8e9)
    assert topo.n_links == 7  # 2*2 vertical + 3... rows*(cols-1) + cols*(rows-1)
    hops = topo.hop_matrix()
    for a in range(6):
        for b in range(6):
            ra, ca = divmod(a, 3)
            rb, cb = divmod(b, 3)
            assert hops[a, b] == abs(ra - rb) + abs(ca - cb)


def test_glued_8s_routes_and_capacities():
    qpi, nc = 12.8e9, 9.6e9
    topo = glued_8s(qpi_bw=qpi, nc_bw=nc)
    assert topo.n_links == 16  # 2 quads x 6 QPI + 4 node-controller links
    hops = topo.hop_matrix()
    for i in range(8):
        for j in range(8):
            if i == j:
                assert hops[i, j] == 0
            elif i // 4 == j // 4 or j == (i + 4) % 8:
                assert hops[i, j] == 1  # intra-quad QPI or twin controller
            else:
                assert hops[i, j] == 2  # cross-quad via a controller
    # twin links carry the controller bandwidth, quad links the QPI one
    for l, (i, j) in enumerate(topo.link_ends):
        assert topo.link_bw[l] == (nc if j - i == 4 else qpi)
    # every 2-hop route crosses exactly one controller link + one QPI link
    for i in range(8):
        for j in range(8):
            if hops[i, j] == 2:
                kinds = sorted(topo.link_bw[l] for l in topo.route(i, j))
                assert kinds == [nc, qpi]


def test_routing_is_deterministic_and_valid():
    for topo in (ring(7, 1e9), mesh2d(3, 3, 1e9), glued_8s(qpi_bw=2e9, nc_bw=1e9)):
        topo.validate()
        rebuilt = type(topo)(*topo)  # routes are plain data: stable across builds
        assert rebuilt == topo


def test_from_bandwidth_matrix_accepts_arrays_and_stays_hashable():
    bw = np.zeros((3, 3))
    bw[0, 1] = bw[1, 0] = 10e9
    bw[1, 2] = bw[2, 1] = 5e9
    topo = from_bandwidth_matrix("chain3", jnp.asarray(bw))
    hash(topo)  # canonicalized to tuples -> usable as jit static arg
    assert topo.link_ends == ((0, 1), (1, 2))
    assert topo.route(0, 2) == (0, 1)  # routed over both links
    with pytest.raises(ValueError):
        from_bandwidth_matrix("asym", np.array([[0.0, 1e9], [2e9, 0.0]]))
    with pytest.raises(ValueError):  # disconnected
        from_bandwidth_matrix("disc", np.zeros((2, 2)))
    with pytest.raises(ValueError):  # sign typo must not silently drop a link
        neg = bw.copy()
        neg[0, 1] = neg[1, 0] = -10e9
        from_bandwidth_matrix("neg", neg)


def test_route_tiebreak_prefers_widest_bottleneck():
    """Among equal-hop shortest paths the route with the largest bottleneck
    link bandwidth must win: on a 4-ring whose (0,1) link is thin, traffic
    0 -> 2 goes the fat way round even though node 1 is the smaller-id
    predecessor."""
    topo = ring(4, [2e9, 10e9, 10e9, 10e9])  # links (0,1),(0,3),(1,2),(2,3)
    assert topo.link_ends == ((0, 1), (0, 3), (1, 2), (2, 3))
    assert topo.route(0, 2) == (1, 3)  # via node 3: bottleneck 10 GB/s
    assert topo.route(2, 0) == (3, 1)
    # the thin link still carries its own endpoint pair
    assert topo.route(0, 1) == (0,)
    # flip the fat side: one fat link cannot beat the thin bottleneck, so
    # the deterministic smallest-predecessor fallback decides again
    sym = ring(4, [10e9, 10e9, 10e9, 10e9])
    assert sym.route(0, 2) == (0, 2)  # uniform bw: via node 1 (old rule)


def test_route_tiebreak_deterministic_fallback_preserved():
    """With uniform link bandwidths the widest-path rule degenerates to the
    smallest-id-predecessor tie-break, so unweighted routing tables are
    unchanged: equal-width ties on the glued 8-socket machine still pick
    the smallest-id intermediate."""
    topo = glued_8s(qpi_bw=12.8e9, nc_bw=9.6e9)
    # 0 -> 5: via twin 4 (nc then qpi) or via 1 (qpi then nc); both
    # bottleneck at the nc link => fallback picks the smaller-id pred (1)
    route = topo.route(0, 5)
    mids = set(topo.link_ends[route[0]]) & set(topo.link_ends[route[1]])
    assert mids == {1}
    # a 6-ring with one fat link: the antipodal pair's two 3-hop paths tie
    # on the thin bottleneck, so the fat link does not hijack the route
    fat = ring(6, [5e9, 5e9, 50e9, 5e9, 5e9, 5e9])
    thin = ring(6, 5e9)
    assert fat.routes == thin.routes


def test_snc_topology_structure_and_shared_port_routing():
    """snc(): intra-socket links join a socket's nodes; only the first node
    of each socket owns a QPI link, so a non-endpoint node's cross-socket
    route passes through both sockets' endpoints (up to 3 hops)."""
    topo = snc(2, 2, qpi_bw=51.2e9, intra_bw=44e9)
    assert topo.n_nodes == 4 and topo.n_links == 3
    assert topo.link_ends == ((0, 1), (0, 2), (2, 3))
    assert topo.link_bw == (44e9, 51.2e9, 44e9)
    hops = topo.hop_matrix()
    assert hops[0, 2] == 1  # endpoint to endpoint: the QPI link
    assert hops[1, 2] == 2  # non-endpoint routes through its endpoint
    assert hops[1, 3] == 3  # far corner: intra + QPI + intra
    qpi_link = topo.link_ends.index((0, 2))
    for i, j in ((0, 2), (1, 2), (0, 3), (1, 3)):
        assert qpi_link in topo.route(i, j)  # every cross-socket pair
    # degenerate case: one node per socket == fully connected sockets
    assert snc(3, 1, qpi_bw=1e9, intra_bw=2e9).link_ends == fully_connected(
        3, 1e9
    ).link_ends
    with pytest.raises(ValueError):
        snc(1, 2, qpi_bw=1e9, intra_bw=1e9)


def test_machine_fingerprint_distinguishes_topologies():
    a = make_machine("m", sockets=4, qpi_bw=10e9)
    b = make_machine("m", sockets=4, qpi_bw=10e9)
    c = make_machine("m", sockets=4, topology=ring(4, 10e9))
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != c.fingerprint()
    assert a.fingerprint() != a._replace(hop_attenuation=0.9).fingerprint()
    # adjacent-field boundaries must not be ambiguous: '32','5.0' vs '3','25.0'
    d = a._replace(cores_per_socket=3, local_read_bw=25.0)
    e = a._replace(cores_per_socket=32, local_read_bw=5.0)
    assert d.fingerprint() != e.fingerprint()


# ---------------------------------------------------------------------------
# fully-connected topologies degenerate exactly to the seed scalar model
# ---------------------------------------------------------------------------


def _seed_resource_tensor(machine, qpi_bw, read_unit, write_unit, socket_of):
    """The seed's scalar-pair implementation, verbatim (modulo the removed
    ``qpi_bw`` field, passed explicitly)."""
    s = machine.sockets
    n = socket_of.shape[0]
    onehot = jax.nn.one_hot(socket_of, s)
    rr = onehot[:, :, None] * read_unit[:, None, :]
    ww = onehot[:, :, None] * write_unit[:, None, :]
    off_diag = (1.0 - jnp.eye(s))[None, :, :]
    rr_remote = rr * off_diag
    ww_remote = ww * off_diag
    pair_i, pair_j = np.triu_indices(s, k=1)
    qpi_usage = (
        rr_remote[:, pair_i, pair_j]
        + rr_remote[:, pair_j, pair_i]
        + ww_remote[:, pair_i, pair_j]
        + ww_remote[:, pair_j, pair_i]
    )
    usage = jnp.concatenate(
        [
            read_unit,
            write_unit,
            rr_remote.reshape(n, s * s),
            ww_remote.reshape(n, s * s),
            qpi_usage,
        ],
        axis=1,
    )
    inf = jnp.inf
    remote_read_caps = jnp.where(
        jnp.eye(s, dtype=bool), inf, machine.remote_read_bw
    ).reshape(s * s)
    remote_write_caps = jnp.where(
        jnp.eye(s, dtype=bool), inf, machine.remote_write_bw
    ).reshape(s * s)
    caps = jnp.concatenate(
        [
            machine.bank_read_caps(),
            machine.bank_write_caps(),
            remote_read_caps,
            remote_write_caps,
            jnp.full((pair_i.shape[0],), qpi_bw, jnp.float32),
        ]
    )
    return usage, caps


@pytest.mark.parametrize(
    "machine,n_per",
    [
        (E5_2630_V3, [5, 3]),
        (E5_2699_V3, [12, 6]),
        (E7_4830_V3, [6, 4, 4, 2]),
    ],
)
def test_fully_connected_resource_tensor_is_bitwise_seed(machine, n_per):
    n_threads = int(sum(n_per))
    rng = np.random.default_rng(7)
    read_unit = jnp.asarray(rng.uniform(0, 2e9, (n_threads, machine.sockets)), jnp.float32)
    write_unit = jnp.asarray(rng.uniform(0, 1e9, (n_threads, machine.sockets)), jnp.float32)
    socket_of = _thread_nodes(jnp.asarray(n_per, jnp.int32), n_threads)
    usage, caps = _resource_tensor(machine, read_unit, write_unit, socket_of)
    legacy_u, legacy_c = _seed_resource_tensor(
        machine, machine.topology.link_bw[0], read_unit, write_unit, socket_of
    )
    np.testing.assert_array_equal(np.asarray(usage), np.asarray(legacy_u))
    np.testing.assert_array_equal(np.asarray(caps), np.asarray(legacy_c))


# Golden medians — evaluate_accuracy(machine, bench @ 8 threads,
# noise_std=0.02, key=PRNGKey(3)), median of errors_combined in %.
# Originally recorded from the seed scalar-pair implementation (commit
# acbf77a); re-recorded when the shared-slab batch engine replaced the
# per-placement measurement-key chain with batched (P, s, s) noise draws
# (same lognormal model, different PRNG stream — exact same magnitudes).
# The noise-FREE arithmetic still matches the per-placement reference
# bit-tight: tests/test_placement_sweep.py pins evaluate_batch against a
# simulate() loop at noise_std=0, and test_grouped_solver.py pins the
# grouped/per-thread equivalence at 1e-6 on raw rates.
_SEED_ACCURACY_MEDIANS = {
    ("E5-2630v3-8c", "Swim"): 0.11666179448366165,
    ("E5-2630v3-8c", "CG"): 0.17466020584106445,
    ("E5-2630v3-8c", "NPO"): 0.10933627188205719,
    ("E5-2699v3-18c", "Swim"): 0.1166609674692154,
    ("E5-2699v3-18c", "CG"): 0.17466005682945251,
    ("E5-2699v3-18c", "NPO"): 0.1093355342745781,
}


@pytest.mark.parametrize("machine", [E5_2630_V3, E5_2699_V3])
def test_accuracy_medians_match_seed_on_2socket_presets(machine):
    """The per-link model with a fully-connected topology must reproduce
    the recorded evaluate_accuracy medians on both paper machines (same
    placements, same PRNG stream, same arithmetic)."""
    from repro.core.numa.evaluate import evaluate_accuracy

    for bench in ("Swim", "CG", "NPO"):
        wl = benchmark_workload(bench, 8)
        res = evaluate_accuracy(machine, wl, noise_std=0.02, key=jax.random.PRNGKey(3))
        med = float(np.median(np.asarray(res.errors_combined)) * 100.0)
        # rel=1e-4 (was 1e-6): the group-collapsed solver reorders float
        # sums across a group's identical rows, moving medians ~1e-5
        # relative; a genuine model change moves them orders more (the
        # grouped/per-thread equivalence itself is pinned at 1e-6 on raw
        # rates by tests/test_grouped_solver.py)
        assert med == pytest.approx(
            _SEED_ACCURACY_MEDIANS[(machine.name, bench)], rel=1e-4
        ), bench


# ---------------------------------------------------------------------------
# routed topologies: attenuated remote caps + multi-hop charging
# ---------------------------------------------------------------------------


def test_remote_caps_attenuate_with_hops():
    caps = np.asarray(E7_8860_V3.remote_read_caps())
    hops = E7_8860_V3.topology.hop_matrix()
    base = E7_8860_V3.remote_read_bw
    att = E7_8860_V3.hop_attenuation
    assert np.isinf(np.diagonal(caps)).all()
    np.testing.assert_allclose(caps[hops == 1], np.float32(base), rtol=1e-6)
    np.testing.assert_allclose(caps[hops == 2], np.float32(base * att), rtol=1e-6)


def test_multihop_flow_saturates_controller_link():
    """All threads on socket 0 reading a static allocation on socket 5:
    the 2-hop route's node-controller link must bound the traffic below
    what the same machine with direct links everywhere would allow."""
    routed = E7_8860_V3
    direct = routed._replace(
        topology=fully_connected(8, 12.8e9), hop_attenuation=1.0
    )
    wl = mixed_workload(
        "far", 16, read_mix=(1.0, 0.0, 0.0), read_bpi=2.0, write_bpi=0.0,
        static_socket=5,
    )
    p = jnp.asarray([16, 0, 0, 0, 0, 0, 0, 0], jnp.int32)
    thr_routed = float(simulate(routed, wl, p).throughput)
    thr_direct = float(simulate(direct, wl, p).throughput)
    assert thr_routed < thr_direct
    # the flow 0 -> bank 5 respects the attenuated 2-hop remote cap
    flow = float(simulate(routed, wl, p).read_flows[0, 5])
    cap = float(np.asarray(routed.remote_read_caps())[0, 5])
    assert flow <= cap * (1 + 1e-4)


def test_shared_link_contention_between_pairs():
    """Two flows whose routes share a link must split its capacity, even
    though they use disjoint socket pairs — inexpressible in the scalar
    model.  On a 4-node chain 0-1-2-3, pair (0,2) routes over links
    (0,1)+(1,2) and pair (1,2) uses link (1,2): both charge (1,2)."""
    bw = np.zeros((4, 4))
    for i, j in ((0, 1), (1, 2), (2, 3)):
        bw[i, j] = bw[j, i] = 10e9
    chain = make_machine(
        "chain4", sockets=4, cores_per_socket=8,
        local_read_bw=200e9, local_write_bw=200e9,
        remote_read_ratio=1.0, remote_write_ratio=1.0,
        topology=from_bandwidth_matrix("chain4", bw),
    )
    # per-thread arrays: one thread on socket 0 and one on socket 1, both
    # reading a static region on socket 2 as fast as they can issue
    wl = mixed_workload(
        "contend", 2, read_mix=(1.0, 0.0, 0.0), read_bpi=8.0, write_bpi=0.0,
        static_socket=2,
    )
    res = simulate(chain, wl, jnp.asarray([1, 1, 0, 0], jnp.int32))
    inflow = float(np.asarray(res.read_flows)[:, 2].sum())
    assert inflow <= 10e9 * (1 + 1e-4)  # the shared (1,2) link caps BOTH flows


# ---------------------------------------------------------------------------
# end to end: glued 8-socket machine through the batched engine + advisor
# ---------------------------------------------------------------------------


def test_glued8s_evaluate_batch_and_advisor_end_to_end():
    from repro.core.meshsig.advisor import rank_numa_placements
    from repro.core.numa.evaluate import enumerate_placements, evaluate_batch

    machine = E7_8860_V3
    wl = benchmark_workload("CG", 16)
    placements = enumerate_placements(machine, 16, max_placements=24, seed=2)
    batch = evaluate_batch(machine, wl, placements, keys=jax.random.PRNGKey(5))
    errs = np.asarray(batch.errors_combined)
    assert errs.shape == (1, 24, 2 * machine.sockets)
    assert np.isfinite(errs).all()
    assert errs.max() < 2e-3  # noise-free + in-model => predictions exact

    ranked = rank_numa_placements(machine, wl, max_placements=64, top_k=8)
    assert len(ranked) == 8
    thrs = [r.predicted_throughput for r in ranked]
    assert thrs == sorted(thrs, reverse=True)
    assert all(sum(r.placement) == 16 for r in ranked)


@pytest.mark.slow
def test_glued8s_suite_sweep_stays_in_error_band():
    """Nightly regression net for the big routed sweep: the full benchmark
    suite over a budgeted glued-8s placement sweep keeps the paper-band
    median error (2.34% at s = 2) despite multi-hop routing."""
    from repro.core.numa.evaluate import evaluate_suite

    r = evaluate_suite(
        E7_8860_V3,
        2 * E7_8860_V3.cores_per_socket,
        noise_std=0.02,
        include_violators=False,
        max_placements=40,
    )
    assert r.all_errors.size > 1000
    assert 0.0 < r.median_error_pct < 2.34


def test_advisor_prefers_fewer_hops_on_glued_machine():
    """With an interleaved-heavy workload, concentrating threads inside
    one quad (1-hop links only) must rank above spreading them across the
    controller: the ranker's link charging sees the extra hops."""
    from repro.core.bwsig import DirectionSignature
    from repro.core.meshsig.advisor import _placement_scores

    machine = E7_8860_V3
    # a purely interleaved signature: traffic spreads over all banks
    sig = DirectionSignature(
        static_socket=jnp.zeros((), jnp.int32),
        static_fraction=jnp.zeros(()),
        local_fraction=jnp.zeros(()),
        per_thread_fraction=jnp.zeros(()),
    )
    intra_quad = jnp.asarray([[4, 4, 4, 4, 0, 0, 0, 0]], jnp.int32)
    cross_quad = jnp.asarray([[4, 4, 0, 0, 4, 4, 0, 0]], jnp.int32)
    _, thr_intra = _placement_scores(
        machine, sig, sig, intra_quad, 1.0, 0.25
    )
    _, thr_cross = _placement_scores(
        machine, sig, sig, cross_quad, 1.0, 0.25
    )
    assert float(thr_intra[0]) >= float(thr_cross[0])


# ---------------------------------------------------------------------------
# satellite fixes: progressive-fill iteration count, asymmetric fallback
# ---------------------------------------------------------------------------


def test_progressive_fill_converges_in_reduced_iterations():
    """min(n_threads, n_resources) + 1 iterations reach the same fixed
    point as the seed's n_resources + 2 (172 on the 8-socket preset)."""
    from repro.core.numa.simulator import _mix_rows

    machine = E7_8860_V3
    wl = benchmark_workload("CG", 32)
    n_per = jnp.asarray([8, 8, 4, 4, 4, 2, 2, 0], jnp.int32)
    socket_of = _thread_nodes(n_per, 32)
    read_mix = _mix_rows(
        wl.read_static, wl.read_local, wl.read_per_thread,
        wl.static_socket, socket_of, n_per,
    )
    write_mix = _mix_rows(
        wl.write_static, wl.write_local, wl.write_per_thread,
        wl.static_socket, socket_of, n_per,
    )
    rate_of = machine.node_rates()[socket_of]
    read_unit = rate_of[:, None] * wl.read_bpi[:, None] * read_mix
    write_unit = rate_of[:, None] * wl.write_bpi[:, None] * write_mix
    usage, caps = _resource_tensor(machine, read_unit, write_unit, socket_of)
    n, n_res = usage.shape
    assert n_res > n  # the 8-socket preset is resource-dominated
    fast = _progressive_fill(usage, caps, min(n, n_res) + 1)
    slow = _progressive_fill(usage, caps, n_res + 2)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(slow))


@pytest.mark.parametrize(
    "machine,n_threads",
    [(E5_2630_V3, 8), (E5_2699_V3, 18), (E7_4830_V3, 16), (E7_8860_V3, 32)],
)
def test_asymmetric_placement_unchanged_for_feasible_splits(machine, n_threads):
    """The fallback must not disturb the profiling protocol anywhere the
    3:1 split was already feasible."""
    s = machine.sockets
    cap = machine.cores_per_socket
    first = min(-(-3 * n_threads // 4), cap)
    rest = n_threads - first
    others = [rest // (s - 1)] * (s - 1)
    others[0] += rest - sum(others)
    expect = [first] + others
    got = np.asarray(asymmetric_placement(machine, n_threads)).tolist()
    assert got == expect


def test_asymmetric_placement_falls_back_gracefully():
    # 2 threads on 2 sockets: 3:1 target leaves zero threads elsewhere;
    # nearest valid *unequal* split is everything on socket 0.
    got = np.asarray(asymmetric_placement(E5_2630_V3, 2)).tolist()
    assert got == [2, 0]
    # 1 thread: only unequal splits exist
    assert np.asarray(asymmetric_placement(E5_2630_V3, 1)).tolist() == [1, 0]
    # full machine: the equal split is the only valid one — returned, not raised
    full = np.asarray(asymmetric_placement(E5_2630_V3, 16)).tolist()
    assert full == [8, 8]
    # infeasible counts raise ValueError, never AssertionError
    with pytest.raises(ValueError):
        asymmetric_placement(E5_2630_V3, 17)
    # the fallback still differs from the symmetric run whenever possible
    sym = np.asarray(symmetric_placement(E5_2630_V3, 8)).tolist()
    asym = np.asarray(asymmetric_placement(E5_2630_V3, 8)).tolist()
    assert sym != asym
