"""The placement-advisor service (``repro.serve``): three-tier fast path,
micro-batching, and the serving contracts the PR commits to.

Contracts under test:

* **Determinism** — concurrent mixed hit/miss streams produce answers
  bit-identical to serial evaluation (batch rows never interact; padding
  always lands on the same traced shape).
* **Coalescing** — open-loop concurrent misses for one ``(machine,
  budget)`` group answer in far fewer simulator calls than queries, and a
  lone miss still answers once its ``max_wait_s`` deadline fires.
* **No steady-state retraces** — after one warmup query per group, a
  1k-query mixed stream registers zero new jit shapes (the service
  counter AND jax's own trace-cache size agree).
* **Tier routing** — small machines sweep (tier 2, exhaustive hence
  ``optimal``), 16-node machines fall back to warm-started branch and
  bound (tier 3).
* **Primitives** — the LRU cache evicts in recency order under threads;
  the metrics snapshot is JSON-ready and ``reset(keep_traces=True)``
  arms the steady-state assertion.
"""

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core.numa import E5_2630_V3, E7_4830_V3, make_machine
from repro.serve import (
    Advice,
    AdvisorService,
    LRUCache,
    QuerySignature,
    ServiceMetrics,
)
from repro.serve.service import _advise_batch_jit


def _sigs(n, seed=0):
    from repro.launch.advisor_serve import signature_pool

    return signature_pool(n, seed=seed)


@pytest.fixture(scope="module")
def service():
    svc = AdvisorService(max_wait_s=0.002)
    yield svc
    svc.close()


# ---------------------------------------------------------------------------
# LRUCache
# ---------------------------------------------------------------------------


def test_lru_cache_bounds_and_recency():
    c = LRUCache(capacity=3)
    for k in "abc":
        c.put(k, k.upper())
    assert c.get("a") == "A"  # refresh 'a'
    c.put("d", "D")  # evicts 'b' (least recent)
    assert "b" not in c and len(c) == 3
    assert c.keys() == ["c", "a", "d"]
    c.put("c", "C2")  # refresh via put
    c.put("e", "E")  # evicts 'a'
    assert "a" not in c and c.get("c") == "C2"
    with pytest.raises(ValueError):
        LRUCache(capacity=0)


def test_lru_cache_thread_safety_hammer():
    c = LRUCache(capacity=32)
    errors = []

    def worker(base):
        try:
            for i in range(500):
                c.put((base, i % 50), i)
                c.get((base, (i * 7) % 50))
                len(c)
        except Exception as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(b,)) for b in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(c) <= 32


# ---------------------------------------------------------------------------
# ServiceMetrics
# ---------------------------------------------------------------------------


def test_metrics_snapshot_and_reset():
    m = ServiceMetrics(latency_window=8)
    m.record_query("cache", 1e-6)
    m.record_query("batch", 2e-3)
    m.record_batch(4)
    assert m.register_trace(("k", 1)) is True
    assert m.register_trace(("k", 1)) is False  # already registered
    snap = m.snapshot()
    assert snap["queries"] == 2
    assert snap["tier_counts"] == {
        "cache": 1, "batch": 1, "search": 0, "schedule": 0, "degraded": 0
    }
    assert snap["batch_size_hist"] == {4: 1}
    assert snap["mean_batch_size"] == 4.0
    assert snap["retraces"] == 1
    assert snap["cache_p99_ms"] < snap["batch_p50_ms"]
    m.reset(keep_traces=True)
    snap = m.snapshot()
    assert snap["queries"] == 0 and snap["retraces"] == 0
    assert m.register_trace(("k", 1)) is False  # key set survived the reset
    m.reset()
    assert m.register_trace(("k", 1)) is True  # full reset forgets keys


def test_metrics_latency_ring_wraps():
    m = ServiceMetrics(latency_window=4)
    for i in range(10):
        m.record_query("cache", float(i))
    pct = m.latency_percentiles("cache", qs=(50.0,))
    # only the last window of 4 samples (6..9) is retained
    assert 6.0 <= pct["p50"] <= 9.0


# ---------------------------------------------------------------------------
# Tier 1 + 2: cache, micro-batching, determinism
# ---------------------------------------------------------------------------


def test_cache_hit_returns_identical_object(service):
    sig = _sigs(1, seed=21)[0]
    first = service.query(E7_4830_V3, sig, 24)
    again = service.query(E7_4830_V3, sig, 24)
    assert again is first  # the hit path returns the cached Advice itself
    assert service.metrics.snapshot()["tier_counts"]["cache"] >= 1


def test_advice_fields_and_feasibility(service):
    adv = service.query(E7_4830_V3, _sigs(1, seed=22)[0], 24)
    assert isinstance(adv, Advice)
    p = np.asarray(adv.placement)
    assert p.shape == (E7_4830_V3.n_nodes,)
    assert p.sum() == 24 and (p >= 0).all()
    assert (p <= E7_4830_V3.cores_per_node).all()
    assert adv.objective > 0 and adv.predicted_bandwidth > 0
    assert adv.tier == "batch" and adv.optimal


def test_concurrent_mixed_stream_matches_serial():
    # serial reference: one query at a time on a fresh service
    sigs = _sigs(24, seed=5)
    serial = AdvisorService(max_wait_s=0.0)
    reference = {s: serial.query(E7_4830_V3, s, 24) for s in sigs}
    serial.close()

    svc = AdvisorService(max_wait_s=0.002)
    svc.warmup(E7_4830_V3, 24)
    # mixed stream: every signature queried 3x from 6 threads, so each is
    # a miss once (batched with arbitrary batch-mates) and a hit after
    stream = [sigs[(3 * i + j) % len(sigs)] for i in range(3) for j in range(len(sigs))]
    results: dict[int, Advice] = {}
    lock = threading.Lock()
    idx = iter(range(len(stream)))

    def worker():
        while True:
            with lock:
                i = next(idx, None)
            if i is None:
                return
            results[i] = svc.query(E7_4830_V3, stream[i], 24)

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.close()

    assert len(results) == len(stream)
    for i, sig in enumerate(stream):
        got, want = results[i], reference[sig]
        assert got.placement == want.placement
        assert got.objective == want.objective  # bit-identical, no tolerance
        assert got.predicted_bandwidth == want.predicted_bandwidth


def test_open_loop_misses_coalesce_into_batches():
    svc = AdvisorService(max_batch=8, max_wait_s=0.01)
    svc.warmup(E7_4830_V3, 24)
    svc.metrics.reset(keep_traces=True)
    sigs = _sigs(32, seed=6)
    futures = [svc.submit(E7_4830_V3, s, 24) for s in sigs]
    answers = [f.result(timeout=60) for f in futures]
    snap = svc.metrics.snapshot()
    svc.close()
    assert all(isinstance(a, Advice) for a in answers)
    # far fewer simulator calls than queries, and real coalescing
    assert snap["batch_calls"] < len(sigs)
    assert snap["mean_batch_size"] > 1.5
    assert sum(n * s for s, n in snap["batch_size_hist"].items()) == len(sigs)


def test_lone_miss_answers_at_the_deadline():
    svc = AdvisorService(max_batch=8, max_wait_s=0.05)
    svc.warmup(E7_4830_V3, 24)
    svc.metrics.reset(keep_traces=True)
    t0 = time.perf_counter()
    fut = svc.submit(E7_4830_V3, _sigs(1, seed=33)[0], 24)
    advice = fut.result(timeout=30)
    elapsed = time.perf_counter() - t0
    snap = svc.metrics.snapshot()
    svc.close()
    assert isinstance(advice, Advice)
    assert elapsed >= 0.05  # the batcher held the queue open until the deadline
    assert snap["batch_size_hist"] == {1: 1}  # ...then flushed the lone query


def test_identical_concurrent_misses_compute_once():
    svc = AdvisorService(max_wait_s=0.005)
    svc.warmup(E7_4830_V3, 24)
    svc.metrics.reset(keep_traces=True)
    sig = _sigs(1, seed=44)[0]
    futures = [svc.submit(E7_4830_V3, sig, 24) for _ in range(6)]
    answers = [f.result(timeout=30) for f in futures]
    snap = svc.metrics.snapshot()
    svc.close()
    assert all(a is answers[0] for a in answers)  # in-flight dedup
    assert sum(n * s for s, n in snap["batch_size_hist"].items()) == 1


def test_submit_returns_resolved_future_on_hit(service):
    sig = _sigs(1, seed=55)[0]
    service.query(E7_4830_V3, sig, 24)
    fut = service.submit(E7_4830_V3, sig, 24)
    assert isinstance(fut, Future) and fut.done()
    assert fut.result().placement == service.query(E7_4830_V3, sig, 24).placement


def test_zero_retraces_across_mixed_1k_stream():
    from repro.launch.advisor_serve import drive_threads, mixed_stream

    svc = AdvisorService(max_wait_s=0.002)
    fp = svc.register(E7_4830_V3)
    hot = _sigs(16, seed=0)
    svc.warmup(fp, 24)
    for sig in hot:
        svc.query(fp, sig, 24)
    svc.metrics.reset(keep_traces=True)
    cache_entries = getattr(_advise_batch_jit, "_cache_size", lambda: None)()

    fresh = _sigs(1000, seed=9)
    stream = mixed_stream(
        hot, fresh, hot[:1], 1000,
        sweep_target=(fp, 24), search_target=(fp, 24),
        hit_fraction=0.75, search_fraction=0.0,
    )
    results, _ = drive_threads(svc, stream, n_workers=4)
    snap = svc.metrics.snapshot()
    now_entries = getattr(_advise_batch_jit, "_cache_size", lambda: None)()
    svc.close()
    assert all(r is not None for r in results)
    assert snap["queries"] == 1000
    assert snap["tier_counts"]["batch"] > 0  # stream really mixed misses in
    assert snap["retraces"] == 0  # the committed steady-state contract
    if cache_entries is not None:  # jax's own count agrees when available
        assert now_entries == cache_entries


def test_registry_and_fingerprint_front_end(service):
    fp = service.register(E5_2630_V3)
    assert isinstance(fp, str)
    adv = service.query(fp, _sigs(1, seed=66)[0], 8)
    assert np.asarray(adv.placement).sum() == 8
    with pytest.raises(KeyError):
        service.query("no-such-fingerprint", _sigs(1)[0], 8)


def test_canonicalization_merges_float_noise(service):
    a = QuerySignature((1 / 3, 1 / 3, 0.1), (0.2, 0.2, 0.2))
    b = QuerySignature(
        (0.33333333333, 0.333333333401, 0.1), (0.2, 0.2, 0.2)
    )
    assert a.canonical() == b.canonical()
    assert service.query(E7_4830_V3, a, 24) is service.query(E7_4830_V3, b, 24)


# ---------------------------------------------------------------------------
# Tier 3: search fallback
# ---------------------------------------------------------------------------


def test_sixteen_node_machine_routes_to_search_tier():
    m16 = make_machine(
        "snc2-8s", sockets=8, cores_per_socket=8, nodes_per_socket=2,
        qpi_bw=25.6e9,
    )
    svc = AdvisorService()
    assert svc.uses_search(m16, 32)
    assert not svc.uses_search(E7_4830_V3, 24)
    adv = svc.query(m16, _sigs(1, seed=77)[0], 32, timeout=300)
    p = np.asarray(adv.placement)
    snap = svc.metrics.snapshot()
    svc.close()
    assert adv.tier == "search"
    assert p.shape == (16,) and p.sum() == 32
    assert (p >= 0).all() and (p <= m16.cores_per_node).all()
    assert adv.objective > 0 and adv.predicted_bandwidth > 0
    assert snap["tier_counts"]["search"] == 1


# ---------------------------------------------------------------------------
# Lifecycle
# ---------------------------------------------------------------------------


def test_close_is_idempotent_and_rejects_new_queries():
    svc = AdvisorService()
    svc.close()
    svc.close()
    with pytest.raises(RuntimeError):
        svc.query(E7_4830_V3, _sigs(1)[0], 24)


def test_answer_cache_is_bounded():
    svc = AdvisorService(answer_capacity=8, max_wait_s=0.0)
    svc.warmup(E7_4830_V3, 24)
    for sig in _sigs(20, seed=88):
        svc.query(E7_4830_V3, sig, 24)
    assert len(svc._answers) <= 8
    svc.close()


# ---------------------------------------------------------------------------
# Metrics under churn
# ---------------------------------------------------------------------------


def test_metrics_reset_during_inflight_batch():
    """reset(keep_traces=True) racing an in-flight batch must neither
    crash the batcher nor corrupt counters: completions landing after the
    reset are counted from zero, the trace-key set survives, and a warmed
    group still registers no retrace."""
    svc = AdvisorService(max_batch=4, max_wait_s=0.05)
    svc.warmup(E7_4830_V3, 24)
    sigs = _sigs(8, seed=101)
    futures = [svc.submit(E7_4830_V3, s, 24) for s in sigs]
    # the batcher is holding the queue open for max_wait_s; reset now
    svc.metrics.reset(keep_traces=True)
    answers = [f.result(timeout=60) for f in futures]
    snap = svc.metrics.snapshot()
    assert all(isinstance(a, Advice) for a in answers)
    # every completion recorded after the reset is counted exactly once,
    # and none of them retraced the warmed group
    assert snap["tier_counts"]["batch"] == len(sigs)
    assert snap["retraces"] == 0
    # the service keeps serving normally afterwards
    hit = svc.query(E7_4830_V3, sigs[0], 24)
    assert hit is answers[0]
    assert svc.metrics.snapshot()["tier_counts"]["cache"] == 1
    svc.close()


def test_metrics_full_reset_forgets_traces_under_serving():
    svc = AdvisorService(max_wait_s=0.0)
    svc.warmup(E7_4830_V3, 24)
    svc.metrics.reset()  # full reset: the warmed shape is forgotten...
    svc.query(E7_4830_V3, _sigs(1, seed=102)[0], 24)
    snap = svc.metrics.snapshot()
    svc.close()
    assert snap["retraces"] == 1  # ...so the next batch re-registers it


# ---------------------------------------------------------------------------
# Phased queries (tier: schedule)
# ---------------------------------------------------------------------------


def _flip_phases():
    a = QuerySignature((0.7, 0.1, 0.0), (0.0, 0.0, 0.0), read_bpi=5.0,
                       static_socket=0)
    b = QuerySignature((0.7, 0.1, 0.0), (0.0, 0.0, 0.0), read_bpi=5.0,
                       static_socket=1)
    return [(a, 5.0), (b, 5.0)]


def test_query_schedule_end_to_end():
    from repro.core.numa.temporal import MigrationModel
    from repro.serve import ScheduleAdvice

    svc = AdvisorService()
    model = MigrationModel(thread_move_bytes=1e6, page_move_bytes=1e6)
    adv = svc.query_schedule(
        E5_2630_V3, _flip_phases(), 8, model=model, timeout=300
    )
    snap = svc.metrics.snapshot()
    assert isinstance(adv, ScheduleAdvice)
    assert adv.tier == "schedule"
    assert len(adv.placements) == 2
    assert all(sum(p) == 8 for p in adv.placements)
    assert adv.gain_pct > 0.0  # the flip is worth migrating for
    assert adv.placements[0] != adv.placements[1]
    assert adv.total_work > adv.static_work
    assert snap["tier_counts"]["schedule"] == 1

    # second ask is a cache hit returning the same object
    again = svc.query_schedule(E5_2630_V3, _flip_phases(), 8, model=model)
    assert again is adv
    assert svc.metrics.snapshot()["tier_counts"]["cache"] >= 1
    svc.close()


def test_submit_schedule_dedupes_inflight():
    from repro.core.numa.temporal import MigrationModel

    svc = AdvisorService()
    model = MigrationModel(thread_move_bytes=1e6, page_move_bytes=1e6)
    futures = [
        svc.submit_schedule(E5_2630_V3, _flip_phases(), 8, model=model)
        for _ in range(4)
    ]
    answers = [f.result(timeout=300) for f in futures]
    snap = svc.metrics.snapshot()
    svc.close()
    assert all(a is answers[0] for a in answers)  # computed once
    assert snap["tier_counts"]["schedule"] + snap["tier_counts"]["cache"] >= 1


def test_schedule_canonicalization_merges_float_noise():
    svc = AdvisorService()
    a = QuerySignature((1 / 3, 1 / 3, 0.1), (0.2, 0.2, 0.2))
    b = QuerySignature((0.33333333333, 0.333333333401, 0.1), (0.2, 0.2, 0.2))
    first = svc.query_schedule(E5_2630_V3, [(a, 1.0)], 8, timeout=300)
    second = svc.query_schedule(E5_2630_V3, [(b, 1.0000000004)], 8)
    svc.close()
    assert second is first


def test_query_schedule_rejects_empty_phases():
    svc = AdvisorService()
    with pytest.raises(ValueError):
        svc.query_schedule(E5_2630_V3, [], 8)
    svc.close()
