"""Pallas kernel sweeps: shapes x dtypes, allclose vs the pure-jnp oracles
(interpret mode — the kernel body executes in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import mha_flash
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.kernel import selective_scan
from repro.kernels.mamba_scan.ops import ssm_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref


def _qkv(key, B, H, Kv, Sq, Skv, dh, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = (jax.random.normal(k1, (B, H, Sq, dh)) * 0.5).astype(dtype)
    k = (jax.random.normal(k2, (B, Kv, Skv, dh)) * 0.5).astype(dtype)
    v = (jax.random.normal(k3, (B, Kv, Skv, dh)) * 0.5).astype(dtype)
    return q, k, v


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5), jnp.bfloat16: dict(atol=2e-2, rtol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Kv,Sq,Skv,dh,block",
    [
        (1, 4, 4, 128, 128, 64, 64),  # MHA square
        (2, 8, 2, 128, 128, 64, 64),  # GQA 4:1
        (1, 4, 1, 64, 256, 32, 64),  # MQA, Skv > Sq (right-aligned)
        (1, 2, 2, 256, 256, 128, 128),  # wide head
    ],
)
def test_flash_attention_sweep(dtype, B, H, Kv, Sq, Skv, dh, block):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, H, Kv, Sq, Skv, dh, dtype)
    got = flash_attention(
        q, k, v, causal=True, block_q=block, block_kv=block, interpret=True
    )
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 4, 2, 128, 128, 64, jnp.float32)
    got = flash_attention(
        q, k, v, causal=True, window=window, block_q=64, block_kv=64, interpret=True
    )
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_attention_softcap():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 2, 2, 64, 64, 32, jnp.float32)
    got = flash_attention(
        q, k, v, causal=True, logit_cap=30.0, block_q=32, block_kv=32, interpret=True
    )
    want = attention_ref(q, k, v, causal=True, logit_cap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 2, 2, 64, 64, 32, jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=32, block_kv=32, interpret=True)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_matches_model_xla_path():
    """The kernel agrees with the model's blocked-XLA attention too."""
    from repro.models.attention import blocked_attention

    B, H, Kv, S, dh = 2, 8, 4, 128, 32
    q, k, v = _qkv(jax.random.PRNGKey(4), B, H, Kv, S, S, dh, jnp.float32)
    got = mha_flash(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True,
        interpret=True,
    )
    want = blocked_attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True,
        block_q=64,
        block_kv=64,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------


def _ssm_inputs(key, B, S, di, n):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    dt = jax.nn.softplus(jax.random.normal(k1, (B, S, di)) - 2.0)
    a = -jnp.exp(jax.random.normal(k2, (di, n)) * 0.3)
    b = jax.random.normal(k3, (B, S, n)) * 0.5
    c = jax.random.normal(k4, (B, S, n)) * 0.5
    x = jax.random.normal(k5, (B, S, di))
    return dt, a, b, c, x


@pytest.mark.parametrize(
    "B,S,di,n,block_d,chunk",
    [
        (1, 64, 32, 8, 16, 32),
        (2, 128, 64, 16, 32, 64),
        (1, 96, 48, 16, 16, 32),  # chunk not dividing S/2 exercises chunk=32x3
        (2, 64, 128, 4, 128, 16),
    ],
)
def test_selective_scan_sweep(B, S, di, n, block_d, chunk):
    dt, a, b, c, x = _ssm_inputs(jax.random.PRNGKey(0), B, S, di, n)
    got = selective_scan(dt, a, b, c, x, block_d=block_d, chunk=chunk, interpret=True)
    want, _ = selective_scan_ref(dt, a, b, c, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_ssm_scan_wrapper_matches_model_chunked_scan():
    """Kernel vs the model's associative chunked scan (mamba.py)."""
    from repro.models.mamba import _chunk_scan

    B, S, di, n = 1, 64, 32, 8
    dt, a, b, c, x = _ssm_inputs(jax.random.PRNGKey(1), B, S, di, n)
    got = ssm_scan(dt, a, b, c, x, interpret=True)

    da = jnp.exp(dt[..., None] * a[None, None])
    dbx = (dt * x)[..., None] * b[:, :, None, :]
    hs, _ = _chunk_scan(da, dbx, jnp.zeros((B, di, n)))
    want = jnp.einsum("bsdn,bsn->bsd", hs, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_selective_scan_dtype_bf16_inputs():
    B, S, di, n = 1, 64, 32, 8
    dt, a, b, c, x = _ssm_inputs(jax.random.PRNGKey(2), B, S, di, n)
    got = ssm_scan(
        dt.astype(jnp.bfloat16), a, b.astype(jnp.bfloat16),
        c.astype(jnp.bfloat16), x.astype(jnp.bfloat16), interpret=True,
    )
    want, _ = selective_scan_ref(
        dt.astype(jnp.bfloat16).astype(jnp.float32), a,
        b.astype(jnp.bfloat16).astype(jnp.float32),
        c.astype(jnp.bfloat16).astype(jnp.float32),
        x.astype(jnp.bfloat16).astype(jnp.float32),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-2, rtol=2e-2)
