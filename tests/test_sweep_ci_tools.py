"""The CI sweep tooling itself: the regression gate script
(``benchmarks/check_sweep_regression.py`` — previously untested) and the
artifact-history trend dashboard (``benchmarks/sweep_dashboard.py``).

The gate's contract under test: pass when errors hold, fail on error
regression beyond tolerance, fail when a baseline sweep is missing from
the new artifact, ignore sweeps the baseline does not know (new machines
land in the artifact first, the baseline is updated by hand), and the
throughput floor only bites when explicitly enabled.
"""

import importlib.util
import json
import sys
from pathlib import Path

import pytest


def _load_benchmark(name):
    path = Path(__file__).resolve().parents[1] / "benchmarks" / f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def gate():
    return _load_benchmark("check_sweep_regression")


@pytest.fixture(scope="module")
def dashboard():
    return _load_benchmark("sweep_dashboard")


def _rec(sweep, err, pps=1000.0):
    return {"sweep": sweep, "median_error_pct": err, "placements_per_sec": pps}


# ---------------------------------------------------------------------------
# check_sweep_regression.check
# ---------------------------------------------------------------------------


def test_gate_passes_within_tolerance(gate):
    base = [_rec("a", 0.05), _rec("b", 0.10)]
    new = [_rec("a", 0.20), _rec("b", 0.05)]  # +0.15 <= 0.25 tolerance
    assert gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0) == []


def test_gate_fails_on_error_regression(gate):
    base = [_rec("a", 0.05)]
    new = [_rec("a", 0.45)]
    failures = gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "regressed" in failures[0]


def test_gate_fails_when_baseline_sweep_missing_from_artifact(gate):
    base = [_rec("a", 0.05), _rec("gone", 0.05)]
    new = [_rec("a", 0.05)]
    failures = gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "missing" in failures[0]


def test_gate_ignores_new_machine_keys(gate):
    """A sweep present only in the new artifact (a machine added this PR)
    must not fail the gate — the committed baseline is extended by hand
    once the new sweep's numbers settle."""
    base = [_rec("a", 0.05)]
    new = [_rec("a", 0.05), _rec("brand-new-machine", 9.99)]
    assert gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0) == []


def test_gate_throughput_floor_only_when_enabled(gate):
    base = [_rec("a", 0.05, pps=1000.0)]
    new = [_rec("a", 0.05, pps=100.0)]
    assert gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0) == []
    failures = gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.5)
    assert len(failures) == 1 and "throughput" in failures[0]


def test_gate_main_pass_and_fail_exit_codes(gate, tmp_path, monkeypatch):
    base_p = tmp_path / "base.json"
    new_p = tmp_path / "new.json"
    base_p.write_text(json.dumps([_rec("a", 0.05)]))
    new_p.write_text(json.dumps([_rec("a", 0.05)]))
    monkeypatch.setattr(
        sys, "argv", ["check", str(new_p), "--baseline", str(base_p)]
    )
    gate.main()  # passes: no SystemExit
    new_p.write_text(json.dumps([_rec("a", 5.0)]))
    with pytest.raises(SystemExit) as exc:
        gate.main()
    assert exc.value.code == 1


def test_gate_main_concatenates_multiple_artifacts(gate, tmp_path, monkeypatch):
    """CI passes the placement sweep AND the mesh-advisor artifact in one
    invocation; every baseline sweep just has to appear in *some* of them."""
    base_p = tmp_path / "base.json"
    base_p.write_text(json.dumps([_rec("a", 0.05), _rec("mesh", 0.0)]))
    sweep_p = tmp_path / "sweep.json"
    sweep_p.write_text(json.dumps([_rec("a", 0.05)]))
    mesh_p = tmp_path / "mesh.json"
    mesh_p.write_text(json.dumps([_rec("mesh", 0.0)]))
    monkeypatch.setattr(
        sys,
        "argv",
        ["check", str(sweep_p), str(mesh_p), "--baseline", str(base_p)],
    )
    gate.main()  # both sweeps found across the two artifacts: passes
    monkeypatch.setattr(
        sys, "argv", ["check", str(sweep_p), "--baseline", str(base_p)]
    )
    with pytest.raises(SystemExit):  # mesh record now missing
        gate.main()


def test_gate_absolute_floor_from_baseline_record(gate):
    base = [dict(_rec("a", 0.05, pps=1000.0), min_placements_per_sec=800)]
    ok = [_rec("a", 0.05, pps=900.0)]
    slow = [_rec("a", 0.05, pps=500.0)]
    assert gate.check(ok, base, error_tolerance=0.25, min_pps_ratio=0.0) == []
    failures = gate.check(slow, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "floor" in failures[0]


def _search_rec(sweep, regret=0.0, tts=0.1, **extra):
    return {
        "sweep": sweep,
        "regret_pct": regret,
        "regret_vs": "exhaustive",
        "time_to_solution_s": tts,
        **extra,
    }


def test_gate_search_records_pass_within_limits(gate):
    base = [
        _search_rec("search-a", max_regret_pct=1.0, max_time_to_solution_s=1.0)
    ]
    new = [_search_rec("search-a", regret=0.9, tts=0.95)]
    assert gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0) == []


def test_gate_search_records_fail_on_regret_and_time(gate):
    base = [
        _search_rec("search-a", max_regret_pct=1.0, max_time_to_solution_s=1.0)
    ]
    bad_regret = [_search_rec("search-a", regret=1.5, tts=0.1)]
    failures = gate.check(
        bad_regret, base, error_tolerance=0.25, min_pps_ratio=0.0
    )
    assert len(failures) == 1 and "regret" in failures[0]
    slow = [_search_rec("search-a", regret=0.0, tts=2.5)]
    failures = gate.check(slow, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "time-to-solution" in failures[0]


def test_gate_mixes_sweep_and_search_records(gate):
    """One baseline holds both record kinds (as the committed
    sweep_baseline.json now does); each is gated by its own rule and a
    search record never trips the error/throughput checks."""
    base = [
        dict(_rec("a", 0.05, pps=1000.0), min_placements_per_sec=800),
        _search_rec("search-a", max_regret_pct=1.0, max_time_to_solution_s=1.0),
    ]
    new = [_rec("a", 0.05, pps=900.0), _search_rec("search-a")]
    assert gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0) == []
    failures = gate.check(
        [_rec("a", 0.05, pps=900.0), _search_rec("search-a", regret=2.0)],
        base,
        error_tolerance=0.25,
        min_pps_ratio=0.0,
    )
    assert len(failures) == 1 and "regret" in failures[0]


def _serve_rec(sweep, qps=1000.0, p99=1.0, **extra):
    return {"sweep": sweep, "queries": 100, "qps": qps, "p99_ms": p99, **extra}


def _serve_base(sweep, **extra):
    return _serve_rec(
        sweep, min_qps=500.0, max_p99_ms=10.0, **extra
    )


def test_gate_serve_records_pass_within_limits(gate):
    base = [_serve_base("serve-a", max_retraces=0, min_mean_batch_size=2.0)]
    new = [_serve_rec("serve-a", qps=600.0, p99=9.0, retraces=0,
                      mean_batch_size=4.0)]
    assert gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0) == []


def test_gate_serve_records_fail_below_qps_floor(gate):
    base = [_serve_base("serve-a")]
    new = [_serve_rec("serve-a", qps=100.0)]
    failures = gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "qps below the committed floor" in failures[0]


def test_gate_serve_records_fail_above_p99_ceiling(gate):
    base = [_serve_base("serve-a")]
    new = [_serve_rec("serve-a", p99=25.0)]
    failures = gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "p99" in failures[0]


def test_gate_serve_records_fail_on_any_retrace(gate):
    """The committed mixed-stream record pins ``max_retraces: 0`` — a
    single steady-state jit retrace is a shape leak and must fail CI."""
    base = [_serve_base("serve-mixed", max_retraces=0)]
    ok = [_serve_rec("serve-mixed", retraces=0)]
    assert gate.check(ok, base, error_tolerance=0.25, min_pps_ratio=0.0) == []
    bad = [_serve_rec("serve-mixed", retraces=1)]
    failures = gate.check(bad, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "retraces" in failures[0]


def test_gate_serve_records_fail_below_mean_batch_floor(gate):
    base = [_serve_base("serve-miss", min_mean_batch_size=2.0)]
    bad = [_serve_rec("serve-miss", mean_batch_size=1.1)]
    failures = gate.check(bad, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "coalescing" in failures[0]


def test_gate_serve_record_never_trips_other_rules(gate):
    """A serve record carries neither median_error_pct nor regret_pct —
    it must be dispatched to the serve branch, not KeyError in another."""
    base = [
        dict(_rec("a", 0.05, pps=1000.0), min_placements_per_sec=800),
        _search_rec("search-a", max_regret_pct=1.0, max_time_to_solution_s=1.0),
        _serve_base("serve-a"),
    ]
    new = [
        _rec("a", 0.05, pps=900.0),
        _search_rec("search-a"),
        _serve_rec("serve-a", qps=600.0),
    ]
    assert gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0) == []


def test_committed_baseline_cache_hit_floor_is_10x_miss_floor():
    """ISSUE-8 acceptance: the committed cache-hit qps floor must sit at
    least 10x above the miss-path floor (the answer cache has to be worth
    an order of magnitude)."""
    baseline = json.loads(
        (Path(__file__).resolve().parents[1] / "benchmarks"
         / "sweep_baseline.json").read_text()
    )
    by_sweep = {rec["sweep"]: rec for rec in baseline}
    hit = by_sweep["advisor-serve cache-hit"]
    miss = by_sweep["advisor-serve miss-batched"]
    assert hit["min_qps"] >= 10 * miss["min_qps"]
    assert by_sweep["advisor-serve mixed"]["max_retraces"] == 0


def test_gate_main_missing_baseline_file(gate, tmp_path, monkeypatch):
    new_p = tmp_path / "new.json"
    new_p.write_text(json.dumps([_rec("a", 0.05)]))
    monkeypatch.setattr(
        sys,
        "argv",
        ["check", str(new_p), "--baseline", str(tmp_path / "nope.json")],
    )
    with pytest.raises(FileNotFoundError):
        gate.main()


# ---------------------------------------------------------------------------
# sweep_dashboard
# ---------------------------------------------------------------------------


def test_sparkline_shapes(dashboard):
    assert dashboard.sparkline([]) == ""
    assert dashboard.sparkline([1.0, 1.0, 1.0]) == "▄▄▄"
    up = dashboard.sparkline([0.0, 0.5, 1.0])
    assert up[0] == "▁" and up[-1] == "█" and len(up) == 3


def test_load_history_orders_skips_garbage_and_appends_current(
    dashboard, tmp_path
):
    hist = tmp_path / "hist"
    for stamp, err in (("2026-01-02__run-b", 0.2), ("2026-01-01__run-a", 0.1)):
        d = hist / stamp
        d.mkdir(parents=True)
        (d / "placement_sweep.json").write_text(json.dumps([_rec("a", err)]))
    (hist / "2026-01-02__run-b" / "broken.json").write_text("{nope")
    (hist / "2026-01-03__empty").mkdir()
    current = tmp_path / "current.json"
    current.write_text(json.dumps([_rec("a", 0.3), _rec("new", 1.0)]))

    runs = dashboard.load_history(hist, current)
    assert [r["run"] for r in runs] == [
        "2026-01-01__run-a", "2026-01-02__run-b", "current",
    ]
    series = dashboard.aggregate(runs)
    assert series["a"]["errors"] == [0.1, 0.2, 0.3]
    assert series["new"]["errors"] == [1.0]  # machines added later: short series

    md = dashboard.render_markdown(series)
    assert "| a | 3 | 0.3000 | +0.1000 |" in md
    assert "| new | 1 | 1.0000 |" in md
    assert dashboard.sparkline([0.1, 0.2, 0.3]) in md


def test_load_history_merges_multiple_currents(dashboard, tmp_path):
    """This run's several artifacts (placement sweep + mesh advisor) merge
    into ONE trailing "current" point, not separate runs."""
    hist = tmp_path / "hist"
    d = hist / "2026-01-01__run-a"
    d.mkdir(parents=True)
    (d / "placement_sweep.json").write_text(json.dumps([_rec("a", 0.1)]))
    sweep = tmp_path / "sweep.json"
    sweep.write_text(json.dumps([_rec("a", 0.2)]))
    mesh = tmp_path / "mesh.json"
    mesh.write_text(json.dumps([_rec("mesh", 0.0)]))

    runs = dashboard.load_history(hist, [sweep, mesh, tmp_path / "absent.json"])
    assert [r["run"] for r in runs] == ["2026-01-01__run-a", "current"]
    series = dashboard.aggregate(runs)
    assert series["a"]["errors"] == [0.1, 0.2]
    assert series["mesh"]["errors"] == [0.0]


def test_load_history_without_history_dir(dashboard, tmp_path):
    """First run of a fresh repo: no prior artifacts, only the current
    sweep — the dashboard still renders."""
    current = tmp_path / "current.json"
    current.write_text(json.dumps([_rec("a", 0.5)]))
    runs = dashboard.load_history(tmp_path / "does-not-exist", current)
    assert len(runs) == 1
    md = dashboard.render_markdown(dashboard.aggregate(runs))
    assert "| a | 1 | 0.5000 |" in md


def test_render_markdown_empty(dashboard):
    md = dashboard.render_markdown({})
    assert "no sweep artifacts" in md


def test_dashboard_trends_search_records(dashboard, tmp_path):
    hist = tmp_path / "hist"
    d = hist / "2026-01-01__run-a"
    d.mkdir(parents=True)
    (d / "placement_search.json").write_text(
        json.dumps([_search_rec("search-a", regret=0.0, tts=0.5)])
    )
    current = tmp_path / "current.json"
    current.write_text(
        json.dumps(
            [_rec("a", 0.1), _search_rec("search-a", regret=0.2, tts=0.4)]
        )
    )
    runs = dashboard.load_history(hist, current)
    series = dashboard.aggregate(runs)
    assert series["search-a"]["regret"] == [0.0, 0.2]
    assert series["search-a"]["tts"] == [0.5, 0.4]
    assert series["a"]["errors"] == [0.1]
    md = dashboard.render_markdown(series)
    assert "Placement search" in md
    assert "| search-a | 2 | 0.2000 | 0.2000 | 0.400 |" in md
    # the sweep table must not pick up the search record
    assert "| search-a | 1 |" not in md


def test_dashboard_trends_serve_records(dashboard, tmp_path):
    hist = tmp_path / "hist"
    d = hist / "2026-01-01__run-a"
    d.mkdir(parents=True)
    (d / "advisor_serve.json").write_text(
        json.dumps([_serve_rec("advisor-serve cache-hit", qps=50000.0, p99=0.1)])
    )
    current = tmp_path / "current.json"
    current.write_text(
        json.dumps([
            _rec("a", 0.1),
            _serve_rec("advisor-serve cache-hit", qps=100000.0, p99=0.05),
        ])
    )
    runs = dashboard.load_history(hist, current)
    series = dashboard.aggregate(runs)
    assert series["advisor-serve cache-hit"]["qps"] == [50000.0, 100000.0]
    assert series["advisor-serve cache-hit"]["p99"] == [0.1, 0.05]
    assert series["a"]["errors"] == [0.1]
    md = dashboard.render_markdown(series)
    assert "Advisor service" in md
    # fourth table row: qps latest, x2.0 vs first run, p99 latest + worst
    assert "| advisor-serve cache-hit | 2 | 100,000 | x2.0 | 0.050 | 0.100 |" in md
    # neither the sweep nor the search table picks up the serve record
    assert "| advisor-serve cache-hit | 1 |" not in md


def _schedule_rec(sweep, *, gain=1.0, tts=0.1, **extra):
    return dict(
        sweep=sweep, machine="m", n_nodes=2, n_threads=8, phases=2,
        gain_pct=gain, time_to_solution_s=tts, **extra,
    )


def test_gate_schedule_records_pass_and_fail_on_gain_floor(gate):
    base = [_schedule_rec("sched-a", min_static_gain_pct=0.5,
                          max_time_to_solution_s=2.0)]
    ok = [_schedule_rec("sched-a", gain=0.9)]
    assert gate.check(ok, base, error_tolerance=0.25, min_pps_ratio=0.0) == []
    bad = [_schedule_rec("sched-a", gain=0.1)]
    failures = gate.check(bad, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "time axis lost" in failures[0]


def test_gate_schedule_records_fail_when_static_ceiling_broken(gate):
    """The prohibitive-migration record commits max_gain_pct: 0 — the
    scheduler choosing to move despite priced-out migration is a cost
    model bug and must fail CI."""
    base = [_schedule_rec("sched-static", min_static_gain_pct=0.0,
                          max_gain_pct=0.0, max_time_to_solution_s=2.0)]
    ok = [_schedule_rec("sched-static", gain=0.0)]
    assert gate.check(ok, base, error_tolerance=0.25, min_pps_ratio=0.0) == []
    bad = [_schedule_rec("sched-static", gain=0.2)]
    failures = gate.check(bad, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "prohibitive" in failures[0]


def test_gate_schedule_records_fail_above_time_cap(gate):
    base = [_schedule_rec("sched-a", min_static_gain_pct=0.5,
                          max_time_to_solution_s=2.0)]
    bad = [_schedule_rec("sched-a", tts=10.0)]
    failures = gate.check(bad, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "time-to-solution" in failures[0]


def _chaos_rec(sweep="resil-chaos", **over):
    rec = {
        "sweep": sweep, "queries": 100, "qps": 100.0, "degraded_rate": 0.1,
        "hangs": 0, "all_tagged": True, "search_retry_ok": True,
    }
    rec.update(over)
    return rec


def _chaos_base(**over):
    return _chaos_rec(
        min_qps=25.0, max_degraded_rate=0.5, max_hangs=0, **over
    )


def test_gate_resilience_chaos_pass_and_fail(gate):
    base = [_chaos_base()]
    ok = [_chaos_rec(qps=50.0, degraded_rate=0.3)]
    assert gate.check(ok, base, error_tolerance=0.25, min_pps_ratio=0.0) == []
    bad = [_chaos_rec(degraded_rate=0.9)]
    failures = gate.check(bad, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "degraded_rate" in failures[0]
    hung = [_chaos_rec(hangs=1)]
    failures = gate.check(hung, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "hangs" in failures[0]
    slow = [_chaos_rec(qps=5.0)]
    failures = gate.check(slow, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "min_qps" in failures[0]


def test_gate_resilience_chaos_fails_on_untagged_or_surfaced_fault(gate):
    """The chaos record's boolean contracts: every answer fidelity-tagged
    and the search-tier query answered exact through retries."""
    base = [_chaos_base()]
    untagged = [_chaos_rec(all_tagged=False)]
    failures = gate.check(
        untagged, base, error_tolerance=0.25, min_pps_ratio=0.0
    )
    assert len(failures) == 1 and "all_tagged" in failures[0]
    surfaced = [_chaos_rec(search_retry_ok=False)]
    failures = gate.check(
        surfaced, base, error_tolerance=0.25, min_pps_ratio=0.0
    )
    assert len(failures) == 1 and "search_retry_ok" in failures[0]


def test_gate_resilience_recovery_nan_means_never_recovered(gate):
    """recovery_s = NaN encodes "never answered exact again" — it must
    FAIL the ceiling, not slip through a NaN comparison."""
    base = [{"sweep": "resil-rec", "recovery_s": 0.1, "max_recovery_s": 10.0}]
    ok = [{"sweep": "resil-rec", "recovery_s": 2.0}]
    assert gate.check(ok, base, error_tolerance=0.25, min_pps_ratio=0.0) == []
    never = [{"sweep": "resil-rec", "recovery_s": float("nan")}]
    failures = gate.check(never, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1 and "recovery_s" in failures[0]
    slow = [{"sweep": "resil-rec", "recovery_s": 60.0}]
    failures = gate.check(slow, base, error_tolerance=0.25, min_pps_ratio=0.0)
    assert len(failures) == 1


def _swap_rec(**over):
    rec = {
        "sweep": "resil-swap", "swaps": 1, "rollbacks": 1,
        "torn_reads": 0, "nan_rejected": 4,
    }
    rec.update(over)
    return rec


def test_gate_resilience_hot_swap_exact_counts_and_torn_reads(gate):
    base = [_swap_rec(
        expected_swaps=1, expected_rollbacks=1, max_torn_reads=0,
        min_nan_rejected=1,
    )]
    assert gate.check(
        [_swap_rec()], base, error_tolerance=0.25, min_pps_ratio=0.0
    ) == []
    # exact-count semantics: too MANY swaps fails just like too few
    for bad in (
        _swap_rec(swaps=2), _swap_rec(rollbacks=0),
        _swap_rec(torn_reads=1), _swap_rec(nan_rejected=0),
    ):
        failures = gate.check(
            [bad], base, error_tolerance=0.25, min_pps_ratio=0.0
        )
        assert len(failures) == 1, bad


def test_gate_resilience_record_never_trips_serve_branch(gate):
    """The chaos record carries min_qps AND resilience keys — dispatch
    order (resilience before serve) must route it to the resilience
    branch, where a missing p99_ms is fine."""
    base = [
        _chaos_base(),
        _serve_base("serve-a"),
    ]
    new = [_chaos_rec(qps=50.0), _serve_rec("serve-a", qps=600.0)]
    assert gate.check(new, base, error_tolerance=0.25, min_pps_ratio=0.0) == []


def test_committed_baseline_resilience_records():
    """ISSUE-10 acceptance: the committed baseline pins zero hangs, zero
    torn reads, exactly one swap and one rollback, and NaN rejection."""
    baseline = json.loads(
        (Path(__file__).resolve().parents[1] / "benchmarks"
         / "sweep_baseline.json").read_text()
    )
    by_sweep = {rec["sweep"]: rec for rec in baseline}
    chaos = by_sweep["serve-resilience chaos-mixed"]
    assert chaos["max_hangs"] == 0 and chaos["all_tagged"] is True
    swap = by_sweep["serve-resilience hot-swap"]
    assert swap["max_torn_reads"] == 0
    assert swap["expected_swaps"] == 1 and swap["expected_rollbacks"] == 1
    assert swap["min_nan_rejected"] >= 1
    assert by_sweep["serve-resilience recovery"]["max_recovery_s"] > 0


def test_dashboard_trends_schedule_records(dashboard, tmp_path):
    hist = tmp_path / "hist"
    d = hist / "2026-01-01__run-a"
    d.mkdir(parents=True)
    (d / "schedule_search.json").write_text(
        json.dumps([_schedule_rec("sched-a", gain=0.8, tts=0.05)])
    )
    current = tmp_path / "current.json"
    current.write_text(
        json.dumps([_rec("a", 0.1), _schedule_rec("sched-a", gain=1.0,
                                                  tts=0.04)])
    )
    runs = dashboard.load_history(hist, current)
    series = dashboard.aggregate(runs)
    assert series["sched-a"]["gain"] == [0.8, 1.0]
    assert series["sched-a"]["stts"] == [0.05, 0.04]
    md = dashboard.render_markdown(series)
    assert "Schedule search" in md
    assert "| sched-a | 2 | 1.0000 | 1.0000 | 0.040 |" in md
    # the sweep table must not pick up the schedule record
    assert "| sched-a | 1 |" not in md


def test_dashboard_trends_resilience_records(dashboard, tmp_path):
    hist = tmp_path / "hist"
    d = hist / "2026-01-01__run-a"
    d.mkdir(parents=True)
    (d / "serve_resilience.json").write_text(json.dumps([
        _chaos_rec(degraded_rate=0.2),
        {"sweep": "resil-rec", "recovery_s": 0.5},
        _swap_rec(torn_reads=0),
    ]))
    current = tmp_path / "current.json"
    current.write_text(json.dumps([
        _chaos_rec(degraded_rate=0.1),
        {"sweep": "resil-rec", "recovery_s": 0.3},
        _swap_rec(torn_reads=0),
    ]))
    runs = dashboard.load_history(hist, current)
    series = dashboard.aggregate(runs)
    # chaos record carries qps too; resilience branch must win dispatch
    assert series["resil-chaos"]["resilience"] == [0.2, 0.1]
    assert series["resil-chaos"]["metric"] == "degraded_rate"
    assert series["resil-rec"]["resilience"] == [0.5, 0.3]
    assert series["resil-rec"]["metric"] == "recovery_s"
    assert series["resil-swap"]["metric"] == "torn_reads"
    md = dashboard.render_markdown(series)
    assert "Serve resilience" in md
    assert "| resil-chaos | 2 | degraded_rate | 0.1 | 0.2 |" in md
    # no qps table row for the chaos record
    assert "Advisor service" not in md


@pytest.fixture()
def docgate():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "check_docstrings",
        Path(__file__).resolve().parents[1]
        / "benchmarks" / "check_docstrings.py",
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docstring_gate_flags_public_only(docgate, tmp_path):
    src = tmp_path / "mod.py"
    src.write_text(
        '"""Module doc."""\n'
        "def documented():\n"
        '    """Has one."""\n'
        "def naked(): pass\n"
        "def _private(): pass\n"
        "class Thing:\n"
        '    """Doc."""\n'
        "    def method(self): pass\n"
        "    def __dunder__(self): pass\n"
        "    def ok(self):\n"
        '        """Doc."""\n'
    )
    findings = docgate.check_file(src)
    assert len(findings) == 2
    assert any("'naked'" in f for f in findings)
    assert any("'Thing.method'" in f for f in findings)


def test_docstring_gate_flags_missing_module_doc(docgate, tmp_path):
    src = tmp_path / "bare.py"
    src.write_text("x = 1\n")
    findings = docgate.check_file(src)
    assert findings == [f"{src}:1: public module has no docstring"]


def test_docstring_gate_passes_on_shipped_packages(docgate):
    """The committed public API stays fully documented — the same
    invocation CI runs."""
    root = Path(__file__).resolve().parents[1]
    assert docgate.check_paths(
        [root / "src/repro/core/numa", root / "src/repro/serve"]
    ) == []
