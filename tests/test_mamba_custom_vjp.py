"""The §Perf a5 custom VJP: backward of the linear recurrence must match
autodiff-through-associative_scan exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.mamba import _combine, _linear_scan


def _naive(da, dbx, h0):
    cum_a, cum_b = jax.lax.associative_scan(_combine, (da, dbx), axis=1)
    return cum_a * h0[:, None] + cum_b


def _inputs(key, B=2, c=16, d=4, n=3):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    da = jax.nn.sigmoid(jax.random.normal(k1, (B, c, d, n)))
    dbx = jax.random.normal(k2, (B, c, d, n)) * 0.3
    h0 = jax.random.normal(k3, (B, d, n))
    w = jax.random.normal(k4, (B, c, d, n))
    return da, dbx, h0, w


def test_forward_matches():
    da, dbx, h0, _ = _inputs(jax.random.PRNGKey(0))
    np.testing.assert_allclose(
        np.asarray(_linear_scan(da, dbx, h0)),
        np.asarray(_naive(da, dbx, h0)),
        rtol=1e-5,
    )


def test_gradients_match_autodiff():
    da, dbx, h0, w = _inputs(jax.random.PRNGKey(1))
    f1 = lambda *a: (_naive(*a) * w).sum()
    f2 = lambda *a: (_linear_scan(*a) * w).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(da, dbx, h0)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(da, dbx, h0)
    for a, b, name in zip(g1, g2, ["da", "dbx", "h0"]):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-5, err_msg=name
        )


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 1000),
    c=st.sampled_from([4, 8, 32]),
)
def test_gradients_match_property(seed, c):
    da, dbx, h0, w = _inputs(jax.random.PRNGKey(seed), B=1, c=c, d=3, n=2)
    f1 = lambda *a: (_naive(*a) * w).sum()
    f2 = lambda *a: (_linear_scan(*a) * w).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(da, dbx, h0)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(da, dbx, h0)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-5)
