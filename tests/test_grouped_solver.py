"""Group-collapsed saturation solver vs the per-thread reference.

The grouped ``simulate`` hot path collapses (node, rate, bytes/instr)
equivalence classes of threads into weighted rows; these tests pin its
exact equivalence (<= 1e-6) with ``simulate_reference`` — rates, flows
and counters — across every preset, the benchmark suite (violators
included), random placements and noise keys, plus the static class
machinery itself (partition inference, multiplicities, jit/vmap paths
and differentiability through ``caps``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.numa import (
    E5_2630_V3,
    E5_2630_V3_MIXED_DIMM,
    E5_2630_V3_THROTTLED,
    E5_2699_V3,
    E5_2699_V3_SNC2,
    E7_4830_V3,
    E7_8860_V3,
    machine_caps,
    make_machine,
    mixed_workload,
    simulate,
    simulate_reference,
    thread_class_starts,
)
from repro.core.numa.benchmarks import benchmark_workload
from repro.core.numa.simulator import (
    _group_multiplicities,
    _group_resource_tensor,
    _mix_rows,
    _resource_tensor,
    _thread_nodes,
    class_starts_from_arrays,
)
from repro.core.numa.workload import violator_workload

ALL_PRESETS = [
    E5_2630_V3,
    E5_2699_V3,
    E7_4830_V3,
    E7_8860_V3,
    E5_2699_V3_SNC2,
    E5_2630_V3_THROTTLED,
    E5_2630_V3_MIXED_DIMM,
]

RATE_TOL = 1e-6  # the tentpole's acceptance bound on |grouped - per-thread|


def _random_placement(machine, n_threads, rng):
    """A random feasible composition of n_threads over the machine's nodes."""
    s, cap = machine.n_nodes, machine.cores_per_node
    counts = np.zeros((s,), np.int64)
    for _ in range(n_threads):
        open_nodes = np.flatnonzero(counts < cap)
        counts[rng.choice(open_nodes)] += 1
    return jnp.asarray(counts, jnp.int32)


def _assert_equivalent(machine, wl, placement, **kwargs):
    a = simulate(machine, wl, placement, **kwargs)
    b = simulate_reference(machine, wl, placement, **kwargs)
    np.testing.assert_allclose(
        np.asarray(a.rates), np.asarray(b.rates), rtol=0, atol=RATE_TOL
    )
    for ga, gb in (
        (a.read_flows, b.read_flows),
        (a.write_flows, b.write_flows),
    ):
        np.testing.assert_allclose(
            np.asarray(ga), np.asarray(gb), rtol=1e-5, atol=1e-4
        )
    for ga, gb in zip(jax.tree.leaves(a.sample), jax.tree.leaves(b.sample)):
        np.testing.assert_allclose(
            np.asarray(ga), np.asarray(gb), rtol=1e-5, atol=1e-4
        )
    np.testing.assert_allclose(
        float(a.throughput), float(b.throughput), rtol=1e-5
    )


# ---------------------------------------------------------------------------
# equivalence on every preset (the acceptance bound)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", ALL_PRESETS, ids=lambda m: m.name)
@pytest.mark.parametrize("bench", ["CG", "Swim", "EP", "Page rank"])
def test_grouped_matches_reference_on_all_presets(machine, bench):
    rng = np.random.default_rng(hash((machine.name, bench)) % 2**32)
    n = 2 * machine.cores_per_node
    n -= n % machine.n_nodes
    wl = benchmark_workload(bench, n)
    for trial in range(3):
        placement = _random_placement(machine, n, rng)
        _assert_equivalent(machine, wl, placement)


@pytest.mark.parametrize("machine", ALL_PRESETS, ids=lambda m: m.name)
def test_grouped_matches_reference_with_noise_and_background(machine):
    """Noise multiplies the solved flows, so equal solver outputs under
    the same key must stay equal through the noisy counter path."""
    n = machine.n_nodes * 2
    wl = benchmark_workload("NPO", n)
    placement = _random_placement(machine, n, np.random.default_rng(0))
    _assert_equivalent(
        machine, wl, placement,
        noise_std=0.02, background_bw=1e8, key=jax.random.PRNGKey(17),
    )


def test_grouped_matches_reference_under_jit_and_vmap():
    """The batch engine's exact shape: traced placements, static classes."""
    machine = E7_8860_V3
    wl = benchmark_workload("Page rank", 32)
    classes = thread_class_starts(wl)
    rng = np.random.default_rng(3)
    placements = jnp.stack([_random_placement(machine, 32, rng) for _ in range(8)])

    grouped = jax.jit(
        jax.vmap(
            lambda p: simulate(machine, wl, p, thread_classes=classes).rates
        )
    )(placements)
    reference = jax.jit(
        jax.vmap(lambda p: simulate_reference(machine, wl, p).rates)
    )(placements)
    np.testing.assert_allclose(
        np.asarray(grouped), np.asarray(reference), rtol=0, atol=RATE_TOL
    )


def test_grouped_differentiable_through_caps():
    """The calibration hook: gradients of a loss through simulate(...,
    caps=...) must flow and agree with the per-thread reference."""
    machine = E5_2699_V3_SNC2
    wl = mixed_workload(  # heavy enough that banks/links actually bind
        "heavy", 16, read_mix=(0.4, 0.2, 0.2), read_bpi=8.0, write_bpi=4.0
    )
    placement = jnp.asarray([5, 3, 4, 4], jnp.int32)
    caps0 = machine_caps(machine)
    classes = thread_class_starts(wl)

    def loss_grouped(caps):
        res = simulate(machine, wl, placement, caps=caps, thread_classes=classes)
        return (res.read_flows.sum() + res.write_flows.sum()) / 1e9

    def loss_reference(caps):
        res = simulate_reference(machine, wl, placement, caps=caps)
        return (res.read_flows.sum() + res.write_flows.sum()) / 1e9

    ga = jax.grad(loss_grouped)(caps0)
    gb = jax.grad(loss_reference)(caps0)
    assert np.isfinite(np.asarray(ga)).all()
    assert float(jnp.abs(ga).max()) > 0.0  # some capacity binds
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), rtol=1e-3, atol=1e-6)


# ---------------------------------------------------------------------------
# the static class machinery
# ---------------------------------------------------------------------------


def test_thread_class_starts_homogeneous_and_violator():
    assert thread_class_starts(mixed_workload("m", 16, read_mix=(0.2, 0.3, 0.1))) == (0,)
    assert thread_class_starts(benchmark_workload("Page rank", 16)) == (0, 8)
    # a batch shares the common refinement (union of boundaries)
    both = thread_class_starts(
        [mixed_workload("m", 16, read_mix=(0.2, 0.3, 0.1)),
         benchmark_workload("Page rank", 16)]
    )
    assert both == (0, 8)


def test_class_starts_from_arrays_runs_not_values():
    # equal values in non-adjacent runs stay separate classes (runs keep
    # the interval-overlap multiplicity computation valid)
    starts = class_starts_from_arrays([np.asarray([1.0, 2.0, 1.0, 1.0])])
    assert starts == (0, 1, 2)
    # scalars and single-thread arrays contribute no boundaries
    assert class_starts_from_arrays([np.asarray(3), np.asarray([5.0])]) == (0,)


def test_group_multiplicities_interval_overlap():
    # classes (0..3), (4..9); nodes of sizes [2, 5, 3]
    mult = np.asarray(
        _group_multiplicities((0, 4), 10, jnp.asarray([2, 5, 3], jnp.int32))
    )
    np.testing.assert_array_equal(mult, [[2, 2, 0], [0, 3, 3]])
    assert mult.sum() == 10


def test_simulate_rejects_invalid_thread_classes():
    wl = mixed_workload("m", 8, read_mix=(0.2, 0.3, 0.1))
    for bad in ((1, 4), (0, 4, 4), (0, 8)):
        with pytest.raises(ValueError):
            simulate(E5_2630_V3, wl, jnp.asarray([4, 4]), thread_classes=bad)


def test_group_resource_tensor_matches_per_thread_rows():
    """A group's unit usage row must equal the per-thread row of any of
    its members — same slab order, same remote/link charges."""
    machine = E7_8860_V3
    s = machine.n_nodes
    n = 16
    wl = benchmark_workload("CG", n)
    placement = jnp.asarray([4, 4, 2, 2, 2, 1, 1, 0], jnp.int32)
    node_of = _thread_nodes(placement, n)
    rate_of = machine.node_rates()[node_of]

    read_mix = _mix_rows(
        wl.read_static, wl.read_local, wl.read_per_thread,
        wl.static_socket, node_of, placement,
    )
    write_mix = _mix_rows(
        wl.write_static, wl.write_local, wl.write_per_thread,
        wl.static_socket, node_of, placement,
    )
    read_unit = rate_of[:, None] * wl.read_bpi[:, None] * read_mix
    write_unit = rate_of[:, None] * wl.write_bpi[:, None] * write_mix
    per_thread, caps_t = _resource_tensor(machine, read_unit, write_unit, node_of)

    res = simulate(machine, wl, placement)  # smoke: grouped path runs
    assert res.rates.shape == (n,)

    # grouped slab: CG is homogeneous -> one class, rows = nodes
    from repro.core.numa.simulator import _group_mix_rows

    g_read_mix = _group_mix_rows(
        wl.read_static[:1], wl.read_local[:1], wl.read_per_thread[:1],
        wl.static_socket, placement,
    )
    g_write_mix = _group_mix_rows(
        wl.write_static[:1], wl.write_local[:1], wl.write_per_thread[:1],
        wl.static_socket, placement,
    )
    g_read_unit = machine.node_rates()[None, :, None] * wl.read_bpi[0] * g_read_mix
    g_write_unit = machine.node_rates()[None, :, None] * wl.write_bpi[0] * g_write_mix
    grouped, caps_g = _group_resource_tensor(machine, g_read_unit, g_write_unit)
    np.testing.assert_array_equal(np.asarray(caps_t), np.asarray(caps_g))
    for t in range(n):
        k = int(node_of[t])
        np.testing.assert_allclose(
            np.asarray(grouped[k]), np.asarray(per_thread[t]), rtol=1e-6
        )


def test_violator_classes_get_distinct_rates():
    """The Page-rank violator's hot half must be able to saturate at a
    different rate than the cold half on the same node — grouping by
    (class, node) keeps that degree of freedom."""
    wl = violator_workload("pr", 8, read_bpi=6.0, hot_intensity=3.0)
    res = simulate(E5_2630_V3, wl, jnp.asarray([4, 4], jnp.int32))
    ref = simulate_reference(E5_2630_V3, wl, jnp.asarray([4, 4], jnp.int32))
    np.testing.assert_allclose(
        np.asarray(res.rates), np.asarray(ref.rates), rtol=0, atol=RATE_TOL
    )
    r = np.asarray(res.rates)
    assert not np.allclose(r[:4], r[4:])  # hot vs cold actually differ


# ---------------------------------------------------------------------------
# property sweep: random machines, workloads, placements, noise keys
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    preset=st.integers(0, len(ALL_PRESETS) - 1),
    n_threads=st.integers(1, 16),
    noise=st.sampled_from([0.0, 0.02]),
    key=st.integers(0, 2**16),
    hot=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_property_grouped_equals_reference(
    preset, n_threads, noise, key, hot, seed
):
    machine = ALL_PRESETS[preset]
    n_threads = min(n_threads, machine.n_nodes * machine.cores_per_node)
    rng = np.random.default_rng(seed)
    wl = violator_workload(
        "prop", n_threads,
        hot_fraction=hot,
        hot_intensity=1.0 + 2.0 * hot,
        static_socket=int(rng.integers(machine.n_nodes)),
    )
    placement = _random_placement(machine, n_threads, rng)
    _assert_equivalent(
        machine, wl, placement,
        noise_std=noise, key=jax.random.PRNGKey(key),
    )
