"""Integration tests for the §6 evaluation harness (paper-claim anchors)."""

import numpy as np
import pytest

from repro.core.numa import E5_2630_V3, E5_2699_V3
from repro.core.numa.benchmarks import benchmark_workload, suite_names
from repro.core.numa.evaluate import (
    evaluate_accuracy,
    evaluate_stability,
    evaluate_suite,
    sweep_placements,
)


def test_sweep_respects_one_thread_per_core():
    p = np.asarray(sweep_placements(E5_2630_V3, 8))
    assert p.sum(axis=1).tolist() == [8] * len(p)
    assert p.max() <= 8
    assert len(p) == 9  # 0..8 on socket 0


def test_suite_has_23_benchmarks():
    names = suite_names(include_violators=True)
    assert len(names) == 23  # paper Table 1
    assert "Page rank" in names and "EP" in names


def test_noise_free_accuracy_is_exact_for_representable_workloads():
    """With perfect counters and an in-model workload the fit+predict
    pipeline must reproduce measurements exactly — the correctness anchor
    behind the paper's Figure 17."""
    wl = benchmark_workload("Swim", 16)
    res = evaluate_accuracy(E5_2699_V3, wl)
    assert float(np.asarray(res.errors_combined).max()) < 1e-3


def test_violator_has_much_larger_error_than_representable():
    wl_good = benchmark_workload("Swim", 16)
    wl_bad = benchmark_workload("Page rank", 16)
    good = evaluate_accuracy(E5_2699_V3, wl_good)
    bad = evaluate_accuracy(E5_2699_V3, wl_bad)
    assert float(np.asarray(bad.errors_combined).mean()) > 10 * float(
        np.asarray(good.errors_combined).mean() + 1e-6
    )
    # and the §6.2.1 detector ranks them accordingly
    assert float(bad.misfit) > 10 * float(good.misfit)


@pytest.mark.slow
def test_suite_median_error_within_paper_band():
    """Paper §6.2.2: median error 2.34% of bandwidth over thousands of
    measurements.  Our ground truth is in-model by construction (except the
    violator), so the median with realistic counter noise must land *below*
    the paper's 2.34%."""
    r = evaluate_suite(E5_2699_V3, noise_std=0.02)
    assert r.all_errors.size > 1000  # "thousands of measurements"
    assert r.median_error_pct < 2.34
    # errors are not degenerate zeros under noise
    assert r.median_error_pct > 0.01


@pytest.mark.slow
def test_stability_across_machines():
    """Paper Figure 14: mean combined-signature change 6.8%, median 4.2%.
    Our simulated machines differ only through saturation-induced rate
    asymmetries, so changes must be small and below the paper's levels."""
    r = evaluate_stability(E5_2630_V3, E5_2699_V3, noise_std=0.01)
    assert r.mean_combined_pct < 6.8
    assert r.median_combined_pct < 4.2


# ---------------------------------------------------------------------------
# module caches: bounded, LRU, thread-safe (the advisor service calls this
# module from many threads — unbounded or torn caches were real failures)
# ---------------------------------------------------------------------------


def test_signature_cache_is_bounded_with_lru_eviction():
    from repro.core.numa import evaluate as ev

    saved = dict(ev._SIG_CACHE)
    try:
        ev._SIG_CACHE.clear()
        for i in range(ev._SIG_CACHE_MAX + 500):
            ev._cache_insert(("synthetic", i), i)
        assert len(ev._SIG_CACHE) == ev._SIG_CACHE_MAX
        # oldest synthetic keys were evicted, newest survive
        assert ("synthetic", 0) not in ev._SIG_CACHE
        assert ("synthetic", ev._SIG_CACHE_MAX + 499) in ev._SIG_CACHE
        # a hit refreshes recency: touch the current oldest, insert one
        # more, and the touched entry must survive the sweep
        oldest = next(iter(ev._SIG_CACHE))
        assert ev._cache_lookup(oldest) is not None
        ev._cache_insert(("synthetic", "tail"), 0)
        assert oldest in ev._SIG_CACHE
    finally:
        ev._SIG_CACHE.clear()
        ev._SIG_CACHE.update(saved)


def test_workload_and_support_memos_are_bounded():
    import jax.numpy as jnp

    from repro.core.numa import evaluate as ev

    for i in range(ev._MEMO_CACHE_MAX + 40):
        wl = benchmark_workload("CG", 8)
        ev._stack_workloads([wl])
        placements = jnp.asarray(np.asarray([[8 - j, j] for j in range(3)]))
        ev._support_arrays(placements)
    assert len(ev._STACK_CACHE) <= ev._MEMO_CACHE_MAX
    assert len(ev._SUPPORT_CACHE) <= ev._MEMO_CACHE_MAX
    # memo hit returns the identical stacked value (id-keyed)
    wl = benchmark_workload("CG", 8)
    first = ev._stack_workloads([wl])
    assert ev._stack_workloads([wl]) is first


def test_memo_caches_survive_concurrent_hammer():
    import threading

    from repro.core.numa import evaluate as ev

    errors = []

    def worker(seed):
        try:
            for i in range(200):
                ev._memo_put(
                    ev._STACK_CACHE, ev._MEMO_LOCK, ("hammer", seed, i % 80),
                    (None, i), ev._MEMO_CACHE_MAX,
                )
                ev._memo_get(
                    ev._STACK_CACHE, ev._MEMO_LOCK,
                    ("hammer", seed, (i * 13) % 80),
                )
                ev._cache_insert(("hammer-sig", seed, i % 80), i)
                ev._cache_lookup(("hammer-sig", seed, (i * 7) % 80))
        except Exception as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(ev._STACK_CACHE) <= ev._MEMO_CACHE_MAX
    assert len(ev._SIG_CACHE) <= ev._SIG_CACHE_MAX


def test_enumerate_placements_budget_edges():
    """Core-cap feasibility: the boundary budget yields exactly the full
    machine, zero threads yields the empty placement, and anything beyond
    ``s * cores_per_node`` (or negative) is rejected up front."""
    from repro.core.numa.evaluate import count_placements, enumerate_placements

    m = E5_2630_V3  # 2 nodes x 8 cores
    full = m.n_nodes * m.cores_per_node
    at_cap = np.asarray(enumerate_placements(m, full))
    assert at_cap.shape == (1, m.n_nodes)
    assert at_cap.tolist() == [[m.cores_per_node] * m.n_nodes]
    assert count_placements(m, full) == 1

    empty = np.asarray(enumerate_placements(m, 0))
    assert empty.tolist() == [[0] * m.n_nodes]

    with pytest.raises(ValueError):
        enumerate_placements(m, full + 1)
    with pytest.raises(ValueError):
        enumerate_placements(m, -1)
    # per-node caps hold on a feasible-but-tight budget
    tight = np.asarray(enumerate_placements(m, full - 1))
    assert (tight <= m.cores_per_node).all()
    assert len(tight) == count_placements(m, full - 1) == m.n_nodes
