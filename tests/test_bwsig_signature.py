"""Tests for the signature model and its application (paper §3–§4)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bwsig import (
    DirectionSignature,
    interleaved_fraction,
    placement_matrix,
    predict_counters,
    predict_flows,
)


def test_worked_example_figure5():
    """Paper §4 worked example: static=0.2@socket2, local=0.35,
    per-thread=0.3, interleaved=0.15, placement (3, 1) threads.

    Figure 5's combined matrix:
      socket1 row: 0.65 local, 0.35 to bank 2
      socket2 row: 0.30 to bank 1, 0.70 local
    """
    sig = DirectionSignature.make(
        static_socket=1,  # paper's "socket 2", 0-indexed
        static_fraction=0.2,
        local_fraction=0.35,
        per_thread_fraction=0.3,
    )
    assert np.isclose(float(interleaved_fraction(sig)), 0.15)
    m = placement_matrix(sig, jnp.asarray([3, 1]))
    expected = np.array([[0.65, 0.35], [0.30, 0.70]])
    np.testing.assert_allclose(np.asarray(m), expected, atol=1e-6)


def test_rows_sum_to_one_worked_example():
    sig = DirectionSignature.make(1, 0.2, 0.35, 0.3)
    m = placement_matrix(sig, jnp.asarray([3, 1]))
    np.testing.assert_allclose(np.asarray(m.sum(axis=1)), [1.0, 1.0], atol=1e-6)


def test_pure_class_matrices():
    n = jnp.asarray([3, 1])
    np.testing.assert_allclose(
        np.asarray(placement_matrix(DirectionSignature.make(0, 1.0, 0, 0), n)),
        [[1, 0], [1, 0]],
        atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(placement_matrix(DirectionSignature.make(0, 0, 1.0, 0), n)),
        np.eye(2),
        atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(placement_matrix(DirectionSignature.make(0, 0, 0, 1.0), n)),
        [[0.75, 0.25], [0.75, 0.25]],
        atol=1e-6,
    )
    np.testing.assert_allclose(  # interleaved = remainder class
        np.asarray(placement_matrix(DirectionSignature.make(0, 0, 0, 0), n)),
        [[0.5, 0.5], [0.5, 0.5]],
        atol=1e-6,
    )


def test_interleaved_uses_only_used_sockets():
    """Paper §4: interleaved cells are 1/s over *used* sockets."""
    sig = DirectionSignature.make(0, 0, 0, 0)  # pure interleaved
    m = placement_matrix(sig, jnp.asarray([4, 0]))
    np.testing.assert_allclose(np.asarray(m[0]), [1.0, 0.0], atol=1e-6)


def test_predict_counters_reduction():
    sig = DirectionSignature.make(1, 0.2, 0.35, 0.3)
    demand = jnp.asarray([30.0, 10.0])
    local, remote = predict_counters(sig, demand, jnp.asarray([3, 1]))
    flows = predict_flows(sig, demand, jnp.asarray([3, 1]))
    np.testing.assert_allclose(np.asarray(local), np.diag(np.asarray(flows)))
    np.testing.assert_allclose(
        np.asarray(local + remote), np.asarray(flows.sum(0)), rtol=1e-6
    )
    # Conservation: all demand lands on some bank.
    np.testing.assert_allclose(float((local + remote).sum()), 40.0, rtol=1e-6)


@st.composite
def signatures(draw, s: int = 2):
    fracs = draw(
        st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3).filter(
            lambda f: sum(f) <= 1.0
        )
    )
    socket = draw(st.integers(0, s - 1))
    return DirectionSignature.make(socket, fracs[0], fracs[1], fracs[2])


@settings(max_examples=50, deadline=None)
@given(
    sig=signatures(),
    n0=st.integers(0, 16),
    n1=st.integers(0, 16),
)
def test_placement_matrix_row_stochastic(sig, n0, n1):
    """Property (paper Fig 5 caption): every used socket's row sums to 1,
    all entries are in [0, 1]."""
    if n0 + n1 == 0:
        return
    n = jnp.asarray([n0, n1])
    m = np.asarray(placement_matrix(sig, n))
    assert (m >= -1e-6).all() and (m <= 1 + 1e-6).all()
    for i, cnt in enumerate([n0, n1]):
        if cnt > 0:
            assert np.isclose(m[i].sum(), 1.0, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(sig=signatures(), scale=st.floats(0.1, 100.0))
def test_flow_conservation(sig, scale):
    """Total predicted flow equals total demand regardless of signature."""
    demand = jnp.asarray([2.0, 3.0]) * scale
    flows = predict_flows(sig, demand, jnp.asarray([2, 2]))
    assert np.isclose(float(flows.sum()), float(demand.sum()), rtol=1e-5)
