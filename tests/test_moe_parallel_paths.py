"""Numerical equivalence of the three MoE execution paths on a real
(8 fake-device) mesh: gather-EP, a2a-EP, and the no-gather decode path
must all match the single-device reference."""

import subprocess
import sys

import pytest

_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.models import moe as moe_mod
from repro.parallel import context as ctx

cfg = dataclasses.replace(
    get_config("qwen3-moe-30b-a3b").reduced(),
    n_experts=8, experts_per_token=2, capacity_factor=8.0,  # no drops
)
key = jax.random.PRNGKey(0)
with ctx.use_mesh(None):
    pass
mesh = jax.make_mesh((2, 4), ("data", "model"))

# params must be built under the mesh so the expert factor matches
with ctx.use_mesh(mesh):
    p = moe_mod.init_moe_params(key, cfg, jnp.float32)
x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model)) * 0.3

# single-device reference
ref, _ = moe_mod.moe_ffn(cfg, p, x)

with ctx.use_mesh(mesh):
    got_gather, _ = jax.jit(lambda p, x: moe_mod.moe_ffn(cfg, p, x))(p, x)
    got_a2a, _ = jax.jit(lambda p, x: moe_mod.moe_ffn_a2a(cfg, p, x))(p, x)
    got_decode, _ = jax.jit(
        lambda p, x: moe_mod.moe_ffn(cfg, p, x, decode=True)
    )(p, x)

for name, got in (("gather", got_gather), ("a2a", got_a2a), ("decode", got_decode)):
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(got, np.float32),
        atol=2e-3, rtol=2e-3, err_msg=name,
    )
print("MOE PATHS OK")
"""


@pytest.mark.slow
def test_moe_paths_agree_on_mesh():
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET],
        capture_output=True,
        text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MOE PATHS OK" in r.stdout
