"""Direct coverage for ``launch/mesh.py`` (candidate enumeration + the
advisor entry point + sharding policy helpers) and the pure error-metric
helpers of ``meshsig/validate.py``."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.meshsig.fit import MeshProfile, class_factor, fit_mesh_signature
from repro.launch import mesh as mesh_lib


def synth_profile(axes, *, grad_bytes=1e9, gather_bytes=5e8, a2a_base=2e9):
    b = axes.get("data", 1) * axes.get("pod", 1)
    kd, km = axes["data"], axes["model"]
    out = {
        ("interleaved", "data"): class_factor("interleaved", kd) * grad_bytes,
        ("static", "data"): class_factor("static", kd) * gather_bytes,
        ("per_shard", "model"): class_factor("per_shard", km) * a2a_base / b,
    }
    return MeshProfile(
        axis_sizes=dict(axes),
        class_axis_bytes=out,
        local_bytes=1e10 / b,
        flops=1e13 / b,
    )


def fitted_sig():
    return fit_mesh_signature(
        synth_profile({"data": 8, "model": 2}),
        synth_profile({"data": 4, "model": 4}),
    )


# ---------------------------------------------------------------------------
# candidate_mesh_axes
# ---------------------------------------------------------------------------


def test_candidate_mesh_axes_enumerates_factorizations():
    cands = mesh_lib.candidate_mesh_axes(16)
    assert cands == [
        {"data": 16, "model": 1},
        {"data": 8, "model": 2},
        {"data": 4, "model": 4},
        {"data": 2, "model": 8},
        {"data": 1, "model": 16},
    ]
    # dict key order is the advisor's embedding order: outer axis first
    assert all(list(c) == ["data", "model"] for c in cands)


def test_candidate_mesh_axes_bounds_and_names():
    cands = mesh_lib.candidate_mesh_axes(
        12, axis_names=("pod", "model"), min_model=2, max_model=6
    )
    assert cands == [
        {"pod": 6, "model": 2},
        {"pod": 4, "model": 3},
        {"pod": 3, "model": 4},
        {"pod": 2, "model": 6},
    ]


def test_candidate_mesh_axes_raises_when_empty():
    with pytest.raises(ValueError, match="no factorization"):
        mesh_lib.candidate_mesh_axes(7, min_model=2, max_model=6)
    with pytest.raises(ValueError, match=">= 1 device"):
        mesh_lib.candidate_mesh_axes(0)


# ---------------------------------------------------------------------------
# advise_mesh_shape
# ---------------------------------------------------------------------------


def test_advise_mesh_shape_scalar_and_routed_agree_on_fc():
    from repro.core.meshsig.advisor import CHIP_V5E
    from repro.core.meshsig.device_topology import nvlink_island

    sig = fitted_sig()
    scalar = mesh_lib.advise_mesh_shape(sig, 16)
    routed = mesh_lib.advise_mesh_shape(
        sig, 16, topology=nvlink_island(16, CHIP_V5E.ici_bw)
    )
    assert len(scalar) == 5
    assert scalar[0].step_s <= scalar[-1].step_s
    assert [r.axis_sizes for r in scalar] == [r.axis_sizes for r in routed]
    assert routed[0].step_s == pytest.approx(scalar[0].step_s, rel=1e-9)


def test_advise_mesh_shape_chip_override_scales_compute():
    from repro.core.meshsig.advisor import CHIP_V5E, CHIP_V5P

    sig = fitted_sig()
    v5e = mesh_lib.advise_mesh_shape(sig, 16, chip=CHIP_V5E)
    v5p = mesh_lib.advise_mesh_shape(sig, 16, chip=CHIP_V5P)
    by_axes = {tuple(r.axis_sizes.items()): r for r in v5e}
    for r in v5p:
        e = by_axes[tuple(r.axis_sizes.items())]
        assert r.compute_s == pytest.approx(
            e.compute_s * CHIP_V5E.peak_flops / CHIP_V5P.peak_flops
        )


# ---------------------------------------------------------------------------
# Sharding policy helpers
# ---------------------------------------------------------------------------


def test_serve_params_replicated_threshold(monkeypatch):
    from repro.configs.base import get_config

    cfg = get_config("llama3-8b")  # 8B bf16 / 16-way TP ~ 1 GB << 6 GB
    assert mesh_lib.serve_params_replicated(cfg)
    monkeypatch.setattr(mesh_lib, "SERVE_REPLICATION_LIMIT", 1)
    assert not mesh_lib.serve_params_replicated(cfg)


def test_batch_shardings_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {
        "tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32),
        "scalar": jax.ShapeDtypeStruct((), jnp.float32),
    }
    sh = mesh_lib.batch_shardings(mesh, tree)
    assert sh["tokens"].spec[0] == ("data",)  # 4 % 1 == 0 -> data axis
    assert sh["scalar"].spec == jax.sharding.PartitionSpec()


# ---------------------------------------------------------------------------
# meshsig/validate.py pure helpers
# ---------------------------------------------------------------------------


def _validate_module():
    # validate.py sets XLA_FLAGS for its own __main__ use; initialize the
    # backend first so importing it cannot re-shape this process's devices
    jax.devices()
    from repro.core.meshsig import validate

    return validate


def test_measured_axis_bytes_collapses_classes():
    validate = _validate_module()
    prof = MeshProfile(
        axis_sizes={"data": 4, "model": 2},
        class_axis_bytes={
            ("interleaved", "data"): 6.0,
            ("static", "data"): 2.0,
            ("per_shard", "model"): 3.0,
        },
        local_bytes=0.0,
        flops=0.0,
    )
    assert validate.measured_axis_bytes(prof) == {"data": 8.0, "model": 3.0}


def test_prediction_errors_distinct_and_symmetric():
    validate = _validate_module()
    sig = fitted_sig()
    # distinct sizes: exact per-axis attribution -> perfect prediction
    axes = {"data": 8, "model": 2}
    meas = validate.measured_axis_bytes(synth_profile(axes))
    errs = validate.prediction_errors(sig, axes, meas)
    assert set(errs) == {"data", "model"}
    assert max(errs.values()) < 1e-6
    # symmetric sizes: only the total is identified
    axes = {"data": 4, "model": 4}
    meas = validate.measured_axis_bytes(synth_profile(axes))
    errs = validate.prediction_errors(sig, axes, meas)
    assert set(errs) == {"total"}
    assert errs["total"] < 1e-6
    # a deliberately-wrong measurement shows up as % of total traffic
    errs = validate.prediction_errors(
        sig, axes, {a: v * 2 for a, v in meas.items()}
    )
    assert errs["total"] == pytest.approx(50.0, rel=1e-3)
