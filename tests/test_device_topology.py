"""Routed device meshes + the ICI calibration round trip.

The two acceptance pins for the routed collective model live here:

* **Scalar parity** — on a fully-connected uniform-bandwidth topology the
  routed advisor equals the scalar ``ici_bw`` division exactly, so the
  refactor cannot drift rankings on fabrics the old model already handled.
* **Cross-island regression** — on a glued multi-host topology the routed
  model separates two candidates with *identical axis sizes* (island-local
  vs glue-striding embeddings) that the scalar model scores identically.
"""

import numpy as np
import pytest

from repro.core.graphtop import from_fit, ring
from repro.core.meshsig.advisor import CHIP_V5E, rank_meshes
from repro.core.meshsig.calibrate import (
    fit_device_topology,
    fit_from_synthetic,
    link_relative_errors,
    probe_suite,
    collect_samples,
)
from repro.core.meshsig.device_topology import (
    DeviceTopology,
    ici_torus2d,
    nvlink_island,
    ring_of_islands,
)
from repro.core.meshsig.fit import MeshProfile, class_factor, fit_mesh_signature


def synth_profile(axes, *, grad_bytes=1e9, gather_bytes=5e8, a2a_base=2e9):
    """Same ground-truth generator as ``test_meshsig`` / the mesh-rank
    benchmark: grad all-reduce + param all-gather on data, MoE all-to-all
    on model scaling 1/batch."""
    b = axes.get("data", 1) * axes.get("pod", 1)
    kd, km = axes["data"], axes["model"]
    out = {
        ("interleaved", "data"): class_factor("interleaved", kd) * grad_bytes,
        ("static", "data"): class_factor("static", kd) * gather_bytes,
        ("per_shard", "model"): class_factor("per_shard", km) * a2a_base / b,
    }
    return MeshProfile(
        axis_sizes=dict(axes),
        class_axis_bytes=out,
        local_bytes=1e10 / b,
        flops=1e13 / b,
    )


def fitted_sig():
    return fit_mesh_signature(
        synth_profile({"data": 8, "model": 2}),
        synth_profile({"data": 4, "model": 4}),
    )


# ---------------------------------------------------------------------------
# Embedding + charging mechanics
# ---------------------------------------------------------------------------


def test_device_groups_row_major_and_order_dependent():
    topo = nvlink_island(8)
    g1 = topo.device_groups({"data": 2, "model": 4})
    assert g1["model"] == [[0, 1, 2, 3], [4, 5, 6, 7]]  # minor = contiguous
    assert g1["data"] == [[0, 4], [1, 5], [2, 6], [3, 7]]
    g2 = topo.device_groups({"model": 4, "data": 2})
    assert g2["model"] == [[0, 2, 4, 6], [1, 3, 5, 7]]  # now major = strided
    assert g2["data"] == [[0, 1], [2, 3], [4, 5], [6, 7]]


def test_device_groups_size_mismatch_raises():
    with pytest.raises(ValueError, match="need 8 devices"):
        nvlink_island(4).device_groups({"data": 2, "model": 4})


def test_axis_pair_bytes_ring_successors():
    topo = nvlink_island(4)
    pair = topo.axis_pair_bytes({"data": 4}, "data", 3.0)
    n = 4
    sent = {(i, j) for i in range(n) for j in range(n) if pair[i * n + j]}
    assert sent == {(0, 1), (1, 2), (2, 3), (3, 0)}
    assert pair.sum() == pytest.approx(4 * 3.0)
    # size-1 groups (and zero bytes) charge nothing
    assert not topo.axis_pair_bytes({"data": 1, "model": 4}, "data", 3.0).any()
    assert not topo.axis_pair_bytes({"data": 4}, "data", 0.0).any()


def test_link_loads_one_hop_conservation():
    topo = ici_torus2d(4, 4)
    B = {"data": 2e9, "model": 3e9}
    loads = topo.link_loads({"data": 4, "model": 4}, B)
    # both axes embed as contiguous torus rings: every ring step is one
    # hop, so total directed bytes == devices * per-device bytes per axis
    assert loads.sum() == pytest.approx(16 * (2e9 + 3e9))


# ---------------------------------------------------------------------------
# Acceptance pin 1: scalar parity on fully-connected uniform fabrics
# ---------------------------------------------------------------------------


def test_fc_uniform_equals_scalar_model():
    topo = nvlink_island(16, CHIP_V5E.ici_bw)
    axes = {"data": 4, "model": 4}
    B = {"data": 7e8, "model": 13e8}
    routed = topo.per_axis_times(axes, B)
    for a in axes:
        assert routed[a] == pytest.approx(B[a] / CHIP_V5E.ici_bw, rel=1e-12)


def test_rank_meshes_routed_scalar_parity_fc():
    sig = fitted_sig()
    candidates = [
        {"data": 16, "model": 1},
        {"data": 8, "model": 2},
        {"data": 4, "model": 4},
        {"data": 2, "model": 8},
        {"data": 1, "model": 16},
    ]
    scalar = rank_meshes(sig, candidates)
    routed = rank_meshes(
        sig, candidates, topology=nvlink_island(16, CHIP_V5E.ici_bw)
    )
    s_by = {tuple(sorted(r.axis_sizes.items())): r for r in scalar}
    for r in routed:
        s = s_by[tuple(sorted(r.axis_sizes.items()))]
        assert r.step_s == pytest.approx(s.step_s, rel=1e-9)
        assert r.collective_s == pytest.approx(s.collective_s, rel=1e-9)
    assert [r.axis_sizes for r in routed] == [r.axis_sizes for r in scalar]


# ---------------------------------------------------------------------------
# Acceptance pin 2: glued multi-host separates identical axis sizes
# ---------------------------------------------------------------------------


def test_cross_island_ranked_below_island_local():
    # heavy MoE all-to-all makes the MODEL axis the one that must stay
    # inside an island
    sig = fit_mesh_signature(
        synth_profile({"data": 8, "model": 2}, grad_bytes=1e8,
                      gather_bytes=5e7, a2a_base=64e9),
        synth_profile({"data": 4, "model": 4}, grad_bytes=1e8,
                      gather_bytes=5e7, a2a_base=64e9),
    )
    topo = ring_of_islands(2, 8)
    island_local = {"data": 2, "model": 8}  # model contiguous, inside islands
    cross_island = {"model": 8, "data": 2}  # model strided across the glue
    # scalar model: same sizes -> literally identical step time (the two
    # dicts are ==, so only the embedding-aware model can tell them apart)
    s = rank_meshes(sig, [island_local, cross_island])
    assert s[0].step_s == pytest.approx(s[1].step_s, rel=1e-12)
    # routed model: the glue links are ~18x thinner than NVLink, so the
    # striding candidate funnels its heavy model ring into them
    r = rank_meshes(sig, [island_local, cross_island], topology=topo)
    assert list(r[0].axis_sizes) == ["data", "model"]  # island-local wins
    assert list(r[1].axis_sizes) == ["model", "data"]
    assert r[1].collective_s > 3 * r[0].collective_s


def test_per_axis_times_sees_glue_bottleneck():
    topo = ring_of_islands(2, 8)
    B = {"data": 1e9, "model": 8e9}
    local = topo.per_axis_times({"data": 2, "model": 8}, B)
    strided = topo.per_axis_times({"model": 8, "data": 2}, B)
    assert strided["model"] > 3 * local["model"]


# ---------------------------------------------------------------------------
# Multipath charging (satellite: off by default, splits when enabled)
# ---------------------------------------------------------------------------


def test_multipath_splits_ring_collective_both_ways():
    # ring of 4 devices; the strided major axis pairs opposite corners,
    # whose two 2-hop routes are equal-cost
    axes = {"a": 2, "b": 2}
    B = {"a": 4e9, "b": 0.0}
    single = DeviceTopology(graph=ring(4, 10e9))
    multi = DeviceTopology(graph=ring(4, 10e9), multipath=True)
    l1 = single.link_loads(axes, B)
    l2 = multi.link_loads(axes, B)
    assert np.count_nonzero(l1) < 8  # single path leaves slots idle
    assert np.count_nonzero(l2) == 8  # every direction carries traffic
    assert l1.sum() == pytest.approx(l2.sum())  # same total byte-hops
    # splitting halves the most-loaded link, so the axis time halves
    t1 = single.per_axis_times(axes, B)["a"]
    t2 = multi.per_axis_times(axes, B)["a"]
    assert t2 == pytest.approx(t1 / 2)


# ---------------------------------------------------------------------------
# Acceptance pin 3: ICI calibration round trip within 5%
# ---------------------------------------------------------------------------


def perturbed_torus(rows=4, cols=4, base=50e9, spread=0.3, seed=0):
    t = ici_torus2d(rows, cols, base)
    rng = np.random.default_rng(seed)
    bw = base * (1 + spread * rng.uniform(-1, 1, t.graph.n_links))
    return DeviceTopology(graph=from_fit(t.graph, bw), multipath=False)


def test_calibration_roundtrip_synthetic_torus():
    truth = perturbed_torus()
    res = fit_from_synthetic(
        truth, axis_sizes_list=[{"data": 4, "model": 4}, {"data": 2, "model": 8}]
    )
    errs = link_relative_errors(res.topology, truth)
    assert errs.max() < 0.05, errs.max()
    assert res.final_loss < 1e-3
    assert res.topology.graph.routes == truth.graph.routes  # structure held


def test_calibration_roundtrip_with_noise():
    truth = perturbed_torus(seed=3)
    import jax

    res = fit_from_synthetic(
        truth,
        axis_sizes_list=[{"data": 4, "model": 4}],
        noise_std=0.01,
        key=jax.random.PRNGKey(7),
    )
    assert link_relative_errors(res.topology, truth).max() < 0.05


def test_calibration_tie_equal_bw_groups_classes():
    # glued ring: the island links are one hardware class, the glue links
    # another.  The template encodes the class partition via placeholder
    # bandwidths (tie_equal_bw ties links with equal TEMPLATE values); the
    # fit then recovers one shared parameter per class.
    truth = ring_of_islands(2, 4, island_bw=400e9, host_bw=20e9)
    placeholder = [
        100e9 if (i < 4) == (j < 4) else 1e9  # island-internal vs glue
        for i, j in truth.graph.link_ends
    ]
    template = DeviceTopology(graph=from_fit(truth.graph, placeholder))
    res = fit_from_synthetic(truth, template, tie_equal_bw=True)
    assert res.groups.n_params == 2
    assert link_relative_errors(res.topology, truth).max() < 0.05


def test_fit_rejects_mismatched_charge_width():
    truth = perturbed_torus()
    charges = probe_suite(truth)
    samples = collect_samples(truth, charges)
    wrong = nvlink_island(4)
    with pytest.raises(ValueError, match="directed slots"):
        fit_device_topology(wrong, samples)
