"""Domain-neutral routed-graph topology engine.

One graph core for every interconnect this repo models: NUMA hosts
(``repro.core.numa`` — QPI meshes, glued node controllers, sub-NUMA
clusters) and accelerator device meshes (``repro.core.meshsig`` — ICI
tori, NVLink islands, multi-host rings).  A :class:`LinkGraph` is a
hashable link list with per-link capacities and statically computed
widest-shortest-path routes; consumers derive pair→link incidence
matrices (unit or fractional-multipath, undirected or directed) and fit
per-link bandwidths through the :class:`LinkGroups` symmetry packing.

``repro.core.numa.topology`` re-exports all of this under its historical
names (``Topology`` is a ``LinkGraph`` subclass, so reprs, fingerprints
and golden digests are unchanged bit-for-bit); new code should import
from here.
"""

from repro.core.graphtop.graph import (
    LinkGraph,
    LinkGroups,
    all_shortest_routes,
    all_widest_routes,
    from_bandwidth_matrix,
    from_fit,
    fully_connected,
    glued,
    link_groups,
    mesh2d,
    ring,
    snc,
    torus2d,
    torus3d,
    tree,
)

__all__ = [
    "LinkGraph",
    "LinkGroups",
    "all_shortest_routes",
    "all_widest_routes",
    "from_bandwidth_matrix",
    "from_fit",
    "fully_connected",
    "glued",
    "link_groups",
    "mesh2d",
    "ring",
    "snc",
    "torus2d",
    "torus3d",
    "tree",
]
