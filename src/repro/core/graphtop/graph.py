"""Routed link graphs: bandwidth-capacitated links + static routing.

The engine behind every interconnect model in the repo.  The paper's
machines are dual-socket boxes where "the interconnect" is a single QPI
link, but large NUMA machines have strongly distance-dependent bandwidth
(STREAM-style measurements show per-hop cliffs — Bergstrom,
arXiv:1103.3225), glued 8-socket systems route far socket pairs through
node controllers, and accelerator meshes (ICI tori, NVLink islands,
multi-host rings) are graphs from the start.  A :class:`LinkGraph`
captures that structure:

* an undirected link list with per-link capacities (bytes/s), and
* a statically computed shortest-path routing table: for every ordered
  node pair, the sequence of links its traffic crosses.

Everything is stored as nested tuples of python scalars, so a
``LinkGraph`` (and any spec that embeds one, e.g.
:class:`~repro.core.numa.machine.MachineSpec`) stays hashable — it can be
a ``jax.jit`` static argument and a signature-cache key even when the
builder was handed numpy/JAX arrays for the bandwidth matrix.  The
derived *arrays* (link capacities, hop matrix, pair→link routing
incidence) are materialized lazily and cached per graph; inside a trace
they are compile-time constants, so consumers keep fixed
``(n, n_links)``-shaped slabs that jit and vmap handle identically for
any node count.

Routing is hop-count shortest path (BFS) with bandwidth-aware tie-breaks:
among equal-hop routes the one with the largest bottleneck link bandwidth
wins (widest-shortest path), and remaining ties fall back to the
smallest-id predecessor in the previous BFS layer — with uniform link
bandwidths this reduces exactly to the old smallest-predecessor rule, so
routing tables stay reproducible across processes.

**Multipath** (:func:`all_widest_routes`): when several equal-hop routes
share the best bottleneck bandwidth, flow can be split evenly across all
of them instead of pinned to the deterministic tie-break winner.  The
incidence matrices take ``multipath=True`` to return the fractional
pair→link matrix (each route carries ``1/k`` of the pair's flow); the
default ``multipath=False`` reproduces the single-route tables
bit-for-bit, which is what the NUMA golden pins ride on.

What a graph's nodes *are* is the embedding domain's business: NUMA
nodes for hosts (:mod:`repro.core.numa.topology`), devices for
accelerator meshes (:mod:`repro.core.meshsig.device_topology`).
"""

from __future__ import annotations

from functools import lru_cache
from typing import NamedTuple, Sequence

import numpy as np


class LinkGraph(NamedTuple):
    """An interconnect graph over ``n_nodes`` nodes with static routes.

    ``link_ends[l] = (i, j)`` with ``i < j`` names the l-th undirected
    link; ``link_bw[l]`` is its capacity in bytes/s (both directions share
    it, like QPI — duplex consumers charge each direction against the full
    capacity via :meth:`directed_route_incidence`).
    ``routes[i * n_nodes + j]`` is the tuple of link indices the ordered
    pair ``i -> j`` crosses (empty for ``i == j``).
    """

    name: str
    n_nodes: int
    link_ends: tuple[tuple[int, int], ...]
    link_bw: tuple[float, ...]
    routes: tuple[tuple[int, ...], ...]

    @property
    def n_links(self) -> int:
        return len(self.link_ends)

    def route(self, i: int, j: int) -> tuple[int, ...]:
        """Link indices crossed by traffic from node ``i`` to ``j``."""
        return self.routes[i * self.n_nodes + j]

    @property
    def max_hops(self) -> int:
        return max((len(r) for r in self.routes), default=0)

    @property
    def is_fully_direct(self) -> bool:
        """True when every distinct pair is one hop (no routed traffic) —
        the regime where the link model degenerates to the scalar-pair
        model of the original 2-socket formulation."""
        return self.max_hops <= 1

    def hop_matrix(self) -> np.ndarray:
        """``(n, n)`` int hop counts (0 on the diagonal)."""
        return _hop_matrix(self)

    def route_incidence(
        self, *, multipath: bool = False, weighting: str = "equal"
    ) -> np.ndarray:
        """``(n*n, n_links)`` float32 matrix ``R`` with ``R[i*n+j, l] = 1``
        iff link ``l`` is on the route ``i -> j``.  Charging per-link usage
        is then one matmul: ``flows.reshape(-1, n*n) @ R``.  With
        ``multipath=True`` each pair's flow splits over its equal-hop
        routes, so entries become fractional; ``weighting`` picks the
        split:

        * ``"equal"`` (default) — ``1/k`` over the equal-hop
          *equal-bottleneck* (widest-tie) route set, bit-for-bit the
          historical table;
        * ``"bottleneck"`` — over ALL equal-hop shortest routes, each
          weighted by its bottleneck link bandwidth (a route through a
          thin link carries proportionally less of the pair's flow —
          ECMP with unequal-cost shares).  With all-equal route
          bottlenecks this coincides with ``"equal"`` over the same set.

        The default single-route table is unchanged bit-for-bit."""
        if multipath:
            if weighting == "equal":
                return _route_incidence_multipath(self)
            if weighting == "bottleneck":
                return _route_incidence_bottleneck(self)
            raise ValueError(
                f"unknown multipath weighting {weighting!r} "
                "(expected 'equal' or 'bottleneck')"
            )
        if weighting != "equal":
            raise ValueError("weighting requires multipath=True")
        return _route_incidence(self, multihop_only=False)

    def route_incidence_multihop(self) -> np.ndarray:
        """Like :meth:`route_incidence` but with single-hop rows zeroed —
        the *extra* charges routed topologies add on top of the direct
        endpoint-pair traffic every link always carries."""
        return _route_incidence(self, multihop_only=True)

    def directed_route_incidence(
        self, *, multipath: bool = False, weighting: str = "equal"
    ) -> np.ndarray:
        """``(n*n, 2 * n_links)`` float32 incidence over *directed* link
        slots: column ``2l`` is link ``l`` traversed in canonical
        (low-id -> high-id) direction, ``2l + 1`` the reverse.  Full-duplex
        fabrics (ICI, NVLink) charge each direction against the link's full
        capacity; half-duplex consumers can fold the two columns.  With
        ``multipath=True`` entries are the fractional multipath split
        (``weighting`` as in :meth:`route_incidence`: equal over widest
        ties, or bottleneck-bandwidth-proportional over all shortest
        routes)."""
        if weighting not in ("equal", "bottleneck"):
            raise ValueError(
                f"unknown multipath weighting {weighting!r} "
                "(expected 'equal' or 'bottleneck')"
            )
        if weighting == "bottleneck" and not multipath:
            raise ValueError("weighting requires multipath=True")
        return _directed_route_incidence(
            self, multipath=multipath, weighting=weighting
        )

    def all_routes(self, i: int, j: int) -> tuple[tuple[int, ...], ...]:
        """Every equal-hop route from ``i`` to ``j`` whose bottleneck
        bandwidth ties the widest-shortest optimum (deterministic order;
        the primary ``route(i, j)`` is always among them)."""
        return all_widest_routes(self)[i * self.n_nodes + j]

    def all_shortest_routes_of(self, i: int, j: int) -> tuple[tuple[int, ...], ...]:
        """Every equal-hop shortest route from ``i`` to ``j`` regardless
        of bottleneck bandwidth — the route set bottleneck-weighted
        multipath splits over (:meth:`route_incidence` with
        ``weighting="bottleneck"``)."""
        return all_shortest_routes(self)[i * self.n_nodes + j]

    def validate(self) -> None:
        n = self.n_nodes
        if len(self.routes) != n * n:
            raise ValueError(f"routes must have {n * n} entries")
        if len(self.link_bw) != len(self.link_ends):
            raise ValueError("link_bw and link_ends disagree on link count")
        if len(set(self.link_ends)) != len(self.link_ends):
            raise ValueError("duplicate links: endpoint pairs must be unique")
        for l, (i, j) in enumerate(self.link_ends):
            if not (0 <= i < j < n):
                raise ValueError(f"link {l} endpoints {(i, j)} invalid")
            if self.link_bw[l] <= 0:
                raise ValueError(f"link {l} has non-positive bandwidth")
        for i in range(n):
            for j in range(n):
                r = self.route(i, j)
                if i == j:
                    if r:
                        raise ValueError(f"self-route {i} must be empty")
                    continue
                if not r:
                    raise ValueError(f"nodes {i} and {j} are disconnected")
                at = i
                for l in r:
                    a, b = self.link_ends[l]
                    if at == a:
                        at = b
                    elif at == b:
                        at = a
                    else:
                        raise ValueError(f"route {i}->{j} breaks at link {l}")
                if at != j:
                    raise ValueError(f"route {i}->{j} ends at {at}")


@lru_cache(maxsize=128)
def _hop_matrix(graph: LinkGraph) -> np.ndarray:
    n = graph.n_nodes
    hops = np.zeros((n, n), np.int32)
    for i in range(n):
        for j in range(n):
            hops[i, j] = len(graph.route(i, j))
    hops.setflags(write=False)
    return hops


@lru_cache(maxsize=128)
def _route_incidence(graph: LinkGraph, *, multihop_only: bool) -> np.ndarray:
    n = graph.n_nodes
    R = np.zeros((n * n, graph.n_links), np.float32)
    for i in range(n):
        for j in range(n):
            r = graph.route(i, j)
            if multihop_only and len(r) <= 1:
                continue
            for l in r:
                R[i * n + j, l] = 1.0
    R.setflags(write=False)
    return R


@lru_cache(maxsize=128)
def _route_incidence_multipath(graph: LinkGraph) -> np.ndarray:
    n = graph.n_nodes
    R = np.zeros((n * n, graph.n_links), np.float32)
    routes = all_widest_routes(graph)
    for pair, alts in enumerate(routes):
        if not alts:
            continue
        w = 1.0 / len(alts)
        for r in alts:
            for l in r:
                R[pair, l] += w
    R.setflags(write=False)
    return R


def _route_shares(
    graph: LinkGraph, alts: tuple[tuple[int, ...], ...]
) -> list[float]:
    """Bottleneck-proportional flow shares over a route set: route ``r``
    carries ``bottleneck(r) / sum_r' bottleneck(r')`` of the pair's flow.
    Equal bottlenecks reduce to the even ``1/k`` split."""
    widths = [
        min((graph.link_bw[l] for l in r), default=float("inf")) for r in alts
    ]
    total = sum(widths)
    return [w / total for w in widths]


@lru_cache(maxsize=128)
def _route_incidence_bottleneck(graph: LinkGraph) -> np.ndarray:
    """Unequal ECMP: split each pair's flow over ALL its equal-hop
    shortest routes, weighted by route bottleneck bandwidth — a route
    whose narrowest link is 10x thinner carries 10x less flow, instead of
    being either excluded (widest-tie equal split) or charged evenly."""
    n = graph.n_nodes
    R = np.zeros((n * n, graph.n_links), np.float32)
    routes = all_shortest_routes(graph)
    for pair, alts in enumerate(routes):
        if not alts:
            continue
        for r, share in zip(alts, _route_shares(graph, alts)):
            for l in r:
                R[pair, l] += share
    R.setflags(write=False)
    return R


def _walk_directions(graph: LinkGraph, src: int, route: tuple[int, ...]):
    """Yield ``(link, direction)`` along ``route`` from ``src``: direction
    0 traverses the link low-id -> high-id, 1 the reverse."""
    at = src
    for l in route:
        a, b = graph.link_ends[l]
        if at == a:
            yield l, 0
            at = b
        else:
            yield l, 1
            at = a


@lru_cache(maxsize=128)
def _directed_route_incidence(
    graph: LinkGraph, *, multipath: bool, weighting: str = "equal"
) -> np.ndarray:
    n = graph.n_nodes
    R = np.zeros((n * n, 2 * graph.n_links), np.float32)
    for i in range(n):
        for j in range(n):
            if not multipath:
                alts = (graph.route(i, j),)
            elif weighting == "bottleneck":
                alts = graph.all_shortest_routes_of(i, j)
            else:
                alts = graph.all_routes(i, j)
            alts = tuple(r for r in alts if r)
            if not alts:
                continue
            if multipath and weighting == "bottleneck":
                shares = _route_shares(graph, alts)
            else:
                shares = [1.0 / len(alts)] * len(alts)
            for r, share in zip(alts, shares):
                for l, d in _walk_directions(graph, i, r):
                    R[i * n + j, 2 * l + d] += share
    R.setflags(write=False)
    return R


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def _shortest_routes(
    n: int,
    link_ends: Sequence[tuple[int, int]],
    link_bw: Sequence[float] | None = None,
) -> tuple[tuple[int, ...], ...]:
    """BFS hop-count routing for every ordered pair, with bandwidth-aware
    tie-breaking: among equal-hop shortest paths the route with the largest
    bottleneck link bandwidth wins (widest-shortest path).  Remaining ties
    break deterministically toward the smallest-id predecessor in the
    previous BFS layer, then the smallest link id — with uniform link
    bandwidths (or ``link_bw=None``) this is exactly the old
    smallest-predecessor rule, so routing tables are reproducible across
    processes and unchanged for unweighted topologies."""
    widths = (
        [float("inf")] * len(link_ends) if link_bw is None else [float(b) for b in link_bw]
    )
    adj: list[list[tuple[int, int]]] = [[] for _ in range(n)]  # node -> (nbr, link)
    for l, (i, j) in enumerate(link_ends):
        adj[i].append((j, l))
        adj[j].append((i, l))
    for nbrs in adj:
        nbrs.sort()

    routes: list[tuple[int, ...]] = []
    for src in range(n):
        dist = {src: 0}
        order: list[int] = []  # nodes in (layer, id) order — DP dependencies first
        frontier = [src]
        while frontier:
            nxt: list[int] = []
            for u in frontier:
                for v, _ in adj[u]:
                    if v not in dist:
                        dist[v] = dist[u] + 1
                        nxt.append(v)
            nxt = sorted(set(nxt))
            order.extend(nxt)
            frontier = nxt
        # Widest-path DP over the BFS layering: a node's route width is the
        # best min(predecessor width, entering link bandwidth) over the
        # previous layer, ties preferring (smallest pred id, smallest link).
        width = {src: float("inf")}
        prev: dict[int, tuple[int, int]] = {}  # node -> (prev node, link)
        for v in order:
            best: tuple[float, int, int] | None = None
            for u, l in adj[v]:
                if dist.get(u) == dist[v] - 1:
                    key = (-min(width[u], widths[l]), u, l)
                    if best is None or key < best:
                        best = key
            assert best is not None  # v was discovered from the previous layer
            width[v] = -best[0]
            prev[v] = (best[1], best[2])
        for dst in range(n):
            if dst == src:
                routes.append(())
                continue
            if dst not in dist:
                raise ValueError(f"node {dst} unreachable from {src}")
            path: list[int] = []
            at = dst
            while at != src:
                at, l = prev[at]
                path.append(l)
            routes.append(tuple(reversed(path)))
    return tuple(routes)


@lru_cache(maxsize=64)
def all_widest_routes(graph: LinkGraph) -> tuple[tuple[tuple[int, ...], ...], ...]:
    """For every ordered pair, ALL shortest (equal-hop) routes whose
    bottleneck bandwidth equals the widest-shortest optimum — the route set
    multipath flow splits over.  Routes enumerate in deterministic
    (predecessor-id, link-id) order, so the fractional incidence matrices
    are reproducible across processes; with no ties the set is exactly the
    singleton primary route.  Intended for the small graphs this repo
    models (the shortest-path DAG of a ``k``-dim torus has combinatorially
    many corner-to-corner routes; the fractional matrices are cached per
    graph)."""
    n = graph.n_nodes
    widths = [float(b) for b in graph.link_bw]
    adj: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for l, (i, j) in enumerate(graph.link_ends):
        adj[i].append((j, l))
        adj[j].append((i, l))
    for nbrs in adj:
        nbrs.sort()

    out: list[tuple[tuple[int, ...], ...]] = []
    for src in range(n):
        dist = {src: 0}
        order: list[int] = []
        frontier = [src]
        while frontier:
            nxt: list[int] = []
            for u in frontier:
                for v, _ in adj[u]:
                    if v not in dist:
                        dist[v] = dist[u] + 1
                        nxt.append(v)
            nxt = sorted(set(nxt))
            order.extend(nxt)
            frontier = nxt
        # best achievable bottleneck width per node (same DP as the router)
        width = {src: float("inf")}
        for v in order:
            width[v] = max(
                min(width[u], widths[l])
                for u, l in adj[v]
                if dist.get(u) == dist[v] - 1
            )
        # enumerate every shortest route achieving width[dst], memoized over
        # (node, required bottleneck): a route through predecessor u via
        # link l has bottleneck width[dst] iff min(prefix, widths[l]) can
        # still reach it.
        memo: dict[int, tuple[tuple[int, ...], ...]] = {src: ((),)}

        def routes_to(v: int) -> tuple[tuple[int, ...], ...]:
            got = memo.get(v)
            if got is not None:
                return got
            target = width[v]
            acc: list[tuple[int, ...]] = []
            for u, l in adj[v]:
                if dist.get(u) != dist[v] - 1:
                    continue
                if min(width[u], widths[l]) < target:
                    continue  # this arm cannot carry the optimal bottleneck
                for prefix in routes_to(u):
                    if min((widths[k] for k in prefix), default=float("inf")) >= target:
                        acc.append(prefix + (l,))
            memo[v] = tuple(acc)
            return memo[v]

        for dst in range(n):
            if dst == src:
                out.append(())
            elif dst not in dist:
                raise ValueError(f"node {dst} unreachable from {src}")
            else:
                out.append(routes_to(dst))
    return tuple(out)


@lru_cache(maxsize=64)
def all_shortest_routes(graph: LinkGraph) -> tuple[tuple[tuple[int, ...], ...], ...]:
    """For every ordered pair, ALL equal-hop shortest routes — no
    bottleneck filtering (superset of :func:`all_widest_routes` per pair).
    This is the route set unequal (bottleneck-weighted) multipath splits
    over: a route through a thin link stays in the set and carries a
    proportionally small share, where the widest-tie set would drop it
    entirely.  Deterministic (predecessor-id, link-id) enumeration order,
    same caveats on combinatorial torus route counts as
    :func:`all_widest_routes`."""
    n = graph.n_nodes
    adj: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for l, (i, j) in enumerate(graph.link_ends):
        adj[i].append((j, l))
        adj[j].append((i, l))
    for nbrs in adj:
        nbrs.sort()

    out: list[tuple[tuple[int, ...], ...]] = []
    for src in range(n):
        dist = {src: 0}
        frontier = [src]
        while frontier:
            nxt: list[int] = []
            for u in frontier:
                for v, _ in adj[u]:
                    if v not in dist:
                        dist[v] = dist[u] + 1
                        nxt.append(v)
            frontier = sorted(set(nxt))
        memo: dict[int, tuple[tuple[int, ...], ...]] = {src: ((),)}

        def routes_to(v: int) -> tuple[tuple[int, ...], ...]:
            got = memo.get(v)
            if got is not None:
                return got
            acc: list[tuple[int, ...]] = []
            for u, l in adj[v]:
                if dist.get(u) != dist[v] - 1:
                    continue
                for prefix in routes_to(u):
                    acc.append(prefix + (l,))
            memo[v] = tuple(acc)
            return memo[v]

        for dst in range(n):
            if dst == src:
                out.append(())
            elif dst not in dist:
                raise ValueError(f"node {dst} unreachable from {src}")
            else:
                out.append(routes_to(dst))
    return tuple(out)


def _as_bw_list(link_bw, n_links: int, what: str) -> list[float]:
    """Canonicalize a scalar / sequence / array of link bandwidths to a
    plain list of python floats (array-valued input stays hashable)."""
    arr = np.asarray(link_bw, np.float64)
    if arr.ndim == 0:
        return [float(arr)] * n_links
    flat = [float(v) for v in arr.reshape(-1)]
    if len(flat) != n_links:
        raise ValueError(f"{what}: expected {n_links} bandwidths, got {len(flat)}")
    return flat


def _build(name: str, n: int, ends: list[tuple[int, int]], bws: list[float]) -> LinkGraph:
    graph = LinkGraph(
        name=name,
        n_nodes=n,
        link_ends=tuple(ends),
        link_bw=tuple(bws),
        routes=_shortest_routes(n, ends, bws),
    )
    graph.validate()
    return graph


def from_bandwidth_matrix(name: str, bw: np.ndarray) -> LinkGraph:
    """Build a graph from a symmetric ``(n, n)`` link-bandwidth matrix
    (0 = no link) — the natural form for measured machines.  Accepts any
    array-like; values are canonicalized to python floats."""
    bw = np.asarray(bw, np.float64)
    if bw.ndim != 2 or bw.shape[0] != bw.shape[1]:
        raise ValueError(f"need a square matrix, got shape {bw.shape}")
    if not np.allclose(bw, bw.T):
        raise ValueError("link bandwidth matrix must be symmetric")
    if (bw < 0).any():
        raise ValueError("link bandwidths must be >= 0 (0 = no link)")
    n = bw.shape[0]
    ends = [(i, j) for i in range(n) for j in range(i + 1, n) if bw[i, j] > 0]
    bws = [float(bw[i, j]) for i, j in ends]
    return _build(name, n, ends, bws)


# ---------------------------------------------------------------------------
# Calibration support: parameter <-> link-matrix packing and fitted rebuilds
# ---------------------------------------------------------------------------


class LinkGroups(NamedTuple):
    """Parameter↔matrix packing for fitting link bandwidths.

    ``groups`` partitions a graph's link ids into tied classes: every
    link in a group shares one free parameter (the symmetry/structure mask
    of the inverse problem — e.g. a glued 8-socket machine's 12 QPI links
    are one hardware part, its 4 node-controller links another; a 2D
    torus's row links one ICI class, its column links another).  The
    untied parameterization is ``n_links`` singleton groups.  ``pack``
    reduces per-link values to the free-parameter vector; ``unpack``
    scatters a parameter vector back to per-link order.  Both work on
    numpy and traced JAX arrays (``unpack`` is a pure gather), so the
    packing layer sits inside a jitted objective.
    """

    groups: tuple[tuple[int, ...], ...]

    @property
    def n_params(self) -> int:
        return len(self.groups)

    @property
    def n_links(self) -> int:
        return sum(len(g) for g in self.groups)

    def link_index(self) -> np.ndarray:
        """``(n_links,)`` free-parameter id of every link."""
        idx = np.zeros((self.n_links,), np.int32)
        for p, group in enumerate(self.groups):
            for l in group:
                idx[l] = p
        return idx

    def pack(self, link_bw) -> np.ndarray:
        """Per-link values -> ``(n_params,)`` group means."""
        bw = np.asarray(link_bw, np.float64)
        return np.array([bw[list(g)].mean() for g in self.groups])

    def unpack(self, params):
        """``(n_params,)`` free parameters -> per-link values (a gather:
        differentiable, vmappable)."""
        return params[self.link_index()]

    def validate(self) -> None:
        seen = sorted(l for g in self.groups for l in g)
        if seen != list(range(len(seen))):
            raise ValueError("groups must partition the link ids exactly")
        if any(not g for g in self.groups):
            raise ValueError("empty link group")


def link_groups(graph: LinkGraph, *, tie_equal_bw: bool = False) -> LinkGroups:
    """The natural parameterization of a graph's link bandwidths.

    With ``tie_equal_bw`` links whose *template* bandwidths are equal share
    one parameter (structural knowledge: same physical link class);
    otherwise every link is free.  Fitting stays well-posed either way —
    ties just let a link that never saturates in the sample set inherit
    its class's recovered capacity."""
    if not tie_equal_bw:
        groups = tuple((l,) for l in range(graph.n_links))
    else:
        by_bw: dict[float, list[int]] = {}
        for l, bw in enumerate(graph.link_bw):
            by_bw.setdefault(float(bw), []).append(l)
        groups = tuple(tuple(ls) for _, ls in sorted(by_bw.items()))
    out = LinkGroups(groups=groups)
    out.validate()
    return out


def from_fit(template: LinkGraph, link_bw, *, name: str | None = None) -> LinkGraph:
    """Rebuild a graph from fitted per-link bandwidths, holding the
    template's link list AND routing tables static — the contract of the
    calibration inverse problem (§ the forward model's routes are
    compile-time structure; only capacities are free parameters).  Values
    are canonicalized to python floats so the result stays hashable, and
    the template's class is preserved (a ``numa.topology.Topology``
    template yields a ``Topology``, keeping fingerprints in-domain)."""
    bws = _as_bw_list(link_bw, template.n_links, "from_fit")
    graph = type(template)(
        name=template.name if name is None else name,
        n_nodes=template.n_nodes,
        link_ends=template.link_ends,
        link_bw=tuple(bws),
        routes=template.routes,
    )
    graph.validate()
    return graph


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def fully_connected(n: int, link_bw) -> LinkGraph:
    """Every node pair directly linked (2-socket machines, fully
    QPI-meshed quad Haswell-EX, an NVLink-switched island).  Links
    enumerate in upper-triangle order, matching the scalar-pair model's
    resource layout exactly."""
    ends = [(i, j) for i in range(n) for j in range(i + 1, n)]
    bws = _as_bw_list(link_bw, len(ends), "fully_connected")
    return _build(f"fc{n}", n, ends, bws)


def ring(n: int, link_bw) -> LinkGraph:
    """Nodes on a bidirectional ring — the worst-case hop spread
    (diameter ``n // 2``), and the 1D torus of a single ICI axis."""
    if n < 2:
        raise ValueError("ring needs >= 2 nodes")
    ends = sorted(tuple(sorted((i, (i + 1) % n))) for i in range(n))
    ends = list(dict.fromkeys(ends))  # n == 2: one link, not two
    bws = _as_bw_list(link_bw, len(ends), "ring")
    return _build(f"ring{n}", n, ends, bws)


def _grid_ends(dims: tuple[int, ...], *, wrap: bool) -> list[tuple[int, int]]:
    """Nearest-neighbour links of a row-major ``dims`` grid, optionally
    with wraparound (torus) links, deduplicated (a wrapped length-2 axis
    would repeat its grid link)."""
    strides = [1] * len(dims)
    for k in range(len(dims) - 2, -1, -1):
        strides[k] = strides[k + 1] * dims[k + 1]
    ends: list[tuple[int, int]] = []
    for u in range(int(np.prod(dims))):
        coord = [(u // strides[k]) % dims[k] for k in range(len(dims))]
        for k, size in enumerate(dims):
            if size < 2:
                continue
            if coord[k] + 1 < size:
                ends.append((u, u + strides[k]))
            elif wrap:
                v = u - (size - 1) * strides[k]
                ends.append(tuple(sorted((u, v))))
    ends = sorted(dict.fromkeys(ends))
    return ends


def mesh2d(rows: int, cols: int, link_bw) -> LinkGraph:
    """Nodes on a ``rows x cols`` grid with nearest-neighbour links
    (SGI/HPE hypercube-ish blades flattened to 2D)."""
    n = rows * cols
    if n < 2:
        raise ValueError("mesh2d needs >= 2 nodes")
    ends = _grid_ends((rows, cols), wrap=False)
    bws = _as_bw_list(link_bw, len(ends), "mesh2d")
    return _build(f"mesh{rows}x{cols}", n, ends, bws)


def torus2d(rows: int, cols: int, link_bw) -> LinkGraph:
    """``rows x cols`` grid with wraparound links in both axes — the ICI
    2D torus of a TPU v5e-class slice.  Length-2 axes contribute a single
    link per pair (wrap deduplicated)."""
    n = rows * cols
    if n < 2:
        raise ValueError("torus2d needs >= 2 nodes")
    ends = _grid_ends((rows, cols), wrap=True)
    bws = _as_bw_list(link_bw, len(ends), "torus2d")
    return _build(f"torus{rows}x{cols}", n, ends, bws)


def torus3d(x: int, y: int, z: int, link_bw) -> LinkGraph:
    """``x * y * z`` 3D torus — the ICI fabric of a v4/v5p-class cube."""
    n = x * y * z
    if n < 2:
        raise ValueError("torus3d needs >= 2 nodes")
    ends = _grid_ends((x, y, z), wrap=True)
    bws = _as_bw_list(link_bw, len(ends), "torus3d")
    return _build(f"torus{x}x{y}x{z}", n, ends, bws)


def tree(n: int, link_bw, *, branching: int = 2) -> LinkGraph:
    """A balanced ``branching``-ary tree over ``n`` nodes (node ``i``'s
    parent is ``(i - 1) // branching``) — switch-hierarchy fabrics where
    every cross-subtree pair funnels through shared uplinks."""
    if n < 2:
        raise ValueError("tree needs >= 2 nodes")
    if branching < 1:
        raise ValueError("tree needs branching >= 1")
    ends = sorted((min(i, (i - 1) // branching), max(i, (i - 1) // branching))
                  for i in range(1, n))
    bws = _as_bw_list(link_bw, len(ends), "tree")
    return _build(f"tree{n}b{branching}", n, ends, bws)


def glued(
    n_islands: int,
    island_size: int,
    intra_bw,
    glue_bw,
    *,
    ring_islands: bool = False,
) -> LinkGraph:
    """``n_islands`` fully-meshed islands of ``island_size`` nodes glued by
    twin links: node ``i`` of island ``a`` reaches its twin in island
    ``a + 1`` (and island 0, when ``ring_islands`` — deduplicated for 2
    islands).  This is the glued-socket node-controller shape of Haswell-EX
    8-socket machines AND the multi-host accelerator shape (NVLink island
    per host, host interconnect between): cross-island non-twin pairs route
    over 2 hops, charging an intra link and a glue link — the bandwidth
    cliff a scalar interconnect constant cannot express."""
    if n_islands < 2:
        raise ValueError("glued needs >= 2 islands")
    if island_size < 1:
        raise ValueError("glued needs >= 1 node per island")
    ends: list[tuple[int, int]] = []
    bws: list[float] = []
    for a in range(n_islands):
        base = a * island_size
        for i in range(island_size):
            for j in range(i + 1, island_size):
                ends.append((base + i, base + j))
                bws.append(0.0)  # placeholder, filled below
    n_intra = len(ends)
    intra = _as_bw_list(intra_bw, n_intra, "glued intra_bw")
    bws = list(intra)
    glue_pairs: list[tuple[int, int]] = []
    last = n_islands if ring_islands and n_islands > 2 else n_islands - 1
    for a in range(last):
        b = (a + 1) % n_islands
        for i in range(island_size):
            glue_pairs.append(
                tuple(sorted((a * island_size + i, b * island_size + i)))
            )
    glue = _as_bw_list(glue_bw, len(glue_pairs), "glued glue_bw")
    ends.extend(glue_pairs)
    bws.extend(glue)
    order = sorted(range(len(ends)), key=lambda k: ends[k])
    ends = [ends[k] for k in order]
    bws = [bws[k] for k in order]
    return _build(f"glued{n_islands}x{island_size}", n_islands * island_size, ends, bws)


def snc(
    sockets: int, nodes_per_socket: int, *, qpi_bw: float, intra_bw: float
) -> LinkGraph:
    """Sub-NUMA clustering (SNC / Cluster-on-Die): each socket splits into
    ``nodes_per_socket`` NUMA nodes joined by fast intra-socket (in-die
    mesh) links, while each socket's FIRST node is its interconnect
    endpoint and the endpoints are fully QPI-meshed.  Cross-socket traffic
    from a non-endpoint node routes through its socket's endpoint, so both
    of a socket's nodes *share* the one QPI port — the SNC reality a
    per-socket machine model cannot express.  With ``nodes_per_socket=1``
    this degenerates to :func:`fully_connected`."""
    if sockets < 2:
        raise ValueError("snc needs >= 2 sockets")
    if nodes_per_socket < 1:
        raise ValueError("snc needs >= 1 node per socket")
    ends: list[tuple[int, int]] = []
    bws: list[float] = []
    for s in range(sockets):
        base = s * nodes_per_socket
        for i in range(nodes_per_socket):
            for j in range(i + 1, nodes_per_socket):
                ends.append((base + i, base + j))
                bws.append(float(intra_bw))
    for a in range(sockets):
        for b in range(a + 1, sockets):
            ends.append((a * nodes_per_socket, b * nodes_per_socket))
            bws.append(float(qpi_bw))
    order = sorted(range(len(ends)), key=lambda k: ends[k])
    ends = [ends[k] for k in order]
    bws = [bws[k] for k in order]
    n = sockets * nodes_per_socket
    return _build(f"snc{sockets}x{nodes_per_socket}", n, ends, bws)
