"""A JAX-native NUMA machine simulator.

Real Haswell boxes and PCM counters are unavailable in this environment, so
the paper's experimental substrate is rebuilt as a simulator that

* solves the max-min-fair bandwidth-saturation steady state of a
  parameterized multi-socket machine (progressive filling over banks,
  hop-attenuated remote paths, the per-link routed interconnect topology
  and core issue rates), and
* emits exactly the counters the paper's method reads (bank-perspective
  local/remote reads/writes + per-socket instructions + elapsed time),
  with configurable measurement noise and background traffic.

The two evaluation machines are parameterized from the paper's Figure 2
bandwidth ratios.  Everything is ``jit``/``vmap``-able so the paper's
"thousands of measurements" evaluation runs as a single batched call.
"""

from repro.core.numa.topology import (
    Topology,
    LinkGroups,
    from_bandwidth_matrix,
    from_fit,
    fully_connected,
    glued_8s,
    link_groups,
    mesh2d,
    ring,
    snc,
)
from repro.core.numa.machine import (
    MachineSpec,
    canonical_bank_assignment,
    E5_2630_V3,
    E5_2630_V3_MIXED_DIMM,
    E5_2630_V3_THROTTLED,
    E5_2699_V3,
    E5_2699_V3_SNC2,
    E7_4830_V3,
    E7_8860_V3,
    MACHINES,
    make_machine,
)
from repro.core.numa.workload import Workload, pure_workload, mixed_workload
from repro.core.numa.simulator import (
    SimulationResult,
    machine_caps,
    simulate,
    simulate_counters,
    simulate_reference,
    profile_pair,
    symmetric_placement,
    asymmetric_placement,
    thread_class_starts,
)
from repro.core.numa.search import (
    SearchResult,
    advisor_warm_seeds,
    branch_and_bound,
    exact_objectives,
    optimize_placement,
    placement_upper_bound,
    relaxed_work_rate,
)
from repro.core.numa.temporal import (
    MigrationModel,
    Phase,
    PhasedWorkload,
    Schedule,
    ScheduleSearchResult,
    evaluate_schedule,
    follow_banks,
    optimize_schedule,
    phased_workload,
    transition_cost,
)
from repro.core.numa.calibrate import (
    CalibrationParams,
    CalibrationResult,
    CalibrationSamples,
    blind_template,
    collect_sweep,
    fit_from_simulated,
    fit_machine,
    link_relative_errors,
    local_bw_relative_errors,
    probe_suite,
    samples_from_counters,
    seed_parameters,
)

__all__ = [
    "Topology",
    "LinkGroups",
    "from_bandwidth_matrix",
    "from_fit",
    "fully_connected",
    "glued_8s",
    "link_groups",
    "mesh2d",
    "ring",
    "snc",
    "MachineSpec",
    "canonical_bank_assignment",
    "E5_2630_V3",
    "E5_2630_V3_MIXED_DIMM",
    "E5_2630_V3_THROTTLED",
    "E5_2699_V3",
    "E5_2699_V3_SNC2",
    "E7_4830_V3",
    "E7_8860_V3",
    "MACHINES",
    "make_machine",
    "Workload",
    "pure_workload",
    "mixed_workload",
    "SimulationResult",
    "machine_caps",
    "simulate",
    "simulate_counters",
    "simulate_reference",
    "thread_class_starts",
    "profile_pair",
    "symmetric_placement",
    "asymmetric_placement",
    "SearchResult",
    "advisor_warm_seeds",
    "branch_and_bound",
    "exact_objectives",
    "optimize_placement",
    "placement_upper_bound",
    "relaxed_work_rate",
    "MigrationModel",
    "Phase",
    "PhasedWorkload",
    "Schedule",
    "ScheduleSearchResult",
    "evaluate_schedule",
    "follow_banks",
    "optimize_schedule",
    "phased_workload",
    "transition_cost",
    "CalibrationParams",
    "CalibrationResult",
    "CalibrationSamples",
    "blind_template",
    "collect_sweep",
    "fit_from_simulated",
    "fit_machine",
    "link_relative_errors",
    "local_bw_relative_errors",
    "probe_suite",
    "samples_from_counters",
    "seed_parameters",
]
