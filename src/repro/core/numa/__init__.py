"""A JAX-native NUMA machine simulator.

Real Haswell boxes and PCM counters are unavailable in this environment, so
the paper's experimental substrate is rebuilt as a simulator that

* solves the max-min-fair bandwidth-saturation steady state of a
  parameterized multi-socket machine (progressive filling over banks,
  hop-attenuated remote paths, the per-link routed interconnect topology
  and core issue rates), and
* emits exactly the counters the paper's method reads (bank-perspective
  local/remote reads/writes + per-socket instructions + elapsed time),
  with configurable measurement noise and background traffic.

The two evaluation machines are parameterized from the paper's Figure 2
bandwidth ratios.  Everything is ``jit``/``vmap``-able so the paper's
"thousands of measurements" evaluation runs as a single batched call.
"""

from repro.core.numa.topology import (
    Topology,
    from_bandwidth_matrix,
    fully_connected,
    glued_8s,
    mesh2d,
    ring,
    snc,
)
from repro.core.numa.machine import (
    MachineSpec,
    E5_2630_V3,
    E5_2630_V3_THROTTLED,
    E5_2699_V3,
    E5_2699_V3_SNC2,
    E7_4830_V3,
    E7_8860_V3,
    MACHINES,
    make_machine,
)
from repro.core.numa.workload import Workload, pure_workload, mixed_workload
from repro.core.numa.simulator import (
    SimulationResult,
    simulate,
    simulate_counters,
    profile_pair,
    symmetric_placement,
    asymmetric_placement,
)

__all__ = [
    "Topology",
    "from_bandwidth_matrix",
    "fully_connected",
    "glued_8s",
    "mesh2d",
    "ring",
    "snc",
    "MachineSpec",
    "E5_2630_V3",
    "E5_2630_V3_THROTTLED",
    "E5_2699_V3",
    "E5_2699_V3_SNC2",
    "E7_4830_V3",
    "E7_8860_V3",
    "MACHINES",
    "make_machine",
    "Workload",
    "pure_workload",
    "mixed_workload",
    "SimulationResult",
    "simulate",
    "simulate_counters",
    "profile_pair",
    "symmetric_placement",
    "asymmetric_placement",
]
