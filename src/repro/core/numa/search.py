"""Placement *search* — find the best thread placement without sweeping.

The composition space explodes past ~8 NUMA nodes (SNC-2 on an 8-socket
box is 16 nodes), so exhaustive :func:`repro.core.numa.evaluate.
sweep_placements` + ``evaluate_batch`` stops being an option exactly where
the paper's consumers (Pandia-style predictors, Smart Arrays) need answers
the fastest.  Two escapes, both driving the same grouped max-min solver
that powers the sweep:

* :func:`optimize_placement` — **relaxed gradient ascent**.  Fractional
  node thread-counts are parameterized as ``n_threads * softmax(logits)``
  and pushed through a continuous relaxation of the structured shared-slab
  fill (:func:`repro.core.numa.simulator._progressive_fill_structured`
  with the fixed-count loop, which is reverse-differentiable).  Multi-start
  AdamW (``repro.optim.adamw``) climbs predicted work rate, then the
  fractional optimum is rounded (largest remainder, cap-aware) and
  polished by exact single-thread moves.

* :func:`branch_and_bound` — **provably (1+gap)-optimal search** over
  compositions.  Thread->node assignment is contiguous, so a search node
  is a prefix ``(n_1 .. n_j)``; the upper bound combines the prefix's
  admissible per-group value with a suffix DP over the remaining nodes
  (see :func:`placement_upper_bound`).  Best-first expansion with an
  incumbent from cheap heuristic placements; leaves are exactly evaluated
  in jitted batches.

The admissible bound deserves a note: the mesh advisor's signature-only
worst-utilization roofline (``rank_numa_placements``) is a *ranking*
heuristic, not an upper bound — progressive filling lets unfrozen groups
keep climbing after the first bottleneck saturates, so the true work rate
can exceed ``n * min(1, 1/worst_util)``.  The bound used here is instead
built from per-group *isolated* rates: a (class c, node k) group's shared
rate in ANY placement is at most ``min(1, min_r cap_r / u_lower(c,k,r))``
where ``u_lower`` keeps only the placement-independent slab components
(static + local rows) plus the own-node per-thread (``>= 1/n``) and
interleave (``>= 1/s``) floors — every term only shrinks relative to the
real usage, and max-min filling never rates a group above its isolated
ceiling.  Summed with per-group totals clipped at ``cap_r / u_lower``
(a group of ``m`` threads moves at most ``cap/u`` regardless of ``m``),
this dominates the simulated work rate placement-for-placement.

Objective: total instruction rate (``instructions.sum()`` — thread rates
weighted by their node's issue rate), so heterogeneous (throttled /
big.LITTLE) machines optimize real work, not thread-rate count.
"""

from __future__ import annotations

import heapq
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.numa.machine import MachineSpec, canonical_bank_assignment
from repro.core.numa.simulator import (
    _group_multiplicities,
    _progressive_fill_structured,
    group_slab_components,
    pad_rows,
    simulate_grouped_batch,
    split_caps,
    thread_class_starts,
)
from repro.core.numa.workload import Workload
from repro.optim import adamw


class SearchResult(NamedTuple):
    """One found placement plus the effort receipts."""

    placement: tuple[int, ...]  # threads per NUMA node
    objective: float  # instructions/s of `placement` (exact simulation)
    evaluations: int  # exact batched-simulator placements evaluated
    nodes_expanded: int  # B&B tree nodes popped (0 for the optimizer)
    optimal: bool  # True iff B&B exhausted the tree within `gap`


def _classes_for(workload: Workload, thread_classes) -> tuple[int, ...]:
    return (
        thread_class_starts([workload])
        if thread_classes is None
        else tuple(int(v) for v in thread_classes)
    )


# ---------------------------------------------------------------------------
# Exact batched evaluation (shared by both modes and by tests)
# ---------------------------------------------------------------------------


@partial(
    jax.jit, static_argnames=("machine", "thread_classes", "bank_assignment")
)
def _objective_batch_jit(
    machine, wl_arrays, placements, thread_classes, bank_assignment=None
):
    # one bucket per placement: fixed shapes for any placement batch, so
    # the search loop reuses a single trace per padded batch size
    wl = Workload("search", *wl_arrays)
    sim = simulate_grouped_batch(
        machine,
        wl,
        placements,
        thread_classes=thread_classes,
        support=(placements > 0).astype(jnp.int32),
        slab_id=jnp.arange(placements.shape[0], dtype=jnp.int32),
        bank_assignment=bank_assignment,
    )
    return sim.instructions.sum(axis=1)


def exact_objectives(
    machine: MachineSpec,
    workload: Workload,
    placements,
    *,
    thread_classes: tuple[int, ...] | None = None,
    bank_assignment=None,
) -> np.ndarray:
    """Simulated work rate (instructions/s) of each placement — the ground
    truth both search modes optimize, batched through one jitted trace per
    padded batch size (rows padded by repetition, so no retrace churn).

    ``bank_assignment`` prices one page placement for the whole batch
    (``None`` = node-local): the scheduler's "threads moved, pages
    stayed" candidates are scored through this hook."""
    classes = _classes_for(workload, thread_classes)
    p = np.asarray(placements, np.int32)
    if p.ndim == 1:
        p = p[None, :]
    n_rows = p.shape[0]
    out = _objective_batch_jit(
        machine,
        tuple(workload[1:]),
        jnp.asarray(pad_rows(p)),
        classes,
        canonical_bank_assignment(machine, bank_assignment),
    )
    return np.asarray(out)[:n_rows]


# ---------------------------------------------------------------------------
# Relaxed continuous objective (differentiable)
# ---------------------------------------------------------------------------


def _continuous_multiplicities(
    class_starts: tuple[int, ...], n: int, p: Array
) -> Array:
    """:func:`repro.core.numa.simulator._group_multiplicities` for
    *fractional* node counts: the interval-overlap is piecewise linear in
    ``p``, so gradients flow."""
    bounds = jnp.asarray(class_starts + (n,), p.dtype)
    node_hi = jnp.cumsum(p)
    node_lo = node_hi - p
    lo = jnp.maximum(bounds[:-1, None], node_lo[None, :])
    hi = jnp.minimum(bounds[1:, None], node_hi[None, :])
    return jnp.maximum(hi - lo, 0.0)  # (C, s)


def relaxed_work_rate(
    machine: MachineSpec,
    workload: Workload,
    p: Array,
    *,
    thread_classes: tuple[int, ...] | None = None,
    tau: float = 0.25,
) -> Array:
    """Differentiable work rate of a *fractional* placement ``p`` (positive
    reals summing to ``n_threads``).  The hard support indicator becomes
    ``p / (p + tau)`` so emptying a node is a smooth event; at integer
    placements with ``tau -> 0`` this approaches the exact grouped solve."""
    classes = _classes_for(workload, thread_classes)
    s = machine.n_nodes
    n = workload.n_threads
    topo = machine.topology
    comps = group_slab_components(machine, workload, classes)
    C = comps.base_read.shape[0]
    G = C * s
    dtype = comps.base_read.dtype
    dense_caps, rr_caps, ww_caps = split_caps(machine)
    offdiag = (1.0 - jnp.eye(s, dtype=dtype))[None, :, :]
    n_links = topo.n_links
    iterations = min(G, 2 * s + 2 * s * s + n_links) + 1

    p = p.astype(dtype)
    pt_row = p / jnp.maximum(p.sum(), 1.0)
    used = p / (p + tau)
    il_row = used / jnp.maximum(used.sum(), 1.0)
    ru = (
        comps.base_read
        + comps.pt_read[:, :, None] * pt_row[None, None, :]
        + comps.il_read[:, :, None] * il_row[None, None, :]
    )
    wu = (
        comps.base_write
        + comps.pt_write[:, :, None] * pt_row[None, None, :]
        + comps.il_write[:, :, None] * il_row[None, None, :]
    )
    if n_links:
        inc = jnp.asarray(
            np.asarray(topo.route_incidence(), np.float32).reshape(s, s, n_links)
        )
        lu = jnp.einsum("ckj,kjl->ckl", (ru + wu) * offdiag, inc)
    else:
        lu = jnp.zeros((C, s, 0), dtype)
    dense = jnp.concatenate(
        [ru.reshape(G, s), wu.reshape(G, s), lu.reshape(G, n_links)], axis=1
    )
    mult = _continuous_multiplicities(classes, n, p)  # (C, s)
    x = _progressive_fill_structured(
        dense,
        ru * offdiag,
        wu * offdiag,
        mult.reshape(G),
        dense_caps,
        rr_caps,
        ww_caps,
        iterations,
        early_exit=False,  # keep the fixed loop: reverse-differentiable
    )
    node_rates = machine.node_rates().astype(dtype)
    return (mult * x.reshape(C, s) * node_rates[None, :]).sum()


# ---------------------------------------------------------------------------
# Mode (a): multi-start gradient ascent + round-and-polish
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("machine", "thread_classes", "steps", "lr", "tau"),
)
def _ascend_starts_jit(
    machine, wl_arrays, logits0, thread_classes, steps, lr, tau
):
    wl = Workload("search", *wl_arrays)
    n = wl.n_threads
    cap = float(machine.cores_per_node)
    scale = n * jnp.max(machine.node_rates())

    def loss(logits):
        p = n * jax.nn.softmax(logits)
        obj = relaxed_work_rate(
            machine, wl, p, thread_classes=thread_classes, tau=tau
        )
        over = jnp.maximum(p - cap, 0.0)
        return -(obj / scale) + 10.0 * jnp.sum(over * over)

    grad = jax.vmap(jax.grad(loss))
    params = {"logits": logits0}
    state = adamw.init(params)

    def step(carry, _):
        params, state = carry
        # the relaxed fill is only piecewise-smooth: at freeze boundaries a
        # start can emit non-finite cotangents — zero them instead of
        # poisoning the whole trajectory
        g = {"logits": jnp.nan_to_num(grad(params["logits"]), nan=0.0, posinf=0.0, neginf=0.0)}
        params, state = adamw.update(
            g, state, params, lr=lr, weight_decay=0.0
        )
        return (params, state), None

    (params, _), _ = jax.lax.scan(step, (params, state), None, length=steps)
    return n * jax.nn.softmax(params["logits"], axis=-1)


def _round_capped(p_cont: np.ndarray, n: int, cap: int) -> np.ndarray:
    """Largest-remainder rounding of a fractional placement onto the
    integer composition simplex with per-node caps."""
    q = np.clip(p_cont, 0.0, cap)
    base = np.floor(q).astype(np.int64)
    frac = q - base
    rem = n - int(base.sum())
    order = list(np.argsort(-frac))
    while rem > 0:
        for k in order:
            if rem == 0:
                break
            if base[k] < cap:
                base[k] += 1
                rem -= 1
    while rem < 0:
        for k in reversed(order):
            if rem == 0:
                break
            if base[k] > 0:
                base[k] -= 1
                rem += 1
    return base.astype(np.int32)


def _neighbours(p: np.ndarray, cap: int) -> list[np.ndarray]:
    """All single-thread moves (src with a thread, dst with headroom)."""
    s = p.shape[0]
    out = []
    for src in range(s):
        if p[src] == 0:
            continue
        for dst in range(s):
            if dst == src or p[dst] >= cap:
                continue
            q = p.copy()
            q[src] -= 1
            q[dst] += 1
            out.append(q)
    return out


def optimize_placement(
    machine: MachineSpec,
    workload: Workload,
    *,
    thread_classes: tuple[int, ...] | None = None,
    n_starts: int = 16,
    steps: int = 150,
    lr: float = 0.25,
    tau: float = 0.25,
    seed: int = 0,
    polish: bool = True,
    max_polish_passes: int | None = None,
) -> SearchResult:
    """Multi-start relaxed gradient ascent on predicted work rate, then
    round-and-polish: the fractional optima are snapped to integer
    compositions (largest remainder, cap-aware) and hill-climbed with
    exact single-thread moves.  Cost is independent of the composition
    count — this is the mode for 16+-node machines where enumeration is
    infeasible."""
    classes = _classes_for(workload, thread_classes)
    s = machine.n_nodes
    n = workload.n_threads
    cap = machine.cores_per_node
    if not 0 < n <= s * cap:
        raise ValueError(f"{n} threads do not fit {s} nodes x {cap} cores")

    rng = np.random.default_rng(seed)
    logits0 = np.zeros((n_starts, s), np.float32)
    # start 0: uniform spread; a few one-hot-ish packers; the rest random
    for i in range(1, min(n_starts, s + 1)):
        logits0[i, (i - 1) % s] = 3.0
    if n_starts > s + 1:
        logits0[s + 1 :] = rng.normal(0.0, 1.5, (n_starts - s - 1, s))
    p_frac = np.asarray(
        _ascend_starts_jit(
            machine,
            tuple(workload[1:]),
            jnp.asarray(logits0),
            classes,
            int(steps),
            float(lr),
            float(tau),
        )
    )

    seen: dict[tuple[int, ...], None] = {}
    uniform = np.full(s, n / s)
    for row in p_frac:
        if not np.all(np.isfinite(row)):  # a diverged start; fall back
            row = uniform
        seen.setdefault(tuple(int(v) for v in _round_capped(row, n, cap)), None)
    candidates = [np.asarray(c, np.int32) for c in seen]
    values = exact_objectives(
        machine, workload, np.stack(candidates), thread_classes=classes
    )
    evals = len(candidates)
    best_i = int(np.argmax(values))
    best, best_val = candidates[best_i], float(values[best_i])

    if polish:
        passes = 4 * s if max_polish_passes is None else max_polish_passes
        for _ in range(passes):
            moves = _neighbours(best, cap)
            if not moves:
                break
            vals = exact_objectives(
                machine, workload, np.stack(moves), thread_classes=classes
            )
            evals += len(moves)
            i = int(np.argmax(vals))
            if float(vals[i]) <= best_val * (1.0 + 1e-7):
                break
            best, best_val = moves[i], float(vals[i])

    return SearchResult(
        placement=tuple(int(v) for v in best),
        objective=best_val,
        evaluations=evals,
        nodes_expanded=0,
        optimal=False,
    )


# ---------------------------------------------------------------------------
# Mode (b): branch and bound with an admissible per-group roofline
# ---------------------------------------------------------------------------


def _group_rate_ceilings(
    machine: MachineSpec, workload: Workload, classes: tuple[int, ...]
) -> np.ndarray:
    """``(C, s)`` admissible per-thread rate ceiling ``cap_r / u_lower`` of
    a (class, node) group, *before* the demand clip at 1.0 (callers clip
    per-group totals instead: ``m`` threads move at most
    ``min(m, ceiling)``).  ``u_lower`` keeps only usage components every
    placement is guaranteed to charge — see the module docstring."""
    s = machine.n_nodes
    n = workload.n_threads
    comps = jax.tree.map(np.asarray, group_slab_components(machine, workload, classes))
    own = np.eye(s)[None, :, :]  # (1, s, s): the own-node bank column
    # own-node floors: pt_row[k] >= 1/n and il_row[k] >= 1/s whenever the
    # group exists (it holds at least one of the n threads; at most s
    # nodes are used) — every other pt/il contribution is bounded below
    # by zero and dropped
    ru = comps.base_read + (
        comps.pt_read[:, :, None] / n + comps.il_read[:, :, None] / s
    ) * own
    wu = comps.base_write + (
        comps.pt_write[:, :, None] / n + comps.il_write[:, :, None] / s
    ) * own

    dense_caps, rr_caps, ww_caps = (
        np.asarray(a, np.float64) for a in split_caps(machine)
    )
    bank_r = dense_caps[:s]
    bank_w = dense_caps[s : 2 * s]
    link_caps = dense_caps[2 * s :]
    offdiag = 1.0 - np.eye(s)

    with np.errstate(divide="ignore"):
        # bank capacities: usage row j vs cap j
        r_banks = np.where(ru > 0, bank_r[None, None, :] / np.maximum(ru, 1e-30), np.inf)
        w_banks = np.where(wu > 0, bank_w[None, None, :] / np.maximum(wu, 1e-30), np.inf)
        ceil = np.minimum(r_banks.min(axis=2), w_banks.min(axis=2))  # (C, s)
        # remote per-pair path capacities (diagonal caps are inf already)
        rr = np.where(
            ru * offdiag > 0,
            np.asarray(rr_caps)[None, :, :] / np.maximum(ru * offdiag, 1e-30),
            np.inf,
        )
        wwp = np.where(
            wu * offdiag > 0,
            np.asarray(ww_caps)[None, :, :] / np.maximum(wu * offdiag, 1e-30),
            np.inf,
        )
        ceil = np.minimum(ceil, np.minimum(rr.min(axis=2), wwp.min(axis=2)))
        if machine.n_links:
            inc = np.asarray(
                machine.topology.route_incidence(), np.float64
            ).reshape(s, s, machine.n_links)
            lu = np.einsum("ckj,kjl->ckl", (ru + wu) * offdiag, inc)
            links = np.where(
                lu > 0, link_caps[None, None, :] / np.maximum(lu, 1e-30), np.inf
            )
            ceil = np.minimum(ceil, links.min(axis=2))
    return ceil  # (C, s) in threads-at-full-rate units


class _BoundTables(NamedTuple):
    value: np.ndarray  # (s, n+1, cap+1) admissible value of t threads at
    #                    offset m on node j (thread->node order is contiguous)
    suffix: np.ndarray  # (s+1, n+1) best completion value from (node, offset)


def _bound_tables(
    machine: MachineSpec, workload: Workload, classes: tuple[int, ...]
) -> _BoundTables:
    s = machine.n_nodes
    n = workload.n_threads
    cap = machine.cores_per_node
    ceil = _group_rate_ceilings(machine, workload, classes)  # (C, s)
    rates = np.asarray(machine.node_rates(), np.float64)
    starts = np.asarray(classes + (n,), np.int64)
    C = len(classes)
    # cum[c, m] = threads of class c among the first m threads
    cum = np.zeros((C, n + 1), np.int64)
    for c in range(C):
        lo, hi = starts[c], starts[c + 1]
        cum[c] = np.clip(np.arange(n + 1), lo, hi) - lo

    value = np.zeros((s, n + 1, cap + 1))
    t_grid = np.arange(cap + 1)
    for j in range(s):
        acc = np.zeros((n + 1, cap + 1))
        for c in range(C):
            hi = cum[c][np.minimum(np.arange(n + 1)[:, None] + t_grid[None, :], n)]
            acc += np.minimum(hi - cum[c][:, None], ceil[c, j])
        value[j] = acc * rates[j]

    suffix = np.full((s + 1, n + 1), -np.inf)
    suffix[s, n] = 0.0
    for j in range(s - 1, -1, -1):
        for m in range(n + 1):
            t_max = min(cap, n - m)
            cand = value[j, m, : t_max + 1] + suffix[j + 1, m : m + t_max + 1]
            suffix[j, m] = cand.max() if cand.size else -np.inf
    return _BoundTables(value=value, suffix=suffix)


def placement_upper_bound(
    machine: MachineSpec,
    workload: Workload,
    placements,
    *,
    thread_classes: tuple[int, ...] | None = None,
) -> np.ndarray:
    """Admissible work-rate roofline of each placement: for every
    placement ``p``, ``bound(p) >= exact_objectives(p)`` (the branch-and-
    bound invariant; pinned by tests on random placements).  Vectorized
    host-side lookup into the same per-node value tables B&B prunes with."""
    classes = _classes_for(workload, thread_classes)
    tables = _bound_tables(machine, workload, classes)
    p = np.asarray(placements, np.int64)
    if p.ndim == 1:
        p = p[None, :]
    offs = np.concatenate(
        [np.zeros((p.shape[0], 1), np.int64), np.cumsum(p, axis=1)[:, :-1]], axis=1
    )
    s = machine.n_nodes
    out = np.zeros(p.shape[0])
    for j in range(s):
        out += tables.value[j, offs[:, j], p[:, j]]
    return out


def _heuristic_seeds(machine: MachineSpec, n: int) -> list[np.ndarray]:
    """Cheap incumbents: spread the threads as evenly as caps allow over
    the k fastest nodes, for every k that fits."""
    s = machine.n_nodes
    cap = machine.cores_per_node
    order = np.argsort(-np.asarray(machine.node_rates(), np.float64), kind="stable")
    seeds = []
    for k in range(1, s + 1):
        if k * cap < n:
            continue
        p = np.zeros(s, np.int64)
        chosen = order[:k]
        base, extra = divmod(n, k)
        if base >= cap and extra:
            continue
        for i, node in enumerate(chosen):
            p[node] = min(cap, base + (1 if i < extra else 0))
        if p.sum() == n:
            seeds.append(p.astype(np.int32))
    return seeds


def advisor_warm_seeds(
    machine: MachineSpec,
    workload: Workload,
    *,
    top_k: int = 8,
    max_placements: int = 4096,
    noise_std: float = 0.0,
    key=None,
) -> list[np.ndarray]:
    """Incumbent seeds from the advisor's *signature-only* ranking
    (:func:`repro.core.meshsig.advisor.rank_numa_placements`): the top-k
    placements by the cheap roofline score, to be evaluated *exactly* by
    the caller.  The ranking costs one cached 2-run fit plus a vmapped
    matrix pass over (a sample of) the composition space — no simulation
    per candidate — so it is a legitimate warm start even on machines
    whose spaces cannot be enumerated (``max_placements`` caps the ranked
    sample there).  The roofline is a heuristic, NOT admissible
    (:func:`repro.core.meshsig.advisor.numa_placement_bounds`): seeds only
    ever *raise* the incumbent, they never prune — so a warm start can
    never worsen the certificate.

    Returns no seeds when the thread count does not divide evenly over the
    nodes: the 2-run fit needs the symmetric profiling placement, so the
    ranking is unavailable and the caller falls back to its heuristic
    seeds alone."""
    from repro.core.meshsig.advisor import rank_numa_placements

    if workload.n_threads % machine.n_nodes != 0:
        return []
    ranked = rank_numa_placements(
        machine,
        workload,
        top_k=top_k,
        max_placements=max_placements,
        noise_std=noise_std,
        key=key,
    )
    return [np.asarray(r.placement, np.int32) for r in ranked]


def branch_and_bound(
    machine: MachineSpec,
    workload: Workload,
    *,
    thread_classes: tuple[int, ...] | None = None,
    gap: float = 0.0,
    max_nodes: int = 200_000,
    leaf_batch: int = 64,
    seed_placements: Sequence | None = None,
    advisor_seeds: int = 0,
    advisor_max_placements: int = 4096,
) -> SearchResult:
    """Best-first branch and bound over thread compositions.  Returns a
    placement whose exact work rate is within ``gap`` (relative) of the
    global optimum when the tree is exhausted (``optimal=True``); hitting
    ``max_nodes`` degrades gracefully to the incumbent.

    The tree assigns node counts left to right; a node's bound is its
    prefix value plus the suffix DP completion (both admissible — see
    :func:`placement_upper_bound`).  Leaves are evaluated exactly in
    jitted batches of ``leaf_batch``; pure-python everywhere else, so the
    search itself never compiles anything new.

    ``advisor_seeds > 0`` warm-starts the incumbent from the advisor's
    signature-only ranking (:func:`advisor_warm_seeds` top-k, evaluated
    exactly alongside the heuristic seeds).  A better initial incumbent
    tightens the prune level from the first pop, so the warm start can
    only shrink the expanded tree — it never loosens the certificate
    (seeds never prune; only exact evaluations move the incumbent)."""
    classes = _classes_for(workload, thread_classes)
    s = machine.n_nodes
    n = workload.n_threads
    cap = machine.cores_per_node
    if not 0 < n <= s * cap:
        raise ValueError(f"{n} threads do not fit {s} nodes x {cap} cores")
    tables = _bound_tables(machine, workload, classes)
    value, suffix = tables.value, tables.suffix

    seeds = [np.asarray(p, np.int32) for p in (seed_placements or [])]
    if advisor_seeds > 0:
        seeds.extend(
            advisor_warm_seeds(
                machine,
                workload,
                top_k=advisor_seeds,
                max_placements=advisor_max_placements,
            )
        )
    seeds.extend(_heuristic_seeds(machine, n))
    incumbent_p = seeds[0]
    vals = exact_objectives(machine, workload, np.stack(seeds), thread_classes=classes)
    evals = len(seeds)
    best_i = int(np.argmax(vals))
    incumbent_p, incumbent = seeds[best_i], float(vals[best_i])

    def prune_level() -> float:
        return incumbent * (1.0 + gap)

    # heap entries: (-bound, tiebreak, depth, offset, prefix_value, prefix)
    root_bound = suffix[0, 0]
    heap = [(-root_bound, 0, 0, 0, 0.0, ())]
    tiebreak = 1
    expanded = 0
    leaves: list[tuple[float, tuple[int, ...]]] = []
    exhausted = True

    def flush_leaves():
        nonlocal incumbent, incumbent_p, evals
        if not leaves:
            return
        batch = np.asarray([p for _, p in leaves], np.int32)
        vals = exact_objectives(machine, workload, batch, thread_classes=classes)
        evals += len(leaves)
        i = int(np.argmax(vals))
        if float(vals[i]) > incumbent:
            incumbent = float(vals[i])
            incumbent_p = batch[i]
        leaves.clear()

    while heap:
        neg_bound, _, depth, off, pval, prefix = heapq.heappop(heap)
        if -neg_bound <= prune_level():
            break  # best-first: nothing left can beat the incumbent
        if expanded >= max_nodes:
            exhausted = False
            break
        expanded += 1
        if depth == s - 1:
            # the last node count is forced; emit a leaf
            t = n - off
            if 0 <= t <= cap:
                leaves.append((pval + value[depth, off, t], prefix + (t,)))
                if len(leaves) >= leaf_batch:
                    flush_leaves()
            continue
        remaining_cap = (s - depth - 1) * cap
        t_lo = max(0, n - off - remaining_cap)
        t_hi = min(cap, n - off)
        for t in range(t_lo, t_hi + 1):
            child_val = pval + value[depth, off, t]
            child_bound = child_val + suffix[depth + 1, off + t]
            if child_bound <= prune_level():
                continue
            heapq.heappush(
                heap,
                (-child_bound, tiebreak, depth + 1, off + t, child_val, prefix + (t,)),
            )
            tiebreak += 1
    flush_leaves()

    return SearchResult(
        placement=tuple(int(v) for v in incumbent_p),
        objective=incumbent,
        evaluations=evals,
        nodes_expanded=expanded,
        optimal=exhausted,
    )
