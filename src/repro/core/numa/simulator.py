"""Steady-state NUMA bandwidth simulator with max-min fair saturation.

Given a machine, a workload and a thread placement this computes the
execution rate of every thread under bandwidth saturation and emits the
performance counters the paper's fitting procedure reads.

Placements are vectors of thread counts per NUMA *node* (for
``nodes_per_socket=1`` machines a node is a socket, the paper's case).
Each thread issues at its node's ``core_rate`` — heterogeneous machines
(throttled sockets, big.LITTLE) make threads on slow nodes demand
proportionally less bandwidth and retire fewer instructions.

The saturation model is *progressive filling* (max-min fairness): all
threads speed up together until some resource (a memory bank's read or
write capacity, a remote path, the interconnect, or the core issue rate)
saturates; the threads crossing that resource freeze and the rest keep
growing.  This reproduces the first-order behaviour the paper observes —
e.g. a single thread saturating the QPI on the low-end machine (§5.2) and
the rate asymmetries between sockets that motivate the normalization step.

The solver is a fixed-iteration ``lax.fori_loop`` and the whole function is
``jit``/``vmap``-able over placements, so evaluating thousands of
placements (paper §6.2.2: 2322 data points) is a single batched call.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.bwsig.counters import CounterSample, counters_from_flows
from repro.core.numa.machine import MachineSpec
from repro.core.numa.workload import Workload

_EPS = 1e-12


class SimulationResult(NamedTuple):
    rates: Array  # (n,) per-thread execution-rate multiplier in (0, 1]
    read_flows: Array  # (n_nodes, n_nodes) bytes/s from node i CPUs to bank j
    write_flows: Array  # (n_nodes, n_nodes)
    sample: CounterSample  # the counters the model is allowed to see
    throughput: Array  # scalar: sum of thread rates (relative performance)


def _thread_nodes(n_per_node: Array, n_threads: int) -> Array:
    """Contiguous thread->node assignment: the first ``n_0`` threads land
    on node 0, the next ``n_1`` on node 1, ...  (This ordering is what
    makes the Page-rank violator's early-chunk threads move between nodes
    as the placement changes.)"""
    bounds = jnp.cumsum(n_per_node)
    t = jnp.arange(n_threads)
    return jnp.searchsorted(bounds, t, side="right").astype(jnp.int32)


def _mix_rows(
    static_frac: Array,
    local_frac: Array,
    per_thread_frac: Array,
    static_socket: Array,
    node_of: Array,
    n_per_node: Array,
) -> Array:
    """Ground-truth per-thread traffic mix over banks — the per-thread
    version of the paper's §4 class matrices.  One bank per NUMA node;
    ``static_socket`` names the *node* holding the Static allocation."""
    s = n_per_node.shape[0]
    n = node_of.shape[0]
    nf = n_per_node.astype(jnp.float32)
    used = (nf > 0).astype(jnp.float32)
    s_used = jnp.maximum(used.sum(), 1.0)

    static_row = (jnp.arange(s) == static_socket).astype(jnp.float32)  # (s,)
    local_rows = jax.nn.one_hot(node_of, s)  # (n, s)
    pt_row = nf / jnp.maximum(nf.sum(), 1.0)  # (s,)
    il_row = used / s_used  # (s,)

    inter = 1.0 - static_frac - local_frac - per_thread_frac
    mix = (
        static_frac[:, None] * static_row[None, :]
        + local_frac[:, None] * local_rows
        + per_thread_frac[:, None] * pt_row[None, :]
        + inter[:, None] * il_row[None, :]
    )
    return mix  # (n, s)


def machine_caps(machine: MachineSpec) -> Array:
    """The capacity vector of :func:`_resource_tensor`'s resource slab, in
    slab order: bank reads (s), bank writes (s), remote read paths (s*s),
    remote write paths (s*s), interconnect links (n_links).  Split out so
    the calibration inverse problem can substitute a *traced* capacity
    vector (free parameters under ``jax.grad``) while the machine itself
    stays the static structural template."""
    s = machine.n_nodes
    return jnp.concatenate(
        [
            machine.bank_read_caps(),
            machine.bank_write_caps(),
            machine.remote_read_caps().reshape(s * s),
            machine.remote_write_caps().reshape(s * s),
            machine.link_caps(),
        ]
    )


def _resource_tensor(
    machine: MachineSpec,
    read_unit: Array,  # (n, s) bytes/s to each bank at full speed
    write_unit: Array,  # (n, s)
    node_of: Array,  # (n,)
    caps: Array | None = None,  # capacity-vector override (calibration)
) -> tuple[Array, Array]:
    """Build the per-thread resource-usage matrix ``U[t, r]`` and the
    capacity vector ``caps[r]``.

    With ``s = machine.n_nodes`` (one bank per NUMA node), resources are:
    bank read caps (s), bank write caps (s), remote read paths (s*s,
    diagonal unconstrained, per-pair hop-attenuated capacity), remote
    write paths (s*s), interconnect *links* (n_links): a flow from node
    ``i`` to bank ``j`` charges every link on ``route(i, j)``.

    The routing structure is static (python tuples on the machine), so the
    link slab keeps a fixed ``(n, n_links)`` shape that jit and vmap handle
    identically for any node count or topology.  ``caps`` overrides the
    machine-derived capacity vector (same slab order, from
    :func:`machine_caps`) — the hook the calibration fit differentiates
    through.
    """
    s = machine.n_nodes
    n = node_of.shape[0]
    topo = machine.topology
    onehot = jax.nn.one_hot(node_of, s)  # (n, s)

    # (n, s, s): thread t's flow from its node i to bank j.
    rr = onehot[:, :, None] * read_unit[:, None, :]
    ww = onehot[:, :, None] * write_unit[:, None, :]
    off_diag = (1.0 - jnp.eye(s))[None, :, :]
    rr_remote = rr * off_diag
    ww_remote = ww * off_diag

    # Per-link usage, in two parts.  (1) Direct traffic: each link always
    # carries its own endpoint pair (both directions) — a vectorized
    # endpoint-index gather summed in the scalar-pair model's exact order,
    # so fully-connected topologies reproduce it bit for bit.  (2) Routed
    # traffic: multi-hop pairs charge the full flow to every link on their
    # route via the static pair->link incidence matrix.
    n_links = topo.n_links
    if n_links:
        ends_i = np.asarray([e[0] for e in topo.link_ends])
        ends_j = np.asarray([e[1] for e in topo.link_ends])
        link_usage = (
            rr_remote[:, ends_i, ends_j]
            + rr_remote[:, ends_j, ends_i]
            + ww_remote[:, ends_i, ends_j]
            + ww_remote[:, ends_j, ends_i]
        )
        if not topo.is_fully_direct:
            routed = jnp.asarray(topo.route_incidence_multihop())  # (s*s, L)
            cross = (rr_remote + ww_remote).reshape(n, s * s)
            link_usage = link_usage + cross @ routed
    else:
        link_usage = jnp.zeros((n, 0))

    usage = jnp.concatenate(
        [
            read_unit,  # bank read
            write_unit,  # bank write
            rr_remote.reshape(n, s * s),
            ww_remote.reshape(n, s * s),
            link_usage,
        ],
        axis=1,
    )

    if caps is None:
        caps = machine_caps(machine)
    return usage, caps


def _progressive_fill(usage: Array, caps: Array, iterations: int) -> Array:
    """Max-min fair rates: grow all threads together, freeze the set
    crossing each successive bottleneck."""
    n = usage.shape[0]

    def body(_, state):
        x, frozen = state
        active = ~frozen
        frozen_usage = (usage * jnp.where(frozen, x, 0.0)[:, None]).sum(0)
        act_usage = (usage * active[:, None].astype(usage.dtype)).sum(0)
        resid = jnp.maximum(caps - frozen_usage, 0.0)
        lam = jnp.where(act_usage > _EPS, resid / jnp.maximum(act_usage, _EPS), jnp.inf)
        lam_star = jnp.minimum(jnp.min(lam), 1.0)
        bottleneck = lam <= lam_star * (1.0 + 1e-6)
        uses_bottleneck = (usage * bottleneck[None, :]).sum(1) > _EPS
        freeze_now = active & (uses_bottleneck | (lam_star >= 1.0))
        x = jnp.where(freeze_now, lam_star, x)
        frozen = frozen | freeze_now
        return x, frozen

    x0 = jnp.zeros((n,), usage.dtype)
    frozen0 = jnp.zeros((n,), bool)
    x, frozen = jax.lax.fori_loop(0, iterations, body, (x0, frozen0))
    # Anything still unfrozen touches no finite resource: runs at full speed.
    return jnp.where(frozen, x, 1.0)


def simulate(
    machine: MachineSpec,
    workload: Workload,
    n_per_node: Array,
    *,
    elapsed: float = 1.0,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    key: Array | None = None,
    caps: Array | None = None,
) -> SimulationResult:
    """Run the workload on the machine under the given placement (threads
    per NUMA node) and emit ground truth + the paper-visible performance
    counters.

    ``caps`` substitutes the machine's capacity vector (slab order of
    :func:`machine_caps`) with traced values — the differentiable-forward
    hook ``repro.core.numa.calibrate`` fits machine parameters through;
    everything else about the machine (routes, rates, thread geometry)
    stays static structure."""
    s = machine.n_nodes
    n = workload.n_threads
    n_per_node = jnp.asarray(n_per_node)
    node_of = _thread_nodes(n_per_node, n)
    rate_of = machine.node_rates()[node_of]  # (n,) per-thread issue rate

    read_mix = _mix_rows(
        workload.read_static,
        workload.read_local,
        workload.read_per_thread,
        workload.static_socket,
        node_of,
        n_per_node,
    )
    write_mix = _mix_rows(
        workload.write_static,
        workload.write_local,
        workload.write_per_thread,
        workload.static_socket,
        node_of,
        n_per_node,
    )
    read_unit = rate_of[:, None] * workload.read_bpi[:, None] * read_mix
    write_unit = rate_of[:, None] * workload.write_bpi[:, None] * write_mix

    usage, caps = _resource_tensor(machine, read_unit, write_unit, node_of, caps)
    # Each progressive-filling iteration freezes at least one thread set
    # (either a bottleneck's users or, at lam* >= 1, every active thread),
    # and each bottleneck saturates at most one new resource — so
    # min(n_threads, n_resources) + 1 iterations always reach the fixed
    # point.  (The former n_resources + 2 count was 172 iterations on the
    # 8-socket preset for 32 threads.)
    iterations = min(usage.shape[0], usage.shape[1]) + 1
    rates = _progressive_fill(usage, caps, iterations)

    onehot = jax.nn.one_hot(node_of, s)
    read_flows = onehot.T @ (rates[:, None] * read_unit) * elapsed
    write_flows = onehot.T @ (rates[:, None] * write_unit) * elapsed
    instructions = onehot.T @ (rates * rate_of) * elapsed

    if noise_std > 0.0 or background_bw > 0.0:
        if key is None:
            key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        read_flows = read_flows * jnp.exp(
            noise_std * jax.random.normal(k1, read_flows.shape)
        ) + background_bw * elapsed / (s * s)
        write_flows = write_flows * jnp.exp(
            noise_std * jax.random.normal(k2, write_flows.shape)
        ) + background_bw * elapsed / (s * s)
        instructions = instructions * jnp.exp(
            0.2 * noise_std * jax.random.normal(k3, instructions.shape)
        )

    sample = counters_from_flows(
        read_flows, write_flows, instructions, jnp.asarray(elapsed), n_per_node
    )
    return SimulationResult(
        rates=rates,
        read_flows=read_flows,
        write_flows=write_flows,
        sample=sample,
        throughput=rates.sum(),
    )


def simulate_counters(
    machine: MachineSpec,
    workload: Workload,
    n_per_node: Array,
    **kwargs,
) -> CounterSample:
    return simulate(machine, workload, n_per_node, **kwargs).sample


def symmetric_placement(machine: MachineSpec, n_threads: int) -> Array:
    """Paper §5.1 run 1: equal threads per NUMA node, 1 thread/core."""
    assert n_threads % machine.n_nodes == 0, "symmetric run needs equal split"
    per = n_threads // machine.n_nodes
    assert per <= machine.cores_per_node
    return jnp.full((machine.n_nodes,), per, jnp.int32)


def asymmetric_placement(machine: MachineSpec, n_threads: int) -> Array:
    """Paper §5.1 run 2: same thread count, unequal split (Figure 7 uses a
    roughly 2:1 split on the first socket) — generalized to NUMA nodes.

    The 3:1 target split can be infeasible — e.g. 2 threads on a 2-node
    machine leave zero threads for the second node, and a full machine
    admits only the equal split.  Instead of asserting, fall back to the
    nearest valid split: node 0 gets the feasible count closest to the
    3:1 target (ties prefer the heavier node) that still yields an
    *unequal* split when any exists; a perfectly full machine returns the
    only (equal) valid placement.
    """
    s = machine.n_nodes
    cap = machine.cores_per_node
    if not 0 < n_threads <= s * cap:
        raise ValueError(f"{n_threads} threads do not fit {s} nodes x {cap} cores")
    target = -(-3 * n_threads // 4)

    def split_for(first: int) -> list[int] | None:
        rest = n_threads - first
        if rest < 0 or rest > (s - 1) * cap:
            return None
        others = [rest // (s - 1)] * (s - 1)
        others[0] += rest - sum(others)
        # spill overflow beyond per-socket capacity rightward; a no-op
        # whenever the heaped shape was already feasible (seed behaviour)
        for k in range(s - 2):
            if others[k] > cap:
                others[k + 1] += others[k] - cap
                others[k] = cap
        counts = [first] + others
        return counts if max(counts) <= cap else None

    candidates = sorted(
        range(min(cap, n_threads) + 1), key=lambda f: (abs(f - target), -f)
    )
    fallback = None
    for first in candidates:
        counts = split_for(first)
        if counts is None:
            continue
        if len(set(counts)) > 1:
            return jnp.asarray(counts, jnp.int32)
        if fallback is None:
            fallback = counts
    assert fallback is not None  # n_threads <= s * cap guarantees a split
    return jnp.asarray(fallback, jnp.int32)


def profile_pair(
    machine: MachineSpec,
    workload: Workload,
    *,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    key: Array | None = None,
) -> tuple[CounterSample, CounterSample]:
    """The paper's 2-run profiling protocol (§5.1): one symmetric and one
    asymmetric placement of the same thread count."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k_sym, k_asym = jax.random.split(key)
    sym = simulate_counters(
        machine,
        workload,
        symmetric_placement(machine, workload.n_threads),
        noise_std=noise_std,
        background_bw=background_bw,
        key=k_sym,
    )
    asym = simulate_counters(
        machine,
        workload,
        asymmetric_placement(machine, workload.n_threads),
        noise_std=noise_std,
        background_bw=background_bw,
        key=k_asym,
    )
    return sym, asym
