"""Steady-state NUMA bandwidth simulator with max-min fair saturation.

Given a machine, a workload and a thread placement this computes the
execution rate of every thread under bandwidth saturation and emits the
performance counters the paper's fitting procedure reads.

Placements are vectors of thread counts per NUMA *node* (for
``nodes_per_socket=1`` machines a node is a socket, the paper's case).
Each thread issues at its node's ``core_rate`` — heterogeneous machines
(throttled sockets, big.LITTLE) make threads on slow nodes demand
proportionally less bandwidth and retire fewer instructions.

The saturation model is *progressive filling* (max-min fairness): all
threads speed up together until some resource (a memory bank's read or
write capacity, a remote path, the interconnect, or the core issue rate)
saturates; the threads crossing that resource freeze and the rest keep
growing.  This reproduces the first-order behaviour the paper observes —
e.g. a single thread saturating the QPI on the low-end machine (§5.2) and
the rate asymmetries between sockets that motivate the normalization step.

The solver is a fixed-iteration ``lax.fori_loop`` and the whole function is
``jit``/``vmap``-able over placements, so evaluating thousands of
placements (paper §6.2.2: 2322 data points) is a single batched call.
Interconnect structure (link list, routes, the pair→link incidence
matrices consumed below) comes from the machine's topology — a
:mod:`repro.core.graphtop` link graph — and enters the trace as
compile-time constants.

Group-collapsed hot path
------------------------

Threads on the same NUMA node with the same per-thread workload column
(mix fractions + bytes/instruction) are *identical* rows of the resource
slab, so the solver never needs the thread axis: :func:`simulate` runs
max-min fairness over **thread groups** — ``(class, node)`` equivalence
classes with integer multiplicities — shrinking the slab from
``(n_threads, R)`` to ``(n_classes * n_nodes, R)`` (32 -> 8 rows on the
8-socket preset for a homogeneous workload) and the iteration bound from
``min(n_threads, R) + 1`` to ``min(n_groups, R) + 1``.  Classes are
*static* maximal runs of the thread index range over which every
workload array is constant (:func:`thread_class_starts`); group
multiplicities are cheap traced interval overlaps, so the grouped path
stays ``jit``/``vmap``-able over placements and differentiable through
``caps``.  Per-thread rates, flows and counters are reconstructed
exactly from the group rates (identical support rows freeze together in
progressive filling, so members of a group provably share one rate).

:func:`simulate_reference` keeps the per-thread formulation verbatim as
the test-only reference implementation (the way PR 3's verbatim replica
pinned the node refactor); when ``simulate`` cannot learn the class
structure (traced workload arrays and no ``thread_classes`` argument) it
falls back to that path.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.bwsig.counters import CounterSample, counters_from_flows
from repro.core.numa.machine import MachineSpec, canonical_bank_assignment
from repro.core.numa.workload import Workload

_EPS = 1e-12


class SimulationResult(NamedTuple):
    """One simulated run: per-thread rates, per-node-pair flow matrices,
    the counter sample the model is allowed to observe, and the scalar
    throughput the sweep/search layers maximize."""

    rates: Array  # (n,) per-thread execution-rate multiplier in (0, 1]
    read_flows: Array  # (n_nodes, n_nodes) bytes/s from node i CPUs to bank j
    write_flows: Array  # (n_nodes, n_nodes)
    sample: CounterSample  # the counters the model is allowed to see
    throughput: Array  # scalar: sum of thread rates (relative performance)


def _thread_nodes(n_per_node: Array, n_threads: int) -> Array:
    """Contiguous thread->node assignment: the first ``n_0`` threads land
    on node 0, the next ``n_1`` on node 1, ...  (This ordering is what
    makes the Page-rank violator's early-chunk threads move between nodes
    as the placement changes.)"""
    bounds = jnp.cumsum(n_per_node)
    t = jnp.arange(n_threads)
    return jnp.searchsorted(bounds, t, side="right").astype(jnp.int32)


def _mix_rows(
    static_frac: Array,
    local_frac: Array,
    per_thread_frac: Array,
    static_socket: Array,
    node_of: Array,
    n_per_node: Array,
    bank_assignment: tuple[int, ...] | None = None,
) -> Array:
    """Ground-truth per-thread traffic mix over banks — the per-thread
    version of the paper's §4 class matrices.  One bank per NUMA node;
    ``static_socket`` names the *node* holding the Static allocation.
    ``bank_assignment`` redirects the Local class: a thread on node ``k``
    reads its "local" buffers from bank ``bank_assignment[k]`` (pages left
    behind by a migration, or deliberately placed on another node)."""
    s = n_per_node.shape[0]
    n = node_of.shape[0]
    nf = n_per_node.astype(jnp.float32)
    used = (nf > 0).astype(jnp.float32)
    s_used = jnp.maximum(used.sum(), 1.0)

    static_row = (jnp.arange(s) == static_socket).astype(jnp.float32)  # (s,)
    if bank_assignment is None:
        local_rows = jax.nn.one_hot(node_of, s)  # (n, s)
    else:
        bank_of = jnp.asarray(bank_assignment, jnp.int32)[node_of]
        local_rows = jax.nn.one_hot(bank_of, s)  # (n, s)
    pt_row = nf / jnp.maximum(nf.sum(), 1.0)  # (s,)
    il_row = used / s_used  # (s,)

    inter = 1.0 - static_frac - local_frac - per_thread_frac
    mix = (
        static_frac[:, None] * static_row[None, :]
        + local_frac[:, None] * local_rows
        + per_thread_frac[:, None] * pt_row[None, :]
        + inter[:, None] * il_row[None, :]
    )
    return mix  # (n, s)


def machine_caps(machine: MachineSpec) -> Array:
    """The capacity vector of :func:`_resource_tensor`'s resource slab, in
    slab order: bank reads (s), bank writes (s), remote read paths (s*s),
    remote write paths (s*s), interconnect links (n_links).  Split out so
    the calibration inverse problem can substitute a *traced* capacity
    vector (free parameters under ``jax.grad``) while the machine itself
    stays the static structural template."""
    s = machine.n_nodes
    return jnp.concatenate(
        [
            machine.bank_read_caps(),
            machine.bank_write_caps(),
            machine.remote_read_caps().reshape(s * s),
            machine.remote_write_caps().reshape(s * s),
            machine.link_caps(),
        ]
    )


def _resource_tensor(
    machine: MachineSpec,
    read_unit: Array,  # (n, s) bytes/s to each bank at full speed
    write_unit: Array,  # (n, s)
    node_of: Array,  # (n,)
    caps: Array | None = None,  # capacity-vector override (calibration)
    multipath: bool = False,
) -> tuple[Array, Array]:
    """Build the per-thread resource-usage matrix ``U[t, r]`` and the
    capacity vector ``caps[r]``.

    With ``s = machine.n_nodes`` (one bank per NUMA node), resources are:
    bank read caps (s), bank write caps (s), remote read paths (s*s,
    diagonal unconstrained, per-pair hop-attenuated capacity), remote
    write paths (s*s), interconnect *links* (n_links): a flow from node
    ``i`` to bank ``j`` charges every link on ``route(i, j)``.

    The routing structure is static (python tuples on the machine), so the
    link slab keeps a fixed ``(n, n_links)`` shape that jit and vmap handle
    identically for any node count or topology.  ``caps`` overrides the
    machine-derived capacity vector (same slab order, from
    :func:`machine_caps`) — the hook the calibration fit differentiates
    through.

    ``multipath=True`` splits each pair's flow evenly over all of its
    equal-cost widest routes (``graphtop`` fractional incidence) instead
    of charging the single primary route; the default single-route
    charging is unchanged bit for bit.
    """
    s = machine.n_nodes
    n = node_of.shape[0]
    topo = machine.topology
    onehot = jax.nn.one_hot(node_of, s)  # (n, s)

    # (n, s, s): thread t's flow from its node i to bank j.
    rr = onehot[:, :, None] * read_unit[:, None, :]
    ww = onehot[:, :, None] * write_unit[:, None, :]
    off_diag = (1.0 - jnp.eye(s))[None, :, :]
    rr_remote = rr * off_diag
    ww_remote = ww * off_diag

    # Per-link usage, in two parts.  (1) Direct traffic: each link always
    # carries its own endpoint pair (both directions) — a vectorized
    # endpoint-index gather summed in the scalar-pair model's exact order,
    # so fully-connected topologies reproduce it bit for bit.  (2) Routed
    # traffic: multi-hop pairs charge the full flow to every link on their
    # route via the static pair->link incidence matrix.  Under multipath
    # the two-part split is meaningless (a "direct" pair may still split
    # over parallel equal-cost routes), so the whole charge goes through
    # the fractional incidence in one matmul.
    n_links = topo.n_links
    if n_links and multipath:
        inc = jnp.asarray(topo.route_incidence(multipath=True))  # (s*s, L)
        link_usage = (rr_remote + ww_remote).reshape(n, s * s) @ inc
    elif n_links:
        ends_i = np.asarray([e[0] for e in topo.link_ends])
        ends_j = np.asarray([e[1] for e in topo.link_ends])
        link_usage = (
            rr_remote[:, ends_i, ends_j]
            + rr_remote[:, ends_j, ends_i]
            + ww_remote[:, ends_i, ends_j]
            + ww_remote[:, ends_j, ends_i]
        )
        if not topo.is_fully_direct:
            routed = jnp.asarray(topo.route_incidence_multihop())  # (s*s, L)
            cross = (rr_remote + ww_remote).reshape(n, s * s)
            link_usage = link_usage + cross @ routed
    else:
        link_usage = jnp.zeros((n, 0))

    usage = jnp.concatenate(
        [
            read_unit,  # bank read
            write_unit,  # bank write
            rr_remote.reshape(n, s * s),
            ww_remote.reshape(n, s * s),
            link_usage,
        ],
        axis=1,
    )

    if caps is None:
        caps = machine_caps(machine)
    return usage, caps


def _progressive_fill(usage: Array, caps: Array, iterations: int) -> Array:
    """Max-min fair rates: grow all threads together, freeze the set
    crossing each successive bottleneck."""
    n = usage.shape[0]

    def body(_, state):
        x, frozen = state
        active = ~frozen
        frozen_usage = (usage * jnp.where(frozen, x, 0.0)[:, None]).sum(0)
        act_usage = (usage * active[:, None].astype(usage.dtype)).sum(0)
        resid = jnp.maximum(caps - frozen_usage, 0.0)
        lam = jnp.where(act_usage > _EPS, resid / jnp.maximum(act_usage, _EPS), jnp.inf)
        lam_star = jnp.minimum(jnp.min(lam), 1.0)
        bottleneck = lam <= lam_star * (1.0 + 1e-6)
        uses_bottleneck = (usage * bottleneck[None, :]).sum(1) > _EPS
        freeze_now = active & (uses_bottleneck | (lam_star >= 1.0))
        x = jnp.where(freeze_now, lam_star, x)
        frozen = frozen | freeze_now
        return x, frozen

    x0 = jnp.zeros((n,), usage.dtype)
    frozen0 = jnp.zeros((n,), bool)
    x, frozen = jax.lax.fori_loop(0, iterations, body, (x0, frozen0))
    # Anything still unfrozen touches no finite resource: runs at full speed.
    return jnp.where(frozen, x, 1.0)


def simulate_reference(
    machine: MachineSpec,
    workload: Workload,
    n_per_node: Array,
    *,
    elapsed: float = 1.0,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    key: Array | None = None,
    caps: Array | None = None,
    multipath: bool = False,
    bank_assignment: tuple[int, ...] | None = None,
) -> SimulationResult:
    """The per-thread reference solver: one resource-slab row per thread.

    This is the pre-grouping formulation kept verbatim — the reference
    implementation the grouped hot path (:func:`simulate`) is tested
    against, and the fallback when the class structure of a traced
    workload is unknown.  Prefer :func:`simulate` everywhere else: it is
    exact to ~1 ulp and its cost scales with nodes, not threads."""
    bank_assignment = canonical_bank_assignment(machine, bank_assignment)
    s = machine.n_nodes
    n = workload.n_threads
    n_per_node = jnp.asarray(n_per_node)
    node_of = _thread_nodes(n_per_node, n)
    rate_of = machine.node_rates()[node_of]  # (n,) per-thread issue rate

    read_mix = _mix_rows(
        workload.read_static,
        workload.read_local,
        workload.read_per_thread,
        workload.static_socket,
        node_of,
        n_per_node,
        bank_assignment,
    )
    write_mix = _mix_rows(
        workload.write_static,
        workload.write_local,
        workload.write_per_thread,
        workload.static_socket,
        node_of,
        n_per_node,
        bank_assignment,
    )
    read_unit = rate_of[:, None] * workload.read_bpi[:, None] * read_mix
    write_unit = rate_of[:, None] * workload.write_bpi[:, None] * write_mix

    usage, caps = _resource_tensor(
        machine, read_unit, write_unit, node_of, caps, multipath=multipath
    )
    # Each progressive-filling iteration freezes at least one thread set
    # (either a bottleneck's users or, at lam* >= 1, every active thread),
    # and each bottleneck saturates at most one new resource — so
    # min(n_threads, n_resources) + 1 iterations always reach the fixed
    # point.  (The former n_resources + 2 count was 172 iterations on the
    # 8-socket preset for 32 threads.)
    iterations = min(usage.shape[0], usage.shape[1]) + 1
    rates = _progressive_fill(usage, caps, iterations)

    onehot = jax.nn.one_hot(node_of, s)
    read_flows = onehot.T @ (rates[:, None] * read_unit) * elapsed
    write_flows = onehot.T @ (rates[:, None] * write_unit) * elapsed
    instructions = onehot.T @ (rates * rate_of) * elapsed

    return _finalize_result(
        rates, read_flows, write_flows, instructions, n_per_node,
        elapsed, noise_std, background_bw, key, s,
    )


def _finalize_result(
    rates: Array,
    read_flows: Array,
    write_flows: Array,
    instructions: Array,
    n_per_node: Array,
    elapsed: float,
    noise_std: float,
    background_bw: float,
    key: Array | None,
    s: int,
) -> SimulationResult:
    """Measurement noise + counter reduction, shared by the grouped and
    per-thread paths (op-for-op the pre-grouping tail of ``simulate``)."""
    if noise_std > 0.0 or background_bw > 0.0:
        if key is None:
            key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        read_flows = read_flows * jnp.exp(
            noise_std * jax.random.normal(k1, read_flows.shape)
        ) + background_bw * elapsed / (s * s)
        write_flows = write_flows * jnp.exp(
            noise_std * jax.random.normal(k2, write_flows.shape)
        ) + background_bw * elapsed / (s * s)
        instructions = instructions * jnp.exp(
            0.2 * noise_std * jax.random.normal(k3, instructions.shape)
        )

    sample = counters_from_flows(
        read_flows, write_flows, instructions, jnp.asarray(elapsed), n_per_node
    )
    return SimulationResult(
        rates=rates,
        read_flows=read_flows,
        write_flows=write_flows,
        sample=sample,
        throughput=rates.sum(),
    )


# ---------------------------------------------------------------------------
# Group-collapsed solver: (class, node) equivalence classes of threads
# ---------------------------------------------------------------------------


def class_starts_from_arrays(arrays) -> tuple[int, ...]:
    """Static thread-class boundaries from concrete per-thread arrays.

    Classes are *maximal runs* of the thread index range over which every
    array (last axis = threads; scalars are skipped) is constant.  Runs —
    not value-equivalence classes — because the contiguous thread->node
    assignment makes interval overlap the multiplicity computation; a
    finer partition is always correct.  Returns the tuple of class start
    indices, e.g. ``(0,)`` for a homogeneous workload or ``(0, n//2)``
    for the Page-rank violator's hot/cold halves."""
    boundary = None
    for a in arrays:
        a = np.asarray(a)
        if a.ndim == 0 or a.shape[-1] < 2:
            continue
        diff = a[..., 1:] != a[..., :-1]
        diff = diff.reshape(-1, diff.shape[-1]).any(axis=0)
        boundary = diff if boundary is None else (boundary | diff)
    if boundary is None:
        return (0,)
    return (0,) + tuple(int(i) + 1 for i in np.flatnonzero(boundary))


def thread_class_starts(workloads) -> tuple[int, ...]:
    """Common static class refinement over one or more workloads: the
    partition of ``[0, n)`` into maximal runs where *every* workload's
    per-thread arrays are constant.  A batch of workloads evaluated in
    one trace must share one (static) partition, so the refinement is the
    union of each workload's class boundaries."""
    if isinstance(workloads, Workload):
        workloads = [workloads]
    # wl[1:-1]: every per-thread array field; static_socket (a scalar, and
    # a per-*sample* axis once stacked) never partitions the thread range.
    arrays = [a for wl in workloads for a in wl[1:-1]]
    return class_starts_from_arrays(arrays)


def _infer_thread_classes(workload: Workload) -> tuple[int, ...] | None:
    """Class boundaries from a concrete workload; ``None`` when any array
    field is traced (inside jit/vmap the values are unreadable — callers
    must pass ``thread_classes`` explicitly to stay on the grouped path)."""
    if any(isinstance(f, jax.core.Tracer) for f in workload[1:]):
        return None
    return thread_class_starts(workload)


def _group_multiplicities(
    class_starts: tuple[int, ...], n: int, n_per_node: Array
) -> Array:
    """``(C, s)`` thread count of class ``c`` on node ``k``: the overlap
    of the static class interval with the traced node interval of the
    contiguous thread->node assignment."""
    bounds = jnp.asarray(class_starts + (n,), jnp.int32)  # (C+1,) static
    node_hi = jnp.cumsum(n_per_node.astype(jnp.int32))
    node_lo = node_hi - n_per_node.astype(jnp.int32)
    lo = jnp.maximum(bounds[:-1, None], node_lo[None, :])
    hi = jnp.minimum(bounds[1:, None], node_hi[None, :])
    return jnp.maximum(hi - lo, 0)


def _group_mix_rows(
    static_frac: Array,  # (C,)
    local_frac: Array,
    per_thread_frac: Array,
    static_socket: Array,
    n_per_node: Array,
    bank_assignment: tuple[int, ...] | None = None,
) -> Array:
    """``(C, s, s)`` traffic mix over banks for a class-``c`` thread
    placed on node ``k`` — :func:`_mix_rows` with the thread axis replaced
    by the (class, node) grid.  ``bank_assignment`` redirects row ``k``'s
    Local column to bank ``bank_assignment[k]`` (see
    :func:`repro.core.numa.machine.canonical_bank_assignment`)."""
    s = n_per_node.shape[0]
    nf = n_per_node.astype(jnp.float32)
    used = (nf > 0).astype(jnp.float32)
    s_used = jnp.maximum(used.sum(), 1.0)

    static_row = (jnp.arange(s) == static_socket).astype(jnp.float32)  # (s,)
    if bank_assignment is None:
        local_rows = jnp.eye(s)  # node k's local row
    else:
        local_rows = jax.nn.one_hot(jnp.asarray(bank_assignment, jnp.int32), s)
    pt_row = nf / jnp.maximum(nf.sum(), 1.0)
    il_row = used / s_used

    inter = 1.0 - static_frac - local_frac - per_thread_frac
    return (
        static_frac[:, None, None] * static_row[None, None, :]
        + local_frac[:, None, None] * local_rows[None, :, :]
        + per_thread_frac[:, None, None] * pt_row[None, None, :]
        + inter[:, None, None] * il_row[None, None, :]
    )


def _group_resource_tensor(
    machine: MachineSpec,
    read_unit: Array,  # (C, s, s) bytes/s of one class-c thread on node k
    write_unit: Array,
    caps: Array | None = None,
    multipath: bool = False,
) -> tuple[Array, Array]:
    """Per-*group* resource-usage matrix ``U[g, r]`` (``g = c * s + k``)
    in the exact slab order of :func:`_resource_tensor` / :func:`machine_caps`.

    Each group only ever occupies its own node's row of the ``s x s``
    remote slabs, so those columns are built by a static scatter (every
    group row places its ``s`` bank flows at columns ``k*s + j``) instead
    of the per-thread path's dense one-hot masking; per-link charges
    gather the node's rows of the full route-incidence matrix (direct and
    multi-hop routes alike, matching the reference's two-part sum).
    ``multipath=True`` swaps in the fractional equal-cost-multipath
    incidence (bit-for-bit unchanged when off)."""
    s = machine.n_nodes
    C = read_unit.shape[0]
    G = C * s
    topo = machine.topology

    read_flat = read_unit.reshape(G, s)
    write_flat = write_unit.reshape(G, s)
    node_idx = np.tile(np.arange(s), C)  # (G,) static: group g lives on node g%s
    offdiag = jnp.asarray(
        np.arange(s)[None, :] != node_idx[:, None], read_flat.dtype
    )  # (G, s) static constant
    rr_vals = read_flat * offdiag
    ww_vals = write_flat * offdiag

    cols = node_idx[:, None] * s + np.arange(s)[None, :]  # (G, s) static
    rows = np.arange(G)[:, None]
    rr_remote = jnp.zeros((G, s * s), read_flat.dtype).at[rows, cols].set(rr_vals)
    ww_remote = jnp.zeros((G, s * s), write_flat.dtype).at[rows, cols].set(ww_vals)

    if topo.n_links:
        # (s, s, L) static: node k's rows of the full pair->link incidence
        inc = np.asarray(
            topo.route_incidence(multipath=multipath)
        ).reshape(s, s, topo.n_links)
        inc_rows = jnp.asarray(inc[node_idx])  # (G, s, L) static constant
        link_usage = jnp.einsum("gj,gjl->gl", rr_vals + ww_vals, inc_rows)
    else:
        link_usage = jnp.zeros((G, 0))

    usage = jnp.concatenate(
        [read_flat, write_flat, rr_remote, ww_remote, link_usage], axis=1
    )
    if caps is None:
        caps = machine_caps(machine)
    return usage, caps


def _progressive_fill_grouped(
    unit_usage: Array, mult: Array, caps: Array, iterations: int
) -> Array:
    """Weighted max-min fairness over thread groups: ``unit_usage[g]`` is
    one member's resource row, ``mult[g]`` the member count.  Identical
    rows freeze together in :func:`_progressive_fill` (the freeze rule
    only reads a row's *support*), so solving over groups with summed
    usage reproduces the per-thread rates exactly; empty groups carry
    zero usage and cannot move any bottleneck."""
    g = unit_usage.shape[0]
    total_usage = unit_usage * mult[:, None]

    def body(_, state):
        x, frozen = state
        active = ~frozen
        frozen_usage = (total_usage * jnp.where(frozen, x, 0.0)[:, None]).sum(0)
        act_usage = (total_usage * active[:, None].astype(unit_usage.dtype)).sum(0)
        resid = jnp.maximum(caps - frozen_usage, 0.0)
        lam = jnp.where(act_usage > _EPS, resid / jnp.maximum(act_usage, _EPS), jnp.inf)
        lam_star = jnp.minimum(jnp.min(lam), 1.0)
        bottleneck = lam <= lam_star * (1.0 + 1e-6)
        uses_bottleneck = (unit_usage * bottleneck[None, :]).sum(1) > _EPS
        freeze_now = active & (uses_bottleneck | (lam_star >= 1.0))
        x = jnp.where(freeze_now, lam_star, x)
        frozen = frozen | freeze_now
        return x, frozen

    x0 = jnp.zeros((g,), unit_usage.dtype)
    frozen0 = jnp.zeros((g,), bool)
    x, frozen = jax.lax.fori_loop(0, iterations, body, (x0, frozen0))
    return jnp.where(frozen, x, 1.0)


# ---------------------------------------------------------------------------
# Batched shared-slab evaluation: one resource build per support bucket
# ---------------------------------------------------------------------------
#
# A placement enters the grouped solver through exactly three channels:
# the (C, s) multiplicity grid, the per-thread row ``pt_row = n / sum(n)``
# and the interleave row ``il_row = used / s_used`` (support only).  The
# unit-demand tensor is *linear* in the mix rows, so it decomposes exactly:
#
#   unit(c, k, j) = base(c, k, j)                      static + local terms
#                 + pt_coeff(c, k) * pt_row(j)         per-thread term
#                 + il_coeff(c, k) * il_row(j)         interleaved term
#
# ``base`` and the coefficients are placement-independent (built once per
# benchmark); ``il_row`` only depends on the placement's *support pattern*
# (which nodes hold any thread), so placements are bucketed by support and
# the base+interleave slab — including its per-link charges — is built
# once per bucket.  Only the rank-1 ``pt_row`` update and the multiplicity
# grid remain per-placement work.
#
# The slab itself is kept *structured* instead of materializing the dense
# ``(G, R)`` matrix of :func:`_group_resource_tensor`: each remote path
# ``(k, j)`` is used only by the C groups living on node ``k``, so the
# remote constraints stay in ``(C, s, s)`` form (``2*C*s^2`` entries
# instead of the dense scatter's ``2*C*s^3``) and the fill contracts them
# with per-node einsums.  The max-min semantics are identical; only the
# zero padding is gone.


class GroupSlabs(NamedTuple):
    """Placement-independent slab components of one benchmark's unit
    demand (see the decomposition note above)."""

    base_read: Array  # (C, s, s) static + local unit demand
    base_write: Array  # (C, s, s)
    pt_read: Array  # (C, s) coefficient of the per-thread row
    pt_write: Array  # (C, s)
    il_read: Array  # (C, s) coefficient of the interleave row
    il_write: Array  # (C, s)


class GroupedBatchResult(NamedTuple):
    """Per-placement ground truth from :func:`simulate_grouped_batch`
    (noise-free; measurement noise is a batched post-pass for the callers
    that want it)."""

    read_flows: Array  # (P, s, s)
    write_flows: Array  # (P, s, s)
    instructions: Array  # (P, s)
    throughput: Array  # (P,) sum of thread rates
    group_rates: Array  # (P, C, s) shared rate of class c on node k


def group_slab_components(
    machine: MachineSpec,
    workload: Workload,
    thread_classes: tuple[int, ...],
    bank_assignment: tuple[int, ...] | None = None,
) -> GroupSlabs:
    """Build the placement-independent unit-demand components for every
    (class, node) group — one call per benchmark, shared by every
    placement bucket.  ``bank_assignment`` (canonicalized: ``None`` means
    node-local) lands in the Local term of the base slab, so the whole
    batched path — including :func:`_group_resource_tensor`-style route
    charging of now-remote Local flows — prices page placement with zero
    extra per-placement work."""
    s = machine.n_nodes
    rep = np.asarray(thread_classes, np.int64)  # class representatives
    node_rates = machine.node_rates()  # (s,)
    if bank_assignment is None:
        local_mat = jnp.eye(s, dtype=node_rates.dtype)
    else:
        local_mat = jax.nn.one_hot(
            jnp.asarray(bank_assignment, jnp.int32), s, dtype=node_rates.dtype
        )

    def direction(static_frac, local_frac, pt_frac, bpi):
        sf = static_frac[rep]
        lf = local_frac[rep]
        pf = pt_frac[rep]
        inter = 1.0 - sf - lf - pf
        unit = node_rates[None, :, None] * bpi[rep][:, None, None]  # (C, s, 1)
        static_row = (
            jnp.arange(s) == workload.static_socket
        ).astype(node_rates.dtype)
        base = unit * (
            sf[:, None, None] * static_row[None, None, :]
            + lf[:, None, None] * local_mat[None, :, :]
        )
        coeff = unit[:, :, 0]  # (C, s)
        return base, coeff * pf[:, None], coeff * inter[:, None]

    base_r, pt_r, il_r = direction(
        workload.read_static,
        workload.read_local,
        workload.read_per_thread,
        workload.read_bpi,
    )
    base_w, pt_w, il_w = direction(
        workload.write_static,
        workload.write_local,
        workload.write_per_thread,
        workload.write_bpi,
    )
    return GroupSlabs(base_r, base_w, pt_r, pt_w, il_r, il_w)


def split_caps(
    machine: MachineSpec, caps: Array | None = None
) -> tuple[Array, Array, Array]:
    """Split a :func:`machine_caps`-order capacity vector into the
    structured fill's three blocks: dense ``[bank reads (s), bank writes
    (s), links (L)]``, remote-read ``(s, s)`` and remote-write ``(s, s)``."""
    s = machine.n_nodes
    if caps is None:
        dense = jnp.concatenate(
            [machine.bank_read_caps(), machine.bank_write_caps(), machine.link_caps()]
        )
        return dense, machine.remote_read_caps(), machine.remote_write_caps()
    dense = jnp.concatenate([caps[: 2 * s], caps[2 * s + 2 * s * s :]])
    rr = caps[2 * s : 2 * s + s * s].reshape(s, s)
    ww = caps[2 * s + s * s : 2 * s + 2 * s * s].reshape(s, s)
    return dense, rr, ww


def _progressive_fill_structured(
    dense: Array,  # (G, 2s + L) unit usage: bank reads, bank writes, links
    rem_read: Array,  # (C, s, s) off-diagonal-masked remote read unit usage
    rem_write: Array,  # (C, s, s)
    mult: Array,  # (G,) group multiplicities (float)
    dense_caps: Array,  # (2s + L,)
    rr_caps: Array,  # (s, s) inf diagonal
    ww_caps: Array,  # (s, s)
    iterations: int,
    early_exit: bool = False,
) -> Array:
    """:func:`_progressive_fill_grouped` over the structured slab: the
    dense block matmuls while each remote path contracts only the C groups
    on its source node.  Same freeze rule, bottleneck tolerance and
    fixed-point; ``early_exit=True`` swaps the fori_loop for a while_loop
    that stops once every group froze (bit-identical — post-freeze
    iterations are no-ops — but not reverse-differentiable, so the
    calibration/search gradient paths keep the fixed-count loop)."""
    C, s, _ = rem_read.shape
    g = dense.shape[0]
    dtype = dense.dtype

    def body(state):
        x, frozen = state
        active = ~frozen
        wt_frozen = (jnp.where(frozen, x, 0.0) * mult).astype(dtype)
        wt_active = jnp.where(active, mult, 0.0).astype(dtype)
        fz_dense = wt_frozen @ dense
        act_dense = wt_active @ dense
        wf = wt_frozen.reshape(C, s)
        wa = wt_active.reshape(C, s)
        fz_rr = jnp.einsum("ck,ckj->kj", wf, rem_read)
        act_rr = jnp.einsum("ck,ckj->kj", wa, rem_read)
        fz_ww = jnp.einsum("ck,ckj->kj", wf, rem_write)
        act_ww = jnp.einsum("ck,ckj->kj", wa, rem_write)

        def lam_of(resid, act):
            return jnp.where(
                act > _EPS, resid / jnp.maximum(act, _EPS), jnp.inf
            )

        lam_d = lam_of(jnp.maximum(dense_caps - fz_dense, 0.0), act_dense)
        lam_rr = lam_of(jnp.maximum(rr_caps - fz_rr, 0.0), act_rr)
        lam_ww = lam_of(jnp.maximum(ww_caps - fz_ww, 0.0), act_ww)
        lam_star = jnp.minimum(
            jnp.minimum(jnp.min(lam_d), jnp.min(lam_rr)),
            jnp.minimum(jnp.min(lam_ww), 1.0),
        )
        tol = lam_star * (1.0 + 1e-6)
        bn_d = lam_d <= tol
        bn_rr = lam_rr <= tol
        bn_ww = lam_ww <= tol
        uses = (
            (dense * bn_d[None, :]).sum(1)
            + jnp.einsum("ckj,kj->ck", rem_read, bn_rr.astype(dtype)).reshape(g)
            + jnp.einsum("ckj,kj->ck", rem_write, bn_ww.astype(dtype)).reshape(g)
        ) > _EPS
        freeze_now = active & (uses | (lam_star >= 1.0))
        x = jnp.where(freeze_now, lam_star, x)
        frozen = frozen | freeze_now
        return x, frozen

    state0 = (jnp.zeros((g,), dtype), jnp.zeros((g,), bool))
    if early_exit:
        x, frozen = jax.lax.while_loop(
            lambda st: ~jnp.all(st[1]), body, state0
        )
    else:
        x, frozen = jax.lax.fori_loop(
            0, iterations, lambda _, st: body(st), state0
        )
    return jnp.where(frozen, x, 1.0)


def bucket_size(n: int, *, base: int = 8) -> int:
    """The padded batch size for ``n`` rows: the smallest power-of-two
    bucket >= ``base`` that holds them.  Variable-size batches (search
    leaf batches, the advisor service's micro-batches) jit one trace per
    *bucket* instead of one per exact size, so steady-state serving stops
    retracing as soon as every bucket has been seen once."""
    if n < 0:
        raise ValueError(f"cannot bucket {n} rows")
    padded = base
    while padded < n:
        padded *= 2
    return padded


def pad_rows(rows: np.ndarray, *, base: int = 8) -> np.ndarray:
    """Pad a row batch to its :func:`bucket_size` by repeating row 0 —
    fixed jit shapes for variable batch sizes.  Callers slice the first
    ``len(rows)`` outputs back out; the padding rows are real (repeated)
    work, so results for them are well-defined and discarded."""
    rows = np.asarray(rows)
    padded = bucket_size(rows.shape[0], base=base)
    if padded == rows.shape[0]:
        return rows
    return np.concatenate(
        [rows, np.repeat(rows[:1], padded - rows.shape[0], axis=0)]
    )


def support_patterns(placements) -> tuple[np.ndarray, np.ndarray]:
    """Host-side bucketing of concrete placements by support pattern
    (which nodes hold any thread).  Returns the ``(n_buckets, s)`` 0/1
    support matrix — rows in lexicographic order, so the bucket layout is
    deterministic regardless of placement order — and the ``(P,)`` bucket
    id of every placement."""
    p = np.asarray(placements)
    sup = (p > 0).astype(np.int32)
    uniq, slab_id = np.unique(sup, axis=0, return_inverse=True)
    return uniq, slab_id.astype(np.int32).reshape(-1)


def simulate_grouped_batch(
    machine: MachineSpec,
    workload: Workload,
    placements: Array,  # (P, s) integer thread counts per node
    *,
    thread_classes: tuple[int, ...],
    support: Array | None = None,  # (n_buckets, s) support patterns
    slab_id: Array | None = None,  # (P,) bucket of each placement
    caps: Array | None = None,
    multipath: bool = False,
    elapsed: float = 1.0,
    early_exit: bool = True,
    bank_assignment: tuple[int, ...] | None = None,
) -> GroupedBatchResult:
    """Ground truth for a whole placement batch in one pass: bucket the
    placements by support pattern, build the base+interleave slab once per
    bucket, and vmap the structured progressive fill over only the traced
    multiplicity grids and rank-1 per-thread updates.

    ``support`` / ``slab_id`` (from :func:`support_patterns`) may be
    passed in when the caller already bucketed on the host — mandatory
    when ``placements`` is traced; computed here otherwise.

    ``bank_assignment`` applies one page placement (Local-class backing
    node per placement node; ``None`` = node-local) to the whole batch —
    the scheduler evaluates "threads moved, pages stayed" placements
    through this hook."""
    bank_assignment = canonical_bank_assignment(machine, bank_assignment)
    s = machine.n_nodes
    n = workload.n_threads
    topo = machine.topology
    placements = jnp.asarray(placements)
    if support is None or slab_id is None:
        support, slab_id = support_patterns(placements)
    support = jnp.asarray(support)
    slab_id = jnp.asarray(slab_id)

    comps = group_slab_components(
        machine, workload, thread_classes, bank_assignment
    )
    C = comps.base_read.shape[0]
    G = C * s
    dtype = comps.base_read.dtype
    dense_caps, rr_caps, ww_caps = split_caps(machine, caps)
    offdiag = (1.0 - jnp.eye(s, dtype=dtype))[None, :, :]  # (1, s, s)
    node_rates = machine.node_rates().astype(dtype)
    n_links = topo.n_links
    if n_links:
        inc = jnp.asarray(
            np.asarray(
                topo.route_incidence(multipath=multipath), np.float32
            ).reshape(s, s, n_links)
        )
    iterations = min(G, 2 * s + 2 * s * s + n_links) + 1

    def bucket_slab(sup):
        used = sup.astype(dtype)
        il_row = used / jnp.maximum(used.sum(), 1.0)  # (s,)
        ru = comps.base_read + comps.il_read[:, :, None] * il_row[None, None, :]
        wu = comps.base_write + comps.il_write[:, :, None] * il_row[None, None, :]
        if n_links:
            cross = (ru + wu) * offdiag
            lu = jnp.einsum("ckj,kjl->ckl", cross, inc)
        else:
            lu = jnp.zeros((C, s, 0), dtype)
        return ru, wu, lu

    b_ru, b_wu, b_lu = jax.vmap(bucket_slab)(support)

    if n_links:
        # per-link charge of one unit of pt_row flow from node k (the
        # diagonal rows of inc are all-zero, so no off-diagonal mask needed)
        def pt_link(pt_row):
            return jnp.einsum("j,kjl->kl", pt_row, inc)  # (s, L)
    starts = tuple(int(v) for v in np.asarray(thread_classes, np.int64))

    def per_placement(p, sid):
        nf = p.astype(dtype)
        pt_row = nf / jnp.maximum(nf.sum(), 1.0)
        ru = b_ru[sid] + comps.pt_read[:, :, None] * pt_row[None, None, :]
        wu = b_wu[sid] + comps.pt_write[:, :, None] * pt_row[None, None, :]
        if n_links:
            lu = b_lu[sid] + (
                (comps.pt_read + comps.pt_write)[:, :, None]
                * pt_link(pt_row)[None, :, :]
            )
        else:
            lu = b_lu[sid]
        dense = jnp.concatenate(
            [ru.reshape(G, s), wu.reshape(G, s), lu.reshape(G, n_links)], axis=1
        )
        rem_read = ru * offdiag
        rem_write = wu * offdiag
        mult = _group_multiplicities(starts, n, p).astype(dtype)  # (C, s)
        x = _progressive_fill_structured(
            dense, rem_read, rem_write, mult.reshape(G),
            dense_caps, rr_caps, ww_caps, iterations, early_exit=early_exit,
        )
        xg = x.reshape(C, s)
        weight = mult * xg
        read_flows = jnp.einsum("ck,ckj->kj", weight, ru) * elapsed
        write_flows = jnp.einsum("ck,ckj->kj", weight, wu) * elapsed
        instructions = (weight * node_rates[None, :]).sum(0) * elapsed
        return GroupedBatchResult(
            read_flows=read_flows,
            write_flows=write_flows,
            instructions=instructions,
            throughput=weight.sum(),
            group_rates=xg,
        )

    return jax.vmap(per_placement)(placements, slab_id)


def simulate(
    machine: MachineSpec,
    workload: Workload,
    n_per_node: Array,
    *,
    elapsed: float = 1.0,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    key: Array | None = None,
    caps: Array | None = None,
    thread_classes: tuple[int, ...] | None = None,
    multipath: bool = False,
    bank_assignment: tuple[int, ...] | None = None,
) -> SimulationResult:
    """Run the workload on the machine under the given placement (threads
    per NUMA node) and emit ground truth + the paper-visible performance
    counters.

    ``bank_assignment`` places the Local class's pages: entry ``k`` names
    the node whose DIMMs back the local buffers of threads on node ``k``
    (``None`` = node-local, bit-for-bit today's behavior).  Redirected
    Local flows are charged like any other remote traffic: the remote
    path ``(k, bank)`` and every link on its route.

    ``caps`` substitutes the machine's capacity vector (slab order of
    :func:`machine_caps`) with traced values — the differentiable-forward
    hook ``repro.core.numa.calibrate`` fits machine parameters through;
    everything else about the machine (routes, rates, thread geometry)
    stays static structure.

    ``thread_classes`` is the static class-start partition from
    :func:`thread_class_starts` — required to stay on the group-collapsed
    hot path when the workload arrays are traced (inside jit/vmap their
    values cannot be inspected).  With concrete arrays it is inferred;
    otherwise the per-thread :func:`simulate_reference` path runs."""
    bank_assignment = canonical_bank_assignment(machine, bank_assignment)
    if thread_classes is None:
        thread_classes = _infer_thread_classes(workload)
    if thread_classes is None:
        return simulate_reference(
            machine, workload, n_per_node,
            elapsed=elapsed, noise_std=noise_std, background_bw=background_bw,
            key=key, caps=caps, multipath=multipath,
            bank_assignment=bank_assignment,
        )

    s = machine.n_nodes
    n = workload.n_threads
    n_per_node = jnp.asarray(n_per_node)
    starts = np.asarray(thread_classes, np.int64)
    if starts.size == 0 or starts[0] != 0 or (np.diff(starts) <= 0).any() or (
        starts[-1] >= n
    ):
        raise ValueError(f"invalid thread_classes {thread_classes} for {n} threads")
    C = starts.size
    rep = starts  # class representative = first member (static gather)

    node_rates = machine.node_rates()  # (s,)
    read_mix = _group_mix_rows(
        workload.read_static[rep],
        workload.read_local[rep],
        workload.read_per_thread[rep],
        workload.static_socket,
        n_per_node,
        bank_assignment,
    )
    write_mix = _group_mix_rows(
        workload.write_static[rep],
        workload.write_local[rep],
        workload.write_per_thread[rep],
        workload.static_socket,
        n_per_node,
        bank_assignment,
    )
    # (C, s, s): one class-c thread's unit demand on node k toward bank j
    read_unit = node_rates[None, :, None] * workload.read_bpi[rep][:, None, None] * read_mix
    write_unit = node_rates[None, :, None] * workload.write_bpi[rep][:, None, None] * write_mix

    usage, caps = _group_resource_tensor(
        machine, read_unit, write_unit, caps, multipath=multipath
    )
    mult = _group_multiplicities(thread_classes, n, n_per_node)  # (C, s)
    mult_f = mult.astype(usage.dtype)
    iterations = min(usage.shape[0], usage.shape[1]) + 1
    x = _progressive_fill_grouped(usage, mult_f.reshape(C * s), caps, iterations)
    xg = x.reshape(C, s)

    weight = mult_f * xg  # (C, s): threads x shared group rate
    read_flows = jnp.einsum("ck,ckj->kj", weight, read_unit) * elapsed
    write_flows = jnp.einsum("ck,ckj->kj", weight, write_unit) * elapsed
    instructions = (weight * node_rates[None, :]).sum(0) * elapsed

    node_of = _thread_nodes(n_per_node, n)
    class_of = np.searchsorted(starts, np.arange(n), side="right") - 1  # static
    rates = xg[class_of, node_of]

    return _finalize_result(
        rates, read_flows, write_flows, instructions, n_per_node,
        elapsed, noise_std, background_bw, key, s,
    )


def simulate_counters(
    machine: MachineSpec,
    workload: Workload,
    n_per_node: Array,
    **kwargs,
) -> CounterSample:
    """Just the performance counters of a simulated run — what a real
    profiling pass would hand the fitting pipeline."""
    return simulate(machine, workload, n_per_node, **kwargs).sample


def symmetric_placement(machine: MachineSpec, n_threads: int) -> Array:
    """Paper §5.1 run 1: equal threads per NUMA node, 1 thread/core."""
    assert n_threads % machine.n_nodes == 0, "symmetric run needs equal split"
    per = n_threads // machine.n_nodes
    assert per <= machine.cores_per_node
    return jnp.full((machine.n_nodes,), per, jnp.int32)


def asymmetric_placement(machine: MachineSpec, n_threads: int) -> Array:
    """Paper §5.1 run 2: same thread count, unequal split (Figure 7 uses a
    roughly 2:1 split on the first socket) — generalized to NUMA nodes.

    The 3:1 target split can be infeasible — e.g. 2 threads on a 2-node
    machine leave zero threads for the second node, and a full machine
    admits only the equal split.  Instead of asserting, fall back to the
    nearest valid split: node 0 gets the feasible count closest to the
    3:1 target (ties prefer the heavier node) that still yields an
    *unequal* split when any exists; a perfectly full machine returns the
    only (equal) valid placement.
    """
    s = machine.n_nodes
    cap = machine.cores_per_node
    if not 0 < n_threads <= s * cap:
        raise ValueError(f"{n_threads} threads do not fit {s} nodes x {cap} cores")
    target = -(-3 * n_threads // 4)

    def split_for(first: int) -> list[int] | None:
        rest = n_threads - first
        if rest < 0 or rest > (s - 1) * cap:
            return None
        others = [rest // (s - 1)] * (s - 1)
        others[0] += rest - sum(others)
        # spill overflow beyond per-socket capacity rightward; a no-op
        # whenever the heaped shape was already feasible (seed behaviour)
        for k in range(s - 2):
            if others[k] > cap:
                others[k + 1] += others[k] - cap
                others[k] = cap
        counts = [first] + others
        return counts if max(counts) <= cap else None

    candidates = sorted(
        range(min(cap, n_threads) + 1), key=lambda f: (abs(f - target), -f)
    )
    fallback = None
    for first in candidates:
        counts = split_for(first)
        if counts is None:
            continue
        if len(set(counts)) > 1:
            return jnp.asarray(counts, jnp.int32)
        if fallback is None:
            fallback = counts
    assert fallback is not None  # n_threads <= s * cap guarantees a split
    return jnp.asarray(fallback, jnp.int32)


def profile_pair(
    machine: MachineSpec,
    workload: Workload,
    *,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    key: Array | None = None,
    thread_classes: tuple[int, ...] | None = None,
) -> tuple[CounterSample, CounterSample]:
    """The paper's 2-run profiling protocol (§5.1): one symmetric and one
    asymmetric placement of the same thread count.  ``thread_classes``
    keeps traced callers (the batched fit) on the grouped solver."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k_sym, k_asym = jax.random.split(key)
    sym = simulate_counters(
        machine,
        workload,
        symmetric_placement(machine, workload.n_threads),
        noise_std=noise_std,
        background_bw=background_bw,
        key=k_sym,
        thread_classes=thread_classes,
    )
    asym = simulate_counters(
        machine,
        workload,
        asymmetric_placement(machine, workload.n_threads),
        noise_std=noise_std,
        background_bw=background_bw,
        key=k_asym,
        thread_classes=thread_classes,
    )
    return sym, asym
