"""Workload descriptions for the NUMA simulator.

A :class:`Workload` carries *per-thread* ground-truth access mixes.  For
well-behaved applications every thread shares the same mix and the paper's
4-class model is exact; model-violating workloads (paper §6.2: Page rank's
skewed node ordering) give different threads different mixes or intensities,
so the bandwidth pattern changes with placement in ways the model cannot
express — which is precisely what the §6.2.1 detector must flag.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import Array


class Workload(NamedTuple):
    """Ground truth for ``n`` threads on an ``s``-node machine.

    Fraction arrays have shape ``(n,)`` and describe each thread's true
    traffic mix per direction (interleaved = remainder).  ``*_bpi`` are
    bytes/instruction intensities.  ``static_socket`` is shared (the Static
    class is, by definition, a single allocation) and names the NUMA *node*
    holding it — on ``nodes_per_socket=1`` machines, the socket.
    """

    name: str
    read_static: Array
    read_local: Array
    read_per_thread: Array
    write_static: Array
    write_local: Array
    write_per_thread: Array
    read_bpi: Array
    write_bpi: Array
    static_socket: Array  # int32 scalar

    @property
    def n_threads(self) -> int:
        """Thread count (the leading axis of every per-thread field)."""
        return self.read_static.shape[0]

    def read_interleaved(self) -> Array:
        """Per-thread interleaved read fraction — the residual class."""
        return 1.0 - self.read_static - self.read_local - self.read_per_thread

    def write_interleaved(self) -> Array:
        """Per-thread interleaved write fraction — the residual class."""
        return 1.0 - self.write_static - self.write_local - self.write_per_thread


def mixed_workload(
    name: str,
    n_threads: int,
    *,
    read_mix: tuple[float, float, float] = (0.0, 0.0, 0.0),
    write_mix: tuple[float, float, float] | None = None,
    read_bpi: float = 0.6,
    write_bpi: float = 0.2,
    static_socket: int = 0,
) -> Workload:
    """A homogeneous workload: every thread shares the same
    ``(static, local, per_thread)`` mix — the model-representable case."""
    if write_mix is None:
        write_mix = read_mix
    for mix in (read_mix, write_mix):
        assert min(mix) >= 0.0 and sum(mix) <= 1.0 + 1e-6, mix
    ones = jnp.ones((n_threads,), jnp.float32)
    return Workload(
        name=name,
        read_static=ones * read_mix[0],
        read_local=ones * read_mix[1],
        read_per_thread=ones * read_mix[2],
        write_static=ones * write_mix[0],
        write_local=ones * write_mix[1],
        write_per_thread=ones * write_mix[2],
        read_bpi=ones * read_bpi,
        write_bpi=ones * write_bpi,
        static_socket=jnp.asarray(static_socket, jnp.int32),
    )


def pure_workload(
    name: str,
    n_threads: int,
    pattern: str,
    *,
    read_bpi: float = 0.6,
    write_bpi: float = 0.2,
    static_socket: int = 0,
) -> Workload:
    """The §6.1 synthetic benchmarks: index-chasing arrays placed with a
    single pure pattern (Static / Local / Interleaved / Per-thread)."""
    mixes = {
        "static": (1.0, 0.0, 0.0),
        "local": (0.0, 1.0, 0.0),
        "per_thread": (0.0, 0.0, 1.0),
        "interleaved": (0.0, 0.0, 0.0),
    }
    if pattern not in mixes:
        raise ValueError(f"unknown pattern {pattern!r}")
    return mixed_workload(
        name,
        n_threads,
        read_mix=mixes[pattern],
        write_mix=mixes[pattern],
        read_bpi=read_bpi,
        write_bpi=write_bpi,
        static_socket=static_socket,
    )


def violator_workload(
    name: str,
    n_threads: int,
    *,
    base_read_mix: tuple[float, float, float] = (0.05, 0.15, 0.4),
    hot_fraction: float = 0.5,
    hot_intensity: float = 2.0,
    hot_extra_static: float = 0.35,
    read_bpi: float = 0.7,
    write_bpi: float = 0.15,
    static_socket: int = 0,
) -> Workload:
    """A Page-rank-like model violator (paper §6.2, Figure 16).

    The graph's early chunks hold the well-connected nodes, so the threads
    that own them (the first ``hot_fraction`` of the thread range, which a
    contiguous placement maps to the first socket) are hotter and lean much
    harder on the shared early region — effectively extra static traffic
    that moves with the threads instead of staying put.  The 4-class model
    cannot represent this.
    """
    n = n_threads
    t = jnp.arange(n)
    hot = (t < jnp.round(hot_fraction * n)).astype(jnp.float32)
    ones = jnp.ones((n,), jnp.float32)
    rs, rl, rp = base_read_mix
    read_static = ones * rs + hot * hot_extra_static
    read_local = ones * rl * (1.0 - hot * 0.5)
    read_per_thread = ones * rp * (1.0 - hot * 0.5)
    # keep each thread's mix a valid distribution
    total = read_static + read_local + read_per_thread
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(total, 1e-9))
    read_static, read_local, read_per_thread = (
        read_static * scale,
        read_local * scale,
        read_per_thread * scale,
    )
    bpi = ones * read_bpi * (1.0 + hot * (hot_intensity - 1.0))
    return Workload(
        name=name,
        read_static=read_static,
        read_local=read_local,
        read_per_thread=read_per_thread,
        write_static=ones * 0.05,
        write_local=ones * 0.6,
        write_per_thread=ones * 0.2,
        read_bpi=bpi,
        write_bpi=ones * write_bpi,
        static_socket=jnp.asarray(static_socket, jnp.int32),
    )
