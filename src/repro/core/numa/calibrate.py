"""Learned topology calibration — the inverse problem of the simulator.

The paper parameterizes its bandwidth model from counters sampled in two
carefully chosen runs; every ``MachineSpec`` in this repo was, until now,
hand-specified.  This module solves the *inverse* problem the ROADMAP's
"Learned topology fit" item asks for: given a set of ``(placement,
observed counters)`` samples — produced by the simulator for synthetic
ground truth, or by any ``bwsig/counters.py``-shaped counter trace from a
real machine — recover the free parameters of a machine:

* the per-link interconnect bandwidths (through the topology's
  symmetry/structure packing, :func:`repro.core.graphtop.link_groups` —
  the same packing + AdamW-in-log-space recipe
  :mod:`repro.core.meshsig.calibrate` runs for ICI links),
* ``hop_attenuation``, and
* the (per-node) ``local_read_bw`` / ``local_write_bw`` tuples,

holding the structural template fixed: node count, core rates, routing
tables and the remote path base capacities (the ratio-characterized
quantities of paper Figure 2, measurable from a single remote STREAM-style
run) all come from the template spec.

The fit is two-stage, mirroring the paper's philosophy of cheap seeding
plus model refinement:

1. **Counter seeding** (:func:`seed_parameters`) — closed-form lower
   bounds read straight off the samples.  Each bank's capacity is seeded
   by the largest total it was ever observed to move; per-pair flows are
   recovered from the bank-perspective remote counters by the same
   thread-count apportionment rule ``bwsig.fit`` uses (exact whenever one
   remote source is active, which the probe suite guarantees), charged
   along the static routes to seed every link; multi-hop pair flows
   lower-bound the attenuation.  On a saturating probe sweep these bounds
   are *tight* — the seed alone is often within a few percent.
2. **Projected gradient over the differentiable simulator**
   (:func:`fit_machine`) — all parameters are refined jointly by AdamW in
   log space (positivity by reparameterization, the smooth form of a
   projection) against the squared relative counter error of the full
   max-min-fair forward model, one jitted ``lax.scan`` of
   ``value_and_grad`` steps with the machine template static and only the
   capacity vector traced (``simulate(..., caps=...)``).

The probe suite (:func:`probe_suite`) is the sweep design that makes the
problem identifiable: per-node local probes saturate each bank in each
direction, per-ordered-pair static probes saturate thin links and the
hop-attenuated remote paths (these include the paper's 2-run
symmetric/asymmetric pair), and spread interleave/static-sink probes
saturate fat shared links that no single pair can fill (an SNC socket's
QPI port carries both directions of every cross-socket pair at once).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.bwsig.counters import CounterSample
from repro.core.bwsig.fit import _remote_source_weights
from repro.core.numa.machine import GB, MachineSpec
from repro.core.numa.simulator import (
    asymmetric_placement,
    class_starts_from_arrays,
    simulate,
    thread_class_starts,
)
from repro.core.numa.topology import LinkGroups, from_fit, link_groups
from repro.core.numa.workload import Workload, mixed_workload
from repro.optim import adamw

_EPS = 1e-9
# Finite stand-in for the unconstrained diagonal of the remote-path caps:
# its usage column is structurally zero, so any value never binds — but a
# finite one keeps the progressive-fill linearization coefficients finite
# under reverse-mode AD (inf residuals turn 0-cotangent products into NaN).
_UNUSED_CAP = 1e5


class CalibrationSamples(NamedTuple):
    """A counter sweep: ``P`` profiling runs of known workloads/placements.

    ``wl_arrays`` stacks every array field of the run's :class:`Workload`
    over the leading sample axis (the jit boundary cannot carry the name
    string); counters are bytes (or instructions) observed over
    ``elapsed`` seconds, bank-perspective, exactly the
    :class:`~repro.core.bwsig.counters.CounterSample` view real hardware
    exposes."""

    wl_arrays: tuple[Array, ...]  # leaves (P, n) / (P,)
    placements: Array  # (P, s) int32
    local_read: Array  # (P, s)
    remote_read: Array  # (P, s)
    local_write: Array  # (P, s)
    remote_write: Array  # (P, s)
    instructions: Array  # (P, s)
    elapsed: Array  # (P,)

    @property
    def n_samples(self) -> int:
        """Number of profiled placements in the sample set."""
        return int(self.placements.shape[0])

    @property
    def n_nodes(self) -> int:
        """NUMA node count of the machine the samples came from."""
        return int(self.placements.shape[1])


class CalibrationParams(NamedTuple):
    """Free parameters, unconstrained: capacities live in log space and
    the attenuation behind a sigmoid, so plain gradient steps stay inside
    the feasible set (the smooth projection)."""

    log_link_bw: Array  # (n_groups,)
    log_local_read: Array  # (s,)
    log_local_write: Array  # (s,)
    att_raw: Array  # () — hop_attenuation = sigmoid(att_raw)


class SampleDiagnostics(NamedTuple):
    """Ingestion receipts from :func:`clean_samples`: how many rows
    arrived, how many survived, and why the rest were rejected — the
    counted evidence a production counter feed is (or is not) healthy."""

    n_total: int
    n_kept: int
    n_rejected: int
    reasons: tuple[str, ...]  # one short description per reject category

    @property
    def reject_rate(self) -> float:
        """Fraction of ingested rows rejected (0.0 on an empty batch)."""
        return self.n_rejected / self.n_total if self.n_total else 0.0


class CalibrationResult(NamedTuple):
    """A fitted machine plus the optimizer's receipts (loss trajectory,
    seed-vs-final loss, and the raw parameters behind the spec).
    ``diagnostics`` carries the sample-ingestion receipts when the fit
    cleaned its input (``fit_machine(clean=True)``, the default)."""

    machine: MachineSpec  # the fitted spec (concrete, validated)
    params: CalibrationParams
    groups: LinkGroups
    loss_history: np.ndarray  # (steps,)
    seed_loss: float
    final_loss: float
    diagnostics: "SampleDiagnostics | None" = None


# ---------------------------------------------------------------------------
# Sample construction
# ---------------------------------------------------------------------------


def _workload_arrays(wl: Workload) -> tuple[Array, ...]:
    return tuple(wl[1:])


def _stack_probe_workloads(wls: Sequence[Workload]) -> tuple[Array, ...]:
    n_threads = {w.n_threads for w in wls}
    if len(n_threads) != 1:
        raise ValueError(f"probe workloads must share a thread count, got {n_threads}")
    return tuple(
        jnp.stack(parts) for parts in zip(*(_workload_arrays(w) for w in wls))
    )


def samples_from_counters(
    workloads: Sequence[Workload],
    placements,
    counters: Sequence[CounterSample],
) -> CalibrationSamples:
    """Package an externally measured counter trace (one
    :class:`CounterSample` per known workload+placement run) for fitting —
    the path a real machine's PCM trace takes into the calibrator."""
    if not len(workloads) == len(counters):
        raise ValueError("one CounterSample per workload run required")
    placements = jnp.asarray(placements, jnp.int32)
    if placements.shape[0] != len(workloads):
        raise ValueError("one placement per workload run required")
    # each CounterSample records the placement of its own run — a silent
    # order mismatch against the placements argument would apportion the
    # remote counters by the wrong thread counts and corrupt the fit
    for k, c in enumerate(counters):
        recorded = np.asarray(c.n_per_socket)
        if not np.array_equal(recorded, np.asarray(placements[k])):
            raise ValueError(
                f"run {k}: placement {np.asarray(placements[k]).tolist()} "
                f"disagrees with the counter sample's recorded placement "
                f"{recorded.tolist()}"
            )
    return CalibrationSamples(
        wl_arrays=_stack_probe_workloads(workloads),
        placements=placements,
        local_read=jnp.stack([c.local_read for c in counters]),
        remote_read=jnp.stack([c.remote_read for c in counters]),
        local_write=jnp.stack([c.local_write for c in counters]),
        remote_write=jnp.stack([c.remote_write for c in counters]),
        instructions=jnp.stack([c.instructions for c in counters]),
        elapsed=jnp.stack([jnp.asarray(c.elapsed, jnp.float32) for c in counters]),
    )


def _take_rows(samples: CalibrationSamples, keep: np.ndarray) -> CalibrationSamples:
    """Index every leaf of a sample set by the ``keep`` row indices."""
    take = lambda arr: jnp.asarray(np.asarray(arr)[keep])
    return CalibrationSamples(
        wl_arrays=tuple(take(a) for a in samples.wl_arrays),
        placements=take(samples.placements),
        local_read=take(samples.local_read),
        remote_read=take(samples.remote_read),
        local_write=take(samples.local_write),
        remote_write=take(samples.remote_write),
        instructions=take(samples.instructions),
        elapsed=take(samples.elapsed),
    )


def clean_samples(
    samples: CalibrationSamples,
    *,
    on_empty: str = "raise",
) -> tuple[CalibrationSamples, SampleDiagnostics]:
    """NaN-guard a sample batch before it can poison the AdamW fit.

    A row (one profiled placement) is rejected when any of its workload
    arrays, placement entries or counters is non-finite, any counter is
    negative, or its elapsed time is not strictly positive — the three
    corruption modes a production counter feed actually exhibits (dropped
    MSR reads surface as NaN/garbage, wrap-around as negatives, a dead
    sampling interval as elapsed 0).  Returns the surviving rows plus a
    :class:`SampleDiagnostics` counting what was dropped and why.

    ``on_empty="raise"`` (default) raises a descriptive ``ValueError``
    when *no* row survives — a silently empty fit input is the worst
    possible outcome; ``on_empty="ignore"`` returns the empty batch for
    callers that accumulate across batches and check later.
    """
    P = samples.n_samples
    leaves = (
        samples.wl_arrays
        + (
            samples.placements,
            samples.local_read,
            samples.remote_read,
            samples.local_write,
            samples.remote_write,
            samples.instructions,
            samples.elapsed,
        )
    )
    finite = np.ones((P,), bool)
    for arr in leaves:
        a = np.asarray(arr, np.float64).reshape(P, -1)
        finite &= np.isfinite(a).all(axis=1)
    counters = np.concatenate(
        [
            np.asarray(c, np.float64).reshape(P, -1)
            for c in (
                samples.local_read, samples.remote_read,
                samples.local_write, samples.remote_write,
                samples.instructions,
            )
        ],
        axis=1,
    )
    with np.errstate(invalid="ignore"):
        nonneg = ~(counters < 0).any(axis=1)
        pos_elapsed = np.asarray(samples.elapsed, np.float64) > 0
    keep_mask = finite & nonneg & pos_elapsed
    reasons = []
    for mask, what in (
        (~finite, "non-finite values"),
        (finite & ~nonneg, "negative counters"),
        (finite & nonneg & ~pos_elapsed, "non-positive elapsed time"),
    ):
        idx = np.flatnonzero(mask)
        if idx.size:
            shown = ", ".join(str(i) for i in idx[:8])
            more = f", +{idx.size - 8} more" if idx.size > 8 else ""
            reasons.append(f"{idx.size} row(s) with {what} (rows {shown}{more})")
    diag = SampleDiagnostics(
        n_total=P,
        n_kept=int(keep_mask.sum()),
        n_rejected=int(P - keep_mask.sum()),
        reasons=tuple(reasons),
    )
    if diag.n_kept == 0 and on_empty == "raise":
        raise ValueError(
            f"all {P} calibration samples rejected: " + "; ".join(reasons)
            if reasons
            else "calibration sample batch is empty"
        )
    if diag.n_rejected == 0:
        return samples, diag
    return _take_rows(samples, np.flatnonzero(keep_mask)), diag


def concat_samples(batches: Sequence[CalibrationSamples]) -> CalibrationSamples:
    """Concatenate sample batches along the sample axis — the
    accumulation step of a production recalibration stream, where
    counters arrive machine-by-machine in partial sweeps rather than as
    one designed probe suite.  All batches must agree on node count and
    probe thread count."""
    if not batches:
        raise ValueError("need at least one sample batch to concatenate")
    if len(batches) == 1:
        return batches[0]
    nodes = {b.n_nodes for b in batches}
    if len(nodes) != 1:
        raise ValueError(f"sample batches disagree on node count: {nodes}")
    shapes = {tuple(np.asarray(a).shape[1:] for a in b.wl_arrays) for b in batches}
    if len(shapes) != 1:
        raise ValueError(
            "sample batches disagree on workload shape (thread counts differ?)"
        )
    cat = lambda leaves: jnp.concatenate([jnp.asarray(a) for a in leaves])
    return CalibrationSamples(
        wl_arrays=tuple(
            cat([b.wl_arrays[i] for b in batches])
            for i in range(len(batches[0].wl_arrays))
        ),
        placements=cat([b.placements for b in batches]),
        local_read=cat([b.local_read for b in batches]),
        remote_read=cat([b.remote_read for b in batches]),
        local_write=cat([b.local_write for b in batches]),
        remote_write=cat([b.remote_write for b in batches]),
        instructions=cat([b.instructions for b in batches]),
        elapsed=cat([b.elapsed for b in batches]),
    )


def take_samples(samples: CalibrationSamples, idx) -> CalibrationSamples:
    """Row-subset a sample set (``idx`` is any numpy index expression) —
    the partial-sweep path: fitting proceeds from whatever subset of the
    probe suite a production trace happened to cover."""
    return _take_rows(samples, np.asarray(idx))


# ---------------------------------------------------------------------------
# Probe sweep design
# ---------------------------------------------------------------------------


def _spread_placement(s: int, n_threads: int) -> np.ndarray:
    counts = np.full((s,), n_threads // s, np.int32)
    counts[: n_threads % s] += 1
    return counts


def probe_suite(
    template: MachineSpec,
    n_threads: int | None = None,
    *,
    read_bpi: float = 8.0,
    write_bpi: float = 4.0,
) -> list[tuple[Workload, np.ndarray]]:
    """The designed calibration sweep: ``(workload, placement)`` pairs
    whose union of saturation patterns identifies every free parameter.

    Only the template's *structure* (node count, cores per node, issue
    rates) shapes the design — bandwidths are what the sweep measures.
    All probes share one thread count so the whole sweep stacks into a
    single vmapped trace."""
    s, cap = template.n_nodes, template.cores_per_node
    if n_threads is None:
        n_threads = min(cap, 8)
    if not 0 < n_threads <= cap:
        raise ValueError(f"{n_threads} probe threads exceed {cap} cores/node")
    nt = n_threads
    probes: list[tuple[Workload, np.ndarray]] = []

    def one_node(i: int) -> np.ndarray:
        p = np.zeros((s,), np.int32)
        p[i] = nt
        return p

    # 1. per-node local probes, one direction at a time: saturate each
    #    bank's read and write capacity in isolation.
    for i in range(s):
        for tag, rb, wb in (("r", read_bpi, 0.0), ("w", 0.0, write_bpi)):
            probes.append(
                (
                    mixed_workload(
                        f"cal-local-{tag}{i}", nt,
                        read_mix=(0.0, 1.0, 0.0), read_bpi=rb, write_bpi=wb,
                    ),
                    one_node(i),
                )
            )

    # 2. per-ordered-pair static probes: all threads on node i streaming a
    #    Static allocation on node j — saturates the (i, j) remote path
    #    (hop-attenuated) or the thinnest link on route(i, j), whichever
    #    is tighter, one direction at a time.
    for i in range(s):
        for j in range(s):
            if i == j:
                continue
            for tag, rb, wb in (("r", read_bpi, 0.0), ("w", 0.0, write_bpi)):
                probes.append(
                    (
                        mixed_workload(
                            f"cal-pair-{tag}{i}-{j}", nt,
                            read_mix=(1.0, 0.0, 0.0), read_bpi=rb,
                            write_bpi=wb, static_socket=j,
                        ),
                        one_node(i),
                    )
                )

    # 3. spread interleave stress probes: every node pumping traffic to
    #    every bank at once — the only pattern that fills fat shared links
    #    (an SNC QPI port carries both directions of 2*k^2 node pairs).
    spread = _spread_placement(s, nt)
    for tag, rb, wb in (
        ("r", read_bpi, 0.0),
        ("w", 0.0, write_bpi),
        ("rw", read_bpi, write_bpi),
    ):
        probes.append(
            (
                mixed_workload(
                    f"cal-inter-{tag}", nt,
                    read_mix=(0.0, 0.0, 0.0), read_bpi=rb, write_bpi=wb,
                ),
                spread,
            )
        )

    # 4. static-sink stress probes: every *other* node's threads
    #    converging on one bank — saturates the sink's incident links with
    #    multi-source (routed) traffic no single pair can generate.  The
    #    sink node hosts no threads (its local traffic would win a
    #    max-min share of the bank and starve the link below saturation),
    #    and several write:read ratios are swept so that for some ratio
    #    the incident link binds before either bank-direction cap does
    #    (link binds iff (R+W)/C_link exceeds both R/C_read and W/C_write
    #    — a window in W/R that depends on the capacities under test).
    for j in range(s):
        if s < 2:
            break
        others = np.zeros((s,), np.int32)
        share = _spread_placement(s - 1, nt)
        others[np.arange(s) != j] = share
        for alpha in (0.25, 0.5, 1.0):
            probes.append(
                (
                    mixed_workload(
                        f"cal-sink-{j}-a{alpha}", nt,
                        read_mix=(1.0, 0.0, 0.0), read_bpi=read_bpi,
                        write_bpi=read_bpi * alpha, static_socket=j,
                    ),
                    others,
                )
            )

    # 5. the paper's 2-run pair (§5.1): one symmetric and one asymmetric
    #    placement of a generic mixed workload — the classic seeding runs,
    #    kept in-sweep so the fit and the paper's protocol share data.
    wl_2run = mixed_workload(
        "cal-2run", nt, read_mix=(0.3, 0.3, 0.2),
        read_bpi=read_bpi * 0.5, write_bpi=write_bpi * 0.5,
    )
    probes.append((wl_2run, spread))
    probes.append(
        (wl_2run, np.asarray(asymmetric_placement(template, nt), np.int32))
    )
    return probes


@partial(
    jax.jit,
    static_argnames=("machine", "noise_std", "background_bw", "thread_classes"),
)
def _collect_jit(
    machine, wl_arrays, placements, keys, noise_std, background_bw, thread_classes
):
    def one(arrays, placement, key):
        wl = Workload("calib", *arrays)
        res = simulate(
            machine, wl, placement,
            noise_std=noise_std, background_bw=background_bw, key=key,
            thread_classes=thread_classes,
        )
        smp = res.sample
        return (
            smp.local_read, smp.remote_read, smp.local_write,
            smp.remote_write, smp.instructions,
        )

    return jax.vmap(one)(wl_arrays, placements, keys)


def collect_sweep(
    machine: MachineSpec,
    probes: Sequence[tuple[Workload, np.ndarray]] | None = None,
    *,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    key: Array | None = None,
) -> CalibrationSamples:
    """Run a probe sweep through the simulator (the synthetic-ground-truth
    path) and package the observed counters for fitting.  ``probes``
    defaults to :func:`probe_suite` on the machine itself."""
    if probes is None:
        probes = probe_suite(machine)
    wls = [wl for wl, _ in probes]
    placements = jnp.asarray(np.stack([p for _, p in probes]), jnp.int32)
    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, len(wls))
    wl_arrays = _stack_probe_workloads(wls)
    lr, rr, lw, rw, ins = _collect_jit(
        machine, wl_arrays, placements, keys,
        float(noise_std), float(background_bw),
        thread_class_starts(wls),
    )
    return CalibrationSamples(
        wl_arrays=wl_arrays,
        placements=placements,
        local_read=lr, remote_read=rr, local_write=lw, remote_write=rw,
        instructions=ins,
        elapsed=jnp.ones((len(wls),), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Stage 1: counter seeding
# ---------------------------------------------------------------------------


def _pair_flows(samples: CalibrationSamples, counter: Array) -> Array:
    """``(P, s, s)`` estimated source->bank flows from a bank-perspective
    counter, apportioning each bank's remote traffic to the other nodes in
    proportion to their thread counts — ``bwsig.fit``'s rule, exact when a
    single remote source is active (every pair probe; the paper's s=2)."""
    w = jax.vmap(_remote_source_weights)(samples.placements)  # (P, bank j, src i)
    return jnp.swapaxes(w * counter[:, :, None], 1, 2)  # (P, i, j)


def seed_parameters(
    template: MachineSpec,
    samples: CalibrationSamples,
    groups: LinkGroups | None = None,
    *,
    floor_frac: float = 0.02,
) -> CalibrationParams:
    """Closed-form seeds: every observed rate is a lower bound on the
    capacity it crossed, and the probe suite makes the interesting bounds
    tight.  Never exercised parameters are floored at ``floor_frac`` of
    the largest seed in their family so log-space stays finite."""
    if groups is None:
        groups = link_groups(template.topology)
    s = template.n_nodes
    el = samples.elapsed[:, None]
    lr = samples.local_read / el
    rr = samples.remote_read / el
    lw = samples.local_write / el
    rw = samples.remote_write / el

    def floored(x: Array) -> Array:
        return jnp.maximum(x, jnp.maximum(floor_frac * x.max(), 1.0))

    bank_r = floored((lr + rr).max(0))
    bank_w = floored((lw + rw).max(0))

    pair_r = _pair_flows(samples, rr)
    pair_w = _pair_flows(samples, rw)
    incidence = jnp.asarray(template.topology.route_incidence())  # (s*s, L)
    charge = (pair_r + pair_w).reshape(samples.placements.shape[0], s * s) @ incidence
    link_seed = np.asarray(floored(charge.max(0)))

    # attenuation: a multi-hop pair's flow obeys flow <= base * att**(h-1),
    # so every (flow/base)**(1/(h-1)) lower-bounds att; take the best bound
    # over pairs and directions.
    hops = np.asarray(template.topology.hop_matrix(), np.float64)
    att_seed = 0.95
    if hops.max() > 1:
        ests = []
        for base, flows in (
            (template.remote_read_bw, np.asarray(pair_r.max(0), np.float64)),
            (template.remote_write_bw, np.asarray(pair_w.max(0), np.float64)),
        ):
            multi = hops > 1
            ratio = np.clip(flows / max(base, _EPS), 1e-6, 1.0)
            ests.append((ratio ** (1.0 / np.maximum(hops - 1.0, 1.0)))[multi])
        att_seed = float(np.clip(np.concatenate(ests).max(), 0.3, 0.995))

    return CalibrationParams(
        log_link_bw=jnp.log(jnp.asarray(groups.pack(link_seed), jnp.float32)),
        log_local_read=jnp.log(bank_r.astype(jnp.float32)),
        log_local_write=jnp.log(bank_w.astype(jnp.float32)),
        att_raw=jnp.asarray(np.log(att_seed / (1.0 - att_seed)), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Stage 2: projected gradient over the differentiable forward model
# ---------------------------------------------------------------------------


def _caps_from(
    template: MachineSpec, groups: LinkGroups, params: CalibrationParams
) -> Array:
    """Assemble the traced capacity vector (simulator slab order) from the
    free parameters; routing, hop counts and the remote path bases stay
    static template structure."""
    s = template.n_nodes
    link_bw = groups.unpack(jnp.exp(params.log_link_bw))
    bank_r = jnp.exp(params.log_local_read)
    bank_w = jnp.exp(params.log_local_write)
    hops = jnp.asarray(template.topology.hop_matrix(), jnp.float32)
    if template.topology.max_hops > 1:
        att = jax.nn.sigmoid(params.att_raw)
    else:  # single-hop: attenuation is structurally unobservable
        att = jnp.asarray(1.0, jnp.float32)
    extra = jnp.maximum(hops - 1.0, 0.0)
    rr = jnp.where(hops == 0, _UNUSED_CAP, template.remote_read_bw * att**extra)
    ww = jnp.where(hops == 0, _UNUSED_CAP, template.remote_write_bw * att**extra)
    return jnp.concatenate(
        [bank_r, bank_w, rr.reshape(s * s), ww.reshape(s * s), link_bw]
    )


def _residual_penalty(r: Array, huber_delta: float | None) -> Array:
    """Sum of squared residuals, or — when ``huber_delta`` is set — the
    Huber penalty: quadratic inside ``delta``, linear outside, so a few
    wildly corrupted counter rows pull the fit linearly instead of
    quadratically (the outlier-robust loss production traces need)."""
    if huber_delta is None:
        return (r**2).sum()
    a = jnp.abs(r)
    d = huber_delta
    return jnp.where(a <= d, 0.5 * a * a, d * (a - 0.5 * d)).sum()


def _sweep_loss(
    template: MachineSpec,
    groups: LinkGroups,
    samples: CalibrationSamples,
    params: CalibrationParams,
    instruction_weight: float,
    thread_classes: tuple[int, ...],
    huber_delta: float | None = None,
) -> Array:
    caps = _caps_from(template, groups, params)

    def per_sample(arrays, placement, olr, orr, olw, orw, oins, el):
        wl = Workload("calib", *arrays)
        res = simulate(
            template, wl, placement, caps=caps, thread_classes=thread_classes
        )
        smp = res.sample
        obs = jnp.concatenate([olr, orr, olw, orw]) / el
        sim = jnp.concatenate(
            [smp.local_read, smp.remote_read, smp.local_write, smp.remote_write]
        )
        total = jnp.maximum(obs.sum(), _EPS)
        err = _residual_penalty((sim - obs) / total, huber_delta)
        itot = jnp.maximum(oins.sum() / el, _EPS)
        err += instruction_weight * _residual_penalty(
            (smp.instructions - oins / el) / itot, huber_delta
        )
        return err

    errs = jax.vmap(per_sample)(
        samples.wl_arrays,
        samples.placements,
        samples.local_read,
        samples.remote_read,
        samples.local_write,
        samples.remote_write,
        samples.instructions,
        samples.elapsed,
    )
    return errs.mean()


@partial(
    jax.jit,
    static_argnames=(
        "template", "groups", "steps", "lr", "instruction_weight",
        "thread_classes", "huber_delta",
    ),
)
def _fit_jit(
    template, groups, samples, params, steps, lr, instruction_weight,
    thread_classes, huber_delta=None,
):
    schedule = adamw.cosine_schedule(
        lr, warmup_steps=min(20, max(steps // 10, 1)), total_steps=steps
    )
    # adamw.update splices its (param, m, v) work tuples back apart with
    # is_leaf=isinstance(..., tuple), so hand it a dict view of the params
    # (a NamedTuple root would itself be spliced).
    state = adamw.init(params._asdict())

    def step_fn(carry, _):
        p, st = carry
        loss, grads = jax.value_and_grad(
            lambda q: _sweep_loss(
                template, groups, samples, CalibrationParams(**q),
                instruction_weight, thread_classes, huber_delta,
            )
        )(p)
        new_p, new_st = adamw.update(
            grads, st, p, lr=schedule(st.step), weight_decay=0.0
        )
        return (new_p, new_st), loss

    (final, _), history = jax.lax.scan(
        step_fn, (params._asdict(), state), None, length=steps
    )
    final_params = CalibrationParams(**final)
    # history[k] is the loss at the PRE-update params of step k; evaluate
    # the returned params once so the reported final loss matches the
    # machine actually handed back
    final_loss = _sweep_loss(
        template, groups, samples, final_params, instruction_weight,
        thread_classes, huber_delta,
    )
    return final_params, history, final_loss


def fitted_machine(
    template: MachineSpec,
    groups: LinkGroups,
    params: CalibrationParams,
    *,
    name: str | None = None,
) -> MachineSpec:
    """Materialize a concrete, validated ``MachineSpec`` from fitted
    parameters: per-link bandwidths through :func:`topology.from_fit`
    (routes held static), per-node local tuples, scalar attenuation."""
    link_bw = np.exp(np.asarray(params.log_link_bw, np.float64))
    full_link_bw = np.asarray(groups.unpack(link_bw))
    att = (
        float(jax.nn.sigmoid(params.att_raw))
        if template.topology.max_hops > 1
        else template.hop_attenuation
    )
    machine = template._replace(
        name=name or f"{template.name}-fit",
        local_read_bw=tuple(
            float(v) for v in np.exp(np.asarray(params.log_local_read, np.float64))
        ),
        local_write_bw=tuple(
            float(v) for v in np.exp(np.asarray(params.log_local_write, np.float64))
        ),
        hop_attenuation=att,
        topology=from_fit(
            template.topology, full_link_bw, name=f"{template.topology.name}-fit"
        ),
    )
    machine.validate()
    return machine


def fit_machine(
    template: MachineSpec,
    samples: CalibrationSamples,
    *,
    steps: int = 250,
    lr: float = 0.03,
    tie_equal_bw: bool = False,
    groups: LinkGroups | None = None,
    init: CalibrationParams | None = None,
    instruction_weight: float = 0.25,
    name: str | None = None,
    clean: bool = True,
    huber_delta: float | None = None,
) -> CalibrationResult:
    """Fit a machine's free parameters from a counter sweep.

    ``template`` supplies the structure (topology link list + routes, node
    counts, core rates, remote path bases); its bandwidth values are *not*
    consulted — seeding reads them off the samples.  ``tie_equal_bw``
    shares one parameter across links the template marks as the same class
    (see :func:`repro.core.numa.topology.link_groups`).

    ``clean=True`` (default) runs :func:`clean_samples` first, so
    corrupted/non-finite counter rows are rejected (and counted in
    ``result.diagnostics``) instead of silently poisoning the AdamW fit;
    ``huber_delta`` switches the loss from squared to Huber on the
    relative residuals — the outlier-robust setting for noisy partial
    production traces (a sweep-relative delta around 0.01–0.1 works; None
    keeps the exact squared loss and bit-identical legacy fits)."""
    if samples.n_nodes != template.n_nodes:
        raise ValueError(
            f"samples cover {samples.n_nodes} nodes; template has "
            f"{template.n_nodes}"
        )
    diagnostics = None
    if clean:
        samples, diagnostics = clean_samples(samples)
    if samples.n_samples == 0:
        raise ValueError("no calibration samples to fit from")
    if groups is None:
        groups = link_groups(template.topology, tie_equal_bw=tie_equal_bw)
    if init is None:
        init = seed_parameters(template, samples, groups)
    # samples.wl_arrays are concrete here (the jit boundary is below), so
    # the static class refinement of the whole sweep is readable — this is
    # what keeps every gradient step on the grouped solver.  The last leaf
    # is the stacked static_socket scalar, whose trailing axis is samples,
    # not threads — exclude it.
    thread_classes = class_starts_from_arrays(samples.wl_arrays[:-1])
    huber = None if huber_delta is None else float(huber_delta)
    seed_loss = float(
        _sweep_loss(
            template, groups, samples, init, instruction_weight,
            thread_classes, huber,
        )
    )
    params, history, final_loss = _fit_jit(
        template, groups, samples, init, int(steps), float(lr),
        float(instruction_weight), thread_classes, huber,
    )
    return CalibrationResult(
        machine=fitted_machine(template, groups, params, name=name),
        params=params,
        groups=groups,
        loss_history=np.asarray(history),
        seed_loss=seed_loss,
        final_loss=float(final_loss),
        diagnostics=diagnostics,
    )


# ---------------------------------------------------------------------------
# Round-trip drivers and diagnostics
# ---------------------------------------------------------------------------


def blind_template(
    machine: MachineSpec,
    *,
    link_bw: float = 20.0 * GB,
    local_read_bw: float = 40.0 * GB,
    local_write_bw: float = 20.0 * GB,
    hop_attenuation: float = 1.0,
) -> MachineSpec:
    """Strip a machine of everything the calibration is supposed to
    recover, keeping only structure: link list + routes, node geometry,
    core rates and the remote path bases.  The replacement values are
    deliberately uninformative — seeding overwrites them."""
    return machine._replace(
        name=f"{machine.name}-blind",
        local_read_bw=local_read_bw,
        local_write_bw=local_write_bw,
        hop_attenuation=hop_attenuation,
        topology=from_fit(
            machine.topology,
            np.full((machine.n_links,), link_bw),
            name=f"{machine.topology.name}-blind",
        ),
    )


def fit_from_simulated(
    machine: MachineSpec,
    template: MachineSpec | None = None,
    *,
    probes: Sequence[tuple[Workload, np.ndarray]] | None = None,
    noise_std: float = 0.0,
    key: Array | None = None,
    **fit_kwargs,
) -> CalibrationResult:
    """The synthetic round trip: sweep ``machine`` (ground truth) through
    the simulator, then fit blind from the samples alone.  ``template``
    defaults to :func:`blind_template` of the machine."""
    samples = collect_sweep(machine, probes, noise_std=noise_std, key=key)
    if template is None:
        template = blind_template(machine)
    return fit_machine(template, samples, **fit_kwargs)


def counter_errors_pct(
    machine: MachineSpec, samples: CalibrationSamples
) -> np.ndarray:
    """``(P,)`` per-sample relative total-counter error (%) of
    ``machine``'s predicted counters against the observed sweep — the
    forward model replayed over the samples' workloads/placements and
    compared bank by bank.  This is the quantity the live-recalibration
    swap guard gates on: a refit spec must not *regress* it."""
    P = samples.n_samples
    if P == 0:
        raise ValueError("cannot score a machine against zero samples")
    if samples.n_nodes != machine.n_nodes:
        raise ValueError(
            f"samples cover {samples.n_nodes} nodes; machine has "
            f"{machine.n_nodes}"
        )
    keys = jax.random.split(jax.random.PRNGKey(0), P)
    thread_classes = class_starts_from_arrays(samples.wl_arrays[:-1])
    lr, rr, lw, rw, _ = _collect_jit(
        machine, samples.wl_arrays, samples.placements, keys, 0.0, 0.0,
        thread_classes,
    )
    sim = np.concatenate(
        [np.asarray(x, np.float64).reshape(P, -1) for x in (lr, rr, lw, rw)],
        axis=1,
    )
    el = np.asarray(samples.elapsed, np.float64).reshape(P, 1)
    obs = np.concatenate(
        [
            np.asarray(x, np.float64).reshape(P, -1)
            for x in (
                samples.local_read, samples.remote_read,
                samples.local_write, samples.remote_write,
            )
        ],
        axis=1,
    ) / el
    denom = np.maximum(np.abs(obs).sum(axis=1), _EPS)
    return 100.0 * np.abs(sim - obs).sum(axis=1) / denom


def sweep_median_error_pct(
    machine: MachineSpec, samples: CalibrationSamples
) -> float:
    """Median of :func:`counter_errors_pct` — the single sweep-median
    number the recalibration swap guard compares old-vs-new specs on."""
    return float(np.median(counter_errors_pct(machine, samples)))


def link_relative_errors(
    fitted: MachineSpec, reference: MachineSpec
) -> np.ndarray:
    """``(n_links,)`` relative error of every fitted link bandwidth
    against a reference machine with the same link list."""
    if fitted.topology.link_ends != reference.topology.link_ends:
        raise ValueError("machines disagree on the link list")
    fit = np.asarray(fitted.topology.link_bw, np.float64)
    ref = np.asarray(reference.topology.link_bw, np.float64)
    return np.abs(fit - ref) / ref


def local_bw_relative_errors(
    fitted: MachineSpec, reference: MachineSpec
) -> dict[str, np.ndarray]:
    """Per-node relative errors of the fitted local bandwidths."""
    out = {}
    for direction in ("read", "write"):
        fit = np.asarray(fitted.node_local_bw(direction), np.float64)
        ref = np.asarray(reference.node_local_bw(direction), np.float64)
        out[direction] = np.abs(fit - ref) / ref
    return out
