"""Learned topology calibration — the inverse problem of the simulator.

The paper parameterizes its bandwidth model from counters sampled in two
carefully chosen runs; every ``MachineSpec`` in this repo was, until now,
hand-specified.  This module solves the *inverse* problem the ROADMAP's
"Learned topology fit" item asks for: given a set of ``(placement,
observed counters)`` samples — produced by the simulator for synthetic
ground truth, or by any ``bwsig/counters.py``-shaped counter trace from a
real machine — recover the free parameters of a machine:

* the per-link interconnect bandwidths (through the topology's
  symmetry/structure packing, :func:`repro.core.graphtop.link_groups` —
  the same packing + AdamW-in-log-space recipe
  :mod:`repro.core.meshsig.calibrate` runs for ICI links),
* ``hop_attenuation``, and
* the (per-node) ``local_read_bw`` / ``local_write_bw`` tuples,

holding the structural template fixed: node count, core rates, routing
tables and the remote path base capacities (the ratio-characterized
quantities of paper Figure 2, measurable from a single remote STREAM-style
run) all come from the template spec.

The fit is two-stage, mirroring the paper's philosophy of cheap seeding
plus model refinement:

1. **Counter seeding** (:func:`seed_parameters`) — closed-form lower
   bounds read straight off the samples.  Each bank's capacity is seeded
   by the largest total it was ever observed to move; per-pair flows are
   recovered from the bank-perspective remote counters by the same
   thread-count apportionment rule ``bwsig.fit`` uses (exact whenever one
   remote source is active, which the probe suite guarantees), charged
   along the static routes to seed every link; multi-hop pair flows
   lower-bound the attenuation.  On a saturating probe sweep these bounds
   are *tight* — the seed alone is often within a few percent.
2. **Projected gradient over the differentiable simulator**
   (:func:`fit_machine`) — all parameters are refined jointly by AdamW in
   log space (positivity by reparameterization, the smooth form of a
   projection) against the squared relative counter error of the full
   max-min-fair forward model, one jitted ``lax.scan`` of
   ``value_and_grad`` steps with the machine template static and only the
   capacity vector traced (``simulate(..., caps=...)``).

The probe suite (:func:`probe_suite`) is the sweep design that makes the
problem identifiable: per-node local probes saturate each bank in each
direction, per-ordered-pair static probes saturate thin links and the
hop-attenuated remote paths (these include the paper's 2-run
symmetric/asymmetric pair), and spread interleave/static-sink probes
saturate fat shared links that no single pair can fill (an SNC socket's
QPI port carries both directions of every cross-socket pair at once).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.bwsig.counters import CounterSample
from repro.core.bwsig.fit import _remote_source_weights
from repro.core.numa.machine import GB, MachineSpec
from repro.core.numa.simulator import (
    asymmetric_placement,
    class_starts_from_arrays,
    simulate,
    thread_class_starts,
)
from repro.core.numa.topology import LinkGroups, from_fit, link_groups
from repro.core.numa.workload import Workload, mixed_workload
from repro.optim import adamw

_EPS = 1e-9
# Finite stand-in for the unconstrained diagonal of the remote-path caps:
# its usage column is structurally zero, so any value never binds — but a
# finite one keeps the progressive-fill linearization coefficients finite
# under reverse-mode AD (inf residuals turn 0-cotangent products into NaN).
_UNUSED_CAP = 1e5


class CalibrationSamples(NamedTuple):
    """A counter sweep: ``P`` profiling runs of known workloads/placements.

    ``wl_arrays`` stacks every array field of the run's :class:`Workload`
    over the leading sample axis (the jit boundary cannot carry the name
    string); counters are bytes (or instructions) observed over
    ``elapsed`` seconds, bank-perspective, exactly the
    :class:`~repro.core.bwsig.counters.CounterSample` view real hardware
    exposes."""

    wl_arrays: tuple[Array, ...]  # leaves (P, n) / (P,)
    placements: Array  # (P, s) int32
    local_read: Array  # (P, s)
    remote_read: Array  # (P, s)
    local_write: Array  # (P, s)
    remote_write: Array  # (P, s)
    instructions: Array  # (P, s)
    elapsed: Array  # (P,)

    @property
    def n_samples(self) -> int:
        """Number of profiled placements in the sample set."""
        return int(self.placements.shape[0])

    @property
    def n_nodes(self) -> int:
        """NUMA node count of the machine the samples came from."""
        return int(self.placements.shape[1])


class CalibrationParams(NamedTuple):
    """Free parameters, unconstrained: capacities live in log space and
    the attenuation behind a sigmoid, so plain gradient steps stay inside
    the feasible set (the smooth projection)."""

    log_link_bw: Array  # (n_groups,)
    log_local_read: Array  # (s,)
    log_local_write: Array  # (s,)
    att_raw: Array  # () — hop_attenuation = sigmoid(att_raw)


class CalibrationResult(NamedTuple):
    """A fitted machine plus the optimizer's receipts (loss trajectory,
    seed-vs-final loss, and the raw parameters behind the spec)."""

    machine: MachineSpec  # the fitted spec (concrete, validated)
    params: CalibrationParams
    groups: LinkGroups
    loss_history: np.ndarray  # (steps,)
    seed_loss: float
    final_loss: float


# ---------------------------------------------------------------------------
# Sample construction
# ---------------------------------------------------------------------------


def _workload_arrays(wl: Workload) -> tuple[Array, ...]:
    return tuple(wl[1:])


def _stack_probe_workloads(wls: Sequence[Workload]) -> tuple[Array, ...]:
    n_threads = {w.n_threads for w in wls}
    if len(n_threads) != 1:
        raise ValueError(f"probe workloads must share a thread count, got {n_threads}")
    return tuple(
        jnp.stack(parts) for parts in zip(*(_workload_arrays(w) for w in wls))
    )


def samples_from_counters(
    workloads: Sequence[Workload],
    placements,
    counters: Sequence[CounterSample],
) -> CalibrationSamples:
    """Package an externally measured counter trace (one
    :class:`CounterSample` per known workload+placement run) for fitting —
    the path a real machine's PCM trace takes into the calibrator."""
    if not len(workloads) == len(counters):
        raise ValueError("one CounterSample per workload run required")
    placements = jnp.asarray(placements, jnp.int32)
    if placements.shape[0] != len(workloads):
        raise ValueError("one placement per workload run required")
    # each CounterSample records the placement of its own run — a silent
    # order mismatch against the placements argument would apportion the
    # remote counters by the wrong thread counts and corrupt the fit
    for k, c in enumerate(counters):
        recorded = np.asarray(c.n_per_socket)
        if not np.array_equal(recorded, np.asarray(placements[k])):
            raise ValueError(
                f"run {k}: placement {np.asarray(placements[k]).tolist()} "
                f"disagrees with the counter sample's recorded placement "
                f"{recorded.tolist()}"
            )
    return CalibrationSamples(
        wl_arrays=_stack_probe_workloads(workloads),
        placements=placements,
        local_read=jnp.stack([c.local_read for c in counters]),
        remote_read=jnp.stack([c.remote_read for c in counters]),
        local_write=jnp.stack([c.local_write for c in counters]),
        remote_write=jnp.stack([c.remote_write for c in counters]),
        instructions=jnp.stack([c.instructions for c in counters]),
        elapsed=jnp.stack([jnp.asarray(c.elapsed, jnp.float32) for c in counters]),
    )


# ---------------------------------------------------------------------------
# Probe sweep design
# ---------------------------------------------------------------------------


def _spread_placement(s: int, n_threads: int) -> np.ndarray:
    counts = np.full((s,), n_threads // s, np.int32)
    counts[: n_threads % s] += 1
    return counts


def probe_suite(
    template: MachineSpec,
    n_threads: int | None = None,
    *,
    read_bpi: float = 8.0,
    write_bpi: float = 4.0,
) -> list[tuple[Workload, np.ndarray]]:
    """The designed calibration sweep: ``(workload, placement)`` pairs
    whose union of saturation patterns identifies every free parameter.

    Only the template's *structure* (node count, cores per node, issue
    rates) shapes the design — bandwidths are what the sweep measures.
    All probes share one thread count so the whole sweep stacks into a
    single vmapped trace."""
    s, cap = template.n_nodes, template.cores_per_node
    if n_threads is None:
        n_threads = min(cap, 8)
    if not 0 < n_threads <= cap:
        raise ValueError(f"{n_threads} probe threads exceed {cap} cores/node")
    nt = n_threads
    probes: list[tuple[Workload, np.ndarray]] = []

    def one_node(i: int) -> np.ndarray:
        p = np.zeros((s,), np.int32)
        p[i] = nt
        return p

    # 1. per-node local probes, one direction at a time: saturate each
    #    bank's read and write capacity in isolation.
    for i in range(s):
        for tag, rb, wb in (("r", read_bpi, 0.0), ("w", 0.0, write_bpi)):
            probes.append(
                (
                    mixed_workload(
                        f"cal-local-{tag}{i}", nt,
                        read_mix=(0.0, 1.0, 0.0), read_bpi=rb, write_bpi=wb,
                    ),
                    one_node(i),
                )
            )

    # 2. per-ordered-pair static probes: all threads on node i streaming a
    #    Static allocation on node j — saturates the (i, j) remote path
    #    (hop-attenuated) or the thinnest link on route(i, j), whichever
    #    is tighter, one direction at a time.
    for i in range(s):
        for j in range(s):
            if i == j:
                continue
            for tag, rb, wb in (("r", read_bpi, 0.0), ("w", 0.0, write_bpi)):
                probes.append(
                    (
                        mixed_workload(
                            f"cal-pair-{tag}{i}-{j}", nt,
                            read_mix=(1.0, 0.0, 0.0), read_bpi=rb,
                            write_bpi=wb, static_socket=j,
                        ),
                        one_node(i),
                    )
                )

    # 3. spread interleave stress probes: every node pumping traffic to
    #    every bank at once — the only pattern that fills fat shared links
    #    (an SNC QPI port carries both directions of 2*k^2 node pairs).
    spread = _spread_placement(s, nt)
    for tag, rb, wb in (
        ("r", read_bpi, 0.0),
        ("w", 0.0, write_bpi),
        ("rw", read_bpi, write_bpi),
    ):
        probes.append(
            (
                mixed_workload(
                    f"cal-inter-{tag}", nt,
                    read_mix=(0.0, 0.0, 0.0), read_bpi=rb, write_bpi=wb,
                ),
                spread,
            )
        )

    # 4. static-sink stress probes: every *other* node's threads
    #    converging on one bank — saturates the sink's incident links with
    #    multi-source (routed) traffic no single pair can generate.  The
    #    sink node hosts no threads (its local traffic would win a
    #    max-min share of the bank and starve the link below saturation),
    #    and several write:read ratios are swept so that for some ratio
    #    the incident link binds before either bank-direction cap does
    #    (link binds iff (R+W)/C_link exceeds both R/C_read and W/C_write
    #    — a window in W/R that depends on the capacities under test).
    for j in range(s):
        if s < 2:
            break
        others = np.zeros((s,), np.int32)
        share = _spread_placement(s - 1, nt)
        others[np.arange(s) != j] = share
        for alpha in (0.25, 0.5, 1.0):
            probes.append(
                (
                    mixed_workload(
                        f"cal-sink-{j}-a{alpha}", nt,
                        read_mix=(1.0, 0.0, 0.0), read_bpi=read_bpi,
                        write_bpi=read_bpi * alpha, static_socket=j,
                    ),
                    others,
                )
            )

    # 5. the paper's 2-run pair (§5.1): one symmetric and one asymmetric
    #    placement of a generic mixed workload — the classic seeding runs,
    #    kept in-sweep so the fit and the paper's protocol share data.
    wl_2run = mixed_workload(
        "cal-2run", nt, read_mix=(0.3, 0.3, 0.2),
        read_bpi=read_bpi * 0.5, write_bpi=write_bpi * 0.5,
    )
    probes.append((wl_2run, spread))
    probes.append(
        (wl_2run, np.asarray(asymmetric_placement(template, nt), np.int32))
    )
    return probes


@partial(
    jax.jit,
    static_argnames=("machine", "noise_std", "background_bw", "thread_classes"),
)
def _collect_jit(
    machine, wl_arrays, placements, keys, noise_std, background_bw, thread_classes
):
    def one(arrays, placement, key):
        wl = Workload("calib", *arrays)
        res = simulate(
            machine, wl, placement,
            noise_std=noise_std, background_bw=background_bw, key=key,
            thread_classes=thread_classes,
        )
        smp = res.sample
        return (
            smp.local_read, smp.remote_read, smp.local_write,
            smp.remote_write, smp.instructions,
        )

    return jax.vmap(one)(wl_arrays, placements, keys)


def collect_sweep(
    machine: MachineSpec,
    probes: Sequence[tuple[Workload, np.ndarray]] | None = None,
    *,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    key: Array | None = None,
) -> CalibrationSamples:
    """Run a probe sweep through the simulator (the synthetic-ground-truth
    path) and package the observed counters for fitting.  ``probes``
    defaults to :func:`probe_suite` on the machine itself."""
    if probes is None:
        probes = probe_suite(machine)
    wls = [wl for wl, _ in probes]
    placements = jnp.asarray(np.stack([p for _, p in probes]), jnp.int32)
    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, len(wls))
    wl_arrays = _stack_probe_workloads(wls)
    lr, rr, lw, rw, ins = _collect_jit(
        machine, wl_arrays, placements, keys,
        float(noise_std), float(background_bw),
        thread_class_starts(wls),
    )
    return CalibrationSamples(
        wl_arrays=wl_arrays,
        placements=placements,
        local_read=lr, remote_read=rr, local_write=lw, remote_write=rw,
        instructions=ins,
        elapsed=jnp.ones((len(wls),), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Stage 1: counter seeding
# ---------------------------------------------------------------------------


def _pair_flows(samples: CalibrationSamples, counter: Array) -> Array:
    """``(P, s, s)`` estimated source->bank flows from a bank-perspective
    counter, apportioning each bank's remote traffic to the other nodes in
    proportion to their thread counts — ``bwsig.fit``'s rule, exact when a
    single remote source is active (every pair probe; the paper's s=2)."""
    w = jax.vmap(_remote_source_weights)(samples.placements)  # (P, bank j, src i)
    return jnp.swapaxes(w * counter[:, :, None], 1, 2)  # (P, i, j)


def seed_parameters(
    template: MachineSpec,
    samples: CalibrationSamples,
    groups: LinkGroups | None = None,
    *,
    floor_frac: float = 0.02,
) -> CalibrationParams:
    """Closed-form seeds: every observed rate is a lower bound on the
    capacity it crossed, and the probe suite makes the interesting bounds
    tight.  Never exercised parameters are floored at ``floor_frac`` of
    the largest seed in their family so log-space stays finite."""
    if groups is None:
        groups = link_groups(template.topology)
    s = template.n_nodes
    el = samples.elapsed[:, None]
    lr = samples.local_read / el
    rr = samples.remote_read / el
    lw = samples.local_write / el
    rw = samples.remote_write / el

    def floored(x: Array) -> Array:
        return jnp.maximum(x, jnp.maximum(floor_frac * x.max(), 1.0))

    bank_r = floored((lr + rr).max(0))
    bank_w = floored((lw + rw).max(0))

    pair_r = _pair_flows(samples, rr)
    pair_w = _pair_flows(samples, rw)
    incidence = jnp.asarray(template.topology.route_incidence())  # (s*s, L)
    charge = (pair_r + pair_w).reshape(samples.placements.shape[0], s * s) @ incidence
    link_seed = np.asarray(floored(charge.max(0)))

    # attenuation: a multi-hop pair's flow obeys flow <= base * att**(h-1),
    # so every (flow/base)**(1/(h-1)) lower-bounds att; take the best bound
    # over pairs and directions.
    hops = np.asarray(template.topology.hop_matrix(), np.float64)
    att_seed = 0.95
    if hops.max() > 1:
        ests = []
        for base, flows in (
            (template.remote_read_bw, np.asarray(pair_r.max(0), np.float64)),
            (template.remote_write_bw, np.asarray(pair_w.max(0), np.float64)),
        ):
            multi = hops > 1
            ratio = np.clip(flows / max(base, _EPS), 1e-6, 1.0)
            ests.append((ratio ** (1.0 / np.maximum(hops - 1.0, 1.0)))[multi])
        att_seed = float(np.clip(np.concatenate(ests).max(), 0.3, 0.995))

    return CalibrationParams(
        log_link_bw=jnp.log(jnp.asarray(groups.pack(link_seed), jnp.float32)),
        log_local_read=jnp.log(bank_r.astype(jnp.float32)),
        log_local_write=jnp.log(bank_w.astype(jnp.float32)),
        att_raw=jnp.asarray(np.log(att_seed / (1.0 - att_seed)), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Stage 2: projected gradient over the differentiable forward model
# ---------------------------------------------------------------------------


def _caps_from(
    template: MachineSpec, groups: LinkGroups, params: CalibrationParams
) -> Array:
    """Assemble the traced capacity vector (simulator slab order) from the
    free parameters; routing, hop counts and the remote path bases stay
    static template structure."""
    s = template.n_nodes
    link_bw = groups.unpack(jnp.exp(params.log_link_bw))
    bank_r = jnp.exp(params.log_local_read)
    bank_w = jnp.exp(params.log_local_write)
    hops = jnp.asarray(template.topology.hop_matrix(), jnp.float32)
    if template.topology.max_hops > 1:
        att = jax.nn.sigmoid(params.att_raw)
    else:  # single-hop: attenuation is structurally unobservable
        att = jnp.asarray(1.0, jnp.float32)
    extra = jnp.maximum(hops - 1.0, 0.0)
    rr = jnp.where(hops == 0, _UNUSED_CAP, template.remote_read_bw * att**extra)
    ww = jnp.where(hops == 0, _UNUSED_CAP, template.remote_write_bw * att**extra)
    return jnp.concatenate(
        [bank_r, bank_w, rr.reshape(s * s), ww.reshape(s * s), link_bw]
    )


def _sweep_loss(
    template: MachineSpec,
    groups: LinkGroups,
    samples: CalibrationSamples,
    params: CalibrationParams,
    instruction_weight: float,
    thread_classes: tuple[int, ...],
) -> Array:
    caps = _caps_from(template, groups, params)

    def per_sample(arrays, placement, olr, orr, olw, orw, oins, el):
        wl = Workload("calib", *arrays)
        res = simulate(
            template, wl, placement, caps=caps, thread_classes=thread_classes
        )
        smp = res.sample
        obs = jnp.concatenate([olr, orr, olw, orw]) / el
        sim = jnp.concatenate(
            [smp.local_read, smp.remote_read, smp.local_write, smp.remote_write]
        )
        total = jnp.maximum(obs.sum(), _EPS)
        err = (((sim - obs) / total) ** 2).sum()
        itot = jnp.maximum(oins.sum() / el, _EPS)
        err += instruction_weight * (
            ((smp.instructions - oins / el) / itot) ** 2
        ).sum()
        return err

    errs = jax.vmap(per_sample)(
        samples.wl_arrays,
        samples.placements,
        samples.local_read,
        samples.remote_read,
        samples.local_write,
        samples.remote_write,
        samples.instructions,
        samples.elapsed,
    )
    return errs.mean()


@partial(
    jax.jit,
    static_argnames=(
        "template", "groups", "steps", "lr", "instruction_weight",
        "thread_classes",
    ),
)
def _fit_jit(
    template, groups, samples, params, steps, lr, instruction_weight,
    thread_classes,
):
    schedule = adamw.cosine_schedule(
        lr, warmup_steps=min(20, max(steps // 10, 1)), total_steps=steps
    )
    # adamw.update splices its (param, m, v) work tuples back apart with
    # is_leaf=isinstance(..., tuple), so hand it a dict view of the params
    # (a NamedTuple root would itself be spliced).
    state = adamw.init(params._asdict())

    def step_fn(carry, _):
        p, st = carry
        loss, grads = jax.value_and_grad(
            lambda q: _sweep_loss(
                template, groups, samples, CalibrationParams(**q),
                instruction_weight, thread_classes,
            )
        )(p)
        new_p, new_st = adamw.update(
            grads, st, p, lr=schedule(st.step), weight_decay=0.0
        )
        return (new_p, new_st), loss

    (final, _), history = jax.lax.scan(
        step_fn, (params._asdict(), state), None, length=steps
    )
    final_params = CalibrationParams(**final)
    # history[k] is the loss at the PRE-update params of step k; evaluate
    # the returned params once so the reported final loss matches the
    # machine actually handed back
    final_loss = _sweep_loss(
        template, groups, samples, final_params, instruction_weight,
        thread_classes,
    )
    return final_params, history, final_loss


def fitted_machine(
    template: MachineSpec,
    groups: LinkGroups,
    params: CalibrationParams,
    *,
    name: str | None = None,
) -> MachineSpec:
    """Materialize a concrete, validated ``MachineSpec`` from fitted
    parameters: per-link bandwidths through :func:`topology.from_fit`
    (routes held static), per-node local tuples, scalar attenuation."""
    link_bw = np.exp(np.asarray(params.log_link_bw, np.float64))
    full_link_bw = np.asarray(groups.unpack(link_bw))
    att = (
        float(jax.nn.sigmoid(params.att_raw))
        if template.topology.max_hops > 1
        else template.hop_attenuation
    )
    machine = template._replace(
        name=name or f"{template.name}-fit",
        local_read_bw=tuple(
            float(v) for v in np.exp(np.asarray(params.log_local_read, np.float64))
        ),
        local_write_bw=tuple(
            float(v) for v in np.exp(np.asarray(params.log_local_write, np.float64))
        ),
        hop_attenuation=att,
        topology=from_fit(
            template.topology, full_link_bw, name=f"{template.topology.name}-fit"
        ),
    )
    machine.validate()
    return machine


def fit_machine(
    template: MachineSpec,
    samples: CalibrationSamples,
    *,
    steps: int = 250,
    lr: float = 0.03,
    tie_equal_bw: bool = False,
    groups: LinkGroups | None = None,
    init: CalibrationParams | None = None,
    instruction_weight: float = 0.25,
    name: str | None = None,
) -> CalibrationResult:
    """Fit a machine's free parameters from a counter sweep.

    ``template`` supplies the structure (topology link list + routes, node
    counts, core rates, remote path bases); its bandwidth values are *not*
    consulted — seeding reads them off the samples.  ``tie_equal_bw``
    shares one parameter across links the template marks as the same class
    (see :func:`repro.core.numa.topology.link_groups`)."""
    if samples.n_nodes != template.n_nodes:
        raise ValueError(
            f"samples cover {samples.n_nodes} nodes; template has "
            f"{template.n_nodes}"
        )
    if groups is None:
        groups = link_groups(template.topology, tie_equal_bw=tie_equal_bw)
    if init is None:
        init = seed_parameters(template, samples, groups)
    # samples.wl_arrays are concrete here (the jit boundary is below), so
    # the static class refinement of the whole sweep is readable — this is
    # what keeps every gradient step on the grouped solver.  The last leaf
    # is the stacked static_socket scalar, whose trailing axis is samples,
    # not threads — exclude it.
    thread_classes = class_starts_from_arrays(samples.wl_arrays[:-1])
    seed_loss = float(
        _sweep_loss(
            template, groups, samples, init, instruction_weight, thread_classes
        )
    )
    params, history, final_loss = _fit_jit(
        template, groups, samples, init, int(steps), float(lr),
        float(instruction_weight), thread_classes,
    )
    return CalibrationResult(
        machine=fitted_machine(template, groups, params, name=name),
        params=params,
        groups=groups,
        loss_history=np.asarray(history),
        seed_loss=seed_loss,
        final_loss=float(final_loss),
    )


# ---------------------------------------------------------------------------
# Round-trip drivers and diagnostics
# ---------------------------------------------------------------------------


def blind_template(
    machine: MachineSpec,
    *,
    link_bw: float = 20.0 * GB,
    local_read_bw: float = 40.0 * GB,
    local_write_bw: float = 20.0 * GB,
    hop_attenuation: float = 1.0,
) -> MachineSpec:
    """Strip a machine of everything the calibration is supposed to
    recover, keeping only structure: link list + routes, node geometry,
    core rates and the remote path bases.  The replacement values are
    deliberately uninformative — seeding overwrites them."""
    return machine._replace(
        name=f"{machine.name}-blind",
        local_read_bw=local_read_bw,
        local_write_bw=local_write_bw,
        hop_attenuation=hop_attenuation,
        topology=from_fit(
            machine.topology,
            np.full((machine.n_links,), link_bw),
            name=f"{machine.topology.name}-blind",
        ),
    )


def fit_from_simulated(
    machine: MachineSpec,
    template: MachineSpec | None = None,
    *,
    probes: Sequence[tuple[Workload, np.ndarray]] | None = None,
    noise_std: float = 0.0,
    key: Array | None = None,
    **fit_kwargs,
) -> CalibrationResult:
    """The synthetic round trip: sweep ``machine`` (ground truth) through
    the simulator, then fit blind from the samples alone.  ``template``
    defaults to :func:`blind_template` of the machine."""
    samples = collect_sweep(machine, probes, noise_std=noise_std, key=key)
    if template is None:
        template = blind_template(machine)
    return fit_machine(template, samples, **fit_kwargs)


def link_relative_errors(
    fitted: MachineSpec, reference: MachineSpec
) -> np.ndarray:
    """``(n_links,)`` relative error of every fitted link bandwidth
    against a reference machine with the same link list."""
    if fitted.topology.link_ends != reference.topology.link_ends:
        raise ValueError("machines disagree on the link list")
    fit = np.asarray(fitted.topology.link_bw, np.float64)
    ref = np.asarray(reference.topology.link_bw, np.float64)
    return np.abs(fit - ref) / ref


def local_bw_relative_errors(
    fitted: MachineSpec, reference: MachineSpec
) -> dict[str, np.ndarray]:
    """Per-node relative errors of the fitted local bandwidths."""
    out = {}
    for direction in ("read", "write"):
        fit = np.asarray(fitted.node_local_bw(direction), np.float64)
        ref = np.asarray(reference.node_local_bw(direction), np.float64)
        out[direction] = np.abs(fit - ref) / ref
    return out
