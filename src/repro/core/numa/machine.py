"""Machine descriptions for the NUMA simulator.

Paper §2 / Figure 2: the evaluation machines are dual-socket Intel Haswell
systems, Xeon E5-2630 v3 (8 cores/socket) and Xeon E5-2699 v3 (18
cores/socket).  "Both systems have similar read and write bandwidths to
local memory, but drastically different performance when accessing remote
memory where the 8 core processors only have 0.16 of the bandwidth for
remote reads and 0.23 of the bandwidth for remote writes relative to local
reads and writes.  On the 18 core processors ... 0.59 of the bandwidth for
remote reads and 0.83 of the bandwidth for remote writes."

Absolute local bandwidths are not printed in the paper (they are in a
figure); the values below use public STREAM-class measurements for
quad-channel DDR4-1866/2133 Haswell parts and apply the paper's exact
remote/local ratios.  The *model* never sees these constants — they only
shape the simulated ground truth.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import Array

GB = 1e9


class MachineSpec(NamedTuple):
    """A multi-socket NUMA machine.

    Bandwidth capacities are bytes/s.  ``remote_*_bw`` caps each ordered
    socket pair's path (remote controller + interconnect direction);
    ``qpi_bw`` caps the total traffic crossing each unordered socket pair.
    ``core_rate`` is instructions/s per thread at full speed.
    """

    name: str
    sockets: int
    cores_per_socket: int
    local_read_bw: float
    local_write_bw: float
    remote_read_bw: float
    remote_write_bw: float
    qpi_bw: float
    core_rate: float

    @property
    def total_cores(self) -> int:
        return self.sockets * self.cores_per_socket

    def bank_read_caps(self) -> Array:
        return jnp.full((self.sockets,), self.local_read_bw)

    def bank_write_caps(self) -> Array:
        return jnp.full((self.sockets,), self.local_write_bw)


# Xeon E5-2630 v3: 8 cores, 2.4 GHz, DDR4-1866.  The cheap machine whose
# remote links are easily saturated (paper Figure 1: up to 3x slowdown).
E5_2630_V3 = MachineSpec(
    name="E5-2630v3-8c",
    sockets=2,
    cores_per_socket=8,
    local_read_bw=52.0 * GB,
    local_write_bw=28.0 * GB,
    remote_read_bw=0.16 * 52.0 * GB,  # paper ratio 0.16
    remote_write_bw=0.23 * 28.0 * GB,  # paper ratio 0.23
    qpi_bw=16.0 * GB,
    core_rate=2.4e9,
)

# Xeon E5-2699 v3: 18 cores, 2.3 GHz, DDR4-2133.  The expensive machine that
# is "far more forgiving of thread and memory placement".
E5_2699_V3 = MachineSpec(
    name="E5-2699v3-18c",
    sockets=2,
    cores_per_socket=18,
    local_read_bw=62.0 * GB,
    local_write_bw=34.0 * GB,
    remote_read_bw=0.59 * 62.0 * GB,  # paper ratio 0.59
    remote_write_bw=0.83 * 34.0 * GB,  # paper ratio 0.83
    qpi_bw=51.2 * GB,
    core_rate=2.3e9,
)

# ---------------------------------------------------------------------------
# Beyond-paper presets: 4- and 8-socket machines.  The paper's method is
# derived for 2 sockets; these presets drive the generalized (s >= 2)
# placement-sweep engine where NUMA effects are most severe.  The simulator
# models every remote path with one capacity (no hop-count asymmetry), which
# matches a fully QPI-connected quad-socket Haswell-EX; the glued 8-socket
# topology is approximated the same way.
# ---------------------------------------------------------------------------

# Xeon E7-4830 v3: quad-socket Haswell-EX, 12 cores/socket, DDR4 behind the
# memory buffer (lower local bandwidth than the 2-socket parts), fully
# connected QPI.
E7_4830_V3 = MachineSpec(
    name="E7-4830v3-4s12c",
    sockets=4,
    cores_per_socket=12,
    local_read_bw=46.0 * GB,
    local_write_bw=25.0 * GB,
    remote_read_bw=0.30 * 46.0 * GB,
    remote_write_bw=0.40 * 25.0 * GB,
    qpi_bw=19.2 * GB,
    core_rate=2.1e9,
)

# Xeon E7-8860 v3: 8-socket Haswell-EX, 16 cores/socket.  Socket pairs
# beyond the directly-linked ones route through node controllers; the
# single per-pair capacity below is the effective per-pair share.
E7_8860_V3 = MachineSpec(
    name="E7-8860v3-8s16c",
    sockets=8,
    cores_per_socket=16,
    local_read_bw=50.0 * GB,
    local_write_bw=27.0 * GB,
    remote_read_bw=0.35 * 50.0 * GB,
    remote_write_bw=0.45 * 27.0 * GB,
    qpi_bw=12.8 * GB,
    core_rate=2.2e9,
)

MACHINES: dict[str, MachineSpec] = {
    E5_2630_V3.name: E5_2630_V3,
    E5_2699_V3.name: E5_2699_V3,
    E7_4830_V3.name: E7_4830_V3,
    E7_8860_V3.name: E7_8860_V3,
}


def make_machine(
    name: str = "generic",
    sockets: int = 2,
    cores_per_socket: int = 8,
    local_read_bw: float = 50.0 * GB,
    local_write_bw: float = 28.0 * GB,
    remote_read_ratio: float = 0.5,
    remote_write_ratio: float = 0.5,
    qpi_bw: float = 32.0 * GB,
    core_rate: float = 2.4e9,
) -> MachineSpec:
    """Build a custom machine from local bandwidths and remote/local ratios
    (the way the paper characterizes its systems)."""
    return MachineSpec(
        name=name,
        sockets=sockets,
        cores_per_socket=cores_per_socket,
        local_read_bw=local_read_bw,
        local_write_bw=local_write_bw,
        remote_read_bw=remote_read_ratio * local_read_bw,
        remote_write_bw=remote_write_ratio * local_write_bw,
        qpi_bw=qpi_bw,
        core_rate=core_rate,
    )
