"""Machine descriptions for the NUMA simulator.

Paper §2 / Figure 2: the evaluation machines are dual-socket Intel Haswell
systems, Xeon E5-2630 v3 (8 cores/socket) and Xeon E5-2699 v3 (18
cores/socket).  "Both systems have similar read and write bandwidths to
local memory, but drastically different performance when accessing remote
memory where the 8 core processors only have 0.16 of the bandwidth for
remote reads and 0.23 of the bandwidth for remote writes relative to local
reads and writes.  On the 18 core processors ... 0.59 of the bandwidth for
remote reads and 0.83 of the bandwidth for remote writes."

Absolute local bandwidths are not printed in the paper (they are in a
figure); the values below use public STREAM-class measurements for
quad-channel DDR4-1866/2133 Haswell parts and apply the paper's exact
remote/local ratios.  The *model* never sees these constants — they only
shape the simulated ground truth.

Beyond the paper, every machine carries a :class:`Topology` — a per-link
interconnect bandwidth matrix with static shortest-path routing (the
shared :mod:`repro.core.graphtop` engine under its NUMA name) — instead
of the single scalar ``qpi_bw`` the 2-socket formulation used.  Remote
path capacities become per-ordered-pair, attenuated per extra hop
(``hop_attenuation``), and interconnect capacity is enforced per *link*
with multi-hop traffic charging every link it crosses.  For a
fully-connected topology (every pair 1 hop) this degenerates exactly to
the old scalar model.  All fields stay hashable python scalars / nested
tuples, so a ``MachineSpec`` remains a valid ``jax.jit`` static argument
and cache-key component; array-valued topology input is canonicalized at
construction and :meth:`MachineSpec.fingerprint` digests every field for
content-addressed caches.

The unit of placement is a NUMA **node**, not a socket.  A socket
contributes ``nodes_per_socket`` nodes (sub-NUMA clustering / Cluster-on-
Die splits a socket's memory controllers into 2+ domains joined by
intra-socket links — see :func:`repro.core.numa.topology.snc`), so a
machine exposes ``n_nodes = sockets * nodes_per_socket`` memory banks,
placement slots of ``cores_per_node`` cores each, and a topology whose
node count must equal ``n_nodes``.  ``core_rate`` may be a per-node tuple
to model big.LITTLE-style parts or thermally throttled sockets; all
bandwidth fields are **per node** (an SNC domain owns half its socket's
channels, so its per-node ``local_*_bw`` is roughly half the socket's).
Homogeneous machines with ``nodes_per_socket=1`` reproduce the per-socket
model bit for bit.
"""

from __future__ import annotations

import hashlib
from functools import lru_cache
from typing import NamedTuple

import jax.numpy as jnp
from jax import Array

from repro.core.numa.topology import Topology, fully_connected, glued_8s, snc

GB = 1e9


class MachineSpec(NamedTuple):
    """A multi-socket NUMA machine, modeled as a graph of NUMA nodes.

    Bandwidth capacities are bytes/s and **per node** (for
    ``nodes_per_socket=1`` that is per socket, the paper's granularity).
    ``remote_read_bw``/``remote_write_bw`` cap each *one-hop* ordered node
    pair's path (remote controller + interconnect direction); pairs whose
    route is longer are attenuated by ``hop_attenuation`` per extra hop
    (:meth:`remote_read_caps`).  The interconnect itself is ``topology``:
    per-link capacities plus static routes over ``n_nodes`` nodes, with
    every link on a route charged the full flow.  ``core_rate`` is
    instructions/s per thread at full speed — either one scalar for every
    node or a per-node tuple (heterogeneous cores, throttled sockets).
    ``local_read_bw``/``local_write_bw`` follow the same convention: one
    scalar shared by every memory bank, or a per-node tuple (mixed DIMM
    populations, HBM+DDR tiered nodes); scalar specs stay bit-for-bit
    identical to the pre-tuple model via :meth:`node_local_bw`.  All
    spellings stay hashable so the spec remains a jit static argument.
    """

    name: str
    sockets: int
    cores_per_socket: int
    local_read_bw: float | tuple[float, ...]
    local_write_bw: float | tuple[float, ...]
    remote_read_bw: float
    remote_write_bw: float
    core_rate: float | tuple[float, ...]
    topology: Topology
    hop_attenuation: float = 1.0
    nodes_per_socket: int = 1

    @property
    def total_cores(self) -> int:
        """Cores machine-wide — the hard cap on thread count."""
        return self.sockets * self.cores_per_socket

    @property
    def n_nodes(self) -> int:
        """NUMA nodes — the unit of placement, memory banks and counters."""
        return self.sockets * self.nodes_per_socket

    @property
    def cores_per_node(self) -> int:
        """Placement slots per NUMA node (SNC splits a socket's cores)."""
        return self.cores_per_socket // self.nodes_per_socket

    @property
    def n_links(self) -> int:
        """Physical interconnect links in the routed topology."""
        return self.topology.n_links

    def node_rates(self) -> Array:
        """``(n_nodes,)`` per-node core issue rate (instructions/s).  A
        scalar ``core_rate`` broadcasts to every node."""
        if isinstance(self.core_rate, tuple):
            return jnp.asarray(self.core_rate, jnp.float32)
        return jnp.full((self.n_nodes,), self.core_rate, jnp.float32)

    def validate(self) -> None:
        """Raise ``ValueError`` on an inconsistent spec (SNC split that
        does not divide the cores, per-node tuples of the wrong length,
        topology/node-count mismatch)."""
        if self.nodes_per_socket < 1:
            raise ValueError("nodes_per_socket must be >= 1")
        if self.cores_per_socket % self.nodes_per_socket:
            raise ValueError(
                f"{self.cores_per_socket} cores/socket do not split evenly "
                f"over {self.nodes_per_socket} nodes/socket"
            )
        if self.topology.n_nodes != self.n_nodes:
            raise ValueError(
                f"topology has {self.topology.n_nodes} nodes; machine has "
                f"{self.sockets} sockets x {self.nodes_per_socket} nodes = "
                f"{self.n_nodes}"
            )
        if isinstance(self.core_rate, tuple):
            if len(self.core_rate) != self.n_nodes:
                raise ValueError(
                    f"core_rate has {len(self.core_rate)} entries for "
                    f"{self.n_nodes} nodes"
                )
            if min(self.core_rate) <= 0:
                raise ValueError("core_rate entries must be positive")
        elif self.core_rate <= 0:
            raise ValueError("core_rate must be positive")
        for field in ("local_read_bw", "local_write_bw"):
            bw = getattr(self, field)
            if isinstance(bw, tuple):
                if len(bw) != self.n_nodes:
                    raise ValueError(
                        f"{field} has {len(bw)} entries for {self.n_nodes} nodes"
                    )
                if min(bw) <= 0:
                    raise ValueError(f"{field} entries must be positive")
            elif bw <= 0:
                raise ValueError(f"{field} must be positive")

    def node_local_bw(self, direction: str) -> Array:
        """``(n_nodes,)`` per-node local bank capacity for one direction.
        A scalar field broadcasts to every node through the exact
        pre-tuple code path (bit-for-bit); a tuple gives each bank its own
        capacity (mixed DIMM populations, HBM+DDR tiers).  Every consumer
        of ``local_*_bw`` that wants a per-node view must go through this
        helper instead of assuming the scalar spelling."""
        if direction == "read":
            bw = self.local_read_bw
        elif direction == "write":
            bw = self.local_write_bw
        else:
            raise ValueError(f"unknown direction {direction!r}")
        if isinstance(bw, tuple):
            return jnp.asarray(bw, jnp.float32)
        return jnp.full((self.n_nodes,), bw)

    def bank_read_caps(self) -> Array:
        """``(n_nodes,)`` per-bank read capacity (alias of
        ``node_local_bw("read")`` in resource-slab vocabulary)."""
        return self.node_local_bw("read")

    def bank_write_caps(self) -> Array:
        """``(n_nodes,)`` per-bank write capacity."""
        return self.node_local_bw("write")

    def link_caps(self) -> Array:
        """Per-link interconnect capacities, ``(n_links,)``."""
        return jnp.asarray(self.topology.link_bw, jnp.float32)

    def _remote_caps(self, base: float) -> Array:
        hops = jnp.asarray(self.topology.hop_matrix(), jnp.float32)
        att = jnp.asarray(self.hop_attenuation, jnp.float32) ** jnp.maximum(
            hops - 1.0, 0.0
        )
        return jnp.where(hops == 0, jnp.inf, base * att)

    def remote_read_caps(self) -> Array:
        """``(n_nodes, n_nodes)`` per-ordered-node-pair remote read capacity:
        ``inf`` on the diagonal, the 1-hop cap attenuated per extra routed
        hop elsewhere."""
        return self._remote_caps(self.remote_read_bw)

    def remote_write_caps(self) -> Array:
        """``(n_nodes, n_nodes)`` remote write twin of
        :meth:`remote_read_caps`."""
        return self._remote_caps(self.remote_write_bw)

    def fingerprint(self) -> str:
        """Content digest over every field (topology included) — the
        machine component of signature-cache keys, stable across processes
        and robust to array-valued topology input (canonicalized to
        tuples at construction).  Memoized on the spec itself (specs are
        immutable): the repr walk over the topology tables is ms-scale on
        8-socket machines and signature-cache keys are built on every
        ``evaluate_batch`` call."""
        return _fingerprint(self)


@lru_cache(maxsize=256)
def _fingerprint(machine: MachineSpec) -> str:
    digest = hashlib.blake2b(digest_size=16)
    for part in (
        machine.name,
        machine.sockets,
        machine.cores_per_socket,
        machine.nodes_per_socket,
        machine.local_read_bw,
        machine.local_write_bw,
        machine.remote_read_bw,
        machine.remote_write_bw,
        machine.core_rate,
        machine.hop_attenuation,
        machine.topology,
    ):
        digest.update(repr(part).encode())
        digest.update(b"\x1f")  # field separator: '325.0' != '32','5.0'
    return digest.hexdigest()


def canonical_bank_assignment(
    machine: MachineSpec, bank_assignment
) -> tuple[int, ...] | None:
    """Validate and canonicalize a page/bank placement.

    ``bank_assignment[k] = j`` declares that the *Local*-class buffers of
    threads placed on node ``k`` are backed by node ``j``'s DIMMs (their
    pages were first-touched there, or migrated there).  ``None`` and the
    identity mapping both mean today's node-local behavior and normalize
    to ``None`` so every default code path — and every jit/signature cache
    key — stays bit-for-bit identical to the assignment-free model.

    Only the Local class has a free home: Static already carries its own
    placement knob (``static_socket``), and the Per-thread / Interleaved
    classes are defined by their allocation policy, not by a home node.
    """
    if bank_assignment is None:
        return None
    s = machine.n_nodes
    ba = tuple(int(b) for b in bank_assignment)
    if len(ba) != s:
        raise ValueError(
            f"bank_assignment {ba} has {len(ba)} entries for {s} nodes"
        )
    if any(not 0 <= b < s for b in ba):
        raise ValueError(f"bank_assignment {ba} names a node outside 0..{s - 1}")
    if ba == tuple(range(s)):
        return None  # identity == node-local default
    return ba


# Xeon E5-2630 v3: 8 cores, 2.4 GHz, DDR4-1866.  The cheap machine whose
# remote links are easily saturated (paper Figure 1: up to 3x slowdown).
E5_2630_V3 = MachineSpec(
    name="E5-2630v3-8c",
    sockets=2,
    cores_per_socket=8,
    local_read_bw=52.0 * GB,
    local_write_bw=28.0 * GB,
    remote_read_bw=0.16 * 52.0 * GB,  # paper ratio 0.16
    remote_write_bw=0.23 * 28.0 * GB,  # paper ratio 0.23
    core_rate=(2.4e9, 2.4e9),
    topology=fully_connected(2, 16.0 * GB),  # one QPI link
)

# Xeon E5-2699 v3: 18 cores, 2.3 GHz, DDR4-2133.  The expensive machine that
# is "far more forgiving of thread and memory placement".
E5_2699_V3 = MachineSpec(
    name="E5-2699v3-18c",
    sockets=2,
    cores_per_socket=18,
    local_read_bw=62.0 * GB,
    local_write_bw=34.0 * GB,
    remote_read_bw=0.59 * 62.0 * GB,  # paper ratio 0.59
    remote_write_bw=0.83 * 34.0 * GB,  # paper ratio 0.83
    core_rate=(2.3e9, 2.3e9),
    topology=fully_connected(2, 51.2 * GB),
)

# ---------------------------------------------------------------------------
# Beyond-paper presets: 4- and 8-socket machines.  The paper's method is
# derived for 2 sockets; these presets drive the generalized (s >= 2)
# placement-sweep engine where NUMA effects are most severe.
# ---------------------------------------------------------------------------

# Xeon E7-4830 v3: quad-socket Haswell-EX, 12 cores/socket, DDR4 behind the
# memory buffer (lower local bandwidth than the 2-socket parts), fully
# connected QPI — every remote pair is one hop.
E7_4830_V3 = MachineSpec(
    name="E7-4830v3-4s12c",
    sockets=4,
    cores_per_socket=12,
    local_read_bw=46.0 * GB,
    local_write_bw=25.0 * GB,
    remote_read_bw=0.30 * 46.0 * GB,
    remote_write_bw=0.40 * 25.0 * GB,
    core_rate=(2.1e9,) * 4,
    topology=fully_connected(4, 19.2 * GB),
)

# Xeon E7-8860 v3: 8-socket Haswell-EX built from two fully QPI-meshed
# quads glued by node controllers.  Twin sockets (i, i+4) are one
# controller hop apart; every other cross-quad pair routes over 2 hops
# (QPI + controller), charging both links and paying the per-hop
# attenuation on its remote-path capacity.
E7_8860_V3 = MachineSpec(
    name="E7-8860v3-8s16c",
    sockets=8,
    cores_per_socket=16,
    local_read_bw=50.0 * GB,
    local_write_bw=27.0 * GB,
    remote_read_bw=0.35 * 50.0 * GB,
    remote_write_bw=0.45 * 27.0 * GB,
    core_rate=(2.2e9,) * 8,
    topology=glued_8s(qpi_bw=12.8 * GB, nc_bw=9.6 * GB),
    hop_attenuation=0.8,
)

# ---------------------------------------------------------------------------
# Node-graph presets: sub-NUMA clustering and heterogeneous core rates.
# ---------------------------------------------------------------------------

# The 18-core machine in SNC-2 / Cluster-on-Die mode: each socket splits
# into two 9-core NUMA domains, each owning half the socket's memory
# channels (half the local bandwidth) behind a fast in-die link; the two
# domains share the socket's single QPI port, so a non-endpoint domain's
# cross-socket traffic routes over 2-3 hops through the shared link.
E5_2699_V3_SNC2 = MachineSpec(
    name="E5-2699v3-18c-snc2",
    sockets=2,
    cores_per_socket=18,
    nodes_per_socket=2,
    local_read_bw=31.0 * GB,
    local_write_bw=17.0 * GB,
    remote_read_bw=0.59 * 31.0 * GB,  # paper ratio against the per-node bank
    remote_write_bw=0.83 * 17.0 * GB,
    core_rate=(2.3e9,) * 4,
    topology=snc(2, 2, qpi_bw=51.2 * GB, intra_bw=44.0 * GB),
    hop_attenuation=0.9,
)

# The 8-core machine with socket 1 thermally throttled to 2/3 clock — the
# big.LITTLE-style asymmetry case: identical banks and links, but threads
# on node 1 issue (and demand bandwidth) at only 1.6 GHz.
E5_2630_V3_THROTTLED = MachineSpec(
    name="E5-2630v3-8c-throttled",
    sockets=2,
    cores_per_socket=8,
    local_read_bw=52.0 * GB,
    local_write_bw=28.0 * GB,
    remote_read_bw=0.16 * 52.0 * GB,
    remote_write_bw=0.23 * 28.0 * GB,
    core_rate=(2.4e9, 1.6e9),
    topology=fully_connected(2, 16.0 * GB),
)

# The 8-core machine with socket 1's DIMM slots only half-populated — the
# mixed-DIMM-population case per-node bandwidth vectors exist for: bank 1
# has half the channels (half the local bandwidth), banks stay otherwise
# identical, so placement quality now depends on WHICH node memory lands
# on even for fully local workloads.
E5_2630_V3_MIXED_DIMM = MachineSpec(
    name="E5-2630v3-8c-mixed-dimm",
    sockets=2,
    cores_per_socket=8,
    local_read_bw=(52.0 * GB, 26.0 * GB),
    local_write_bw=(28.0 * GB, 14.0 * GB),
    remote_read_bw=0.16 * 52.0 * GB,
    remote_write_bw=0.23 * 28.0 * GB,
    core_rate=(2.4e9, 2.4e9),
    topology=fully_connected(2, 16.0 * GB),
)

MACHINES: dict[str, MachineSpec] = {
    E5_2630_V3.name: E5_2630_V3,
    E5_2699_V3.name: E5_2699_V3,
    E7_4830_V3.name: E7_4830_V3,
    E7_8860_V3.name: E7_8860_V3,
    E5_2699_V3_SNC2.name: E5_2699_V3_SNC2,
    E5_2630_V3_THROTTLED.name: E5_2630_V3_THROTTLED,
    E5_2630_V3_MIXED_DIMM.name: E5_2630_V3_MIXED_DIMM,
}

for _machine in MACHINES.values():
    _machine.validate()


def _as_node_bw(value) -> float | tuple[float, ...]:
    """Canonicalize a local-bandwidth argument: scalars stay scalars (the
    bit-for-bit pre-tuple path), sequences become hashable per-node
    tuples."""
    if isinstance(value, (int, float)):
        return float(value)
    return tuple(float(v) for v in value)


def make_machine(
    name: str = "generic",
    sockets: int = 2,
    cores_per_socket: int = 8,
    local_read_bw: float | tuple[float, ...] = 50.0 * GB,
    local_write_bw: float | tuple[float, ...] = 28.0 * GB,
    remote_read_ratio: float = 0.5,
    remote_write_ratio: float = 0.5,
    qpi_bw: float = 32.0 * GB,
    core_rate: float | tuple[float, ...] = 2.4e9,
    topology: Topology | None = None,
    hop_attenuation: float = 1.0,
    nodes_per_socket: int = 1,
    intra_bw: float | None = None,
) -> MachineSpec:
    """Build a custom machine from local bandwidths and remote/local ratios
    (the way the paper characterizes its systems).  Without an explicit
    ``topology``, every node pair gets a direct ``qpi_bw`` link when
    ``nodes_per_socket == 1`` (the old scalar-interconnect behaviour), or
    an SNC topology (:func:`repro.core.numa.topology.snc`, with
    ``intra_bw`` intra-socket links — default ``2 * qpi_bw``) when a
    socket hosts several nodes.  ``core_rate`` may be a scalar (every node
    identical) or a per-node sequence, which is canonicalized to a
    hashable per-node tuple."""
    n_nodes = sockets * nodes_per_socket
    if topology is None:
        if nodes_per_socket == 1:
            topology = fully_connected(sockets, qpi_bw)
        else:
            topology = snc(
                sockets,
                nodes_per_socket,
                qpi_bw=qpi_bw,
                intra_bw=2.0 * qpi_bw if intra_bw is None else intra_bw,
            )
    if not isinstance(core_rate, (int, float)):
        core_rate = tuple(float(r) for r in core_rate)
        if len(core_rate) == 1:
            core_rate = core_rate * n_nodes
    local_read_bw = _as_node_bw(local_read_bw)
    local_write_bw = _as_node_bw(local_write_bw)
    # remote/local ratios are how the paper characterizes a machine; with
    # per-node local tuples the (scalar) remote path caps anchor on the
    # mean bank bandwidth
    mean_read = (
        sum(local_read_bw) / len(local_read_bw)
        if isinstance(local_read_bw, tuple)
        else local_read_bw
    )
    mean_write = (
        sum(local_write_bw) / len(local_write_bw)
        if isinstance(local_write_bw, tuple)
        else local_write_bw
    )
    machine = MachineSpec(
        name=name,
        sockets=sockets,
        cores_per_socket=cores_per_socket,
        local_read_bw=local_read_bw,
        local_write_bw=local_write_bw,
        remote_read_bw=remote_read_ratio * mean_read,
        remote_write_bw=remote_write_ratio * mean_write,
        core_rate=core_rate,
        topology=topology,
        hop_attenuation=hop_attenuation,
        nodes_per_socket=nodes_per_socket,
    )
    machine.validate()
    return machine
