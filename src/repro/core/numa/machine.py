"""Machine descriptions for the NUMA simulator.

Paper §2 / Figure 2: the evaluation machines are dual-socket Intel Haswell
systems, Xeon E5-2630 v3 (8 cores/socket) and Xeon E5-2699 v3 (18
cores/socket).  "Both systems have similar read and write bandwidths to
local memory, but drastically different performance when accessing remote
memory where the 8 core processors only have 0.16 of the bandwidth for
remote reads and 0.23 of the bandwidth for remote writes relative to local
reads and writes.  On the 18 core processors ... 0.59 of the bandwidth for
remote reads and 0.83 of the bandwidth for remote writes."

Absolute local bandwidths are not printed in the paper (they are in a
figure); the values below use public STREAM-class measurements for
quad-channel DDR4-1866/2133 Haswell parts and apply the paper's exact
remote/local ratios.  The *model* never sees these constants — they only
shape the simulated ground truth.

Beyond the paper, every machine carries a :class:`Topology` — a per-link
interconnect bandwidth matrix with static shortest-path routing — instead
of the single scalar ``qpi_bw`` the 2-socket formulation used.  Remote
path capacities become per-ordered-pair, attenuated per extra hop
(``hop_attenuation``), and interconnect capacity is enforced per *link*
with multi-hop traffic charging every link it crosses.  For a
fully-connected topology (every pair 1 hop) this degenerates exactly to
the old scalar model.  All fields stay hashable python scalars / nested
tuples, so a ``MachineSpec`` remains a valid ``jax.jit`` static argument
and cache-key component; array-valued topology input is canonicalized at
construction and :meth:`MachineSpec.fingerprint` digests every field for
content-addressed caches.
"""

from __future__ import annotations

import hashlib
from typing import NamedTuple

import jax.numpy as jnp
from jax import Array

from repro.core.numa.topology import Topology, fully_connected, glued_8s

GB = 1e9


class MachineSpec(NamedTuple):
    """A multi-socket NUMA machine.

    Bandwidth capacities are bytes/s.  ``remote_read_bw``/``remote_write_bw``
    cap each *one-hop* ordered socket pair's path (remote controller +
    interconnect direction); pairs whose route is longer are attenuated by
    ``hop_attenuation`` per extra hop (:meth:`remote_read_caps`).  The
    interconnect itself is ``topology``: per-link capacities plus static
    routes, with every link on a route charged the full flow.
    ``core_rate`` is instructions/s per thread at full speed.
    """

    name: str
    sockets: int
    cores_per_socket: int
    local_read_bw: float
    local_write_bw: float
    remote_read_bw: float
    remote_write_bw: float
    core_rate: float
    topology: Topology
    hop_attenuation: float = 1.0

    @property
    def total_cores(self) -> int:
        return self.sockets * self.cores_per_socket

    @property
    def n_links(self) -> int:
        return self.topology.n_links

    def bank_read_caps(self) -> Array:
        return jnp.full((self.sockets,), self.local_read_bw)

    def bank_write_caps(self) -> Array:
        return jnp.full((self.sockets,), self.local_write_bw)

    def link_caps(self) -> Array:
        """Per-link interconnect capacities, ``(n_links,)``."""
        return jnp.asarray(self.topology.link_bw, jnp.float32)

    def _remote_caps(self, base: float) -> Array:
        hops = jnp.asarray(self.topology.hop_matrix(), jnp.float32)
        att = jnp.asarray(self.hop_attenuation, jnp.float32) ** jnp.maximum(
            hops - 1.0, 0.0
        )
        return jnp.where(hops == 0, jnp.inf, base * att)

    def remote_read_caps(self) -> Array:
        """``(s, s)`` per-ordered-pair remote read capacity: ``inf`` on the
        diagonal, the 1-hop cap attenuated per extra routed hop elsewhere."""
        return self._remote_caps(self.remote_read_bw)

    def remote_write_caps(self) -> Array:
        return self._remote_caps(self.remote_write_bw)

    def fingerprint(self) -> str:
        """Content digest over every field (topology included) — the
        machine component of signature-cache keys, stable across processes
        and robust to array-valued topology input (canonicalized to
        tuples at construction)."""
        digest = hashlib.blake2b(digest_size=16)
        for part in (
            self.name,
            self.sockets,
            self.cores_per_socket,
            self.local_read_bw,
            self.local_write_bw,
            self.remote_read_bw,
            self.remote_write_bw,
            self.core_rate,
            self.hop_attenuation,
            self.topology,
        ):
            digest.update(repr(part).encode())
            digest.update(b"\x1f")  # field separator: '325.0' != '32','5.0'
        return digest.hexdigest()


# Xeon E5-2630 v3: 8 cores, 2.4 GHz, DDR4-1866.  The cheap machine whose
# remote links are easily saturated (paper Figure 1: up to 3x slowdown).
E5_2630_V3 = MachineSpec(
    name="E5-2630v3-8c",
    sockets=2,
    cores_per_socket=8,
    local_read_bw=52.0 * GB,
    local_write_bw=28.0 * GB,
    remote_read_bw=0.16 * 52.0 * GB,  # paper ratio 0.16
    remote_write_bw=0.23 * 28.0 * GB,  # paper ratio 0.23
    core_rate=2.4e9,
    topology=fully_connected(2, 16.0 * GB),  # one QPI link
)

# Xeon E5-2699 v3: 18 cores, 2.3 GHz, DDR4-2133.  The expensive machine that
# is "far more forgiving of thread and memory placement".
E5_2699_V3 = MachineSpec(
    name="E5-2699v3-18c",
    sockets=2,
    cores_per_socket=18,
    local_read_bw=62.0 * GB,
    local_write_bw=34.0 * GB,
    remote_read_bw=0.59 * 62.0 * GB,  # paper ratio 0.59
    remote_write_bw=0.83 * 34.0 * GB,  # paper ratio 0.83
    core_rate=2.3e9,
    topology=fully_connected(2, 51.2 * GB),
)

# ---------------------------------------------------------------------------
# Beyond-paper presets: 4- and 8-socket machines.  The paper's method is
# derived for 2 sockets; these presets drive the generalized (s >= 2)
# placement-sweep engine where NUMA effects are most severe.
# ---------------------------------------------------------------------------

# Xeon E7-4830 v3: quad-socket Haswell-EX, 12 cores/socket, DDR4 behind the
# memory buffer (lower local bandwidth than the 2-socket parts), fully
# connected QPI — every remote pair is one hop.
E7_4830_V3 = MachineSpec(
    name="E7-4830v3-4s12c",
    sockets=4,
    cores_per_socket=12,
    local_read_bw=46.0 * GB,
    local_write_bw=25.0 * GB,
    remote_read_bw=0.30 * 46.0 * GB,
    remote_write_bw=0.40 * 25.0 * GB,
    core_rate=2.1e9,
    topology=fully_connected(4, 19.2 * GB),
)

# Xeon E7-8860 v3: 8-socket Haswell-EX built from two fully QPI-meshed
# quads glued by node controllers.  Twin sockets (i, i+4) are one
# controller hop apart; every other cross-quad pair routes over 2 hops
# (QPI + controller), charging both links and paying the per-hop
# attenuation on its remote-path capacity.
E7_8860_V3 = MachineSpec(
    name="E7-8860v3-8s16c",
    sockets=8,
    cores_per_socket=16,
    local_read_bw=50.0 * GB,
    local_write_bw=27.0 * GB,
    remote_read_bw=0.35 * 50.0 * GB,
    remote_write_bw=0.45 * 27.0 * GB,
    core_rate=2.2e9,
    topology=glued_8s(qpi_bw=12.8 * GB, nc_bw=9.6 * GB),
    hop_attenuation=0.8,
)

MACHINES: dict[str, MachineSpec] = {
    E5_2630_V3.name: E5_2630_V3,
    E5_2699_V3.name: E5_2699_V3,
    E7_4830_V3.name: E7_4830_V3,
    E7_8860_V3.name: E7_8860_V3,
}


def make_machine(
    name: str = "generic",
    sockets: int = 2,
    cores_per_socket: int = 8,
    local_read_bw: float = 50.0 * GB,
    local_write_bw: float = 28.0 * GB,
    remote_read_ratio: float = 0.5,
    remote_write_ratio: float = 0.5,
    qpi_bw: float = 32.0 * GB,
    core_rate: float = 2.4e9,
    topology: Topology | None = None,
    hop_attenuation: float = 1.0,
) -> MachineSpec:
    """Build a custom machine from local bandwidths and remote/local ratios
    (the way the paper characterizes its systems).  Without an explicit
    ``topology`` every socket pair gets a direct ``qpi_bw`` link (the old
    scalar-interconnect behaviour); pass a :class:`Topology` — or build one
    with :func:`repro.core.numa.topology.from_bandwidth_matrix` — for
    routed machines."""
    if topology is None:
        topology = fully_connected(sockets, qpi_bw)
    if topology.n_nodes != sockets:
        raise ValueError(
            f"topology has {topology.n_nodes} nodes for {sockets} sockets"
        )
    return MachineSpec(
        name=name,
        sockets=sockets,
        cores_per_socket=cores_per_socket,
        local_read_bw=local_read_bw,
        local_write_bw=local_write_bw,
        remote_read_bw=remote_read_ratio * local_read_bw,
        remote_write_bw=remote_write_ratio * local_write_bw,
        core_rate=core_rate,
        topology=topology,
        hop_attenuation=hop_attenuation,
    )
