"""Interconnect topologies: link bandwidth matrices + static routing.

The paper's machines are dual-socket boxes where "the interconnect" is a
single QPI link, but large NUMA machines have strongly distance-dependent
bandwidth (STREAM-style measurements show per-hop cliffs — Bergstrom,
arXiv:1103.3225), and glued 8-socket systems route far socket pairs
through node controllers.  A :class:`Topology` captures that structure:

* an undirected link list with per-link capacities (bytes/s), and
* a statically computed shortest-path routing table: for every ordered
  socket pair, the sequence of links its traffic crosses.

Everything is stored as nested tuples of python scalars, so a
``Topology`` (and the :class:`~repro.core.numa.machine.MachineSpec` that
embeds one) stays hashable — it can be a ``jax.jit`` static argument and
a signature-cache key even when the builder was handed numpy/JAX arrays
for the bandwidth matrix.  The derived *arrays* (link capacities, hop
matrix, pair→link routing incidence) are materialized lazily and cached
per topology; inside a trace they are compile-time constants, so the
simulator's resource slab keeps a fixed ``(n, n_links)`` shape that jit
and vmap handle identically for any socket count.

Routing is hop-count shortest path (BFS) with bandwidth-aware tie-breaks:
among equal-hop routes the one with the largest bottleneck link bandwidth
wins (widest-shortest path), and remaining ties fall back to the
smallest-id predecessor in the previous BFS layer — with uniform link
bandwidths this reduces exactly to the old smallest-predecessor rule, so
routing tables stay reproducible across processes.

A topology's nodes are NUMA *nodes*, not sockets: a sub-NUMA-clustered
(SNC / Cluster-on-Die) part contributes ``nodes_per_socket`` nodes per
socket, joined by intra-socket links (:func:`snc`), and the
:class:`~repro.core.numa.machine.MachineSpec` embedding the topology
requires ``n_nodes == sockets * nodes_per_socket``.
"""

from __future__ import annotations

from functools import lru_cache
from typing import NamedTuple, Sequence

import numpy as np


class Topology(NamedTuple):
    """An interconnect graph over ``n_nodes`` NUMA nodes with static routes.

    ``link_ends[l] = (i, j)`` with ``i < j`` names the l-th undirected
    link; ``link_bw[l]`` is its capacity in bytes/s (both directions share
    it, like QPI).  ``routes[i * n_nodes + j]`` is the tuple of link
    indices the ordered pair ``i -> j`` crosses (empty for ``i == j``).
    """

    name: str
    n_nodes: int
    link_ends: tuple[tuple[int, int], ...]
    link_bw: tuple[float, ...]
    routes: tuple[tuple[int, ...], ...]

    @property
    def n_links(self) -> int:
        return len(self.link_ends)

    def route(self, i: int, j: int) -> tuple[int, ...]:
        """Link indices crossed by traffic from socket ``i`` to ``j``."""
        return self.routes[i * self.n_nodes + j]

    @property
    def max_hops(self) -> int:
        return max((len(r) for r in self.routes), default=0)

    @property
    def is_fully_direct(self) -> bool:
        """True when every distinct pair is one hop (no routed traffic) —
        the regime where the link model degenerates to the scalar-pair
        model of the original 2-socket formulation."""
        return self.max_hops <= 1

    def hop_matrix(self) -> np.ndarray:
        """``(n, n)`` int hop counts (0 on the diagonal)."""
        return _hop_matrix(self)

    def route_incidence(self) -> np.ndarray:
        """``(n*n, n_links)`` float32 matrix ``R`` with ``R[i*n+j, l] = 1``
        iff link ``l`` is on the route ``i -> j``.  Charging per-link usage
        is then one matmul: ``flows.reshape(-1, n*n) @ R``."""
        return _route_incidence(self, multihop_only=False)

    def route_incidence_multihop(self) -> np.ndarray:
        """Like :meth:`route_incidence` but with single-hop rows zeroed —
        the *extra* charges routed topologies add on top of the direct
        endpoint-pair traffic every link always carries."""
        return _route_incidence(self, multihop_only=True)

    def validate(self) -> None:
        n = self.n_nodes
        if len(self.routes) != n * n:
            raise ValueError(f"routes must have {n * n} entries")
        if len(self.link_bw) != len(self.link_ends):
            raise ValueError("link_bw and link_ends disagree on link count")
        if len(set(self.link_ends)) != len(self.link_ends):
            raise ValueError("duplicate links: endpoint pairs must be unique")
        for l, (i, j) in enumerate(self.link_ends):
            if not (0 <= i < j < n):
                raise ValueError(f"link {l} endpoints {(i, j)} invalid")
            if self.link_bw[l] <= 0:
                raise ValueError(f"link {l} has non-positive bandwidth")
        for i in range(n):
            for j in range(n):
                r = self.route(i, j)
                if i == j:
                    if r:
                        raise ValueError(f"self-route {i} must be empty")
                    continue
                if not r:
                    raise ValueError(f"nodes {i} and {j} are disconnected")
                at = i
                for l in r:
                    a, b = self.link_ends[l]
                    if at == a:
                        at = b
                    elif at == b:
                        at = a
                    else:
                        raise ValueError(f"route {i}->{j} breaks at link {l}")
                if at != j:
                    raise ValueError(f"route {i}->{j} ends at {at}")


@lru_cache(maxsize=128)
def _hop_matrix(topo: Topology) -> np.ndarray:
    n = topo.n_nodes
    hops = np.zeros((n, n), np.int32)
    for i in range(n):
        for j in range(n):
            hops[i, j] = len(topo.route(i, j))
    hops.setflags(write=False)
    return hops


@lru_cache(maxsize=128)
def _route_incidence(topo: Topology, *, multihop_only: bool) -> np.ndarray:
    n = topo.n_nodes
    R = np.zeros((n * n, topo.n_links), np.float32)
    for i in range(n):
        for j in range(n):
            r = topo.route(i, j)
            if multihop_only and len(r) <= 1:
                continue
            for l in r:
                R[i * n + j, l] = 1.0
    R.setflags(write=False)
    return R


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def _shortest_routes(
    n: int,
    link_ends: Sequence[tuple[int, int]],
    link_bw: Sequence[float] | None = None,
) -> tuple[tuple[int, ...], ...]:
    """BFS hop-count routing for every ordered pair, with bandwidth-aware
    tie-breaking: among equal-hop shortest paths the route with the largest
    bottleneck link bandwidth wins (widest-shortest path).  Remaining ties
    break deterministically toward the smallest-id predecessor in the
    previous BFS layer, then the smallest link id — with uniform link
    bandwidths (or ``link_bw=None``) this is exactly the old
    smallest-predecessor rule, so routing tables are reproducible across
    processes and unchanged for unweighted topologies."""
    widths = (
        [float("inf")] * len(link_ends) if link_bw is None else [float(b) for b in link_bw]
    )
    adj: list[list[tuple[int, int]]] = [[] for _ in range(n)]  # node -> (nbr, link)
    for l, (i, j) in enumerate(link_ends):
        adj[i].append((j, l))
        adj[j].append((i, l))
    for nbrs in adj:
        nbrs.sort()

    routes: list[tuple[int, ...]] = []
    for src in range(n):
        dist = {src: 0}
        order: list[int] = []  # nodes in (layer, id) order — DP dependencies first
        frontier = [src]
        while frontier:
            nxt: list[int] = []
            for u in frontier:
                for v, _ in adj[u]:
                    if v not in dist:
                        dist[v] = dist[u] + 1
                        nxt.append(v)
            nxt = sorted(set(nxt))
            order.extend(nxt)
            frontier = nxt
        # Widest-path DP over the BFS layering: a node's route width is the
        # best min(predecessor width, entering link bandwidth) over the
        # previous layer, ties preferring (smallest pred id, smallest link).
        width = {src: float("inf")}
        prev: dict[int, tuple[int, int]] = {}  # node -> (prev node, link)
        for v in order:
            best: tuple[float, int, int] | None = None
            for u, l in adj[v]:
                if dist.get(u) == dist[v] - 1:
                    key = (-min(width[u], widths[l]), u, l)
                    if best is None or key < best:
                        best = key
            assert best is not None  # v was discovered from the previous layer
            width[v] = -best[0]
            prev[v] = (best[1], best[2])
        for dst in range(n):
            if dst == src:
                routes.append(())
                continue
            if dst not in dist:
                raise ValueError(f"node {dst} unreachable from {src}")
            path: list[int] = []
            at = dst
            while at != src:
                at, l = prev[at]
                path.append(l)
            routes.append(tuple(reversed(path)))
    return tuple(routes)


def _as_bw_list(link_bw, n_links: int, what: str) -> list[float]:
    """Canonicalize a scalar / sequence / array of link bandwidths to a
    plain list of python floats (array-valued input stays hashable)."""
    arr = np.asarray(link_bw, np.float64)
    if arr.ndim == 0:
        return [float(arr)] * n_links
    flat = [float(v) for v in arr.reshape(-1)]
    if len(flat) != n_links:
        raise ValueError(f"{what}: expected {n_links} bandwidths, got {len(flat)}")
    return flat


def from_bandwidth_matrix(name: str, bw: np.ndarray) -> Topology:
    """Build a topology from a symmetric ``(n, n)`` link-bandwidth matrix
    (0 = no link) — the natural form for measured machines.  Accepts any
    array-like; values are canonicalized to python floats."""
    bw = np.asarray(bw, np.float64)
    if bw.ndim != 2 or bw.shape[0] != bw.shape[1]:
        raise ValueError(f"need a square matrix, got shape {bw.shape}")
    if not np.allclose(bw, bw.T):
        raise ValueError("link bandwidth matrix must be symmetric")
    if (bw < 0).any():
        raise ValueError("link bandwidths must be >= 0 (0 = no link)")
    n = bw.shape[0]
    ends = [(i, j) for i in range(n) for j in range(i + 1, n) if bw[i, j] > 0]
    bws = [float(bw[i, j]) for i, j in ends]
    topo = Topology(
        name=name,
        n_nodes=n,
        link_ends=tuple(ends),
        link_bw=tuple(bws),
        routes=_shortest_routes(n, ends, bws),
    )
    topo.validate()
    return topo


# ---------------------------------------------------------------------------
# Calibration support: parameter <-> link-matrix packing and fitted rebuilds
# ---------------------------------------------------------------------------


class LinkGroups(NamedTuple):
    """Parameter↔matrix packing for fitting link bandwidths.

    ``groups`` partitions a topology's link ids into tied classes: every
    link in a group shares one free parameter (the symmetry/structure mask
    of the inverse problem — e.g. a glued 8-socket machine's 12 QPI links
    are one hardware part, its 4 node-controller links another).  The
    untied parameterization is ``n_links`` singleton groups.  ``pack``
    reduces per-link values to the free-parameter vector; ``unpack``
    scatters a parameter vector back to per-link order.  Both work on
    numpy and traced JAX arrays (``unpack`` is a pure gather), so the
    packing layer sits inside a jitted objective.
    """

    groups: tuple[tuple[int, ...], ...]

    @property
    def n_params(self) -> int:
        return len(self.groups)

    @property
    def n_links(self) -> int:
        return sum(len(g) for g in self.groups)

    def link_index(self) -> np.ndarray:
        """``(n_links,)`` free-parameter id of every link."""
        idx = np.zeros((self.n_links,), np.int32)
        for p, group in enumerate(self.groups):
            for l in group:
                idx[l] = p
        return idx

    def pack(self, link_bw) -> np.ndarray:
        """Per-link values -> ``(n_params,)`` group means."""
        bw = np.asarray(link_bw, np.float64)
        return np.array([bw[list(g)].mean() for g in self.groups])

    def unpack(self, params):
        """``(n_params,)`` free parameters -> per-link values (a gather:
        differentiable, vmappable)."""
        return params[self.link_index()]

    def validate(self) -> None:
        seen = sorted(l for g in self.groups for l in g)
        if seen != list(range(len(seen))):
            raise ValueError("groups must partition the link ids exactly")
        if any(not g for g in self.groups):
            raise ValueError("empty link group")


def link_groups(topo: Topology, *, tie_equal_bw: bool = False) -> LinkGroups:
    """The natural parameterization of a topology's link bandwidths.

    With ``tie_equal_bw`` links whose *template* bandwidths are equal share
    one parameter (structural knowledge: same physical link class);
    otherwise every link is free.  Fitting stays well-posed either way —
    ties just let a link that never saturates in the sample set inherit
    its class's recovered capacity."""
    if not tie_equal_bw:
        groups = tuple((l,) for l in range(topo.n_links))
    else:
        by_bw: dict[float, list[int]] = {}
        for l, bw in enumerate(topo.link_bw):
            by_bw.setdefault(float(bw), []).append(l)
        groups = tuple(tuple(ls) for _, ls in sorted(by_bw.items()))
    out = LinkGroups(groups=groups)
    out.validate()
    return out


def from_fit(template: Topology, link_bw, *, name: str | None = None) -> Topology:
    """Rebuild a topology from fitted per-link bandwidths, holding the
    template's link list AND routing tables static — the contract of the
    calibration inverse problem (§ the forward model's routes are
    compile-time structure; only capacities are free parameters).  Values
    are canonicalized to python floats so the result stays hashable."""
    bws = _as_bw_list(link_bw, template.n_links, "from_fit")
    topo = Topology(
        name=template.name if name is None else name,
        n_nodes=template.n_nodes,
        link_ends=template.link_ends,
        link_bw=tuple(bws),
        routes=template.routes,
    )
    topo.validate()
    return topo


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def fully_connected(n: int, link_bw) -> Topology:
    """Every socket pair directly linked (the 2-socket machines and fully
    QPI-meshed quad Haswell-EX).  Links enumerate in upper-triangle order,
    matching the scalar-pair model's resource layout exactly."""
    ends = [(i, j) for i in range(n) for j in range(i + 1, n)]
    bws = _as_bw_list(link_bw, len(ends), "fully_connected")
    topo = Topology(
        name=f"fc{n}",
        n_nodes=n,
        link_ends=tuple(ends),
        link_bw=tuple(bws),
        routes=_shortest_routes(n, ends, bws),
    )
    topo.validate()
    return topo


def ring(n: int, link_bw) -> Topology:
    """Sockets on a bidirectional ring — the worst-case hop spread
    (diameter ``n // 2``)."""
    if n < 2:
        raise ValueError("ring needs >= 2 nodes")
    ends = sorted(tuple(sorted((i, (i + 1) % n))) for i in range(n))
    ends = list(dict.fromkeys(ends))  # n == 2: one link, not two
    bws = _as_bw_list(link_bw, len(ends), "ring")
    topo = Topology(
        name=f"ring{n}",
        n_nodes=n,
        link_ends=tuple(ends),
        link_bw=tuple(bws),
        routes=_shortest_routes(n, ends, bws),
    )
    topo.validate()
    return topo


def mesh2d(rows: int, cols: int, link_bw) -> Topology:
    """Sockets on a ``rows x cols`` grid with nearest-neighbour links
    (SGI/HPE hypercube-ish blades flattened to 2D)."""
    n = rows * cols
    if n < 2:
        raise ValueError("mesh2d needs >= 2 nodes")
    ends = []
    for r in range(rows):
        for c in range(cols):
            u = r * cols + c
            if c + 1 < cols:
                ends.append((u, u + 1))
            if r + 1 < rows:
                ends.append((u, u + cols))
    ends.sort()
    bws = _as_bw_list(link_bw, len(ends), "mesh2d")
    topo = Topology(
        name=f"mesh{rows}x{cols}",
        n_nodes=n,
        link_ends=tuple(ends),
        link_bw=tuple(bws),
        routes=_shortest_routes(n, ends, bws),
    )
    topo.validate()
    return topo


def glued_8s(qpi_bw: float, nc_bw: float) -> Topology:
    """The glued 8-socket node-controller topology (Haswell-EX E7-8800
    class): two fully QPI-meshed quads; socket ``i`` of quad 0 reaches its
    twin ``i + 4`` over a node-controller link.  Cross-quad non-twin pairs
    route over 2 hops (one QPI + one controller link), so far traffic
    charges both — the hop-count bandwidth cliff the scalar model could
    not express."""
    ends: list[tuple[int, int]] = []
    bws: list[float] = []
    for base in (0, 4):
        for i in range(4):
            for j in range(i + 1, 4):
                ends.append((base + i, base + j))
                bws.append(float(qpi_bw))
    for i in range(4):
        ends.append((i, i + 4))
        bws.append(float(nc_bw))
    order = sorted(range(len(ends)), key=lambda k: ends[k])
    ends = [ends[k] for k in order]
    bws = [bws[k] for k in order]
    topo = Topology(
        name="glued8s",
        n_nodes=8,
        link_ends=tuple(ends),
        link_bw=tuple(bws),
        routes=_shortest_routes(8, ends, bws),
    )
    topo.validate()
    return topo


def snc(
    sockets: int, nodes_per_socket: int, *, qpi_bw: float, intra_bw: float
) -> Topology:
    """Sub-NUMA clustering (SNC / Cluster-on-Die): each socket splits into
    ``nodes_per_socket`` NUMA nodes joined by fast intra-socket (in-die
    mesh) links, while each socket's FIRST node is its interconnect
    endpoint and the endpoints are fully QPI-meshed.  Cross-socket traffic
    from a non-endpoint node routes through its socket's endpoint, so both
    of a socket's nodes *share* the one QPI port — the SNC reality a
    per-socket machine model cannot express.  With ``nodes_per_socket=1``
    this degenerates to :func:`fully_connected`."""
    if sockets < 2:
        raise ValueError("snc needs >= 2 sockets")
    if nodes_per_socket < 1:
        raise ValueError("snc needs >= 1 node per socket")
    ends: list[tuple[int, int]] = []
    bws: list[float] = []
    for s in range(sockets):
        base = s * nodes_per_socket
        for i in range(nodes_per_socket):
            for j in range(i + 1, nodes_per_socket):
                ends.append((base + i, base + j))
                bws.append(float(intra_bw))
    for a in range(sockets):
        for b in range(a + 1, sockets):
            ends.append((a * nodes_per_socket, b * nodes_per_socket))
            bws.append(float(qpi_bw))
    order = sorted(range(len(ends)), key=lambda k: ends[k])
    ends = [ends[k] for k in order]
    bws = [bws[k] for k in order]
    n = sockets * nodes_per_socket
    topo = Topology(
        name=f"snc{sockets}x{nodes_per_socket}",
        n_nodes=n,
        link_ends=tuple(ends),
        link_bw=tuple(bws),
        routes=_shortest_routes(n, ends, bws),
    )
    topo.validate()
    return topo
