"""NUMA interconnect topologies — the host-side face of
:mod:`repro.core.graphtop`.

Historically this module *was* the graph engine; the machinery (hashable
link graphs, BFS widest-shortest-path routing with deterministic
tie-breaks, pair→link incidence matrices, the :class:`LinkGroups`
calibration packing, and the generic builders) now lives in
:mod:`repro.core.graphtop.graph`, shared with the accelerator-mesh
models in :mod:`repro.core.meshsig.device_topology`.  Everything that was
importable from here still is:

* :class:`Topology` is a field-free subclass of
  :class:`~repro.core.graphtop.LinkGraph`.  ``namedtuple`` reprs, ``_make``
  and ``_replace`` all go through ``self.__class__``, so a ``Topology``
  prints as ``Topology(...)`` exactly as before — which is what keeps
  :meth:`~repro.core.numa.machine.MachineSpec.fingerprint` digests (they
  hash ``repr(topology)``) and every golden pin bit-for-bit unchanged.
* The builders below rewrap the shared implementations and preserve the
  historical names (``fc{n}``, ``ring{n}``, ``mesh{rows}x{cols}``,
  ``glued8s``, ``snc{s}x{n}``), link enumeration order, and routing
  tables byte-for-byte.
* ``LinkGroups`` / ``link_groups`` / ``from_fit`` / ``from_bandwidth_matrix``
  re-export the shared code (``from_fit`` preserves the template's class,
  so fitting a ``Topology`` yields a ``Topology``).

A topology's nodes are NUMA *nodes*, not sockets: a sub-NUMA-clustered
(SNC / Cluster-on-Die) part contributes ``nodes_per_socket`` nodes per
socket, joined by intra-socket links (:func:`snc`), and the
:class:`~repro.core.numa.machine.MachineSpec` embedding the topology
requires ``n_nodes == sockets * nodes_per_socket``.
"""

from __future__ import annotations

from repro.core.graphtop import graph as _graph
from repro.core.graphtop.graph import (  # noqa: F401  (re-exported API)
    LinkGraph,
    LinkGroups,
    _as_bw_list,
    _shortest_routes,
    all_widest_routes,
    from_fit,
    link_groups,
)


class Topology(LinkGraph):
    """An interconnect graph over ``n_nodes`` NUMA nodes with static
    routes — a :class:`~repro.core.graphtop.LinkGraph` under its
    historical NUMA name (no new fields, no new behaviour; the class
    identity matters because machine fingerprints digest ``repr``)."""

    __slots__ = ()


def _rewrap(g: LinkGraph, *, name: str | None = None) -> Topology:
    topo = Topology(
        name=g.name if name is None else name,
        n_nodes=g.n_nodes,
        link_ends=g.link_ends,
        link_bw=g.link_bw,
        routes=g.routes,
    )
    return topo


def from_bandwidth_matrix(name: str, bw) -> Topology:
    """Build a topology from a symmetric ``(n, n)`` link-bandwidth matrix
    (0 = no link) — the natural form for measured machines."""
    return _rewrap(_graph.from_bandwidth_matrix(name, bw))


def fully_connected(n: int, link_bw) -> Topology:
    """Every socket pair directly linked (the 2-socket machines and fully
    QPI-meshed quad Haswell-EX).  Links enumerate in upper-triangle order,
    matching the scalar-pair model's resource layout exactly."""
    return _rewrap(_graph.fully_connected(n, link_bw))


def ring(n: int, link_bw) -> Topology:
    """Sockets on a bidirectional ring — the worst-case hop spread
    (diameter ``n // 2``)."""
    return _rewrap(_graph.ring(n, link_bw))


def mesh2d(rows: int, cols: int, link_bw) -> Topology:
    """Sockets on a ``rows x cols`` grid with nearest-neighbour links
    (SGI/HPE hypercube-ish blades flattened to 2D)."""
    return _rewrap(_graph.mesh2d(rows, cols, link_bw))


def glued_8s(qpi_bw: float, nc_bw: float) -> Topology:
    """The glued 8-socket node-controller topology (Haswell-EX E7-8800
    class): two fully QPI-meshed quads; socket ``i`` of quad 0 reaches its
    twin ``i + 4`` over a node-controller link.  Cross-quad non-twin pairs
    route over 2 hops (one QPI + one controller link), so far traffic
    charges both — the hop-count bandwidth cliff the scalar model could
    not express.  Exactly :func:`repro.core.graphtop.glued` with two
    islands of four, under the historical ``glued8s`` name."""
    return _rewrap(_graph.glued(2, 4, qpi_bw, nc_bw), name="glued8s")


def snc(
    sockets: int, nodes_per_socket: int, *, qpi_bw: float, intra_bw: float
) -> Topology:
    """Sub-NUMA clustering (SNC / Cluster-on-Die): each socket splits into
    ``nodes_per_socket`` NUMA nodes joined by fast intra-socket (in-die
    mesh) links, while each socket's FIRST node is its interconnect
    endpoint and the endpoints are fully QPI-meshed.  Cross-socket traffic
    from a non-endpoint node routes through its socket's endpoint, so both
    of a socket's nodes *share* the one QPI port — the SNC reality a
    per-socket machine model cannot express.  With ``nodes_per_socket=1``
    this degenerates to :func:`fully_connected`."""
    return _rewrap(_graph.snc(sockets, nodes_per_socket, qpi_bw=qpi_bw, intra_bw=intra_bw))
