"""Time axis: phased workloads, migration costs, and schedule search.

The steady-state model answers "what does placement ``p`` sustain?"; real
workloads drift through *phases* (graph algorithms alternate compute and
exchange, query engines alternate scan and join).  This module adds the
minimal time structure the advisor needs to become a scheduler:

* :class:`PhasedWorkload` — a piecewise-stationary workload: a sequence
  of per-phase :class:`~repro.core.numa.workload.Workload` signatures
  with durations.  Each phase is evaluated through the existing grouped
  solver (:func:`repro.core.numa.search.exact_objectives`), so a
  single-phase schedule reproduces today's steady-state answers exactly.
* :class:`MigrationModel` — what a phase-boundary move costs: bytes
  dragged per migrated thread (architectural state + cache refill) and
  bytes per thread whose *Local pages* change banks, charged against the
  phase-boundary bandwidth.  Parameterized like the rest of
  :class:`~repro.core.numa.machine.MachineSpec`: physical byte/bandwidth
  numbers, machine-derived default bandwidth.
* :func:`optimize_schedule` — joint per-phase placement search: a
  candidate pool per phase scored by the (differentiable) grouped fill,
  then an exact DP/beam pass over phase boundaries trading steady-state
  throughput against transition cost.  The page/bank placement axis
  (``bank_assignment``, PAPERS.md "Bandwidth-Aware Page Placement in
  NUMA") lets the scheduler *leave pages behind* when threads move — the
  DP weighs "move threads + migrate pages" against "move threads, pay
  remote Local traffic forever" per boundary.

Thread moves are derived from the contiguous thread->node assignment
(:func:`repro.core.numa.simulator._thread_nodes`): moving from placement
``a`` to ``b`` migrates exactly the threads whose node changes.  Page
moves count the threads whose Local-class backing bank changes between
consecutive ``(placement, bank_assignment)`` states.
"""

from __future__ import annotations

import time
from typing import NamedTuple, Sequence

import numpy as np

from repro.core.numa.evaluate import count_placements, enumerate_placements
from repro.core.numa.machine import MachineSpec, canonical_bank_assignment
from repro.core.numa.search import (
    _heuristic_seeds,
    exact_objectives,
    optimize_placement,
)
from repro.core.numa.workload import Workload

# ---------------------------------------------------------------------------
# Phased workloads
# ---------------------------------------------------------------------------


class Phase(NamedTuple):
    """One stationary segment of a :class:`PhasedWorkload`."""

    workload: Workload
    duration: float  # seconds the phase runs before the next one starts


class PhasedWorkload(NamedTuple):
    """A piecewise-stationary workload: phases with durations.

    Every phase must keep the same thread count — phases change *what*
    the threads do, not how many there are (spawn/join churn is a
    different axis).  Durations are seconds of steady-state execution;
    the schedule objective is total instructions retired across the whole
    horizon, so long phases dominate exactly as they should.
    """

    name: str
    phases: tuple[Phase, ...]

    @property
    def n_threads(self) -> int:
        """Thread count shared by every phase."""
        return self.phases[0].workload.n_threads

    def validate(self) -> None:
        """Raise ``ValueError`` on empty, non-positive-duration or
        thread-count-mismatched phase lists."""
        if not self.phases:
            raise ValueError(f"phased workload {self.name!r} has no phases")
        n = self.phases[0].workload.n_threads
        for i, ph in enumerate(self.phases):
            if ph.workload.n_threads != n:
                raise ValueError(
                    f"phase {i} has {ph.workload.n_threads} threads, "
                    f"phase 0 has {n}"
                )
            if not ph.duration > 0.0:
                raise ValueError(f"phase {i} duration {ph.duration} <= 0")


def phased_workload(
    name: str, phases: Sequence[tuple[Workload, float]]
) -> PhasedWorkload:
    """Build and validate a :class:`PhasedWorkload` from ``(workload,
    duration_s)`` pairs."""
    pw = PhasedWorkload(
        name, tuple(Phase(wl, float(dur)) for wl, dur in phases)
    )
    pw.validate()
    return pw


# ---------------------------------------------------------------------------
# Migration cost model
# ---------------------------------------------------------------------------


class MigrationModel(NamedTuple):
    """What a phase-boundary reconfiguration costs.

    ``thread_move_bytes`` is the traffic one migrated thread drags across
    the boundary (architectural state plus the cold-cache refill on the
    destination node — order LLC-slice size).  ``page_move_bytes`` is the
    Local-class working set that must be copied when one thread's pages
    change backing bank.  ``bandwidth`` is the bytes/s available to the
    move; ``None`` derives it from the machine (the slowest local read
    bank — migration streams through memory, so the weakest DIMM group
    on the path bounds it).  The resulting stall is charged against the
    start of the next phase: a boundary that moves ``T`` threads and
    re-banks ``P`` threads' pages costs
    ``(T * thread_move_bytes + P * page_move_bytes) / bandwidth`` seconds
    of that phase's execution.
    """

    thread_move_bytes: float = 8e6
    page_move_bytes: float = 256e6
    bandwidth: float | None = None

    def boundary_bandwidth(self, machine: MachineSpec) -> float:
        """The bytes/s a phase-boundary move sustains on ``machine``."""
        if self.bandwidth is not None:
            return float(self.bandwidth)
        return float(np.min(np.asarray(machine.node_local_bw("read"))))


def thread_nodes(placement, n_threads: int) -> np.ndarray:
    """Host-side contiguous thread->node map of a concrete placement —
    the numpy twin of the solver's ``_thread_nodes``."""
    p = np.asarray(placement, np.int64)
    if int(p.sum()) != n_threads:
        raise ValueError(f"placement {p.tolist()} does not hold {n_threads} threads")
    return np.repeat(np.arange(p.shape[0]), p)


def thread_banks(placement, bank_assignment, n_threads: int) -> np.ndarray:
    """Per-thread Local-class backing bank under one ``(placement,
    bank_assignment)`` state (``None`` = node-local)."""
    nodes = thread_nodes(placement, n_threads)
    if bank_assignment is None:
        return nodes
    return np.asarray(bank_assignment, np.int64)[nodes]


def transition_cost(
    machine: MachineSpec,
    model: MigrationModel,
    n_threads: int,
    prev_placement,
    prev_banks,
    next_placement,
    next_banks,
) -> tuple[float, int, int]:
    """Seconds of stall (plus the thread/page move counts behind it) to
    reconfigure from one ``(placement, bank_assignment)`` state to the
    next."""
    nodes_a = thread_nodes(prev_placement, n_threads)
    nodes_b = thread_nodes(next_placement, n_threads)
    banks_a = thread_banks(prev_placement, prev_banks, n_threads)
    banks_b = thread_banks(next_placement, next_banks, n_threads)
    moved_threads = int((nodes_a != nodes_b).sum())
    moved_pages = int((banks_a != banks_b).sum())
    bytes_moved = (
        model.thread_move_bytes * moved_threads
        + model.page_move_bytes * moved_pages
    )
    return bytes_moved / model.boundary_bandwidth(machine), moved_threads, moved_pages


def follow_banks(
    machine: MachineSpec,
    n_threads: int,
    prev_placement,
    prev_banks,
    next_placement,
) -> tuple[int, ...] | None:
    """The bank assignment that keeps pages where they are when threads
    move from ``prev_placement`` to ``next_placement``.

    ``bank_assignment`` is per *node*, but the threads landing on a node
    may come from several old nodes — the assignment points each
    destination node at the bank backing the *plurality* of its arriving
    threads (ties to the lowest bank id; empty nodes keep the identity).
    Minority threads still pay a page move, which :func:`transition_cost`
    charges honestly."""
    s = machine.n_nodes
    nodes_b = thread_nodes(next_placement, n_threads)
    banks_a = thread_banks(prev_placement, prev_banks, n_threads)
    ba = list(range(s))
    for k in range(s):
        held = banks_a[nodes_b == k]
        if held.size:
            ba[k] = int(np.bincount(held, minlength=s).argmax())
    return canonical_bank_assignment(machine, tuple(ba))


# ---------------------------------------------------------------------------
# Schedule evaluation
# ---------------------------------------------------------------------------


class Schedule(NamedTuple):
    """One placement trajectory over a :class:`PhasedWorkload` plus its
    receipts (from :func:`evaluate_schedule` / :func:`optimize_schedule`)."""

    placements: tuple[tuple[int, ...], ...]  # per-phase threads-per-node
    bank_assignments: tuple[tuple[int, ...] | None, ...]  # per-phase pages
    total_work: float  # instructions retired over the whole horizon
    phase_rates: tuple[float, ...]  # instructions/s sustained per phase
    transition_times: tuple[float, ...]  # stall charged at each boundary
    moved_threads: tuple[int, ...]  # thread migrations per boundary
    moved_pages: tuple[int, ...]  # page re-bankings (threads) per boundary


class ScheduleSearchResult(NamedTuple):
    """:func:`optimize_schedule` output: the chosen schedule, the best
    *static* schedule over the same candidate pool (the one-shot
    advisor's answer held for the whole horizon), and search telemetry."""

    schedule: Schedule
    static: Schedule
    gain_pct: float  # 100 * (schedule.work - static.work) / static.work
    candidates: int  # placement pool size the DP searched over
    states_expanded: int  # DP states scored (beam telemetry)
    elapsed_s: float


def _phase_rate(machine, workload, placement, bank_assignment) -> float:
    return float(
        exact_objectives(
            machine,
            workload,
            np.asarray([placement], np.int32),
            bank_assignment=bank_assignment,
        )[0]
    )


def evaluate_schedule(
    machine: MachineSpec,
    phased: PhasedWorkload,
    placements: Sequence,
    *,
    bank_assignments: Sequence | None = None,
    model: MigrationModel | None = None,
) -> Schedule:
    """Score one explicit placement trajectory: per-phase steady-state
    rates through the grouped solver, transition stalls charged against
    the start of each following phase (a stall longer than the phase
    forfeits the whole phase, never goes negative)."""
    phased.validate()
    model = model or MigrationModel()
    n = phased.n_threads
    P = len(phased.phases)
    if len(placements) != P:
        raise ValueError(f"{len(placements)} placements for {P} phases")
    banks: list = list(bank_assignments) if bank_assignments else [None] * P
    if len(banks) != P:
        raise ValueError(f"{len(banks)} bank assignments for {P} phases")
    banks = [canonical_bank_assignment(machine, b) for b in banks]
    placements = [tuple(int(v) for v in p) for p in placements]

    rates, stalls, mts, mps = [], [], [], []
    total = 0.0
    for i, ph in enumerate(phased.phases):
        rate = _phase_rate(machine, ph.workload, placements[i], banks[i])
        if i:
            stall, mt, mp = transition_cost(
                machine, model, n,
                placements[i - 1], banks[i - 1], placements[i], banks[i],
            )
            stalls.append(stall)
            mts.append(mt)
            mps.append(mp)
        else:
            stall = 0.0
        total += rate * max(ph.duration - stall, 0.0)
        rates.append(rate)
    return Schedule(
        placements=tuple(placements),
        bank_assignments=tuple(banks),
        total_work=total,
        phase_rates=tuple(rates),
        transition_times=tuple(stalls),
        moved_threads=tuple(mts),
        moved_pages=tuple(mps),
    )


# ---------------------------------------------------------------------------
# Schedule search: candidate pool + DP/beam over phase boundaries
# ---------------------------------------------------------------------------


def _candidate_pool(
    machine: MachineSpec,
    phased: PhasedWorkload,
    per_phase: int,
    sweep_limit: int,
    seed: int,
) -> list[tuple[int, ...]]:
    """The shared placement pool the DP searches: each phase's top
    placements (exhaustive argsort when the composition space fits
    ``sweep_limit``, gradient search + heuristic seeds beyond), unioned
    across phases so "stay on another phase's best" is always a legal
    move and the static baseline is always reachable."""
    n = phased.n_threads
    pool: dict[tuple[int, ...], None] = {}
    small = count_placements(machine, n) <= sweep_limit
    if small:
        all_p = np.asarray(enumerate_placements(machine, n))
    for ph in phased.phases:
        if small:
            scores = exact_objectives(machine, ph.workload, all_p)
            top = np.argsort(scores)[::-1][:per_phase]
            cands = [tuple(int(v) for v in all_p[i]) for i in top]
        else:
            best = optimize_placement(machine, ph.workload, seed=seed).placement
            cands = [tuple(int(v) for v in best)]
            cands += [
                tuple(int(v) for v in s)
                for s in _heuristic_seeds(machine, n)
            ]
            cands = cands[:per_phase]
        for c in cands:
            pool.setdefault(c, None)
    return list(pool)


class _State(NamedTuple):
    placement_idx: int
    banks: tuple[int, ...] | None
    work: float
    history: tuple  # ((placement_idx, banks, stall, mt, mp), ...) per phase


def optimize_schedule(
    machine: MachineSpec,
    phased: PhasedWorkload,
    *,
    model: MigrationModel | None = None,
    candidates_per_phase: int = 8,
    beam_width: int = 24,
    allow_page_placement: bool = True,
    sweep_limit: int = 20_000,
    seed: int = 0,
) -> ScheduleSearchResult:
    """Search per-phase placements jointly against the migration model.

    Two-stage: (1) build a shared candidate placement pool (per-phase
    top-k through the grouped solver, unioned across phases); (2) exact
    DP over phase boundaries on that pool, beam-pruned to ``beam_width``
    states per phase.  At every boundary each (state, next-placement)
    pair is expanded two ways: *migrate pages* (next phase runs
    node-local, pays thread + page bytes) and — when
    ``allow_page_placement`` — *leave pages behind*
    (:func:`follow_banks`: next phase pays remote Local traffic instead
    of the copy).  Rates for non-local bank states are scored lazily and
    memoized, so the exact solver runs once per distinct
    ``(phase, placement, banks)`` actually reached.

    The returned ``static`` schedule holds the pool's best fixed
    placement for the whole horizon — the one-shot advisor's answer —
    and ``gain_pct`` is the scheduler's improvement over it.  Since the
    constant trajectory is always in the DP's feasible set, ``gain_pct``
    is never negative.
    """
    phased.validate()
    model = model or MigrationModel()
    t0 = time.perf_counter()
    n = phased.n_threads
    P = len(phased.phases)
    pool = _candidate_pool(
        machine, phased, candidates_per_phase, sweep_limit, seed
    )
    pool_arr = np.asarray(pool, np.int32)

    # identity-bank rates: one batched grouped-solver call per phase
    base_rates = [
        exact_objectives(machine, ph.workload, pool_arr) for ph in phased.phases
    ]
    rate_memo: dict[tuple[int, int, tuple[int, ...]], float] = {}

    def rate_of(phase_i: int, j: int, banks) -> float:
        if banks is None:
            return float(base_rates[phase_i][j])
        key = (phase_i, j, banks)
        if key not in rate_memo:
            rate_memo[key] = _phase_rate(
                machine, phased.phases[phase_i].workload, pool[j], banks
            )
        return rate_memo[key]

    expanded = 0
    dur0 = phased.phases[0].duration
    beam = [
        _State(j, None, float(base_rates[0][j]) * dur0,
               ((j, None, 0.0, 0, 0),))
        for j in range(len(pool))
    ]
    beam.sort(key=lambda st: -st.work)
    beam = beam[: max(beam_width, 1)]
    expanded += len(pool)

    for i in range(1, P):
        dur = phased.phases[i].duration
        nxt: dict[tuple[int, tuple[int, ...] | None], _State] = {}
        for st in beam:
            for j in range(len(pool)):
                options: list[tuple[int, ...] | None] = [None]
                if allow_page_placement:
                    fb = follow_banks(
                        machine, n, pool[st.placement_idx], st.banks, pool[j]
                    )
                    if fb is not None:
                        options.append(fb)
                for banks in options:
                    stall, mt, mp = transition_cost(
                        machine, model, n,
                        pool[st.placement_idx], st.banks, pool[j], banks,
                    )
                    work = st.work + rate_of(i, j, banks) * max(
                        dur - stall, 0.0
                    )
                    expanded += 1
                    key = (j, banks)
                    if key not in nxt or work > nxt[key].work:
                        nxt[key] = _State(
                            j, banks, work,
                            st.history + ((j, banks, stall, mt, mp),),
                        )
        beam = sorted(nxt.values(), key=lambda st: -st.work)[: max(beam_width, 1)]

    best = beam[0]
    schedule = Schedule(
        placements=tuple(pool[j] for j, *_ in best.history),
        bank_assignments=tuple(b for _, b, *_ in best.history),
        total_work=best.work,
        phase_rates=tuple(
            rate_of(i, j, b) for i, (j, b, *_) in enumerate(best.history)
        ),
        transition_times=tuple(h[2] for h in best.history[1:]),
        moved_threads=tuple(h[3] for h in best.history[1:]),
        moved_pages=tuple(h[4] for h in best.history[1:]),
    )

    # best static trajectory over the same pool (no moves, no stalls).
    # float64 like the DP's python accumulation, so an identical
    # trajectory sums to the identical total and gain_pct is exactly 0.
    static_work = sum(
        np.asarray(base_rates[i], np.float64) * phased.phases[i].duration
        for i in range(P)
    )
    sj = int(np.argmax(static_work))
    static = Schedule(
        placements=(pool[sj],) * P,
        bank_assignments=(None,) * P,
        total_work=float(static_work[sj]),
        phase_rates=tuple(float(base_rates[i][sj]) for i in range(P)),
        transition_times=(0.0,) * (P - 1),
        moved_threads=(0,) * (P - 1),
        moved_pages=(0,) * (P - 1),
    )
    gain = 100.0 * (schedule.total_work - static.total_work) / max(
        static.total_work, 1e-30
    )
    return ScheduleSearchResult(
        schedule=schedule,
        static=static,
        gain_pct=gain,
        candidates=len(pool),
        states_expanded=expanded,
        elapsed_s=time.perf_counter() - t0,
    )
