"""Evaluation harness reproducing the paper's §6 methodology.

* ``evaluate_accuracy``: fit a workload's signature from the 2 profiling
  runs, then predict the bank counters of *every* other thread distribution
  and compare against (simulated) measurements — paper §6.2.2 / Figures 16–18.
* ``evaluate_stability``: fit the same workload on two machines and measure
  how much bandwidth the signature reallocates — paper §6.2.1 / Figures 13–15.

Errors are reported the paper's way: per counter measurement, as a
percentage of the run's total bandwidth.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.bwsig import (
    BandwidthSignature,
    fit_signature,
    misfit_score,
    predict_counters,
    signature_distance,
)
from repro.core.numa.benchmarks import benchmark_workload, suite_names
from repro.core.numa.machine import MachineSpec
from repro.core.numa.simulator import profile_pair, simulate
from repro.core.numa.workload import Workload


def sweep_placements(machine: MachineSpec, n_threads: int) -> Array:
    """All 2-socket thread distributions that keep one thread per core
    (paper §6.2.2: "varied the distribution of the threads between the two
    sockets maintaining a single thread per core")."""
    cores = machine.cores_per_socket
    lo = max(0, n_threads - cores)
    hi = min(cores, n_threads)
    return jnp.asarray(
        [[i, n_threads - i] for i in range(lo, hi + 1)], jnp.int32
    )


class AccuracyResult(NamedTuple):
    placements: Array  # (P, s)
    errors_read: Array  # (P, 2s) |pred-meas| as fraction of run bandwidth
    errors_write: Array  # (P, 2s)
    errors_combined: Array  # (P, 2s)
    total_bw: Array  # (P,) bytes/s moved by the run
    misfit: Array  # scalar §6.2.1 detector score
    signature: BandwidthSignature


def _direction_errors(sig_dir, placement, flows, local_meas, remote_meas):
    demand = flows.sum(axis=1)
    pred_local, pred_remote = predict_counters(sig_dir, demand, placement)
    return jnp.concatenate(
        [jnp.abs(pred_local - local_meas), jnp.abs(pred_remote - remote_meas)]
    )


def evaluate_accuracy(
    machine: MachineSpec,
    workload: Workload,
    *,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    key: Array | None = None,
) -> AccuracyResult:
    if key is None:
        key = jax.random.PRNGKey(0)
    k_prof, k_meas = jax.random.split(key)
    sym, asym = profile_pair(
        machine,
        workload,
        noise_std=noise_std,
        background_bw=background_bw,
        key=k_prof,
    )
    sig = fit_signature(sym, asym)
    sig_combined = fit_signature(sym, asym, combined=True)
    detector = misfit_score(sym, "read")

    placements = sweep_placements(machine, workload.n_threads)
    keys = jax.random.split(k_meas, placements.shape[0])

    def one(placement, k):
        res = simulate(
            machine,
            workload,
            placement,
            noise_std=noise_std,
            background_bw=background_bw,
            key=k,
        )
        total = res.read_flows.sum() + res.write_flows.sum()
        total = jnp.maximum(total, 1e-9)
        e_read = (
            _direction_errors(
                sig.read,
                placement,
                res.read_flows,
                res.sample.local_read,
                res.sample.remote_read,
            )
            / total
        )
        e_write = (
            _direction_errors(
                sig.write,
                placement,
                res.write_flows,
                res.sample.local_write,
                res.sample.remote_write,
            )
            / total
        )
        comb_flows = res.read_flows + res.write_flows
        e_comb = (
            _direction_errors(
                sig_combined.read,
                placement,
                comb_flows,
                res.sample.local_read + res.sample.local_write,
                res.sample.remote_read + res.sample.remote_write,
            )
            / total
        )
        return e_read, e_write, e_comb, total

    e_read, e_write, e_comb, totals = jax.vmap(one)(placements, keys)
    return AccuracyResult(
        placements=placements,
        errors_read=e_read,
        errors_write=e_write,
        errors_combined=e_comb,
        total_bw=totals,
        misfit=detector,
        signature=sig,
    )


class SuiteAccuracy(NamedTuple):
    names: list[str]
    per_benchmark: dict[str, AccuracyResult]
    all_errors: np.ndarray  # every counter measurement's % error
    median_error_pct: float
    p75_error_pct: float


def evaluate_suite(
    machine: MachineSpec,
    n_threads: int | None = None,
    *,
    noise_std: float = 0.0,
    include_violators: bool = True,
    seed: int = 0,
) -> SuiteAccuracy:
    """Fit + predict every suite benchmark over every placement — the
    paper's "thousands of measurements" (§6.2.2)."""
    if n_threads is None:
        n_threads = machine.cores_per_socket  # largest single-socket count
    names = suite_names(include_violators)
    key = jax.random.PRNGKey(seed)
    results: dict[str, AccuracyResult] = {}
    chunks = []
    for i, name in enumerate(names):
        wl = benchmark_workload(name, n_threads)
        res = evaluate_accuracy(
            machine, wl, noise_std=noise_std, key=jax.random.fold_in(key, i)
        )
        results[name] = res
        chunks.append(np.asarray(res.errors_combined).ravel())
    all_errors = np.concatenate(chunks) * 100.0
    return SuiteAccuracy(
        names=names,
        per_benchmark=results,
        all_errors=all_errors,
        median_error_pct=float(np.median(all_errors)),
        p75_error_pct=float(np.percentile(all_errors, 75)),
    )


class StabilityResult(NamedTuple):
    names: list[str]
    read_change: dict[str, float]
    write_change: dict[str, float]
    combined_change: dict[str, float]
    mean_combined_pct: float
    median_combined_pct: float


def evaluate_stability(
    machine_a: MachineSpec,
    machine_b: MachineSpec,
    n_threads_a: int | None = None,
    n_threads_b: int | None = None,
    *,
    noise_std: float = 0.0,
    include_violators: bool = True,
    seed: int = 0,
) -> StabilityResult:
    """Fit each benchmark on both machines; report reallocated bandwidth
    between the two signatures (paper Figures 13–15)."""
    if n_threads_a is None:
        n_threads_a = machine_a.cores_per_socket
    if n_threads_b is None:
        n_threads_b = machine_b.cores_per_socket
    names = suite_names(include_violators)
    key = jax.random.PRNGKey(seed)
    read_c, write_c, comb_c = {}, {}, {}
    for i, name in enumerate(names):
        k = jax.random.fold_in(key, i)
        ka, kb = jax.random.split(k)
        wa = benchmark_workload(name, n_threads_a)
        wb = benchmark_workload(name, n_threads_b)
        sym_a, asym_a = profile_pair(machine_a, wa, noise_std=noise_std, key=ka)
        sym_b, asym_b = profile_pair(machine_b, wb, noise_std=noise_std, key=kb)
        sig_a = fit_signature(sym_a, asym_a)
        sig_b = fit_signature(sym_b, asym_b)
        read_c[name] = float(signature_distance(sig_a.read, sig_b.read)) * 100
        write_c[name] = float(signature_distance(sig_a.write, sig_b.write)) * 100
        ca = fit_signature(sym_a, asym_a, combined=True)
        cb = fit_signature(sym_b, asym_b, combined=True)
        comb_c[name] = float(signature_distance(ca.read, cb.read)) * 100
    vals = np.asarray(list(comb_c.values()))
    return StabilityResult(
        names=names,
        read_change=read_c,
        write_change=write_c,
        combined_change=comb_c,
        mean_combined_pct=float(vals.mean()),
        median_combined_pct=float(np.median(vals)),
    )
