"""Evaluation harness reproducing the paper's §6 methodology — batched.

* ``sweep_placements`` / ``enumerate_placements``: every thread
  distribution over ``s >= 2`` sockets keeping one thread per core
  (compositions of ``n_threads``), with a deterministic subsampling budget
  for the combinatorial counts that appear at 4+ sockets.
* ``evaluate_batch``: the single jitted entry point — fit each workload's
  signature from the 2 profiling runs, then predict the bank counters of
  *every* placement and compare against (simulated) measurements, vmapped
  over placements *and* benchmarks in one trace (paper §6.2.2 at the
  paper's "thousands of measurements" scale).
* ``evaluate_accuracy`` / ``evaluate_suite``: thin routes through
  ``evaluate_batch`` (paper Figures 16–18).
* ``evaluate_stability``: fit the same workload on two machines and measure
  how much bandwidth the signature reallocates — one batched fit trace per
  machine (paper §6.2.1 / Figures 13–15).

Errors are reported the paper's way: per counter measurement, as a
percentage of the run's total bandwidth.  Fitted signatures are cached
keyed on ``(machine, workload, noise, key)`` so repeated evaluations (the
advisor's inner loop) never re-profile.
"""

from __future__ import annotations

import hashlib
import random as _pyrandom
import threading
from functools import lru_cache, partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.bwsig import (
    BandwidthSignature,
    DirectionSignature,
    fit_signature,
    misfit_score,
    predict_counters,
    signature_distance,
)
from repro.core.numa.benchmarks import benchmark_workload, suite_names
from repro.core.numa.machine import MachineSpec, canonical_bank_assignment
from repro.core.numa.simulator import (
    profile_pair,
    simulate,
    simulate_grouped_batch,
    support_patterns,
    thread_class_starts,
)
from repro.core.numa.workload import Workload

# ---------------------------------------------------------------------------
# Placement enumeration: compositions of n_threads over s sockets
# ---------------------------------------------------------------------------


@lru_cache(maxsize=256)
def _composition_table(s: int, cap: int, n: int) -> tuple[tuple[int, ...], ...]:
    """``T[k][m]``: number of compositions of ``m`` into ``k`` ordered parts
    each in ``[0, cap]`` (python ints — exact at any scale).  Cached:
    every ``count_placements`` / ``enumerate_placements`` call used to
    rebuild the full DP table (~``s * n * cap`` bigint additions) even
    for the same machine geometry; the sweep drivers hit a handful of
    ``(s, cap, n)`` keys thousands of times."""
    T = [[0] * (n + 1) for _ in range(s + 1)]
    T[0][0] = 1
    for k in range(1, s + 1):
        prev, cur = T[k - 1], T[k]
        for m in range(n + 1):
            cur[m] = sum(prev[m - j] for j in range(min(cap, m) + 1))
    return tuple(tuple(row) for row in T)


def _unrank_compositions(
    table: tuple[tuple[int, ...], ...], ranks, s: int, cap: int, n: int
) -> np.ndarray:
    """Vectorized unranking of composition ``ranks`` through the counting
    table: one numpy pass per position instead of a per-rank python loop
    over ``s * cap`` table cells.  Falls back to the exact-bigint python
    loop when any table entry overflows int64 (possible from ~20 nodes
    up — far beyond any preset; the int64 path is bit-exact below that)."""
    ranks = list(ranks)
    out = np.empty((len(ranks), s), np.int32)
    if not ranks:
        return out
    if max(max(row) for row in table) < 2**62:  # every table entry fits int64
        T = np.asarray(table, np.int64)  # (s+1, n+1)
        r = np.asarray(ranks, np.int64)
        m = np.full(r.shape, n, np.int64)
        j_grid = np.arange(cap + 1, dtype=np.int64)
        for k in range(s, 0, -1):
            idx = m[:, None] - j_grid[None, :]  # (R, cap+1)
            counts = np.where(idx >= 0, T[k - 1][np.clip(idx, 0, None)], 0)
            csum = counts.cumsum(axis=1)
            j = (csum <= r[:, None]).sum(axis=1)  # first j with r < csum[j]
            prev = np.take_along_axis(csum, np.maximum(j - 1, 0)[:, None], 1)[:, 0]
            r = r - np.where(j > 0, prev, 0)
            out[:, s - k] = j
            m = m - j
        return out
    for row, rank in enumerate(ranks):
        r, m = rank, n
        for k in range(s, 0, -1):
            for j in range(min(cap, m) + 1):
                c = table[k - 1][m - j]
                if r < c:
                    out[row, s - k] = j
                    m -= j
                    break
                r -= c
    return out


def count_placements(machine: MachineSpec, n_threads: int) -> int:
    """How many one-thread-per-core distributions of ``n_threads`` over the
    machine's NUMA nodes exist."""
    table = _composition_table(machine.n_nodes, machine.cores_per_node, n_threads)
    return table[machine.n_nodes][n_threads]


def enumerate_placements(
    machine: MachineSpec,
    n_threads: int,
    *,
    max_placements: int | None = None,
    seed: int = 0,
) -> Array:
    """All (or a deterministic sample of) thread distributions over the
    machine's NUMA nodes keeping one thread per core — the s >= 2
    generalization of the paper's §6.2.2 sweep, with per-node core caps
    (``cores_per_node``, so SNC machines never overfill a half-socket
    domain).

    Placements are emitted in lexicographic order (node-0 count
    ascending), which at ``s = 2`` is exactly the classic ``[i, n - i]``
    sweep.  When the composition count exceeds ``max_placements`` a
    uniform sample of ranks (seeded, deterministic) is drawn and unranked
    through the counting table, so huge 8-socket spaces never need to be
    materialized.

    The counting table is memoized per ``(s, cap, n)`` and unranking is
    numpy-vectorized over the whole rank batch (one pass per node
    position).  Benchmark: the full 1469-placement 4-socket enumeration
    dropped ~25x (8.5 ms -> 0.33 ms warm) and a 512-rank sample of the
    8-socket space ~10x (6.5 ms -> 0.65 ms) on the CI-class container —
    previously every sweep/advisor call rebuilt the DP table and walked
    a python loop per rank.
    """
    s, cap = machine.n_nodes, machine.cores_per_node
    if not 0 <= n_threads <= s * cap:
        raise ValueError(
            f"{n_threads} threads do not fit {s} nodes x {cap} cores"
        )
    table = _composition_table(s, cap, n_threads)
    total = table[s][n_threads]
    if max_placements is not None and total > max_placements:
        ranks: Sequence[int] = sorted(
            _pyrandom.Random(seed).sample(range(total), max_placements)
        )
    else:
        ranks = range(total)
    return jnp.asarray(_unrank_compositions(table, ranks, s, cap, n_threads))


def sweep_placements(
    machine: MachineSpec,
    n_threads: int,
    *,
    max_placements: int | None = None,
    seed: int = 0,
) -> Array:
    """All thread distributions that keep one thread per core (paper
    §6.2.2: "varied the distribution of the threads between the two
    sockets maintaining a single thread per core") — generalized to any
    NUMA-node count via :func:`enumerate_placements`."""
    return enumerate_placements(
        machine, n_threads, max_placements=max_placements, seed=seed
    )


# ---------------------------------------------------------------------------
# The batched fit + predict engine
# ---------------------------------------------------------------------------


class AccuracyResult(NamedTuple):
    """Fit-and-predict accuracy of the model on one workload: per-counter
    prediction errors over a placement sweep, as fractions of run
    bandwidth (the paper's §6.2 evaluation protocol)."""

    placements: Array  # (P, s)
    errors_read: Array  # (P, 2s) |pred-meas| as fraction of run bandwidth
    errors_write: Array  # (P, 2s)
    errors_combined: Array  # (P, 2s)
    total_bw: Array  # (P,) bytes/s moved by the run
    misfit: Array  # scalar §6.2.1 detector score
    signature: BandwidthSignature


class BatchAccuracy(NamedTuple):
    """`evaluate_batch` output: leading axis = benchmark (B), then placement."""

    placements: Array  # (P, s)
    errors_read: Array  # (B, P, 2s)
    errors_write: Array  # (B, P, 2s)
    errors_combined: Array  # (B, P, 2s)
    total_bw: Array  # (B, P)
    misfit: Array  # (B,)
    signatures: BandwidthSignature  # leaves stacked over B
    combined_signatures: BandwidthSignature  # leaves stacked over B


def _direction_errors(sig_dir, placement, flows, local_meas, remote_meas):
    demand = flows.sum(axis=1)
    pred_local, pred_remote = predict_counters(sig_dir, demand, placement)
    return jnp.concatenate(
        [jnp.abs(pred_local - local_meas), jnp.abs(pred_remote - remote_meas)]
    )


def _batched_direction_errors(
    sig_dir, pt, il, used, demand, local_meas, remote_meas
):
    """:func:`_direction_errors` for a whole placement batch at once.

    ``predict_counters`` only ever reads the diagonal and the column sums
    of the predicted ``(s, s)`` flow matrix, and every term of the §4
    placement matrix is rank-1 in the bank axis — so both counters close
    over ``(P, s)`` element-wise math without materializing a per-placement
    matrix:

        pred[i, j] = demand_i * (sf*st_j + lf*δij + pf*pt_j
                                 + inter * used_i * used_j / s_used)
        local[j]   = pred[j, j]
        remote[j]  = sum_i pred[i, j] - local[j]

    ``pt`` and ``il`` are the per-thread and interleave rows (``(P, s)``,
    shared with the simulator's slab build), ``used`` the support mask."""
    s = pt.shape[-1]
    st = (jnp.arange(s) == sig_dir.static_socket).astype(pt.dtype)  # (s,)
    inter = jnp.clip(
        1.0
        - sig_dir.static_fraction
        - sig_dir.local_fraction
        - sig_dir.per_thread_fraction,
        0.0,
        1.0,
    )
    total = demand.sum(axis=1, keepdims=True)  # (P, 1)
    total_used = (demand * used).sum(axis=1, keepdims=True)
    colw = (
        sig_dir.static_fraction * st[None, :]
        + sig_dir.per_thread_fraction * pt
        + inter * il
    )  # (P, s): the bank-axis weights shared by every used row
    local = demand * (colw + sig_dir.local_fraction)
    colsum = (
        sig_dir.static_fraction * st[None, :]
        + sig_dir.per_thread_fraction * pt
    ) * total + inter * il * total_used + sig_dir.local_fraction * demand
    remote = colsum - local
    return jnp.concatenate(
        [jnp.abs(local - local_meas), jnp.abs(remote - remote_meas)], axis=1
    )


def _workload_arrays(wl: Workload) -> tuple[Array, ...]:
    """The array fields of a Workload (everything but the name) — the jit
    boundary cannot carry the string leaf."""
    return tuple(wl[1:])


def _as_workload_list(
    workloads: Workload | Sequence[Workload],
) -> list[Workload]:
    wl_list = [workloads] if isinstance(workloads, Workload) else list(workloads)
    n_threads = {w.n_threads for w in wl_list}
    if len(n_threads) != 1:
        raise ValueError(f"workloads must share a thread count, got {n_threads}")
    return wl_list


def _memo_get(cache: dict, lock: threading.RLock, key):
    """LRU-touching lookup into an id-keyed memo cache: a hit re-inserts
    the entry at the young end (python dicts preserve insertion order), so
    hot keys survive eviction cycles.  Guarded by ``lock`` — the advisor
    service hammers these memos from concurrent threads."""
    with lock:
        hit = cache.pop(key, None)
        if hit is not None:
            cache[key] = hit
        return hit


def _memo_put(cache: dict, lock: threading.RLock, key, value, max_entries: int):
    """Bounded insert: evict oldest-first past ``max_entries`` (the memos
    used to grow per distinct object id for the life of the process under
    workloads that never repeat — the serving miss path is exactly that)."""
    with lock:
        cache[key] = value
        while len(cache) > max_entries:
            cache.pop(next(iter(cache)))


def _stack_workloads(wl_list: Sequence[Workload]) -> tuple[Array, ...]:
    """Stack each array field over a leading benchmark axis.

    Memoized on the workload objects' identities (the values keep the
    workloads alive, so ids cannot be recycled while a key is live):
    sweep/advisor loops re-evaluate the same suite hundreds of times and
    the ~40 small ``jnp.stack`` dispatches were a measurable slice of the
    per-call wall time.  LRU-bounded and lock-guarded (see
    :func:`_memo_get`): unbounded id-keyed growth and torn eviction were
    both real failure modes once the advisor service started calling this
    from many threads."""
    key = tuple(id(w) for w in wl_list)
    hit = _memo_get(_STACK_CACHE, _MEMO_LOCK, key)
    if hit is not None:
        return hit[1]
    stacked = tuple(
        jnp.stack(parts)
        for parts in zip(*(_workload_arrays(w) for w in wl_list))
    )
    _memo_put(
        _STACK_CACHE, _MEMO_LOCK, key, (tuple(wl_list), stacked),
        _MEMO_CACHE_MAX,
    )
    return stacked


_MEMO_LOCK = threading.RLock()
_MEMO_CACHE_MAX = 64
_STACK_CACHE: dict[tuple, tuple] = {}


def _support_arrays(placements: Array) -> tuple[Array, Array]:
    """Device-ready ``(support, slab_id)`` for a placement batch, memoized
    on the batch object's identity (the value keeps the batch alive) —
    the host-side ``np.unique`` bucketing is pure overhead when the same
    enumerated sweep is evaluated repeatedly.  Same LRU bound + lock as
    :func:`_stack_workloads`."""
    key = id(placements)
    hit = _memo_get(_SUPPORT_CACHE, _MEMO_LOCK, key)
    if hit is not None:
        return hit[1]
    support, slab_id = support_patterns(placements)
    value = (jnp.asarray(support), jnp.asarray(slab_id))
    _memo_put(
        _SUPPORT_CACHE, _MEMO_LOCK, key, (placements, value), _MEMO_CACHE_MAX
    )
    return value


_SUPPORT_CACHE: dict[int, tuple] = {}


def _normalize_keys(keys: Array | None, n: int) -> Array:
    """One PRNG key per workload: default PRNGKey(0), broadcast a single
    key, pass a (n, 2) stack through."""
    if keys is None:
        return jnp.stack([jax.random.PRNGKey(0)] * n)
    keys = jnp.asarray(keys)
    if keys.ndim == 1:
        keys = jnp.broadcast_to(keys, (n,) + keys.shape)
    return keys


def _fit_one(machine, arrays, prof_key, noise_std, background_bw, thread_classes):
    wl = Workload("batched", *arrays)
    sym, asym = profile_pair(
        machine,
        wl,
        noise_std=noise_std,
        background_bw=background_bw,
        key=prof_key,
        thread_classes=thread_classes,
    )
    sig = fit_signature(sym, asym)
    sig_combined = fit_signature(sym, asym, combined=True)
    detector = misfit_score(sym, "read")
    return sig, sig_combined, detector


@partial(
    jax.jit,
    static_argnames=(
        "machine", "noise_std", "background_bw", "thread_classes", "multipath",
        "bank_assignment",
    ),
)
def _evaluate_batch_jit(
    machine: MachineSpec,
    wl_arrays: tuple[Array, ...],  # leaves carry a leading benchmark axis B
    placements: Array,  # (P, s)
    support: Array,  # (n_buckets, s) support patterns (host-bucketed)
    slab_id: Array,  # (P,) bucket of each placement
    base_keys: Array,  # (B, 2)
    noise_std: float,
    background_bw: float,
    thread_classes: tuple[int, ...],
    multipath: bool = False,
    bank_assignment: tuple[int, ...] | None = None,
):
    """One trace: vmap over benchmarks of (fit, then the shared-slab
    batched solver + batched noise/error tails).  ``thread_classes`` is
    the batch's common static class refinement
    (:func:`thread_class_starts`) — the workload arrays are traced here,
    so it must ride in as a static argument to keep every inner solve on
    the group-collapsed path.  ``support`` / ``slab_id`` carry the
    host-side support bucketing into the trace
    (:func:`repro.core.numa.simulator.support_patterns`): the base +
    interleave resource slab is built once per bucket and only the traced
    multiplicities and the rank-1 per-thread update vary per placement.

    Measurement noise is drawn in three batched ``(P, ...)`` draws per
    benchmark (split of the measurement key) instead of a per-placement
    key chain — same lognormal model, one RNG pass."""
    s = machine.n_nodes

    def per_benchmark(arrays, base_key):
        k_prof, k_meas = jax.random.split(base_key)
        sig, sig_combined, detector = _fit_one(
            machine, arrays, k_prof, noise_std, background_bw, thread_classes
        )
        wl = Workload("batched", *arrays)
        sim = simulate_grouped_batch(
            machine,
            wl,
            placements,
            thread_classes=thread_classes,
            support=support,
            slab_id=slab_id,
            multipath=multipath,
            bank_assignment=bank_assignment,
        )
        read_flows, write_flows = sim.read_flows, sim.write_flows
        if noise_std > 0.0 or background_bw > 0.0:
            # the error metrics never read the (noised) instruction
            # counters, so only the two flow draws are materialized
            kr, kw = jax.random.split(k_meas)
            read_flows = read_flows * jnp.exp(
                noise_std * jax.random.normal(kr, read_flows.shape)
            ) + background_bw / (s * s)
            write_flows = write_flows * jnp.exp(
                noise_std * jax.random.normal(kw, write_flows.shape)
            ) + background_bw / (s * s)

        local_read = jnp.diagonal(read_flows, axis1=1, axis2=2)  # (P, s)
        remote_read = read_flows.sum(axis=1) - local_read
        local_write = jnp.diagonal(write_flows, axis1=1, axis2=2)
        remote_write = write_flows.sum(axis=1) - local_write
        totals = jnp.maximum(
            read_flows.sum(axis=(1, 2)) + write_flows.sum(axis=(1, 2)), 1e-9
        )

        # batched §4 prediction: the placement-matrix terms are rank-1 in
        # the bank axis, so the counter errors close over (P, s) math
        # (guards mirror bwsig's _per_thread_matrix/_interleaved_matrix)
        nf = placements.astype(jnp.float32)
        pt = nf / jnp.maximum(nf.sum(axis=1, keepdims=True), 1.0)
        used = (nf > 0).astype(jnp.float32)
        il = used / jnp.maximum(used.sum(axis=1, keepdims=True), 1.0)
        inv = 1.0 / totals[:, None]
        e_read = inv * _batched_direction_errors(
            sig.read, pt, il, used,
            read_flows.sum(axis=2), local_read, remote_read,
        )
        e_write = inv * _batched_direction_errors(
            sig.write, pt, il, used,
            write_flows.sum(axis=2), local_write, remote_write,
        )
        e_comb = inv * _batched_direction_errors(
            sig_combined.read, pt, il, used,
            read_flows.sum(axis=2) + write_flows.sum(axis=2),
            local_read + local_write, remote_read + remote_write,
        )
        return e_read, e_write, e_comb, totals, detector, sig, sig_combined

    return jax.vmap(per_benchmark)(wl_arrays, base_keys)


def evaluate_batch(
    machine: MachineSpec,
    workloads: Workload | Sequence[Workload],
    placements: Array,
    *,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    keys: Array | None = None,
    multipath: bool = False,
    bank_assignment=None,
) -> BatchAccuracy:
    """Fit + predict every workload over every placement in ONE jitted,
    doubly-vmapped trace, bucketing the placements by support pattern so
    the resource slab is built once per bucket (see
    :func:`repro.core.numa.simulator.simulate_grouped_batch`).

    ``keys`` is one PRNG key per workload (or a single key, split/shared
    exactly like :func:`evaluate_accuracy` does); defaults to
    ``PRNGKey(0)`` per workload.  Output rows stay in the caller's
    placement order — bucketing is an internal gather, not a reorder.

    ``bank_assignment`` applies one page placement to every simulated
    placement (``None`` = node-local; see
    :func:`repro.core.numa.machine.canonical_bank_assignment`).  The
    2-run profiling fit is *not* re-pointed — signatures describe the
    workload, not the placement — so cached signatures stay shared
    across bank assignments.
    """
    wl_list = _as_workload_list(workloads)
    keys = _normalize_keys(keys, len(wl_list))
    placements = jnp.asarray(placements)
    support, slab_id = _support_arrays(placements)

    stacked = _stack_workloads(wl_list)
    e_read, e_write, e_comb, totals, misfit, sigs, csigs = _evaluate_batch_jit(
        machine,
        stacked,
        placements,
        support,
        slab_id,
        keys,
        float(noise_std),
        float(background_bw),
        thread_class_starts(wl_list),
        multipath,
        canonical_bank_assignment(machine, bank_assignment),
    )
    result = BatchAccuracy(
        placements=placements,
        errors_read=e_read,
        errors_write=e_write,
        errors_combined=e_comb,
        total_bw=totals,
        misfit=misfit,
        signatures=sigs,
        combined_signatures=csigs,
    )
    # Cache under the *profiling* key each fit actually consumed (the batch
    # trace splits its base key), so `fitted_signatures` — whose keys ARE
    # profiling keys — agrees with these entries.  The writeback is skipped
    # for keys already cached and indexes the stacked trees on host (one
    # device->host pull of the small signature leaves instead of dozens of
    # per-benchmark gather dispatches): this tail used to cost more wall
    # time than the whole jitted solve on repeated sweeps.
    prof_keys = np.asarray(jax.vmap(lambda k: jax.random.split(k)[0])(keys))
    cache_keys = [
        _cache_key(machine, wl, noise_std, background_bw, prof_keys[i])
        for i, wl in enumerate(wl_list)
    ]
    missing = [i for i, ck in enumerate(cache_keys) if _cache_lookup(ck) is None]
    if missing:
        sigs_np = jax.tree.map(np.asarray, sigs)
        csigs_np = jax.tree.map(np.asarray, csigs)
        misfit_np = np.asarray(misfit)
        for i in missing:
            _cache_insert(
                cache_keys[i],
                (
                    _tree_index(sigs_np, i),
                    _tree_index(csigs_np, i),
                    misfit_np[i],
                ),
            )
    return result


def _tree_index(tree, i: int):
    return jax.tree.map(lambda x: x[i], tree)


def _accuracy_from_batch(batch: BatchAccuracy, i: int) -> AccuracyResult:
    return AccuracyResult(
        placements=batch.placements,
        errors_read=batch.errors_read[i],
        errors_write=batch.errors_write[i],
        errors_combined=batch.errors_combined[i],
        total_bw=batch.total_bw[i],
        misfit=batch.misfit[i],
        signature=_tree_index(batch.signatures, i),
    )


# ---------------------------------------------------------------------------
# Fitted-signature cache
# ---------------------------------------------------------------------------

_SIG_CACHE: dict[tuple, tuple[BandwidthSignature, BandwidthSignature, Array]] = {}
_SIG_CACHE_MAX = 4096
# One re-entrant lock serializes every _SIG_CACHE read-modify-write: the
# LRU touch (pop + re-insert) and the eviction sweep are multi-step dict
# mutations that interleave corruptly under free threading.  Fits are
# idempotent, so two threads racing on the same *miss* just both compute
# and the second insert wins — correctness never depends on the lock
# covering the (long) jitted fit itself.
_SIG_LOCK = threading.RLock()


def _workload_fingerprint(wl: Workload) -> tuple:
    digest = hashlib.blake2b(digest_size=16)
    for field in _workload_arrays(wl):
        a = np.asarray(field)
        digest.update(str(a.shape).encode())
        digest.update(str(a.dtype).encode())
        digest.update(a.tobytes())
    return (wl.name, wl.n_threads, digest.hexdigest())


def _cache_key(machine, wl, noise_std, background_bw, key) -> tuple:
    # The machine is content-addressed through its fingerprint: topology
    # tables (tuple-canonicalized from whatever array form they were built
    # with) are digested alongside the scalar fields, so two specs with
    # identical link matrices and routes share cache entries.  Per-node
    # tuple spellings of core_rate / local_*_bw digest differently from
    # their scalar equivalents, so a calibration-fitted machine never
    # collides with the preset it was fitted from.
    return (
        machine.fingerprint(),
        _workload_fingerprint(wl),
        float(noise_std),
        float(background_bw),
        np.asarray(key).tobytes(),
    )


def _evict_cache_if_full() -> None:
    """Ordered FIFO/LRU eviction: drop the *oldest* entries (python dicts
    preserve insertion order; :func:`_cache_lookup` re-inserts on hit, so
    hot keys migrate to the young end and survive eviction cycles — the
    previous behaviour of clearing the whole cache at the high-water mark
    threw away every hot signature with the cold ones)."""
    with _SIG_LOCK:
        while len(_SIG_CACHE) > _SIG_CACHE_MAX:
            _SIG_CACHE.pop(next(iter(_SIG_CACHE)))


def _cache_lookup(cache_key: tuple):
    """LRU-touching get: a hit moves the entry to the young (newest) end
    (atomically — pop + re-insert under the cache lock)."""
    with _SIG_LOCK:
        value = _SIG_CACHE.pop(cache_key, None)
        if value is not None:
            _SIG_CACHE[cache_key] = value
        return value


def _cache_insert(cache_key: tuple, value) -> None:
    """Locked insert + eviction sweep (the only way entries enter the
    signature cache)."""
    with _SIG_LOCK:
        _SIG_CACHE[cache_key] = value
        while len(_SIG_CACHE) > _SIG_CACHE_MAX:
            _SIG_CACHE.pop(next(iter(_SIG_CACHE)))


def _cache_signatures(machine, wl, noise_std, background_bw, key, value) -> None:
    _cache_insert(_cache_key(machine, wl, noise_std, background_bw, key), value)


@partial(
    jax.jit,
    static_argnames=("machine", "noise_std", "background_bw", "thread_classes"),
)
def _fit_batch_jit(
    machine, wl_arrays, prof_keys, noise_std, background_bw, thread_classes
):
    def per_benchmark(arrays, prof_key):
        return _fit_one(
            machine, arrays, prof_key, noise_std, background_bw, thread_classes
        )

    return jax.vmap(per_benchmark)(wl_arrays, prof_keys)


def fitted_signatures(
    machine: MachineSpec,
    workloads: Workload | Sequence[Workload],
    *,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    keys: Array | None = None,
) -> list[tuple[BandwidthSignature, BandwidthSignature, Array]]:
    """Cached 2-run fits: ``(signature, combined_signature, misfit)`` per
    workload.  ``keys`` are the *profiling* keys handed straight to
    ``profile_pair`` (the seed implementation's stream).  Cache key =
    (machine, workload, noise, key); misses are fitted in a single
    vmapped trace."""
    wl_list = _as_workload_list(workloads)
    keys = _normalize_keys(keys, len(wl_list))

    cache_keys = [
        _cache_key(machine, wl, noise_std, background_bw, keys[i])
        for i, wl in enumerate(wl_list)
    ]
    results = {}
    for i, ck in enumerate(cache_keys):
        hit = _cache_lookup(ck)
        if hit is not None:
            results[i] = hit
    missing = [i for i in range(len(wl_list)) if i not in results]
    if missing:
        missing_wls = [wl_list[i] for i in missing]
        stacked = _stack_workloads(missing_wls)
        sigs, csigs, mis = _fit_batch_jit(
            machine,
            stacked,
            keys[jnp.asarray(missing)],
            float(noise_std),
            float(background_bw),
            thread_class_starts(missing_wls),
        )
        for row, i in enumerate(missing):
            results[i] = (
                _tree_index(sigs, row),
                _tree_index(csigs, row),
                mis[row],
            )
            _cache_insert(cache_keys[i], results[i])
    return [results[i] for i in range(len(wl_list))]


# ---------------------------------------------------------------------------
# Paper §6 drivers
# ---------------------------------------------------------------------------


def evaluate_accuracy(
    machine: MachineSpec,
    workload: Workload,
    *,
    noise_std: float = 0.0,
    background_bw: float = 0.0,
    key: Array | None = None,
    max_placements: int | None = None,
) -> AccuracyResult:
    """Profile two placements, fit the bandwidth signature, and score its
    counter predictions against simulated measurements over the full
    placement sweep (§6.2: fit on 2 runs, predict the rest)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    placements = sweep_placements(
        machine, workload.n_threads, max_placements=max_placements
    )
    batch = evaluate_batch(
        machine,
        [workload],
        placements,
        noise_std=noise_std,
        background_bw=background_bw,
        keys=jnp.stack([key]),
    )
    return _accuracy_from_batch(batch, 0)


def _default_suite_threads(machine: MachineSpec) -> int:
    """Largest single-socket thread count, rounded down so the symmetric
    profiling run can split it evenly over the machine's NUMA nodes (a
    no-op for every ``nodes_per_socket=1`` preset)."""
    n_threads = machine.cores_per_socket
    n_threads -= n_threads % machine.n_nodes
    return n_threads or machine.n_nodes


class SuiteAccuracy(NamedTuple):
    """Suite-level accuracy rollup: per-benchmark results plus the pooled
    error distribution and its headline percentiles."""

    names: list[str]
    per_benchmark: dict[str, AccuracyResult]
    all_errors: np.ndarray  # every counter measurement's % error
    median_error_pct: float
    p75_error_pct: float


def evaluate_suite(
    machine: MachineSpec,
    n_threads: int | None = None,
    *,
    noise_std: float = 0.0,
    include_violators: bool = True,
    seed: int = 0,
    max_placements: int | None = None,
) -> SuiteAccuracy:
    """Fit + predict every suite benchmark over every placement — the
    paper's "thousands of measurements" (§6.2.2) — in a single jitted
    ``evaluate_batch`` trace (no per-benchmark retracing)."""
    if n_threads is None:
        n_threads = _default_suite_threads(machine)
    names = suite_names(include_violators)
    key = jax.random.PRNGKey(seed)
    workloads = [benchmark_workload(name, n_threads) for name in names]
    keys = jnp.stack([jax.random.fold_in(key, i) for i in range(len(names))])
    placements = sweep_placements(machine, n_threads, max_placements=max_placements)
    batch = evaluate_batch(
        machine, workloads, placements, noise_std=noise_std, keys=keys
    )
    results = {
        name: _accuracy_from_batch(batch, i) for i, name in enumerate(names)
    }
    all_errors = np.asarray(batch.errors_combined).reshape(-1) * 100.0
    return SuiteAccuracy(
        names=names,
        per_benchmark=results,
        all_errors=all_errors,
        median_error_pct=float(np.median(all_errors)),
        p75_error_pct=float(np.percentile(all_errors, 75)),
    )


class StabilityResult(NamedTuple):
    """Signature stability across machines: how much each benchmark's
    fitted signature moves when refit on a different machine (§6.3)."""

    names: list[str]
    read_change: dict[str, float]
    write_change: dict[str, float]
    combined_change: dict[str, float]
    mean_combined_pct: float
    median_combined_pct: float


def evaluate_stability(
    machine_a: MachineSpec,
    machine_b: MachineSpec,
    n_threads_a: int | None = None,
    n_threads_b: int | None = None,
    *,
    noise_std: float = 0.0,
    include_violators: bool = True,
    seed: int = 0,
) -> StabilityResult:
    """Fit each benchmark on both machines; report reallocated bandwidth
    between the two signatures (paper Figures 13–15).  Each machine's
    suite is fitted through one batched (cached) trace."""
    if n_threads_a is None:
        n_threads_a = _default_suite_threads(machine_a)
    if n_threads_b is None:
        n_threads_b = _default_suite_threads(machine_b)
    names = suite_names(include_violators)
    key = jax.random.PRNGKey(seed)
    keys_a, keys_b = [], []
    for i in range(len(names)):
        ka, kb = jax.random.split(jax.random.fold_in(key, i))
        keys_a.append(ka)
        keys_b.append(kb)
    wl_a = [benchmark_workload(name, n_threads_a) for name in names]
    wl_b = [benchmark_workload(name, n_threads_b) for name in names]
    fits_a = fitted_signatures(
        machine_a, wl_a, noise_std=noise_std, keys=jnp.stack(keys_a)
    )
    fits_b = fitted_signatures(
        machine_b, wl_b, noise_std=noise_std, keys=jnp.stack(keys_b)
    )

    read_c, write_c, comb_c = {}, {}, {}
    for name, (sig_a, csig_a, _), (sig_b, csig_b, _) in zip(
        names, fits_a, fits_b
    ):
        read_c[name] = float(signature_distance(sig_a.read, sig_b.read)) * 100
        write_c[name] = float(signature_distance(sig_a.write, sig_b.write)) * 100
        comb_c[name] = float(signature_distance(csig_a.read, csig_b.read)) * 100
    vals = np.asarray(list(comb_c.values()))
    return StabilityResult(
        names=names,
        read_change=read_c,
        write_change=write_c,
        combined_change=comb_c,
        mean_combined_pct=float(vals.mean()),
        median_combined_pct=float(np.median(vals)),
    )
