"""The paper's benchmark suite, re-expressed as simulator workloads.

Paper Table 1 draws 23 workloads from NPB, SPEC OMP, in-memory graph
analytics and database joins.  Their true memory traces are not available
here, so each is given a plausible ground-truth mix consistent with how the
paper describes the families:

* NPB solvers (BT/LU/SP/MG/CG/FT): large shared grids, partially
  partitioned per thread — per-thread heavy with interleaved halo traffic.
* EP is embarrassingly parallel — almost pure local.
* IS (integer sort) and the hash joins (NPO/PRHO/PRH/PRO/Sort join)
  shuffle data between all threads — interleaved/per-thread heavy, strong
  write components.
* SPEC OMP physics codes (Applu/Apsi/Bwaves/Equake/FMA-3D/Swim/Wupwise/MD/
  Art): master-thread-loaded inputs (a static component) plus partitioned
  working sets.  Equake performs almost exclusively reads (its write
  signature is noise — paper §6.2.1).
* Page rank (GA) violates the model: the early, well-connected chunk of
  the graph is hotter than the rest (paper Figure 16) — modeled with
  per-thread heterogeneity that the 4-class model cannot express.

The *absolute* mixes are synthetic; what the evaluation demonstrates is the
paper's pipeline — fit on 2 runs, predict every other placement, measure
error distributions, flag misfits — on a diverse population of signatures,
including low-bandwidth workloads that reproduce the paper's observation
that large errors concentrate where little data moves.
"""

from __future__ import annotations

from typing import Iterable

from repro.core.numa.workload import (
    Workload,
    mixed_workload,
    violator_workload,
)

# name -> (read_mix(static, local, per_thread), write_mix, read_bpi, write_bpi, static_socket)
_SUITE: dict[str, tuple] = {
    # NPB
    "BT": ((0.05, 0.25, 0.55), (0.02, 0.38, 0.50), 0.55, 0.28, 0),
    "CG": ((0.10, 0.10, 0.45), (0.02, 0.58, 0.30), 0.80, 0.12, 0),
    "EP": ((0.02, 0.93, 0.03), (0.00, 0.97, 0.02), 0.04, 0.02, 0),
    "FT": ((0.05, 0.05, 0.30), (0.03, 0.07, 0.30), 0.90, 0.45, 0),
    "IS": ((0.04, 0.06, 0.20), (0.02, 0.08, 0.22), 0.70, 0.60, 0),
    "LU": ((0.06, 0.30, 0.52), (0.03, 0.42, 0.45), 0.50, 0.22, 0),
    "MD": ((0.12, 0.55, 0.25), (0.03, 0.75, 0.15), 0.18, 0.05, 0),
    "MG": ((0.08, 0.15, 0.55), (0.04, 0.22, 0.52), 0.75, 0.30, 0),
    "SP": ((0.05, 0.28, 0.55), (0.02, 0.40, 0.48), 0.60, 0.25, 0),
    # SPEC OMP
    "Applu": ((0.15, 0.35, 0.40), (0.05, 0.55, 0.30), 0.45, 0.20, 0),
    "Apsi": ((0.20, 0.40, 0.30), (0.08, 0.60, 0.22), 0.25, 0.10, 0),
    "Art": ((0.30, 0.45, 0.15), (0.05, 0.80, 0.08), 0.35, 0.06, 0),
    "Bwaves": ((0.10, 0.20, 0.55), (0.04, 0.30, 0.55), 0.85, 0.35, 0),
    "Equake": ((0.18, 0.32, 0.35), (0.10, 0.45, 0.25), 0.55, 0.004, 0),
    "FMA-3D": ((0.12, 0.38, 0.35), (0.05, 0.55, 0.28), 0.40, 0.18, 0),
    "Swim": ((0.08, 0.12, 0.60), (0.04, 0.16, 0.62), 0.95, 0.50, 0),
    "Wupwise": ((0.10, 0.30, 0.45), (0.05, 0.40, 0.40), 0.50, 0.22, 0),
    # Database joins (Balkesen et al.)
    "NPO": ((0.35, 0.05, 0.45), (0.08, 0.12, 0.55), 0.65, 0.30, 0),
    "PRHO": ((0.10, 0.15, 0.30), (0.05, 0.20, 0.35), 0.70, 0.55, 0),
    "PRH": ((0.12, 0.12, 0.35), (0.06, 0.15, 0.40), 0.75, 0.58, 0),
    "PRO": ((0.10, 0.18, 0.32), (0.05, 0.22, 0.38), 0.68, 0.52, 0),
    "Sort join": ((0.08, 0.10, 0.35), (0.04, 0.12, 0.40), 0.80, 0.62, 0),
}

# Low-bandwidth workloads (bpi scaled down) that reproduce the paper's
# "errors concentrate in low-bandwidth benchmarks" observation.
_LOW_BW = {"EP", "MD", "Art", "Apsi"}


def benchmark_workload(name: str, n_threads: int) -> Workload:
    """Instantiate one suite workload for ``n_threads`` threads."""
    if name == "Page rank":
        return violator_workload("Page rank", n_threads)
    read_mix, write_mix, rbpi, wbpi, socket = _SUITE[name]
    return mixed_workload(
        name,
        n_threads,
        read_mix=read_mix,
        write_mix=write_mix,
        read_bpi=rbpi,
        write_bpi=wbpi,
        static_socket=socket,
    )


def suite_names(include_violators: bool = True) -> list[str]:
    """Names of the paper's Table 1 benchmarks (23 with the
    assumption-violating ``"Page rank"`` included, 22 without)."""
    names = list(_SUITE)
    if include_violators:
        names.append("Page rank")
    return names


def suite(n_threads: int, include_violators: bool = True) -> Iterable[Workload]:
    """Yield every Table 1 benchmark as an ``n_threads``-thread workload."""
    for name in suite_names(include_violators):
        yield benchmark_workload(name, n_threads)
