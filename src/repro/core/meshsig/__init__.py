"""Mesh-domain bandwidth signatures — the paper's technique on TPU meshes.

``hlo_counters`` is the performance-counter layer: it reads a compiled
SPMD module the way the paper reads PCM — producing per-class traffic
counters (FLOPs, HBM bytes, per-axis collective bytes, multiplied through
loop trip counts).  ``fit`` turns two profiling *compilations* into a mesh
bandwidth signature; ``advisor`` applies it to rank candidate meshes.

``device_topology`` embeds the mesh into the shared routed-graph engine
(:mod:`repro.core.graphtop`, the same core that routes NUMA machines) so
collective bytes are charged per physical link instead of against one
scalar ``ICI_BW``, and ``calibrate`` fits per-link ICI bandwidths from
measured collective times the way ``numa/calibrate.py`` fits QPI links.
"""

from repro.core.meshsig.advisor import (
    CHIP_V5E,
    CHIP_V5P,
    ChipSpec,
    MeshRanking,
    advise_schedule,
    numa_placement_bounds,
    rank_meshes,
)
from repro.core.meshsig.device_topology import (
    DeviceTopology,
    ici_torus2d,
    ici_torus3d,
    nvlink_island,
    ring_of_islands,
)
from repro.core.meshsig.hlo_counters import HloAnalysis, analyze_hlo

__all__ = [
    "CHIP_V5E",
    "CHIP_V5P",
    "ChipSpec",
    "DeviceTopology",
    "HloAnalysis",
    "MeshRanking",
    "advise_schedule",
    "analyze_hlo",
    "ici_torus2d",
    "ici_torus3d",
    "numa_placement_bounds",
    "nvlink_island",
    "rank_meshes",
    "ring_of_islands",
]
