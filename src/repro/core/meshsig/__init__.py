"""Mesh-domain bandwidth signatures — the paper's technique on TPU meshes.

``hlo_counters`` is the performance-counter layer: it reads a compiled
SPMD module the way the paper reads PCM — producing per-class traffic
counters (FLOPs, HBM bytes, per-axis collective bytes, multiplied through
loop trip counts).  ``fit`` turns two profiling *compilations* into a mesh
bandwidth signature; ``advisor`` applies it to rank candidate meshes.
"""

from repro.core.meshsig.hlo_counters import HloAnalysis, analyze_hlo

__all__ = ["HloAnalysis", "analyze_hlo"]
