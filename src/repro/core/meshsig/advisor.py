"""Placement advisor — the Pandia use-case (paper §1) in both domains.

* TPU mesh: given a fitted :class:`MeshSignature`, rank candidate mesh
  aspect ratios by predicted step time WITHOUT compiling them — the three
  roofline terms are evaluated from the signature's predicted per-axis
  link bytes, predicted local HBM traffic, and compute scaling.
* NUMA machine: given a fitted :class:`BandwidthSignature` (2 profiling
  runs), rank candidate thread placements on any s >= 2 socket machine
  WITHOUT measuring them — the batched placement-sweep engine scores
  thousands of compositions in one vmapped call
  (:func:`rank_numa_placements`).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.meshsig.device_topology import DeviceTopology
from repro.core.meshsig.fit import MeshSignature


@dataclass(frozen=True)
class ChipSpec:
    """Per-chip roofline constants.  Callers pick a preset (or build their
    own) instead of monkeypatching module globals."""

    name: str
    peak_flops: float  # bf16 FLOP/s
    hbm_bw: float  # bytes/s
    ici_bw: float  # bytes/s per ICI link (the scalar-model fallback)


CHIP_V5E = ChipSpec(name="v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)
CHIP_V5P = ChipSpec(name="v5p", peak_flops=459e12, hbm_bw=2.765e12, ici_bw=100e9)

# Back-compat module aliases (historically monkeypatched; prefer ChipSpec)
PEAK_FLOPS = CHIP_V5E.peak_flops
HBM_BW = CHIP_V5E.hbm_bw
ICI_BW = CHIP_V5E.ici_bw


@dataclass
class MeshRanking:
    axis_sizes: dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    per_axis_s: dict[str, float]

    @property
    def step_s(self) -> float:
        # collectives overlap compute at best; the bound is the max term
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)


def rank_meshes(
    sig: MeshSignature,
    candidates: list[dict[str, int]],
    *,
    chip: ChipSpec = CHIP_V5E,
    topology: DeviceTopology | None = None,
    peak_flops: float | None = None,
    hbm_bw: float | None = None,
    ici_bw: float | None = None,
) -> list[MeshRanking]:
    """Evaluate every candidate mesh; returns rankings sorted by predicted
    step time (best first).

    With a :class:`DeviceTopology` the collective term routes every axis
    ring over the physical link graph (per-directed-link charging; a
    candidate's dict order picks the row-major device embedding), so two
    candidates with identical axis sizes can rank differently by how they
    lay onto the fabric.  Without one, each axis's bytes are divided by
    the chip's scalar ``ici_bw`` — the two agree exactly on a
    fully-connected uniform-bandwidth topology.  The explicit
    ``peak_flops`` / ``hbm_bw`` / ``ici_bw`` keywords override the chip's
    values (back-compat with the old module-global interface)."""
    peak_flops = chip.peak_flops if peak_flops is None else peak_flops
    hbm_bw = chip.hbm_bw if hbm_bw is None else hbm_bw
    ici_bw = chip.ici_bw if ici_bw is None else ici_bw
    out = []
    for axes in candidates:
        b = axes.get("data", 1) * axes.get("pod", 1)
        flops = sig.flops0 * sig.batch_shards0 / b  # per-device compute
        per_axis_bytes = sig.predict_axis_bytes(axes)
        if topology is None:
            per_axis_s = {a: v / ici_bw for a, v in per_axis_bytes.items()}
        else:
            per_axis_s = topology.per_axis_times(axes, per_axis_bytes)
        out.append(
            MeshRanking(
                axis_sizes=axes,
                compute_s=flops / peak_flops,
                memory_s=sig.predict_local_bytes(axes) / hbm_bw,
                collective_s=max(per_axis_s.values(), default=0.0),
                per_axis_s=per_axis_s,
            )
        )
    return sorted(out, key=lambda r: r.step_s)


# ---------------------------------------------------------------------------
# NUMA-domain advisor: rank thread placements from a fitted signature
# ---------------------------------------------------------------------------


@dataclass
class PlacementRanking:
    """One candidate placement's predicted cost (no measurement)."""

    placement: tuple[int, ...]  # threads per NUMA node
    remote_fraction: float  # predicted fraction of traffic leaving its node
    predicted_throughput: float  # roofline bound on the sum of thread rates,
    # each thread weighted by its node's relative core rate (a full-speed
    # thread on the fastest node counts 1.0)


@partial(jax.jit, static_argnames=("machine",))
def _placement_scores(  # bpi weights stay traced: one compile per machine
    machine, sig_read, sig_write, placements, read_bpi, write_bpi
) -> tuple[Array, Array]:
    """Signature-only roofline per placement: predict the (s, s) flow
    matrices the way §4 applies a signature (demand follows thread count),
    divide by every resource capacity, and bound the achievable rate by
    the worst utilization — the NUMA analogue of the mesh advisor's
    max-term step-time bound.

    Remote utilization is hop-aware: each ordered pair is scored against
    its per-pair (hop-attenuated) path capacity, and interconnect traffic
    is charged to every *link* on the pair's static route, so placements
    that push flow across a glued machine's node controllers rank below
    ones keeping traffic inside a quad.

    Demand is per-node-rate-aware: threads on a throttled or little node
    issue (and demand bandwidth) at that node's ``core_rate``, and the
    throughput bound weighs each thread by its node's relative rate — so
    the roofline trades compute asymmetry against locality instead of
    treating all nodes as equal."""
    from repro.core.bwsig import placement_matrix

    # Per-pair remote path caps (inf diagonal), the static pair->link
    # routing incidence and the per-node issue rates; all compile-time
    # constants per machine.
    rr_caps = machine.remote_read_caps()
    ww_caps = machine.remote_write_caps()
    route_inc = jnp.asarray(machine.topology.route_incidence())  # (s*s, L)
    link_caps = machine.link_caps()
    node_rates = machine.node_rates()
    rel_rates = node_rates / node_rates.max()

    def one(p):
        n = p.astype(jnp.float32)
        # demand-weighted node shares: a node's traffic scales with its
        # thread count *and* issue rate, so the remote fraction must too
        # (for homogeneous machines rel_rates == 1 and this is n / sum(n));
        # rel-rate mass can legitimately sum below 1, so guard with an
        # epsilon rather than the integer-thread-count clamp of 1.0
        nw = n * rel_rates
        w = nw / jnp.maximum(nw.sum(), 1e-9)
        demand_r = n * node_rates * read_bpi  # unsaturated bytes/s
        demand_w = n * node_rates * write_bpi
        flows_r = demand_r[:, None] * placement_matrix(sig_read, p)
        flows_w = demand_w[:, None] * placement_matrix(sig_write, p)

        utils = [
            # per-node bank capacities (scalar local_*_bw broadcasts; mixed
            # DIMM machines carry per-node tuples)
            flows_r.sum(0) / machine.node_local_bw("read"),
            flows_w.sum(0) / machine.node_local_bw("write"),
            (flows_r / rr_caps).reshape(-1),
            (flows_w / ww_caps).reshape(-1),
        ]
        if machine.n_links:
            # diagonal (self) pairs have empty routes => all-zero incidence
            # rows, so local flows drop out of the link charge on their own
            cross = (flows_r + flows_w).reshape(-1)
            utils.append((cross @ route_inc) / link_caps)
        worst = jnp.concatenate(utils).max()
        rate = jnp.minimum(1.0, 1.0 / jnp.maximum(worst, 1e-9))
        throughput = nw.sum() * rate

        remote_r = 1.0 - (w * jnp.diagonal(placement_matrix(sig_read, p))).sum()
        remote_w = 1.0 - (w * jnp.diagonal(placement_matrix(sig_write, p))).sum()
        weight = read_bpi + write_bpi
        frac = (read_bpi * remote_r + write_bpi * remote_w) / jnp.maximum(
            weight, 1e-9
        )
        return frac, throughput

    return jax.vmap(one)(placements)


def rank_numa_placements(
    machine,
    workload,
    *,
    noise_std: float = 0.0,
    key=None,
    max_placements: int | None = None,
    top_k: int | None = None,
    placements=None,
) -> list[PlacementRanking]:
    """Rank every one-thread-per-core placement of ``workload`` over
    ``machine``'s NUMA nodes (any node count, heterogeneous core rates
    included) by predicted throughput (desc), then predicted
    remote-traffic fraction (asc).

    Profiling cost is exactly the paper's 2 runs (cached); ranking cost is
    one vmapped matrix evaluation over the candidate set — no simulation
    or measurement per candidate.  ``placements`` overrides the candidate
    set (an ``(P, s)`` array): callers that already hold an enumerated or
    sampled set — the advisor service's per-machine placement cache, a
    search warm start — rank it directly instead of re-enumerating.
    """
    from repro.core.numa.evaluate import enumerate_placements, fitted_signatures

    (sig, _, _), = fitted_signatures(
        machine, workload, noise_std=noise_std,
        keys=None if key is None else jnp.stack([key]),
    )
    if placements is None:
        placements = enumerate_placements(
            machine, workload.n_threads, max_placements=max_placements
        )
    else:
        placements = jnp.asarray(placements)
    read_bpi = float(np.asarray(workload.read_bpi).mean())
    write_bpi = float(np.asarray(workload.write_bpi).mean())
    fracs, thrs = _placement_scores(
        machine, sig.read, sig.write, placements, read_bpi, write_bpi
    )
    fracs, thrs = np.asarray(fracs), np.asarray(thrs)
    order = np.lexsort((fracs, -thrs))
    if top_k is not None:
        order = order[:top_k]
    p_np = np.asarray(placements)
    return [
        PlacementRanking(
            placement=tuple(int(v) for v in p_np[i]),
            remote_fraction=float(fracs[i]),
            predicted_throughput=float(thrs[i]),
        )
        for i in order
    ]


def advise_schedule(
    machine,
    phased,
    *,
    model=None,
    candidates_per_phase: int = 8,
    beam_width: int = 24,
    allow_page_placement: bool = True,
):
    """Schedule a phased workload: the time-axis sibling of
    :func:`rank_numa_placements`.

    Where the one-shot ranker answers "which placement for this
    signature?", this answers "which placement *per phase*, and is
    reconfiguring at each boundary worth its cost?" — delegating to
    :func:`repro.core.numa.temporal.optimize_schedule` (candidate pool
    through the grouped solver, DP/beam over phase boundaries, optional
    page-placement states).  ``phased`` is a
    :class:`~repro.core.numa.temporal.PhasedWorkload`; ``model`` a
    :class:`~repro.core.numa.temporal.MigrationModel` (``None`` = default
    byte costs, machine-derived boundary bandwidth).  Returns the full
    :class:`~repro.core.numa.temporal.ScheduleSearchResult` — schedule,
    best-static baseline, and ``gain_pct`` never below zero.
    """
    from repro.core.numa.temporal import optimize_schedule

    return optimize_schedule(
        machine,
        phased,
        model=model,
        candidates_per_phase=candidates_per_phase,
        beam_width=beam_width,
        allow_page_placement=allow_page_placement,
    )


def numa_placement_bounds(machine, workload, placements, *, thread_classes=None):
    """Admissible per-placement upper bounds on total work rate
    (instructions/s), suitable for certifying search optimality.

    The ranking score above (:func:`_placement_scores`) is a *heuristic*
    roofline: it scales every thread by the single worst resource
    utilization, which can under-estimate a placement whose threads split
    across independently-saturating resources — i.e. it is NOT an
    admissible bound and must never be used to prune a branch-and-bound
    search.  This helper delegates to the simulator-side bound
    (:func:`repro.core.numa.search.placement_upper_bound`), which caps each
    thread group by its isolated-rate resource ceilings and therefore
    always sits at or above the simulated rate.
    """
    from repro.core.numa.search import placement_upper_bound

    return placement_upper_bound(
        machine, workload, placements, thread_classes=thread_classes
    )
