"""Placement advisor — the Pandia use-case (paper §1) on a TPU mesh.

Given a fitted :class:`MeshSignature`, rank candidate mesh aspect ratios by
predicted step time WITHOUT compiling them: the three roofline terms are
evaluated from the signature's predicted per-axis link bytes, predicted
local HBM traffic, and compute scaling.  The launcher (or the straggler
hook) can then pick a mesh before paying a single extra compilation.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.meshsig.fit import MeshSignature

# TPU v5e-class chip constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link


@dataclass
class MeshRanking:
    axis_sizes: dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    per_axis_s: dict[str, float]

    @property
    def step_s(self) -> float:
        # collectives overlap compute at best; the bound is the max term
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)


def rank_meshes(
    sig: MeshSignature,
    candidates: list[dict[str, int]],
    *,
    peak_flops: float = PEAK_FLOPS,
    hbm_bw: float = HBM_BW,
    ici_bw: float = ICI_BW,
) -> list[MeshRanking]:
    """Evaluate every candidate mesh; returns rankings sorted by predicted
    step time (best first)."""
    out = []
    for axes in candidates:
        b = axes.get("data", 1) * axes.get("pod", 1)
        flops = sig.flops0 * sig.batch_shards0 / b  # per-device compute
        per_axis_bytes = sig.predict_axis_bytes(axes)
        per_axis_s = {a: v / ici_bw for a, v in per_axis_bytes.items()}
        out.append(
            MeshRanking(
                axis_sizes=axes,
                compute_s=flops / peak_flops,
                memory_s=sig.predict_local_bytes(axes) / hbm_bw,
                collective_s=max(per_axis_s.values(), default=0.0),
                per_axis_s=per_axis_s,
            )
        )
    return sorted(out, key=lambda r: r.step_s)
