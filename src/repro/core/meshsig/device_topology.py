"""Routed device meshes: accelerator interconnects as link graphs.

The scalar advisor divides per-axis collective bytes by one ``ICI_BW``
constant — correct only when every axis ring runs over dedicated,
uniform links.  Real fabrics are not like that: a 2D/3D ICI torus has
per-dimension links, an NVLink island is fully switched, and a multi-host
system glues fast islands with a much thinner host interconnect.  A
:class:`DeviceTopology` embeds the mesh into a
:class:`~repro.core.graphtop.LinkGraph` (the same engine that routes NUMA
machines) so collective link bytes are charged per *physical link* along
static routes:

* devices map to graph nodes row-major over the candidate's axis order
  (``{"data": 2, "model": 8}`` lays the model axis contiguous; swapping
  the key order transposes the embedding) — which is exactly how two
  candidates with identical axis sizes can differ: one keeps its heavy
  axis inside an island, the other strides it across the glue links;
* each axis's collective runs as a ring over its device groups: every
  member sends the signature's per-device axis link bytes to its ring
  successor, charged along the widest-shortest route;
* links are full-duplex (ICI/NVLink): each direction of an undirected
  link gets the full ``link_bw`` via the directed incidence matrix, and
  the axis time is the most-loaded directed link's ``bytes / bw``.

On a fully-connected uniform-bandwidth graph every ring step is a
dedicated one-hop link, so the axis time collapses to
``axis_bytes / link_bw`` — the scalar model exactly (the parity pin in
``tests/test_device_topology.py``).  With ``multipath=True`` the charge
splits over all equal-hop equal-bottleneck routes
(:meth:`~repro.core.graphtop.LinkGraph.directed_route_incidence`).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import numpy as np

from repro.core.graphtop import (
    LinkGraph,
    fully_connected,
    glued,
    torus2d,
    torus3d,
)


class DeviceTopology(NamedTuple):
    """A device interconnect: a routed link graph plus charging policy.

    Hashable (the graph is nested tuples), so a ``DeviceTopology`` can key
    signature caches and sit in jit-static arguments like a NUMA
    :class:`~repro.core.numa.topology.Topology` does."""

    graph: LinkGraph
    multipath: bool = False

    @property
    def n_devices(self) -> int:
        return self.graph.n_nodes

    @property
    def name(self) -> str:
        return self.graph.name

    def device_groups(self, axis_sizes: dict[str, int]) -> dict[str, list[list[int]]]:
        """Per-axis communication groups under the row-major embedding of
        ``axis_sizes`` (dict order = major-to-minor).  Group member order
        is the ring order of that axis's collectives."""
        names = list(axis_sizes)
        dims = [int(axis_sizes[a]) for a in names]
        if math.prod(dims) != self.n_devices:
            raise ValueError(
                f"axis sizes {axis_sizes} need {math.prod(dims)} devices; "
                f"topology {self.name!r} has {self.n_devices}"
            )
        strides = [1] * len(dims)
        for k in range(len(dims) - 2, -1, -1):
            strides[k] = strides[k + 1] * dims[k + 1]
        out: dict[str, list[list[int]]] = {}
        for p, axis in enumerate(names):
            groups = []
            for base in range(self.n_devices):
                if (base // strides[p]) % dims[p] != 0:
                    continue  # not the group's first member
                groups.append([base + t * strides[p] for t in range(dims[p])])
            out[axis] = groups
        return out

    def axis_pair_bytes(
        self, axis_sizes: dict[str, int], axis: str, bytes_per_device: float
    ) -> np.ndarray:
        """``(n*n,)`` ordered-pair bytes for one axis's ring collective:
        every group member sends ``bytes_per_device`` (the signature's
        per-device axis link bytes, ring passes already folded in via
        ``class_factor``) to its ring successor."""
        n = self.n_devices
        pair = np.zeros((n * n,), np.float64)
        if bytes_per_device <= 0:
            return pair
        for group in self.device_groups(axis_sizes)[axis]:
            if len(group) < 2:
                continue
            for t, d in enumerate(group):
                succ = group[(t + 1) % len(group)]
                pair[d * n + succ] += bytes_per_device
        return pair

    def per_axis_times(
        self, axis_sizes: dict[str, int], per_axis_bytes: dict[str, float]
    ) -> dict[str, float]:
        """Per-axis collective time: route every ring transfer, charge each
        directed link, take the most-loaded link's ``bytes / bw``."""
        R = np.asarray(self.graph.directed_route_incidence(multipath=self.multipath))
        slot_bw = np.repeat(np.asarray(self.graph.link_bw, np.float64), 2)
        out: dict[str, float] = {}
        for axis in axis_sizes:
            pair = self.axis_pair_bytes(
                axis_sizes, axis, per_axis_bytes.get(axis, 0.0)
            )
            loads = pair @ R  # (2L,) directed link bytes
            out[axis] = float((loads / slot_bw).max()) if loads.any() else 0.0
        return out

    def collective_time(
        self, axis_sizes: dict[str, int], per_axis_bytes: dict[str, float]
    ) -> float:
        """Step-level collective bound: the max over axes (axes overlap no
        worse than the scalar model assumes)."""
        times = self.per_axis_times(axis_sizes, per_axis_bytes)
        return max(times.values(), default=0.0)

    def link_loads(
        self, axis_sizes: dict[str, int], per_axis_bytes: dict[str, float]
    ) -> np.ndarray:
        """``(2 * n_links,)`` total directed-link bytes across all axes —
        the observable the ICI calibration fits against."""
        R = np.asarray(self.graph.directed_route_incidence(multipath=self.multipath))
        total = np.zeros((R.shape[1],), np.float64)
        for axis in axis_sizes:
            pair = self.axis_pair_bytes(
                axis_sizes, axis, per_axis_bytes.get(axis, 0.0)
            )
            total += pair @ R
        return total


# ---------------------------------------------------------------------------
# Builders — the fabrics the advisor ranks over
# ---------------------------------------------------------------------------

ICI_LINK_BW = 50e9  # v5e-class per-link ICI, bytes/s (ChipSpec.ici_bw default)
NVLINK_BW = 450e9  # switched island per-pair effective bytes/s
HOST_LINK_BW = 25e9  # inter-host (DCN/IB-class) per-link bytes/s


def ici_torus2d(rows: int, cols: int, link_bw=ICI_LINK_BW, *, multipath: bool = False) -> DeviceTopology:
    """A ``rows x cols`` ICI torus (v5e-class slice)."""
    return DeviceTopology(graph=torus2d(rows, cols, link_bw), multipath=multipath)


def ici_torus3d(x: int, y: int, z: int, link_bw=ICI_LINK_BW, *, multipath: bool = False) -> DeviceTopology:
    """An ``x * y * z`` ICI torus (v4/v5p-class cube)."""
    return DeviceTopology(graph=torus3d(x, y, z, link_bw), multipath=multipath)


def nvlink_island(n: int, link_bw=NVLINK_BW, *, multipath: bool = False) -> DeviceTopology:
    """A fully-switched island: every device pair one hop (NVLink/NVSwitch
    style) — the regime where the routed model equals the scalar one."""
    return DeviceTopology(graph=fully_connected(n, link_bw), multipath=multipath)


def ring_of_islands(
    n_islands: int,
    island_size: int,
    island_bw=NVLINK_BW,
    host_bw=HOST_LINK_BW,
    *,
    multipath: bool = False,
) -> DeviceTopology:
    """Multi-host: fully-switched islands of ``island_size`` devices, host
    ``a``'s device ``i`` linked to host ``a + 1``'s device ``i`` (and wrap
    for > 2 hosts) — the glued-socket shape of
    :func:`repro.core.graphtop.glued` wearing its accelerator hat.  Heavy
    traffic striding across islands funnels into the thin host links,
    which is exactly what the scalar ``ICI_BW`` model cannot see."""
    return DeviceTopology(
        graph=glued(
            n_islands, island_size, island_bw, host_bw, ring_islands=True
        ),
        multipath=multipath,
    )
