"""Fitting a mesh bandwidth signature from two profiling compilations.

The paper's §5 protocol, transplanted (DESIGN.md §3):

====================  =====================================================
paper                 mesh domain
====================  =====================================================
symmetric run         compile at mesh (16, 16) — axis sizes equal, so a
                      group-of-16 collective cannot be attributed to an
                      axis (the Interleaved/Per-thread ambiguity of §5.1)
asymmetric run        compile at mesh (32, 8) — group sizes now identify
                      the axis, the way unequal thread counts identify the
                      per-thread fraction in §5.5
Static class          all-gather traffic (same bytes pulled by every
                      member: FSDP weight gathers, replications)
Local class           bytes that never cross links (HBM minus collectives)
Interleaved class     all-reduce / reduce-scatter (ring-spread reduction)
Per-thread class      all-to-all + collective-permute (traffic follows
                      shard ownership: MoE dispatch, resharding)
====================  =====================================================

Each (class, axis) term carries two fit parameters: base bytes ``beta`` and
a batch-scaling exponent ``e in {0, 1}`` (weights-like traffic is
mesh-size-invariant per device; activations-like traffic scales inversely
with the number of batch shards).  Two compilations give two equations per
term — exactly identifying both, the same minimal-measurement argument the
paper makes for its 8 properties.

Prediction then gives per-axis link bytes for ANY mesh aspect without
compiling it; ``validate`` checks predictions against real compilations
(the §6.2.2 accuracy experiment, with median-% error as the metric).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

from repro.core.meshsig.hlo_counters import HloAnalysis

CLASS_OF_KIND = {
    "all-gather": "static",
    "all-reduce": "interleaved",
    "reduce-scatter": "interleaved",
    "all-to-all": "per_shard",
    "collective-permute": "per_shard",
}

# link-byte factor for one ring pass at axis size k, per class
def class_factor(cls: str, k: int) -> float:
    if k <= 1:
        return 0.0
    if cls == "interleaved":
        return 2.0 * (k - 1) / k
    return (k - 1) / k  # static (AG), per_shard (A2A); permute ~ 1 ~ (k-1)/k


@dataclass
class MeshProfile:
    """One profiling compilation's counters (the paper's CounterSample)."""

    axis_sizes: dict[str, int]  # e.g. {"data": 16, "model": 16}
    class_axis_bytes: dict[tuple[str, str], float]  # (class, axis) -> link bytes
    local_bytes: float  # HBM bytes that never cross links
    flops: float


def profile_from_analysis(
    analysis: HloAnalysis, axis_sizes: dict[str, int]
) -> MeshProfile:
    """Attribute collectives to axes by group size.  Requires distinct axis
    sizes for exact attribution (the asymmetric run); ties are split evenly
    (the symmetric run's inherent ambiguity, resolved by the fit)."""
    sizes = dict(axis_sizes)
    total_devices = math.prod(sizes.values())
    out: dict[tuple[str, str], float] = {}
    coll_bytes = 0.0
    for op in analysis.collectives:
        cls = CLASS_OF_KIND.get(op.kind)
        if cls is None or op.link_bytes <= 0:
            continue
        coll_bytes += op.link_bytes
        matches = [a for a, k in sizes.items() if k == op.group]
        if not matches and op.group >= total_devices:
            matches = list(sizes)  # global collective: spans every axis
        if not matches:
            # group spans a product of axes (e.g. 512 = pod*data*model slice)
            matches = [max(sizes, key=sizes.get)]
        share = op.link_bytes / len(matches)
        for a in matches:
            key = (cls, a)
            out[key] = out.get(key, 0.0) + share
    return MeshProfile(
        axis_sizes=sizes,
        class_axis_bytes=out,
        local_bytes=max(analysis.hbm_bytes - coll_bytes, 0.0),
        flops=analysis.flops,
    )


@dataclass
class MeshSignature:
    """Fitted signature: per (class, axis) base bytes + scaling exponent.

    ``beta`` is the full-tensor bytes behind the collective (so the
    per-axis link bytes at axis size k with b batch shards are
    ``class_factor(cls, k) * beta / b**e``).
    """

    terms: dict[tuple[str, str], tuple[float, float]]  # (cls, axis) -> (beta, e)
    local_bytes0: float  # local bytes at the reference batch-shard count
    flops0: float
    batch_shards0: int  # reference number of batch shards (data axis)

    def predict_axis_bytes(self, axis_sizes: dict[str, int]) -> dict[str, float]:
        b = axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)
        out: dict[str, float] = {a: 0.0 for a in axis_sizes}
        for (cls, axis), (beta, e) in self.terms.items():
            if axis not in axis_sizes:
                continue
            k = axis_sizes[axis]
            out[axis] += class_factor(cls, k) * beta / (b / self.batch_shards0) ** e
        return out

    def predict_local_bytes(self, axis_sizes: dict[str, int]) -> float:
        # compute-local traffic scales with per-device work (1/batch shards)
        b = axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)
        return self.local_bytes0 * self.batch_shards0 / b

    def class_fractions(self) -> dict[str, float]:
        """The paper-style signature view: fraction of traffic per class."""
        totals: dict[str, float] = {}
        for (cls, _), (beta, _) in self.terms.items():
            totals[cls] = totals.get(cls, 0.0) + beta
        totals["local"] = self.local_bytes0
        s = sum(totals.values()) or 1.0
        return {k: v / s for k, v in totals.items()}


def fit_mesh_signature(sym: MeshProfile, asym: MeshProfile) -> MeshSignature:
    """The 2-compilation fit.

    The asymmetric profile attributes axes exactly; the symmetric profile
    supplies the second equation per term that identifies the batch-scaling
    exponent ``e`` (model selection over {0, 1}, then beta re-fit) — the
    mesh analogue of §5.4/§5.5's rearrangements.
    """
    b_sym = sym.axis_sizes.get("data", 1) * sym.axis_sizes.get("pod", 1)
    b_asym = asym.axis_sizes.get("data", 1) * asym.axis_sizes.get("pod", 1)

    terms: dict[tuple[str, str], tuple[float, float]] = {}
    keys = set(asym.class_axis_bytes) | set(sym.class_axis_bytes)
    for cls, axis in keys:
        k_asym = asym.axis_sizes.get(axis, 1)
        k_sym = sym.axis_sizes.get(axis, 1)
        y_asym = asym.class_axis_bytes.get((cls, axis), 0.0)
        y_sym = sym.class_axis_bytes.get((cls, axis), 0.0)
        f_asym = class_factor(cls, k_asym)
        f_sym = class_factor(cls, k_sym)
        if f_asym <= 0 or y_asym <= 0:
            continue
        beta_asym = y_asym / f_asym  # base bytes implied by the asym run
        if y_sym > 0 and f_sym > 0 and b_sym != b_asym:
            beta_sym = y_sym / f_sym
            # choose the exponent that best reconciles the two runs
            best_e, best_err = 0.0, float("inf")
            for e in (0.0, 1.0):
                pred_sym = beta_asym * (b_asym / b_sym) ** e
                err = abs(math.log(max(pred_sym, 1e-30) / max(beta_sym, 1e-30)))
                if err < best_err:
                    best_e, best_err = e, err
            # re-fit beta at the symmetric reference (geometric mean)
            beta0 = math.sqrt(
                beta_sym * beta_asym * (b_asym / b_sym) ** best_e
            )
            terms[(cls, axis)] = (beta0, best_e)
        else:
            terms[(cls, axis)] = (beta_asym, 0.0)
    return MeshSignature(
        terms=terms,
        local_bytes0=sym.local_bytes,
        flops0=sym.flops,
        batch_shards0=b_sym,
    )
