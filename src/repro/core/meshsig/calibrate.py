"""ICI link-bandwidth calibration — ``numa/calibrate.py``'s inverse
problem, mesh domain.

A :class:`~repro.core.meshsig.device_topology.DeviceTopology` gives the
advisor a routed forward model ``t = max_l bytes_l / bw_l`` (most-loaded
directed link).  This module recovers the per-link bandwidths from
measured collective times, reusing the NUMA calibrator's recipe on the
shared graph engine:

1. **Probe design** (:func:`probe_suite`) — one collective-permute per
   directed link between adjacent devices (a 1-hop route charges exactly
   that link, so its time *is* ``bytes / bw``: the mesh analogue of the
   per-pair static probes), plus ring probes over whole axis groups that
   exercise the fabric the way real steps do (multi-link max; these make
   the refinement stage sensitive to links the pair probes under-drive in
   a noisy trace).
2. **Closed-form seeding** (:func:`seed_link_bw`) — every sample lower-
   bounds each charged link's capacity by ``bytes_l / t``; the permute
   probes make the bound an equality, so on clean data the seed alone
   round-trips.
3. **AdamW refinement in log space** (:func:`fit_device_topology`) — the
   :class:`~repro.core.graphtop.LinkGroups` packing ties symmetric links
   (all row links of a torus are one hardware class), and a jitted
   ``lax.scan`` of ``value_and_grad`` steps minimizes squared relative
   time error through the (subdifferentiable) max — the same
   ``repro.optim.adamw`` stage ``numa/calibrate._fit_jit`` runs over the
   NUMA simulator.

The fitted graph is rebuilt with :func:`repro.core.graphtop.from_fit`
(routes held static — only capacities are free parameters), exactly the
contract the NUMA side fits under.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.graphtop import LinkGroups, from_fit, link_groups
from repro.core.meshsig.device_topology import DeviceTopology
from repro.optim import adamw

_EPS = 1e-9


class CollectiveSamples(NamedTuple):
    """A calibration sweep: ``P`` measured collective runs.

    ``charges[p]`` is the known per-directed-link byte vector of run ``p``
    (slot ``2l`` = link ``l`` low->high, ``2l + 1`` reverse — computed
    from the run's collective schedule by
    :meth:`DeviceTopology.link_loads`, NOT measured); ``times[p]`` is the
    measured wall time of the run's collective phase."""

    charges: Array  # (P, 2 * n_links) float32
    times: Array  # (P,) float32 seconds

    @property
    def n_samples(self) -> int:
        return int(self.charges.shape[0])


class MeshCalibrationResult(NamedTuple):
    topology: DeviceTopology  # fitted (concrete, validated graph)
    link_bw: np.ndarray  # (n_links,) fitted bytes/s
    groups: LinkGroups
    loss_history: np.ndarray  # (steps,)
    seed_loss: float
    final_loss: float


# ---------------------------------------------------------------------------
# Probe design + synthetic collection
# ---------------------------------------------------------------------------


def probe_suite(
    template: DeviceTopology,
    *,
    probe_bytes: float = 1e9,
    axis_sizes_list: Sequence[dict[str, int]] = (),
) -> np.ndarray:
    """``(P, 2L)`` charge vectors of the designed sweep.

    Per-directed-link permute probes identify every link exactly; the
    optional axis-ring probes (one per candidate in ``axis_sizes_list``,
    charging ``probe_bytes`` per device on every axis) add realistic
    multi-link samples."""
    L = template.graph.n_links
    rows: list[np.ndarray] = []
    for slot in range(2 * L):
        v = np.zeros((2 * L,), np.float64)
        v[slot] = probe_bytes
        rows.append(v)
    for axes in axis_sizes_list:
        rows.append(
            template.link_loads(axes, {a: probe_bytes for a in axes})
        )
    return np.stack(rows)


def collect_samples(
    truth: DeviceTopology,
    charges: np.ndarray,
    *,
    noise_std: float = 0.0,
    key: Array | None = None,
) -> CollectiveSamples:
    """Run a charge sweep through the forward model of a ground-truth
    topology (the synthetic round-trip path; real traces package measured
    times with the same schedule-derived charges instead)."""
    charges = np.asarray(charges, np.float64)
    slot_bw = np.repeat(np.asarray(truth.graph.link_bw, np.float64), 2)
    times = (charges / slot_bw).max(axis=1)
    if noise_std > 0.0:
        if key is None:
            key = jax.random.PRNGKey(0)
        noise = np.asarray(jax.random.normal(key, (len(times),)))
        times = times * np.clip(1.0 + noise_std * noise, 0.05, None)
    return CollectiveSamples(
        charges=jnp.asarray(charges, jnp.float32),
        times=jnp.asarray(times, jnp.float32),
    )


# ---------------------------------------------------------------------------
# Stage 1: closed-form seeding
# ---------------------------------------------------------------------------


def seed_link_bw(template: DeviceTopology, samples: CollectiveSamples) -> np.ndarray:
    """``(n_links,)`` seeds: ``t >= bytes_l / bw_l`` for every charged
    link, so ``bytes_l / t`` lower-bounds ``bw_l``; the permute probes
    make the best bound tight.  Links no sample drives are floored at the
    template's value (nothing observed — keep the prior)."""
    charges = np.asarray(samples.charges, np.float64)  # (P, 2L)
    times = np.asarray(samples.times, np.float64)[:, None]
    bounds = charges / np.maximum(times, _EPS)  # (P, 2L)
    per_slot = bounds.max(axis=0)
    per_link = np.maximum(per_slot[0::2], per_slot[1::2])
    prior = np.asarray(template.graph.link_bw, np.float64)
    return np.where(per_link > 0.0, per_link, prior)


# ---------------------------------------------------------------------------
# Stage 2: AdamW refinement through the max-link forward model
# ---------------------------------------------------------------------------


def _time_loss(groups: LinkGroups, samples: CollectiveSamples, log_bw: Array) -> Array:
    link_bw = groups.unpack(jnp.exp(log_bw))  # (L,)
    slot_bw = jnp.repeat(link_bw, 2)  # (2L,)
    pred = (samples.charges / slot_bw).max(axis=1)  # (P,)
    rel = (pred - samples.times) / jnp.maximum(samples.times, _EPS)
    return (rel**2).mean()


@partial(jax.jit, static_argnames=("groups", "steps", "lr"))
def _fit_jit(groups, samples, log_bw, steps, lr):
    schedule = adamw.cosine_schedule(
        lr, warmup_steps=min(20, max(steps // 10, 1)), total_steps=steps
    )
    state = adamw.init({"log_bw": log_bw})

    def step_fn(carry, _):
        p, st = carry
        loss, grads = jax.value_and_grad(
            lambda q: _time_loss(groups, samples, q["log_bw"])
        )(p)
        new_p, new_st = adamw.update(
            grads, st, p, lr=schedule(st.step), weight_decay=0.0
        )
        return (new_p, new_st), loss

    (final, _), history = jax.lax.scan(
        step_fn, ({"log_bw": log_bw}, state), None, length=steps
    )
    final_loss = _time_loss(groups, samples, final["log_bw"])
    return final["log_bw"], history, final_loss


def fit_device_topology(
    template: DeviceTopology,
    samples: CollectiveSamples,
    *,
    tie_equal_bw: bool = False,
    groups: LinkGroups | None = None,
    steps: int = 200,
    lr: float = 0.05,
    name: str | None = None,
) -> MeshCalibrationResult:
    """Fit per-link ICI bandwidths from a collective sweep.

    ``template`` supplies structure only (link list + routes + charging
    policy); its bandwidth values seed un-driven links but are otherwise
    not consulted.  ``tie_equal_bw`` shares one parameter across links the
    template marks as the same class (a torus axis, the glue links of a
    multi-host ring) — see :func:`repro.core.graphtop.link_groups`."""
    if samples.charges.shape[1] != 2 * template.graph.n_links:
        raise ValueError(
            f"samples charge {samples.charges.shape[1]} directed slots; "
            f"template has {2 * template.graph.n_links}"
        )
    if groups is None:
        groups = link_groups(template.graph, tie_equal_bw=tie_equal_bw)
    seed = seed_link_bw(template, samples)
    log_bw = jnp.log(jnp.asarray(groups.pack(seed), jnp.float32))
    seed_loss = float(_time_loss(groups, samples, log_bw))
    fitted_log, history, final_loss = _fit_jit(
        groups, samples, log_bw, int(steps), float(lr)
    )
    link_bw = np.asarray(
        groups.unpack(np.exp(np.asarray(fitted_log, np.float64)))
    )
    graph = from_fit(
        template.graph, link_bw,
        name=name or f"{template.graph.name}-fit",
    )
    return MeshCalibrationResult(
        topology=DeviceTopology(graph=graph, multipath=template.multipath),
        link_bw=link_bw,
        groups=groups,
        loss_history=np.asarray(history),
        seed_loss=seed_loss,
        final_loss=float(final_loss),
    )


def fit_from_synthetic(
    truth: DeviceTopology,
    template: DeviceTopology | None = None,
    *,
    probe_bytes: float = 1e9,
    axis_sizes_list: Sequence[dict[str, int]] = (),
    noise_std: float = 0.0,
    key: Array | None = None,
    **fit_kwargs,
) -> MeshCalibrationResult:
    """The synthetic round trip: sweep ``truth`` through the forward
    model, then fit blind from a structure-only template (the truth's
    graph with uniform placeholder bandwidths)."""
    charges = probe_suite(
        truth, probe_bytes=probe_bytes, axis_sizes_list=axis_sizes_list
    )
    samples = collect_samples(truth, charges, noise_std=noise_std, key=key)
    if template is None:
        mean_bw = float(np.mean(truth.graph.link_bw))
        blind = from_fit(
            truth.graph,
            np.full((truth.graph.n_links,), mean_bw),
            name=f"{truth.graph.name}-blind",
        )
        template = DeviceTopology(graph=blind, multipath=truth.multipath)
    return fit_device_topology(template, samples, **fit_kwargs)


def link_relative_errors(
    fitted: DeviceTopology, reference: DeviceTopology
) -> np.ndarray:
    """``(n_links,)`` relative error of fitted link bandwidths against a
    reference topology with the same link list."""
    if fitted.graph.link_ends != reference.graph.link_ends:
        raise ValueError("topologies disagree on the link list")
    fit = np.asarray(fitted.graph.link_bw, np.float64)
    ref = np.asarray(reference.graph.link_bw, np.float64)
    return np.abs(fit - ref) / ref
