"""Performance counters for compiled SPMD modules.

The paper reads PCM counters at the memory banks (§2.1); on a TPU mesh the
equivalent observability point is the compiled HLO module.  This module
parses post-partitioning HLO text and produces, with **loop trip counts
multiplied through** (XLA's own ``cost_analysis`` counts while bodies only
once — measured and worked around here):

* ``flops`` — dot-product FLOPs (matmul-dominated models; elementwise ops
  are ignored just as the MXU roofline ignores them);
* ``hbm_bytes`` — Σ over top-level ops of (operand + result bytes): fusion
  internals stay on-chip, so top-level operands/results approximate HBM
  traffic;
* ``collectives`` — every all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute with its result bytes, replica-group
  size, estimated per-device link bytes, and execution count.

The paper's "lessons learned" (§2.1.1) transfer directly: we do not try to
attribute physical ICI hops (the QPI lesson — routing is opaque and noisy);
we count bytes at the collective boundary, which is the bank-perspective
view.  And we count *executed* work via trip counts rather than trusting a
rate-style summary (the IPC lesson).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_CALLEE_RES = [
    re.compile(r"body=%?([\w.\-]+)"),
    re.compile(r"condition=%?([\w.\-]+)"),
    re.compile(r"calls=%?([\w.\-]+)"),
    re.compile(r"to_apply=%?([\w.\-]+)"),
]
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALL_RE = re.compile(r"\b([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVE_KINDS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "copy-start", "copy-done", "partition-id",
    "replica-id", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "reshape",
}

# Ops a TPU fusion pass melts into neighbors: counted as zero HBM traffic
# in the fusion-idealized byte model (the raw Sum(op boundaries) figure is
# kept separately as an upper bound — CPU-compiled modules fuse far less
# than the TPU pipeline would).
_ELEMENTWISE_FREE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "power", "convert", "compare",
    "select", "and", "or", "not", "xor", "broadcast", "clamp", "sign",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "is-finite",
    "while", "conditional", "call", "custom-call", "optimization-barrier",
    "rng", "rng-bit-generator", "pad", "reverse", "concatenate",
}

# Slice-like ops physically touch the slice, not the whole buffer.
_SLICE_OPS = {"dynamic-slice", "slice"}
_UPDATE_OPS = {"dynamic-update-slice"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> list[list[int]]:
    out = []
    for _, dims in _SHAPE_RE.findall(type_str):
        out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    line: str


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    callees: list[tuple[str, float]] = field(default_factory=list)  # (name, mult)


@dataclass
class CollectiveOp:
    kind: str
    bytes: float  # result bytes x executions
    group: int
    count: float  # executions (trip-multiplied)
    link_bytes: float  # per-device link traffic estimate


@dataclass
class HloAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0  # fusion-idealized model (TPU-like fusion)
    hbm_bytes_raw: float = 0.0  # every top-level op boundary (upper bound)
    collectives: list[CollectiveOp] = field(default_factory=list)
    n_computations: int = 0
    unknown_trip_loops: int = 0

    def collective_summary(self) -> dict:
        per_kind: dict[str, dict] = {}
        total_link = 0.0
        for c in self.collectives:
            s = per_kind.setdefault(
                c.kind, {"count": 0.0, "bytes": 0.0, "link_bytes": 0.0}
            )
            s["count"] += c.count
            s["bytes"] += c.bytes
            s["link_bytes"] += c.link_bytes
            total_link += c.link_bytes
        return {"per_kind": per_kind, "link_bytes_total": total_link}


def _parse_computations(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    current: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if current is None:
            if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
                header = stripped
                is_entry = header.startswith("ENTRY")
                m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", header)
                if not m:
                    continue
                current = Computation(name=m.group(1))
                if is_entry:
                    entry = current.name
            continue
        if stripped == "}" or stripped.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        name, rest = om.group(1), om.group(2)
        cm = _CALL_RE.search(rest)
        kind = cm.group(1) if cm else ""
        result_type = rest[: cm.start()].strip() if cm else ""
        op = Op(name=name, kind=kind, result_type=result_type, line=stripped)
        current.ops.append(op)
        if kind == "while":
            tm = _TRIP_RE.search(stripped)
            trip = float(tm.group(1)) if tm else -1.0
            for cr in _CALLEE_RES[:2]:
                c = cr.search(stripped)
                if c:
                    current.callees.append((c.group(1), trip))
        else:
            for cr in _CALLEE_RES[2:]:
                c = cr.search(stripped)
                if c:
                    current.callees.append((c.group(1), 1.0))
            bm = _BRANCH_RE.search(stripped)
            if bm:
                for b in bm.group(1).split(","):
                    current.callees.append((b.strip().lstrip("%"), 1.0))
    return comps, entry


def _multipliers(comps: dict[str, Computation], entry: str) -> tuple[dict[str, float], int]:
    mult: dict[str, float] = {entry: 1.0}
    unknown = 0
    frontier = [entry]
    while frontier:
        name = frontier.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult[name]
        for callee, factor in comp.callees:
            f = factor
            if f < 0:
                unknown += 1
                f = 1.0
            new = m * f
            if mult.get(callee, 0.0) < new:
                mult[callee] = new
                frontier.append(callee)
    return mult, unknown


def _dot_flops(op: Op, type_of: dict[str, str]) -> float:
    """FLOPs for a dot: 2 * prod(result dims) * prod(contracting dims)."""
    res = _shape_elems(op.result_type)
    if not res:
        return 0.0
    result_elems = math.prod(res[0]) if res[0] else 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    operands = _OPERAND_RE.findall(op.line.split("(", 1)[1])
    contract = 1
    if cm and operands:
        lhs_type = type_of.get(operands[0], "")
        lhs_dims = _shape_elems(lhs_type)
        if lhs_dims and cm.group(1):
            for idx in cm.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims[0]):
                    contract *= lhs_dims[0][i]
    return 2.0 * result_elems * contract


def _op_bytes(op: Op, type_of: dict[str, str]) -> float:
    """HBM traffic upper bound: operand bytes read + result bytes written."""
    total = float(_shape_bytes(op.result_type))
    paren = op.line.split("(", 1)
    if len(paren) == 2:
        # operands are %refs up to the first ')'
        args = paren[1].split(")", 1)[0]
        for ref in _OPERAND_RE.findall(args):
            total += _shape_bytes(type_of.get(ref, ""))
    return total


def _op_bytes_model(op: Op, type_of: dict[str, str]) -> float:
    """Fusion-idealized HBM traffic (the roofline memory-term source):

    * elementwise/convert/broadcast/control ops: 0 (fused on TPU),
    * slice reads / in-place slice updates: the slice, not the buffer,
    * dots / fusions / reductions / copies / collectives: operand + result
      boundaries (these genuinely materialize).
    """
    kind = op.kind
    if kind in _FREE_OPS or kind in _ELEMENTWISE_FREE or not kind:
        return 0.0
    if kind in _SLICE_OPS:
        return 2.0 * float(_shape_bytes(op.result_type))  # read + write slice
    if kind in _UPDATE_OPS:
        paren = op.line.split("(", 1)
        if len(paren) == 2:
            refs = _OPERAND_RE.findall(paren[1].split(")", 1)[0])
            if len(refs) >= 2:
                return 2.0 * float(_shape_bytes(type_of.get(refs[1], "")))
        return 0.0
    return _op_bytes(op, type_of)


def _collective_link_bytes(kind: str, result_bytes: float, group: int) -> float:
    k = max(group, 1)
    if kind == "all-reduce":
        return 2.0 * result_bytes * (k - 1) / k
    if kind == "all-gather":
        return result_bytes * (k - 1) / k  # result is the gathered size
    if kind == "reduce-scatter":
        return result_bytes * (k - 1)  # result is the shard size
    if kind == "all-to-all":
        return result_bytes * (k - 1) / k
    return result_bytes  # collective-permute


def analyze_hlo(text: str) -> HloAnalysis:
    comps, entry = _parse_computations(text)
    mult, unknown = _multipliers(comps, entry)

    analysis = HloAnalysis(n_computations=len(comps), unknown_trip_loops=unknown)
    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None:
            continue  # unreachable (dead) computation
        type_of = {op.name: op.result_type for op in comp.ops}
        for op in comp.ops:
            kind = op.kind
            if kind in ("dot", "convolution"):
                analysis.flops += m * _dot_flops(op, type_of)
            analysis.hbm_bytes += m * _op_bytes_model(op, type_of)
            if kind in _FREE_OPS or not kind:
                continue
            if kind in _COLLECTIVE_KINDS:
                base = kind.replace("-start", "")
                rb = float(_shape_bytes(op.result_type))
                group = 0
                gm = _GROUPS_LIST_RE.search(op.line)
                if gm:
                    group = len(gm.group(1).split(","))
                else:
                    im = _GROUPS_IOTA_RE.search(op.line)
                    if im:
                        group = int(im.group(2))
                analysis.collectives.append(
                    CollectiveOp(
                        kind=base,
                        bytes=rb * m,
                        group=group,
                        count=m,
                        link_bytes=_collective_link_bytes(base, rb, group) * m,
                    )
                )
            analysis.hbm_bytes_raw += m * _op_bytes(op, type_of)
    return analysis
