import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Mesh-signature validation — the paper's §6.2.2 accuracy experiment in
the mesh domain.

Profile two compilations (symmetric 16x16, asymmetric 32x8), fit the
signature, predict the per-axis collective link bytes of UNSEEN mesh
aspects, then actually compile those meshes and measure.  Errors are
reported the paper's way: |predicted - measured| as a percentage of the
run's total link traffic, plus the advisor's ranking quality.

Run as a script (needs its own process: 512 host devices):
    PYTHONPATH=src python -m repro.core.meshsig.validate --arch llama3-8b
"""

import argparse
import json
import time
from pathlib import Path

import jax

from repro.configs.base import SHAPES, get_config
from repro.core.meshsig.advisor import CHIP_V5E, ChipSpec, rank_meshes
from repro.core.meshsig.fit import (
    MeshProfile,
    MeshSignature,
    fit_mesh_signature,
    profile_from_analysis,
)
from repro.core.meshsig.hlo_counters import analyze_hlo
from repro.launch import mesh as mesh_lib

RESULTS = Path(__file__).resolve().parents[4] / "benchmarks" / "dryrun_results"

# Adaptation finding (EXPERIMENTS.md §Mesh-signature): unlike the NUMA
# domain, a *symmetric* mesh profile cannot attribute group-size-k
# collectives to an axis when both axes have size k, so BOTH profiling
# compilations are asymmetric (they play the roles of the paper's two
# runs: two placements that jointly identify every signature parameter).
FIT_MESHES = [{"data": 32, "model": 8}, {"data": 64, "model": 4}]
VAL_MESHES = [{"data": 8, "model": 32}, {"data": 4, "model": 64}, {"data": 16, "model": 16}]


def measured_axis_bytes(prof: MeshProfile) -> dict[str, float]:
    """Collapse a profile's (class, axis) link bytes to per-axis totals —
    the measured counterpart of ``sig.predict_axis_bytes``."""
    meas = {a: 0.0 for a in prof.axis_sizes}
    for (_, a), v in prof.class_axis_bytes.items():
        meas[a] += v
    return meas


def prediction_errors(
    sig: MeshSignature, axes: dict[str, int], meas: dict[str, float]
) -> dict[str, float]:
    """Per-axis |predicted - measured| as % of the run's total link
    traffic (the paper's §6.2.2 metric).  Distinct axis sizes attribute
    measurements exactly; a symmetric mesh only identifies the total."""
    pred = sig.predict_axis_bytes(axes)
    total = sum(meas.values()) or 1.0
    if len(set(axes.values())) == len(axes):
        return {a: abs(pred.get(a, 0.0) - meas[a]) / total * 100 for a in axes}
    return {"total": abs(sum(pred.values()) - total) / total * 100}


def profile_mesh(cfg, shape, axes: dict) -> tuple[MeshProfile, float]:
    from repro.launch.dryrun import lower_cell  # sets the same XLA_FLAGS

    mesh = jax.make_mesh(tuple(axes.values()), tuple(axes.keys()))
    t0 = time.time()
    with mesh_lib.cell_context(mesh, cfg, shape):
        jitted, args, _ = lower_cell(cfg, shape, mesh)
        compiled = jitted.lower(*args).compile()
    analysis = analyze_hlo(compiled.as_text())
    return profile_from_analysis(analysis, axes), time.time() - t0


def run_validation(
    arch: str = "llama3-8b",
    shape_name: str = "train_4k",
    *,
    chip: ChipSpec = CHIP_V5E,
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]

    profiles: dict[str, MeshProfile] = {}
    record: dict = {"arch": arch, "shape": shape_name, "meshes": {}}

    sym, t_sym = profile_mesh(cfg, shape, FIT_MESHES[0])
    asym, t_asym = profile_mesh(cfg, shape, FIT_MESHES[1])
    sig = fit_mesh_signature(sym, asym)
    record["fit_compile_s"] = round(t_sym + t_asym, 1)
    record["class_fractions"] = sig.class_fractions()
    record["terms"] = {
        f"{cls}/{axis}": {"beta": beta, "e": e}
        for (cls, axis), (beta, e) in sig.terms.items()
    }

    errors = []
    actual_times = {}
    for axes in VAL_MESHES:
        name = "x".join(str(v) for v in axes.values())
        try:
            prof, t = profile_mesh(cfg, shape, axes)
        except Exception as e:  # a candidate may be un-compilable; record it
            record["meshes"][name] = {"error": str(e)[:300]}
            continue
        pred = sig.predict_axis_bytes(axes)
        meas = measured_axis_bytes(prof)
        mesh_errs = prediction_errors(sig, axes, meas)
        errors.extend(mesh_errs.values())
        actual_times[name] = sum(meas.values())
        record["meshes"][name] = {
            "predicted_axis_bytes": pred,
            "measured_axis_bytes": meas,
            "error_pct_of_total": mesh_errs,
            "compile_s": round(t, 1),
        }

    errors.sort()
    record["median_error_pct"] = errors[len(errors) // 2] if errors else None
    record["max_error_pct"] = errors[-1] if errors else None

    # Advisor ranking vs measured total link bytes on the validation meshes
    rankings = rank_meshes(sig, VAL_MESHES, chip=chip)
    record["advisor_order"] = [
        "x".join(str(v) for v in r.axis_sizes.values()) for r in rankings
    ]
    record["measured_order"] = sorted(actual_times, key=actual_times.get)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()
    rec = run_validation(args.arch, args.shape)
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"meshsig_validation__{args.arch}__{args.shape}.json"
    out.write_text(json.dumps(rec, indent=1, default=str))
    print(json.dumps({k: rec[k] for k in (
        "arch", "shape", "class_fractions", "median_error_pct",
        "max_error_pct", "advisor_order", "measured_order") if k in rec},
        indent=1, default=str))


if __name__ == "__main__":
    main()
