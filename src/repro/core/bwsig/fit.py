"""Fitting a bandwidth signature from two profiling runs — paper §5.

The protocol:

1. Run the workload twice: once with a *symmetric* placement (equal thread
   counts per socket) and once with an *asymmetric* one (same total thread
   count, unequal split) — paper §5.1, Figure 7.
2. Normalize each run's bank counters by the per-thread instruction rate of
   the socket the traffic is to/from — §5.2.
3. Static socket + static fraction from the symmetric run's bank imbalance —
   §5.3.
4. Local fraction from the symmetric run's remote-access ratio — §5.4.
5. Per-thread fraction from the asymmetric run by interpolating between the
   all-per-thread and all-interleaved expectations — §5.5.

The code is written for general socket counts ``s`` but reduces *exactly* to
the paper's equations at ``s = 2`` (the case the paper's Intel counters
support directly).  For ``s > 2`` the only extra assumption is that a bank's
``remote`` counter is apportioned to the other sockets in proportion to
their thread counts (the hardware merges all remote sources into one
counter; the paper never needs to split it because with two sockets there is
only one possible source).

Everything is pure ``jnp`` and differentiable apart from the static-socket
argmax, so fits can be vmapped over large batches of counter samples.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import Array

from repro.core.bwsig.counters import CounterSample
from repro.core.bwsig.signature import BandwidthSignature, DirectionSignature

_EPS = 1e-20


class NormalizedDirection(dict):
    pass


def _per_thread_rate(sample: CounterSample) -> Array:
    """Average per-thread instruction rate per socket (paper §5.2 — the
    paper records instructions and elapsed time instead of IPC, §2.1.1)."""
    n = sample.n_per_socket.astype(jnp.float32)
    denom = jnp.maximum(n * sample.elapsed, _EPS)
    rate = sample.instructions / denom
    # An empty socket executed nothing; use rate 1 so division is a no-op
    # (its counters are zero anyway).
    return jnp.where(n > 0, rate, 1.0)


def _remote_source_weights(n_per_socket: Array) -> Array:
    """``w[j, i]``: fraction of bank ``j``'s remote counter sourced from
    socket ``i``.  Exact (=1 on the single other socket) for s == 2."""
    n = n_per_socket.astype(jnp.float32)
    s = n.shape[0]
    off = 1.0 - jnp.eye(s)
    w = off * n[None, :]
    denom = jnp.maximum(w.sum(axis=1, keepdims=True), _EPS)
    return w / denom


def normalize_sample(sample: CounterSample, direction: str) -> dict[str, Array]:
    """Paper §5.2: divide each bank counter by the average per-thread
    instruction rate of the socket the traffic was to or from.

    Returns per-bank ``local`` and ``remote`` normalized traffic for one
    direction, the remote source-weight matrix, and the run's placement.
    """
    rate = _per_thread_rate(sample)
    if direction == "read":
        local, remote = sample.local_read, sample.remote_read
    elif direction == "write":
        local, remote = sample.local_write, sample.remote_write
    else:
        raise ValueError(f"unknown direction {direction!r}")

    w = _remote_source_weights(sample.n_per_socket)
    # Local traffic at bank j is from socket j's threads.
    local_n = local / jnp.maximum(rate, _EPS)
    # Remote traffic at bank j is from the other sockets; normalize each
    # attributed share by its source socket's rate and re-sum.
    shares = w * remote[:, None]  # [bank j, source i]
    remote_n = (shares / jnp.maximum(rate[None, :], _EPS)).sum(axis=1)
    return {
        "local": local_n,
        "remote": remote_n,
        "source_weights": w,
        "n_per_socket": sample.n_per_socket,
    }


# ---------------------------------------------------------------------------
# §5.3 static fraction
# ---------------------------------------------------------------------------


def fit_static(sym: dict[str, Array]) -> tuple[Array, Array]:
    """Static socket = the bank moving the most data in the symmetric run;
    static fraction = its excess over the other banks' mean, divided by the
    total (reduces to ``(b2 - b1) / (b1 + b2)`` for s = 2 — paper §5.3)."""
    totals = sym["local"] + sym["remote"]
    s = totals.shape[0]
    static_socket = jnp.argmax(totals).astype(jnp.int32)
    peak = totals[static_socket]
    others_mean = (totals.sum() - peak) / jnp.maximum(s - 1, 1)
    total = jnp.maximum(totals.sum(), _EPS)
    static_fraction = jnp.clip((peak - others_mean) / total, 0.0, 1.0)
    return static_socket, static_fraction


# ---------------------------------------------------------------------------
# §5.4 local fraction
# ---------------------------------------------------------------------------


def fit_local(
    sym: dict[str, Array], static_socket: Array, static_fraction: Array
) -> Array:
    """Paper §5.4.

    After removing the static component from the static bank (in the
    symmetric run ``1/s`` of static traffic is local to that bank, the rest
    remote), the measured remote ratio obeys

        r = (s-1)/s * (1 - local / (1 - static))

    which is rearranged for the local fraction.
    """
    local, remote = sym["local"], sym["remote"]
    s = local.shape[0]
    total = jnp.maximum((local + remote).sum(), _EPS)
    static_total = static_fraction * total

    onehot = jnp.arange(s) == static_socket
    local = jnp.where(onehot, local - static_total / s, local)
    remote = jnp.where(onehot, remote - static_total * (s - 1) / s, remote)
    local = jnp.maximum(local, 0.0)
    remote = jnp.maximum(remote, 0.0)

    r_per_bank = remote / jnp.maximum(local + remote, _EPS)
    r = r_per_bank.mean()
    frac = 1.0 - r * s / (s - 1)
    local_fraction = frac * (1.0 - static_fraction)
    return jnp.clip(local_fraction, 0.0, 1.0 - static_fraction)


# ---------------------------------------------------------------------------
# §5.5 per-thread fraction
# ---------------------------------------------------------------------------


def fit_per_thread(
    asym: dict[str, Array],
    static_socket: Array,
    static_fraction: Array,
    local_fraction: Array,
) -> Array:
    """Paper §5.5: disambiguate Per-thread from Interleaved using the
    asymmetric run."""
    local, remote = asym["local"], asym["remote"]
    w = asym["source_weights"]
    n = asym["n_per_socket"].astype(jnp.float32)
    s = local.shape[0]

    # Per-CPU demand totals: local traffic at a CPU's own bank plus its share
    # of every other bank's remote counter (for s = 2 this is exactly
    # ``reads_CPU1 = l_bank1 + r_bank2`` as in the paper).
    per_cpu = local + (w * remote[:, None]).sum(axis=0)

    # Remove the static component from the static bank's counters: remote
    # static traffic comes from the other CPUs, local static traffic from the
    # static bank's own CPU (paper's two subtraction equations).
    onehot = jnp.arange(s) == static_socket
    remote_static = static_fraction * ((1.0 - onehot) * per_cpu).sum()
    local_static = static_fraction * (onehot * per_cpu).sum()
    remote = jnp.where(onehot, remote - remote_static, remote)
    local = jnp.where(onehot, local - local_static, local)

    # Remove each CPU's thread-local traffic from its own bank.
    local = local - local_fraction * per_cpu
    local = jnp.maximum(local, 0.0)
    remote = jnp.maximum(remote, 0.0)

    # Fraction of each CPU's remaining traffic that stays on its local bank.
    remote_from_cpu = (w * remote[:, None]).sum(axis=0)
    l_measured = local / jnp.maximum(local + remote_from_cpu, _EPS)

    # Expectations if everything were Per-thread vs everything Interleaved.
    used = (n > 0).astype(jnp.float32)
    s_used = jnp.maximum(used.sum(), 1.0)
    pt_expect = n / jnp.maximum(n.sum(), _EPS)
    il_expect = used / s_used

    # Interpolate l = PT*p + IL*(1-p) and solve for p by least squares over
    # sockets (exactly the paper's rearrangement when s = 2).
    active = used * jnp.where(local + remote_from_cpu > _EPS, 1.0, 0.0)
    dx = (pt_expect - il_expect) * active
    dy = (l_measured - il_expect) * active
    p = (dx * dy).sum() / jnp.maximum((dx * dx).sum(), _EPS)
    p = jnp.clip(p, 0.0, 1.0)

    per_thread = p * (1.0 - local_fraction - static_fraction)
    return jnp.clip(per_thread, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Whole-signature drivers
# ---------------------------------------------------------------------------


def fit_direction(
    sym_sample: CounterSample, asym_sample: CounterSample, direction: str
) -> DirectionSignature:
    """Fit one direction's 4 properties from the two profiling runs."""
    sym = normalize_sample(sym_sample, direction)
    asym = normalize_sample(asym_sample, direction)
    static_socket, static_fraction = fit_static(sym)
    local_fraction = fit_local(sym, static_socket, static_fraction)
    per_thread = fit_per_thread(asym, static_socket, static_fraction, local_fraction)
    return DirectionSignature(
        static_socket=static_socket,
        static_fraction=static_fraction,
        local_fraction=local_fraction,
        per_thread_fraction=per_thread,
    )


def fit_signature(
    sym_sample: CounterSample,
    asym_sample: CounterSample,
    *,
    combined: bool = False,
) -> BandwidthSignature:
    """Fit the full 8-property signature (paper §5).

    With ``combined=True``, reads and writes are merged before fitting and
    the same direction signature is used for both slots — the fallback the
    paper applies when one direction carries too little traffic (§6.2.1).
    """
    if combined:
        sym_sample = sym_sample.combined()
        asym_sample = asym_sample.combined()
        d = fit_direction(sym_sample, asym_sample, "read")
        return BandwidthSignature(read=d, write=d)
    return BandwidthSignature(
        read=fit_direction(sym_sample, asym_sample, "read"),
        write=fit_direction(sym_sample, asym_sample, "write"),
    )
