"""Detecting workloads the model cannot represent — paper §6.2.1.

"Fortunately it is possible to detect when situations like this occur as
there is redundant information in the program counters that highlights the
inconsistency.  For example once we remove the static fraction with the
symmetric placement we expect the placement to be symmetric.  If when we
examine the local remote ratio for each socket we find that it is not
symmetric this is a sign that the application does not fit the model.  The
bigger the difference the worse the fit."
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import Array

from repro.core.bwsig.counters import CounterSample
from repro.core.bwsig.fit import fit_static, normalize_sample
from repro.core.bwsig.signature import (
    BandwidthSignature,
    DirectionSignature,
    interleaved_fraction,
)

_EPS = 1e-20


def misfit_score(sym_sample: CounterSample, direction: str = "read") -> Array:
    """Redundancy check on the *symmetric* profiling run.

    After the static component is removed, both the per-bank residual totals
    and the per-bank remote ratios must be equal across banks for any
    workload the 4-class model can represent.  The score is the combined
    normalized spread of the two; 0 = perfect fit, larger = worse.
    """
    sym = normalize_sample(sym_sample, direction)
    static_socket, static_fraction = fit_static(sym)

    local, remote = sym["local"], sym["remote"]
    s = local.shape[0]
    total = jnp.maximum((local + remote).sum(), _EPS)
    static_total = static_fraction * total
    onehot = jnp.arange(s) == static_socket
    local = jnp.maximum(jnp.where(onehot, local - static_total / s, local), 0.0)
    remote = jnp.maximum(
        jnp.where(onehot, remote - static_total * (s - 1) / s, remote), 0.0
    )

    residual_totals = local + remote
    mean_total = jnp.maximum(residual_totals.mean(), _EPS)
    total_spread = jnp.abs(residual_totals - mean_total).max() / mean_total

    r = remote / jnp.maximum(local + remote, _EPS)
    r_spread = jnp.abs(r - r.mean()).max()

    return total_spread + r_spread


def _class_vector(sig: DirectionSignature, s: int) -> Array:
    """Expand a direction signature into a distribution over traffic
    classes: one slot per possible static socket + local + per-thread +
    interleaved.  Moving the static socket therefore counts as a full
    reallocation of the static bandwidth."""
    static = (jnp.arange(s) == sig.static_socket) * sig.static_fraction
    rest = jnp.stack(
        [sig.local_fraction, sig.per_thread_fraction, interleaved_fraction(sig)]
    )
    return jnp.concatenate([static, rest])


def signature_distance(
    a: BandwidthSignature | DirectionSignature,
    b: BandwidthSignature | DirectionSignature,
    s: int = 2,
) -> Array:
    """Fraction of the bandwidth reallocated between two signatures
    (the metric of paper Figure 14) — half the L1 distance between the
    class distributions, in [0, 1]."""
    if isinstance(a, BandwidthSignature):
        assert isinstance(b, BandwidthSignature)
        return 0.5 * (
            signature_distance(a.read, b.read, s)
            + signature_distance(a.write, b.write, s)
        )
    va = _class_vector(a, s)
    vb = _class_vector(b, s)
    return 0.5 * jnp.abs(va - vb).sum()
