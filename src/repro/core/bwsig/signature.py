"""The bandwidth signature and its application to thread placements.

Implements paper §3 (the 4-class traffic taxonomy and the 8-property
signature) and §4 (applying a signature to a placement as a matrix
computation).

Conventions
-----------
* ``s`` denotes the number of sockets; placements are integer vectors
  ``n_per_socket`` of shape ``(s,)`` giving the thread count on each socket.
* All fractions live in ``[0, 1]`` and ``static + local + per_thread <= 1``;
  the remainder is the Interleaved fraction (paper §3).
* Matrices are indexed ``[cpu_socket, memory_bank]``; every row of a
  placement matrix for a socket that hosts at least one thread sums to 1
  (paper Figure 5: "every row sums to 1, but not every column").

Everything here is pure ``jnp`` so it can be ``jit``/``vmap``-ed over
thousands of candidate placements — that is exactly the use the paper puts
the model to (Pandia-style placement search).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array


class DirectionSignature(NamedTuple):
    """Signature for one traffic direction (reads or writes) — paper §3.

    ``static_socket`` is the socket index the Static class is pinned to;
    the three fractions describe the Per-thread / Local / Static classes and
    the Interleaved class is the remainder ``1 - (static + local + per_thread)``.
    """

    static_socket: Array  # int32 scalar
    static_fraction: Array  # float scalar in [0, 1]
    local_fraction: Array  # float scalar in [0, 1]
    per_thread_fraction: Array  # float scalar in [0, 1]

    @staticmethod
    def make(
        static_socket: int = 0,
        static_fraction: float = 0.0,
        local_fraction: float = 0.0,
        per_thread_fraction: float = 0.0,
    ) -> "DirectionSignature":
        return DirectionSignature(
            jnp.asarray(static_socket, jnp.int32),
            jnp.asarray(static_fraction, jnp.float64 if jax.config.x64_enabled else jnp.float32),
            jnp.asarray(local_fraction, jnp.float64 if jax.config.x64_enabled else jnp.float32),
            jnp.asarray(per_thread_fraction, jnp.float64 if jax.config.x64_enabled else jnp.float32),
        )


class BandwidthSignature(NamedTuple):
    """The full 8-property signature: separate read and write directions."""

    read: DirectionSignature
    write: DirectionSignature


def interleaved_fraction(sig: DirectionSignature) -> Array:
    """The remainder class — paper §3: "Any remaining bandwidth is deemed
    to be Interleaved"."""
    return jnp.clip(
        1.0 - sig.static_fraction - sig.local_fraction - sig.per_thread_fraction,
        0.0,
        1.0,
    )


# ---------------------------------------------------------------------------
# Paper §4 — the four per-class matrices and their weighted combination.
# ---------------------------------------------------------------------------


def _static_matrix(static_socket: Array, s: int) -> Array:
    """All traffic lands on the static bank: one-hot column (paper §4)."""
    cols = jnp.arange(s)
    return jnp.broadcast_to((cols == static_socket).astype(jnp.float32), (s, s))


def _local_matrix(s: int) -> Array:
    """Each socket talks to its own bank: the identity (paper §4)."""
    return jnp.eye(s, dtype=jnp.float32)


def _per_thread_matrix(n_per_socket: Array) -> Array:
    """Columns weighted by the fraction of threads on each socket:
    ``column_i = n_i / sum_j n_j`` (paper §4)."""
    n = n_per_socket.astype(jnp.float32)
    total = jnp.maximum(n.sum(), 1.0)
    weights = n / total
    s = n_per_socket.shape[0]
    return jnp.broadcast_to(weights[None, :], (s, s))


def _interleaved_matrix(n_per_socket: Array) -> Array:
    """Traffic spread evenly over the *used* sockets: cells where both the
    CPU and the bank belong to used sockets hold ``1/s_used`` (paper §4)."""
    used = (n_per_socket > 0).astype(jnp.float32)
    s_used = jnp.maximum(used.sum(), 1.0)
    return (used[:, None] * used[None, :]) / s_used


def placement_matrix(sig: DirectionSignature, n_per_socket: Array) -> Array:
    """Combine the four class matrices, weighted by the signature fractions.

    Returns the ``(s, s)`` row-stochastic matrix mapping a thread's socket to
    the fraction of its bandwidth predicted on each CPU->bank link — the
    matrix of paper Figure 5.
    """
    n_per_socket = jnp.asarray(n_per_socket)
    s = n_per_socket.shape[0]
    inter = interleaved_fraction(sig)
    m = (
        sig.static_fraction * _static_matrix(sig.static_socket, s)
        + sig.local_fraction * _local_matrix(s)
        + sig.per_thread_fraction * _per_thread_matrix(n_per_socket)
        + inter * _interleaved_matrix(n_per_socket)
    )
    return m


def predict_flows(
    sig: DirectionSignature,
    demand_per_socket: Array,
    n_per_socket: Array,
) -> Array:
    """Scale the placement matrix rows by per-socket bandwidth demand.

    ``demand_per_socket[i]`` is the total bytes/s the threads on socket ``i``
    want to move in this direction (computed independently of the model, as
    the paper prescribes in §4).  Returns ``flows[i, j]`` = bytes/s from the
    CPUs on socket ``i`` to memory bank ``j``.
    """
    m = placement_matrix(sig, n_per_socket)
    return demand_per_socket[:, None] * m


def predict_counters(
    sig: DirectionSignature,
    demand_per_socket: Array,
    n_per_socket: Array,
) -> tuple[Array, Array]:
    """Reduce predicted flows to the bank-perspective counters the hardware
    exposes (paper §2.1): per-bank ``local`` (from the bank's own socket) and
    ``remote`` (from every other socket) traffic."""
    flows = predict_flows(sig, demand_per_socket, n_per_socket)
    local = jnp.diagonal(flows)
    remote = flows.sum(axis=0) - local
    return local, remote
