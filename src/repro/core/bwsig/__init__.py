"""Bandwidth-signature engine — the paper's core contribution.

This package is domain independent: it consumes performance-counter samples
(:class:`CounterSample`) and produces/consumes bandwidth signatures
(:class:`BandwidthSignature`).  Two domains drive it:

* ``repro.core.numa`` — the faithful reproduction: counters come from a
  simulated NUMA machine's memory-bank monitors (paper §2.1).
* ``repro.core.meshsig`` — the TPU adaptation: counters come from compiled-HLO
  collective-byte accounting on a device mesh.
"""

from repro.core.bwsig.signature import (
    BandwidthSignature,
    DirectionSignature,
    interleaved_fraction,
    placement_matrix,
    predict_counters,
    predict_flows,
)
from repro.core.bwsig.counters import CounterSample, counters_from_flows
from repro.core.bwsig.fit import (
    fit_direction,
    fit_signature,
    normalize_sample,
)
from repro.core.bwsig.detect import misfit_score, signature_distance

__all__ = [
    "BandwidthSignature",
    "DirectionSignature",
    "CounterSample",
    "counters_from_flows",
    "interleaved_fraction",
    "placement_matrix",
    "predict_counters",
    "predict_flows",
    "fit_direction",
    "fit_signature",
    "normalize_sample",
    "misfit_score",
    "signature_distance",
]
