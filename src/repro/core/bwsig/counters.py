"""Performance-counter samples — the data the model is fitted from.

Paper §2.1: the counters of interest are, per memory bank, the volume of
data moved for the *local* socket and for *remote* sockets (reported from the
bank's perspective, not the CPU's), plus per-socket instruction counts and
the elapsed time.  :class:`CounterSample` is that record.

``counters_from_flows`` reduces a ground-truth ``(s, s)`` flow matrix (which
only a simulator — or a hypothetical perfect counter set — can see) to the
bank-perspective view real hardware exposes.  The fitting code in
``fit.py`` only ever consumes the reduced view, exactly as the paper's
method does.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import Array


class CounterSample(NamedTuple):
    """One profiling run's counter readings on an ``s``-bank machine,
    where a bank is a NUMA node (``machine.n_nodes``; on the paper's
    ``nodes_per_socket=1`` machines, a socket).

    All per-bank arrays have shape ``(s,)``; ``instructions`` is per node
    (CPU perspective — paper Figure 8 caption); ``elapsed`` is scalar
    seconds; ``n_per_socket`` records the thread placement of the run (the
    fitting equations need it; one entry per node).
    """

    local_read: Array
    remote_read: Array
    local_write: Array
    remote_write: Array
    instructions: Array
    elapsed: Array
    n_per_socket: Array

    @property
    def sockets(self) -> int:
        return self.local_read.shape[-1]

    def totals(self, direction: str) -> Array:
        """Total per-bank traffic for one direction (paper §5.3)."""
        if direction == "read":
            return self.local_read + self.remote_read
        if direction == "write":
            return self.local_write + self.remote_write
        if direction == "combined":
            return (
                self.local_read
                + self.remote_read
                + self.local_write
                + self.remote_write
            )
        raise ValueError(f"unknown direction {direction!r}")

    def combined(self) -> "CounterSample":
        """Collapse reads and writes into a single direction.

        Paper §6.2.1 evaluates a combined-bandwidth signature when one
        direction has too little traffic to give a usable signal (e.g.
        equake's writes).  The combined sample carries the summed traffic in
        the *read* slots and zeros in the write slots.
        """
        return CounterSample(
            local_read=self.local_read + self.local_write,
            remote_read=self.remote_read + self.remote_write,
            local_write=jnp.zeros_like(self.local_write),
            remote_write=jnp.zeros_like(self.remote_write),
            instructions=self.instructions,
            elapsed=self.elapsed,
            n_per_socket=self.n_per_socket,
        )


def counters_from_flows(
    read_flows: Array,
    write_flows: Array,
    instructions: Array,
    elapsed: Array,
    n_per_socket: Array,
) -> CounterSample:
    """Reduce ground-truth ``flows[i, j]`` (socket ``i`` CPUs -> bank ``j``,
    bytes) to the bank-perspective counters of paper §2.1."""
    l_read = jnp.diagonal(read_flows)
    r_read = read_flows.sum(axis=0) - l_read
    l_write = jnp.diagonal(write_flows)
    r_write = write_flows.sum(axis=0) - l_write
    return CounterSample(
        local_read=l_read,
        remote_read=r_read,
        local_write=l_write,
        remote_write=r_write,
        instructions=instructions,
        elapsed=jnp.asarray(elapsed),
        n_per_socket=jnp.asarray(n_per_socket),
    )
