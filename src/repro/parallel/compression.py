"""Gradient compression: int8 ring exchange with error feedback.

Distributed-optimization trick (DESIGN.md §7): the data-parallel gradient
reduction is the largest recurring collective in training (the paper's
*Interleaved* class — ring traffic spread evenly over the axis).  Replacing
the fp32 all-reduce with an int8 reduce-scatter + all-gather cuts its link
bytes ~4x:

    all-reduce fp32 ring:  2 * (k-1)/k * 4B per element
    int8 RS + int8 AG:     2 * (k-1)/k * 1B per element (+ scales)

Quantization is per-tensor symmetric with an **error-feedback residual**
(the caller carries it between steps), which keeps SGD convergence — the
quantization error is re-injected next step instead of being lost.

Implemented with explicit ``shard_map`` collectives so the byte reduction
is visible to the HLO counters (and to real ICI).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import Array
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.parallel import context as ctx


def _quantize(x: Array) -> tuple[Array, Array]:
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(x: Array, axis_names: tuple[str, ...]) -> Array:
    """Mean over ``axis_names`` of an fp32 tensor using int8 wire format.

    Must be called inside shard_map.  Implementation: int8 reduce-scatter
    (via all-to-all on the flattened tensor) -> local fp32 sum -> int8
    all-gather.
    """
    k = 1
    for a in axis_names:
        k *= compat.axis_size(a)
    if k == 1:
        return x
    shape = x.shape
    n = x.size
    pad = (-n) % k
    flat = jnp.pad(x.reshape(-1), (0, pad))
    chunks = flat.reshape(k, (n + pad) // k)

    q, scale = _quantize(chunks)
    # reduce-scatter: each member ends with the sum of its chunk
    axis = axis_names[0] if len(axis_names) == 1 else axis_names
    swapped = jax.lax.all_to_all(q[:, None], axis, split_axis=0, concat_axis=1)
    scales = jax.lax.all_gather(scale, axis)
    # swapped: (1, k, chunk) int8 — dequantize each peer's contribution
    parts = swapped[0].astype(jnp.float32) * scales[:, None]
    local_sum = parts.sum(axis=0)  # fp32 sum of my chunk
    q2, scale2 = _quantize(local_sum)
    gathered = jax.lax.all_gather(q2, axis)  # (k, chunk) int8
    scales2 = jax.lax.all_gather(scale2, axis)
    full = (gathered.astype(jnp.float32) * scales2[:, None]).reshape(-1)
    out = full[:n].reshape(shape)
    return out / k


def compressed_grad_mean(
    grads: Any, residual: Any | None = None
) -> tuple[Any, Any]:
    """Error-feedback compressed data-parallel gradient mean.

    ``grads`` are batch-sharded (already averaged within each shard's
    microbatch); this averages them across the data axes with int8 wire
    traffic.  Returns (mean_grads, new_residual).  With no active mesh this
    is the identity (single host).
    """
    mesh = ctx.current_mesh()
    axes = ctx.physical_axes("dp_all")
    if mesh is None or not axes:
        return grads, residual

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        def body(gb, rb):
            with_fb = gb.astype(jnp.float32) + rb
            reduced = compressed_psum_mean(with_fb, axes)
            new_r = with_fb - reduced  # local quantization error, re-injected
            return reduced.astype(gb.dtype), new_r

        spec = P()  # grads enter replicated per dp shard group
        return compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(spec, spec),
            out_specs=(spec, spec),
            check_vma=False,
        )(g, r)

    pairs = jax.tree.map(one, grads, residual)
    mean = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_res
