"""Mesh context + logical-axis sharding helpers.

Models never name physical mesh axes directly; they annotate tensors with
*logical* dims which this module maps onto whatever mesh is active:

=========  =====================================================
logical    physical axes
=========  =====================================================
"batch"    ("pod", "data") — whichever exist on the active mesh
"fsdp"     "data" (parameter sharding for ZeRO-3 style gathers)
"expert"   "model" (expert-parallel dimension)
"tp"       "model" (tensor-parallel dimension)
"seq"      "model" (sequence sharding for long-context caches)
None       replicated
=========  =====================================================

With no active mesh (unit tests, smoke tests on 1 CPU device) every helper
degrades to the identity, so model code is mesh-agnostic.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

_LOGICAL = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "dp_all": ("pod", "data"),
    "tp": ("model",),
    "expert": ("model",),
    "efsdp": ("data",),  # expert-weight FSDP dim (kept under serve remaps)
    "seq": ("model",),
    # Decode-cache dims; the launcher overrides these per (arch, shape) so
    # e.g. a global_batch=1 long-context cell can spread the sequence over
    # every mesh axis.
    "cache_batch": ("data",),
    "cache_seq": ("model",),
}


@contextlib.contextmanager
def use_logical_rules(**overrides: tuple[str, ...]):
    """Temporarily remap logical dims to different physical axes."""
    saved = {k: _LOGICAL[k] for k in overrides}
    _LOGICAL.update(overrides)
    try:
        yield
    finally:
        _LOGICAL.update(saved)


def current_mesh() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def resolve(*logical_dims: str | None) -> P:
    """Map logical dims to a PartitionSpec for the active mesh."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    names = set(mesh.axis_names)
    out = []
    for dim in logical_dims:
        if dim is None:
            out.append(None)
            continue
        axes = tuple(a for a in _LOGICAL[dim] if a in names)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def sharding(*logical_dims: str | None) -> NamedSharding | None:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(*logical_dims))


def shard(x: jax.Array, *logical_dims: str | None) -> jax.Array:
    """``with_sharding_constraint`` against the active mesh (identity when
    no mesh is active)."""
    s = sharding(*logical_dims)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def axis_size(logical: str) -> int:
    """Product of the mesh axes a logical dim maps to (1 with no mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    names = set(mesh.axis_names)
    size = 1
    for a in _LOGICAL[logical]:
        if a in names:
            size *= mesh.shape[a]
    return size


def physical_axes(logical: str) -> tuple[str, ...]:
    mesh = current_mesh()
    if mesh is None:
        return ()
    names = set(mesh.axis_names)
    return tuple(a for a in _LOGICAL[logical] if a in names)


def divisible(n: int, logical: str) -> bool:
    return n % axis_size(logical) == 0


def divisible_batch_axes(n: int) -> tuple[str, ...]:
    """The largest prefix of the batch axes whose product divides ``n``
    (empty for n=1: replicate instead of shard)."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    names = set(mesh.axis_names)
    axes: list[str] = []
    prod = 1
    for a in _LOGICAL["batch"]:
        if a in names and n % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes)
