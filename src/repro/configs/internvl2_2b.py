"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab_size=92553,
        attn_pattern="full",
        rope_theta=1_000_000.0,
        frontend="vit_patches",
        frontend_tokens=256,  # one image tile's worth of patch embeddings
        long_context_ok=False,
        notes=(
            "LM backbone only: input_specs() provides precomputed ViT patch "
            "embeddings (B, 256, d_model) prepended to the token sequence."
        ),
    )
)
