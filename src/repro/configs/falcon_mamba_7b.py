"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free [arXiv:2410.05355]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,  # attention-free, no separate FFN: mamba block IS the layer
        vocab_size=65024,
        attn_pattern="none",
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,  # d_inner = 8192
        long_context_ok=True,  # O(1)-state decode
        notes=(
            "Attention-free: the paper's attention-sharding aspects do not "
            "apply; TP shards d_inner channels (independent across the scan)."
        ),
    )
)
