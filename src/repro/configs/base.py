"""Model/config system.

One :class:`ModelConfig` covers every assigned architecture family (dense /
MoE / SSM / hybrid / enc-dec / VLM) through block-pattern fields; each
``src/repro/configs/<arch>.py`` instantiates the exact published
configuration and registers it under its ``--arch`` id.

Input shapes are the four assigned cells (train_4k / prefill_32k /
decode_32k / long_500k).  ``input_specs`` builds ShapeDtypeStruct stand-ins
for the dry-run (no allocation); the smoke tests instantiate *reduced*
configs via :meth:`ModelConfig.reduced`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shapes (assigned): seq_len x global_batch; decode/long lower serve_step.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    """A decoder-style (or enc-dec) transformer/SSM/hybrid model."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int  # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    d_head: int = 0  # derived from d_model/n_heads when 0

    # --- attention pattern ---
    attn_pattern: str = "full"  # full | swa | local_global | none
    sliding_window: int = 4_096
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10_000.0

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # MoE replaces the dense FFN every k-th layer
    capacity_factor: float = 1.25
    moe_impl: str = "gather"  # gather (x replicated over tp, psum combine)
    #                         | a2a (seq-sharded tokens, all-to-all dispatch)

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0  # derived ceil(d_model/16) when 0
    attn_every: int = 0  # hybrid: attention mixer every k-th layer (jamba 1:7 -> 8)

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    max_target_len: int = 448  # whisper decoder cap

    # --- modality frontend stubs ---
    frontend: str = "none"  # none | audio_frames | vit_patches
    frontend_tokens: int = 0  # number of patch embeddings prepended (vlm)

    # --- numerics / training ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- notes / skips ---
    long_context_ok: bool = False  # sub-quadratic: run long_500k
    notes: str = ""

    # ---------------- derived helpers ----------------

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded for clean 2D sharding (Megatron-style)."""
        return _round_up(self.vocab_size, 256)

    def mixer_kind(self, layer: int) -> str:
        """Which sequence mixer a layer uses."""
        if self.attn_pattern == "none":
            return "mamba"
        if self.attn_every:  # hybrid (jamba): attention every k-th layer
            return "attn" if layer % self.attn_every == 0 else "mamba"
        return "attn"

    def attn_kind(self, layer: int) -> str:
        """full | swa — per layer (gemma2 alternates local/global)."""
        if self.attn_pattern == "local_global":
            return "swa" if layer % 2 == 0 else "full"
        if self.attn_pattern == "swa":
            return "swa"
        return "full"

    def ffn_kind(self, layer: int) -> str:
        if self.n_experts and layer % self.moe_every == (self.moe_every - 1):
            return "moe"
        return "dense"

    @property
    def group_size(self) -> int:
        """Layer-pattern period: layers are scanned in groups of this size
        so heterogeneous stacks (hybrid/alternating) still scan."""
        period = 1
        if self.attn_every:
            period = self.attn_every
        if self.attn_pattern == "local_global":
            period = max(period, 2)
        if self.n_experts:
            period = _lcm(period, self.moe_every)
        assert self.n_layers % period == 0, (self.name, period, self.n_layers)
        return period

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.group_size

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    # ---------------- parameter counting ----------------

    def param_count(self) -> int:
        """Total parameters (embedding included)."""
        total = self.padded_vocab * self.d_model  # embed
        if not self.tie_embeddings:
            total += self.padded_vocab * self.d_model
        for layer in range(self.n_layers):
            total += self._layer_params(layer)
        if self.is_encoder_decoder:
            for _ in range(self.encoder_layers):
                total += self._attn_params() + self._dense_ffn_params() + 2 * self.d_model
            total += self.max_target_len * self.d_model  # decoder pos embed
            total += self.n_layers * (self._attn_params() + self.d_model)  # cross attn
        if self.frontend == "vlm":
            total += self.d_model * self.d_model  # patch projection
        total += self.d_model  # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts;
        enc-dec: encoder + cross-attention are fully active)."""
        total = self.padded_vocab * self.d_model
        if not self.tie_embeddings:
            total += self.padded_vocab * self.d_model
        for layer in range(self.n_layers):
            total += self._layer_params(layer, active_only=True)
        if self.is_encoder_decoder:
            for _ in range(self.encoder_layers):
                total += self._attn_params() + self._dense_ffn_params() + 2 * self.d_model
            total += self.n_layers * (self._attn_params() + self.d_model)  # cross
        total += self.d_model
        return total

    def _attn_params(self) -> int:
        hd = self.head_dim
        return (
            self.d_model * self.n_heads * hd  # q
            + 2 * self.d_model * self.n_kv_heads * hd  # kv
            + self.n_heads * hd * self.d_model  # o
        )

    def _dense_ffn_params(self) -> int:
        return 3 * self.d_model * self.d_ff  # SwiGLU

    def _moe_ffn_params(self, active_only: bool = False) -> int:
        e = self.experts_per_token if active_only else self.n_experts
        return e * 3 * self.d_model * self.d_ff + self.d_model * self.n_experts

    def _mamba_params(self) -> int:
        di, n, dtr = self.d_inner, self.ssm_state, self.dt_rank_actual
        return (
            self.d_model * 2 * di  # in_proj
            + di * self.ssm_conv  # conv
            + di * (dtr + 2 * n)  # x_proj
            + dtr * di + di  # dt_proj
            + di * n + di  # A_log, D
            + di * self.d_model  # out_proj
        )

    def _layer_params(self, layer: int, active_only: bool = False) -> int:
        total = 2 * self.d_model  # norms
        if self.mixer_kind(layer) == "attn":
            total += self._attn_params()
        else:
            total += self._mamba_params()
        if self.ffn_kind(layer) == "moe":
            total += self._moe_ffn_params(active_only)
        else:
            total += self._dense_ffn_params()
        return total

    # ---------------- reduced configs for smoke tests ----------------

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config that runs a CPU train/serve step."""
        period = self.group_size
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * period,
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_head=16 if self.n_heads else 0,
            d_ff=128,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            max_target_len=32,
            sliding_window=32,
            frontend_tokens=8 if self.frontend_tokens else 0,
            rope_theta=10_000.0,
        )


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.name not in _REGISTRY, f"duplicate arch {cfg.name}"
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    # Import every sibling config module exactly once.
    from repro.configs import (  # noqa: F401
        deepseek_7b,
        falcon_mamba_7b,
        gemma2_9b,
        h2o_danube_1_8b,
        internvl2_2b,
        jamba_1_5_large,
        llama3_8b,
        mixtral_8x22b,
        qwen3_moe_30b_a3b,
        whisper_medium,
    )

    _LOADED = True


# ---------------------------------------------------------------------------
# Cell applicability (DESIGN.md §Arch-applicability)
# ---------------------------------------------------------------------------


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
