"""gemma2-9b [dense] — local+global alternating, logit softcap [arXiv:2408.00118]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        d_head=256,  # gemma2 uses wide heads (q proj 3584 -> 4096)
        d_ff=14336,
        vocab_size=256000,
        attn_pattern="local_global",
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        rope_theta=10_000.0,
        tie_embeddings=True,
        long_context_ok=False,  # global layers are quadratic; see DESIGN.md
        notes="long_500k skipped: alternating pattern still has full-attention layers.",
    )
)
