"""whisper-medium [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-medium",
        family="audio",
        n_layers=24,  # decoder layers
        encoder_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,  # MHA
        d_ff=4096,
        vocab_size=51865,
        attn_pattern="full",
        max_target_len=448,
        frontend="audio_frames",
        tie_embeddings=True,
        long_context_ok=False,
        notes=(
            "Backbone only: input_specs() provides precomputed frame "
            "embeddings (B, seq, d_model) in place of the conv frontend. "
            "Shape cells size the ENCODER sequence; the decoder is capped "
            "at 448 tokens (model limit). decode_* attends a cross-KV "
            "cache of seq_len encoder states."
        ),
    )
)
