"""h2o-danube-1.8b [dense] — llama+mistral mix, SWA [arXiv:2401.16818; hf]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        attn_pattern="swa",
        sliding_window=4096,
        rope_theta=10_000.0,
        long_context_ok=True,  # SWA: windowed KV cache at 500k
    )
)
