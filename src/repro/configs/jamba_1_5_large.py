"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7, MoE 16e top-2 [arXiv:2403.19887]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        n_experts=16,
        experts_per_token=2,
        moe_every=2,  # MoE replaces the dense FFN every 2nd layer
        attn_pattern="full",
        attn_every=8,  # 1 attention : 7 mamba per Jamba block
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,  # d_inner = 16384
        rope_theta=10_000.0,
        moment_dtype="bfloat16",  # 398B: fp32 moments would not fit 256 chips
        long_context_ok=True,  # hybrid: 9 attention layers, rest O(1)-state
        notes=(
            "16 experts = model axis: EP path. bf16 Adam moments keep "
            "optimizer state at ~9.4 GB/chip on the single-pod mesh."
        ),
    )
)
