"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_head=128,  # qwen3 uses explicit head_dim 128
        d_ff=768,  # per-expert FFN width
        vocab_size=151936,
        n_experts=128,
        experts_per_token=8,
        moe_every=1,
        attn_pattern="full",
        rope_theta=1_000_000.0,
        long_context_ok=False,  # pure full attention
        notes=(
            "128 experts >= model axis: EP path (experts sharded over "
            "'model', all-to-all dispatch — the paper's per-thread class)."
        ),
    )
)
