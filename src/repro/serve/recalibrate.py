"""Live recalibration: stream counter samples into a serving advisor.

The paper's premise is that a machine's bandwidth model comes from a
couple of counter runs — which means the model can *drift* whenever the
machine does (BIOS updates, DIMM swaps, thermal throttling, a neighbour
saturating an interconnect).  :class:`Recalibrator` closes the loop for a
live :class:`~repro.serve.service.AdvisorService`:

1. **Ingest** — counter sample batches arrive per machine handle, in any
   order, covering any subset of the probe suite (production traces are
   partial sweeps, not designed experiments).  Every batch is NaN-guarded
   through :func:`~repro.core.numa.calibrate.clean_samples` — corrupted
   rows are rejected and counted, never fitted — and buffered per handle.
2. **Refit** — :meth:`Recalibrator.recalibrate` concatenates a handle's
   buffer and refits with the outlier-robust (Huberized) loss, seeded
   from the machine's current structure.  Partial coverage is fine: the
   fit recovers whatever parameters the observed placements identify.
3. **Guard & swap** — the refit spec replays the very samples it was
   fitted from (:func:`~repro.core.numa.calibrate.sweep_median_error_pct`)
   and is compared against the *current* spec on the same samples.  Only
   a refit that does not regress the sweep-median error beyond
   ``max_error_regression_pp`` is hot-swapped in
   (:meth:`AdvisorService.swap_machine` — versioned epoch, per-machine
   cache invalidation, in-flight queries unaffected).  A regressing refit
   is rejected — the previous spec keeps serving, which is the rollback —
   and counted on the service metrics.

Every decision is returned (and kept in :attr:`Recalibrator.events`) as a
:class:`RecalibrationEvent` — the audit trail chaos tests and the
resilience benchmark assert over.  A ``"recalibrate"`` fault site and the
injector's counter-corruption hook make the failure paths testable.
"""

from __future__ import annotations

import threading
import time
from typing import NamedTuple, Sequence

import jax.numpy as jnp

from repro.core.numa.calibrate import (
    CalibrationSamples,
    clean_samples,
    concat_samples,
    fit_machine,
    samples_from_counters,
    sweep_median_error_pct,
)
from repro.serve.faults import NO_FAULTS, FaultInjector
from repro.serve.service import AdvisorService


class RecalibrationEvent(NamedTuple):
    """One refit decision: what was fitted, how it scored, what happened.

    ``old_error_pct`` / ``new_error_pct`` are the current and refit
    spec's sweep-median counter errors on the *same* ingested samples —
    the pair the acceptance guard compares.  ``epoch`` is the service
    epoch after the decision (bumped iff ``accepted``)."""

    handle: str
    accepted: bool
    reason: str
    epoch: int
    old_error_pct: float
    new_error_pct: float
    n_samples: int
    n_rejected: int
    fit_seconds: float


class Recalibrator:
    """Background recalibration worker for one :class:`AdvisorService`.

    Thread-safe: producers may :meth:`ingest` while a (manual or
    :meth:`start`-ed periodic) :meth:`recalibrate` runs.  The worker never
    blocks the serving path — fitting happens on the caller/background
    thread and the only service interaction is the atomic
    ``swap_machine`` at the end of an accepted refit.
    """

    def __init__(
        self,
        service: AdvisorService,
        *,
        min_samples: int = 16,
        max_error_regression_pp: float = 0.5,
        fit_steps: int = 120,
        fit_lr: float = 0.03,
        huber_delta: float | None = 0.05,
        warm_swap: bool = True,
        faults: FaultInjector | None = None,
    ):
        self.service = service
        self.min_samples = int(min_samples)
        self.max_error_regression_pp = float(max_error_regression_pp)
        self.fit_steps = int(fit_steps)
        self.fit_lr = float(fit_lr)
        self.huber_delta = huber_delta
        self.warm_swap = bool(warm_swap)
        self.faults = faults if faults is not None else service.faults
        self.events: list[RecalibrationEvent] = []
        self._lock = threading.Lock()
        self._buffers: dict[str, list[CalibrationSamples]] = {}
        self._rejected: dict[str, int] = {}
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- ingestion -----------------------------------------------------------

    def ingest(self, handle: str, samples: CalibrationSamples):
        """Buffer a counter sample batch for ``handle``; returns the
        batch's :class:`~repro.core.numa.calibrate.SampleDiagnostics`.
        Corrupt/non-finite rows are rejected here (and remembered, so the
        eventual :class:`RecalibrationEvent` reports them) — a poisoned
        feed degrades coverage, never the fit."""
        lr, rr, lw, rw, ins, el = self.faults.corrupt_counters((
            samples.local_read, samples.remote_read,
            samples.local_write, samples.remote_write,
            samples.instructions, samples.elapsed,
        ))
        samples = samples._replace(
            local_read=jnp.asarray(lr), remote_read=jnp.asarray(rr),
            local_write=jnp.asarray(lw), remote_write=jnp.asarray(rw),
            instructions=jnp.asarray(ins), elapsed=jnp.asarray(el),
        )
        cleaned, diag = clean_samples(samples, on_empty="ignore")
        with self._lock:
            if cleaned.n_samples:
                self._buffers.setdefault(handle, []).append(cleaned)
            self._rejected[handle] = (
                self._rejected.get(handle, 0) + diag.n_rejected
            )
        return diag

    def ingest_counters(self, handle: str, workloads: Sequence,
                        placements, counters: Sequence):
        """Ingest an externally measured trace — one
        :class:`~repro.core.bwsig.counters.CounterSample` per known
        ``(workload, placement)`` run — via
        :func:`~repro.core.numa.calibrate.samples_from_counters`."""
        return self.ingest(
            handle, samples_from_counters(workloads, placements, counters)
        )

    def buffered(self, handle: str) -> int:
        """Clean samples currently buffered for ``handle``."""
        with self._lock:
            return sum(
                b.n_samples for b in self._buffers.get(handle, [])
            )

    # -- refit & guard -------------------------------------------------------

    def recalibrate(self, handle: str) -> RecalibrationEvent:
        """Refit ``handle`` from its buffered samples, guard, and swap.

        Consumes the buffer whatever the outcome — a rejected fit's
        samples are as suspect as its parameters, so the next window
        starts fresh.  Returns (and records) the decision event."""
        with self._lock:
            batches = self._buffers.pop(handle, [])
            n_rejected = self._rejected.pop(handle, 0)
        n_samples = sum(b.n_samples for b in batches)
        current = self.service.machine_spec(handle)
        if n_samples < self.min_samples:
            event = RecalibrationEvent(
                handle=handle, accepted=False,
                reason=(
                    f"insufficient samples ({n_samples} clean < "
                    f"{self.min_samples} required; {n_rejected} rejected)"
                ),
                epoch=self.service.epoch_of(handle),
                old_error_pct=float("nan"), new_error_pct=float("nan"),
                n_samples=n_samples, n_rejected=n_rejected,
                fit_seconds=0.0,
            )
            with self._lock:
                self.events.append(event)
            return event
        samples = concat_samples(batches)
        t0 = time.perf_counter()
        try:
            self.faults.fire("recalibrate")
            old_err = sweep_median_error_pct(current, samples)
            result = fit_machine(
                current, samples,
                steps=self.fit_steps, lr=self.fit_lr,
                huber_delta=self.huber_delta, clean=False,
            )
            new_err = sweep_median_error_pct(result.machine, samples)
        except Exception as exc:
            event = RecalibrationEvent(
                handle=handle, accepted=False,
                reason=f"refit failed: {exc}",
                epoch=self.service.epoch_of(handle),
                old_error_pct=float("nan"), new_error_pct=float("nan"),
                n_samples=n_samples, n_rejected=n_rejected,
                fit_seconds=time.perf_counter() - t0,
            )
            with self._lock:
                self.events.append(event)
            return event
        fit_seconds = time.perf_counter() - t0
        if new_err <= old_err + self.max_error_regression_pp:
            epoch = self.service.swap_machine(
                handle, result.machine, warm=self.warm_swap
            )
            event = RecalibrationEvent(
                handle=handle, accepted=True,
                reason=(
                    f"sweep-median error {old_err:.3f}% -> {new_err:.3f}%"
                ),
                epoch=epoch,
                old_error_pct=old_err, new_error_pct=new_err,
                n_samples=n_samples, n_rejected=n_rejected,
                fit_seconds=fit_seconds,
            )
        else:
            # the guard IS the rollback: the regressing spec is never
            # installed, the previous (current) spec keeps serving
            self.service.metrics.record_rollback()
            event = RecalibrationEvent(
                handle=handle, accepted=False,
                reason=(
                    f"refit regressed sweep-median error "
                    f"{old_err:.3f}% -> {new_err:.3f}% "
                    f"(> +{self.max_error_regression_pp}pp); "
                    "previous spec retained"
                ),
                epoch=self.service.epoch_of(handle),
                old_error_pct=old_err, new_error_pct=new_err,
                n_samples=n_samples, n_rejected=n_rejected,
                fit_seconds=fit_seconds,
            )
        with self._lock:
            self.events.append(event)
        return event

    # -- background loop -----------------------------------------------------

    def start(self, interval_s: float = 30.0) -> None:
        """Recalibrate every buffered handle every ``interval_s`` seconds
        on a daemon thread, until :meth:`stop`."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("recalibrator already running")
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(interval_s):
                with self._lock:
                    handles = [
                        h for h, b in self._buffers.items()
                        if sum(x.n_samples for x in b) >= self.min_samples
                    ]
                for handle in handles:
                    if self._stop.is_set():
                        return
                    self.recalibrate(handle)

        self._thread = threading.Thread(
            target=loop, name="advisor-recalibrate", daemon=True
        )
        self._thread.start()

    def stop(self, timeout: float | None = 5.0) -> None:
        """Stop the background loop (idempotent; safe if never started)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
