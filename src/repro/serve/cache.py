"""Thread-safe bounded LRU cache — tier 1 of the advisor service.

A deliberately tiny primitive: one ``OrderedDict`` guarded by one lock.
The service's hit path is ``get`` → return the cached :class:`~repro.
serve.service.Advice` object itself — no copy, no new answer object, no
per-hit heap traffic beyond the interpreter's call frames (the value was
allocated once, on the miss that computed it).  ``move_to_end`` keeps the
recency order without reinserting, so a hit never triggers an eviction
sweep either.

Also reused to bound the service's per-``(machine, budget)`` placement
tables, which would otherwise grow per distinct query shape for the life
of the process.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable


class LRUCache:
    """Bounded thread-safe LRU mapping.

    ``get`` refreshes recency; ``put`` inserts (or refreshes) and evicts
    the least-recently-used entry past ``capacity``.  All operations are
    O(1) under a single non-reentrant lock — the critical sections never
    call out, so the lock cannot be held across user code.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._data: OrderedDict[Hashable, Any] = OrderedDict()

    def get(self, key: Hashable, default: Any = None) -> Any:
        """Return the cached value (refreshing its recency) or ``default``."""
        with self._lock:
            try:
                value = self._data[key]
            except KeyError:
                return default
            self._data.move_to_end(key)
            return value

    def put(self, key: Hashable, value: Any) -> None:
        """Insert/refresh a key, evicting least-recent entries over capacity."""
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._data

    def clear(self) -> None:
        """Drop every entry (capacity is unchanged)."""
        with self._lock:
            self._data.clear()

    def pop_where(self, pred) -> int:
        """Drop every entry whose *key* satisfies ``pred``; returns the
        number removed.  The service uses this for per-machine cache
        invalidation on spec hot-swap — keys of other machines (and of
        the new epoch) survive untouched.  ``pred`` must be pure (it runs
        under the cache lock)."""
        with self._lock:
            doomed = [k for k in self._data if pred(k)]
            for k in doomed:
                del self._data[k]
            return len(doomed)

    def keys(self) -> list:
        """Snapshot of the keys, oldest first (for tests/introspection)."""
        with self._lock:
            return list(self._data.keys())
