"""Fault injection for the advisor serving stack.

The resilience contracts of :class:`~repro.serve.service.AdvisorService`
("bounded when unhealthy") are only testable if unhealth can be
manufactured on demand.  This module is the manufacturing plant: a
:class:`FaultInjector` holds an armed-fault registry that the service,
the recalibration worker and the chaos suite all share, and the
instrumented code calls back into it at named *sites*:

* ``"batch"`` — inside the micro-batcher, immediately before the jitted
  batch dispatch (a slow fault here models a stalled compile/dispatch; a
  raise models the evaluator dying mid-batch);
* ``"batcher"`` — at the top of each batcher-loop iteration, before any
  queries are taken (a raise here kills the batcher thread between jobs
  — the service's self-healing restart is what keeps queries flowing);
* ``"search"`` — inside each branch-and-bound attempt (raises are
  absorbed by the search tier's retry-with-backoff ladder);
* ``"schedule"`` — inside the phased-query worker;
* ``"rank"`` — inside the degradation ladder's signature-only rung
  (failing it forces the ladder down to the stale/fallback rungs);
* ``"recalibrate"`` — inside the recalibration worker's fit.

Faults are armed with a ``times`` budget and disarm themselves after
firing that many times, so a chaos scenario is fully deterministic:
"the 3rd through 5th batches stall 200 ms, then the world heals".
Everything is thread-safe (sites fire from the batcher, pool workers and
caller threads concurrently) and every firing is appended to
:attr:`FaultInjector.log` so tests can assert the scenario actually
happened instead of silently passing against a healthy service.

Clock skew is injected at the *clock*, not at a site: the service reads
deadlines through :meth:`FaultInjector.now`, so a skewed injector makes
every in-flight deadline appear nearer/farther exactly the way a stepped
or drifting system clock would.

The module-level :data:`NO_FAULTS` singleton is the default injector —
permanently empty, its hooks compile down to a dict probe — so
production paths pay one attribute load per site.
"""

from __future__ import annotations

import threading
import time
from typing import Callable

import numpy as np


class FaultError(RuntimeError):
    """Default exception type raised by an armed error fault — distinct
    from real failures so tests can tell injected pain from genuine
    bugs."""


class _Fault:
    """One armed fault at one site: an action plus a remaining-fire
    budget (``None`` = unlimited)."""

    __slots__ = ("kind", "delay_s", "exc_factory", "times")

    def __init__(self, kind: str, *, delay_s: float = 0.0,
                 exc_factory: Callable[[], BaseException] | None = None,
                 times: int | None = 1):
        self.kind = kind
        self.delay_s = delay_s
        self.exc_factory = exc_factory
        self.times = times


class FaultInjector:
    """Thread-safe armed-fault registry shared by the serving stack.

    Arm faults with :meth:`inject_slow` / :meth:`inject_error` /
    :meth:`inject_clock_skew` / :meth:`inject_counter_corruption`;
    instrumented code calls :meth:`fire` at its site, :meth:`now` for
    deadline clocks and :meth:`corrupt_counters` on ingested counter
    batches.  ``log`` records every firing as ``(site, kind)`` tuples in
    order.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._faults: dict[str, list[_Fault]] = {}
        self._skew_s = 0.0
        self._corrupt: _Fault | None = None
        self._corrupt_fraction = 0.0
        self._corrupt_seed = 0
        self.log: list[tuple[str, str]] = []

    # -- arming ------------------------------------------------------------

    def inject_slow(self, site: str, delay_s: float,
                    *, times: int | None = 1) -> "FaultInjector":
        """Arm a slow fault: the next ``times`` firings of ``site`` sleep
        ``delay_s`` seconds before proceeding.  Returns self (chainable)."""
        with self._lock:
            self._faults.setdefault(site, []).append(
                _Fault("slow", delay_s=float(delay_s), times=times)
            )
        return self

    def inject_error(self, site: str, *, times: int | None = 1,
                     exc_factory: Callable[[], BaseException] | None = None,
                     ) -> "FaultInjector":
        """Arm an error fault: the next ``times`` firings of ``site``
        raise (:class:`FaultError` by default)."""
        factory = exc_factory or (lambda: FaultError(f"injected fault at {site!r}"))
        with self._lock:
            self._faults.setdefault(site, []).append(
                _Fault("error", exc_factory=factory, times=times)
            )
        return self

    def inject_clock_skew(self, offset_s: float) -> "FaultInjector":
        """Skew the injected monotonic clock by ``offset_s`` seconds
        (positive = the future arrives early, so deadlines look nearer)."""
        with self._lock:
            self._skew_s = float(offset_s)
        return self

    def inject_counter_corruption(self, *, fraction: float = 0.25,
                                  times: int | None = 1,
                                  seed: int = 0) -> "FaultInjector":
        """Arm counter-batch corruption: the next ``times`` batches passed
        through :meth:`corrupt_counters` get ``fraction`` of their rows
        NaN-poisoned (deterministically, from ``seed``)."""
        with self._lock:
            self._corrupt = _Fault("corrupt", times=times)
            self._corrupt_fraction = float(fraction)
            self._corrupt_seed = int(seed)
        return self

    def clear(self, site: str | None = None) -> None:
        """Disarm every fault at ``site`` (or everywhere when None),
        including clock skew and counter corruption."""
        with self._lock:
            if site is None:
                self._faults.clear()
                self._skew_s = 0.0
                self._corrupt = None
            else:
                self._faults.pop(site, None)

    # -- firing ------------------------------------------------------------

    def _take(self, site: str) -> _Fault | None:
        with self._lock:
            queue = self._faults.get(site)
            if not queue:
                return None
            fault = queue[0]
            if fault.times is not None:
                fault.times -= 1
                if fault.times <= 0:
                    queue.pop(0)
                if not queue:
                    del self._faults[site]
            self.log.append((site, fault.kind))
            return fault

    def fire(self, site: str) -> None:
        """Fire ``site``: no-op when nothing is armed there; otherwise
        consume one budgeted firing — sleeping for slow faults, raising
        for error faults."""
        fault = self._take(site)
        if fault is None:
            return
        if fault.kind == "slow":
            time.sleep(fault.delay_s)
        elif fault.kind == "error":
            raise fault.exc_factory()  # type: ignore[misc]

    def now(self) -> float:
        """The (possibly skewed) monotonic clock deadlines are read from."""
        return time.monotonic() + self._skew_s

    def corrupt_counters(self, arrays: tuple) -> tuple:
        """Pass a tuple of per-sample counter arrays (leading axis =
        samples) through the armed corruption fault, NaN-poisoning a
        deterministic subset of rows; identity when disarmed."""
        with self._lock:
            fault = self._corrupt
            if fault is None:
                return arrays
            if fault.times is not None:
                fault.times -= 1
                if fault.times <= 0:
                    self._corrupt = None
            fraction, seed = self._corrupt_fraction, self._corrupt_seed
            self.log.append(("counters", "corrupt"))
        rng = np.random.default_rng(seed)
        out = []
        n = int(np.asarray(arrays[0]).shape[0])
        k = max(1, int(round(fraction * n)))
        rows = rng.choice(n, size=min(k, n), replace=False)
        for arr in arrays:
            a = np.array(arr, np.float64, copy=True)
            a[rows] = np.nan
            out.append(a)
        return tuple(out)

    def fired(self, site: str) -> int:
        """How many times ``site`` has fired so far."""
        with self._lock:
            return sum(1 for s, _ in self.log if s == site)


NO_FAULTS = FaultInjector()
"""The default, permanently inert injector (arm your own instance for
chaos runs — arming this one would fault every service that kept the
default)."""
