"""Placement advisor as a service: the micro-batched online query engine.

The offline pipeline answers "where should these threads run?" by sweeping
or searching a whole machine per call.  :class:`AdvisorService` turns that
into an online query engine: callers submit ``(workload signature, machine
fingerprint, thread budget)`` and get back a placement plus its predicted
bandwidth and work rate, through a three-tier fast path:

1. **cache** — a thread-safe bounded LRU (:class:`~repro.serve.cache.
   LRUCache`) keyed on the canonicalized query.  The hit path is a dict
   probe returning the already-allocated :class:`Advice` — no simulator
   dispatch, no new answer object.
2. **batch** — concurrent cache misses for the same ``(machine, thread
   budget)`` group coalesce in a pending queue; a batcher thread drains a
   group when it reaches ``max_batch`` or its oldest entry ages past
   ``max_wait_s``, and answers the whole batch in ONE padded
   :func:`~repro.core.numa.simulator.simulate_grouped_batch` sweep over
   the group's cached placement table.  Workload rows are always padded to
   exactly ``max_batch``, so each ``(machine, budget)`` group owns a
   single jit trace — steady-state serving never retraces regardless of
   how the stream batches (and a query's row is independent of its
   batch-mates, so answers are bit-identical to serial evaluation).
3. **search** — machines whose composition space exceeds ``sweep_limit``
   fall back to :func:`~repro.core.numa.search.branch_and_bound`,
   warm-started from the advisor's signature-only ranking
   (``advisor_seeds``), off the batcher thread so searches never stall
   micro-batching.

Every tier is instrumented (:class:`~repro.serve.metrics.ServiceMetrics`):
per-tier counts and p50/p99 latency, batch-size histogram, and the
retrace counter the CI gate holds at zero across a warmed mixed stream.
"""

from __future__ import annotations

import math
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numa.evaluate import enumerate_placements
from repro.core.numa.machine import MachineSpec
from repro.core.numa.search import branch_and_bound
from repro.core.numa.simulator import (
    pad_rows,
    simulate_grouped_batch,
    support_patterns,
)
from repro.core.numa.temporal import (
    MigrationModel,
    optimize_schedule,
    phased_workload,
)
from repro.core.numa.workload import Workload, mixed_workload
from repro.serve.cache import LRUCache
from repro.serve.metrics import ServiceMetrics


class QuerySignature(NamedTuple):
    """The model-representable description of a workload — what the paper's
    4-class signature carries, phrased as a query.  Uniform across threads
    by construction (the serving contract: every thread shares the mix),
    which also pins the jit thread-class refinement to ``(0,)`` for every
    query, one ingredient of the no-retrace guarantee."""

    read_mix: tuple[float, float, float]  # (static, local, per-thread)
    write_mix: tuple[float, float, float]
    read_bpi: float = 0.6
    write_bpi: float = 0.2
    static_socket: int = 0

    def canonical(self) -> "QuerySignature":
        """Round-trip through rounded floats so queries that differ only in
        float noise (1/3 vs 0.333333) share a cache line."""
        return QuerySignature(
            tuple(round(float(v), 6) for v in self.read_mix),
            tuple(round(float(v), 6) for v in self.write_mix),
            round(float(self.read_bpi), 6),
            round(float(self.write_bpi), 6),
            int(self.static_socket),
        )

    def workload(self, n_threads: int, name: str = "serve") -> Workload:
        """Materialize the signature as an ``n_threads`` uniform workload."""
        return mixed_workload(
            name,
            n_threads,
            read_mix=self.read_mix,
            write_mix=self.write_mix,
            read_bpi=self.read_bpi,
            write_bpi=self.write_bpi,
            static_socket=self.static_socket,
        )


@dataclass(frozen=True)
class Advice:
    """One answered query.  ``tier`` names the tier that *computed* the
    answer; a later cache hit returns this same object (the metrics, not
    the advice, record the serving path)."""

    placement: tuple[int, ...]  # threads per NUMA node
    predicted_bandwidth: float  # total bytes/s moved at this placement
    objective: float  # work rate (instructions/s), the quantity maximized
    tier: str  # "batch" | "search"
    optimal: bool  # exhaustive sweep, or B&B certificate within its gap


@dataclass(frozen=True)
class ScheduleAdvice:
    """One answered *phased* query: a placement (and page placement) per
    phase plus the scheduler's receipts.  ``gain_pct`` is the improvement
    over holding the best static placement for the whole horizon — never
    negative (the static trajectory is in the scheduler's feasible set)."""

    placements: tuple[tuple[int, ...], ...]  # per-phase threads per node
    bank_assignments: tuple  # per-phase bank maps (None = node-local)
    total_work: float  # instructions over the horizon
    static_work: float  # best static placement's instructions
    gain_pct: float
    transition_times: tuple[float, ...]  # boundary stalls (seconds)
    tier: str = "schedule"


class _PlacementTable(NamedTuple):
    """Per-``(machine, budget)`` candidate set, padded once at build time
    so every batch against it reuses one trace."""

    placements: jax.Array  # (P_pad, s) device-resident, power-of-two rows
    placements_np: np.ndarray  # host copy for answer extraction
    support: jax.Array  # (n_buckets, s)
    slab_id: jax.Array  # (P_pad,)


class _Pending(NamedTuple):
    key: tuple  # full answer-cache key
    sig: QuerySignature  # canonical
    future: Future
    t0: float  # enqueue time (monotonic) — anchors the batch deadline


@partial(jax.jit, static_argnames=("machine", "thread_classes"))
def _advise_batch_jit(
    machine: MachineSpec,
    wl_arrays: tuple,  # workload fields, each with a leading query axis W
    placements: jax.Array,  # (P, s)
    support: jax.Array,
    slab_id: jax.Array,
    thread_classes: tuple[int, ...],
):
    """One trace answers a whole micro-batch: vmap the shared-slab grouped
    sweep over the query axis, argmax work rate per query, and read the
    winner's total flow off the simulated matrices.  Rows are independent
    (vmap forbids cross-batch interaction), so a query's answer does not
    depend on its batch-mates — the service's determinism contract."""

    def per_query(arrays):
        wl = Workload("serve", *arrays)
        sim = simulate_grouped_batch(
            machine,
            wl,
            placements,
            thread_classes=thread_classes,
            support=support,
            slab_id=slab_id,
        )
        obj = sim.instructions.sum(axis=1)  # (P,)
        best = jnp.argmax(obj)
        bandwidth = sim.read_flows[best].sum() + sim.write_flows[best].sum()
        return best, obj[best], bandwidth

    return jax.vmap(per_query)(wl_arrays)


class AdvisorService:
    """Online placement advisor over a registry of machines.

    Thread-safe: any number of caller threads may :meth:`query` /
    :meth:`submit` concurrently.  Answers are deterministic — bit-identical
    to evaluating the same query serially — because batch rows never
    interact and padding always lands on the same traced shape.

    ``sweep_limit`` draws the tier-2/tier-3 line: a ``(machine, budget)``
    whose full composition count exceeds it is answered by warm-started
    branch and bound instead of an exhaustive sweep.
    """

    def __init__(
        self,
        *,
        answer_capacity: int = 4096,
        table_capacity: int = 16,
        max_batch: int = 8,
        max_wait_s: float = 0.002,
        sweep_limit: int = 20_000,
        search_gap: float = 0.05,
        search_max_nodes: int = 50_000,
        advisor_seeds: int = 8,
        advisor_max_placements: int = 2048,
        search_workers: int = 2,
        metrics: ServiceMetrics | None = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.sweep_limit = int(sweep_limit)
        self.search_gap = float(search_gap)
        self.search_max_nodes = int(search_max_nodes)
        self.advisor_seeds = int(advisor_seeds)
        self.advisor_max_placements = int(advisor_max_placements)
        self.metrics = metrics if metrics is not None else ServiceMetrics()

        self._machines: dict[str, MachineSpec] = {}
        self._answers = LRUCache(answer_capacity)
        self._tables = LRUCache(table_capacity)
        self._cond = threading.Condition()
        # group key (fingerprint, n_threads) -> FIFO of pending misses
        self._pending: dict[tuple, list[_Pending]] = {}
        # answer key -> Future, so concurrent identical misses compute once
        self._inflight: dict[tuple, Future] = {}
        self._closed = False
        self._search_pool = ThreadPoolExecutor(
            max_workers=max(1, int(search_workers)),
            thread_name_prefix="advisor-search",
        )
        self._batcher = threading.Thread(
            target=self._batch_loop, name="advisor-batcher", daemon=True
        )
        self._batcher.start()

    # -- registry ------------------------------------------------------------

    def register(self, machine: MachineSpec) -> str:
        """Add a machine to the registry; returns its fingerprint (the
        handle queries may use in place of the spec)."""
        fp = machine.fingerprint()
        with self._cond:
            self._machines[fp] = machine
        return fp

    def _resolve(self, machine) -> tuple[MachineSpec, str]:
        if isinstance(machine, str):
            with self._cond:
                spec = self._machines.get(machine)
            if spec is None:
                raise KeyError(f"unknown machine fingerprint {machine!r}")
            return spec, machine
        fp = self.register(machine)
        return machine, fp

    # -- public front ends ---------------------------------------------------

    def query(self, machine, signature: QuerySignature, n_threads: int,
              timeout: float | None = None) -> Advice:
        """Synchronous ask-and-wait.  ``machine`` is a MachineSpec or a
        registered fingerprint string."""
        advice, future = self._lookup_or_dispatch(machine, signature, n_threads)
        if advice is not None:
            return advice
        return future.result(timeout)

    def submit(self, machine, signature: QuerySignature,
               n_threads: int) -> Future:
        """Async front end: returns a Future resolving to the
        :class:`Advice` (already resolved on a cache hit)."""
        advice, future = self._lookup_or_dispatch(machine, signature, n_threads)
        if advice is not None:
            future = Future()
            future.set_result(advice)
        return future

    def _lookup_or_dispatch(self, machine, signature, n_threads):
        t0 = time.perf_counter()
        if self._closed:
            raise RuntimeError("AdvisorService is closed")
        spec, fp = self._resolve(machine)
        sig = signature.canonical()
        key = (fp, int(n_threads), sig)
        hit = self._answers.get(key)
        if hit is not None:
            self.metrics.record_query("cache", time.perf_counter() - t0)
            return hit, None
        with self._cond:
            # re-check under the dispatch lock: a batch completion inserts
            # into the answer cache *before* retiring its in-flight future,
            # so a key absent from both here is genuinely uncomputed
            hit = self._answers.get(key)
            if hit is not None:
                self.metrics.record_query(
                    "cache", time.perf_counter() - t0
                )
                return hit, None
            future = self._inflight.get(key)
            if future is None:
                future = Future()
                self._inflight[key] = future
                if self.uses_search(spec, n_threads):
                    self._search_pool.submit(
                        self._run_search, spec, fp, int(n_threads), sig, key
                    )
                else:
                    group = (fp, int(n_threads))
                    self._pending.setdefault(group, []).append(
                        _Pending(key, sig, future, time.perf_counter())
                    )
                    self._cond.notify_all()

        def _record(f, t0=t0):
            if f.cancelled() or f.exception() is not None:
                return
            self.metrics.record_query(
                f.result().tier, time.perf_counter() - t0
            )

        future.add_done_callback(_record)
        return None, future

    # -- phased queries --------------------------------------------------------

    @staticmethod
    def _canonical_phases(phases) -> tuple:
        """Canonicalize a phased query: ``(signature, duration)`` pairs
        with rounded signatures/durations, so float-noise variants of the
        same schedule share one cache line (the phased twin of
        :meth:`QuerySignature.canonical`)."""
        canon = tuple(
            (sig.canonical(), round(float(dur), 6)) for sig, dur in phases
        )
        if not canon:
            raise ValueError("phased query needs at least one phase")
        return canon

    def query_schedule(self, machine, phases, n_threads: int, *,
                       model: MigrationModel | None = None,
                       timeout: float | None = None) -> ScheduleAdvice:
        """Synchronous phased query: ``phases`` is a sequence of
        ``(QuerySignature, duration_s)`` pairs — the signature of each
        stationary segment plus how long it runs.  Answers with one
        placement (and bank assignment) per phase via the migration-aware
        scheduler; cached/deduplicated exactly like one-shot queries,
        computed on the search pool so schedules never stall the
        micro-batcher."""
        advice, future = self._dispatch_schedule(
            machine, phases, n_threads, model
        )
        if advice is not None:
            return advice
        return future.result(timeout)

    def submit_schedule(self, machine, phases, n_threads: int, *,
                        model: MigrationModel | None = None) -> Future:
        """Async twin of :meth:`query_schedule`: returns a Future
        resolving to the :class:`ScheduleAdvice`."""
        advice, future = self._dispatch_schedule(
            machine, phases, n_threads, model
        )
        if advice is not None:
            future = Future()
            future.set_result(advice)
        return future

    def _dispatch_schedule(self, machine, phases, n_threads, model):
        t0 = time.perf_counter()
        if self._closed:
            raise RuntimeError("AdvisorService is closed")
        spec, fp = self._resolve(machine)
        model = model if model is not None else MigrationModel()
        canon = self._canonical_phases(phases)
        key = (fp, int(n_threads), "schedule", canon, model)
        hit = self._answers.get(key)
        if hit is not None:
            self.metrics.record_query("cache", time.perf_counter() - t0)
            return hit, None
        with self._cond:
            hit = self._answers.get(key)
            if hit is not None:
                self.metrics.record_query("cache", time.perf_counter() - t0)
                return hit, None
            future = self._inflight.get(key)
            if future is None:
                future = Future()
                self._inflight[key] = future
                self._search_pool.submit(
                    self._run_schedule, spec, int(n_threads), canon, model, key
                )

        def _record(f, t0=t0):
            if f.cancelled() or f.exception() is not None:
                return
            self.metrics.record_query(
                f.result().tier, time.perf_counter() - t0
            )

        future.add_done_callback(_record)
        return None, future

    def _run_schedule(self, machine: MachineSpec, n_threads: int,
                      canon: tuple, model: MigrationModel,
                      key: tuple) -> None:
        future = self._inflight.get(key)
        try:
            pw = phased_workload(
                "serve-schedule",
                [
                    (sig.workload(n_threads, name=f"phase{i}"), dur)
                    for i, (sig, dur) in enumerate(canon)
                ],
            )
            result = optimize_schedule(
                machine, pw, model=model, sweep_limit=self.sweep_limit
            )
            advice = ScheduleAdvice(
                placements=result.schedule.placements,
                bank_assignments=result.schedule.bank_assignments,
                total_work=result.schedule.total_work,
                static_work=result.static.total_work,
                gain_pct=result.gain_pct,
                transition_times=result.schedule.transition_times,
            )
            self._finish(key, future, advice)
        except BaseException as exc:
            self._fail([(key, future)], exc)

    # -- tier selection & placement tables ------------------------------------

    def uses_search(self, machine: MachineSpec, n_threads: int) -> bool:
        """True when the full composition space of ``n_threads`` over the
        machine's nodes is too large to sweep (tier 3)."""
        s = machine.n_nodes
        return math.comb(int(n_threads) + s - 1, s - 1) > self.sweep_limit

    def _table_for(self, machine: MachineSpec, fp: str,
                   n_threads: int) -> _PlacementTable:
        key = (fp, n_threads)
        table = self._tables.get(key)
        if table is not None:
            return table
        placements = np.asarray(
            enumerate_placements(machine, n_threads), np.int32
        )
        padded = pad_rows(placements)
        support, slab_id = support_patterns(padded)
        table = _PlacementTable(
            placements=jnp.asarray(padded),
            placements_np=padded,
            support=jnp.asarray(support),
            slab_id=jnp.asarray(slab_id),
        )
        self._tables.put(key, table)
        return table

    # -- batch tier ------------------------------------------------------------

    def _batch_loop(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._closed:
                    self._cond.wait()
                if not self._pending and self._closed:
                    return
                group = min(
                    self._pending, key=lambda g: self._pending[g][0].t0
                )
                items = self._pending[group]
                deadline = items[0].t0 + self.max_wait_s
                now = time.perf_counter()
                if (
                    len(items) < self.max_batch
                    and now < deadline
                    and not self._closed
                ):
                    self._cond.wait(deadline - now)
                    continue
                take = items[: self.max_batch]
                rest = items[self.max_batch:]
                if rest:
                    self._pending[group] = rest
                else:
                    del self._pending[group]
            self._run_batch(group, take)

    def _signature_rows(self, sig: QuerySignature, n: int) -> tuple:
        ones = np.ones((n,), np.float32)
        return (
            ones * sig.read_mix[0],
            ones * sig.read_mix[1],
            ones * sig.read_mix[2],
            ones * sig.write_mix[0],
            ones * sig.write_mix[1],
            ones * sig.write_mix[2],
            ones * sig.read_bpi,
            ones * sig.write_bpi,
            np.asarray(sig.static_socket, np.int32),
        )

    def _stacked_arrays(self, sigs: list[QuerySignature], n: int) -> tuple:
        """Stack per-query uniform workload rows and pad the query axis to
        exactly ``max_batch`` by repeating the first row — one traced shape
        per group, whatever the live batch size."""
        rows = [self._signature_rows(sig, n) for sig in sigs]
        stacked = tuple(np.stack(parts) for parts in zip(*rows))
        return tuple(
            jnp.asarray(pad_rows(arr, base=self.max_batch))
            for arr in stacked
        )

    def _finish(self, key: tuple, future: Future, advice: Advice) -> None:
        # answer cache first, in-flight retirement second: every moment a
        # key is absent from the in-flight map it is present in the cache
        self._answers.put(key, advice)
        with self._cond:
            self._inflight.pop(key, None)
        future.set_result(advice)

    def _fail(self, keys_futures, exc: BaseException) -> None:
        with self._cond:
            for key, _ in keys_futures:
                self._inflight.pop(key, None)
        for _, future in keys_futures:
            if not future.done():
                future.set_exception(exc)

    def _run_batch(self, group: tuple, take: list[_Pending]) -> None:
        fp, n_threads = group
        try:
            with self._cond:
                machine = self._machines[fp]
            table = self._table_for(machine, fp, n_threads)
            arrays = self._stacked_arrays([it.sig for it in take], n_threads)
            self.metrics.register_trace(self._trace_key(fp, n_threads, table))
            best, obj, bandwidth = _advise_batch_jit(
                machine, arrays, table.placements, table.support,
                table.slab_id, (0,),
            )
            best = np.asarray(best)
            obj = np.asarray(obj)
            bandwidth = np.asarray(bandwidth)
            self.metrics.record_batch(len(take))
            for i, item in enumerate(take):
                advice = Advice(
                    placement=tuple(
                        int(v) for v in table.placements_np[int(best[i])]
                    ),
                    predicted_bandwidth=float(bandwidth[i]),
                    objective=float(obj[i]),
                    tier="batch",
                    optimal=True,
                )
                self._finish(item.key, item.future, advice)
        except BaseException as exc:  # resolve waiters, keep the loop alive
            self._fail([(it.key, it.future) for it in take], exc)

    def _trace_key(self, fp: str, n_threads: int,
                   table: _PlacementTable) -> tuple:
        return (
            fp,
            n_threads,
            self.max_batch,
            int(table.placements.shape[0]),
            int(table.support.shape[0]),
        )

    # -- search tier -----------------------------------------------------------

    def _run_search(self, machine: MachineSpec, fp: str, n_threads: int,
                    sig: QuerySignature, key: tuple) -> None:
        future = self._inflight.get(key)
        try:
            wl = sig.workload(n_threads)
            result = branch_and_bound(
                machine,
                wl,
                gap=self.search_gap,
                max_nodes=self.search_max_nodes,
                advisor_seeds=self.advisor_seeds,
                advisor_max_placements=self.advisor_max_placements,
            )
            # score the winner through the same jitted evaluator the batch
            # tier uses, so objective/bandwidth are tier-independent
            placement = np.asarray(result.placement, np.int32)[None, :]
            padded = pad_rows(placement)
            support, slab_id = support_patterns(padded)
            table = _PlacementTable(
                placements=jnp.asarray(padded),
                placements_np=padded,
                support=jnp.asarray(support),
                slab_id=jnp.asarray(slab_id),
            )
            arrays = self._stacked_arrays([sig], n_threads)
            self.metrics.register_trace(self._trace_key(fp, n_threads, table))
            _, obj, bandwidth = _advise_batch_jit(
                machine, arrays, table.placements, table.support,
                table.slab_id, (0,),
            )
            advice = Advice(
                placement=tuple(int(v) for v in result.placement),
                predicted_bandwidth=float(np.asarray(bandwidth)[0]),
                objective=float(np.asarray(obj)[0]),
                tier="search",
                optimal=result.optimal,
            )
            self._finish(key, future, advice)
        except BaseException as exc:
            self._fail([(key, future)], exc)

    # -- warmup & lifecycle ------------------------------------------------------

    def warmup(self, machine, n_threads: int,
               signature: QuerySignature | None = None) -> Advice:
        """Trace a ``(machine, budget)`` group's single steady-state jit
        shape (and, on search-tier machines, the search path's caches) by
        answering one query.  After warmup, the retrace counter stays flat
        for ANY stream against this group — the shape never varies."""
        sig = signature if signature is not None else QuerySignature(
            (0.25, 0.25, 0.25), (0.25, 0.25, 0.25)
        )
        return self.query(machine, sig, n_threads)

    def close(self, timeout: float | None = 5.0) -> None:
        """Stop the batcher and search pool, failing any still-pending
        queries with ``RuntimeError``.  Idempotent; the service rejects
        new queries afterwards."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._batcher.join(timeout)
        self._search_pool.shutdown(wait=True)
        with self._cond:
            pending = [it for q in self._pending.values() for it in q]
            self._pending.clear()
        self._fail(
            [(it.key, it.future) for it in pending],
            RuntimeError("AdvisorService closed"),
        )

    def __enter__(self) -> "AdvisorService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
