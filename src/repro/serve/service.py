"""Placement advisor as a service: the micro-batched online query engine.

The offline pipeline answers "where should these threads run?" by sweeping
or searching a whole machine per call.  :class:`AdvisorService` turns that
into an online query engine: callers submit ``(workload signature, machine
handle, thread budget)`` and get back a placement plus its predicted
bandwidth and work rate, through a three-tier fast path:

1. **cache** — a thread-safe bounded LRU (:class:`~repro.serve.cache.
   LRUCache`) keyed on the canonicalized query.  The hit path is a dict
   probe returning the already-allocated :class:`Advice` — no simulator
   dispatch, no new answer object.
2. **batch** — concurrent cache misses for the same ``(machine, thread
   budget)`` group coalesce in a pending queue; a batcher thread drains a
   group when it reaches ``max_batch`` or its oldest entry ages past
   ``max_wait_s``, and answers the whole batch in ONE padded
   :func:`~repro.core.numa.simulator.simulate_grouped_batch` sweep over
   the group's cached placement table.  Workload rows are always padded to
   exactly ``max_batch``, so each ``(machine, budget)`` group owns a
   single jit trace — steady-state serving never retraces regardless of
   how the stream batches (and a query's row is independent of its
   batch-mates, so answers are bit-identical to serial evaluation).
3. **search** — machines whose composition space exceeds ``sweep_limit``
   fall back to :func:`~repro.core.numa.search.branch_and_bound`,
   warm-started from the advisor's signature-only ranking
   (``advisor_seeds``), off the batcher thread so searches never stall
   micro-batching.  Failed attempts retry with backoff and a halved node
   budget, so the tier always lands on a certified incumbent.

Two resilience layers sit on top (PR 10):

**Spec epochs and hot-swap.**  The registry maps a stable *handle* (the
fingerprint at registration, or a caller-chosen ``machine_id``) to a
``(spec, epoch)`` entry.  :meth:`AdvisorService.swap_machine` installs a
recalibrated spec under the same handle with a bumped epoch; every cache
key, pending-batch group and trace key carries the epoch, so in-flight
queries finish against the spec they started with (the pending group pins
the spec object — the batch worker never re-reads the registry) and
invalidation is per-machine: only this handle's stale-epoch answers and
tables are dropped.  :meth:`AdvisorService.rollback_machine` restores the
previous spec (as a new epoch) when a recalibration guard trips.

**Deadlines and the degradation ladder.**  A query may carry
``deadline_s`` (or inherit ``default_deadline_s``); when the exact tiers
cannot answer in time — or the batch/search computation fails outright —
the service walks down a fidelity ladder instead of blocking:
``exact`` (the normal tiers) → ``ranked`` (signature-only roofline via
:func:`~repro.core.meshsig.advisor.rank_numa_placements`, no simulation)
→ ``stale`` (this handle's last known good exact answer) → ``fallback``
(an even spread, the static default the paper's advisor must beat).
Every :class:`Advice` is tagged with the fidelity that produced it, and
degraded answers are never cached — the next query retries the exact
path.  Fault injection (:mod:`repro.serve.faults`) hooks the batcher, the
batch dispatch, the search attempts and the deadline clock so chaos tests
can manufacture every one of these paths deterministically.

Every tier is instrumented (:class:`~repro.serve.metrics.ServiceMetrics`):
per-tier counts and p50/p99 latency, batch-size histogram, fidelity
counts/degraded rate, swap/rollback/restart counters, and the retrace
counter the CI gate holds at zero across a warmed mixed stream.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meshsig.advisor import rank_numa_placements
from repro.core.numa.evaluate import enumerate_placements
from repro.core.numa.machine import MachineSpec
from repro.core.numa.search import branch_and_bound
from repro.core.numa.simulator import (
    pad_rows,
    simulate_grouped_batch,
    support_patterns,
)
from repro.core.numa.temporal import (
    MigrationModel,
    optimize_schedule,
    phased_workload,
)
from repro.core.numa.workload import Workload, mixed_workload
from repro.serve.cache import LRUCache
from repro.serve.faults import NO_FAULTS, FaultInjector
from repro.serve.metrics import ServiceMetrics


class ServiceClosedError(RuntimeError):
    """Raised by every entry point of a closed :class:`AdvisorService`,
    and set on any future the close drained rather than resolved.  A
    dedicated type so callers can tell an orderly shutdown from a compute
    failure (which degrades or propagates, depending on the deadline)."""


class QuerySignature(NamedTuple):
    """The model-representable description of a workload — what the paper's
    4-class signature carries, phrased as a query.  Uniform across threads
    by construction (the serving contract: every thread shares the mix),
    which also pins the jit thread-class refinement to ``(0,)`` for every
    query, one ingredient of the no-retrace guarantee."""

    read_mix: tuple[float, float, float]  # (static, local, per-thread)
    write_mix: tuple[float, float, float]
    read_bpi: float = 0.6
    write_bpi: float = 0.2
    static_socket: int = 0

    def canonical(self) -> "QuerySignature":
        """Round-trip through rounded floats so queries that differ only in
        float noise (1/3 vs 0.333333) share a cache line."""
        return QuerySignature(
            tuple(round(float(v), 6) for v in self.read_mix),
            tuple(round(float(v), 6) for v in self.write_mix),
            round(float(self.read_bpi), 6),
            round(float(self.write_bpi), 6),
            int(self.static_socket),
        )

    def workload(self, n_threads: int, name: str = "serve") -> Workload:
        """Materialize the signature as an ``n_threads`` uniform workload."""
        return mixed_workload(
            name,
            n_threads,
            read_mix=self.read_mix,
            write_mix=self.write_mix,
            read_bpi=self.read_bpi,
            write_bpi=self.write_bpi,
            static_socket=self.static_socket,
        )


@dataclass(frozen=True)
class Advice:
    """One answered query.  ``tier`` names the tier that *computed* the
    answer; a later cache hit returns this same object (the metrics, not
    the advice, record the serving path).  ``fidelity`` is the degradation
    rung that produced it (``exact`` off the normal tiers; ``ranked`` /
    ``stale`` / ``fallback`` off the deadline ladder) and ``epoch`` the
    spec version it was computed against — a stream's answers for one
    ``(machine, epoch)`` are bit-identical no matter when a hot-swap lands
    around them."""

    placement: tuple[int, ...]  # threads per NUMA node
    predicted_bandwidth: float  # total bytes/s moved at this placement
    objective: float  # work rate (instructions/s), the quantity maximized
    tier: str  # "batch" | "search" | "degraded"
    optimal: bool  # exhaustive sweep, or B&B certificate within its gap
    fidelity: str = "exact"  # "exact" | "ranked" | "stale" | "fallback"
    epoch: int = 0  # spec epoch the answer was computed against


@dataclass(frozen=True)
class ScheduleAdvice:
    """One answered *phased* query: a placement (and page placement) per
    phase plus the scheduler's receipts.  ``gain_pct`` is the improvement
    over holding the best static placement for the whole horizon — never
    negative (the static trajectory is in the scheduler's feasible set)."""

    placements: tuple[tuple[int, ...], ...]  # per-phase threads per node
    bank_assignments: tuple  # per-phase bank maps (None = node-local)
    total_work: float  # instructions over the horizon
    static_work: float  # best static placement's instructions
    gain_pct: float
    transition_times: tuple[float, ...]  # boundary stalls (seconds)
    tier: str = "schedule"


class _MachineEntry(NamedTuple):
    """Registry slot: the live spec, its epoch, and the previous entry
    (one step of history — what :meth:`rollback_machine` restores)."""

    spec: MachineSpec
    epoch: int
    previous: "_MachineEntry | None"


class _PlacementTable(NamedTuple):
    """Per-``(machine, budget)`` candidate set, padded once at build time
    so every batch against it reuses one trace."""

    placements: jax.Array  # (P_pad, s) device-resident, power-of-two rows
    placements_np: np.ndarray  # host copy for answer extraction
    support: jax.Array  # (n_buckets, s)
    slab_id: jax.Array  # (P_pad,)


class _Pending(NamedTuple):
    key: tuple  # full answer-cache key
    sig: QuerySignature  # canonical
    future: Future
    t0: float  # enqueue time (monotonic) — anchors the batch deadline


class _PendingGroup(NamedTuple):
    """One coalescing group's queue plus its epoch-pinned spec: the batch
    worker answers from this spec even if a hot-swap lands while the
    group waits, so no batch ever straddles two epochs."""

    spec: MachineSpec
    items: list  # list[_Pending], mutated in place under the service lock


@partial(jax.jit, static_argnames=("machine", "thread_classes"))
def _advise_batch_jit(
    machine: MachineSpec,
    wl_arrays: tuple,  # workload fields, each with a leading query axis W
    placements: jax.Array,  # (P, s)
    support: jax.Array,
    slab_id: jax.Array,
    thread_classes: tuple[int, ...],
):
    """One trace answers a whole micro-batch: vmap the shared-slab grouped
    sweep over the query axis, argmax work rate per query, and read the
    winner's total flow off the simulated matrices.  Rows are independent
    (vmap forbids cross-batch interaction), so a query's answer does not
    depend on its batch-mates — the service's determinism contract."""

    def per_query(arrays):
        wl = Workload("serve", *arrays)
        sim = simulate_grouped_batch(
            machine,
            wl,
            placements,
            thread_classes=thread_classes,
            support=support,
            slab_id=slab_id,
        )
        obj = sim.instructions.sum(axis=1)  # (P,)
        best = jnp.argmax(obj)
        bandwidth = sim.read_flows[best].sum() + sim.write_flows[best].sum()
        return best, obj[best], bandwidth

    return jax.vmap(per_query)(wl_arrays)


class AdvisorService:
    """Online placement advisor over a registry of machines.

    Thread-safe: any number of caller threads may :meth:`query` /
    :meth:`submit` concurrently.  Answers are deterministic — bit-identical
    to evaluating the same query serially — because batch rows never
    interact and padding always lands on the same traced shape.

    ``sweep_limit`` draws the tier-2/tier-3 line: a ``(machine, budget)``
    whose full composition count exceeds it is answered by warm-started
    branch and bound instead of an exhaustive sweep.

    ``default_deadline_s`` (None = wait forever) arms the degradation
    ladder for every query that doesn't carry its own ``deadline_s``;
    ``faults`` installs a :class:`~repro.serve.faults.FaultInjector`
    whose clock the deadline math reads and whose sites the workers fire.
    """

    def __init__(
        self,
        *,
        answer_capacity: int = 4096,
        table_capacity: int = 16,
        max_batch: int = 8,
        max_wait_s: float = 0.002,
        sweep_limit: int = 20_000,
        search_gap: float = 0.05,
        search_max_nodes: int = 50_000,
        search_retries: int = 2,
        search_backoff_s: float = 0.01,
        search_min_nodes: int = 500,
        advisor_seeds: int = 8,
        advisor_max_placements: int = 2048,
        search_workers: int = 2,
        default_deadline_s: float | None = None,
        lkg_capacity: int = 1024,
        faults: FaultInjector | None = None,
        metrics: ServiceMetrics | None = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.sweep_limit = int(sweep_limit)
        self.search_gap = float(search_gap)
        self.search_max_nodes = int(search_max_nodes)
        self.search_retries = int(search_retries)
        self.search_backoff_s = float(search_backoff_s)
        self.search_min_nodes = int(search_min_nodes)
        self.advisor_seeds = int(advisor_seeds)
        self.advisor_max_placements = int(advisor_max_placements)
        self.default_deadline_s = (
            None if default_deadline_s is None else float(default_deadline_s)
        )
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self.faults = faults if faults is not None else NO_FAULTS

        self._machines: dict[str, _MachineEntry] = {}
        self._answers = LRUCache(answer_capacity)
        self._tables = LRUCache(table_capacity)
        # last-known-good exact answers; deliberately NOT invalidated on
        # hot-swap (a stale answer is the ladder's point) and keyed
        # without the epoch
        self._lkg = LRUCache(lkg_capacity)
        self._cond = threading.Condition()
        # group key (handle, epoch, n_threads) -> epoch-pinned queue
        self._pending: dict[tuple, _PendingGroup] = {}
        # answer key -> Future, so concurrent identical misses compute once
        self._inflight: dict[tuple, Future] = {}
        self._closed = False
        self._close_started = False
        self._close_done = threading.Event()
        self._search_pool = ThreadPoolExecutor(
            max_workers=max(1, int(search_workers)),
            thread_name_prefix="advisor-search",
        )
        self._batcher = threading.Thread(
            target=self._batcher_main, name="advisor-batcher", daemon=True
        )
        self._batcher.start()

    # -- registry ------------------------------------------------------------

    def register(self, machine: MachineSpec,
                 machine_id: str | None = None) -> str:
        """Add a machine to the registry; returns its *handle* (its
        fingerprint at registration time, or ``machine_id`` if given).
        Idempotent: a handle already registered is returned as-is without
        touching the live entry — so a caller re-presenting the original
        spec object after a hot-swap does not clobber the swapped spec."""
        handle = machine_id if machine_id is not None else machine.fingerprint()
        with self._cond:
            if handle not in self._machines:
                self._machines[handle] = _MachineEntry(machine, 0, None)
        return handle

    def _resolve(self, machine) -> tuple[MachineSpec, str, int]:
        """``machine`` (spec or handle) -> the live ``(spec, handle,
        epoch)`` triple queries pin themselves to."""
        if isinstance(machine, str):
            handle = machine
        else:
            handle = self.register(machine)
        with self._cond:
            entry = self._machines.get(handle)
        if entry is None:
            raise KeyError(f"unknown machine handle {machine!r}")
        return entry.spec, handle, entry.epoch

    def epoch_of(self, handle: str) -> int:
        """The registry's current spec epoch for ``handle`` (bumped by
        every accepted swap and every rollback)."""
        with self._cond:
            entry = self._machines.get(handle)
        if entry is None:
            raise KeyError(f"unknown machine handle {handle!r}")
        return entry.epoch

    def machine_spec(self, handle: str) -> MachineSpec:
        """The live spec currently serving ``handle``."""
        with self._cond:
            entry = self._machines.get(handle)
        if entry is None:
            raise KeyError(f"unknown machine handle {handle!r}")
        return entry.spec

    # -- hot swap ------------------------------------------------------------

    def swap_machine(self, handle: str, new_spec: MachineSpec,
                     *, warm: bool = True) -> int:
        """Atomically install ``new_spec`` under ``handle`` with a bumped
        epoch; returns the new epoch.

        In-flight queries are untouched: their pending groups pinned the
        old spec at dispatch.  The answer cache and placement tables are
        invalidated for this handle only (stale epochs), never for other
        machines.  ``warm=True`` (default) pre-compiles the new spec's
        batch trace for every thread budget this handle currently serves
        *before* the swap is visible, so the first post-swap queries hit a
        warmed path — the retrace counter stays flat.  Raises ValueError
        when the new spec is structurally incompatible (node or core
        count changed): recalibration refits bandwidths, not topology."""
        with self._cond:
            if self._closed:
                raise ServiceClosedError("AdvisorService is closed")
            entry = self._machines.get(handle)
        if entry is None:
            raise KeyError(f"unknown machine handle {handle!r}")
        old = entry.spec
        if (new_spec.n_nodes != old.n_nodes
                or new_spec.cores_per_node != old.cores_per_node):
            raise ValueError(
                f"swap for {handle!r} changes machine structure "
                f"({old.n_nodes}x{old.cores_per_node} -> "
                f"{new_spec.n_nodes}x{new_spec.cores_per_node}); "
                "register a new machine instead"
            )
        new_epoch = self._install_spec(handle, new_spec, warm=warm)
        self.metrics.record_swap()
        return new_epoch

    def rollback_machine(self, handle: str, *, warm: bool = True) -> int:
        """Restore ``handle``'s previous spec (as a *new* epoch — epochs
        only move forward, so answer provenance stays unambiguous).
        Raises RuntimeError when there is no previous spec to restore."""
        with self._cond:
            entry = self._machines.get(handle)
        if entry is None:
            raise KeyError(f"unknown machine handle {handle!r}")
        if entry.previous is None:
            raise RuntimeError(f"machine {handle!r} has no previous spec")
        new_epoch = self._install_spec(
            handle, entry.previous.spec, warm=warm
        )
        self.metrics.record_rollback()
        return new_epoch

    def _install_spec(self, handle: str, new_spec: MachineSpec,
                      *, warm: bool) -> int:
        # Warm the new spec's traces against the thread budgets this
        # handle already serves, before the swap becomes visible.  The
        # placement tables themselves only depend on (n_nodes, budget) —
        # structurally invariant across swaps — so the arrays are reused;
        # only the jit trace (machine is a static arg) is new.
        warmed: list[tuple[int, _PlacementTable]] = []
        if warm:
            budgets = sorted({
                k[2] for k in self._tables.keys() if k[0] == handle
            })
            for n_threads in budgets:
                table = self._build_table(new_spec, n_threads)
                arrays = self._stacked_arrays(
                    [QuerySignature((1.0, 0.0, 0.0), (1.0, 0.0, 0.0))],
                    n_threads,
                )
                _advise_batch_jit(
                    new_spec, arrays, table.placements, table.support,
                    table.slab_id, (0,),
                )
                warmed.append((n_threads, table))
        with self._cond:
            entry = self._machines[handle]
            new_epoch = entry.epoch + 1
            self._machines[handle] = _MachineEntry(
                new_spec, new_epoch, entry
            )
        # Per-machine invalidation: drop this handle's stale-epoch keys
        # only.  Done after the registry flip so no window serves a stale
        # answer against the new epoch.
        self._answers.pop_where(
            lambda k: k[0] == handle and k[1] != new_epoch
        )
        self._tables.pop_where(
            lambda k: k[0] == handle and k[1] != new_epoch
        )
        for n_threads, table in warmed:
            tk = (handle, new_epoch, n_threads)
            self._tables.put(tk, table)
            self.metrics.register_trace(
                self._trace_key(handle, new_epoch, n_threads, table)
            )
        return new_epoch

    # -- public front ends ---------------------------------------------------

    def query(self, machine, signature: QuerySignature, n_threads: int,
              timeout: float | None = None, *,
              deadline_s: float | None = None) -> Advice:
        """Synchronous ask-and-wait.  ``machine`` is a MachineSpec or a
        registered handle string.

        ``deadline_s`` (falling back to the service's
        ``default_deadline_s``) bounds the wait: past the deadline — or if
        the exact computation fails — the answer comes off the degradation
        ladder (``ranked`` → ``stale`` → ``fallback``) instead of
        blocking or raising.  Without a deadline, ``timeout`` is the
        legacy hard bound: it raises on expiry rather than degrading.
        A closed service raises :class:`ServiceClosedError` either way."""
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        t_deadline = (
            None if deadline_s is None else self.faults.now() + deadline_s
        )
        t0 = time.perf_counter()
        advice, future = self._lookup_or_dispatch(
            machine, signature, n_threads, deadline_s=deadline_s
        )
        if advice is not None:
            return advice
        if t_deadline is None:
            return future.result(timeout)
        try:
            remaining = t_deadline - self.faults.now()
            return future.result(max(remaining, 0.0))
        except ServiceClosedError:
            raise
        except BaseException:
            # deadline expired or the exact tier failed: degrade
            spec, handle, epoch = self._resolve(machine)
            return self._degrade(
                spec, handle, epoch, signature.canonical(),
                int(n_threads), t0,
            )

    def submit(self, machine, signature: QuerySignature,
               n_threads: int) -> Future:
        """Async front end: returns a Future resolving to the
        :class:`Advice` (already resolved on a cache hit).  Futures carry
        no deadline — they resolve with the exact answer or the compute
        failure; the degradation ladder is a :meth:`query`-side policy."""
        advice, future = self._lookup_or_dispatch(machine, signature, n_threads)
        if advice is not None:
            future = Future()
            future.set_result(advice)
        return future

    def _lookup_or_dispatch(self, machine, signature, n_threads,
                            deadline_s: float | None = None):
        t0 = time.perf_counter()
        if self._closed:
            raise ServiceClosedError("AdvisorService is closed")
        spec, handle, epoch = self._resolve(machine)
        sig = signature.canonical()
        key = (handle, epoch, int(n_threads), sig)
        hit = self._answers.get(key)
        if hit is not None:
            self.metrics.record_query("cache", time.perf_counter() - t0)
            self.metrics.record_fidelity(hit.fidelity)
            return hit, None
        with self._cond:
            if self._closed:
                raise ServiceClosedError("AdvisorService is closed")
            # re-check under the dispatch lock: a batch completion inserts
            # into the answer cache *before* retiring its in-flight future,
            # so a key absent from both here is genuinely uncomputed
            hit = self._answers.get(key)
            if hit is not None:
                self.metrics.record_query(
                    "cache", time.perf_counter() - t0
                )
                self.metrics.record_fidelity(hit.fidelity)
                return hit, None
            future = self._inflight.get(key)
            if future is None:
                future = Future()
                self._inflight[key] = future
                if self.uses_search(spec, n_threads):
                    self._search_pool.submit(
                        self._run_search, spec, handle, epoch,
                        int(n_threads), sig, key, deadline_s,
                    )
                else:
                    group = (handle, epoch, int(n_threads))
                    pg = self._pending.get(group)
                    if pg is None:
                        pg = _PendingGroup(spec, [])
                        self._pending[group] = pg
                    pg.items.append(
                        _Pending(key, sig, future, time.perf_counter())
                    )
                    self._cond.notify_all()

        def _record(f, t0=t0):
            if f.cancelled() or f.exception() is not None:
                return
            adv = f.result()
            self.metrics.record_query(adv.tier, time.perf_counter() - t0)
            self.metrics.record_fidelity(getattr(adv, "fidelity", "exact"))

        future.add_done_callback(_record)
        return None, future

    # -- degradation ladder ----------------------------------------------------

    def _degrade(self, spec: MachineSpec, handle: str, epoch: int,
                 sig: QuerySignature, n_threads: int, t0: float) -> Advice:
        """Serve a deadline-missed query off the ladder: signature-only
        roofline ranking → last-known-good exact answer → even spread.
        Never blocks on the simulator, never caches its answer (the next
        identical query retries the exact path — and usually hits the
        cache the late batch populated)."""
        advice = None
        try:
            self.faults.fire("rank")
            ranked = rank_numa_placements(
                spec, sig.workload(n_threads), top_k=1,
                max_placements=self.advisor_max_placements,
            )
            best = ranked[0]
            advice = Advice(
                placement=best.placement,
                predicted_bandwidth=float("nan"),
                objective=float(best.predicted_throughput),
                tier="degraded",
                optimal=False,
                fidelity="ranked",
                epoch=epoch,
            )
        except BaseException:
            lkg = self._lkg.get((handle, n_threads, sig))
            if lkg is None:
                lkg = self._lkg.get(("any", handle, n_threads))
            if lkg is not None:
                advice = dataclasses.replace(
                    lkg, tier="degraded", fidelity="stale"
                )
        if advice is None:
            s = spec.n_nodes
            base, extra = divmod(int(n_threads), s)
            advice = Advice(
                placement=tuple(
                    base + (1 if i < extra else 0) for i in range(s)
                ),
                predicted_bandwidth=float("nan"),
                objective=float("nan"),
                tier="degraded",
                optimal=False,
                fidelity="fallback",
                epoch=epoch,
            )
        self.metrics.record_query("degraded", time.perf_counter() - t0)
        self.metrics.record_fidelity(advice.fidelity)
        return advice

    # -- phased queries --------------------------------------------------------

    @staticmethod
    def _canonical_phases(phases) -> tuple:
        """Canonicalize a phased query: ``(signature, duration)`` pairs
        with rounded signatures/durations, so float-noise variants of the
        same schedule share one cache line (the phased twin of
        :meth:`QuerySignature.canonical`)."""
        canon = tuple(
            (sig.canonical(), round(float(dur), 6)) for sig, dur in phases
        )
        if not canon:
            raise ValueError("phased query needs at least one phase")
        return canon

    def query_schedule(self, machine, phases, n_threads: int, *,
                       model: MigrationModel | None = None,
                       timeout: float | None = None) -> ScheduleAdvice:
        """Synchronous phased query: ``phases`` is a sequence of
        ``(QuerySignature, duration_s)`` pairs — the signature of each
        stationary segment plus how long it runs.  Answers with one
        placement (and bank assignment) per phase via the migration-aware
        scheduler; cached/deduplicated exactly like one-shot queries,
        computed on the search pool so schedules never stall the
        micro-batcher."""
        advice, future = self._dispatch_schedule(
            machine, phases, n_threads, model
        )
        if advice is not None:
            return advice
        return future.result(timeout)

    def submit_schedule(self, machine, phases, n_threads: int, *,
                        model: MigrationModel | None = None) -> Future:
        """Async twin of :meth:`query_schedule`: returns a Future
        resolving to the :class:`ScheduleAdvice`."""
        advice, future = self._dispatch_schedule(
            machine, phases, n_threads, model
        )
        if advice is not None:
            future = Future()
            future.set_result(advice)
        return future

    def _dispatch_schedule(self, machine, phases, n_threads, model):
        t0 = time.perf_counter()
        if self._closed:
            raise ServiceClosedError("AdvisorService is closed")
        spec, handle, epoch = self._resolve(machine)
        model = model if model is not None else MigrationModel()
        canon = self._canonical_phases(phases)
        key = (handle, epoch, int(n_threads), "schedule", canon, model)
        hit = self._answers.get(key)
        if hit is not None:
            self.metrics.record_query("cache", time.perf_counter() - t0)
            self.metrics.record_fidelity("exact")
            return hit, None
        with self._cond:
            if self._closed:
                raise ServiceClosedError("AdvisorService is closed")
            hit = self._answers.get(key)
            if hit is not None:
                self.metrics.record_query("cache", time.perf_counter() - t0)
                self.metrics.record_fidelity("exact")
                return hit, None
            future = self._inflight.get(key)
            if future is None:
                future = Future()
                self._inflight[key] = future
                self._search_pool.submit(
                    self._run_schedule, spec, int(n_threads), canon, model, key
                )

        def _record(f, t0=t0):
            if f.cancelled() or f.exception() is not None:
                return
            adv = f.result()
            self.metrics.record_query(adv.tier, time.perf_counter() - t0)
            self.metrics.record_fidelity(getattr(adv, "fidelity", "exact"))

        future.add_done_callback(_record)
        return None, future

    def _run_schedule(self, machine: MachineSpec, n_threads: int,
                      canon: tuple, model: MigrationModel,
                      key: tuple) -> None:
        future = self._inflight.get(key)
        try:
            self.faults.fire("schedule")
            pw = phased_workload(
                "serve-schedule",
                [
                    (sig.workload(n_threads, name=f"phase{i}"), dur)
                    for i, (sig, dur) in enumerate(canon)
                ],
            )
            result = optimize_schedule(
                machine, pw, model=model, sweep_limit=self.sweep_limit
            )
            advice = ScheduleAdvice(
                placements=result.schedule.placements,
                bank_assignments=result.schedule.bank_assignments,
                total_work=result.schedule.total_work,
                static_work=result.static.total_work,
                gain_pct=result.gain_pct,
                transition_times=result.schedule.transition_times,
            )
            self._finish(key, future, advice)
        except BaseException as exc:
            self._fail([(key, future)], exc)

    # -- tier selection & placement tables ------------------------------------

    def uses_search(self, machine: MachineSpec, n_threads: int) -> bool:
        """True when the full composition space of ``n_threads`` over the
        machine's nodes is too large to sweep (tier 3)."""
        s = machine.n_nodes
        return math.comb(int(n_threads) + s - 1, s - 1) > self.sweep_limit

    def _build_table(self, machine: MachineSpec,
                     n_threads: int) -> _PlacementTable:
        placements = np.asarray(
            enumerate_placements(machine, n_threads), np.int32
        )
        padded = pad_rows(placements)
        support, slab_id = support_patterns(padded)
        return _PlacementTable(
            placements=jnp.asarray(padded),
            placements_np=padded,
            support=jnp.asarray(support),
            slab_id=jnp.asarray(slab_id),
        )

    def _table_for(self, machine: MachineSpec, handle: str, epoch: int,
                   n_threads: int) -> _PlacementTable:
        key = (handle, epoch, n_threads)
        table = self._tables.get(key)
        if table is not None:
            return table
        table = self._build_table(machine, n_threads)
        self._tables.put(key, table)
        return table

    # -- batch tier ------------------------------------------------------------

    def _batcher_main(self) -> None:
        """Self-healing wrapper: a crash anywhere in the batcher loop
        (including an injected ``"batcher"`` fault) loses nothing — the
        pending queues are untouched — and the loop restarts immediately
        unless the service is closing."""
        while True:
            try:
                self._batch_loop()
                return  # orderly exit: closed and drained
            except BaseException:
                with self._cond:
                    if self._closed:
                        return
                self.metrics.record_restart()

    def _batch_loop(self) -> None:
        while True:
            self.faults.fire("batcher")
            with self._cond:
                while not self._pending and not self._closed:
                    self._cond.wait()
                if not self._pending and self._closed:
                    return
                gkey = min(
                    self._pending,
                    key=lambda g: self._pending[g].items[0].t0,
                )
                group = self._pending[gkey]
                deadline = group.items[0].t0 + self.max_wait_s
                now = time.perf_counter()
                if (
                    len(group.items) < self.max_batch
                    and now < deadline
                    and not self._closed
                ):
                    self._cond.wait(deadline - now)
                    continue
                take = group.items[: self.max_batch]
                rest = group.items[self.max_batch:]
                if rest:
                    self._pending[gkey] = _PendingGroup(group.spec, rest)
                else:
                    del self._pending[gkey]
            self._run_batch(gkey, group.spec, take)

    def _signature_rows(self, sig: QuerySignature, n: int) -> tuple:
        ones = np.ones((n,), np.float32)
        return (
            ones * sig.read_mix[0],
            ones * sig.read_mix[1],
            ones * sig.read_mix[2],
            ones * sig.write_mix[0],
            ones * sig.write_mix[1],
            ones * sig.write_mix[2],
            ones * sig.read_bpi,
            ones * sig.write_bpi,
            np.asarray(sig.static_socket, np.int32),
        )

    def _stacked_arrays(self, sigs: list[QuerySignature], n: int) -> tuple:
        """Stack per-query uniform workload rows and pad the query axis to
        exactly ``max_batch`` by repeating the first row — one traced shape
        per group, whatever the live batch size."""
        rows = [self._signature_rows(sig, n) for sig in sigs]
        stacked = tuple(np.stack(parts) for parts in zip(*rows))
        return tuple(
            jnp.asarray(pad_rows(arr, base=self.max_batch))
            for arr in stacked
        )

    def _finish(self, key: tuple, future: Future, advice) -> None:
        # answer cache first, in-flight retirement second: every moment a
        # key is absent from the in-flight map it is present in the cache
        self._answers.put(key, advice)
        if isinstance(advice, Advice) and advice.fidelity == "exact":
            handle, _, n_threads, sig = key[:4]
            self._lkg.put((handle, n_threads, sig), advice)
            self._lkg.put(("any", handle, n_threads), advice)
        with self._cond:
            self._inflight.pop(key, None)
        try:
            future.set_result(advice)
        except Exception:
            pass  # close() already failed this future; the cache has it

    def _fail(self, keys_futures, exc: BaseException) -> None:
        with self._cond:
            for key, _ in keys_futures:
                self._inflight.pop(key, None)
        for _, future in keys_futures:
            try:
                future.set_exception(exc)
            except Exception:
                pass  # already resolved (e.g. by a concurrent close)

    def _run_batch(self, gkey: tuple, machine: MachineSpec,
                   take: list[_Pending]) -> None:
        handle, epoch, n_threads = gkey
        try:
            self.faults.fire("batch")
            table = self._table_for(machine, handle, epoch, n_threads)
            arrays = self._stacked_arrays([it.sig for it in take], n_threads)
            self.metrics.register_trace(
                self._trace_key(handle, epoch, n_threads, table)
            )
            best, obj, bandwidth = _advise_batch_jit(
                machine, arrays, table.placements, table.support,
                table.slab_id, (0,),
            )
            best = np.asarray(best)
            obj = np.asarray(obj)
            bandwidth = np.asarray(bandwidth)
            self.metrics.record_batch(len(take))
            for i, item in enumerate(take):
                advice = Advice(
                    placement=tuple(
                        int(v) for v in table.placements_np[int(best[i])]
                    ),
                    predicted_bandwidth=float(bandwidth[i]),
                    objective=float(obj[i]),
                    tier="batch",
                    optimal=True,
                    epoch=epoch,
                )
                self._finish(item.key, item.future, advice)
        except BaseException as exc:  # resolve waiters, keep the loop alive
            self._fail([(it.key, it.future) for it in take], exc)

    def _trace_key(self, handle: str, epoch: int, n_threads: int,
                   table: _PlacementTable) -> tuple:
        return (
            handle,
            epoch,
            n_threads,
            self.max_batch,
            int(table.placements.shape[0]),
            int(table.support.shape[0]),
        )

    # -- search tier -----------------------------------------------------------

    def _run_search(self, machine: MachineSpec, handle: str, epoch: int,
                    n_threads: int, sig: QuerySignature, key: tuple,
                    deadline_s: float | None = None) -> None:
        future = self._inflight.get(key)
        wl = sig.workload(n_threads)
        # Deadline-aware node budget: a query that only has (say) a fifth
        # of the horizon to spare gets a fifth of the nodes — B&B returns
        # its certified incumbent at ANY budget, so a cut budget degrades
        # the certificate, never the answer's validity.
        max_nodes = self.search_max_nodes
        if deadline_s is not None:
            horizon = 5.0  # seconds the full budget is sized for
            frac = min(1.0, max(deadline_s, 0.0) / horizon)
            max_nodes = max(self.search_min_nodes, int(max_nodes * frac))
        result = None
        for attempt in range(self.search_retries + 1):
            try:
                self.faults.fire("search")
                result = branch_and_bound(
                    machine,
                    wl,
                    gap=self.search_gap,
                    max_nodes=max_nodes,
                    advisor_seeds=self.advisor_seeds,
                    advisor_max_placements=self.advisor_max_placements,
                )
                break
            except BaseException as exc:
                if attempt >= self.search_retries:
                    self._fail([(key, future)], exc)
                    return
                # back off, then retry on a cut node budget: a transient
                # stall is ridden out; a genuinely slow search converges
                # to the cheapest certified incumbent instead of dying
                time.sleep(self.search_backoff_s * (2 ** attempt))
                max_nodes = max(self.search_min_nodes, max_nodes // 2)
        try:
            # score the winner through the same jitted evaluator the batch
            # tier uses, so objective/bandwidth are tier-independent
            placement = np.asarray(result.placement, np.int32)[None, :]
            padded = pad_rows(placement)
            support, slab_id = support_patterns(padded)
            table = _PlacementTable(
                placements=jnp.asarray(padded),
                placements_np=padded,
                support=jnp.asarray(support),
                slab_id=jnp.asarray(slab_id),
            )
            arrays = self._stacked_arrays([sig], n_threads)
            self.metrics.register_trace(
                self._trace_key(handle, epoch, n_threads, table)
            )
            _, obj, bandwidth = _advise_batch_jit(
                machine, arrays, table.placements, table.support,
                table.slab_id, (0,),
            )
            advice = Advice(
                placement=tuple(int(v) for v in result.placement),
                predicted_bandwidth=float(np.asarray(bandwidth)[0]),
                objective=float(np.asarray(obj)[0]),
                tier="search",
                optimal=result.optimal,
                epoch=epoch,
            )
            self._finish(key, future, advice)
        except BaseException as exc:
            self._fail([(key, future)], exc)

    # -- warmup & lifecycle ------------------------------------------------------

    def warmup(self, machine, n_threads: int,
               signature: QuerySignature | None = None) -> Advice:
        """Trace a ``(machine, budget)`` group's single steady-state jit
        shape (and, on search-tier machines, the search path's caches) by
        answering one query; also primes the degradation ladder's ranked
        rung so a deadline miss never pays first-compile latency.  After
        warmup, the retrace counter stays flat for ANY stream against this
        group — the shape never varies."""
        sig = signature if signature is not None else QuerySignature(
            (0.25, 0.25, 0.25), (0.25, 0.25, 0.25)
        )
        advice = self.query(machine, sig, n_threads)
        spec, _, _ = self._resolve(machine)
        rank_numa_placements(
            spec, sig.canonical().workload(int(n_threads)), top_k=1,
            max_placements=self.advisor_max_placements,
        )
        return advice

    def close(self, timeout: float | None = 5.0) -> None:
        """Stop the service: drain-then-fail, idempotent, never hangs.

        The batcher flushes every already-pending micro-batch (their
        futures resolve with exact answers), the search pool stops
        accepting work, and any future still unresolved afterwards —
        queued search jobs that never ran, stragglers past ``timeout`` —
        fails with :class:`ServiceClosedError`.  Concurrent and repeated
        ``close()`` calls are safe: the first runs the shutdown, the rest
        wait for it.  Every entry point raises ``ServiceClosedError``
        immediately once close has begun."""
        with self._cond:
            first = not self._close_started
            self._close_started = True
            self._closed = True
            self._cond.notify_all()
        if not first:
            self._close_done.wait(timeout)
            return
        try:
            self._batcher.join(timeout)
            self._search_pool.shutdown(wait=False, cancel_futures=True)
            with self._cond:
                pending = [
                    it for g in self._pending.values() for it in g.items
                ]
                self._pending.clear()
                inflight = list(self._inflight.items())
                self._inflight.clear()
            exc = ServiceClosedError("AdvisorService is closed")
            self._fail([(it.key, it.future) for it in pending], exc)
            for key, future in inflight:
                if not future.done():
                    self._fail([(key, future)], exc)
        finally:
            self._close_done.set()

    def __enter__(self) -> "AdvisorService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
