"""Placement advisor as a service.

Online query engine over the offline NUMA placement pipeline: a
three-tier fast path (LRU answer cache → micro-batched grouped sweep →
warm-started branch and bound) behind sync and async front ends, fully
instrumented, plus a phased-query path (``query_schedule``: a tuple of
per-phase signatures answered by the migration-aware scheduler).

The resilience layer (PR 10) makes the engine "correct and bounded when
unhealthy": versioned spec epochs with live hot-swap/rollback
(:class:`Recalibrator` streams counter samples into guarded refits), a
deadline-bounded degradation ladder tagging every
:class:`Advice` with its fidelity, and a :class:`FaultInjector` the
chaos suite drives.  See :mod:`repro.serve.service` for the
architecture and ``docs/serving.md`` for the operational contracts.
"""

from repro.serve.cache import LRUCache
from repro.serve.faults import NO_FAULTS, FaultError, FaultInjector
from repro.serve.metrics import FIDELITIES, TIERS, ServiceMetrics
from repro.serve.recalibrate import RecalibrationEvent, Recalibrator
from repro.serve.service import (
    Advice,
    AdvisorService,
    QuerySignature,
    ScheduleAdvice,
    ServiceClosedError,
)

__all__ = [
    "Advice",
    "AdvisorService",
    "FIDELITIES",
    "FaultError",
    "FaultInjector",
    "LRUCache",
    "NO_FAULTS",
    "QuerySignature",
    "RecalibrationEvent",
    "Recalibrator",
    "ScheduleAdvice",
    "ServiceClosedError",
    "ServiceMetrics",
    "TIERS",
]
