"""Placement advisor as a service.

Online query engine over the offline NUMA placement pipeline: a
three-tier fast path (LRU answer cache → micro-batched grouped sweep →
warm-started branch and bound) behind sync and async front ends, fully
instrumented, plus a phased-query path (``query_schedule``: a tuple of
per-phase signatures answered by the migration-aware scheduler).  See
:mod:`repro.serve.service` for the architecture.
"""

from repro.serve.cache import LRUCache
from repro.serve.metrics import TIERS, ServiceMetrics
from repro.serve.service import (
    Advice,
    AdvisorService,
    QuerySignature,
    ScheduleAdvice,
)

__all__ = [
    "Advice",
    "AdvisorService",
    "LRUCache",
    "QuerySignature",
    "ScheduleAdvice",
    "ServiceMetrics",
    "TIERS",
]
