"""Service instrumentation: per-tier counters, batch histogram, latency.

Every query the :class:`~repro.serve.service.AdvisorService` answers is
accounted here, per tier:

* ``cache`` — tier-1 LRU answer-cache hits;
* ``batch`` — tier-2 micro-batched ``simulate_grouped_batch`` misses;
* ``search`` — tier-3 branch-and-bound fallbacks (machines too large to
  sweep);
* ``schedule`` — phased-workload schedule queries (the DP/beam scheduler
  over phase boundaries; see ``AdvisorService.query_schedule``);
* ``degraded`` — deadline-bounded answers served off the degradation
  ladder (roofline ranking / last-known-good / static fallback) instead
  of the exact tiers.

Orthogonally to the tier, every answer carries a *fidelity*
(``FIDELITIES``): ``exact`` for cache/batch/search/schedule answers,
``ranked``/``stale``/``fallback`` for the three degradation-ladder
rungs.  ``degraded_rate`` in the snapshot is the non-exact fraction —
the quantity ``benchmarks/serve_resilience.py`` commits a ceiling on.
Spec hot-swaps, guard rollbacks and batcher-thread restarts are counted
too, so chaos tests can assert the scenario they injected actually
unfolded.

Latencies land in preallocated per-tier numpy ring buffers (one float
store per sample — the hit path never grows a list), and percentiles are
computed lazily in :meth:`ServiceMetrics.snapshot`.  The *retrace
counter* is the serving contract made measurable: the service registers
every jit static key (machine fingerprint, thread classes, padded batch
bucket, placement-table shape) it evaluates through, and a key seen for
the first time is a retrace.  Steady-state serving — every bucket warmed
— must hold this at zero across any query stream; CI and the service
tests assert exactly that.
"""

from __future__ import annotations

import threading
from collections import Counter

import numpy as np

TIERS = ("cache", "batch", "search", "schedule", "degraded")

FIDELITIES = ("exact", "ranked", "stale", "fallback")


class _LatencyRing:
    """Fixed-size ring of the most recent latencies (seconds)."""

    def __init__(self, capacity: int):
        self._buf = np.zeros(capacity, np.float64)
        self._n = 0  # total samples ever recorded

    def record(self, seconds: float) -> None:
        self._buf[self._n % self._buf.shape[0]] = seconds
        self._n += 1

    def values(self) -> np.ndarray:
        return self._buf[: min(self._n, self._buf.shape[0])]

    @property
    def count(self) -> int:
        return self._n


class ServiceMetrics:
    """Thread-safe counters for one :class:`AdvisorService`.

    All mutation happens under one lock (the operations are a few hundred
    nanoseconds; the cache-hit fast path stays far under the committed
    qps floors with the lock in place).  ``snapshot`` returns plain
    python/numpy values so callers can JSON-serialize it directly.
    """

    def __init__(self, latency_window: int = 16384):
        self._lock = threading.Lock()
        self._latency_window = latency_window
        self.reset()

    def reset(self, *, keep_traces: bool = False) -> None:
        """Zero every counter.  ``keep_traces=True`` keeps the registered
        jit-key set (but zeroes the retrace count): the steady-state idiom
        — warm up, ``reset(keep_traces=True)``, serve, assert ``retraces
        == 0`` — only a genuinely new shape counts after the reset."""
        with getattr(self, "_lock", threading.Lock()):
            self.tier_counts = {tier: 0 for tier in TIERS}
            self.fidelity_counts = {f: 0 for f in FIDELITIES}
            self.batch_sizes: Counter = Counter()
            self.batch_calls = 0
            self.retraces = 0
            self.swaps = 0
            self.rollbacks = 0
            self.worker_restarts = 0
            if not keep_traces or not hasattr(self, "_trace_keys"):
                self._trace_keys: set = set()
            self._latency = {
                tier: _LatencyRing(self._latency_window) for tier in TIERS
            }

    # -- recording ---------------------------------------------------------

    def record_query(self, tier: str, seconds: float) -> None:
        """Count one answered query and its latency against ``tier``."""
        with self._lock:
            self.tier_counts[tier] += 1
            self._latency[tier].record(seconds)

    def record_fidelity(self, fidelity: str) -> None:
        """Count one served answer's fidelity (``exact`` / ``ranked`` /
        ``stale`` / ``fallback``)."""
        with self._lock:
            self.fidelity_counts[fidelity] += 1

    def record_swap(self) -> None:
        """Count one accepted spec hot-swap (epoch bump)."""
        with self._lock:
            self.swaps += 1

    def record_rollback(self) -> None:
        """Count one rejected/rolled-back recalibration."""
        with self._lock:
            self.rollbacks += 1

    def record_restart(self) -> None:
        """Count one self-healing batcher-thread restart."""
        with self._lock:
            self.worker_restarts += 1

    def record_batch(self, size: int) -> None:
        """Record one micro-batch flush of ``size`` coalesced queries."""
        with self._lock:
            self.batch_calls += 1
            self.batch_sizes[size] += 1

    def register_trace(self, key) -> bool:
        """Register a jit static key; returns True (and counts a retrace)
        iff the key is new.  Call *before* dispatching the jitted
        function so the counter reflects what jax is about to compile."""
        with self._lock:
            if key in self._trace_keys:
                return False
            self._trace_keys.add(key)
            self.retraces += 1
            return True

    # -- reading -----------------------------------------------------------

    def latency_percentiles(
        self, tier: str | None = None, qs=(50.0, 99.0)
    ) -> dict[str, float]:
        """``{"p50": ..., "p99": ...}`` in seconds over the recent window
        of one tier (or all tiers pooled when ``tier`` is None).  NaN when
        no samples have been recorded."""
        with self._lock:
            if tier is None:
                vals = np.concatenate(
                    [ring.values() for ring in self._latency.values()]
                )
            else:
                vals = self._latency[tier].values()
        if vals.size == 0:
            return {f"p{q:g}": float("nan") for q in qs}
        return {f"p{q:g}": float(np.percentile(vals, q)) for q in qs}

    def snapshot(self) -> dict:
        """A JSON-ready view: per-tier counts and p50/p99 latency (ms),
        batch-size histogram + mean, and the retrace counter."""
        with self._lock:
            counts = dict(self.tier_counts)
            fidelity = dict(self.fidelity_counts)
            sizes = dict(sorted(self.batch_sizes.items()))
            calls = self.batch_calls
            retraces = self.retraces
            swaps = self.swaps
            rollbacks = self.rollbacks
            restarts = self.worker_restarts
            lat = {
                tier: ring.values().copy()
                for tier, ring in self._latency.items()
            }
        n_fid = sum(fidelity.values())
        out: dict = {
            "queries": sum(counts.values()),
            "tier_counts": counts,
            "fidelity_counts": fidelity,
            "degraded_rate": (
                (n_fid - fidelity["exact"]) / n_fid if n_fid else 0.0
            ),
            "batch_calls": calls,
            "batch_size_hist": sizes,
            "retraces": retraces,
            "swaps": swaps,
            "rollbacks": rollbacks,
            "worker_restarts": restarts,
        }
        total = sum(n * size for size, n in sizes.items())
        out["mean_batch_size"] = total / calls if calls else 0.0
        for tier, vals in lat.items():
            if vals.size:
                out[f"{tier}_p50_ms"] = float(np.percentile(vals, 50)) * 1e3
                out[f"{tier}_p99_ms"] = float(np.percentile(vals, 99)) * 1e3
        pooled = np.concatenate(list(lat.values()))
        if pooled.size:
            out["p50_ms"] = float(np.percentile(pooled, 50)) * 1e3
            out["p99_ms"] = float(np.percentile(pooled, 99)) * 1e3
        return out
