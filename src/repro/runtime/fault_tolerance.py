"""Fault-tolerant training runtime.

The pieces a 1000+-node deployment needs (DESIGN.md §7), built so they are
testable on one host:

* ``TrainLoop`` — checkpoint/restart orchestration: periodic async saves,
  automatic resume from the latest valid manifest, deterministic data
  replay (the :class:`~repro.data.pipeline.TokenStream` is counter-based,
  so a restart replays the exact failed step).
* ``StragglerMonitor`` — EWMA step-time outlier detection with a pluggable
  reaction hook (in production: re-plan placement via
  ``repro.core.meshsig.advisor``; in tests: a recorded flag).
* ``remesh`` — elastic scaling: move a live state pytree onto a different
  mesh (512 -> 256 chips) through the topology-independent checkpoint
  shardings; used together with ``checkpoint.restore(..., shardings=...)``.
* ``FailureInjector`` — deterministic fault injection for integration
  tests (kill at step k, resume, verify bit-identical continuation).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import store
from repro.launch import mesh as mesh_lib


class StragglerMonitor:
    """Flags steps whose wall time exceeds ``threshold`` x the EWMA.

    On a real cluster the per-host step times come from the coordinator's
    heartbeats; the reaction hook can evict the straggler's host or ask the
    meshsig advisor for a placement that routes around it.
    """

    def __init__(self, alpha: float = 0.2, threshold: float = 2.0,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma: float | None = None
        self.flagged: list[tuple[int, float, float]] = []
        self.on_straggler = on_straggler

    def observe(self, step: int, seconds: float) -> bool:
        is_straggler = False
        if self.ewma is not None and seconds > self.threshold * self.ewma:
            is_straggler = True
            self.flagged.append((step, seconds, self.ewma))
            if self.on_straggler:
                self.on_straggler(step, seconds, self.ewma)
            # outliers do not poison the average
        else:
            self.ewma = (
                seconds
                if self.ewma is None
                else (1 - self.alpha) * self.ewma + self.alpha * seconds
            )
        return is_straggler


class FailureInjector:
    """Raises a simulated node failure at the configured steps."""

    class NodeFailure(RuntimeError):
        pass

    def __init__(self, fail_at: set[int] | None = None):
        self.fail_at = set(fail_at or ())
        self.fired: set[int] = set()

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.NodeFailure(f"injected node failure at step {step}")


@dataclass
class TrainLoop:
    """Checkpoint/restart training driver.

    ``state`` is any pytree (params, opt state); ``step_fn(state, step) ->
    (state, metrics)`` hides the jit'd train step + data plumbing.
    """

    step_fn: Callable[[Any, int], tuple[Any, dict]]
    ckpt_dir: str | Path
    save_every: int = 50
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    injector: FailureInjector | None = None

    def resume_step(self) -> int | None:
        return store.latest_step(self.ckpt_dir)

    def run(self, state: Any, n_steps: int, *, start_step: int | None = None) -> tuple[Any, int, list[dict]]:
        """Run up to ``n_steps`` total; resumes from the latest checkpoint
        when ``start_step`` is None.  Returns (state, step, metrics)."""
        ckpt = store.AsyncCheckpointer(self.ckpt_dir)
        step = start_step
        if step is None:
            latest = self.resume_step()
            if latest is not None:
                like = jax.eval_shape(lambda x: x, state)
                state = store.restore(self.ckpt_dir, latest, like)
                step = latest
            else:
                step = 0
        history: list[dict] = []
        while step < n_steps:
            if self.injector is not None:
                self.injector.check(step)
            t0 = time.time()
            state, metrics = self.step_fn(state, step)
            jax.block_until_ready(jax.tree.leaves(state)[0])
            dt = time.time() - t0
            self.monitor.observe(step, dt)
            history.append({"step": step, "seconds": dt, **metrics})
            step += 1
            if step % self.save_every == 0 or step == n_steps:
                ckpt.save(step, state)
        ckpt.wait()
        return state, step, history


def remesh(state: Any, spec_tree: Any, new_mesh) -> Any:
    """Elastic re-shard: place ``state`` onto ``new_mesh`` according to the
    logical ``spec_tree`` (the same tree used at init).  Works across
    device-count changes because logical specs are mesh-relative."""
    from repro.parallel import context as ctx

    with ctx.use_mesh(new_mesh):
        shardings = mesh_lib.tree_shardings(new_mesh, spec_tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, shardings
    )
