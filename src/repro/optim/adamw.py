"""AdamW with configurable moment dtype + schedules + global-norm clipping.

Implemented natively (no optax in this environment).  Moments inherit the
parameter sharding, so under FSDP the optimizer state is ZeRO-sharded for
free.  ``moment_dtype="bfloat16"`` halves optimizer HBM for the 398B config
(jamba) at the cost of moment precision — the standard large-scale
trade-off (noted in DESIGN.md §7).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import Array


class AdamWState(NamedTuple):
    step: Array  # scalar int32
    m: Any  # pytree like params
    v: Any  # pytree like params


def init(params: Any, moment_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt if jnp.issubdtype(p.dtype, jnp.floating) else p.dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _decay_mask(path) -> bool:
    """Weight decay applies to matrices only (not norms/biases/1-D)."""
    name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return "norm" not in name and name not in ("dt_bias", "conv_b", "D", "A_log")


def update(
    grads: Any,
    state: AdamWState,
    params: Any,
    *,
    lr: Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[Any, AdamWState]:
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p, m, v
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g32 * g32 * (1 - b2)
        upd = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        if _decay_mask(path):
            upd = upd + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat = jax.tree_util.tree_map_with_path(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[Array], Array]:
    def lr(step: Array) -> Array:
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        prog = jnp.clip(
            (s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup_steps, warm, cos)

    return lr
