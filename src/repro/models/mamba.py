"""Mamba-1 (selective state space) block.

TPU adaptation notes:

* Training/prefill uses a **chunked scan**: an outer ``lax.scan`` over
  sequence chunks carries the (B, d_inner, N) state, and a parallel
  ``associative_scan`` runs inside each chunk.  This bounds the
  materialized state tensor to (B, chunk, d_inner, N) — the VMEM-sized
  working set the Pallas kernel (``repro.kernels.mamba_scan``) tiles — while
  keeping O(log chunk) depth instead of the GPU kernel's
  thread-sequential recurrence.
* All channel dimensions (``d_inner``) are independent across the scan, so
  tensor parallelism shards ``d_inner`` over the ``model`` axis with zero
  per-step communication; only the small x_proj/dt_proj matmuls psum.
* Decode carries (conv window, ssm state) — O(1) per token, which is why
  SSM/hybrid archs run the ``long_500k`` cell.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig
from repro.parallel import context as ctx

DEFAULT_CHUNK = 4096  # see EXPERIMENTS.md SPerf a1/a2: outer-loop carry copies dominate, fewer chunks win


def init_mamba_params(key: Array, cfg: ModelConfig, dtype) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, kconv = cfg.dt_rank_actual, cfg.ssm_conv
    keys = jax.random.split(key, 6)
    scale = d**-0.5
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    return {
        "in_proj": (jax.random.normal(keys[0], (d, 2 * di)) * scale).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (kconv, di)) * kconv**-0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(keys[2], (di, dtr + 2 * n)) * di**-0.5).astype(dtype),
        "dt_proj": (jax.random.normal(keys[3], (dtr, di)) * dtr**-0.5).astype(dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(a).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(keys[4], (di, d)) * di**-0.5).astype(dtype),
    }


def mamba_param_specs(cfg: ModelConfig) -> dict:
    return {
        "in_proj": ("fsdp", "tp"),
        "conv_w": (None, "tp"),
        "conv_b": ("tp",),
        "x_proj": ("tp", None),
        "dt_proj": (None, "tp"),
        "dt_bias": ("tp",),
        "A_log": ("tp", None),
        "D": ("tp",),
        "out_proj": ("tp", "fsdp"),
    }


class MambaCache(NamedTuple):
    conv: Array  # (B, K-1, d_inner) — trailing conv window
    ssm: Array  # (B, d_inner, N) — recurrent state


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> MambaCache:
    return MambaCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        ssm=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    )


def _causal_conv(x: Array, w: Array, b: Array, history: Array | None) -> Array:
    """Depthwise causal conv over seq; (B, S, di), kernel (K, di)."""
    k = w.shape[0]
    if history is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = history.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _ssm_inputs(cfg: ModelConfig, p: dict, x_conv: Array):
    """Shared pre-scan projections: dt, dA-exponent, B, C."""
    dtr, n = cfg.dt_rank_actual, cfg.ssm_state
    x_dbl = x_conv @ p["x_proj"]  # (B, S, dtr + 2N) — psum over tp
    dt, b_ssm, c_ssm = jnp.split(x_dbl, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"].astype(x_conv.dtype))
    dt = ctx.shard(dt.astype(jnp.float32), "batch", None, "tp")
    a = -jnp.exp(p["A_log"])  # (di, N)
    return dt, a, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def _combine(l, r):
    al, bl = l
    ar, br = r
    return al * ar, bl * ar + br


@jax.custom_vjp
def _linear_scan(da: Array, dbx: Array, h0: Array) -> Array:
    """h_t = da_t * h_{t-1} + dbx_t over axis 1; returns all h_t.

    §Perf iteration a5: XLA's autodiff of ``associative_scan`` materializes
    f32 even/odd slice pyramids (~50% of the falcon-mamba train cell's HBM
    bytes).  The backward of a *linear* recurrence is itself a linear
    recurrence (reverse time): lambda_t = dh_t + da_{t+1} * lambda_{t+1},
    then d(da_t) = lambda_t * h_{t-1} and d(dbx_t) = lambda_t — one more
    scan plus elementwise work, no pyramid.
    """
    cum_a, cum_b = jax.lax.associative_scan(_combine, (da, dbx), axis=1)
    return cum_a * h0[:, None].astype(cum_a.dtype) + cum_b


def _linear_scan_fwd(da, dbx, h0):
    h = _linear_scan(da, dbx, h0)
    return h, (da, h, h0)


def _linear_scan_bwd(res, dh):
    da, h, h0 = res
    dh = dh.astype(da.dtype)
    # a_{t+1}, with a_{T+1} := 0 (nothing downstream of the last step)
    a_next = jnp.concatenate([da[:, 1:], jnp.zeros_like(da[:, :1])], axis=1)
    rev = lambda t: jnp.flip(t, axis=1)
    _, lam_rev = jax.lax.associative_scan(
        _combine, (rev(a_next), rev(dh)), axis=1
    )
    lam = rev(lam_rev)  # lambda_t
    h_prev = jnp.concatenate(
        [h0[:, None].astype(h.dtype), h[:, :-1]], axis=1
    )
    d_da = lam * h_prev
    d_dbx = lam
    d_h0 = (da[:, 0] * lam[:, 0]).astype(h0.dtype)
    return d_da, d_dbx, d_h0


_linear_scan.defvjp(_linear_scan_fwd, _linear_scan_bwd)


def _chunk_scan(da: Array, dbx: Array, h0: Array):
    """Within-chunk linear scan.  ``da``/``dbx``: (B, c, di, N);
    ``h0``: (B, di, N).  Returns per-step states and the final state."""
    h = _linear_scan(da, dbx, h0)
    return h, h[:, -1]


def mamba_mixer(
    cfg: ModelConfig,
    p: dict,
    x: Array,  # (B, S, D)
    *,
    chunk: int = DEFAULT_CHUNK,
) -> Array:
    """Training/prefill path (full sequence)."""
    B, S, D = x.shape
    di, n = cfg.d_inner, cfg.ssm_state

    xz = x @ p["in_proj"]  # (B, S, 2*di)
    xz = ctx.shard(xz, "batch", None, "tp")
    xin, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"], None))
    x_conv = ctx.shard(x_conv, "batch", None, "tp")

    dt, a, b_ssm, c_ssm = _ssm_inputs(cfg, p, x_conv)
    xf = x_conv.astype(jnp.float32)

    c = min(chunk, S)
    assert S % c == 0, (S, c)
    n_chunks = S // c

    def step(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * c, c, axis=1)
        dt_c, b_c, c_c, x_c = sl(dt), sl(b_ssm), sl(c_ssm), sl(xf)
        da = jnp.exp(dt_c[..., None] * a[None, None])  # (B, c, di, N)
        dbx = (dt_c * x_c)[..., None] * b_c[:, :, None, :]
        # §Perf iteration a3: the associative-scan level tensors dominate
        # the memory term; storing them in bf16 halves that traffic.  The
        # chunk-boundary state stays f32, bounding drift to one chunk's
        # log-depth of combines.
        hs, h_last = _chunk_scan(
            da.astype(jnp.bfloat16), dbx.astype(jnp.bfloat16), h
        )
        # a4: contract in bf16 with f32 accumulation — casting hs back to
        # f32 would re-materialize the (B, c, di, N) tensor it just saved.
        y = jnp.einsum(
            "bcdn,bcn->bcd",
            hs,
            c_c.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
        return h_last.astype(jnp.float32), y

    h0 = jnp.zeros((B, di, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(n_chunks))
    # ys: (n_chunks, B, c, di) -> (B, S, di)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    y = y + xf * p["D"][None, None]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = ctx.shard(y, "batch", None, "tp")
    out = y @ p["out_proj"]
    return ctx.shard(out, "batch", None, None)


def mamba_decode(
    cfg: ModelConfig,
    p: dict,
    x: Array,  # (B, 1, D)
    cache: MambaCache,
) -> tuple[Array, MambaCache]:
    """O(1) single-token step."""
    B, _, D = x.shape
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)  # (B, 1, di)
    x_conv = jax.nn.silu(
        _causal_conv(xin, p["conv_w"], p["conv_b"], cache.conv)
    )
    new_conv = jnp.concatenate([cache.conv[:, 1:], xin.astype(cache.conv.dtype)], axis=1)

    dt, a, b_ssm, c_ssm = _ssm_inputs(cfg, p, x_conv)
    xf = x_conv.astype(jnp.float32)
    da = jnp.exp(dt[:, 0, :, None] * a[None])  # (B, di, N)
    dbx = (dt[:, 0] * xf[:, 0])[..., None] * b_ssm[:, 0, None, :]
    h = cache.ssm * da + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0]) + xf[:, 0] * p["D"][None]
    y = (y[:, None] * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    return ctx.shard(out, "batch", None, None), MambaCache(conv=new_conv, ssm=h)
