"""Attention: blocked (flash-style) training/prefill path + cached decode.

Design notes (hardware adaptation):

* The training path is a statically *blocked* online-softmax attention —
  the pure-JAX twin of the Pallas kernel in ``repro.kernels.flash_attention``.
  Blocks that are fully masked (future causal blocks, blocks outside a
  sliding window) are skipped at trace time, so SWA prefill at 32k touches
  only O(S * window) work.
* GQA is computed by repeating K/V heads per block: the full Q-head dim is
  then cleanly TP-shardable (every assigned arch has n_heads % 16 == 0),
  while K/V stay small.  The Pallas kernel avoids the repeat in VMEM.
* Decode keeps the KV cache *sequence-sharded* ("seq" logical dim) so a
  32k x 128 cache fits per-chip HBM; the online-softmax reduction over the
  sharded dim becomes a psum — flash-decode in GSPMD form.
* SWA decode uses a ring buffer of window size: 500k-token contexts cost
  O(window) memory (this is why SWA archs run the ``long_500k`` cell).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig
from repro.models.layers import rope, softcap
from repro.parallel import context as ctx

DEFAULT_BLOCK_Q = 1024
DEFAULT_BLOCK_KV = 1024

_NEG_INF = -1e30


def init_attn_params(key: Array, cfg: ModelConfig, dtype) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d**-0.5
    return {
        "wq": (jax.random.normal(k1, (d, h * dh)) * scale).astype(dtype),
        "wk": (jax.random.normal(k2, (d, kv * dh)) * scale).astype(dtype),
        "wv": (jax.random.normal(k3, (d, kv * dh)) * scale).astype(dtype),
        "wo": (jax.random.normal(k4, (h * dh, d)) * (h * dh) ** -0.5).astype(dtype),
    }


def attn_param_specs(cfg: ModelConfig) -> dict:
    return {
        "wq": ("fsdp", "tp"),
        "wk": ("fsdp", "tp"),
        "wv": ("fsdp", "tp"),
        "wo": ("tp", "fsdp"),
    }


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (training / prefill / encoder)
# ---------------------------------------------------------------------------


def _block_bounds(size: int, block: int) -> list[tuple[int, int]]:
    if size <= block:
        return [(0, size)]
    assert size % block == 0, (size, block)
    return [(i * block, block) for i in range(size // block)]


def blocked_attention(
    q: Array,  # (B, Sq, H, dh)
    k: Array,  # (B, Skv, Kv, dh)
    v: Array,  # (B, Skv, Kv, dh)
    *,
    causal: bool,
    window: int = 0,  # 0 = unbounded
    logit_cap: float = 0.0,
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
) -> Array:
    """Statically-blocked attention with online softmax.

    Fully-masked blocks are skipped at trace time; partially-masked blocks
    get an explicit iota mask; interior blocks skip masking entirely.
    """
    B, Sq, H, dh = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    scale = dh**-0.5

    q_blocks = _block_bounds(Sq, block_q)
    kv_blocks = _block_bounds(Skv, block_kv)

    outs = []
    for q0, bq in q_blocks:
        qi = q[:, q0 : q0 + bq].astype(jnp.float32) * scale
        row0, row1 = q_offset + q0, q_offset + q0 + bq - 1  # absolute rows
        m = jnp.full((B, H, bq), _NEG_INF, jnp.float32)
        l = jnp.zeros((B, H, bq), jnp.float32)
        acc = jnp.zeros((B, H, bq, dh), jnp.float32)
        for k0, bk in kv_blocks:
            col0, col1 = k0, k0 + bk - 1
            if causal and col0 > row1:
                continue  # block entirely in the future
            if window and col1 < row0 - window + 1:
                continue  # block entirely outside the sliding window
            kj = jnp.repeat(k[:, k0 : k0 + bk], G, axis=2)  # (B, bk, H, dh)
            vj = jnp.repeat(v[:, k0 : k0 + bk], G, axis=2)
            logits = jnp.einsum(
                "bqhd,bshd->bhqs", qi, kj.astype(jnp.float32)
            )  # (B, H, bq, bk)
            if logit_cap > 0.0:
                logits = softcap(logits, logit_cap)
            needs_causal = causal and col1 > row0
            needs_window = window and col0 < row1 - window + 1
            if needs_causal or needs_window:
                rows = row0 + jnp.arange(bq)[:, None]
                cols = col0 + jnp.arange(bk)[None, :]
                ok = jnp.ones((bq, bk), bool)
                if needs_causal:
                    ok &= cols <= rows
                if needs_window:
                    ok &= cols > rows - window
                logits = jnp.where(ok[None, None], logits, _NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p, vj.astype(jnp.float32)
            )
            m = m_new
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, H, bq, dh)
        outs.append(out.transpose(0, 2, 1, 3))  # (B, bq, H, dh)
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def mha(
    cfg: ModelConfig,
    p: dict,
    x: Array,  # (B, S, D)
    positions: Array,  # (S,) absolute positions
    *,
    kind: str = "full",  # full | swa
    causal: bool = True,
    use_rope: bool = True,
    kv_override: tuple[Array, Array] | None = None,  # cross-attention
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
) -> Array:
    """Full multi-head attention layer (projections + blocked core)."""
    B, S, D = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, h, dh)
    q = ctx.shard(q, "batch", None, "tp", None)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(B, S, kv, dh)
        v = (x @ p["wv"]).reshape(B, S, kv, dh)
        if use_rope:
            q = rope(q, positions[None], cfg.rope_theta)
            k = rope(k, positions[None], cfg.rope_theta)
        k = ctx.shard(k, "batch", None, None, None)
        v = ctx.shard(v, "batch", None, None, None)
    else:
        k, v = kv_override
    out = blocked_attention(
        q,
        k,
        v,
        causal=causal,
        window=cfg.sliding_window if kind == "swa" else 0,
        logit_cap=cfg.attn_logit_softcap,
        block_q=block_q,
        block_kv=block_kv,
    )
    out = ctx.shard(out, "batch", None, "tp", None)
    out = out.reshape(B, S, h * dh) @ p["wo"]
    return ctx.shard(out, "batch", None, None)


def cross_kv(cfg: ModelConfig, p: dict, enc_out: Array) -> tuple[Array, Array]:
    """Project encoder output once; reused by every decode step."""
    B, S, _ = enc_out.shape
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(B, S, kv, dh)
    v = (enc_out @ p["wv"]).reshape(B, S, kv, dh)
    k = ctx.shard(k, "cache_batch", "cache_seq", None, None)
    v = ctx.shard(v, "cache_batch", "cache_seq", None, None)
    return k, v


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: Array  # (B, S_cache, Kv, dh) — ring buffer of size window for SWA
    v: Array


def init_kv_cache(
    cfg: ModelConfig, batch: int, seq_len: int, *, kind: str, dtype
) -> KVCache:
    size = min(seq_len, cfg.sliding_window) if kind == "swa" else seq_len
    shape = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def cache_pspec_dims() -> tuple:
    return ("cache_batch", "cache_seq", None, None)


def mha_decode(
    cfg: ModelConfig,
    p: dict,
    x: Array,  # (B, 1, D)
    cache: KVCache,
    pos: Array,  # scalar int32: index of the new token
    *,
    kind: str = "full",
    use_rope: bool = True,
    cross: bool = False,  # attend a static cross cache; no update, no mask
) -> tuple[Array, KVCache]:
    B, _, D = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = h // kv
    S = cache.k.shape[1]
    windowed = kind == "swa" and S == cfg.sliding_window

    q = (x @ p["wq"]).reshape(B, h, dh)
    # The cache is sequence-sharded; keep q replicated over "tp" so the
    # online-softmax reduction becomes a psum over the cache shards
    # (flash-decode) instead of a cache all-gather.
    q = ctx.shard(q, "batch", None, None)
    if use_rope and not cross:
        q = rope(q[:, None], pos[None, None], cfg.rope_theta)[:, 0]

    if cross:
        k, v = cache.k, cache.v
        valid = None
    else:
        k_new = (x @ p["wk"]).reshape(B, 1, kv, dh)
        v_new = (x @ p["wv"]).reshape(B, 1, kv, dh)
        if use_rope:
            k_new = rope(k_new, pos[None, None], cfg.rope_theta)
        slot = pos % S if windowed else jnp.minimum(pos, S - 1)
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, 1)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, 1)
        k = ctx.shard(k, *cache_pspec_dims())
        v = ctx.shard(v, *cache_pspec_dims())
        idx = jnp.arange(S)
        if windowed:
            valid = idx < jnp.minimum(pos + 1, S)  # ring: all slots live once full
        else:
            valid = idx <= pos

    # Flash-decode sharding (§Perf iterations b1+b2):
    # b1 — the logits chain must STAY sequence-sharded like the cache;
    #      without constraints GSPMD reshards the whole cache to a
    #      head-sharded layout (involuntary full rematerialization:
    #      ~64 GB of all-gather per decode step on llama3 decode_32k).
    #      With them the softmax reduction over the sharded seq dim
    #      lowers to a small psum (link bytes 64.5 GB -> 30 MB, 2149x).
    # b2 — GQA via a grouped einsum against the UNREPEATED cache:
    #      jnp.repeat materialized G x the cache per step (~34 GB/layer
    #      HBM traffic on llama3).  No sharding conflict: the cache is
    #      seq-sharded, heads stay local.
    # b3 — keep the QK/PV dots in the cache dtype with f32 ACCUMULATION
    #      (preferred_element_type) instead of materializing f32 copies of
    #      every K/V slice (~268 MB/layer of pure convert traffic).
    qg = q.reshape(B, kv, G, dh)
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k, preferred_element_type=jnp.float32
    ) * (dh**-0.5)
    logits = ctx.shard(logits, "cache_batch", None, None, "cache_seq")
    if cfg.attn_logit_softcap > 0.0:
        logits = softcap(logits, cfg.attn_logit_softcap)
    if valid is not None:
        logits = jnp.where(valid[None, None, None], logits, _NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd",
        w.astype(v.dtype),
        v,
        preferred_element_type=jnp.float32,
    )
    out = out.astype(x.dtype).reshape(B, 1, h * dh) @ p["wo"]
    new_cache = cache if cross else KVCache(k=k, v=v)
    return ctx.shard(out, "batch", None, None), new_cache
