"""Shared layers: norms, rotary embeddings, SwiGLU FFN, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.parallel import context as ctx


def rms_norm(x: Array, weight: Array, eps: float) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def softcap(x: Array, cap: float) -> Array:
    """gemma2-style logit soft capping."""
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary position embedding.

    ``x``: (..., seq, heads, head_dim); ``positions``: (..., seq) int32.
    """
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    """SwiGLU FFN with Megatron-style TP sharding annotations:
    ``w_gate``/``w_up`` are column-parallel, ``w_down`` row-parallel."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = ctx.shard(h, "batch", None, "tp")
    out = h @ w_down
    return ctx.shard(out, "batch", None, None)


def embed(tokens: Array, table: Array) -> Array:
    out = jnp.take(table, tokens, axis=0)
    return ctx.shard(out, "batch", None, None)


def unembed(x: Array, table: Array, *, transpose: bool, cap: float = 0.0) -> Array:
    """Project to (padded) vocab logits; vocab dim is TP-sharded."""
    logits = x @ (table.T if transpose else table)
    logits = ctx.shard(logits, "batch", None, "tp")
    if cap > 0.0:
        logits = softcap(logits, cap)
    return logits


def sinusoidal_positions(length: int, dim: int) -> Array:
    """Fixed sinusoidal embeddings (whisper encoder)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    idx = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angles = pos / jnp.power(10_000.0, 2 * idx / dim)
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)
