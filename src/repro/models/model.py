"""Model assembly: parameter trees, forward passes, decode steps.

One code path covers all 10 assigned architectures through the config's
block pattern:

* layers are stacked into *groups* of ``cfg.group_size`` (the period of the
  arch's layer pattern — 8 for jamba's 1:7 attn:mamba interleave, 2 for
  gemma2's local/global alternation) and scanned with ``lax.scan`` +
  ``jax.checkpoint``, so HLO size and compile time stay bounded at 512
  devices and activation memory stays at O(groups) layer inputs;
* each *slot* within a group has a statically-known mixer kind
  (attn full/SWA | mamba) and FFN kind (dense | MoE | none);
* enc-dec (whisper) adds an encoder stack and per-layer cross-attention;
* modality frontends are stubs per the assignment: precomputed frame/patch
  embeddings arrive as inputs.

Approximations vs the exact published checkpoints (recorded here and in
DESIGN.md): RMSNorm and SwiGLU are used uniformly (whisper really uses
LayerNorm + GELU; gemma2 adds post-norms), and whisper's decoder uses a
learned position table.  These keep the backbone math/shape/sharding
behaviour identical without per-arch layer forks.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.layers import embed, rms_norm, sinusoidal_positions, softcap, swiglu, unembed
from repro.parallel import context as ctx

# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _dtype(name: str):
    return jnp.dtype(name)


# Leaves kept in float32 regardless of the compute policy: norm scales (the
# norm itself computes in f32), SSM dynamics (A_log/D: exp'd), and router
# logits (top-k stability).
_KEEP_F32_KEYS = ("A_log", "D", "router", "dt_bias")


def cast_for_compute(cfg: ModelConfig, params: dict) -> dict:
    """Mixed-precision policy: master params stay in ``param_dtype`` (the
    optimizer's view); matmul weights are cast to ``compute_dtype`` at the
    step boundary."""
    compute = _dtype(cfg.compute_dtype)

    def cast(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if "norm" in str(name) or name in _KEEP_F32_KEYS:
            return x
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(compute)
        return x

    return jax.tree_util.tree_map_with_path(cast, params)


def _init_ffn(key: Array, cfg: ModelConfig, kind: str, dtype) -> dict:
    if kind == "moe":
        return moe_mod.init_moe_params(key, cfg, dtype)
    if kind == "none":
        return {}
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * d**-0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * d**-0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * f**-0.5).astype(dtype),
    }


def _ffn_specs(cfg: ModelConfig, kind: str) -> dict:
    if kind == "moe":
        return moe_mod.moe_param_specs(cfg)
    if kind == "none":
        return {}
    return {"w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp")}


def slot_kinds(cfg: ModelConfig, slot: int) -> tuple[str, str, str]:
    """(mixer, attn_kind, ffn) for a slot position within a group."""
    mixer = cfg.mixer_kind(slot)
    akind = cfg.attn_kind(slot)
    ffn = cfg.ffn_kind(slot)
    if cfg.d_ff == 0 and ffn == "dense":
        ffn = "none"  # attention-free mamba archs: the mixer is the layer
    return mixer, akind, ffn


def _init_slot(key: Array, cfg: ModelConfig, slot: int, dtype, cross: bool) -> dict:
    mixer, _, ffn = slot_kinds(cfg, slot)
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if mixer == "attn":
        p["mixer"] = attn.init_attn_params(k1, cfg, dtype)
    else:
        p["mixer"] = mb.init_mamba_params(k1, cfg, dtype)
    if ffn != "none":
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ffn"] = _init_ffn(k2, cfg, ffn, dtype)
    if cross:
        p["norm_cross"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["cross"] = attn.init_attn_params(k3, cfg, dtype)
    return p


def _slot_specs(cfg: ModelConfig, slot: int, cross: bool) -> dict:
    mixer, _, ffn = slot_kinds(cfg, slot)
    p: dict[str, Any] = {"norm1": (None,)}
    if mixer == "attn":
        p["mixer"] = attn.attn_param_specs(cfg)
    else:
        p["mixer"] = mb.mamba_param_specs(cfg)
    if ffn != "none":
        p["norm2"] = (None,)
        p["ffn"] = _ffn_specs(cfg, ffn)
    if cross:
        p["norm_cross"] = (None,)
        p["cross"] = attn.attn_param_specs(cfg)
    return p


def _stack_groups(init_one, n_groups: int, key: Array):
    keys = jax.random.split(key, n_groups)
    return jax.vmap(init_one)(keys)


def init_params(cfg: ModelConfig, key: Array) -> dict:
    dtype = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    period = cfg.group_size

    groups = {
        f"slot{s}": _stack_groups(
            functools.partial(_init_slot, cfg=cfg, slot=s, dtype=dtype, cross=False),
            cfg.n_groups,
            jax.random.fold_in(keys[0], s),
        )
        for s in range(period)
    }
    params: dict[str, Any] = {
        "embed": {
            "table": (
                jax.random.normal(keys[1], (cfg.padded_vocab, cfg.d_model)) * 0.02
            ).astype(dtype)
        },
        "groups": groups,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[2], (cfg.d_model, cfg.padded_vocab)) * 0.02
        ).astype(dtype)
    if cfg.frontend == "vit_patches":
        params["frontend"] = {
            "proj": (
                jax.random.normal(keys[3], (cfg.d_model, cfg.d_model))
                * cfg.d_model**-0.5
            ).astype(dtype)
        }
    if cfg.is_encoder_decoder:
        params["encoder"] = {
            "groups": {
                "slot0": _stack_groups(
                    functools.partial(
                        _init_slot, cfg=cfg, slot=0, dtype=dtype, cross=False
                    ),
                    cfg.encoder_layers,
                    keys[4],
                )
            },
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        # decoder groups get cross-attention; rebuild slot0 with cross=True
        params["groups"] = {
            "slot0": _stack_groups(
                functools.partial(_init_slot, cfg=cfg, slot=0, dtype=dtype, cross=True),
                cfg.n_groups,
                keys[5],
            )
        }
        params["dec_pos"] = (
            jax.random.normal(keys[6], (cfg.max_target_len, cfg.d_model)) * 0.02
        ).astype(dtype)
    return params


def param_specs(cfg: ModelConfig) -> dict:
    """Logical-dim tree matching ``init_params`` (group leaves gain a
    leading stacked dim, replicated)."""
    period = cfg.group_size
    cross = cfg.is_encoder_decoder

    def stack(tree):
        return jax.tree.map(
            lambda dims: (None, *dims), tree, is_leaf=lambda x: type(x) is tuple
        )

    specs: dict[str, Any] = {
        "embed": {"table": ("tp", "fsdp")},
        "groups": {
            f"slot{s}": stack(_slot_specs(cfg, s, cross=cross))
            for s in range(period)
        },
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ("fsdp", "tp")
    if cfg.frontend == "vit_patches":
        specs["frontend"] = {"proj": (None, None)}
    if cfg.is_encoder_decoder:
        specs["groups"] = {"slot0": stack(_slot_specs(cfg, 0, cross=True))}
        specs["encoder"] = {
            "groups": {"slot0": stack(_slot_specs(cfg, 0, cross=False))},
            "final_norm": (None,),
        }
        specs["dec_pos"] = (None, None)
    return specs


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _apply_slot(
    cfg: ModelConfig,
    slot: int,
    p: dict,
    x: Array,
    positions: Array,
    *,
    causal: bool,
    use_rope: bool,
    enc_out: Array | None,
    aux: Array,
) -> tuple[Array, Array]:
    mixer, akind, ffn = slot_kinds(cfg, slot)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if mixer == "attn":
        h = attn.mha(
            cfg, p["mixer"], h, positions, kind=akind, causal=causal, use_rope=use_rope
        )
    else:
        h = mb.mamba_mixer(cfg, p["mixer"], h)
    x = x + h
    if enc_out is not None:
        h = rms_norm(x, p["norm_cross"], cfg.norm_eps)
        kv = attn.cross_kv(cfg, p["cross"], enc_out)
        h = attn.mha(
            cfg,
            p["cross"],
            h,
            positions,
            causal=False,
            use_rope=False,
            kv_override=kv,
        )
        x = x + h
    if ffn != "none":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if ffn == "moe":
            h, a = moe_mod.moe_apply(cfg, p["ffn"], h)
            aux = aux + a
        else:
            h = swiglu(h, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"])
        x = x + h
    return x, aux


def _run_stack(
    cfg: ModelConfig,
    groups: dict,
    x: Array,
    positions: Array,
    *,
    causal: bool,
    use_rope: bool,
    enc_out: Array | None = None,
    remat: bool = True,
) -> tuple[Array, Array]:
    period = len(groups)

    def body(carry, gp):
        x, aux = carry
        for s in range(period):
            x, aux = _apply_slot(
                cfg,
                s,
                gp[f"slot{s}"],
                x,
                positions,
                causal=causal,
                use_rope=use_rope,
                enc_out=enc_out,
                aux=aux,
            )
        x = ctx.shard(x, "batch", None, None)
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), groups)
    return x, aux


def _embed_decoder_inputs(
    cfg: ModelConfig, params: dict, batch: dict
) -> tuple[Array, Array, Array | None]:
    """Returns (x, positions, enc_out)."""
    compute = _dtype(cfg.compute_dtype)
    enc_out = None
    if cfg.is_encoder_decoder:
        frames = batch["enc_frames"].astype(compute)  # (B, S_enc, D) stub frontend
        pos_e = sinusoidal_positions(frames.shape[1], cfg.d_model).astype(compute)
        h = ctx.shard(frames + pos_e[None], "batch", None, None)
        enc_out, _ = _run_stack(
            cfg,
            params["encoder"]["groups"],
            h,
            jnp.arange(frames.shape[1]),
            causal=False,
            use_rope=False,
        )
        enc_out = rms_norm(enc_out, params["encoder"]["final_norm"], cfg.norm_eps)

    tokens = batch["tokens"]
    x = embed(tokens, params["embed"]["table"]).astype(compute)
    if cfg.name.startswith("gemma2"):
        x = x * jnp.asarray(cfg.d_model**0.5, compute)  # gemma convention
    if cfg.is_encoder_decoder:
        x = x + params["dec_pos"][None, : x.shape[1]].astype(compute)
    if cfg.frontend == "vit_patches":
        patches = batch["patch_embeds"].astype(compute) @ params["frontend"]["proj"]
        x = jnp.concatenate([patches, x], axis=1)  # image tokens first
    x = ctx.shard(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])
    return x, positions, enc_out


def _unembed(cfg: ModelConfig, params: dict, x: Array) -> Array:
    if cfg.tie_embeddings:
        return unembed(x, params["embed"]["table"], transpose=True, cap=cfg.final_logit_softcap)
    return unembed(x, params["lm_head"], transpose=False, cap=cfg.final_logit_softcap)


def forward_hidden(cfg: ModelConfig, params: dict, batch: dict) -> tuple[Array, Array]:
    """Full-sequence forward up to the final norm; returns (hidden, aux)."""
    params = cast_for_compute(cfg, params)
    x, positions, enc_out = _embed_decoder_inputs(cfg, params, batch)
    use_rope = not cfg.is_encoder_decoder
    x, aux = _run_stack(
        cfg,
        params["groups"],
        x,
        positions,
        causal=True,
        use_rope=use_rope,
        enc_out=enc_out,
    )
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def forward(cfg: ModelConfig, params: dict, batch: dict) -> tuple[Array, Array]:
    """Full-sequence forward; returns (logits, aux_loss)."""
    x, aux = forward_hidden(cfg, params, batch)
    return _unembed(cfg, params, x), aux


def loss_fn(
    cfg: ModelConfig, params: dict, batch: dict, *, z_loss: float = 1e-4, aux_weight: float = 1e-2
) -> tuple[Array, dict]:
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.frontend == "vit_patches":  # loss only over the text positions
        logits = logits[:, -labels.shape[1] :]
    logits = logits.astype(jnp.float32)
    # mask padded vocab rows out of the softmax
    vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    logits = jnp.where(vocab_ok[None, None], logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - true_logit).mean()
    total = nll + z_loss * (lse**2).mean() + aux_weight * aux
    return total, {"nll": nll, "aux": aux, "lse": lse.mean()}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype) -> dict:
    """Decode cache pytree; leaves stacked over groups."""
    period = cfg.group_size

    def one_slot(s):
        mixer, akind, _ = slot_kinds(cfg, s)
        if mixer == "attn":
            size = cfg.max_target_len if cfg.is_encoder_decoder else seq_len
            return attn.init_kv_cache(cfg, batch, size, kind=akind, dtype=dtype)
        return mb.init_mamba_cache(cfg, batch, dtype)

    def stack(tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_groups, *x.shape)), tree)

    cache: dict[str, Any] = {
        f"slot{s}": stack(one_slot(s)) for s in range(period)
    }
    if cfg.is_encoder_decoder:
        kv, dh = cfg.n_kv_heads, cfg.head_dim
        cache["cross"] = attn.KVCache(
            k=jnp.zeros((cfg.n_groups, batch, seq_len, kv, dh), dtype),
            v=jnp.zeros((cfg.n_groups, batch, seq_len, kv, dh), dtype),
        )
    return cache


def cache_specs(cfg: ModelConfig) -> dict:
    """Logical dims for every cache leaf (leading group dim replicated)."""
    period = cfg.group_size
    kv_dims = (None, "cache_batch", "cache_seq", None, None)
    mamba_dims = {
        "conv": (None, "cache_batch", None, "tp"),
        "ssm": (None, "cache_batch", "tp", None),
    }
    out: dict[str, Any] = {}
    for s in range(period):
        mixer, _, _ = slot_kinds(cfg, s)
        if mixer == "attn":
            out[f"slot{s}"] = attn.KVCache(k=kv_dims, v=kv_dims)
        else:
            out[f"slot{s}"] = mb.MambaCache(**mamba_dims)
    if cfg.is_encoder_decoder:
        out["cross"] = attn.KVCache(k=kv_dims, v=kv_dims)
    return out


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    tokens: Array,  # (B, 1) int32
    pos: Array,  # scalar int32 — position of this token
) -> tuple[Array, dict]:
    """One token for every sequence in the batch against the cache."""
    params = cast_for_compute(cfg, params)
    compute = _dtype(cfg.compute_dtype)
    x = embed(tokens, params["embed"]["table"]).astype(compute)
    if cfg.name.startswith("gemma2"):
        x = x * jnp.asarray(cfg.d_model**0.5, compute)
    if cfg.is_encoder_decoder:
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0)[None].astype(compute)
    period = cfg.group_size
    use_rope = not cfg.is_encoder_decoder
    has_cross = cfg.is_encoder_decoder

    def body(x, xs):
        gp, gc = xs
        new_gc = dict(gc)
        for s in range(period):
            p = gp[f"slot{s}"]
            mixer, akind, ffn = slot_kinds(cfg, s)
            h = rms_norm(x, p["norm1"], cfg.norm_eps)
            if mixer == "attn":
                h, new_cache = attn.mha_decode(
                    cfg, p["mixer"], h, gc[f"slot{s}"], pos, kind=akind, use_rope=use_rope
                )
                new_gc[f"slot{s}"] = new_cache
            else:
                h, new_cache = mb.mamba_decode(cfg, p["mixer"], h, gc[f"slot{s}"])
                new_gc[f"slot{s}"] = new_cache
            x = x + h
            if has_cross:
                h = rms_norm(x, p["norm_cross"], cfg.norm_eps)
                h, _ = attn.mha_decode(
                    cfg, p["cross"], h, gc["cross"], pos, cross=True, use_rope=False
                )
                x = x + h
            if ffn != "none":
                h = rms_norm(x, p["norm2"], cfg.norm_eps)
                if ffn == "moe":
                    h, _ = moe_mod.moe_apply(cfg, p["ffn"], h, decode=True)
                else:
                    h = swiglu(h, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"])
                x = x + h
        return x, new_gc

    x, new_cache = jax.lax.scan(body, x, (params["groups"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, x)
    return logits[:, 0], new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict) -> Array:
    """Prefill forward: full-sequence compute, last-position logits only
    (serving never materializes the (B, S, vocab) logit tensor; cache
    writing is exercised by the decode cells)."""
    x, _ = forward_hidden(cfg, params, batch)
    return _unembed(cfg, params, x[:, -1:])[:, 0]
